//! Fig. 11 — normalized performance per DSP: (a) ΔFD throughput / DSP
//! (DRACO vs Dadu-RBD), (b) latency × DSP (DRACO vs Roboshape). Paper
//! bands: 4.2–5.8× higher throughput/DSP; 0.71–0.86× latency·DSP.

use draco::accel::{estimate, Design, RbdFn};
use draco::model::builtin_robot;
use draco::util::bench::Table;

fn main() {
    let mut ta = Table::new(&["robot", "design", "tput/DSP", "vs dadu"]);
    for name in ["iiwa", "hyq", "atlas"] {
        let robot = builtin_robot(name).unwrap();
        let draco = estimate(&Design::draco(&robot), &robot, RbdFn::DeltaFd);
        let dadu = estimate(&Design::dadu_rbd(&robot), &robot, RbdFn::DeltaFd);
        // Normalize by the chip's DSP budget (what Table II reports),
        // not just the momentarily-active slices.
        let d_eff = draco.throughput / Design::draco(&robot).dsp_budget as f64;
        let b_eff = dadu.throughput / Design::dadu_rbd(&robot).dsp_budget as f64;
        ta.row(&[name.into(), "dadu-rbd".into(), format!("{b_eff:.1}"), "1.00x".into()]);
        ta.row(&[
            name.into(),
            "draco".into(),
            format!("{d_eff:.1}"),
            format!("{:.2}x", d_eff / b_eff),
        ]);
    }
    ta.print("Fig 11(a) — ΔFD throughput per DSP (paper: 4.2–5.8x)");

    let mut tb = Table::new(&["robot", "design", "lat*DSP", "draco/roboshape"]);
    for name in ["iiwa", "hyq"] {
        let robot = builtin_robot(name).unwrap();
        let draco = estimate(&Design::draco(&robot), &robot, RbdFn::DeltaFd);
        let rs = estimate(&Design::roboshape(&robot), &robot, RbdFn::DeltaFd);
        let d = draco.latency_us * Design::draco(&robot).dsp_budget as f64;
        let r = rs.latency_us * Design::roboshape(&robot).dsp_budget as f64;
        tb.row(&[name.into(), "roboshape".into(), format!("{r:.0}"), "1.00x".into()]);
        tb.row(&[name.into(), "draco".into(), format!("{d:.0}"), format!("{:.2}x", d / r)]);
    }
    tb.print("Fig 11(b) — ΔFD latency × DSP (paper: 0.71–0.86x, lower is better)");
}
