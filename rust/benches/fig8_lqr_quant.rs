//! Fig. 8 — quantization effects under LQR and MPC on the iiwa:
//! (a) dynamics-derivative error after quantization,
//! (b) control-torque output difference,
//! (c) end-effector trajectory error,
//! (d) MPC optimization-cost comparison,
//! (e) MPC end-effector 3-D trajectory deviation.
//!
//! Paper shape: LQR/MPC are tolerant — trajectory deviations < 0.01 mm
//! (LQR) and < 0.02 mm (MPC at 9-bit frac) despite visible effects on
//! internal quantities.

use draco::control::backend::RbdBackend;
use draco::model::{builtin_robot, State};
use draco::quant::QFormat;
use draco::sim::icms::{compare_runs, run_closed_loop, ControllerKind, IcmsConfig};
use draco::util::bench::Table;
use draco::util::rng::Rng;

fn main() {
    let robot = builtin_robot("iiwa").unwrap();
    // Controller-specific searched formats (§V-A): LQR 10-bit frac,
    // MPC 9-bit frac.
    let lqr_fmt = QFormat::new(12, 10);
    let mpc_fmt = QFormat::new(12, 9);

    // ---- (a) dynamics derivative error
    let mut rng = Rng::new(60);
    let s = State::random(&robot, &mut rng);
    let tau = rng.vec_range(robot.dof(), -5.0, 5.0);
    let (dq_e, dqd_e, _) = RbdBackend::Exact.fd_derivatives(&robot, &s.q, &s.qd, &tau);
    let (dq_q, dqd_q, _) =
        RbdBackend::Quantized(lqr_fmt).fd_derivatives(&robot, &s.q, &s.qd, &tau);
    println!("== Fig 8(a) — ΔFD quantization error (LQR format {}) ==", lqr_fmt.label());
    println!(
        "‖δ(∂q̈/∂q)‖F = {:.4}  (rel {:.2e}), ‖δ(∂q̈/∂q̇)‖F = {:.4}",
        dq_e.sub(&dq_q).frobenius(),
        dq_e.sub(&dq_q).frobenius() / dq_e.frobenius(),
        dqd_e.sub(&dqd_q).frobenius()
    );

    // ---- (b)(c) closed-loop LQR comparison
    let mut cfg = IcmsConfig::default_for(&robot, ControllerKind::Lqr);
    cfg.steps = 1200;
    let float_run = run_closed_loop(&robot, &cfg, RbdBackend::Exact);
    let quant_run = run_closed_loop(&robot, &cfg, RbdBackend::Quantized(lqr_fmt));
    let m = compare_runs(&float_run, &quant_run);
    let mut t = Table::new(&["metric", "value"]);
    t.row(&["max ‖Δτ‖ [Nm]".into(), format!("{:.4}", m.torque_diff_max)]);
    t.row(&["mean ‖Δτ‖ [Nm]".into(), format!("{:.4}", m.torque_diff_mean)]);
    t.row(&["max EE deviation [mm]".into(), format!("{:.4}", m.traj_err_max * 1e3)]);
    t.row(&["mean EE deviation [mm]".into(), format!("{:.4}", m.traj_err_mean * 1e3)]);
    t.print("Fig 8(b,c) — LQR torque & trajectory deviation (paper: traj < 0.01 mm)");

    // ---- (d)(e) MPC cost + trajectory
    let mut cfg = IcmsConfig::default_for(&robot, ControllerKind::Mpc);
    cfg.steps = 300;
    let float_run = run_closed_loop(&robot, &cfg, RbdBackend::Exact);
    let quant_run = run_closed_loop(&robot, &cfg, RbdBackend::Quantized(mpc_fmt));
    let m = compare_runs(&float_run, &quant_run);
    println!("\n== Fig 8(d,e) — MPC ({}) ==", mpc_fmt.label());
    println!("max EE deviation: {:.4} mm (paper: < 0.02 mm at 9-bit frac)", m.traj_err_max * 1e3);
    // 3-D trajectory sample (decimated) for the (e)-style series.
    println!("EE path (float vs quant), every 60th step:");
    for k in (0..float_run.ee.len()).step_by(60) {
        let a = float_run.ee[k];
        let b = quant_run.ee[k];
        println!(
            "  t={:.2}s  float ({:+.4},{:+.4},{:+.4})  quant ({:+.4},{:+.4},{:+.4})",
            float_run.t[k], a[0], a[1], a[2], b[0], b[1], b[2]
        );
    }
}
