//! Fig. 10 — latency and throughput of every RBD function across robots
//! and platforms: CPU (measured on this machine), GPU (GRiD-modeled),
//! Roboshape / Dadu-RBD / DRACO (cycle model). Also prints Table I.
//!
//! Protocol mirrors §V-B: latency from single-task execution, throughput
//! from 256-task batches.

use draco::accel::platforms::TABLE1;
use draco::accel::{estimate, gpu_model, Design, RbdFn};
use draco::dynamics::{fd, fd_derivatives, minv, rnea, rnea_derivatives};
use draco::model::{builtin_robot, Robot, State};
use draco::util::bench::{time_auto, Stats, Table};
use draco::util::rng::Rng;
use std::hint::black_box;

fn measure_cpu(robot: &Robot, f: RbdFn) -> Stats {
    let n = robot.dof();
    let mut rng = Rng::new(5);
    let s = State::random(robot, &mut rng);
    let qdd = rng.vec_range(n, -2.0, 2.0);
    let tau = rnea(robot, &s.q, &s.qd, &qdd, None);
    let r = robot.clone();
    match f {
        RbdFn::Id => time_auto(40.0, move || {
            black_box(rnea(&r, &s.q, &s.qd, &qdd, None));
        }),
        RbdFn::Minv => time_auto(40.0, move || {
            black_box(minv(&r, &s.q));
        }),
        RbdFn::Fd => time_auto(40.0, move || {
            black_box(fd(&r, &s.q, &s.qd, &tau, None));
        }),
        RbdFn::DeltaId => time_auto(40.0, move || {
            black_box(rnea_derivatives(&r, &s.q, &s.qd, &qdd));
        }),
        RbdFn::DeltaFd => time_auto(40.0, move || {
            black_box(fd_derivatives(&r, &s.q, &s.qd, &tau));
        }),
    }
}

fn main() {
    // Table I.
    let mut t1 = Table::new(&["type", "platform", "freq", "evaluated in"]);
    for p in TABLE1 {
        t1.row(&[
            p.kind.to_string(),
            p.name.to_string(),
            format!("{:.0}M", p.freq_hz / 1e6),
            p.evaluated_in.to_string(),
        ]);
    }
    t1.print("Table I — hardware configurations");

    for name in ["iiwa", "hyq", "atlas", "baxter"] {
        let robot = builtin_robot(name).unwrap();
        let mut t = Table::new(&["fn", "platform", "latency(us)", "tput(tasks/s)"]);
        let fns: &[RbdFn] = if name == "baxter" {
            &[RbdFn::DeltaFd] // paper: Baxter is only reported for ΔFD
        } else {
            &RbdFn::ALL
        };
        for &f in fns {
            let cpu = measure_cpu(&robot, f);
            t.row(&[
                f.name().into(),
                "cpu (measured)".into(),
                format!("{:.2}", cpu.median_us()),
                format!("{:.3e}", cpu.throughput(1)),
            ]);
            let g = gpu_model(&robot, f);
            t.row(&[
                f.name().into(),
                "gpu-grid (model)".into(),
                format!("{:.2}", g.latency_us),
                format!("{:.3e}", g.throughput),
            ]);
            for d in [Design::roboshape(&robot), Design::dadu_rbd(&robot), Design::draco(&robot)]
            {
                let p = estimate(&d, &robot, f);
                t.row(&[
                    f.name().into(),
                    d.name.into(),
                    format!("{:.2}", p.latency_us),
                    format!("{:.3e}", p.throughput),
                ]);
            }
            // Paper headline ratios (DRACO vs Dadu-RBD).
            let a = estimate(&Design::draco(&robot), &robot, f);
            let b = estimate(&Design::dadu_rbd(&robot), &robot, f);
            t.row(&[
                f.name().into(),
                "→ draco/dadu".into(),
                format!("{:.2}x", b.latency_us / a.latency_us),
                format!("{:.2}x", a.throughput / b.throughput),
            ]);
        }
        t.print(&format!("Fig 10 — {name}"));
    }
    println!("\npaper bands: throughput +2.2–8x, latency −2.3–7.4x vs Dadu-RBD;");
    println!("latency −1.1–2.6x vs Roboshape. Shapes (who wins, rough factor) should match.");
}
