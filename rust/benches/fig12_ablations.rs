//! Fig. 12 ablations:
//! (a) standalone Minv latency with vs without division deferring —
//!     identical quantization/DSP/MAC configuration (paper: >2×);
//! (b) DSP consumption with vs without inter-module reuse
//!     (paper: −2.7% iiwa, −16.1% Atlas).
//! Also validates the deferred algorithm numerically and replays the
//! staggered divider schedule of Fig. 6(b).

use draco::accel::{estimate, reuse_report, Design, RbdFn};
use draco::dynamics::{minv, minv_dd_traced};
use draco::model::{builtin_robot, State};
use draco::util::bench::Table;
use draco::util::rng::Rng;

fn main() {
    // ---- Fig 12(a)
    let mut ta = Table::new(&["robot", "w/o dd (us)", "w/ dd (us)", "speedup", "tput gain"]);
    for name in ["iiwa", "hyq", "atlas"] {
        let robot = builtin_robot(name).unwrap();
        let with_dd = estimate(&Design::draco(&robot), &robot, RbdFn::Minv);
        let without = estimate(&Design::draco_no_dd(&robot), &robot, RbdFn::Minv);
        ta.row(&[
            name.into(),
            format!("{:.2}", without.latency_us),
            format!("{:.2}", with_dd.latency_us),
            format!("{:.2}x", without.latency_us / with_dd.latency_us),
            format!("{:.2}x", with_dd.throughput / without.throughput),
        ]);
    }
    ta.print("Fig 12(a) — Minv latency, division deferring (paper: >2x)");

    // Numerical equivalence + divider schedule.
    let robot = builtin_robot("iiwa").unwrap();
    let mut rng = Rng::new(3);
    let s = State::random(&robot, &mut rng);
    let (mi_dd, queue) = minv_dd_traced(&robot, &s.q);
    let mi = minv(&robot, &s.q);
    println!(
        "\ndeferred == original: |Δ|∞ = {:.2e}; divider requests (tip→base): {:?}",
        mi.sub(&mi_dd).max_abs(),
        queue.requests.iter().map(|(j, _)| *j).collect::<Vec<_>>()
    );

    // ---- Fig 12(b)
    let mut tb = Table::new(&["robot", "DSP w/ reuse", "DSP w/o", "saved", "II solo→comp"]);
    for name in ["iiwa", "hyq", "atlas"] {
        let robot = builtin_robot(name).unwrap();
        let r = reuse_report(&Design::draco(&robot), &robot);
        tb.row(&[
            name.into(),
            r.dsp_with.to_string(),
            r.dsp_without.to_string(),
            format!("{:.1}%", r.savings_frac * 100.0),
            format!("{}→{}", r.ii_rnea_solo, r.ii_composite),
        ]);
    }
    tb.print("Fig 12(b) — inter-module DSP reuse (paper: 2.7% iiwa, 16.1% atlas; shape: atlas >> iiwa)");
}
