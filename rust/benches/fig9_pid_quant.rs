//! Fig. 9 — PID with dynamics compensation under different quantization
//! settings: temporal evolution of (a) the second joint's posture
//! difference and (b) the end-effector trajectory difference, float vs
//! quantized control of a reach-and-hold task.
//!
//! Paper shape: PID is the most sensitive controller; errors stay small
//! during the large correction phase and accumulate near convergence —
//! 8-bit frac exceeds 1 mm near the target; 12/16-bit stay adequate.

use draco::control::backend::RbdBackend;
use draco::model::builtin_robot;
use draco::quant::QFormat;
use draco::sim::icms::{compare_runs, run_closed_loop, ControllerKind, IcmsConfig};
use draco::sim::traj::Trajectory;
use draco::util::bench::Table;

fn main() {
    let robot = builtin_robot("iiwa").unwrap();
    let mut cfg = IcmsConfig::default_for(&robot, ControllerKind::Pid);
    cfg.steps = 2500;
    cfg.traj = Trajectory::reach(&robot, 0.4, 1.2);

    let float_run = run_closed_loop(&robot, &cfg, RbdBackend::Exact);

    let formats = [
        ("16-frac", QFormat::new(12, 16)),
        ("12-frac", QFormat::new(12, 12)),
        ("8-frac", QFormat::new(12, 8)),
        ("6-frac", QFormat::new(12, 6)),
    ];

    let mut summary = Table::new(&["format", "max EE diff (mm)", "final EE diff (mm)", "final j2 diff (rad)"]);
    let mut series: Vec<(String, Vec<f64>, Vec<f64>)> = Vec::new();
    for (label, fmt) in formats {
        let quant_run = run_closed_loop(&robot, &cfg, RbdBackend::Quantized(fmt));
        let m = compare_runs(&float_run, &quant_run);
        // Joint-2 posture difference over time.
        let j2: Vec<f64> = float_run
            .q
            .iter()
            .zip(&quant_run.q)
            .map(|(a, b)| (a[1] - b[1]).abs())
            .collect();
        summary.row(&[
            label.into(),
            format!("{:.4}", m.traj_err_max * 1e3),
            format!("{:.4}", m.ee_diff.last().unwrap() * 1e3),
            format!("{:.2e}", j2.last().unwrap()),
        ]);
        series.push((label.into(), m.ee_diff.clone(), j2));
    }
    summary.print("Fig 9 — PID quantization sensitivity (reach-and-hold, iiwa)");

    println!("\ntemporal series (EE diff [mm], every 250 steps):");
    print!("{:>8}", "t[s]");
    for (l, _, _) in &series {
        print!("{l:>10}");
    }
    println!();
    for k in (0..cfg.steps).step_by(250) {
        print!("{:>8.2}", k as f64 * cfg.dt);
        for (_, ee, _) in &series {
            print!("{:>10.4}", ee[k] * 1e3);
        }
        println!();
    }
    println!(
        "\n(paper shape: coarser fractional bits → larger, accumulating deviation;\n\
         errors grow in the fine-convergence phase)"
    );
}
