//! Table II — hardware resource usage (DSP/LUT + FF/BRAM/power) for
//! DRACO vs the baselines. Published anchors: DRACO iiwa 5073 DSP/584k
//! LUT, Dadu-RBD iiwa 4241/638k, Roboshape iiwa 5448/515k; DRACO power
//! 33.5 W vs Dadu 36.8 W.

use draco::accel::resources::estimate_resources;
use draco::accel::Design;
use draco::model::builtin_robot;
use draco::util::bench::Table;

fn main() {
    let mut t = Table::new(&["robot", "design", "DSP", "LUT(k)", "FF(k)", "BRAM", "power(W)"]);
    for name in ["iiwa", "hyq", "atlas"] {
        let robot = builtin_robot(name).unwrap();
        for d in [Design::draco(&robot), Design::dadu_rbd(&robot), Design::roboshape(&robot)] {
            let r = estimate_resources(&d, &robot);
            t.row(&[
                name.into(),
                d.name.into(),
                r.dsp.to_string(),
                (r.lut / 1000).to_string(),
                (r.ff / 1000).to_string(),
                r.bram.to_string(),
                format!("{:.1}", r.power_w),
            ]);
        }
    }
    t.print("Table II — resource usage (model; published DSP anchors exact)");
    println!("\npaper anchors: iiwa DSP 5073/4241/5448 (draco/dadu/roboshape);");
    println!("LUT 584k/638k/515k; DRACO 371k FF, 167 BRAM, 33.5 W total power.");
}
