//! Fig. 5(c)/(d) — quantization error propagation and compensation:
//! (c) per-joint velocity quantization error on the iiwa (errors
//!     accumulate with joint depth);
//! (d) element-wise and Frobenius error of quantized M⁻¹ before/after
//!     the diagonal offset compensation (paper: Frobenius 4.97 → 1.65,
//!     off-diagonal 0.23 → 0.36).

use draco::model::builtin_robot;
use draco::quant::analyzer::velocity_error_profile;
use draco::quant::compensate::{evaluate_compensation, MinvCompensation};
use draco::quant::QFormat;
use draco::util::bench::Table;
use draco::util::rng::Rng;

fn main() {
    let robot = builtin_robot("iiwa").unwrap();

    // ---- Fig 5(c)
    let mut t = Table::new(&["joint", "depth", "mean |δv|", "max |δv|"]);
    let mut rng = Rng::new(50);
    let prof = velocity_error_profile(&robot, QFormat::new(10, 8), 256, &mut rng);
    for i in 0..robot.dof() {
        t.row(&[
            robot.links[i].name.clone(),
            robot.depth(i).to_string(),
            format!("{:.3e}", prof.mean_abs_err[i]),
            format!("{:.3e}", prof.max_abs_err[i]),
        ]);
    }
    t.print("Fig 5(c) — per-joint velocity quantization error, iiwa @18-bit (10.8)");
    println!("(expected shape: error grows with joint depth — heuristic ❶)");

    // ---- Fig 5(d)
    let fmt = QFormat::new(10, 8);
    let mut rng = Rng::new(51);
    let comp = MinvCompensation::fit(&robot, fmt, 32, &mut rng);
    let rep = evaluate_compensation(&robot, &comp, 24, &mut rng);
    let mut t2 = Table::new(&["metric", "before", "after"]);
    t2.row(&[
        "Frobenius".into(),
        format!("{:.3}", rep.frobenius_before),
        format!("{:.3}", rep.frobenius_after),
    ]);
    t2.row(&[
        "diag mean |err|".into(),
        format!("{:.4}", rep.diag_mean_before),
        format!("{:.4}", rep.diag_mean_after),
    ]);
    t2.row(&[
        "offdiag mean |err|".into(),
        format!("{:.4}", rep.offdiag_mean_before),
        format!("{:.4}", rep.offdiag_mean_after),
    ]);
    t2.print("Fig 5(d) — quantized M⁻¹ error, before/after diagonal compensation");
    println!(
        "(paper: Frobenius 4.97→1.65 with a slight off-diagonal increase 0.23→0.36;\n\
         expected shape: large Frobenius/diagonal improvement, off-diagonal may worsen)"
    );
}
