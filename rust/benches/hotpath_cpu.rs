//! CPU hot-path microbenchmarks: wall-clock cost of every RBD function on
//! this machine (single thread). These are the *measured* CPU baseline
//! rows feeding Fig. 10/13, and the profile target of the perf pass
//! (EXPERIMENTS.md §Perf).

use draco::dynamics::{aba, crba, fd, minv, minv_dd, rnea, rnea_derivatives};
use draco::model::{builtin_robot, State};
use draco::util::bench::{time_auto, Table};
use draco::util::rng::Rng;
use std::hint::black_box;

fn main() {
    let mut t = Table::new(&["robot", "fn", "median(us)", "mean(us)", "tasks/s"]);
    for name in ["iiwa", "hyq", "atlas", "baxter"] {
        let robot = builtin_robot(name).unwrap();
        let n = robot.dof();
        let mut rng = Rng::new(1);
        let s = State::random(&robot, &mut rng);
        let qdd = rng.vec_range(n, -2.0, 2.0);
        let tau = rnea(&robot, &s.q, &s.qd, &qdd, None);

        let cases: Vec<(&str, Box<dyn FnMut()>)> = vec![
            ("rnea", {
                let (r, s, q) = (robot.clone(), s.clone(), qdd.clone());
                Box::new(move || {
                    black_box(rnea(&r, &s.q, &s.qd, &q, None));
                })
            }),
            ("crba", {
                let (r, s) = (robot.clone(), s.clone());
                Box::new(move || {
                    black_box(crba(&r, &s.q));
                })
            }),
            ("minv", {
                let (r, s) = (robot.clone(), s.clone());
                Box::new(move || {
                    black_box(minv(&r, &s.q));
                })
            }),
            ("minv_dd", {
                let (r, s) = (robot.clone(), s.clone());
                Box::new(move || {
                    black_box(minv_dd(&r, &s.q));
                })
            }),
            ("fd", {
                let (r, s, tt) = (robot.clone(), s.clone(), tau.clone());
                Box::new(move || {
                    black_box(fd(&r, &s.q, &s.qd, &tt, None));
                })
            }),
            ("aba", {
                let (r, s, tt) = (robot.clone(), s.clone(), tau.clone());
                Box::new(move || {
                    black_box(aba(&r, &s.q, &s.qd, &tt, None));
                })
            }),
            ("drnea", {
                let (r, s, q) = (robot.clone(), s.clone(), qdd.clone());
                Box::new(move || {
                    black_box(rnea_derivatives(&r, &s.q, &s.qd, &q));
                })
            }),
        ];
        for (fname, mut f) in cases {
            let st = time_auto(60.0, &mut f);
            t.row(&[
                name.to_string(),
                fname.to_string(),
                format!("{:.2}", st.median_us()),
                format!("{:.2}", st.mean_us()),
                format!("{:.0}", st.throughput(1)),
            ]);
        }
    }
    t.print("CPU hot paths (measured, single thread)");
}
