//! CPU hot-path microbenchmarks: wall-clock cost of every RBD function on
//! this machine (single thread). These are the *measured* CPU baseline
//! rows feeding Fig. 10/13, and the profile target of the perf pass
//! (EXPERIMENTS.md §Perf).
//!
//! Each function is measured twice where a workspace kernel exists:
//! the allocating path (fresh buffers per call, the pre-workspace
//! behaviour) and the `*_ws` path (one reused [`DynWorkspace`], the
//! serving hot path). On top of the per-robot kernel rows, the serving
//! paths are measured too: the quantized native backend
//! (`fd_quant64_ws`), trajectory rollouts through the workspace
//! integrator (`traj64_step_ws`, per step), and a multi-robot mixed
//! batch through the registry coordinator (`serve_fd_mixed64`, robot
//! "mixed" — dispatch and batching included). Results are also written
//! to `BENCH_hotpath.json` (schema `draco.hotpath.v1`) so successive PRs
//! can track the perf trajectory. Pass `--quick` for a smoke run (CI).
//!
//! Parallel-serving rows: `fd_pool64` (the worker-pool handoff — one
//! 64-task batch fanned across the persistent pool), `trace_overhead`
//! (the same pooled batch plus the per-job disabled-tracing span path —
//! must stay within 2% of `fd_pool64`), `serve_fd_par64`
//! (64 FD requests through a coordinator route with intra-route
//! parallelism, to compare against the serial `serve_fd_mixed64`
//! baseline at the same dispatch cost), and `serve_fd_quant_par64` (the
//! same shape through a QUANTIZED route on the engine-generic pool).
//! Quantized-lane rows: `fd_quant64_ws` (legacy rounded-f64 lane) vs
//! `fd_quant_int64` / `minv_quant_int64` (the true-integer i64 lane at
//! the same format and operands — the integer lane should win),
//! `minv_qint_deferred64` (the division-deferring integer M⁻¹ under its
//! shift schedule vs the inline-divider row), `fd_qint_srv64` (the qint
//! serving engine, batched), and `serve_fd_qint_par64` (a qint route on
//! the pool). `mul6_flat` times the flattened branch-free 6×6 kernel
//! that dominates the Minv sweeps.
//!
//! Fused-route rows: `dyn_all_fused64` (one kinematics pass feeding q̈,
//! the deferred M⁻¹, and the RNEA bias) vs `dyn_all_separate64` (the
//! same three outputs through the three separate route kernels — the
//! fused sweep must win), `dyn_all_qint64` (the i64 fused sweep), and
//! `serve_dyn_all_par64` (64 fused requests through a pooled native
//! route, per-worker kinematics memos warm).
//!
//! Network-path rows: `json_lazy_vs_full` (the lazy hot-field scanner
//! over a 64-line request corpus) vs `json_full_tree64` (the full
//! `Json` tree parse of the same lines), and `serve_net_jsonl` (64 FD
//! requests pipelined over a real TCP JSONL connection — framing, lazy
//! ingest, and response streaming included; compare with
//! `serve_fd_par64` for the protocol tax).

use draco::coordinator::{BackendKind, Coordinator, RobotRegistry};
use draco::dynamics::{
    aba, crba, eval_batch, fd, minv, minv_dd, rnea, rnea_derivatives, BatchKernel, BatchTask,
    DynWorkspace, WorkerPool,
};
use draco::model::{builtin_robot, Robot, State};
use draco::net::frame::{req_step_line, req_traj_line};
use draco::net::{Frame, LazyReq, NetClient, NetServer};
use draco::obs::{ObsHub, Terminal};
use draco::quant::scaling::validate_int_backend;
use draco::quant::{QFormat, QuantIntScratch};
use draco::runtime::artifact::ArtifactFn;
use draco::runtime::{NativeEngine, QIntEngine, QuantEngine};
use draco::spatial::mat6::{mul6, xtax};
use draco::spatial::DMat;
use draco::util::bench::{time_auto, Table};
use draco::util::json::{self, Json};
use draco::util::rng::Rng;
use std::collections::BTreeMap;
use std::hint::black_box;
use std::sync::Arc;

const BATCH: usize = 64;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let target_ms = if quick { 8.0 } else { 60.0 };

    let mut t = Table::new(&["robot", "fn", "median(us)", "mean(us)", "tasks/s"]);
    let mut rows_json: Vec<Json> = Vec::new();
    let mut medians: BTreeMap<(String, String), f64> = BTreeMap::new();

    for name in ["iiwa", "hyq", "atlas", "baxter"] {
        let robot = builtin_robot(name).unwrap();
        let n = robot.dof();
        let mut rng = Rng::new(1);
        let s = State::random(&robot, &mut rng);
        let qdd = rng.vec_range(n, -2.0, 2.0);
        let tau = rnea(&robot, &s.q, &s.qd, &qdd, None);
        let batch_tasks: Vec<BatchTask> = (0..BATCH)
            .map(|_| {
                let st = State::random(&robot, &mut rng);
                BatchTask { q: st.q, qd: st.qd, u: rng.vec_range(n, -8.0, 8.0) }
            })
            .collect();

        // (label, tasks per iteration, measured closure)
        let cases: Vec<(&str, usize, Box<dyn FnMut()>)> = vec![
            ("rnea", 1, {
                let (r, s, q) = (robot.clone(), s.clone(), qdd.clone());
                Box::new(move || {
                    black_box(rnea(&r, &s.q, &s.qd, &q, None));
                })
            }),
            ("rnea_ws", 1, {
                let (r, s, q) = (robot.clone(), s.clone(), qdd.clone());
                let mut ws = DynWorkspace::new(&robot);
                let mut out = vec![0.0; n];
                Box::new(move || {
                    ws.rnea_into(&r, &s.q, &s.qd, &q, None, &mut out);
                    black_box(&out);
                })
            }),
            ("crba", 1, {
                let (r, s) = (robot.clone(), s.clone());
                Box::new(move || {
                    black_box(crba(&r, &s.q));
                })
            }),
            ("crba_ws", 1, {
                let (r, s) = (robot.clone(), s.clone());
                let mut ws = DynWorkspace::new(&robot);
                let mut m = DMat::zeros(n, n);
                Box::new(move || {
                    ws.crba_into(&r, &s.q, &mut m);
                    black_box(&m);
                })
            }),
            ("minv", 1, {
                let (r, s) = (robot.clone(), s.clone());
                Box::new(move || {
                    black_box(minv(&r, &s.q));
                })
            }),
            ("minv_dd", 1, {
                let (r, s) = (robot.clone(), s.clone());
                Box::new(move || {
                    black_box(minv_dd(&r, &s.q));
                })
            }),
            ("minv_ws", 1, {
                let (r, s) = (robot.clone(), s.clone());
                let mut ws = DynWorkspace::new(&robot);
                let mut m = DMat::zeros(n, n);
                Box::new(move || {
                    ws.minv_into(&r, &s.q, &mut m);
                    black_box(&m);
                })
            }),
            ("fd", 1, {
                let (r, s, tt) = (robot.clone(), s.clone(), tau.clone());
                Box::new(move || {
                    black_box(fd(&r, &s.q, &s.qd, &tt, None));
                })
            }),
            ("fd_ws", 1, {
                let (r, s, tt) = (robot.clone(), s.clone(), tau.clone());
                let mut ws = DynWorkspace::new(&robot);
                let mut out = vec![0.0; n];
                Box::new(move || {
                    ws.fd_into(&r, &s.q, &s.qd, &tt, None, &mut out);
                    black_box(&out);
                })
            }),
            ("aba", 1, {
                let (r, s, tt) = (robot.clone(), s.clone(), tau.clone());
                Box::new(move || {
                    black_box(aba(&r, &s.q, &s.qd, &tt, None));
                })
            }),
            ("aba_ws", 1, {
                let (r, s, tt) = (robot.clone(), s.clone(), tau.clone());
                let mut ws = DynWorkspace::new(&robot);
                let mut out = vec![0.0; n];
                Box::new(move || {
                    ws.aba_into(&r, &s.q, &s.qd, &tt, None, &mut out);
                    black_box(&out);
                })
            }),
            ("fd_batch64", BATCH, {
                let r = robot.clone();
                let tasks = batch_tasks;
                Box::new(move || {
                    black_box(eval_batch(&r, BatchKernel::Fd, &tasks));
                })
            }),
            ("drnea", 1, {
                let (r, s, q) = (robot.clone(), s.clone(), qdd.clone());
                Box::new(move || {
                    black_box(rnea_derivatives(&r, &s.q, &s.qd, &q));
                })
            }),
        ];
        for (fname, batch, mut f) in cases {
            let st = time_auto(target_ms, &mut f);
            let per_task_median = st.median_us() / batch as f64;
            let tasks_s = st.throughput(batch);
            t.row(&[
                name.to_string(),
                fname.to_string(),
                format!("{per_task_median:.2}"),
                format!("{:.2}", st.mean_us() / batch as f64),
                format!("{tasks_s:.0}"),
            ]);
            medians.insert((name.to_string(), fname.to_string()), per_task_median);
            rows_json.push(json::obj(vec![
                ("robot", json::s(name)),
                ("fn", json::s(fname)),
                ("median_us", json::num(per_task_median)),
                ("mean_us", json::num(st.mean_us() / batch as f64)),
                ("tasks_per_s", json::num(tasks_s)),
            ]));
        }
    }

    // Serving-path rows: the quantized native backend, trajectory
    // rollouts, and a multi-robot mixed batch through the registry
    // coordinator (per-robot backends, channel dispatch included).
    {
        let mut add = |robot: &str, fname: &str, st: &draco::util::bench::Stats, batch: usize| {
            let per_task_median = st.median_us() / batch as f64;
            let tasks_s = st.throughput(batch);
            t.row(&[
                robot.to_string(),
                fname.to_string(),
                format!("{per_task_median:.2}"),
                format!("{:.2}", st.mean_us() / batch as f64),
                format!("{tasks_s:.0}"),
            ]);
            medians.insert((robot.to_string(), fname.to_string()), per_task_median);
            rows_json.push(json::obj(vec![
                ("robot", json::s(robot)),
                ("fn", json::s(fname)),
                ("median_us", json::num(per_task_median)),
                ("mean_us", json::num(st.mean_us() / batch as f64)),
                ("tasks_per_s", json::num(tasks_s)),
            ]));
        };

        let flat_fd_inputs = |robot: &Robot, b: usize, seed: u64| -> Vec<Vec<f32>> {
            let n = robot.dof();
            let mut rng = Rng::new(seed);
            let mut q = Vec::with_capacity(b * n);
            let mut qd = Vec::with_capacity(b * n);
            let mut u = Vec::with_capacity(b * n);
            for _ in 0..b {
                let s = State::random(robot, &mut rng);
                q.extend(s.q.iter().map(|&x| x as f32));
                qd.extend(s.qd.iter().map(|&x| x as f32));
                u.extend(rng.vec_range(n, -6.0, 6.0).iter().map(|&x| x as f32));
            }
            vec![q, qd, u]
        };

        let iiwa = builtin_robot("iiwa").unwrap();
        let atlas = builtin_robot("atlas").unwrap();

        // Quantized native engine, batched FD at the paper's 24-bit
        // format (the legacy rounded-f64 lane).
        let inputs = flat_fd_inputs(&iiwa, BATCH, 2);
        let mut qeng = QuantEngine::new(iiwa.clone(), ArtifactFn::Fd, BATCH, QFormat::new(12, 12));
        let st = time_auto(target_ms, || {
            black_box(qeng.run(&inputs).expect("quant fd batch"));
        });
        add("iiwa", "fd_quant64_ws", &st, BATCH);

        // True-integer fixed-point lane at the same format and the same
        // 64 operands, including the identical per-task f32 decode /
        // encode the engine performs — apples-to-apples with
        // fd_quant64_ws. The integer lane quantizes constants once on
        // ingest and runs i64 mul/shift inner loops.
        {
            let n = iiwa.dof();
            let fmt_int = QFormat::new(12, 12);
            let mut iws = QuantIntScratch::new(n);
            let (mut q, mut qd, mut u, mut o) =
                (vec![0.0f64; n], vec![0.0f64; n], vec![0.0f64; n], vec![0.0f64; n]);
            let mut out32 = vec![0.0f32; BATCH * n];
            let st = time_auto(target_ms, || {
                for k in 0..BATCH {
                    let span = k * n..(k + 1) * n;
                    for (d, s) in q.iter_mut().zip(&inputs[0][span.clone()]) {
                        *d = *s as f64;
                    }
                    for (d, s) in qd.iter_mut().zip(&inputs[1][span.clone()]) {
                        *d = *s as f64;
                    }
                    for (d, s) in u.iter_mut().zip(&inputs[2][span.clone()]) {
                        *d = *s as f64;
                    }
                    iws.fd_into(&iiwa, &q, &qd, &u, fmt_int, &mut o);
                    for (d, s) in out32[span].iter_mut().zip(&o) {
                        *d = *s as f32;
                    }
                }
                black_box(&out32);
            });
            add("iiwa", "fd_quant_int64", &st, BATCH);

            // Integer M⁻¹ over the same 64 q-rows.
            let mut mi = DMat::zeros(n, n);
            let mut out32 = vec![0.0f32; BATCH * n * n];
            let st = time_auto(target_ms, || {
                for k in 0..BATCH {
                    for (d, s) in q.iter_mut().zip(&inputs[0][k * n..(k + 1) * n]) {
                        *d = *s as f64;
                    }
                    iws.minv_into(&iiwa, &q, fmt_int, &mut mi);
                    for (d, s) in out32[k * n * n..(k + 1) * n * n].iter_mut().zip(&mi.d) {
                        *d = *s as f32;
                    }
                }
                black_box(&out32);
            });
            add("iiwa", "minv_quant_int64", &st, BATCH);

            // Division-deferring integer M⁻¹ under the proved shift
            // schedule — compare with the inline-divider minv_quant_int64
            // row above at the same format and q-rows.
            let sched = validate_int_backend(&iiwa, fmt_int).expect("iiwa@12.12 accepted");
            let st = time_auto(target_ms, || {
                for k in 0..BATCH {
                    for (d, s) in q.iter_mut().zip(&inputs[0][k * n..(k + 1) * n]) {
                        *d = *s as f64;
                    }
                    iws.minv_dd_into(&iiwa, &q, &sched, &mut mi);
                    for (d, s) in out32[k * n * n..(k + 1) * n * n].iter_mut().zip(&mi.d) {
                        *d = *s as f32;
                    }
                }
                black_box(&out32);
            });
            add("iiwa", "minv_qint_deferred64", &st, BATCH);

            // Fused INTEGER sweep: one integer kinematics ingest per
            // task feeding q̈, the deferred M⁻¹ rows, and the fixed-point
            // bias — the i64 counterpart of dyn_all_fused64, same
            // per-task f32 decode/encode as the rows above.
            let per = n * n + 2 * n;
            let mut all = vec![0.0f64; per];
            let mut out32 = vec![0.0f32; BATCH * per];
            let st = time_auto(target_ms, || {
                for k in 0..BATCH {
                    let span = k * n..(k + 1) * n;
                    for (d, s) in q.iter_mut().zip(&inputs[0][span.clone()]) {
                        *d = *s as f64;
                    }
                    for (d, s) in qd.iter_mut().zip(&inputs[1][span.clone()]) {
                        *d = *s as f64;
                    }
                    for (d, s) in u.iter_mut().zip(&inputs[2][span]) {
                        *d = *s as f64;
                    }
                    iws.dyn_all_dd_into(&iiwa, &q, &qd, &u, &sched, &mut all);
                    for (d, s) in out32[k * per..(k + 1) * per].iter_mut().zip(&all) {
                        *d = *s as f32;
                    }
                }
                black_box(&out32);
            });
            add("iiwa", "dyn_all_qint64", &st, BATCH);
        }

        // The qint SERVING backend: batched FD through QIntEngine
        // (deferred integer M⁻¹ inside the fused FD, engine-level f32
        // decode/encode included) — apples-to-apples with fd_quant64_ws.
        let mut qieng = QIntEngine::new(iiwa.clone(), ArtifactFn::Fd, BATCH, QFormat::new(12, 12))
            .expect("iiwa@12.12 accepted");
        let st = time_auto(target_ms, || {
            black_box(qieng.run(&inputs).expect("qint fd batch"));
        });
        add("iiwa", "fd_qint_srv64", &st, BATCH);

        // Fused multi-output sweep: ONE kinematics pass per task feeding
        // q̈, the division-deferring M⁻¹, and the RNEA bias
        // (dyn_all_fused64) vs the same three outputs through the three
        // separate route kernels over identical operands
        // (dyn_all_separate64). The fused row must win — the separate
        // calls redo the joint transforms and composite inertias per
        // output.
        {
            let n = iiwa.dof();
            let mut drng = Rng::new(12);
            let tasks: Vec<BatchTask> = (0..BATCH)
                .map(|_| {
                    let s = State::random(&iiwa, &mut drng);
                    BatchTask { q: s.q, qd: s.qd, u: drng.vec_range(n, -6.0, 6.0) }
                })
                .collect();
            let mut ws = DynWorkspace::new(&iiwa);
            let mut fused = vec![0.0f64; n * n + 2 * n];
            let st = time_auto(target_ms, || {
                for task in &tasks {
                    ws.dyn_all_into(&iiwa, &task.q, &task.qd, &task.u, None, &mut fused);
                }
                black_box(&fused);
            });
            add("iiwa", "dyn_all_fused64", &st, BATCH);

            let mut qdd = vec![0.0f64; n];
            let mut mi = DMat::zeros(n, n);
            let mut bias = vec![0.0f64; n];
            let zero = vec![0.0f64; n];
            let st = time_auto(target_ms, || {
                for task in &tasks {
                    ws.fd_into(&iiwa, &task.q, &task.qd, &task.u, None, &mut qdd);
                    ws.minv_into(&iiwa, &task.q, &mut mi);
                    ws.rnea_into(&iiwa, &task.q, &task.qd, &zero, None, &mut bias);
                    black_box((&qdd, &mi, &bias));
                }
            });
            add("iiwa", "dyn_all_separate64", &st, BATCH);
        }

        // Trajectory rollout: 64 integrator steps per request through the
        // workspace (per-task number below = per step).
        let h = 64usize;
        let n = iiwa.dof();
        let mut rng = Rng::new(3);
        let s0 = State::random(&iiwa, &mut rng);
        let q0: Vec<f32> = s0.q.iter().map(|&x| x as f32).collect();
        let qd0: Vec<f32> = s0.qd.iter().map(|&x| x as f32).collect();
        let tau: Vec<f32> =
            rng.vec_range(h * n, -2.0, 2.0).iter().map(|&x| x as f32).collect();
        let mut teng = NativeEngine::new(iiwa.clone(), ArtifactFn::Fd, 8);
        let st = time_auto(target_ms, || {
            black_box(teng.rollout(&q0, &qd0, &tau, 1e-3).expect("rollout"));
        });
        add("iiwa", "traj64_step_ws", &st, h);

        // Multi-robot mixed batch: one registry coordinator serving iiwa
        // (f64 native) and atlas (quantized 32-bit) concurrently; 64
        // interleaved FD requests per iteration, dispatch + batching
        // included.
        let mut reg = RobotRegistry::new();
        reg.register(iiwa.clone(), BackendKind::Native, 32)
            .register(atlas.clone(), BackendKind::NativeQuant(QFormat::new(16, 16)), 32);
        let coord = Coordinator::start_registry(&reg, 100);
        let iiwa_inputs = flat_fd_inputs(&iiwa, 1, 4);
        let atlas_inputs = flat_fd_inputs(&atlas, 1, 5);
        let st = time_auto(target_ms, || {
            let mut rxs = Vec::with_capacity(64);
            for k in 0..64usize {
                let (name, ops) = if k % 2 == 0 {
                    ("iiwa", iiwa_inputs.clone())
                } else {
                    ("atlas", atlas_inputs.clone())
                };
                rxs.push(coord.submit_to(name, ArtifactFn::Fd, ops));
            }
            for rx in rxs {
                black_box(rx.recv().expect("serve answer").expect("serve ok"));
            }
        });
        add("mixed", "serve_fd_mixed64", &st, 64);
        coord.shutdown();

        // Flattened 6×6 kernels: the branch-free flat mul6 and the fused
        // congruence transform XᵀAX (256 evaluations per iteration).
        let mut krng = Rng::new(6);
        let mut a = [0.0f64; 36];
        let mut bmat = [0.0f64; 36];
        for x in a.iter_mut() {
            *x = krng.range(-1.0, 1.0);
        }
        for x in bmat.iter_mut() {
            *x = krng.range(-1.0, 1.0);
        }
        let st = time_auto(target_ms, || {
            for _ in 0..256 {
                black_box(mul6(black_box(&a), black_box(&bmat)));
            }
        });
        add("kernel", "mul6_flat", &st, 256);
        let st = time_auto(target_ms, || {
            for _ in 0..256 {
                black_box(xtax(black_box(&a), black_box(&bmat)));
            }
        });
        add("kernel", "xtax_flat", &st, 256);

        // Worker-pool handoff: one 64-task FD batch fanned across the
        // persistent global pool (chunking, channels, and reassembly
        // included) — compare with the serial fd_batch64 row.
        let pool = WorkerPool::global();
        let mut prng = Rng::new(8);
        let n = iiwa.dof();
        let pool_tasks: Vec<BatchTask> = (0..BATCH)
            .map(|_| {
                let s = State::random(&iiwa, &mut prng);
                BatchTask { q: s.q, qd: s.qd, u: prng.vec_range(n, -8.0, 8.0) }
            })
            .collect();
        let chunks = pool.threads();
        let st = time_auto(target_ms, || {
            black_box(pool.eval(&iiwa, BatchKernel::Fd, &pool_tasks, chunks));
        });
        let pool_median_us = st.median_us();
        add("iiwa", "fd_pool64", &st, BATCH);

        // Disabled-tracing tax: the identical pooled 64-task FD batch,
        // but every task additionally walks the full span hot path the
        // coordinator runs per job — one `OnceLock` load returning the
        // inert span (tracing OFF), the no-op lifecycle stamps, and the
        // terminal finish. The budget is <2% over fd_pool64 above; the
        // bench_diff gate tracks this row.
        let obs = ObsHub::new();
        let st = time_auto(target_ms, || {
            for _ in 0..BATCH {
                let mut span = obs.begin_span("iiwa", "fd", "bulk");
                span.stamp_enqueue();
                span.stamp_formed();
                span.stamp_kernel_start();
                span.stamp_kernel_end();
                span.stamp_chunk();
                span.finish(Terminal::Done);
            }
            black_box(pool.eval(&iiwa, BatchKernel::Fd, &pool_tasks, chunks));
        });
        println!(
            "disabled-tracing overhead vs fd_pool64: {:+.2}% ({:.3} vs {:.3} us/task)",
            (st.median_us() / pool_median_us - 1.0) * 100.0,
            st.median_us() / BATCH as f64,
            pool_median_us / BATCH as f64
        );
        add("iiwa", "trace_overhead", &st, BATCH);

        // Intra-route parallelism: 64 FD requests through ONE
        // coordinator route whose batches split across the worker pool —
        // the parallel counterpart of the serial serve_fd_mixed64
        // baseline (same dispatch + batching overhead, pooled execution).
        let mut preg = RobotRegistry::new();
        preg.register_parallel(iiwa.clone(), BackendKind::Native, 64, 0);
        let pcoord = Coordinator::start_registry(&preg, 100);
        let par_inputs = flat_fd_inputs(&iiwa, 1, 9);
        let st = time_auto(target_ms, || {
            let mut rxs = Vec::with_capacity(64);
            for _ in 0..64usize {
                rxs.push(pcoord.submit_to("iiwa", ArtifactFn::Fd, par_inputs.clone()));
            }
            for rx in rxs {
                black_box(rx.recv().expect("serve answer").expect("serve ok"));
            }
        });
        add("iiwa", "serve_fd_par64", &st, 64);
        pcoord.shutdown();

        // Pooled QUANTIZED serving: the same 64-request dispatch shape
        // through one quantized route whose batches fan out across the
        // engine-generic worker pool (compare with the serial quantized
        // execution inside serve_fd_mixed64 and with serve_fd_par64's
        // f64 route at identical dispatch cost).
        let mut qpreg = RobotRegistry::new();
        qpreg.register_parallel(
            iiwa.clone(),
            BackendKind::NativeQuant(QFormat::new(12, 12)),
            64,
            0,
        );
        let qpcoord = Coordinator::start_registry(&qpreg, 100);
        let qpar_inputs = flat_fd_inputs(&iiwa, 1, 10);
        let st = time_auto(target_ms, || {
            let mut rxs = Vec::with_capacity(64);
            for _ in 0..64usize {
                rxs.push(qpcoord.submit_to("iiwa", ArtifactFn::Fd, qpar_inputs.clone()));
            }
            for rx in rxs {
                black_box(rx.recv().expect("serve answer").expect("serve ok"));
            }
        });
        add("iiwa", "serve_fd_quant_par64", &st, 64);
        qpcoord.shutdown();

        // Pooled INTEGER serving: the same 64-request dispatch shape
        // through a qint route (deferred integer FD on the pool, the
        // engine's shift schedule travelling with every job) — compare
        // with serve_fd_quant_par64's rounded-f64 route at identical
        // dispatch cost.
        let mut ipreg = RobotRegistry::new();
        ipreg.register_parallel(
            iiwa.clone(),
            BackendKind::NativeInt(QFormat::new(12, 12)),
            64,
            0,
        );
        ipreg.validate().expect("iiwa@12.12 accepted");
        let ipcoord = Coordinator::start_registry(&ipreg, 100);
        let ipar_inputs = flat_fd_inputs(&iiwa, 1, 11);
        let st = time_auto(target_ms, || {
            let mut rxs = Vec::with_capacity(64);
            for _ in 0..64usize {
                rxs.push(ipcoord.submit_to("iiwa", ArtifactFn::Fd, ipar_inputs.clone()));
            }
            for rx in rxs {
                black_box(rx.recv().expect("serve answer").expect("serve ok"));
            }
        });
        add("iiwa", "serve_fd_qint_par64", &st, 64);
        ipcoord.shutdown();

        // Pooled FUSED serving: 64 `dyn_all` requests (q̈ ‖ M⁻¹ ‖ C per
        // task) through one parallel native route — the multi-output
        // flat fan-out on the worker pool, with each worker's
        // cross-request kinematics memo staying warm on the repeated
        // operands, so the row tracks the served hit-path cost.
        let mut dpreg = RobotRegistry::new();
        dpreg.register_parallel(iiwa.clone(), BackendKind::Native, 64, 0);
        let dpcoord = Coordinator::start_registry(&dpreg, 100);
        let dpar_inputs = flat_fd_inputs(&iiwa, 1, 12);
        let st = time_auto(target_ms, || {
            let mut rxs = Vec::with_capacity(64);
            for _ in 0..64usize {
                rxs.push(dpcoord.submit_to("iiwa", ArtifactFn::DynAll, dpar_inputs.clone()));
            }
            for rx in rxs {
                black_box(rx.recv().expect("serve answer").expect("serve ok"));
            }
        });
        add("iiwa", "serve_dyn_all_par64", &st, 64);
        dpcoord.shutdown();

        // Wire-ingest cost: the hand-rolled lazy hot-field scanner
        // (json_lazy_vs_full — id/robot/route/class/deadline extracted,
        // payloads left as byte spans) against the full Json tree parse
        // (json_full_tree64) over the same 64-line request corpus the
        // net front-end sees: 48 step requests + 16 trajectory requests
        // with their large tau arrays. The lazy row must win — it is the
        // per-line admission cost of every socket request.
        {
            let n = iiwa.dof();
            let mut jrng = Rng::new(14);
            let mut vecf = |len: usize| -> Vec<f32> {
                jrng.vec_range(len, -1.0, 1.0).iter().map(|&x| x as f32).collect()
            };
            let mut corpus: Vec<String> = Vec::with_capacity(64);
            for id in 0..64u64 {
                if id % 4 == 3 {
                    corpus.push(req_traj_line(
                        id,
                        "iiwa",
                        Some("bulk"),
                        Some(250),
                        &vecf(n),
                        &vecf(n),
                        &vecf(8 * n),
                        1e-3,
                    ));
                } else {
                    corpus.push(req_step_line(
                        id,
                        "iiwa",
                        "fd",
                        Some("interactive"),
                        None,
                        &[vecf(n), vecf(n), vecf(n)],
                    ));
                }
            }
            let st_lazy = time_auto(target_ms, || {
                for line in &corpus {
                    let r = LazyReq::scan(line).expect("lazy scan");
                    black_box((r.id, r.robot, r.route, r.class, r.deadline_us));
                }
            });
            add("iiwa", "json_lazy_vs_full", &st_lazy, 64);
            let st_full = time_auto(target_ms, || {
                for line in &corpus {
                    black_box(Frame::parse(line).expect("full parse"));
                }
            });
            add("iiwa", "json_full_tree64", &st_full, 64);
            println!(
                "lazy hot-field scan vs full Json parse: {:.2}x ({:.3} vs {:.3} us/line)",
                st_full.median_us() / st_lazy.median_us(),
                st_lazy.median_us() / 64.0,
                st_full.median_us() / 64.0
            );
        }

        // End-to-end socket serving: 64 FD requests pipelined over one
        // real TCP JSONL connection per iteration — text framing, lazy
        // ingest, sink submission, and response streaming all included.
        // Compare with serve_fd_par64 (the same dispatch shape without
        // the wire) for the protocol tax.
        {
            let mut nreg = RobotRegistry::new();
            nreg.register(iiwa.clone(), BackendKind::Native, 64);
            let ncoord = Arc::new(Coordinator::start_registry(&nreg, 100));
            let dims: BTreeMap<String, usize> =
                [("iiwa".to_string(), iiwa.dof())].into_iter().collect();
            let server =
                NetServer::start(Arc::clone(&ncoord), dims, "127.0.0.1:0", None, "iiwa", 64, 100)
                    .expect("bind net server");
            let mut client = NetClient::connect(server.addr()).expect("connect net server");
            let n = iiwa.dof();
            let mut nrng = Rng::new(13);
            let lines: Vec<String> = (0..64u64)
                .map(|id| {
                    let ops: Vec<Vec<f32>> = (0..3)
                        .map(|_| {
                            nrng.vec_range(n, -1.0, 1.0).iter().map(|&x| x as f32).collect()
                        })
                        .collect();
                    req_step_line(id, "iiwa", "fd", None, None, &ops)
                })
                .collect();
            let st = time_auto(target_ms, || {
                for line in &lines {
                    client.send_line(line).expect("send req line");
                }
                let mut done = 0;
                while done < 64 {
                    match client.read_frame().expect("response frame") {
                        Frame::Done { .. } => done += 1,
                        Frame::Err { msg, .. } => panic!("err frame on clean traffic: {msg}"),
                        _ => {}
                    }
                }
            });
            add("iiwa", "serve_net_jsonl", &st, 64);
            drop(client);
            server.stop();
        }
    }

    t.print("CPU hot paths (measured, single thread)");

    // Workspace-vs-allocating speedups (median-to-median ratio; >1 means
    // the workspace kernel is faster).
    let mut st = Table::new(&["robot", "fn", "alloc(us)", "ws(us)", "speedup"]);
    let mut speedups_json: Vec<Json> = Vec::new();
    for robot in ["iiwa", "hyq", "atlas", "baxter"] {
        for func in ["rnea", "crba", "minv", "fd", "aba"] {
            let alloc = medians[&(robot.to_string(), func.to_string())];
            let ws = medians[&(robot.to_string(), format!("{func}_ws"))];
            let speedup = alloc / ws;
            st.row(&[
                robot.to_string(),
                func.to_string(),
                format!("{alloc:.2}"),
                format!("{ws:.2}"),
                format!("{speedup:.2}x"),
            ]);
            speedups_json.push(json::obj(vec![
                ("robot", json::s(robot)),
                ("fn", json::s(func)),
                ("alloc_median_us", json::num(alloc)),
                ("ws_median_us", json::num(ws)),
                ("speedup", json::num(speedup)),
            ]));
        }
    }
    st.print("workspace kernels vs allocating paths");

    let out = json::obj(vec![
        ("schema", json::s("draco.hotpath.v1")),
        ("quick", Json::Bool(quick)),
        ("rows", Json::Arr(rows_json)),
        ("speedups", Json::Arr(speedups_json)),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/BENCH_hotpath.json");
    match std::fs::write(path, out.pretty()) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }
}
