//! Fig. 13 — estimated MPC control rates vs trajectory length for iiwa
//! and Atlas on CPU (measured) / Dadu-RBD-on-V80 / DRACO, with the 1 kHz
//! and 250 Hz online-control thresholds, assuming 10 optimization-loop
//! iterations (analytical model of Robomorphic [39]).

use draco::accel::control_rate::{control_rate_hz, max_traj_len, PlatformTimes};
use draco::accel::Design;
use draco::dynamics::{fd, fd_derivatives, rnea};
use draco::model::{builtin_robot, Robot, State};
use draco::util::bench::{time_auto, Table};
use draco::util::rng::Rng;
use std::hint::black_box;

fn measured_cpu_times(robot: &Robot) -> PlatformTimes {
    let n = robot.dof();
    let mut rng = Rng::new(9);
    let s = State::random(robot, &mut rng);
    let qdd = rng.vec_range(n, -1.0, 1.0);
    let tau = rnea(robot, &s.q, &s.qd, &qdd, None);
    let r1 = robot.clone();
    let s1 = s.clone();
    let t1 = tau.clone();
    let fd_t = time_auto(40.0, move || {
        black_box(fd(&r1, &s1.q, &s1.qd, &t1, None));
    });
    let r2 = robot.clone();
    let dfd_t = time_auto(60.0, move || {
        black_box(fd_derivatives(&r2, &s.q, &s.qd, &tau));
    });
    PlatformTimes {
        fd_latency_us: fd_t.median_us(),
        dfd_latency_us: dfd_t.median_us(),
        fd_per_task_us: fd_t.median_us(),
        dfd_per_task_us: dfd_t.median_us(),
    }
}

fn main() {
    let iters = 10;
    let lens = [5usize, 10, 20, 40, 80, 160];
    for name in ["iiwa", "atlas"] {
        let robot = builtin_robot(name).unwrap();
        let platforms: Vec<(&str, PlatformTimes)> = vec![
            ("cpu (measured)", measured_cpu_times(&robot)),
            (
                "dadu-rbd @V80",
                PlatformTimes::from_design(&Design::dadu_rbd_on_v80(&robot), &robot),
            ),
            ("draco", PlatformTimes::from_design(&Design::draco(&robot), &robot)),
        ];
        let mut t = Table::new(&[
            "platform", "T=5", "T=10", "T=20", "T=40", "T=80", "T=160", "maxT@1kHz", "maxT@250Hz",
        ]);
        for (pname, times) in &platforms {
            let mut row = vec![pname.to_string()];
            for &l in &lens {
                row.push(format!("{:.0}", control_rate_hz(times, l, iters)));
            }
            row.push(max_traj_len(times, 1000.0, iters).to_string());
            row.push(max_traj_len(times, 250.0, iters).to_string());
            t.row(&row);
        }
        t.print(&format!(
            "Fig 13 — estimated control rate [Hz] vs trajectory length — {name} ({iters} MPC iters)"
        ));
    }
    println!("\npaper reference point: DRACO sustains 54 steps @250 Hz on Atlas vs 39 for Dadu-RBD");
    println!("(on this testbed the CPU row is measured; FPGA rows come from the cycle model).");
}
