//! Integration: the native serving path end-to-end — coordinator →
//! dynamic batcher → NativeEngine → workspace core — with numerics
//! validated against the f64 reference implementations. Unlike the PJRT
//! tests this needs no artifacts, no features, no Python: it runs on
//! every `cargo test`.

use draco::coordinator::Coordinator;
use draco::dynamics;
use draco::model::{builtin_robot, State};
use draco::runtime::artifact::ArtifactFn;
use draco::util::rng::Rng;

fn to_f32(v: &[f64]) -> Vec<f32> {
    v.iter().map(|&x| x as f32).collect()
}

#[test]
fn native_coordinator_serves_rnea_fd_minv() {
    let robot = builtin_robot("iiwa").unwrap();
    let n = robot.dof();
    let coord = Coordinator::start_native(
        &robot,
        &[(ArtifactFn::Rnea, 16), (ArtifactFn::Fd, 16), (ArtifactFn::Minv, 8)],
        150,
    );
    let mut rng = Rng::new(800);
    let mut pending = Vec::new();
    for k in 0..60usize {
        let s = State::random(&robot, &mut rng);
        let u = rng.vec_range(n, -8.0, 8.0);
        let function = match k % 3 {
            0 => ArtifactFn::Rnea,
            1 => ArtifactFn::Fd,
            _ => ArtifactFn::Minv,
        };
        let ops = match function {
            ArtifactFn::Minv => vec![to_f32(&s.q)],
            _ => vec![to_f32(&s.q), to_f32(&s.qd), to_f32(&u)],
        };
        pending.push((function, s, u, coord.submit(function, ops)));
    }
    for (function, s, u, rx) in pending {
        let out = rx.recv().expect("answer").expect("ok");
        match function {
            ArtifactFn::Rnea | ArtifactFn::Fd => {
                assert_eq!(out.len(), n);
                let want = if function == ArtifactFn::Rnea {
                    dynamics::rnea(&robot, &s.q, &s.qd, &u, None)
                } else {
                    dynamics::fd(&robot, &s.q, &s.qd, &u, None)
                };
                for i in 0..n {
                    let scale = 1.0f64.max(want[i].abs());
                    assert!(
                        ((out[i] as f64) - want[i]).abs() / scale < 2e-3,
                        "{} joint {i}: {} vs {}",
                        function.name(),
                        out[i],
                        want[i]
                    );
                }
            }
            ArtifactFn::Minv => {
                assert_eq!(out.len(), n * n);
                let want = dynamics::minv(&robot, &s.q);
                let scale = want.max_abs();
                for i in 0..n {
                    for j in 0..n {
                        let got = out[i * n + j] as f64;
                        assert!(
                            (got - want[(i, j)]).abs() / scale < 2e-3,
                            "M⁻¹[{i}][{j}]: {got} vs {}",
                            want[(i, j)]
                        );
                    }
                }
            }
        }
    }
    let st = coord.stats();
    assert_eq!(st.completed, 60);
    assert!(st.batches >= 3, "each function route must have flushed");
    coord.shutdown();
}

/// The batcher must never drop, duplicate, or reorder an answer: each
/// response channel gets exactly one result matching its own inputs
/// (checked via a per-request marker), even when requests outnumber the
/// batch size several times over.
#[test]
fn native_coordinator_no_mixups_under_load() {
    let robot = builtin_robot("iiwa").unwrap();
    let n = robot.dof();
    let coord = Coordinator::start_native(&robot, &[(ArtifactFn::Rnea, 8)], 80);
    let mut rng = Rng::new(801);
    // Unique marker per request: qdd = j·0.1·e_0 → τ_0 is affine in j.
    let base = State::random(&robot, &mut rng);
    let t0 = dynamics::rnea(&robot, &base.q, &base.qd, &vec![0.0; n], None);
    let m = dynamics::crba(&robot, &base.q);
    let mut pending = Vec::new();
    for j in 1..=64usize {
        let mut acc = vec![0.0; n];
        acc[0] = j as f64 * 0.1;
        let ops = vec![to_f32(&base.q), to_f32(&base.qd), to_f32(&acc)];
        pending.push((j, coord.submit(ArtifactFn::Rnea, ops)));
    }
    for (j, rx) in pending {
        let out = rx.recv().unwrap().unwrap();
        let want = t0[0] + m[(0, 0)] * 0.1 * j as f64;
        let got = out[0] as f64;
        assert!(
            (got - want).abs() / (1.0 + want.abs()) < 2e-3,
            "request {j}: got {got}, want {want} — answers mixed up?"
        );
    }
    coord.shutdown();
}

/// Partial batches must flush at the window deadline, not hang.
#[test]
fn native_coordinator_flushes_partial_batch() {
    let robot = builtin_robot("hyq").unwrap();
    let n = robot.dof();
    // Batch far larger than the request count.
    let coord = Coordinator::start_native(&robot, &[(ArtifactFn::Fd, 256)], 100);
    let mut rng = Rng::new(802);
    let s = State::random(&robot, &mut rng);
    let tau = rng.vec_range(n, -5.0, 5.0);
    let rx = coord.submit(ArtifactFn::Fd, vec![to_f32(&s.q), to_f32(&s.qd), to_f32(&tau)]);
    let out = rx.recv().expect("answer").expect("ok");
    assert_eq!(out.len(), n);
    let st = coord.stats();
    assert_eq!(st.completed, 1);
    coord.shutdown();
}
