//! Integration: the multi-robot serving registry end-to-end — one
//! coordinator serving several robots concurrently with per-robot
//! backends (f64 native / quantized), plus trajectory batch requests
//! unrolled through the workspace integrator. No artifacts, no features,
//! no Python: runs on every `cargo test`.

use draco::coordinator::{BackendKind, Coordinator, RobotRegistry, TrajRequest};
use draco::dynamics;
use draco::model::{builtin_robot, Robot, State};
use draco::quant::analyzer::rnea_error_stats;
use draco::quant::qrbd::quant_rnea;
use draco::quant::QFormat;
use draco::runtime::artifact::ArtifactFn;
use draco::runtime::{NativeEngine, QuantEngine};
use draco::util::rng::Rng;
use std::sync::Arc;

fn to_f32(v: &[f64]) -> Vec<f32> {
    v.iter().map(|&x| x as f32).collect()
}

fn f32_round(v: &[f64]) -> Vec<f64> {
    v.iter().map(|&x| x as f32 as f64).collect()
}

/// Two robots on different backends behind one coordinator: concurrent
/// clients hammer both; every response must match the *per-robot*
/// reference kernel (a misroute would produce wrong dimensions for one
/// robot pair and wrong numerics for the other).
#[test]
fn registry_serves_two_robots_concurrently() {
    let iiwa = builtin_robot("iiwa").unwrap();
    let atlas = builtin_robot("atlas").unwrap();
    let fmt = QFormat::new(14, 20);
    let mut registry = RobotRegistry::new();
    registry
        .register(iiwa.clone(), BackendKind::Native, 16)
        .register(atlas.clone(), BackendKind::NativeQuant(fmt), 8);
    let coord = Arc::new(Coordinator::start_registry(&registry, 150));

    let client = |coord: Arc<Coordinator>, robot: Robot, seed: u64| {
        std::thread::spawn(move || {
            let n = robot.dof();
            let mut rng = Rng::new(seed);
            let mut pending = Vec::new();
            for k in 0..40usize {
                let s = State::random(&robot, &mut rng);
                let u = rng.vec_range(n, -2.0, 2.0);
                let function = match k % 3 {
                    0 => ArtifactFn::Rnea,
                    1 => ArtifactFn::Fd,
                    _ => ArtifactFn::Minv,
                };
                let ops = match function {
                    ArtifactFn::Minv => vec![to_f32(&s.q)],
                    _ => vec![to_f32(&s.q), to_f32(&s.qd), to_f32(&u)],
                };
                pending.push((function, s, u, coord.submit_to(&robot.name, function, ops)));
            }
            pending
                .into_iter()
                .map(|(f, s, u, rx)| (f, s, u, rx.recv().expect("answer").expect("ok")))
                .collect::<Vec<_>>()
        })
    };

    let h_iiwa = client(Arc::clone(&coord), iiwa.clone(), 810);
    let h_atlas = client(Arc::clone(&coord), atlas.clone(), 811);

    // iiwa (native f64): outputs match the f64 reference on the
    // f32-rounded operands.
    let n = iiwa.dof();
    for (function, s, u, out) in h_iiwa.join().expect("iiwa client") {
        let qr = f32_round(&s.q);
        let qdr = f32_round(&s.qd);
        let ur = f32_round(&u);
        match function {
            ArtifactFn::Rnea | ArtifactFn::Fd => {
                assert_eq!(out.len(), n, "iiwa row length routed wrong");
                let want = if function == ArtifactFn::Rnea {
                    dynamics::rnea(&iiwa, &qr, &qdr, &ur, None)
                } else {
                    dynamics::fd(&iiwa, &qr, &qdr, &ur, None)
                };
                for i in 0..n {
                    let scale = 1.0f64.max(want[i].abs());
                    assert!(
                        ((out[i] as f64) - want[i]).abs() / scale < 2e-3,
                        "iiwa {} joint {i}",
                        function.name()
                    );
                }
            }
            ArtifactFn::Minv => {
                assert_eq!(out.len(), n * n, "iiwa matrix routed wrong");
                let want = dynamics::minv(&iiwa, &qr);
                let scale = want.max_abs();
                for i in 0..n {
                    for j in 0..n {
                        assert!(
                            ((out[i * n + j] as f64) - want[(i, j)]).abs() / scale < 1e-4,
                            "iiwa minv [{i}][{j}]"
                        );
                    }
                }
            }
        }
    }

    // atlas (quantized): outputs match the *quantized* kernels bitwise —
    // proof the route really executes the fixed-point backend.
    let m = atlas.dof();
    for (function, s, u, out) in h_atlas.join().expect("atlas client") {
        let qr = f32_round(&s.q);
        let qdr = f32_round(&s.qd);
        let ur = f32_round(&u);
        match function {
            ArtifactFn::Rnea => {
                assert_eq!(out.len(), m, "atlas row length routed wrong");
                let want = quant_rnea(&atlas, &qr, &qdr, &ur, fmt);
                for i in 0..m {
                    assert_eq!(out[i], want[i] as f32, "atlas quant rnea joint {i}");
                }
            }
            ArtifactFn::Fd | ArtifactFn::Minv => {
                let expect = if function == ArtifactFn::Minv { m * m } else { m };
                assert_eq!(out.len(), expect, "atlas {} routed wrong", function.name());
                assert!(out.iter().all(|x| x.is_finite()));
            }
        }
    }

    assert_eq!(coord.robots(), vec!["atlas".to_string(), "iiwa".to_string()]);
    let st = coord.stats();
    assert!(st.completed >= 80, "all requests answered: {}", st.completed);
    if let Ok(coord) = Arc::try_unwrap(coord) {
        coord.shutdown();
    }
}

/// A URDF-loaded robot registered from the CLI spec, served next to a
/// builtin: the spec's `name=path.urdf[:backend]` form must parse, route
/// under the given name, and answer correct-dimension, finite results on
/// both robots through one coordinator.
#[test]
fn registry_spec_loads_urdf_robot_next_to_builtin() {
    const MINI_URDF: &str = r#"<?xml version="1.0"?>
<robot name="mini-urdf-arm">
  <link name="base"/>
  <link name="upper">
    <inertial>
      <origin xyz="0 0 0.1"/>
      <mass value="2.0"/>
      <inertia ixx="0.02" iyy="0.02" izz="0.01" ixy="0" ixz="0" iyz="0"/>
    </inertial>
  </link>
  <link name="lower">
    <inertial>
      <origin xyz="0 0 0.15"/>
      <mass value="1.0"/>
      <inertia ixx="0.01" iyy="0.01" izz="0.005"/>
    </inertial>
  </link>
  <joint name="j1" type="revolute">
    <parent link="base"/>
    <child link="upper"/>
    <origin xyz="0 0 0.2" rpy="0 0 0"/>
    <axis xyz="0 1 0"/>
    <limit lower="-1.5" upper="1.5" velocity="3.0"/>
  </joint>
  <joint name="j2" type="continuous">
    <parent link="upper"/>
    <child link="lower"/>
    <origin xyz="0 0 0.3"/>
    <axis xyz="0 1 0"/>
  </joint>
</robot>"#;
    let path = std::path::Path::new(env!("CARGO_TARGET_TMPDIR")).join("mini_registry.urdf");
    std::fs::write(&path, MINI_URDF).expect("write temp urdf");

    let spec = format!("iiwa,mini={}:quant@12.14", path.display());
    let registry = RobotRegistry::from_cli_spec(&spec, 8).expect("spec parses");
    assert_eq!(registry.names(), vec!["iiwa".to_string(), "mini".to_string()]);
    let entry = registry.get("mini").expect("urdf robot registered");
    // Registered under the spec's name (not the URDF's own), 2 moving
    // joints, quantized backend.
    assert_eq!(entry.robot.name, "mini");
    assert_eq!(entry.robot.dof(), 2);
    assert_eq!(entry.backend, BackendKind::NativeQuant(QFormat::new(12, 14)));

    let coord = Coordinator::start_registry(&registry, 100);
    // URDF robot: quantized RNEA answers with its own dimension and
    // matches the quantized reference kernel bitwise.
    let q = vec![0.3f32, -0.4];
    let qd = vec![0.1f32, 0.2];
    let u = vec![0.5f32, -0.5];
    let out = coord
        .submit_to("mini", ArtifactFn::Rnea, vec![q.clone(), qd.clone(), u.clone()])
        .recv()
        .expect("answer")
        .expect("ok");
    assert_eq!(out.len(), 2);
    let to64 = |v: &[f32]| v.iter().map(|&x| x as f64).collect::<Vec<f64>>();
    let want = quant_rnea(&entry.robot, &to64(&q), &to64(&qd), &to64(&u), QFormat::new(12, 14));
    for i in 0..2 {
        assert_eq!(out[i], want[i] as f32, "urdf robot joint {i}");
    }
    // The builtin next door still routes with its own dimension.
    let n = registry.get("iiwa").unwrap().robot.dof();
    let out = coord
        .submit_to("iiwa", ArtifactFn::Rnea, vec![vec![0.1; n], vec![0.0; n], vec![0.0; n]])
        .recv()
        .expect("answer")
        .expect("ok");
    assert_eq!(out.len(), n);
    assert!(out.iter().all(|x| x.is_finite()));
    coord.shutdown();
    let _ = std::fs::remove_file(&path);
}

/// Quantized-vs-f64 native engine accuracy: the served error must stay
/// within the envelope the quantization error analyzer measures for the
/// same format, and a finer format must serve strictly more accurately.
#[test]
fn quant_engine_error_bounded_by_analyzer_metrics() {
    let robot = builtin_robot("iiwa").unwrap();
    let n = robot.dof();
    let coarse = QFormat::new(12, 10);
    let fine = QFormat::new(16, 24);

    // Analyzer envelope for the coarse format (same state distribution
    // and q̈ range as the workload below).
    let mut arng = Rng::new(820);
    let stats = rnea_error_stats(&robot, coarse, 48, &mut arng, false);
    assert!(stats.max_abs > 0.0);

    let b = 16;
    let mut rng = Rng::new(821);
    let mut q = Vec::new();
    let mut qd = Vec::new();
    let mut u = Vec::new();
    for _ in 0..b {
        let s = State::random(&robot, &mut rng);
        q.extend(to_f32(&s.q));
        qd.extend(to_f32(&s.qd));
        u.extend(to_f32(&rng.vec_range(n, -2.0, 2.0)));
    }
    let inputs = vec![q, qd, u];

    let mut native = NativeEngine::new(robot.clone(), ArtifactFn::Rnea, b);
    let exact = native.run(&inputs).expect("native run");
    let mut max_err = [0.0f64; 2];
    for (slot, fmt) in [(0usize, coarse), (1, fine)] {
        let mut quant = QuantEngine::new(robot.clone(), ArtifactFn::Rnea, b, fmt);
        let served = quant.run(&inputs).expect("quant run");
        for (a, e) in served.iter().zip(&exact) {
            max_err[slot] = max_err[slot].max((*a as f64 - *e as f64).abs());
        }
    }
    // Envelope: served error within a small multiple of the analyzer's
    // measured max (different random states, hence the margin), and the
    // finer format strictly tighter than the coarse one.
    assert!(
        max_err[0] <= 10.0 * stats.max_abs,
        "served quant error {} exceeds analyzer envelope {}",
        max_err[0],
        stats.max_abs
    );
    assert!(max_err[0] > 0.0, "coarse quantization must be visible");
    assert!(
        max_err[1] < max_err[0],
        "fine format {} must beat coarse {}",
        max_err[1],
        max_err[0]
    );
}

/// Trajectory batch requests: one submit carries a whole (q₀, q̇₀, τ…)
/// rollout; the response must match stepping the forward dynamics
/// per-step on the client side.
#[test]
fn trajectory_batch_matches_per_step_fd() {
    let robot = builtin_robot("iiwa").unwrap();
    let n = robot.dof();
    let mut registry = RobotRegistry::new();
    registry.register(robot.clone(), BackendKind::Native, 8);
    let coord = Coordinator::start_registry(&registry, 100);

    let mut rng = Rng::new(830);
    let s0 = State::random(&robot, &mut rng);
    let h = 16;
    let dt = 1e-3;
    let tau64 = rng.vec_range(h * n, -3.0, 3.0);
    let req = TrajRequest {
        q0: to_f32(&s0.q),
        qd0: to_f32(&s0.qd),
        tau: to_f32(&tau64),
        dt,
    };
    let out = coord
        .submit_traj(&robot.name, req.clone())
        .recv()
        .expect("answer")
        .expect("rollout ok");
    assert_eq!(out.len(), 2 * h * n);

    // Client-side reference: per-step FD + the same semi-implicit update,
    // from the f32-rounded initial state and torques the server decoded.
    let mut q: Vec<f64> = req.q0.iter().map(|&x| x as f64).collect();
    let mut qd: Vec<f64> = req.qd0.iter().map(|&x| x as f64).collect();
    for t in 0..h {
        let tt: Vec<f64> = req.tau[t * n..(t + 1) * n].iter().map(|&x| x as f64).collect();
        let qdd = dynamics::fd(&robot, &q, &qd, &tt, None);
        for i in 0..n {
            qd[i] += qdd[i] * dt;
            q[i] += qd[i] * dt;
        }
        for i in 0..n {
            let got_q = out[t * n + i] as f64;
            let got_qd = out[(h + t) * n + i] as f64;
            assert!(
                (got_q - q[i]).abs() / (1.0f64.max(q[i].abs())) < 1e-4,
                "step {t} q[{i}]: {got_q} vs {}",
                q[i]
            );
            assert!(
                (got_qd - qd[i]).abs() / (1.0f64.max(qd[i].abs())) < 1e-4,
                "step {t} qd[{i}]: {got_qd} vs {}",
                qd[i]
            );
        }
    }
    coord.shutdown();
}

/// Several trajectory requests in one window batch together but keep
/// per-request identity (different horizons, different robots).
#[test]
fn trajectory_batching_preserves_request_identity() {
    let iiwa = builtin_robot("iiwa").unwrap();
    let hyq = builtin_robot("hyq").unwrap();
    let mut registry = RobotRegistry::new();
    registry
        .register(iiwa.clone(), BackendKind::Native, 4)
        .register(hyq.clone(), BackendKind::Native, 4);
    let coord = Coordinator::start_registry(&registry, 200);

    let mut rxs = Vec::new();
    for (robot, h) in [(&iiwa, 3usize), (&hyq, 7), (&iiwa, 5), (&hyq, 2)] {
        let n = robot.dof();
        let req = TrajRequest {
            q0: vec![0.05; n],
            qd0: vec![0.0; n],
            tau: vec![0.0; h * n],
            dt: 1e-3,
        };
        rxs.push((robot.dof(), h, coord.submit_traj(&robot.name, req)));
    }
    for (n, h, rx) in rxs {
        let out = rx.recv().expect("answer").expect("ok");
        assert_eq!(out.len(), 2 * h * n, "horizon/robot mixed up in batching");
        assert!(out.iter().all(|x| x.is_finite()));
    }
    coord.shutdown();
}
