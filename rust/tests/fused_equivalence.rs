//! Fused-route equivalence: the multi-output `dyn_all` route
//! (q̈ ‖ M⁻¹ ‖ C from one kinematics pass) must answer **bitwise
//! identically** to the three separate fd / minv / rnea routes, on
//! every backend lane (f64 native, rounded quant, true-integer qint),
//! for every builtin robot, serially and fanned out across the worker
//! pool. The cross-request kinematics memo riding on the fused route
//! is purely a latency knob: a hit replays the cached sweep outputs
//! through the identical tail, so warm responses are bitwise equal to
//! cold ones — including under concurrent pooled load — adjacent
//! quantized states never alias, and eviction at capacity degrades to
//! a plain (still bitwise-correct) miss.

use draco::coordinator::{BackendKind, Coordinator, RobotRegistry};
use draco::dynamics::{DynWorkspace, DEFAULT_MEMO_CAP};
use draco::model::{builtin_robot, Robot, State};
use draco::quant::qrbd::quant_dyn_all;
use draco::quant::QFormat;
use draco::runtime::artifact::ArtifactFn;
use draco::runtime::{DynamicsEngine, NativeEngine, QIntEngine, QuantEngine};
use draco::util::rng::Rng;
use std::sync::Arc;

/// Flat row-major (b, n) f32 operands: q, q̇, τ.
fn flat_inputs(robot: &Robot, b: usize, seed: u64) -> Vec<Vec<f32>> {
    let n = robot.dof();
    let mut rng = Rng::new(seed);
    let mut q = Vec::with_capacity(b * n);
    let mut qd = Vec::with_capacity(b * n);
    let mut u = Vec::with_capacity(b * n);
    for _ in 0..b {
        let s = State::random(robot, &mut rng);
        q.extend(s.q.iter().map(|&x| x as f32));
        qd.extend(s.qd.iter().map(|&x| x as f32));
        u.extend(rng.vec_range(n, -6.0, 6.0).iter().map(|&x| x as f32));
    }
    vec![q, qd, u]
}

/// Run the fused engine and the three separate engines on identical
/// operands and compare every output slice bitwise. The bias reference
/// is the RNEA route at q̈ = 0 — exactly what C(q, q̇) is.
fn check_fused_vs_separate(
    label: &str,
    n: usize,
    inputs: &[Vec<f32>],
    dyn_all: &mut dyn DynamicsEngine,
    fd: &mut dyn DynamicsEngine,
    minv: &mut dyn DynamicsEngine,
    rnea: &mut dyn DynamicsEngine,
) {
    let b = inputs[0].len() / n;
    let fused = dyn_all.run(inputs).expect("dyn_all run");
    let qdd = fd.run(inputs).expect("fd run");
    let mi = minv.run(std::slice::from_ref(&inputs[0])).expect("minv run");
    let bias = rnea
        .run(&[inputs[0].clone(), inputs[1].clone(), vec![0.0f32; b * n]])
        .expect("rnea run");
    let per = n * n + 2 * n;
    assert_eq!(fused.len(), b * per, "{label}: fused output length");
    for k in 0..b {
        let row = &fused[k * per..(k + 1) * per];
        assert_eq!(&row[..n], &qdd[k * n..(k + 1) * n], "{label}: q̈ diverged (task {k})");
        assert_eq!(
            &row[n..n + n * n],
            &mi[k * n * n..(k + 1) * n * n],
            "{label}: M⁻¹ diverged (task {k})"
        );
        assert_eq!(
            &row[n + n * n..],
            &bias[k * n..(k + 1) * n],
            "{label}: bias diverged (task {k})"
        );
    }
}

/// Engine level, exhaustive: every builtin robot × every backend lane ×
/// serial and pooled execution — the fused sweep equals the three
/// separate route kernels bitwise.
#[test]
fn fused_engine_matches_separate_engines_every_backend_and_robot() {
    let robots = [
        ("iiwa", QFormat::new(12, 12)),
        ("hyq", QFormat::new(12, 12)),
        ("atlas", QFormat::new(12, 14)),
        ("baxter", QFormat::new(13, 13)),
    ];
    const FNS: [ArtifactFn; 4] =
        [ArtifactFn::DynAll, ArtifactFn::Fd, ArtifactFn::Minv, ArtifactFn::Rnea];
    for (name, fmt) in robots {
        let robot = builtin_robot(name).unwrap();
        let n = robot.dof();
        for parallel in [1usize, 0] {
            for b in [1usize, 6] {
                let inputs = flat_inputs(&robot, b, 40_000 + 7 * b as u64);

                let mut nat: Vec<NativeEngine> = FNS
                    .iter()
                    .map(|&f| NativeEngine::with_parallelism(robot.clone(), f, 8, parallel))
                    .collect();
                let (head, tail) = nat.split_at_mut(1);
                let (fd_e, rest) = tail.split_at_mut(1);
                let (mi_e, rn_e) = rest.split_at_mut(1);
                check_fused_vs_separate(
                    &format!("{name}/native parallel={parallel} rows={b}"),
                    n,
                    &inputs,
                    &mut head[0],
                    &mut fd_e[0],
                    &mut mi_e[0],
                    &mut rn_e[0],
                );

                let mut qnt: Vec<QuantEngine> = FNS
                    .iter()
                    .map(|&f| QuantEngine::with_options(robot.clone(), f, 8, fmt, parallel, false))
                    .collect();
                let (head, tail) = qnt.split_at_mut(1);
                let (fd_e, rest) = tail.split_at_mut(1);
                let (mi_e, rn_e) = rest.split_at_mut(1);
                check_fused_vs_separate(
                    &format!("{name}/quant@{} parallel={parallel} rows={b}", fmt.label()),
                    n,
                    &inputs,
                    &mut head[0],
                    &mut fd_e[0],
                    &mut mi_e[0],
                    &mut rn_e[0],
                );

                let mut int: Vec<QIntEngine> = FNS
                    .iter()
                    .map(|&f| {
                        QIntEngine::with_parallelism(robot.clone(), f, 8, fmt, parallel)
                            .expect("accepted format")
                    })
                    .collect();
                let (head, tail) = int.split_at_mut(1);
                let (fd_e, rest) = tail.split_at_mut(1);
                let (mi_e, rn_e) = rest.split_at_mut(1);
                check_fused_vs_separate(
                    &format!("{name}/qint@{} parallel={parallel} rows={b}", fmt.label()),
                    n,
                    &inputs,
                    &mut head[0],
                    &mut fd_e[0],
                    &mut mi_e[0],
                    &mut rn_e[0],
                );
            }
        }
    }
}

/// Coordinator level: a pooled mixed-lane registry answers `dyn_all`
/// requests bitwise equal to its own fd / minv / rnea routes — the
/// serving-path statement of the fused equivalence, dispatch and
/// batching included.
#[test]
fn fused_route_matches_separate_routes_through_the_coordinator() {
    let iiwa = builtin_robot("iiwa").unwrap();
    let hyq = builtin_robot("hyq").unwrap();
    let atlas = builtin_robot("atlas").unwrap();
    let mut reg = RobotRegistry::new();
    reg.register_parallel(iiwa.clone(), BackendKind::Native, 8, 0)
        .register_parallel(hyq.clone(), BackendKind::NativeQuant(QFormat::new(12, 12)), 8, 0)
        .register_parallel(atlas.clone(), BackendKind::NativeInt(QFormat::new(12, 14)), 8, 0);
    reg.validate().expect("int entry accepted");
    let coord = Coordinator::start_registry(&reg, 150);

    let answer = |robot: &str, f: ArtifactFn, ops: Vec<Vec<f32>>| -> Vec<f32> {
        coord.submit_to(robot, f, ops).recv().expect("answer").expect("ok")
    };
    for robot in [&iiwa, &hyq, &atlas] {
        let n = robot.dof();
        let per = n * n + 2 * n;
        for k in 0..3u64 {
            let ops = flat_inputs(robot, 1, 50_000 + 10 * k);
            let fused = answer(&robot.name, ArtifactFn::DynAll, ops.clone());
            let qdd = answer(&robot.name, ArtifactFn::Fd, ops.clone());
            let mi = answer(&robot.name, ArtifactFn::Minv, vec![ops[0].clone()]);
            let bias = answer(
                &robot.name,
                ArtifactFn::Rnea,
                vec![ops[0].clone(), ops[1].clone(), vec![0.0f32; n]],
            );
            assert_eq!(fused.len(), per, "{}: fused response length", robot.name);
            assert_eq!(&fused[..n], &qdd[..], "{}: q̈ route diverged", robot.name);
            assert_eq!(&fused[n..n + n * n], &mi[..], "{}: M⁻¹ route diverged", robot.name);
            assert_eq!(&fused[n + n * n..], &bias[..], "{}: bias route diverged", robot.name);
        }
    }
    coord.shutdown();
}

/// Trajectory rollouts are function-independent: an engine built for
/// the fused route rolls out bitwise identically to the FD engine on
/// every lane — registering a robot's routes for `dyn_all` does not
/// perturb its trajectory serving.
#[test]
fn rollout_on_a_dyn_all_engine_matches_the_fd_engine() {
    let robot = builtin_robot("iiwa").unwrap();
    let n = robot.dof();
    let fmt = QFormat::new(12, 12);
    let mut rng = Rng::new(61_000);
    let s0 = State::random(&robot, &mut rng);
    let h = 10;
    let q0: Vec<f32> = s0.q.iter().map(|&x| x as f32).collect();
    let qd0: Vec<f32> = s0.qd.iter().map(|&x| x as f32).collect();
    let tau: Vec<f32> = rng.vec_range(h * n, -2.0, 2.0).iter().map(|&x| x as f32).collect();

    fn check_rollout(
        lane: &str,
        dyn_all: &mut dyn DynamicsEngine,
        fd: &mut dyn DynamicsEngine,
        q0: &[f32],
        qd0: &[f32],
        tau: &[f32],
    ) {
        let n = dyn_all.n();
        let h = tau.len() / n;
        let got = dyn_all.rollout(q0, qd0, tau, 1e-3).expect("dyn_all rollout");
        let want = fd.rollout(q0, qd0, tau, 1e-3).expect("fd rollout");
        assert_eq!(got.len(), 2 * h * n, "{lane}: rollout length");
        assert_eq!(got, want, "{lane}: dyn_all engine rollout diverged from fd engine");
    }
    check_rollout(
        "native",
        &mut NativeEngine::new(robot.clone(), ArtifactFn::DynAll, 8),
        &mut NativeEngine::new(robot.clone(), ArtifactFn::Fd, 8),
        &q0,
        &qd0,
        &tau,
    );
    check_rollout(
        "quant",
        &mut QuantEngine::new(robot.clone(), ArtifactFn::DynAll, 8, fmt),
        &mut QuantEngine::new(robot.clone(), ArtifactFn::Fd, 8, fmt),
        &q0,
        &qd0,
        &tau,
    );
    check_rollout(
        "qint",
        &mut QIntEngine::new(robot.clone(), ArtifactFn::DynAll, 8, fmt).expect("accepted"),
        &mut QIntEngine::new(robot.clone(), ArtifactFn::Fd, 8, fmt).expect("accepted"),
        &q0,
        &qd0,
        &tau,
    );
}

/// Memo hits under concurrent pooled load stay bitwise identical to
/// the memo-less cold kernel: four client threads hammer one pooled
/// `dyn_all` route with the same four states, and every one of the 192
/// responses equals the fresh-workspace reference — and the memo
/// actually engaged.
#[test]
fn memo_hits_under_concurrent_pooled_load_stay_bitwise_identical() {
    let robot = builtin_robot("iiwa").unwrap();
    let n = robot.dof();
    let mut reg = RobotRegistry::new();
    reg.register_parallel(robot.clone(), BackendKind::Native, 8, 0);
    let coord = Arc::new(Coordinator::start_registry(&reg, 150));

    let probes: Vec<Vec<Vec<f32>>> =
        (0..4u64).map(|k| flat_inputs(&robot, 1, 60_000 + k)).collect();
    // Memo-less cold reference: the fused workspace kernel on the
    // f32-rounded operands the engine sees.
    let mut ws = DynWorkspace::new(&robot);
    let refs: Vec<Vec<f32>> = probes
        .iter()
        .map(|ops| {
            let q: Vec<f64> = ops[0].iter().map(|&x| x as f64).collect();
            let qd: Vec<f64> = ops[1].iter().map(|&x| x as f64).collect();
            let u: Vec<f64> = ops[2].iter().map(|&x| x as f64).collect();
            let mut out = vec![0.0f64; n * n + 2 * n];
            ws.dyn_all_into(&robot, &q, &qd, &u, None, &mut out);
            out.iter().map(|&x| x as f32).collect()
        })
        .collect();

    let rounds = 12usize;
    let handles: Vec<_> = (0..4)
        .map(|_| {
            let coord = Arc::clone(&coord);
            let probes = probes.clone();
            let name = robot.name.clone();
            std::thread::spawn(move || {
                let mut rounds_out = Vec::with_capacity(rounds);
                for _ in 0..rounds {
                    let rxs: Vec<_> = probes
                        .iter()
                        .map(|ops| coord.submit_to(&name, ArtifactFn::DynAll, ops.clone()))
                        .collect();
                    rounds_out.push(
                        rxs.into_iter()
                            .map(|rx| rx.recv().expect("answer").expect("ok"))
                            .collect::<Vec<Vec<f32>>>(),
                    );
                }
                rounds_out
            })
        })
        .collect();
    for h in handles {
        for round in h.join().expect("client thread") {
            for (got, want) in round.iter().zip(&refs) {
                assert_eq!(got, want, "warm pooled response diverged from the cold kernel");
            }
        }
    }
    let st = coord.stats();
    assert!(st.memo_hits > 0, "repeated states under load must hit the memo");
    assert_eq!(
        st.memo_hits + st.memo_misses,
        (4 * rounds * probes.len()) as u64,
        "every dyn_all task is memo-accounted exactly once"
    );
    if let Ok(coord) = Arc::try_unwrap(coord) {
        coord.shutdown();
    }
}

/// Quantized memo keys are the post-quantization words: a state
/// exactly one quantum away from a cached one must MISS (no aliasing)
/// and still answer bitwise equal to the memo-less quantized kernel.
#[test]
fn adjacent_quantized_states_never_alias_in_the_memo() {
    let robot = builtin_robot("iiwa").unwrap();
    let n = robot.dof();
    let fmt = QFormat::new(12, 12);
    let mut eng = QuantEngine::with_options(robot.clone(), ArtifactFn::DynAll, 4, fmt, 1, false);

    let mut rng = Rng::new(77_001);
    let s = State::random(&robot, &mut rng);
    let tau = rng.vec_range(n, -4.0, 4.0);
    // Base state on the quantization grid (grid points at Q12.12 are
    // exactly f32-representable), neighbour exactly one quantum away.
    let q_base: Vec<f32> = s.q.iter().map(|&x| fmt.q(x) as f32).collect();
    let mut q_adj = q_base.clone();
    q_adj[0] += fmt.step() as f32;
    let qd: Vec<f32> = s.qd.iter().map(|&x| fmt.q(x) as f32).collect();
    let tau32: Vec<f32> = tau.iter().map(|&x| x as f32).collect();

    let reference = |q32: &[f32]| -> Vec<f32> {
        let q: Vec<f64> = q32.iter().map(|&x| x as f64).collect();
        let qdr: Vec<f64> = qd.iter().map(|&x| x as f64).collect();
        let ur: Vec<f64> = tau32.iter().map(|&x| x as f64).collect();
        quant_dyn_all(&robot, &q, &qdr, &ur, fmt).iter().map(|&x| x as f32).collect()
    };

    let base_out =
        eng.run(&[q_base.clone(), qd.clone(), tau32.clone()]).expect("base run");
    assert_eq!(base_out, reference(&q_base), "base response vs memo-less kernel");
    assert_eq!(eng.memo_counters(), (0, 1), "cold base state must miss");

    let adj_out = eng.run(&[q_adj.clone(), qd.clone(), tau32.clone()]).expect("adjacent run");
    assert_eq!(
        eng.memo_counters(),
        (0, 2),
        "a state one quantum away must not alias the cached entry"
    );
    assert_eq!(adj_out, reference(&q_adj), "adjacent response vs memo-less kernel");
    assert_ne!(base_out, adj_out, "distinct quantized states must answer differently");

    // The true warm path still works: repeating the base state hits.
    let warm = eng.run(&[q_base.clone(), qd.clone(), tau32.clone()]).expect("warm run");
    assert_eq!(eng.memo_counters(), (1, 2), "bitwise repeat must hit");
    assert_eq!(warm, base_out, "memo hit must be bitwise identical to its cold miss");
}

/// Eviction at capacity: after `DEFAULT_MEMO_CAP` fresh states the
/// oldest entry is gone — its re-run is a miss, not a stale hit — and
/// the evicted-then-recomputed response is bitwise identical to the
/// original cold one. Counters stay monotone throughout.
#[test]
fn memo_evicts_at_capacity_and_recomputes_bitwise_identically() {
    let robot = builtin_robot("iiwa").unwrap();
    let mut eng = NativeEngine::new(robot.clone(), ArtifactFn::DynAll, 1);

    let probes: Vec<Vec<Vec<f32>>> = (0..=DEFAULT_MEMO_CAP as u64)
        .map(|k| flat_inputs(&robot, 1, 80_000 + k))
        .collect();
    let first_cold = eng.run(&probes[0]).expect("cold run");
    assert_eq!(eng.memo_counters(), (0, 1));
    let warm = eng.run(&probes[0]).expect("warm run");
    assert_eq!(eng.memo_counters(), (1, 1), "repeat while cached must hit");
    assert_eq!(warm, first_cold);

    // Fill the memo with DEFAULT_MEMO_CAP fresh states: probe 0 becomes
    // the LRU entry and falls out when the last one is inserted.
    let (mut ph, mut pm) = eng.memo_counters();
    for p in &probes[1..] {
        let out = eng.run(p).expect("fill run");
        assert!(out.iter().all(|x| x.is_finite()));
        let (h, m) = eng.memo_counters();
        assert!(h >= ph && m >= pm, "memo counters must be monotone");
        (ph, pm) = (h, m);
    }
    assert_eq!(
        eng.memo_counters(),
        (1, 1 + DEFAULT_MEMO_CAP as u64),
        "every fresh state is one miss"
    );

    let evicted = eng.run(&probes[0]).expect("post-eviction run");
    assert_eq!(
        eng.memo_counters(),
        (1, 2 + DEFAULT_MEMO_CAP as u64),
        "the evicted state must re-run as a miss, never a stale hit"
    );
    assert_eq!(evicted, first_cold, "recomputed response must equal the original cold one");
}
