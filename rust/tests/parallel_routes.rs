//! Parallel-route equivalence: a coordinator route whose batches fan out
//! across the global worker pool must answer **bitwise identically** to
//! the serial route — same kernels, same decode→kernel→encode chain, one
//! cached workspace per pool worker. Covers full batches, partial
//! batches, mixed robots, and the engine-level fan-out directly.

use draco::coordinator::{BackendKind, Coordinator, RobotRegistry};
use draco::model::{builtin_robot, Robot, State};
use draco::runtime::artifact::ArtifactFn;
use draco::runtime::NativeEngine;
use draco::util::rng::Rng;

/// Flat row-major (b, n) f32 operands for `function`.
fn flat_inputs(robot: &Robot, function: ArtifactFn, b: usize, seed: u64) -> Vec<Vec<f32>> {
    let n = robot.dof();
    let mut rng = Rng::new(seed);
    let mut q = Vec::with_capacity(b * n);
    let mut qd = Vec::with_capacity(b * n);
    let mut u = Vec::with_capacity(b * n);
    for _ in 0..b {
        let s = State::random(robot, &mut rng);
        q.extend(s.q.iter().map(|&x| x as f32));
        qd.extend(s.qd.iter().map(|&x| x as f32));
        u.extend(rng.vec_range(n, -6.0, 6.0).iter().map(|&x| x as f32));
    }
    match function {
        ArtifactFn::Minv => vec![q],
        _ => vec![q, qd, u],
    }
}

/// Engine level: the pooled fan-out inside `NativeEngine::run` is bitwise
/// equal to the serial engine for every function, across full and
/// partial batches and odd chunk counts.
#[test]
fn parallel_engine_matches_serial_bitwise() {
    for name in ["iiwa", "atlas"] {
        let robot = builtin_robot(name).unwrap();
        for function in [ArtifactFn::Rnea, ArtifactFn::Fd, ArtifactFn::Minv] {
            let mut serial = NativeEngine::new(robot.clone(), function, 64);
            for parallel in [2usize, 3, 8, 0] {
                let mut par =
                    NativeEngine::with_parallelism(robot.clone(), function, 64, parallel);
                for b in [2usize, 5, 16, 64] {
                    let inputs = flat_inputs(&robot, function, b, 7_000 + b as u64);
                    let want = serial.run(&inputs).expect("serial run");
                    let got = par.run(&inputs).expect("parallel run");
                    assert_eq!(
                        want, got,
                        "{name}/{} b={b} parallel={parallel}",
                        function.name()
                    );
                }
            }
        }
    }
}

/// Coordinator level: the same request stream through a serial registry
/// and a parallel registry (mixed robots, f64 + quantized backends)
/// produces bitwise-identical responses. The quantized robot pins the
/// routing: its routes always execute serially.
#[test]
fn parallel_route_matches_serial_route_bitwise() {
    let iiwa = builtin_robot("iiwa").unwrap();
    let hyq = builtin_robot("hyq").unwrap();

    let build = |parallel: usize| {
        let mut reg = RobotRegistry::new();
        reg.register_parallel(iiwa.clone(), BackendKind::Native, 16, parallel)
            .register_parallel(hyq.clone(), BackendKind::Native, 16, parallel);
        Coordinator::start_registry(&reg, 20_000)
    };
    let serial = build(1);
    let pooled = build(0); // one chunk per pool worker

    // Full batch (16), partial batch (5), and a single-task batch per
    // (robot, function) pair — identical streams to both coordinators.
    for (robot, base_seed) in [(&iiwa, 100u64), (&hyq, 200)] {
        for function in [ArtifactFn::Rnea, ArtifactFn::Fd, ArtifactFn::Minv] {
            for (burst, seed_off) in [(16usize, 0u64), (5, 1), (1, 2)] {
                let n = robot.dof();
                let per_task: Vec<Vec<Vec<f32>>> = (0..burst)
                    .map(|k| {
                        flat_inputs(robot, function, 1, base_seed + 10 * seed_off + k as u64)
                    })
                    .collect();
                let answers = |coord: &Coordinator| -> Vec<Vec<f32>> {
                    let rxs: Vec<_> = per_task
                        .iter()
                        .map(|ops| coord.submit_to(&robot.name, function, ops.clone()))
                        .collect();
                    rxs.into_iter()
                        .map(|rx| rx.recv().expect("answer").expect("ok"))
                        .collect()
                };
                let want = answers(&serial);
                let got = answers(&pooled);
                assert_eq!(want.len(), got.len());
                for (k, (a, b)) in want.iter().zip(&got).enumerate() {
                    let expect_len = match function {
                        ArtifactFn::Minv => n * n,
                        _ => n,
                    };
                    assert_eq!(a.len(), expect_len);
                    assert_eq!(
                        a, b,
                        "{}/{} burst={burst} task {k} diverged",
                        robot.name,
                        function.name()
                    );
                }
            }
        }
    }
    serial.shutdown();
    pooled.shutdown();
}
