//! Overload-robustness integration tests: QoS priority draining,
//! deadline-aware admission, fault isolation, and circuit-breaker
//! recovery — all through the public coordinator API.
//!
//! Every test drives a `BackendSpec::Chaos` route: its `delay_us`
//! throttle pins capacity (so "the worker is busy" is a constructed
//! fact, not a race), and its infinite-operand sentinel injects panics
//! on demand.

use draco::coordinator::{
    BackendSpec, Coordinator, QosClass, QosPolicy, ServeError, SubmitOptions,
};
use draco::model::builtin_robot;
use draco::runtime::ArtifactFn;
use std::time::Duration;

fn chaos_spec(robot_name: &str, batch: usize, delay_us: u64) -> (BackendSpec, usize) {
    let robot = builtin_robot(robot_name).unwrap();
    let n = robot.dof();
    let spec = BackendSpec::Chaos {
        robot,
        function: ArtifactFn::Fd,
        batch,
        delay_us,
        class: QosClass::default(),
    };
    (spec, n)
}

fn clean_ops(n: usize) -> Vec<Vec<f32>> {
    vec![vec![0.1; n], vec![0.0; n], vec![0.0; n]]
}

fn poison_ops(n: usize) -> Vec<Vec<f32>> {
    let mut ops = clean_ops(n);
    ops[0][0] = f32::INFINITY;
    ops
}

/// While a throttled worker is busy, a Control job submitted *after* a
/// pile of Bulk jobs must still ride the next batch: the class lanes
/// drain strictly by priority, so Control's observed latency stays well
/// under the Bulk median.
#[test]
fn control_jobs_overtake_queued_bulk() {
    let (spec, n) = chaos_spec("iiwa", 2, 20_000);
    let coord = Coordinator::start_with_policy(vec![spec], n, 1_000, QosPolicy::default());

    // Warmup batch occupies the worker for ~20 ms …
    let warm = coord.submit_to("iiwa", ArtifactFn::Fd, clean_ops(n));
    std::thread::sleep(Duration::from_millis(5));
    // … then six Bulk jobs enqueue first, one Control job last.
    let bulk: Vec<_> = (0..6)
        .map(|_| {
            coord.submit_to_opts(
                "iiwa",
                ArtifactFn::Fd,
                clean_ops(n),
                SubmitOptions::class(QosClass::Bulk),
            )
        })
        .collect();
    let control = coord.submit_to_opts(
        "iiwa",
        ArtifactFn::Fd,
        clean_ops(n),
        SubmitOptions::class(QosClass::Control),
    );

    warm.recv().expect("answer").expect("warmup ok");
    control.recv().expect("answer").expect("control ok");
    for rx in bulk {
        rx.recv().expect("answer").expect("bulk ok");
    }

    let st = coord.stats();
    let ctl = st.class(QosClass::Control);
    let blk = st.class(QosClass::Bulk);
    assert_eq!(ctl.completed, 1);
    assert_eq!(blk.completed, 6);
    // Control rode the first post-warmup batch (~2 execution slots of
    // wait); the Bulk median sat at least one extra 20 ms slot behind it.
    assert!(
        ctl.p50_latency_us + 15_000.0 < blk.p50_latency_us,
        "control p50 {} µs did not overtake bulk p50 {} µs",
        ctl.p50_latency_us,
        blk.p50_latency_us
    );
    coord.shutdown();
}

/// Admission control: beyond the per-class cap the coordinator answers
/// `Rejected` immediately — with the offending class, the cap it hit,
/// and a retry hint — instead of queueing without bound.
#[test]
fn over_cap_submissions_are_rejected_with_retry_hint() {
    let (spec, n) = chaos_spec("iiwa", 2, 50_000);
    let policy = QosPolicy { queue_cap: [1, 1, 1], ..QosPolicy::default() };
    let coord = Coordinator::start_with_policy(vec![spec], n, 1_000, policy);

    let first = coord.submit_to_opts(
        "iiwa",
        ArtifactFn::Fd,
        clean_ops(n),
        SubmitOptions::class(QosClass::Bulk),
    );
    let second = coord.submit_to_opts(
        "iiwa",
        ArtifactFn::Fd,
        clean_ops(n),
        SubmitOptions::class(QosClass::Bulk),
    );
    match second.recv().expect("rejection is answered immediately") {
        Err(ServeError::Rejected { class, depth, retry_after_us }) => {
            assert_eq!(class, QosClass::Bulk);
            assert_eq!(depth, 1, "cap of 1 was full");
            assert!(retry_after_us > 0, "rejection must carry a retry hint");
        }
        other => panic!("expected Rejected, got {other:?}"),
    }
    first.recv().expect("answer").expect("admitted job still served");
    let st = coord.stats();
    assert_eq!(st.rejected, 1);
    assert_eq!(st.completed, 1);
    coord.shutdown();
}

/// A job whose deadline lapses while it waits is answered `Expired` at
/// batch formation and never reaches the engine.
#[test]
fn deadline_lapse_answers_expired_without_execution() {
    let (spec, n) = chaos_spec("iiwa", 2, 30_000);
    let coord = Coordinator::start_with_policy(vec![spec], n, 1_000, QosPolicy::default());

    // Occupy the worker for ~30 ms, then submit a 5 ms deadline.
    let warm = coord.submit_to("iiwa", ArtifactFn::Fd, clean_ops(n));
    std::thread::sleep(Duration::from_millis(5));
    let doomed = coord.submit_to_opts(
        "iiwa",
        ArtifactFn::Fd,
        clean_ops(n),
        SubmitOptions::deadline_us(5_000),
    );
    match doomed.recv().expect("expired job is still answered") {
        Err(ServeError::Expired { deadline_us, waited_us }) => {
            assert_eq!(deadline_us, 5_000);
            assert!(waited_us >= 5_000, "reported wait {waited_us} µs below the deadline");
        }
        other => panic!("expected Expired, got {other:?}"),
    }
    warm.recv().expect("answer").expect("warmup ok");
    let st = coord.stats();
    assert_eq!(st.expired, 1);
    assert_eq!(st.completed, 1, "the expired job must not count as completed");
    coord.shutdown();
}

/// A panicking engine fails only its own route's batch: the sibling
/// route keeps serving, the tripped route sheds while its breaker is
/// open, and a half-open probe after the cooldown recovers it.
#[test]
fn route_panic_is_isolated_and_breaker_recovers() {
    let (iiwa_spec, n_iiwa) = chaos_spec("iiwa", 2, 0);
    let (hyq_spec, n_hyq) = chaos_spec("hyq", 2, 0);
    let policy =
        QosPolicy { breaker_trip: 2, breaker_cooldown_us: 50_000, ..QosPolicy::default() };
    let coord =
        Coordinator::start_with_policy(vec![iiwa_spec, hyq_spec], n_iiwa, 500, policy);

    // Two consecutive poisoned batches trip iiwa's breaker …
    for i in 0..2 {
        let res = coord
            .submit_to("iiwa", ArtifactFn::Fd, poison_ops(n_iiwa))
            .recv()
            .expect("panicked batch is still answered");
        match res {
            Err(ServeError::Engine(msg)) => {
                assert!(msg.contains("panic"), "batch {i}: engine error lost the cause: {msg}")
            }
            other => panic!("batch {i}: expected Engine error, got {other:?}"),
        }
        // … while hyq serves clean traffic throughout.
        coord
            .submit_to("hyq", ArtifactFn::Fd, clean_ops(n_hyq))
            .recv()
            .expect("answer")
            .expect("sibling route must keep serving");
    }

    // Breaker open: iiwa sheds at admission, hyq is untouched.
    match coord.submit_to("iiwa", ArtifactFn::Fd, clean_ops(n_iiwa)).recv().expect("answered") {
        Err(ServeError::Shed { consecutive_failures, retry_after_us }) => {
            assert!(consecutive_failures >= 2);
            assert!(retry_after_us > 0);
        }
        other => panic!("expected Shed while the breaker is open, got {other:?}"),
    }
    coord
        .submit_to("hyq", ArtifactFn::Fd, clean_ops(n_hyq))
        .recv()
        .expect("answer")
        .expect("sibling route unaffected by the open breaker");

    // Cooldown lapses → half-open probe is admitted, succeeds, and
    // closes the breaker for good.
    std::thread::sleep(Duration::from_micros(60_000));
    coord
        .submit_to("iiwa", ArtifactFn::Fd, clean_ops(n_iiwa))
        .recv()
        .expect("answer")
        .expect("half-open probe must execute");
    coord
        .submit_to("iiwa", ArtifactFn::Fd, clean_ops(n_iiwa))
        .recv()
        .expect("answer")
        .expect("breaker closed after the probe");

    let st = coord.stats();
    assert!(st.breaker_trips >= 1, "trip must be counted");
    assert_eq!(st.shed, 1);
    coord.shutdown();
}

/// Failure granularity is the batch: a clean job sharing a batch with a
/// poisoned one fails too (documented blast radius), but the route
/// recovers on the very next batch — no breaker trip from a single
/// failure under the default policy.
#[test]
fn poisoned_batch_fails_whole_batch_then_route_recovers() {
    let (spec, n) = chaos_spec("iiwa", 2, 20_000);
    let coord = Coordinator::start_with_policy(vec![spec], n, 1_000, QosPolicy::default());

    // Warmup occupies the worker so the next two jobs co-batch.
    let warm = coord.submit_to("iiwa", ArtifactFn::Fd, clean_ops(n));
    std::thread::sleep(Duration::from_millis(5));
    let poisoned = coord.submit_to("iiwa", ArtifactFn::Fd, poison_ops(n));
    let innocent = coord.submit_to("iiwa", ArtifactFn::Fd, clean_ops(n));

    warm.recv().expect("answer").expect("warmup ok");
    assert!(
        matches!(poisoned.recv().expect("answered"), Err(ServeError::Engine(_))),
        "poisoned job must fail"
    );
    assert!(
        matches!(innocent.recv().expect("answered"), Err(ServeError::Engine(_))),
        "batch-mate shares the blast radius"
    );

    // The next clean batch serves normally — one failed batch does not
    // trip the default breaker.
    coord
        .submit_to("iiwa", ArtifactFn::Fd, clean_ops(n))
        .recv()
        .expect("answer")
        .expect("route recovered after the failed batch");
    coord.shutdown();
}
