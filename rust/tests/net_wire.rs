//! Wire-level integration tests for the streaming JSONL front-end:
//! malformed-frame handling (every bad line is answered in-band with an
//! `err` frame and the connection survives), chunked response streaming
//! (ack < chunks < done, contiguous sequence numbers, bitwise agreement
//! with the in-process API), and record/replay (a `--tee` capture
//! re-executes bitwise-identical through `replay_log`).

use draco::coordinator::{Coordinator, RobotRegistry};
use draco::net::{replay_log, Frame, NetClient, NetServer, MAX_LINE_BYTES};
use draco::net::frame::{req_step_line, req_traj_line};
use draco::coordinator::TrajRequest;
use std::collections::BTreeMap;
use std::io::Write;
use std::net::TcpStream;
use std::sync::Arc;

/// Bring up a server over a fresh single-robot coordinator; returns the
/// server, a coordinator handle for in-process cross-checks, and N.
fn start_server(tee: Option<&str>) -> (NetServer, Arc<Coordinator>, usize) {
    let registry = RobotRegistry::from_cli_spec("iiwa", 4).unwrap();
    let n = registry.get("iiwa").unwrap().robot.dof();
    let coord = Arc::new(Coordinator::start_registry(&registry, 200));
    let dims: BTreeMap<String, usize> = [("iiwa".to_string(), n)].into_iter().collect();
    let server =
        NetServer::start(Arc::clone(&coord), dims, "127.0.0.1:0", tee, "iiwa", 4, 200).unwrap();
    (server, coord, n)
}

fn ops(n: usize, v: f32) -> Vec<Vec<f32>> {
    vec![vec![v; n], vec![0.0; n], vec![0.0; n]]
}

fn expect_err_for(client: &mut NetClient, id: u64) {
    match client.read_frame().unwrap() {
        Frame::Err { id: got, msg } => assert_eq!(got, id, "err for wrong id: {msg}"),
        other => panic!("expected err frame for id {id}, got {other:?}"),
    }
}

/// Read ack + chunks + done for `id`; returns the chunks in order.
fn read_ok_stream(client: &mut NetClient, id: u64) -> Vec<Vec<f32>> {
    match client.read_frame().unwrap() {
        Frame::Ack { id: got } => assert_eq!(got, id),
        other => panic!("expected ack for id {id}, got {other:?}"),
    }
    let mut chunks = Vec::new();
    loop {
        match client.read_frame().unwrap() {
            Frame::Chunk { id: got, seq, data } => {
                assert_eq!(got, id);
                assert_eq!(seq, chunks.len() as u64, "chunk seq must be contiguous");
                chunks.push(data);
            }
            Frame::Done { id: got, chunks: count } => {
                assert_eq!(got, id);
                assert_eq!(count, chunks.len() as u64, "done must name the chunk count");
                return chunks;
            }
            other => panic!("unexpected frame for id {id}: {other:?}"),
        }
    }
}

/// Every malformed line — truncated JSON, binary garbage, unknown
/// route/robot/class, wrong frame type, oversized line — is answered
/// with an `err` frame, and the same connection then serves a clean
/// request. Nothing hangs, nothing disconnects.
#[test]
fn malformed_frames_are_answered_in_band_and_the_connection_survives() {
    let (server, _coord, n) = start_server(None);
    let mut raw = TcpStream::connect(server.addr()).unwrap();
    let mut client = NetClient::from_stream(raw.try_clone().unwrap()).unwrap();

    // Truncated line (unterminated object).
    raw.write_all(b"{\"id\":1,\"type\":\"req\"\n").unwrap();
    expect_err_for(&mut client, 0);

    // Binary garbage: not UTF-8.
    raw.write_all(b"{\"id\":2,\xff\xfe}\n").unwrap();
    expect_err_for(&mut client, 0);

    // Valid JSON, wrong frame type.
    raw.write_all(b"{\"id\":3,\"type\":\"ack\"}\n").unwrap();
    expect_err_for(&mut client, 3);

    // Unknown route / robot / class — the id comes back in the err.
    client.send_line(&req_step_line(4, "iiwa", "warp", None, None, &ops(n, 0.1))).unwrap();
    expect_err_for(&mut client, 4);
    client.send_line(&req_step_line(5, "r2d2", "fd", None, None, &ops(n, 0.1))).unwrap();
    expect_err_for(&mut client, 5);
    client
        .send_line(&req_step_line(6, "iiwa", "fd", Some("warp"), None, &ops(n, 0.1)))
        .unwrap();
    expect_err_for(&mut client, 6);

    // Missing payload.
    raw.write_all(b"{\"id\":7,\"robot\":\"iiwa\",\"route\":\"fd\",\"type\":\"req\"}\n").unwrap();
    expect_err_for(&mut client, 7);

    // Oversized line: capped, discarded to the next newline, answered.
    let mut big = vec![b'a'; MAX_LINE_BYTES + 16];
    big.push(b'\n');
    raw.write_all(&big).unwrap();
    expect_err_for(&mut client, 0);

    // The connection still works.
    client.send_line(&req_step_line(8, "iiwa", "fd", None, None, &ops(n, 0.1))).unwrap();
    let chunks = read_ok_stream(&mut client, 8);
    assert_eq!(chunks.len(), 1);
    assert_eq!(chunks[0].len(), n);
    assert!(chunks[0].iter().all(|x| x.is_finite()));

    drop(client);
    drop(raw);
    server.stop();
}

/// Trajectory responses stream one `q_t ‖ q̇_t` row per chunk, in
/// order, and the concatenation is bitwise identical to the buffered
/// in-process rollout; `dyn_all` splits into its three segments.
#[test]
fn streamed_responses_are_chunked_in_order_and_bitwise_identical() {
    let (server, coord, n) = start_server(None);
    let mut client = NetClient::connect(server.addr()).unwrap();

    let h = 12;
    let q0 = vec![0.2f32; n];
    let qd0 = vec![-0.1f32; n];
    let tau: Vec<f32> = (0..h * n).map(|i| ((i % 7) as f32 - 3.0) * 0.1).collect();
    client
        .send_line(&req_traj_line(1, "iiwa", None, None, &q0, &qd0, &tau, 1e-3))
        .unwrap();
    let rows = read_ok_stream(&mut client, 1);
    assert_eq!(rows.len(), h, "one chunk per integrated row");
    let legacy = coord
        .submit_traj("iiwa", TrajRequest { q0, qd0, tau, dt: 1e-3 })
        .recv()
        .unwrap()
        .unwrap();
    for (t, row) in rows.iter().enumerate() {
        assert_eq!(row.len(), 2 * n);
        for j in 0..n {
            assert_eq!(row[j].to_bits(), legacy[t * n + j].to_bits(), "q row {t}");
            assert_eq!(row[n + j].to_bits(), legacy[(h + t) * n + j].to_bits(), "q̇ row {t}");
        }
    }

    client.send_line(&req_step_line(2, "iiwa", "dynall", None, None, &ops(n, 0.3))).unwrap();
    let segs = read_ok_stream(&mut client, 2);
    let lens: Vec<usize> = segs.iter().map(Vec::len).collect();
    assert_eq!(lens, [n, n * n, n], "dyn_all must frame q̈ | M⁻¹ | C segments");

    drop(client);
    server.stop();
}

/// A tee capture of mixed traffic — steps, a fused route, a streamed
/// trajectory, a deadline-0 expiry, an unknown route — replays clean:
/// every deterministic outcome reproduces bitwise, the refusal is
/// skipped as timing-dependent, and lazy/full parsing agree everywhere.
#[test]
fn tee_capture_replays_bitwise() {
    let tee = std::env::temp_dir().join(format!("draco_net_wire_tee_{}.jsonl", std::process::id()));
    let tee_str = tee.to_str().unwrap().to_string();
    let (server, _coord, n) = start_server(Some(&tee_str));
    let mut client = NetClient::connect(server.addr()).unwrap();

    for id in 1..=3u64 {
        client
            .send_line(&req_step_line(id, "iiwa", "fd", None, None, &ops(n, 0.05 * id as f32)))
            .unwrap();
        assert_eq!(read_ok_stream(&mut client, id).len(), 1);
    }
    client.send_line(&req_step_line(4, "iiwa", "dynall", None, None, &ops(n, 0.4))).unwrap();
    assert_eq!(read_ok_stream(&mut client, 4).len(), 3);

    let h = 6;
    let tau: Vec<f32> = (0..h * n).map(|i| (i as f32).sin()).collect();
    client
        .send_line(&req_traj_line(5, "iiwa", None, None, &vec![0.1; n], &vec![0.0; n], &tau, 1e-3))
        .unwrap();
    assert_eq!(read_ok_stream(&mut client, 5).len(), h);

    // Deadline-0: expired live; replay strips deadlines and skips it.
    client
        .send_line(&req_step_line(6, "iiwa", "fd", Some("bulk"), Some(0), &ops(n, 0.1)))
        .unwrap();
    match client.read_frame().unwrap() {
        Frame::Ack { id: 6 } => {}
        other => panic!("expected ack, got {other:?}"),
    }
    match client.read_frame().unwrap() {
        Frame::Expired { id: 6, .. } => {}
        other => panic!("expected expired, got {other:?}"),
    }

    // Unknown route: a deterministic error — replay must also error.
    client.send_line(&req_step_line(7, "iiwa", "warp", None, None, &ops(n, 0.1))).unwrap();
    expect_err_for(&mut client, 7);

    drop(client);
    server.stop();

    let report = replay_log(&tee_str).unwrap();
    assert_eq!(report.requests, 7);
    assert_eq!(report.compared, 6, "five successes + one deterministic error");
    assert_eq!(report.matched, 6, "replayed payloads must be bitwise identical");
    assert_eq!(report.timing_skipped, 1, "the expired request is timing-dependent");
    assert_eq!(report.lazy_mismatches, 0);
    assert!(report.is_clean());
    let _ = std::fs::remove_file(&tee);
}
