//! Fault-tolerance integration tests for the wire layer: concurrent
//! connections with overlapping request ids (responses must route to
//! the asking socket, bitwise identical to serial in-process
//! submission), multi-connection tee captures replaying clean through
//! the conn-tag namespacing, hostile peers (seeded garbage and torn
//! writes) leaving healthy clients untouched, mid-stream client death
//! cancelling server-side work, and `stop` force-draining connected
//! peers within its grace window.

use draco::coordinator::{Coordinator, RobotRegistry};
use draco::net::frame::{req_step_line, req_traj_line};
use draco::net::{replay_log, FaultPlan, FaultyClient, Frame, NetClient, NetServer};
use draco::runtime::ArtifactFn;
use std::collections::BTreeMap;
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn start_server(tee: Option<&str>) -> (NetServer, Arc<Coordinator>, usize) {
    let registry = RobotRegistry::from_cli_spec("iiwa", 4).unwrap();
    let n = registry.get("iiwa").unwrap().robot.dof();
    let coord = Arc::new(Coordinator::start_registry(&registry, 200));
    let dims: BTreeMap<String, usize> = [("iiwa".to_string(), n)].into_iter().collect();
    let server =
        NetServer::start(Arc::clone(&coord), dims, "127.0.0.1:0", tee, "iiwa", 4, 200).unwrap();
    (server, coord, n)
}

fn ops(n: usize, v: f32) -> Vec<Vec<f32>> {
    vec![vec![v; n], vec![0.0; n], vec![0.0; n]]
}

/// Read ack + chunks + done for `id`, concatenating the payload.
/// `err` frames for id 0 (answers to injected garbage) are ignored.
fn read_payload(client: &mut NetClient, id: u64) -> Vec<f32> {
    let mut payload = Vec::new();
    loop {
        match client.read_frame().unwrap() {
            Frame::Ack { id: got } if got == id => {}
            Frame::Chunk { id: got, data, .. } if got == id => payload.extend(data),
            Frame::Done { id: got, .. } if got == id => return payload,
            Frame::Err { id: 0, .. } => {}
            other => panic!("unexpected frame while waiting on id {id}: {other:?}"),
        }
    }
}

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: value {i}");
    }
}

/// Two simultaneous clients submit interleaved requests with the SAME
/// request ids on the same route but different operands. Each response
/// must come back on the connection that asked, bitwise identical to
/// what serial in-process submission produces for that connection's
/// operands — any cross-connection bleed flips the payload.
#[test]
fn overlapping_ids_on_two_connections_route_bitwise() {
    let (server, coord, n) = start_server(None);
    let ops_a = ops(n, 0.1);
    let ops_b = ops(n, 0.25);
    let want_a = coord.submit_to("iiwa", ArtifactFn::Fd, ops_a.clone()).recv().unwrap().unwrap();
    let want_b = coord.submit_to("iiwa", ArtifactFn::Fd, ops_b.clone()).recv().unwrap().unwrap();

    let mut a = NetClient::connect(server.addr()).unwrap();
    let mut b = NetClient::connect(server.addr()).unwrap();
    for id in 1..=8u64 {
        // Interleave the sends so both connections' requests share
        // batches server-side, then read both responses.
        a.send_line(&req_step_line(id, "iiwa", "fd", None, None, &ops_a)).unwrap();
        b.send_line(&req_step_line(id, "iiwa", "fd", None, None, &ops_b)).unwrap();
        assert_bits_eq(&read_payload(&mut a, id), &want_a, "client A");
        assert_bits_eq(&read_payload(&mut b, id), &want_b, "client B");
    }
    drop(a);
    drop(b);
    server.stop();
}

/// A tee capture of two concurrent connections using overlapping ids
/// replays clean: the conn tags keep the namespaces separate, every
/// request is found, and every deterministic payload reproduces
/// bitwise.
#[test]
fn multi_connection_tee_capture_replays_clean() {
    let tee =
        std::env::temp_dir().join(format!("draco_net_faults_tee_{}.jsonl", std::process::id()));
    let tee_str = tee.to_str().unwrap().to_string();
    let (server, _coord, n) = start_server(Some(&tee_str));

    let mut a = NetClient::connect(server.addr()).unwrap();
    let mut b = NetClient::connect(server.addr()).unwrap();
    for id in 1..=3u64 {
        a.send_line(&req_step_line(id, "iiwa", "fd", None, None, &ops(n, 0.1))).unwrap();
        b.send_line(&req_step_line(id, "iiwa", "dynall", None, None, &ops(n, 0.2))).unwrap();
        let _ = read_payload(&mut a, id);
        let _ = read_payload(&mut b, id);
    }
    drop(a);
    drop(b);
    server.stop();

    let report = replay_log(&tee_str).unwrap();
    assert_eq!(report.requests, 6, "three requests per connection");
    assert_eq!(report.compared, 6);
    assert_eq!(report.matched, 6, "replayed payloads must be bitwise identical");
    assert_eq!(report.lazy_mismatches, 0);
    assert!(report.is_clean());
    let _ = std::fs::remove_file(&tee);
}

/// A hostile peer spraying seeded garbage lines and tearing every write
/// does not perturb a healthy client on the same server: the healthy
/// payloads stay bitwise identical to the in-process reference, and the
/// hostile connection's own well-formed requests still complete.
#[test]
fn faulty_peer_leaves_healthy_client_untouched() {
    let (server, coord, n) = start_server(None);
    let ops_h = ops(n, 0.1);
    let ops_f = ops(n, 0.3);
    let want_h = coord.submit_to("iiwa", ArtifactFn::Fd, ops_h.clone()).recv().unwrap().unwrap();
    let want_f = coord.submit_to("iiwa", ArtifactFn::Fd, ops_f.clone()).recv().unwrap().unwrap();

    let sock = TcpStream::connect(server.addr()).unwrap();
    let mut faulty_reader = NetClient::from_stream(sock.try_clone().unwrap()).unwrap();
    let plan = FaultPlan {
        seed: 0xF001,
        garbage_every: 1.0,
        tear_writes: 1.0,
        fragment_delay_us: 100,
        disconnect_after: 0,
    };
    let mut faulty = FaultyClient::from_stream(sock, plan).unwrap();
    let mut healthy = NetClient::connect(server.addr()).unwrap();

    for id in 1..=6u64 {
        assert!(faulty
            .send_line(&req_step_line(id, "iiwa", "fd", None, None, &ops_f))
            .unwrap());
        healthy.send_line(&req_step_line(id, "iiwa", "fd", None, None, &ops_h)).unwrap();
        assert_bits_eq(&read_payload(&mut healthy, id), &want_h, "healthy");
        assert_bits_eq(&read_payload(&mut faulty_reader, id), &want_f, "faulty");
    }
    drop(healthy);
    drop(faulty);
    drop(faulty_reader);
    server.stop();
}

/// A client that dies while a long trajectory is still streaming (and
/// its egress queue is full) must not wedge the server: production
/// cancels on the dead wire, and a fresh client is served immediately.
#[test]
fn client_death_mid_stream_cancels_and_frees_the_route() {
    let (server, _coord, n) = start_server(None);

    let mut dying = NetClient::connect(server.addr()).unwrap();
    // Horizon far deeper than the egress queue, so the producer is
    // still integrating when the peer vanishes.
    let h = 4096;
    let tau = vec![0.05f32; h * n];
    dying
        .send_line(&req_traj_line(1, "iiwa", None, None, &vec![0.1; n], &vec![0.0; n], &tau, 1e-3))
        .unwrap();
    // Stream has started: ack + one row.
    match dying.read_frame().unwrap() {
        Frame::Ack { id: 1 } => {}
        other => panic!("expected ack, got {other:?}"),
    }
    match dying.read_frame().unwrap() {
        Frame::Chunk { id: 1, .. } => {}
        other => panic!("expected a chunk, got {other:?}"),
    }
    drop(dying);

    // The route must come back to a fresh client promptly — a stuck
    // batch or a held lock would stall this request past the timeout.
    let t0 = Instant::now();
    let mut fresh = NetClient::connect(server.addr()).unwrap();
    fresh.send_line(&req_step_line(2, "iiwa", "fd", None, None, &ops(n, 0.1))).unwrap();
    let payload = read_payload(&mut fresh, 2);
    assert_eq!(payload.len(), n);
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "fresh client stalled {:?} behind a dead peer's stream",
        t0.elapsed()
    );
    drop(fresh);
    server.stop();
}

/// `stop` must not wait on client goodwill: with a peer that stays
/// connected, sends nothing, and reads nothing, the force-drain kills
/// it and `stop` returns within its grace window.
#[test]
fn stop_force_drains_a_connected_idle_client() {
    let (server, _coord, _n) = start_server(None);
    let idler = TcpStream::connect(server.addr()).unwrap();
    let t0 = Instant::now();
    server.stop_within(Duration::from_millis(500));
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "stop took {:?} with an idle client connected",
        t0.elapsed()
    );
    drop(idler);
}
