//! Observability integration tests: span completeness across every
//! terminal path, metrics-vs-trace consistency under pooled multi-client
//! load, the `stats` wire route, and drop-oldest ring overflow — all
//! through the public coordinator and network APIs.
//!
//! The refusal paths reuse the overload-test construction: a
//! `BackendSpec::Chaos` route whose `delay_us` throttle pins capacity
//! (so "the worker is busy" is a constructed fact, not a race) and whose
//! infinite-operand sentinel injects engine panics on demand.

use draco::coordinator::{
    BackendSpec, Coordinator, QosClass, QosPolicy, ResponseSink, ServeError, SubmitOptions,
};
use draco::model::builtin_robot;
use draco::net::{frame, Frame, NetClient, NetServer};
use draco::obs::Terminal;
use draco::runtime::ArtifactFn;
use std::collections::BTreeMap;
use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::Duration;

fn chaos_spec(robot_name: &str, batch: usize, delay_us: u64) -> (BackendSpec, usize) {
    let robot = builtin_robot(robot_name).unwrap();
    let n = robot.dof();
    let spec = BackendSpec::Chaos {
        robot,
        function: ArtifactFn::Fd,
        batch,
        delay_us,
        class: QosClass::default(),
    };
    (spec, n)
}

fn native_spec(robot_name: &str, batch: usize, parallel: usize) -> (BackendSpec, usize) {
    let robot = builtin_robot(robot_name).unwrap();
    let n = robot.dof();
    let spec = BackendSpec::Native {
        robot,
        function: ArtifactFn::Fd,
        batch,
        parallel,
        class: QosClass::default(),
    };
    (spec, n)
}

fn clean_ops(n: usize) -> Vec<Vec<f32>> {
    vec![vec![0.1; n], vec![0.0; n], vec![0.0; n]]
}

fn poison_ops(n: usize) -> Vec<Vec<f32>> {
    let mut ops = clean_ops(n);
    ops[0][0] = f32::INFINITY;
    ops
}

/// Sink whose consumer is already gone — drives the `Cancelled` span
/// path at batch formation.
struct DeadSink {
    done_tx: Sender<Result<(), ServeError>>,
}

impl ResponseSink for DeadSink {
    fn chunk(&mut self, _data: &[f32]) {}
    fn done(&mut self, result: Result<(), ServeError>) {
        let _ = self.done_tx.send(result);
    }
    fn alive(&self) -> bool {
        false
    }
}

/// Every request — served, refused at admission, dropped at formation,
/// failed in the engine, or cancelled — produces exactly one span with
/// the matching terminal; nothing is recorded as `Abandoned`, and the
/// recorded terminal counts agree with the coordinator's own stats.
#[test]
fn every_terminal_path_records_exactly_one_span() {
    let (spec, n) = chaos_spec("iiwa", 2, 20_000);
    let policy = QosPolicy {
        queue_cap: [8, 8, 1],
        breaker_trip: 2,
        breaker_cooldown_us: 200_000,
        ..QosPolicy::default()
    };
    let coord = Coordinator::start_with_policy(vec![spec], n, 1_000, policy);
    coord.obs().enable_tracing(2, 256);

    // Done: one clean request, served.
    coord.submit_to("iiwa", ArtifactFn::Fd, clean_ops(n)).recv().unwrap().expect("clean ok");

    // Rejected: while the worker is busy, the Bulk cap of 1 fills and
    // the second Bulk submission is refused at admission.
    let warm = coord.submit_to("iiwa", ArtifactFn::Fd, clean_ops(n));
    std::thread::sleep(Duration::from_millis(5));
    let b1 = coord.submit_to_opts(
        "iiwa",
        ArtifactFn::Fd,
        clean_ops(n),
        SubmitOptions::class(QosClass::Bulk),
    );
    let b2 = coord.submit_to_opts(
        "iiwa",
        ArtifactFn::Fd,
        clean_ops(n),
        SubmitOptions::class(QosClass::Bulk),
    );
    assert!(matches!(b2.recv().unwrap(), Err(ServeError::Rejected { .. })));
    warm.recv().unwrap().expect("warm ok");
    b1.recv().unwrap().expect("queued bulk ok");

    // Expired: a 5 ms deadline lapses behind a ~20 ms busy worker.
    let warm2 = coord.submit_to("iiwa", ArtifactFn::Fd, clean_ops(n));
    std::thread::sleep(Duration::from_millis(5));
    let doomed = coord.submit_to_opts(
        "iiwa",
        ArtifactFn::Fd,
        clean_ops(n),
        SubmitOptions::deadline_us(5_000),
    );
    assert!(matches!(doomed.recv().unwrap(), Err(ServeError::Expired { .. })));
    warm2.recv().unwrap().expect("warm2 ok");

    // Error ×2 (tripping the breaker), then Shed while it is open.
    for _ in 0..2 {
        assert!(matches!(
            coord.submit_to("iiwa", ArtifactFn::Fd, poison_ops(n)).recv().unwrap(),
            Err(ServeError::Engine(_))
        ));
    }
    assert!(matches!(
        coord.submit_to("iiwa", ArtifactFn::Fd, clean_ops(n)).recv().unwrap(),
        Err(ServeError::Shed { .. })
    ));

    // Cancelled: the sink is dead when the batch forms.
    let (done_tx, done_rx) = std::sync::mpsc::channel();
    coord.submit_to_sink(
        "iiwa",
        ArtifactFn::Fd,
        clean_ops(n),
        SubmitOptions::default(),
        Box::new(DeadSink { done_tx }),
    );
    assert!(matches!(done_rx.recv().unwrap(), Err(ServeError::Cancelled)));

    // Shutdown joins the workers, so every span has been pushed before
    // the drain (the Done path finishes its span after the client's
    // receiver fires).
    let st = coord.stats();
    let obs = Arc::clone(coord.obs());
    coord.shutdown();
    let recs = obs.trace().expect("tracing enabled").drain();

    let mut by_terminal: BTreeMap<Terminal, u64> = BTreeMap::new();
    for r in &recs {
        *by_terminal.entry(r.terminal).or_insert(0) += 1;
    }
    assert_eq!(by_terminal.get(&Terminal::Done), Some(&4), "{by_terminal:?}");
    assert_eq!(by_terminal.get(&Terminal::Rejected), Some(&1));
    assert_eq!(by_terminal.get(&Terminal::Expired), Some(&1));
    assert_eq!(by_terminal.get(&Terminal::Error), Some(&2));
    assert_eq!(by_terminal.get(&Terminal::Shed), Some(&1));
    assert_eq!(by_terminal.get(&Terminal::Cancelled), Some(&1));
    assert_eq!(by_terminal.get(&Terminal::Abandoned), None, "no span may be abandoned");
    assert_eq!(recs.len(), 10, "one span per request, exactly");

    // Recorded terminals agree with the coordinator's own counters.
    assert_eq!(st.completed, 4);
    assert_eq!(st.rejected, 1);
    assert_eq!(st.expired, 1);
    assert_eq!(st.shed, 1);
    assert_eq!(st.cancelled, 1);

    // Stage stamps: served spans carry the full lifecycle in order;
    // admission-refused spans never reach the queue.
    for r in &recs {
        assert!(r.t_end_us >= r.t_admit_us);
        match r.terminal {
            Terminal::Done => {
                let enq = r.t_enqueue_us.expect("done span enqueued");
                let formed = r.t_formed_us.expect("done span formed");
                let ks = r.t_kernel_start_us.expect("done span kernel start");
                let ke = r.t_kernel_end_us.expect("done span kernel end");
                assert!(r.t_admit_us <= enq && enq <= formed && formed <= ks && ks <= ke);
                assert!(ke <= r.t_end_us);
            }
            Terminal::Rejected | Terminal::Shed => {
                assert!(r.t_formed_us.is_none(), "refused span reached formation: {r:?}");
                assert!(r.t_kernel_start_us.is_none());
            }
            Terminal::Expired | Terminal::Cancelled => {
                assert!(r.t_enqueue_us.is_some(), "queued-drop span was never enqueued");
                assert!(r.t_kernel_start_us.is_none(), "dropped span hit the kernel: {r:?}");
            }
            _ => {}
        }
    }
}

/// Four client threads hammer one pooled native route; afterwards the
/// drained trace, the metrics registry, and the coordinator stats must
/// all tell the same story: every job traced `Done` with monotone
/// stamps, one stage sample per executed job, one fill sample per batch.
#[test]
fn trace_and_metrics_agree_under_pooled_load() {
    const THREADS: usize = 4;
    const PER_THREAD: usize = 25;
    let (spec, n) = native_spec("iiwa", 16, 0);
    let coord = Coordinator::start_with_policy(vec![spec], n, 500, QosPolicy::default());
    coord.obs().enable_tracing(8, 8192);

    std::thread::scope(|s| {
        for _ in 0..THREADS {
            s.spawn(|| {
                for _ in 0..PER_THREAD {
                    coord
                        .submit_to("iiwa", ArtifactFn::Fd, clean_ops(n))
                        .recv()
                        .unwrap()
                        .expect("pooled job ok");
                }
            });
        }
    });

    let total = (THREADS * PER_THREAD) as u64;
    let st = coord.stats();
    let snap = coord.obs().snapshot();
    let obs = Arc::clone(coord.obs());
    coord.shutdown();
    let recs = obs.trace().expect("tracing enabled").drain();

    assert_eq!(st.completed, total);
    assert_eq!(recs.len(), total as usize, "one span per served job");
    assert!(recs.iter().all(|r| r.terminal == Terminal::Done));
    for r in &recs {
        let enq = r.t_enqueue_us.unwrap();
        let formed = r.t_formed_us.unwrap();
        let ks = r.t_kernel_start_us.unwrap();
        let ke = r.t_kernel_end_us.unwrap();
        assert!(r.t_admit_us <= enq && enq <= formed && formed <= ks && ks <= ke);
    }
    assert_eq!(obs.trace().unwrap().dropped_spans(), 0, "rings were deep enough");

    // One queue/kernel sample per executed job; one fill/exec sample per
    // batch — the histograms and ServeStats count the same events.
    assert_eq!(snap.hists["stage_queue_us"].count, total);
    assert_eq!(snap.hists["stage_kernel_us"].count, total);
    assert_eq!(snap.hists["batch_fill_pct"].count, st.batches);
    assert_eq!(snap.hists["batch_exec_us"].count, st.batches);
    // The per-class labelled histograms partition the aggregate.
    let per_class: u64 = snap
        .hists
        .iter()
        .filter(|(name, _)| name.starts_with("stage_queue_us{"))
        .map(|(_, h)| h.count)
        .sum();
    assert_eq!(per_class, total);
}

/// The `stats` wire route answers a live snapshot whose serve counters
/// match the coordinator's terminal `ServeStats`, and the net-layer
/// counters see a malformed line the moment one arrives.
#[test]
fn stats_wire_route_matches_serve_stats() {
    let (spec, n) = native_spec("iiwa", 8, 1);
    let coord =
        Arc::new(Coordinator::start_with_policy(vec![spec], n, 500, QosPolicy::default()));
    let dims: BTreeMap<String, usize> = [("iiwa".to_string(), n)].into_iter().collect();
    let server = NetServer::start(Arc::clone(&coord), dims, "127.0.0.1:0", None, "iiwa", 8, 500)
        .expect("bind");
    let mut client = NetClient::connect(server.addr()).expect("connect");

    // Serve a few clean requests over the wire.
    let ops = clean_ops(n);
    for id in 1..=3u64 {
        client
            .send_line(&frame::req_step_line(id, "iiwa", "fd", None, None, &ops))
            .expect("send req");
        loop {
            match client.read_frame().expect("frame") {
                Frame::Done { id: got, .. } if got == id => break,
                Frame::Err { msg, .. } => panic!("err on clean traffic: {msg}"),
                _ => {}
            }
        }
    }
    // One malformed line, answered in-band and counted.
    client.send_line("this is not json").expect("send garbage");
    assert!(matches!(client.read_frame().expect("frame"), Frame::Err { .. }));

    client.send_line(&frame::stats_req_line(9)).expect("send stats req");
    let (counters, gauges) = loop {
        match client.read_frame().expect("frame") {
            Frame::Stats { id, counters, gauges } => {
                assert_eq!(id, 9);
                break (counters, gauges);
            }
            Frame::Err { msg, .. } => panic!("stats request refused: {msg}"),
            _ => {}
        }
    };

    let st = coord.stats();
    assert_eq!(counters["serve_completed"], st.completed);
    assert_eq!(st.completed, 3);
    assert_eq!(counters["serve_rejected"], st.rejected);
    assert_eq!(counters["serve_shed"], st.shed);
    assert_eq!(counters["serve_expired"], st.expired);
    assert_eq!(counters["net_malformed_lines_total"], 1);
    assert_eq!(counters["net_slow_reader_kills_total"], 0);
    assert!(counters.contains_key("pool_chunks_total"));
    // Unlabelled histogram percentiles surface as gauges.
    assert!(gauges.contains_key("stage_kernel_us_p99"), "{gauges:?}");
    assert!(gauges.contains_key("net_egress_queue_highwater"));

    drop(client);
    server.stop();
}

/// With deliberately tiny rings, overload overwrites the oldest spans:
/// the drain returns the newest `capacity` records, `dropped_spans` is
/// exactly the overflow and never decreases.
#[test]
fn ring_overflow_drops_oldest_spans_monotonically() {
    let (spec, n) = native_spec("iiwa", 1, 1);
    let coord = Coordinator::start_with_policy(vec![spec], n, 200, QosPolicy::default());
    // One ring of 4 slots; every worker thread lands on it.
    coord.obs().enable_tracing(1, 4);

    let mut dropped_seen = 0u64;
    let mut t_after_16 = 0u64;
    for k in 0..20 {
        coord.submit_to("iiwa", ArtifactFn::Fd, clean_ops(n)).recv().unwrap().expect("ok");
        let d = coord.obs().trace().unwrap().dropped_spans();
        assert!(d >= dropped_seen, "dropped_spans went backwards at job {k}: {dropped_seen} -> {d}");
        dropped_seen = d;
        if k == 15 {
            // Sequential submissions: jobs 17..20 are admitted after
            // this instant, so drop-oldest must keep exactly them.
            t_after_16 = coord.obs().trace().unwrap().now_us();
        }
    }

    let obs = Arc::clone(coord.obs());
    coord.shutdown();
    let sink = obs.trace().unwrap();
    let recs = sink.drain();
    assert_eq!(recs.len(), 4, "ring keeps exactly its capacity");
    assert_eq!(sink.dropped_spans(), 16, "20 spans through 4 slots drop 16");
    assert!(recs.iter().all(|r| r.terminal == Terminal::Done));
    // Drop-oldest: every survivor is one of the last 4 jobs, all of
    // which were admitted after the 16th job completed.
    assert!(
        recs.iter().all(|r| r.t_admit_us >= t_after_16),
        "an old span survived past 16 newer pushes: {recs:?}"
    );
}
