//! Parallel-quantized equivalence: a quantized route (or engine) whose
//! batches fan out across the engine-generic worker pool must answer
//! **bitwise identically** to serial execution — the pool workers run
//! the exact decode→`QuantScratch`→encode loop of the serial
//! `QuantEngine`, one cached per-(structure, format) scratch per worker.
//! Covers the engine-level fan-out for every RBD function, full/partial
//! batches, and a mixed f64 + quantized registry under concurrent load.

use draco::coordinator::{BackendKind, Coordinator, RobotRegistry};
use draco::model::{builtin_robot, Robot, State};
use draco::quant::QFormat;
use draco::runtime::artifact::ArtifactFn;
use draco::runtime::QuantEngine;
use draco::util::rng::Rng;

/// Flat row-major (b, n) f32 operands for `function`.
fn flat_inputs(robot: &Robot, function: ArtifactFn, b: usize, seed: u64) -> Vec<Vec<f32>> {
    let n = robot.dof();
    let mut rng = Rng::new(seed);
    let mut q = Vec::with_capacity(b * n);
    let mut qd = Vec::with_capacity(b * n);
    let mut u = Vec::with_capacity(b * n);
    for _ in 0..b {
        let s = State::random(robot, &mut rng);
        q.extend(s.q.iter().map(|&x| x as f32));
        qd.extend(s.qd.iter().map(|&x| x as f32));
        u.extend(rng.vec_range(n, -6.0, 6.0).iter().map(|&x| x as f32));
    }
    match function {
        ArtifactFn::Minv => vec![q],
        _ => vec![q, qd, u],
    }
}

/// Engine level: the pooled fan-out inside `QuantEngine::run` is bitwise
/// equal to the serial engine for every function, across full and
/// partial batches, odd chunk counts, and two formats.
#[test]
fn parallel_quant_engine_matches_serial_bitwise() {
    for (name, fmt) in [("iiwa", QFormat::new(12, 14)), ("atlas", QFormat::new(12, 12))] {
        let robot = builtin_robot(name).unwrap();
        for function in [ArtifactFn::Rnea, ArtifactFn::Fd, ArtifactFn::Minv] {
            let mut serial = QuantEngine::new(robot.clone(), function, 64, fmt);
            // One serial reference per batch size, shared by every chunk
            // count.
            let cases: Vec<(Vec<Vec<f32>>, Vec<f32>)> = [2usize, 5, 16, 64]
                .into_iter()
                .map(|b| {
                    let inputs = flat_inputs(&robot, function, b, 9_000 + b as u64);
                    let want = serial.run(&inputs).expect("serial run");
                    (inputs, want)
                })
                .collect();
            for parallel in [2usize, 3, 8, 0] {
                let mut par =
                    QuantEngine::with_parallelism(robot.clone(), function, 64, fmt, parallel);
                for (inputs, want) in &cases {
                    let got = par.run(inputs).expect("parallel run");
                    assert_eq!(
                        want,
                        &got,
                        "{name}/{} fmt={} rows={} parallel={parallel}",
                        function.name(),
                        fmt.label(),
                        inputs[0].len() / robot.dof()
                    );
                }
            }
        }
    }
}

/// Single-task batches never split (below `PAR_MIN_ROWS`) and still
/// match the serial engine exactly.
#[test]
fn tiny_quant_batches_stay_serial_and_identical() {
    let robot = builtin_robot("iiwa").unwrap();
    let fmt = QFormat::new(12, 12);
    let mut serial = QuantEngine::new(robot.clone(), ArtifactFn::Fd, 8, fmt);
    let mut par = QuantEngine::with_parallelism(robot.clone(), ArtifactFn::Fd, 8, fmt, 0);
    let inputs = flat_inputs(&robot, ArtifactFn::Fd, 1, 9_500);
    assert_eq!(serial.run(&inputs).unwrap(), par.run(&inputs).unwrap());
}

/// Coordinator level: the same request stream through a serial registry
/// and a pooled registry — a **mixed** f64 + quantized deployment, both
/// robots parallel — produces bitwise-identical responses under load.
#[test]
fn parallel_quant_route_matches_serial_route_bitwise() {
    let iiwa = builtin_robot("iiwa").unwrap();
    let atlas = builtin_robot("atlas").unwrap();
    let fmt = QFormat::new(12, 12);

    let build = |parallel: usize| {
        let mut reg = RobotRegistry::new();
        reg.register_parallel(iiwa.clone(), BackendKind::Native, 16, parallel)
            .register_parallel(atlas.clone(), BackendKind::NativeQuant(fmt), 16, parallel);
        Coordinator::start_registry(&reg, 20_000)
    };
    let serial = build(1);
    let pooled = build(0); // one chunk per pool worker

    // Full batch (16), partial batch (5), and a single-task batch per
    // (robot, function) pair — identical streams to both coordinators.
    for (robot, base_seed) in [(&iiwa, 300u64), (&atlas, 400)] {
        for function in [ArtifactFn::Rnea, ArtifactFn::Fd, ArtifactFn::Minv] {
            for (burst, seed_off) in [(16usize, 0u64), (5, 1), (1, 2)] {
                let n = robot.dof();
                let per_task: Vec<Vec<Vec<f32>>> = (0..burst)
                    .map(|k| flat_inputs(robot, function, 1, base_seed + 10 * seed_off + k as u64))
                    .collect();
                let answers = |coord: &Coordinator| -> Vec<Vec<f32>> {
                    let rxs: Vec<_> = per_task
                        .iter()
                        .map(|ops| coord.submit_to(&robot.name, function, ops.clone()))
                        .collect();
                    rxs.into_iter()
                        .map(|rx| rx.recv().expect("answer").expect("ok"))
                        .collect()
                };
                let want = answers(&serial);
                let got = answers(&pooled);
                assert_eq!(want.len(), got.len());
                for (k, (a, b)) in want.iter().zip(&got).enumerate() {
                    let expect_len = match function {
                        ArtifactFn::Minv => n * n,
                        _ => n,
                    };
                    assert_eq!(a.len(), expect_len);
                    assert_eq!(
                        a,
                        b,
                        "{}/{} burst={burst} task {k} diverged",
                        robot.name,
                        function.name()
                    );
                }
            }
        }
    }
    serial.shutdown();
    pooled.shutdown();
}

/// Mixed registry under genuinely concurrent clients: interleaved f64
/// and quantized traffic through pooled routes still matches each
/// robot's serial reference engine bitwise (no cross-lane workspace
/// aliasing in the pool workers).
#[test]
fn mixed_registry_under_load_matches_reference_engines() {
    let iiwa = builtin_robot("iiwa").unwrap();
    let hyq = builtin_robot("hyq").unwrap();
    let fmt = QFormat::new(12, 14);
    let mut reg = RobotRegistry::new();
    reg.register_parallel(iiwa.clone(), BackendKind::Native, 8, 0)
        .register_parallel(hyq.clone(), BackendKind::NativeQuant(fmt), 8, 0);
    let coord = std::sync::Arc::new(Coordinator::start_registry(&reg, 150));

    let spawn = |coord: std::sync::Arc<Coordinator>, robot: Robot, seed: u64| {
        std::thread::spawn(move || {
            let reqs: Vec<Vec<Vec<f32>>> = (0..24)
                .map(|k| flat_inputs(&robot, ArtifactFn::Fd, 1, seed + k))
                .collect();
            let rxs: Vec<_> = reqs
                .iter()
                .map(|ops| coord.submit_to(&robot.name, ArtifactFn::Fd, ops.clone()))
                .collect();
            let outs: Vec<Vec<f32>> = rxs
                .into_iter()
                .map(|rx| rx.recv().expect("answer").expect("ok"))
                .collect();
            (reqs, outs)
        })
    };
    let h_iiwa = spawn(std::sync::Arc::clone(&coord), iiwa.clone(), 500);
    let h_hyq = spawn(std::sync::Arc::clone(&coord), hyq.clone(), 600);

    // Serial single-task references (batch identity: every request was
    // its own row, so per-row results are batching-independent).
    let (reqs, outs) = h_iiwa.join().expect("iiwa client");
    let mut iiwa_ref = draco::runtime::NativeEngine::new(iiwa.clone(), ArtifactFn::Fd, 1);
    for (ops, out) in reqs.iter().zip(&outs) {
        assert_eq!(&iiwa_ref.run(ops).expect("ref"), out, "iiwa diverged");
    }
    let (reqs, outs) = h_hyq.join().expect("hyq client");
    let mut hyq_ref = QuantEngine::new(hyq.clone(), ArtifactFn::Fd, 1, fmt);
    for (ops, out) in reqs.iter().zip(&outs) {
        assert_eq!(&hyq_ref.run(ops).expect("ref"), out, "hyq quant diverged");
    }
    if let Ok(coord) = std::sync::Arc::try_unwrap(coord) {
        coord.shutdown();
    }
}
