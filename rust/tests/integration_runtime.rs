//! Integration: load real AOT artifacts (built by `make artifacts`),
//! execute them through the PJRT runtime + coordinator, and validate
//! numerics against the native Rust implementations.
//!
//! The whole target is gated on the `pjrt` feature (the default build has
//! no xla crate); within it, tests SKIP (pass trivially) when
//! `artifacts/` is empty so that `cargo test --features pjrt` works
//! before the Python compile step has run.
#![cfg(feature = "pjrt")]

use draco::coordinator::Coordinator;
use draco::dynamics;
use draco::model::{builtin_robot, State};
use draco::runtime::artifact::{scan_artifacts, ArtifactFn};
use draco::runtime::engine::Engine;
use draco::util::rng::Rng;
use std::path::Path;

fn artifacts_dir() -> std::path::PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have(robot: &str, f: ArtifactFn) -> Option<draco::runtime::artifact::ArtifactMeta> {
    scan_artifacts(&artifacts_dir())
        .into_iter()
        .find(|a| a.robot == robot && a.function == f)
}

#[test]
fn engine_rnea_matches_native() {
    let Some(meta) = have("iiwa", ArtifactFn::Rnea) else {
        eprintln!("SKIP: no iiwa rnea artifact (run `make artifacts`)");
        return;
    };
    let robot = builtin_robot("iiwa").unwrap();
    let n = robot.dof();
    let b = meta.batch;
    let client = xla::PjRtClient::cpu().expect("pjrt");
    let engine = Engine::load(&client, meta, n).expect("compile artifact");

    let mut rng = Rng::new(99);
    let mut q = Vec::new();
    let mut qd = Vec::new();
    let mut qdd = Vec::new();
    let mut states = Vec::new();
    for _ in 0..b {
        let s = State::random(&robot, &mut rng);
        let acc = rng.vec_range(n, -2.0, 2.0);
        q.extend(s.q.iter().map(|&x| x as f32));
        qd.extend(s.qd.iter().map(|&x| x as f32));
        qdd.extend(acc.iter().map(|&x| x as f32));
        states.push((s, acc));
    }
    let out = engine.run(&[q, qd, qdd]).expect("execute");
    assert_eq!(out.len(), b * n);
    for (k, (s, acc)) in states.iter().enumerate() {
        let want = dynamics::rnea(&robot, &s.q, &s.qd, acc, None);
        for i in 0..n {
            let got = out[k * n + i] as f64;
            let scale = 1.0f64.max(want[i].abs());
            assert!(
                (got - want[i]).abs() / scale < 2e-3,
                "task {k} joint {i}: artifact {got} vs native {}",
                want[i]
            );
        }
    }
}

#[test]
fn engine_minv_matches_native() {
    let Some(meta) = have("iiwa", ArtifactFn::Minv) else {
        eprintln!("SKIP: no iiwa minv artifact");
        return;
    };
    let robot = builtin_robot("iiwa").unwrap();
    let n = robot.dof();
    let b = meta.batch;
    let client = xla::PjRtClient::cpu().expect("pjrt");
    let engine = Engine::load(&client, meta, n).expect("compile artifact");

    let mut rng = Rng::new(100);
    let mut q = Vec::new();
    let mut states = Vec::new();
    for _ in 0..b {
        let s = State::random(&robot, &mut rng);
        q.extend(s.q.iter().map(|&x| x as f32));
        states.push(s);
    }
    let out = engine.run(&[q]).expect("execute");
    assert_eq!(out.len(), b * n * n);
    for (k, s) in states.iter().enumerate() {
        let want = dynamics::minv(&robot, &s.q);
        let scale = want.max_abs();
        for i in 0..n {
            for j in 0..n {
                let got = out[k * n * n + i * n + j] as f64;
                assert!(
                    (got - want[(i, j)]).abs() / scale < 2e-3,
                    "task {k} M⁻¹[{i}][{j}]: {got} vs {}",
                    want[(i, j)]
                );
            }
        }
    }
}

#[test]
fn engine_fd_matches_native() {
    let Some(meta) = have("iiwa", ArtifactFn::Fd) else {
        eprintln!("SKIP: no iiwa fd artifact");
        return;
    };
    let robot = builtin_robot("iiwa").unwrap();
    let n = robot.dof();
    let b = meta.batch;
    let client = xla::PjRtClient::cpu().expect("pjrt");
    let engine = Engine::load(&client, meta, n).expect("compile artifact");

    let mut rng = Rng::new(101);
    let mut q = Vec::new();
    let mut qd = Vec::new();
    let mut tau = Vec::new();
    let mut cases = Vec::new();
    for _ in 0..b {
        let s = State::random(&robot, &mut rng);
        let t = rng.vec_range(n, -10.0, 10.0);
        q.extend(s.q.iter().map(|&x| x as f32));
        qd.extend(s.qd.iter().map(|&x| x as f32));
        tau.extend(t.iter().map(|&x| x as f32));
        cases.push((s, t));
    }
    let out = engine.run(&[q, qd, tau]).expect("execute");
    for (k, (s, t)) in cases.iter().enumerate() {
        let want = dynamics::fd(&robot, &s.q, &s.qd, t, None);
        let scale = want.iter().fold(1.0f64, |m, x| m.max(x.abs()));
        for i in 0..n {
            let got = out[k * n + i] as f64;
            assert!(
                (got - want[i]).abs() / scale < 5e-3,
                "task {k} q̈[{i}]: {got} vs {}",
                want[i]
            );
        }
    }
}

#[test]
fn coordinator_batches_and_answers() {
    let Some(meta) = have("iiwa", ArtifactFn::Rnea) else {
        eprintln!("SKIP: no iiwa rnea artifact");
        return;
    };
    let robot = builtin_robot("iiwa").unwrap();
    let n = robot.dof();
    let coord = Coordinator::start_pjrt(vec![meta], n, 150);
    let mut rng = Rng::new(102);
    let mut pending = Vec::new();
    for _ in 0..40 {
        let s = State::random(&robot, &mut rng);
        let acc = rng.vec_range(n, -1.0, 1.0);
        let ops = vec![
            s.q.iter().map(|&x| x as f32).collect(),
            s.qd.iter().map(|&x| x as f32).collect(),
            acc.iter().map(|&x| x as f32).collect(),
        ];
        pending.push((s, acc, coord.submit(ArtifactFn::Rnea, ops)));
    }
    for (s, acc, rx) in pending {
        let out = rx.recv().expect("answer").expect("ok");
        let want = dynamics::rnea(&robot, &s.q, &s.qd, &acc, None);
        for i in 0..n {
            let scale = 1.0f64.max(want[i].abs());
            assert!(((out[i] as f64) - want[i]).abs() / scale < 2e-3);
        }
    }
    let st = coord.stats();
    assert_eq!(st.completed, 40);
    assert!(st.batches >= 1);
    coord.shutdown();
}

/// Property-style: coordinator must never drop, duplicate, or reorder a
/// request's answer (each response channel gets exactly one result whose
/// content matches its own inputs — checked via a per-request marker).
#[test]
fn coordinator_no_mixups_under_load() {
    let Some(meta) = have("iiwa", ArtifactFn::Rnea) else {
        eprintln!("SKIP: no iiwa rnea artifact");
        return;
    };
    let robot = builtin_robot("iiwa").unwrap();
    let n = robot.dof();
    let coord = Coordinator::start_pjrt(vec![meta], n, 80);
    let mut rng = Rng::new(103);
    // Unique marker per request: qdd = j * e_0 → τ depends linearly on j.
    let base = State::random(&robot, &mut rng);
    let t0 = dynamics::rnea(&robot, &base.q, &base.qd, &vec![0.0; n], None);
    let m = dynamics::crba(&robot, &base.q);
    let mut pending = Vec::new();
    for j in 1..=64usize {
        let mut acc = vec![0.0; n];
        acc[0] = j as f64 * 0.1;
        let ops = vec![
            base.q.iter().map(|&x| x as f32).collect(),
            base.qd.iter().map(|&x| x as f32).collect(),
            acc.iter().map(|&x| x as f32).collect(),
        ];
        pending.push((j, coord.submit(ArtifactFn::Rnea, ops)));
    }
    for (j, rx) in pending {
        let out = rx.recv().unwrap().unwrap();
        // Expected τ_0 = t0_0 + M[0][0] * 0.1 j.
        let want = t0[0] + m[(0, 0)] * 0.1 * j as f64;
        let got = out[0] as f64;
        assert!(
            (got - want).abs() / (1.0 + want.abs()) < 2e-3,
            "request {j}: got {got}, want {want} — answers mixed up?"
        );
    }
    coord.shutdown();
}
