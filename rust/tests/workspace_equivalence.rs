//! Property tests for the allocation-free workspace core: every `*_into`
//! kernel and the batch API must agree with the allocating reference
//! implementations — and with the independent ABA oracle — across all
//! builtin robots, random seeds, and randomized tree topologies, while
//! REUSING one workspace across every case (so stale state from a
//! previous task would be caught immediately).

use draco::dynamics::{
    aba, crba, eval_batch, eval_batch_par, fd, minv, rnea, BatchKernel, BatchOutput, BatchTask,
    DynWorkspace,
};
use draco::model::{builtin_robot, Joint, Link, Robot, State};
use draco::spatial::{DMat, Inertia, M3, V3, Xform};
use draco::util::check::{assert_slices_close, close};
use draco::util::rng::Rng;

const ROBOTS: [&str; 4] = ["iiwa", "hyq", "atlas", "baxter"];

/// Random physically-valid robot with 2..=10 joints (same generator
/// family as tests/property_dynamics.rs).
fn random_robot(rng: &mut Rng) -> Robot {
    let n = 2 + rng.below(9);
    let mut links = Vec::with_capacity(n);
    for i in 0..n {
        let parent = if i == 0 {
            None
        } else {
            Some(if rng.f64() < 0.7 { i - 1 } else { rng.below(i) })
        };
        let axis = V3::new(rng.range(-1.0, 1.0), rng.range(-1.0, 1.0), rng.range(0.2, 1.0));
        let joint = if rng.f64() < 0.85 {
            Joint::revolute(axis)
        } else {
            Joint::prismatic(axis)
        };
        let rot_axis = V3::new(rng.range(-1.0, 1.0), rng.range(-1.0, 1.0), rng.range(0.2, 1.0));
        let x_tree = Xform {
            e: M3::rot_axis(&rot_axis, rng.range(-1.5, 1.5)),
            r: V3::new(rng.range(-0.3, 0.3), rng.range(-0.3, 0.3), rng.range(-0.4, 0.4)),
        };
        let mut a = M3::ZERO;
        for r in 0..3 {
            for c in 0..3 {
                a.0[r][c] = rng.range(-0.2, 0.2);
            }
        }
        let mut i_com = a.mul_m(&a.transpose());
        for d in 0..3 {
            i_com.0[d][d] += rng.range(0.02, 0.2);
        }
        let inertia = Inertia::from_com_inertia(
            rng.range(0.3, 6.0),
            V3::new(rng.range(-0.15, 0.15), rng.range(-0.15, 0.15), rng.range(-0.15, 0.15)),
            i_com,
        );
        links.push(Link {
            name: format!("l{i}"),
            parent,
            joint,
            x_tree,
            inertia,
            q_min: -2.0,
            q_max: 2.0,
            qd_max: 3.0,
        });
    }
    let robot = Robot { name: "random".into(), links, gravity: V3::new(0.0, 0.0, -9.81) };
    robot.validate().expect("generator must produce valid robots");
    robot
}

/// Workspace fd vs the independent ABA oracle, all builtins × seeds.
#[test]
fn workspace_fd_matches_aba_oracle_on_builtins() {
    for name in ROBOTS {
        let robot = builtin_robot(name).unwrap();
        let n = robot.dof();
        let mut ws = DynWorkspace::new(&robot);
        let mut qdd_ws = vec![0.0; n];
        for seed in 0..8u64 {
            let mut rng = Rng::new(900 + seed);
            let s = State::random(&robot, &mut rng);
            let tau = rng.vec_range(n, -25.0, 25.0);
            ws.fd_into(&robot, &s.q, &s.qd, &tau, None, &mut qdd_ws);
            let oracle = aba(&robot, &s.q, &s.qd, &tau, None);
            for i in 0..n {
                assert!(
                    close(qdd_ws[i], oracle[i], 1e-9),
                    "{name} seed {seed} joint {i}: ws {} vs aba {}",
                    qdd_ws[i],
                    oracle[i]
                );
            }
        }
    }
}

/// Workspace kernels vs allocating references on random topologies —
/// each case gets a fresh workspace because the tree size changes, but
/// within a case the workspace is exercised by several kernels in a row.
#[test]
fn workspace_kernels_match_references_on_random_trees() {
    let mut rng = Rng::new(0xD8AC0);
    for case in 0..40 {
        let robot = random_robot(&mut rng);
        let n = robot.dof();
        let s = State::random(&robot, &mut rng);
        let tau = rng.vec_range(n, -15.0, 15.0);
        let qdd_in = rng.vec_range(n, -3.0, 3.0);
        let mut ws = DynWorkspace::new(&robot);

        let mut tau_ws = vec![0.0; n];
        ws.rnea_into(&robot, &s.q, &s.qd, &qdd_in, None, &mut tau_ws);
        let tau_ref = rnea(&robot, &s.q, &s.qd, &qdd_in, None);
        assert_slices_close(&tau_ws, &tau_ref, 1e-12, &format!("case {case} rnea"));

        let mut qdd_ws = vec![0.0; n];
        ws.fd_into(&robot, &s.q, &s.qd, &tau, None, &mut qdd_ws);
        let fd_ref = fd(&robot, &s.q, &s.qd, &tau, None);
        assert_slices_close(&qdd_ws, &fd_ref, 1e-8, &format!("case {case} fd vs alloc"));
        let oracle = aba(&robot, &s.q, &s.qd, &tau, None);
        assert_slices_close(&qdd_ws, &oracle, 1e-8, &format!("case {case} fd vs aba"));

        let mut mi_ws = DMat::zeros(n, n);
        ws.minv_into(&robot, &s.q, &mut mi_ws);
        // M⁻¹ must invert CRBA's M: two independent formulations.
        let prod = mi_ws.matmul(&crba(&robot, &s.q));
        let err = prod.sub(&DMat::identity(n)).max_abs();
        assert!(err < 1e-7, "case {case}: |M⁻¹M − I| = {err:.2e}");
        let mi_ref = minv(&robot, &s.q);
        let err = mi_ws.sub(&mi_ref).max_abs();
        assert!(
            err < 1e-8 * (1.0 + mi_ref.max_abs()),
            "case {case}: |minv_ws − minv| = {err:.2e}"
        );
    }
}

/// Round-trip through the workspace kernels alone: fd_ws(rnea_ws(q̈)) = q̈.
#[test]
fn workspace_fd_inverts_workspace_id() {
    for name in ROBOTS {
        let robot = builtin_robot(name).unwrap();
        let n = robot.dof();
        let mut ws = DynWorkspace::new(&robot);
        let mut tau = vec![0.0; n];
        let mut back = vec![0.0; n];
        for seed in 0..4u64 {
            let mut rng = Rng::new(910 + seed);
            let s = State::random(&robot, &mut rng);
            let qdd_in = rng.vec_range(n, -4.0, 4.0);
            ws.rnea_into(&robot, &s.q, &s.qd, &qdd_in, None, &mut tau);
            ws.fd_into(&robot, &s.q, &s.qd, &tau, None, &mut back);
            for i in 0..n {
                assert!(
                    close(back[i], qdd_in[i], 1e-7),
                    "{name} joint {i}: {} vs {}",
                    back[i],
                    qdd_in[i]
                );
            }
        }
    }
}

/// Batch API (single-threaded and threaded) vs per-task references on
/// every builtin robot.
#[test]
fn batched_kernels_match_reference_on_builtins() {
    for name in ROBOTS {
        let robot = builtin_robot(name).unwrap();
        let n = robot.dof();
        let mut rng = Rng::new(920);
        let tasks: Vec<BatchTask> = (0..12)
            .map(|_| {
                let s = State::random(&robot, &mut rng);
                BatchTask { q: s.q, qd: s.qd, u: rng.vec_range(n, -10.0, 10.0) }
            })
            .collect();
        for kernel in [BatchKernel::Rnea, BatchKernel::Fd, BatchKernel::Minv] {
            let single = eval_batch(&robot, kernel, &tasks);
            let par = eval_batch_par(&robot, kernel, &tasks, 4);
            assert_eq!(single.len(), tasks.len());
            for (k, task) in tasks.iter().enumerate() {
                match (&single[k], &par[k]) {
                    (BatchOutput::Vector(a), BatchOutput::Vector(b)) => {
                        let want = match kernel {
                            BatchKernel::Rnea => rnea(&robot, &task.q, &task.qd, &task.u, None),
                            BatchKernel::Fd => fd(&robot, &task.q, &task.qd, &task.u, None),
                            BatchKernel::Minv => unreachable!(),
                        };
                        let tol = if kernel == BatchKernel::Rnea { 1e-12 } else { 1e-9 };
                        assert_slices_close(a, &want, tol, &format!("{name} task {k}"));
                        assert_eq!(a, b, "{name} task {k}: threaded result differs");
                    }
                    (BatchOutput::Matrix(a), BatchOutput::Matrix(b)) => {
                        let want = minv(&robot, &task.q);
                        assert!(a.sub(&want).max_abs() < 1e-9, "{name} task {k} minv");
                        assert!(a.sub(b).max_abs() == 0.0, "{name} task {k}: threaded minv");
                    }
                    _ => panic!("{name} task {k}: output kind mismatch"),
                }
            }
        }
    }
}

/// External forces flow through the workspace fd identically to the
/// oracle route.
#[test]
fn workspace_fd_external_forces_match_oracle() {
    let robot = builtin_robot("iiwa").unwrap();
    let n = robot.dof();
    let mut ws = DynWorkspace::new(&robot);
    let mut rng = Rng::new(930);
    let s = State::random(&robot, &mut rng);
    let tau = rng.vec_range(n, -10.0, 10.0);
    let fe: Vec<draco::spatial::SV> = (0..n)
        .map(|_| draco::spatial::SV::from_slice(&rng.vec_range(6, -4.0, 4.0)))
        .collect();
    let mut got = vec![0.0; n];
    ws.fd_into(&robot, &s.q, &s.qd, &tau, Some(&fe), &mut got);
    let want = aba(&robot, &s.q, &s.qd, &tau, Some(&fe));
    assert_slices_close(&got, &want, 1e-9, "fd_ws with fext vs aba");
}
