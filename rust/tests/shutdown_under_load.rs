//! Shutdown under load: `Coordinator::shutdown` with deep queues must
//! answer every pending receiver with `ShuttingDown` — no response may
//! ever hang — across the f64, rounded-quant, and integer-qint lanes
//! plus the trajectory route.

use draco::coordinator::{
    Coordinator, QosClass, RobotRegistry, ServeError, SubmitOptions, TrajRequest,
};
use draco::model::builtin_robot;
use draco::runtime::ArtifactFn;
use std::sync::mpsc::Receiver;
use std::time::{Duration, Instant};

/// Submit ~40 step jobs per robot (mixed QoS classes) plus trajectory
/// rollouts on a long batching window, shut down immediately, and
/// require every receiver to resolve: either a served result (the job
/// made it into a batch before the stop) or `ShuttingDown` — never a
/// dropped channel, never a hang.
#[test]
fn shutdown_answers_every_queued_job_across_lanes() {
    // One coordinator, three serving lanes: f64 native, rounded quant,
    // and the integer lane (formats the scaling analysis accepts).
    let reg = RobotRegistry::from_cli_spec("iiwa,atlas:quant@12.12,hyq:qint@12.14", 64)
        .expect("spec parses");
    // A 200 ms window means nothing flushes before the shutdown lands:
    // the queues are guaranteed deep when Stop arrives.
    let coord = Coordinator::start_registry(&reg, 200_000);

    let classes = [QosClass::Control, QosClass::Interactive, QosClass::Bulk];
    let mut rxs: Vec<Receiver<_>> = Vec::new();
    for robot_name in ["iiwa", "atlas", "hyq"] {
        let n = builtin_robot(robot_name).unwrap().dof();
        let ops = vec![vec![0.1f32; n], vec![0.0; n], vec![0.0; n]];
        for k in 0..40 {
            rxs.push(coord.submit_to_opts(
                robot_name,
                ArtifactFn::Fd,
                ops.clone(),
                SubmitOptions::class(classes[k % 3]),
            ));
        }
        let h = 4;
        let req = TrajRequest {
            q0: vec![0.1; n],
            qd0: vec![0.0; n],
            tau: vec![0.0; h * n],
            dt: 1e-3,
        };
        for _ in 0..4 {
            rxs.push(coord.submit_traj(robot_name, req.clone()));
        }
    }
    let total = rxs.len();
    assert_eq!(total, 3 * 44);

    let t0 = Instant::now();
    coord.shutdown();

    let mut served = 0usize;
    let mut shut = 0usize;
    for rx in rxs {
        // A bounded wait turns a would-be hang into a test failure
        // instead of a CI timeout.
        match rx.recv_timeout(Duration::from_secs(30)) {
            Ok(Ok(out)) => {
                assert!(!out.is_empty(), "served result must carry data");
                served += 1;
            }
            Ok(Err(ServeError::ShuttingDown)) => shut += 1,
            Ok(Err(other)) => panic!("unexpected serve error during shutdown: {other:?}"),
            Err(e) => panic!("receiver hung across shutdown: {e:?}"),
        }
    }
    assert_eq!(served + shut, total);
    // The 200 ms window guarantees the stop beat the first flush, so at
    // least some jobs must have been answered with ShuttingDown.
    assert!(shut > 0, "expected queued jobs to be failed by shutdown (served={served})");
    // Shutdown must not sit out the full batching window per route.
    assert!(
        t0.elapsed() < Duration::from_secs(20),
        "shutdown under load took {:?}",
        t0.elapsed()
    );
}

/// Dropping the coordinator without calling `shutdown` is the graceful
/// path: workers detect the disconnect and drain what is queued, so
/// every response still resolves.
#[test]
fn dropping_the_coordinator_drains_queued_jobs() {
    let reg = RobotRegistry::from_cli_spec("iiwa", 8).expect("spec parses");
    let coord = Coordinator::start_registry(&reg, 50_000);
    let n = builtin_robot("iiwa").unwrap().dof();
    let ops = vec![vec![0.1f32; n], vec![0.0; n], vec![0.0; n]];
    let rxs: Vec<Receiver<_>> =
        (0..12).map(|_| coord.submit_to("iiwa", ArtifactFn::Fd, ops.clone())).collect();
    drop(coord);
    for rx in rxs {
        match rx.recv_timeout(Duration::from_secs(30)) {
            Ok(Ok(out)) => assert!(!out.is_empty()),
            Ok(Err(e)) => panic!("graceful drain must serve, not fail: {e:?}"),
            Err(e) => panic!("receiver hung after coordinator drop: {e:?}"),
        }
    }
}
