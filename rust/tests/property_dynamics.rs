//! Property tests over RANDOMIZED robot topologies: the dynamics
//! invariants must hold for any physically-valid tree, not just the four
//! builtin robots. Trees are generated with random branching, joint
//! types, axes, placements, and inertias.

use draco::dynamics::{aba, crba, fd, minv, minv_dd, rnea, rnea_derivatives};
use draco::model::{Joint, Link, Robot, State};
use draco::spatial::{DMat, Inertia, M3, V3, Xform};
use draco::util::check::{forall_res, Config};
use draco::util::rng::Rng;

/// Random physically-valid robot with 2..=10 joints.
fn random_robot(rng: &mut Rng) -> Robot {
    let n = 2 + rng.below(9);
    let mut links = Vec::with_capacity(n);
    for i in 0..n {
        let parent = if i == 0 {
            None
        } else {
            // Bias towards chains but allow branching.
            Some(if rng.f64() < 0.7 { i - 1 } else { rng.below(i) })
        };
        let axis = V3::new(rng.range(-1.0, 1.0), rng.range(-1.0, 1.0), rng.range(0.2, 1.0));
        let joint = if rng.f64() < 0.85 {
            Joint::revolute(axis)
        } else {
            Joint::prismatic(axis)
        };
        // Random fixed placement.
        let rot_axis = V3::new(rng.range(-1.0, 1.0), rng.range(-1.0, 1.0), rng.range(0.2, 1.0));
        let x_tree = Xform {
            e: M3::rot_axis(&rot_axis, rng.range(-1.5, 1.5)),
            r: V3::new(rng.range(-0.3, 0.3), rng.range(-0.3, 0.3), rng.range(-0.4, 0.4)),
        };
        // SPD inertia about CoM.
        let mut a = M3::ZERO;
        for r in 0..3 {
            for c in 0..3 {
                a.0[r][c] = rng.range(-0.2, 0.2);
            }
        }
        let mut i_com = a.mul_m(&a.transpose());
        for d in 0..3 {
            i_com.0[d][d] += rng.range(0.02, 0.2);
        }
        let inertia = Inertia::from_com_inertia(
            rng.range(0.3, 6.0),
            V3::new(rng.range(-0.15, 0.15), rng.range(-0.15, 0.15), rng.range(-0.15, 0.15)),
            i_com,
        );
        links.push(Link {
            name: format!("l{i}"),
            parent,
            joint,
            x_tree,
            inertia,
            q_min: -2.0,
            q_max: 2.0,
            qd_max: 3.0,
        });
    }
    let robot =
        Robot { name: "random".into(), links, gravity: V3::new(0.0, 0.0, -9.81) };
    robot.validate().expect("generator must produce valid robots");
    robot
}

#[test]
fn prop_fd_inverts_id_on_random_trees() {
    forall_res(
        "fd-id-roundtrip",
        Config { cases: 40, ..Default::default() },
        |rng| {
            let robot = random_robot(rng);
            let s = State::random(&robot, rng);
            let qdd = rng.vec_range(robot.dof(), -3.0, 3.0);
            (robot, s, qdd)
        },
        |(robot, s, qdd)| {
            let tau = rnea(robot, &s.q, &s.qd, qdd, None);
            let back = fd(robot, &s.q, &s.qd, &tau, None);
            for i in 0..robot.dof() {
                let err = (back[i] - qdd[i]).abs() / (1.0 + qdd[i].abs());
                if err > 1e-6 {
                    return Err(format!("joint {i}: {} vs {} ({err:.2e})", back[i], qdd[i]));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_minv_dd_equals_minv_on_random_trees() {
    forall_res(
        "minv-dd-equiv",
        Config { cases: 40, ..Default::default() },
        |rng| {
            let robot = random_robot(rng);
            let s = State::random(&robot, rng);
            (robot, s)
        },
        |(robot, s)| {
            let a = minv(robot, &s.q);
            let b = minv_dd(robot, &s.q);
            let err = a.sub(&b).max_abs();
            if err > 1e-8 * (1.0 + a.max_abs()) {
                return Err(format!("|minv − minv_dd| = {err:.2e}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_minv_inverts_crba_on_random_trees() {
    forall_res(
        "minv-crba",
        Config { cases: 40, ..Default::default() },
        |rng| {
            let robot = random_robot(rng);
            let s = State::random(&robot, rng);
            (robot, s)
        },
        |(robot, s)| {
            let prod = minv(robot, &s.q).matmul(&crba(robot, &s.q));
            let err = prod.sub(&DMat::identity(robot.dof())).max_abs();
            if err > 1e-7 {
                return Err(format!("|M⁻¹M − I| = {err:.2e}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_aba_matches_minv_route_on_random_trees() {
    forall_res(
        "aba-vs-minv",
        Config { cases: 30, ..Default::default() },
        |rng| {
            let robot = random_robot(rng);
            let s = State::random(&robot, rng);
            let tau = rng.vec_range(robot.dof(), -15.0, 15.0);
            (robot, s, tau)
        },
        |(robot, s, tau)| {
            let a = fd(robot, &s.q, &s.qd, tau, None);
            let b = aba(robot, &s.q, &s.qd, tau, None);
            for i in 0..robot.dof() {
                let err = (a[i] - b[i]).abs() / (1.0 + a[i].abs());
                if err > 1e-6 {
                    return Err(format!("joint {i}: {} vs {}", a[i], b[i]));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_rnea_derivatives_match_fd_on_random_trees() {
    forall_res(
        "drnea-vs-finite-diff",
        Config { cases: 12, ..Default::default() },
        |rng| {
            let robot = random_robot(rng);
            let s = State::random(&robot, rng);
            let qdd = rng.vec_range(robot.dof(), -1.0, 1.0);
            (robot, s, qdd)
        },
        |(robot, s, qdd)| {
            let n = robot.dof();
            let (dq, dqd) = rnea_derivatives(robot, &s.q, &s.qd, qdd);
            let h = 1e-6;
            for j in 0..n {
                let mut qp = s.q.clone();
                let mut qm = s.q.clone();
                qp[j] += h;
                qm[j] -= h;
                let tp = rnea(robot, &qp, &s.qd, qdd, None);
                let tm = rnea(robot, &qm, &s.qd, qdd, None);
                for i in 0..n {
                    let fdiff = (tp[i] - tm[i]) / (2.0 * h);
                    if (fdiff - dq[(i, j)]).abs() > 5e-4 * (1.0 + fdiff.abs()) {
                        return Err(format!("∂τ{i}/∂q{j}: {fdiff} vs {}", dq[(i, j)]));
                    }
                }
                let mut vp = s.qd.clone();
                let mut vm = s.qd.clone();
                vp[j] += h;
                vm[j] -= h;
                let tp = rnea(robot, &s.q, &vp, qdd, None);
                let tm = rnea(robot, &s.q, &vm, qdd, None);
                for i in 0..n {
                    let fdiff = (tp[i] - tm[i]) / (2.0 * h);
                    if (fdiff - dqd[(i, j)]).abs() > 5e-4 * (1.0 + fdiff.abs()) {
                        return Err(format!("∂τ{i}/∂q̇{j}: {fdiff} vs {}", dqd[(i, j)]));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_mass_matrix_spd_on_random_trees() {
    forall_res(
        "crba-spd",
        Config { cases: 40, ..Default::default() },
        |rng| {
            let robot = random_robot(rng);
            let s = State::random(&robot, rng);
            let x = rng.vec_range(robot.dof(), -1.0, 1.0);
            (robot, s, x)
        },
        |(robot, s, x)| {
            let m = crba(robot, &s.q);
            // symmetry
            let asym = m.sub(&m.t()).max_abs();
            if asym > 1e-9 {
                return Err(format!("asymmetry {asym:.2e}"));
            }
            // positive definiteness via the random quadratic form
            let norm2: f64 = x.iter().map(|v| v * v).sum();
            if norm2 > 1e-9 {
                let quad: f64 = m.matvec(x).iter().zip(x).map(|(a, b)| a * b).sum();
                if quad <= 0.0 {
                    return Err(format!("xᵀMx = {quad} ≤ 0"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_json_roundtrip_on_random_trees() {
    forall_res(
        "robot-json-roundtrip",
        Config { cases: 40, ..Default::default() },
        |rng| random_robot(rng),
        |robot| {
            let text = robot.to_json().pretty();
            let back = Robot::from_json_str(&text).map_err(|e| e)?;
            if back.dof() != robot.dof() {
                return Err("dof changed".into());
            }
            // Dynamics must agree through the round trip.
            let q = vec![0.3; robot.dof()];
            let qd = vec![0.1; robot.dof()];
            let qdd = vec![0.2; robot.dof()];
            let a = rnea(robot, &q, &qd, &qdd, None);
            let b = rnea(&back, &q, &qd, &qdd, None);
            for i in 0..robot.dof() {
                if (a[i] - b[i]).abs() > 1e-9 * (1.0 + a[i].abs()) {
                    return Err(format!("τ{i} changed: {} vs {}", a[i], b[i]));
                }
            }
            Ok(())
        },
    );
}
