//! Parallel-integer equivalence: a `qint` route (or engine) whose
//! batches fan out across the engine-generic worker pool must answer
//! **bitwise identically** to serial execution. The pool workers run
//! the exact decode→`QuantIntScratch`→encode loop of the serial
//! `QIntEngine`, one cached per-(structure, format) integer scratch per
//! worker, and every pooled job carries the engine's `Arc<ShiftSchedule>`
//! so the division-deferring sweeps hold with identical per-joint
//! shifts. Covers the engine-level fan-out for every RBD function,
//! full/partial batches, a mixed f64 + quant + qint registry under
//! concurrent load, trajectory rollouts through the integer lane, and
//! the loud-failure path for rejected formats.

use draco::coordinator::{BackendKind, Coordinator, RobotRegistry, TrajRequest};
use draco::model::{builtin_robot, Robot, State};
use draco::quant::QFormat;
use draco::runtime::artifact::ArtifactFn;
use draco::runtime::QIntEngine;
use draco::util::rng::Rng;

/// Flat row-major (b, n) f32 operands for `function`.
fn flat_inputs(robot: &Robot, function: ArtifactFn, b: usize, seed: u64) -> Vec<Vec<f32>> {
    let n = robot.dof();
    let mut rng = Rng::new(seed);
    let mut q = Vec::with_capacity(b * n);
    let mut qd = Vec::with_capacity(b * n);
    let mut u = Vec::with_capacity(b * n);
    for _ in 0..b {
        let s = State::random(robot, &mut rng);
        q.extend(s.q.iter().map(|&x| x as f32));
        qd.extend(s.qd.iter().map(|&x| x as f32));
        u.extend(rng.vec_range(n, -6.0, 6.0).iter().map(|&x| x as f32));
    }
    match function {
        ArtifactFn::Minv => vec![q],
        _ => vec![q, qd, u],
    }
}

/// Engine level: the pooled fan-out inside `QIntEngine::run` is bitwise
/// equal to the serial engine for every function, across full and
/// partial batches, odd chunk counts, and two formats.
#[test]
fn parallel_qint_engine_matches_serial_bitwise() {
    for (name, fmt) in [("iiwa", QFormat::new(12, 14)), ("hyq", QFormat::new(12, 12))] {
        let robot = builtin_robot(name).unwrap();
        for function in [ArtifactFn::Rnea, ArtifactFn::Fd, ArtifactFn::Minv] {
            let mut serial =
                QIntEngine::new(robot.clone(), function, 64, fmt).expect("accepted format");
            let cases: Vec<(Vec<Vec<f32>>, Vec<f32>)> = [2usize, 5, 16, 64]
                .into_iter()
                .map(|b| {
                    let inputs = flat_inputs(&robot, function, b, 11_000 + b as u64);
                    let want = serial.run(&inputs).expect("serial run");
                    (inputs, want)
                })
                .collect();
            for parallel in [2usize, 3, 8, 0] {
                let mut par =
                    QIntEngine::with_parallelism(robot.clone(), function, 64, fmt, parallel)
                        .expect("accepted format");
                for (inputs, want) in &cases {
                    let got = par.run(inputs).expect("parallel run");
                    assert_eq!(
                        want,
                        &got,
                        "{name}/{} fmt={} rows={} parallel={parallel}",
                        function.name(),
                        fmt.label(),
                        inputs[0].len() / robot.dof()
                    );
                }
            }
        }
    }
}

/// Coordinator level: the same request stream through a serial registry
/// and a pooled registry — a mixed f64 + quant + qint deployment with
/// the quant and qint robots at the SAME format, so pool workers must
/// keep the two lanes' scratches apart — produces bitwise-identical
/// responses under load.
#[test]
fn parallel_qint_route_matches_serial_route_bitwise() {
    let iiwa = builtin_robot("iiwa").unwrap();
    let hyq = builtin_robot("hyq").unwrap();
    let atlas = builtin_robot("atlas").unwrap();
    let fmt = QFormat::new(12, 14);

    let build = |parallel: usize| {
        let mut reg = RobotRegistry::new();
        reg.register_parallel(iiwa.clone(), BackendKind::Native, 16, parallel)
            .register_parallel(hyq.clone(), BackendKind::NativeQuant(fmt), 16, parallel)
            .register_parallel(atlas.clone(), BackendKind::NativeInt(fmt), 16, parallel);
        reg.validate().expect("int entries accepted");
        Coordinator::start_registry(&reg, 20_000)
    };
    let serial = build(1);
    let pooled = build(0); // one chunk per pool worker

    for (robot, base_seed) in [(&hyq, 700u64), (&atlas, 800)] {
        for function in [ArtifactFn::Rnea, ArtifactFn::Fd, ArtifactFn::Minv] {
            for (burst, seed_off) in [(16usize, 0u64), (5, 1), (1, 2)] {
                let n = robot.dof();
                let per_task: Vec<Vec<Vec<f32>>> = (0..burst)
                    .map(|k| flat_inputs(robot, function, 1, base_seed + 10 * seed_off + k as u64))
                    .collect();
                let answers = |coord: &Coordinator| -> Vec<Vec<f32>> {
                    let rxs: Vec<_> = per_task
                        .iter()
                        .map(|ops| coord.submit_to(&robot.name, function, ops.clone()))
                        .collect();
                    rxs.into_iter()
                        .map(|rx| rx.recv().expect("answer").expect("ok"))
                        .collect()
                };
                let want = answers(&serial);
                let got = answers(&pooled);
                assert_eq!(want.len(), got.len());
                for (k, (a, b)) in want.iter().zip(&got).enumerate() {
                    let expect_len = match function {
                        ArtifactFn::Minv => n * n,
                        _ => n,
                    };
                    assert_eq!(a.len(), expect_len);
                    assert_eq!(
                        a,
                        b,
                        "{}/{} burst={burst} task {k} diverged",
                        robot.name,
                        function.name()
                    );
                }
            }
        }
    }
    serial.shutdown();
    pooled.shutdown();
}

/// Trajectory requests on a qint robot step through the integer lane:
/// the route's response equals a standalone `QIntEngine` rollout
/// bitwise (same deferred FD, same schedule, same integrator).
#[test]
fn qint_trajectory_route_rolls_through_the_integer_lane() {
    let robot = builtin_robot("iiwa").unwrap();
    let n = robot.dof();
    let fmt = QFormat::new(12, 14);
    let mut reg = RobotRegistry::new();
    reg.register(robot.clone(), BackendKind::NativeInt(fmt), 8);
    let coord = Coordinator::start_registry(&reg, 100);

    let mut rng = Rng::new(12_345);
    let s0 = State::random(&robot, &mut rng);
    let h = 12;
    let req = TrajRequest {
        q0: s0.q.iter().map(|&x| x as f32).collect(),
        qd0: s0.qd.iter().map(|&x| x as f32).collect(),
        tau: rng.vec_range(h * n, -2.0, 2.0).iter().map(|&x| x as f32).collect(),
        dt: 1e-3,
    };
    let out = coord
        .submit_traj("iiwa", req.clone())
        .recv()
        .expect("answer")
        .expect("rollout ok");
    assert_eq!(out.len(), 2 * h * n);
    assert!(out.iter().all(|x| x.is_finite()));

    let mut reference =
        QIntEngine::new(robot.clone(), ArtifactFn::Fd, 8, fmt).expect("accepted format");
    let want = reference.rollout(&req.q0, &req.qd0, &req.tau, req.dt).expect("reference rollout");
    assert_eq!(out, want, "trajectory route bypassed the integer lane");
    coord.shutdown();
}

/// A registry-validated qint robot serves real traffic next to other
/// lanes, and its step answers match the serial reference engine even
/// under concurrent clients (no cross-lane scratch aliasing).
#[test]
fn mixed_lane_registry_under_load_matches_reference_engines() {
    let iiwa = builtin_robot("iiwa").unwrap();
    let hyq = builtin_robot("hyq").unwrap();
    let fmt = QFormat::new(12, 12);
    let mut reg = RobotRegistry::new();
    reg.register_parallel(iiwa.clone(), BackendKind::NativeQuant(fmt), 8, 0)
        .register_parallel(hyq.clone(), BackendKind::NativeInt(fmt), 8, 0);
    reg.validate().expect("int entry accepted");
    let coord = std::sync::Arc::new(Coordinator::start_registry(&reg, 150));

    let spawn = |coord: std::sync::Arc<Coordinator>, robot: Robot, seed: u64| {
        std::thread::spawn(move || {
            let reqs: Vec<Vec<Vec<f32>>> = (0..24)
                .map(|k| flat_inputs(&robot, ArtifactFn::Fd, 1, seed + k))
                .collect();
            let rxs: Vec<_> = reqs
                .iter()
                .map(|ops| coord.submit_to(&robot.name, ArtifactFn::Fd, ops.clone()))
                .collect();
            let outs: Vec<Vec<f32>> = rxs
                .into_iter()
                .map(|rx| rx.recv().expect("answer").expect("ok"))
                .collect();
            (reqs, outs)
        })
    };
    let h_iiwa = spawn(std::sync::Arc::clone(&coord), iiwa.clone(), 900);
    let h_hyq = spawn(std::sync::Arc::clone(&coord), hyq.clone(), 1000);

    let (reqs, outs) = h_iiwa.join().expect("iiwa client");
    let mut iiwa_ref = draco::runtime::QuantEngine::new(iiwa.clone(), ArtifactFn::Fd, 1, fmt);
    for (ops, out) in reqs.iter().zip(&outs) {
        assert_eq!(&iiwa_ref.run(ops).expect("ref"), out, "iiwa quant diverged");
    }
    let (reqs, outs) = h_hyq.join().expect("hyq client");
    let mut hyq_ref =
        QIntEngine::new(hyq.clone(), ArtifactFn::Fd, 1, fmt).expect("accepted format");
    for (ops, out) in reqs.iter().zip(&outs) {
        assert_eq!(&hyq_ref.run(ops).expect("ref"), out, "hyq qint diverged");
    }
    if let Ok(coord) = std::sync::Arc::try_unwrap(coord) {
        coord.shutdown();
    }
}

/// A spec the scaling analysis rejects fails registration with the
/// witness; forcing the same pair past the registry (programmatic
/// registration without `validate()`) fails every request loudly —
/// requests are never silently served by the rounded-f64 lane.
#[test]
fn rejected_qint_routes_fail_loudly_not_silently() {
    let err = RobotRegistry::from_cli_spec("baxter:qint@12.12", 8).unwrap_err();
    assert!(err.contains("minv.Dinv"), "witness missing from registration error: {err}");

    let baxter = builtin_robot("baxter").unwrap();
    let n = baxter.dof();
    let mut reg = RobotRegistry::new();
    reg.register(baxter, BackendKind::NativeInt(QFormat::new(12, 12)), 8);
    assert!(reg.validate().is_err());
    // Start it anyway: the route must answer with the witness, not with
    // rounded-f64 numbers.
    let coord = Coordinator::start_registry(&reg, 100);
    let ops = vec![vec![0.1f32; n], vec![0.0; n], vec![0.0; n]];
    let res = coord.submit_to("baxter", ArtifactFn::Fd, ops).recv().expect("answer");
    let err = res.expect_err("rejected format must not serve");
    assert!(err.to_string().contains("minv.Dinv"), "route error lost the witness: {err}");
    coord.shutdown();
}
