//! Allocation-free dynamics workspace — the CPU analogue of the
//! accelerator's resident task state. Dadu-RBD/DRACO keep all per-task
//! intermediates (transforms, link velocities, articulated inertias, the
//! shared-divider queue) in on-chip buffers so back-to-back tasks pay no
//! setup cost; `DynWorkspace` does the same for the native serving path:
//! every buffer any kernel needs is allocated once per (robot, worker
//! thread) and overwritten per task.
//!
//! The fused [`DynWorkspace::fd_into`] additionally mirrors the RTP
//! pipeline structure of FD = M⁻¹·ID: one kinematics pass feeds both the
//! RNEA bias sweep and the division-deferring Minv sweep, and τ − C is
//! folded directly into the M⁻¹ matvec — no intermediate vectors, no
//! recomputed shared state.

use super::crba::crba_into;
use super::fd::{aba_into, fold_rhs_matvec, AbaScratch};
use super::kinematics::Kin;
use super::minv::{minv_dd_into, DividerQueue, MinvScratch, Topology};
use super::rnea::{bias_into, rnea_into};
use crate::model::Robot;
use crate::spatial::{DMat, M6, SV};

/// Preallocated, n-sized buffers for every dynamics kernel: the kinematic
/// cache, RNEA link accelerations/forces, articulated inertias, the
/// [`DividerQueue`], M⁻¹ scratch, and the per-robot topology index lists.
///
/// One workspace serves one robot; `new` sizes every buffer from the
/// robot's DOF and precomputes the subtree/branch column lists that the
/// masked Minv sweeps otherwise rebuild on every call.
#[derive(Debug, Clone)]
pub struct DynWorkspace {
    n: usize,
    /// Kinematic cache (transforms, subspaces, velocities) for the
    /// current task; recomputed in place per call.
    pub kin: Kin,
    /// Precomputed subtree/branch column lists.
    pub topo: Topology,
    /// RNEA scratch: link accelerations and forces.
    pub a: Vec<SV>,
    pub f: Vec<SV>,
    /// Bias torques C(q, q̇, f_ext) of the last `fd_into`/`bias` pass.
    pub bias: Vec<f64>,
    /// Minv scratch: articulated inertias, U/D, flattened accumulators.
    pub minv_scratch: MinvScratch,
    /// Shared-divider request trace of the last Minv sweep.
    pub divq: DividerQueue,
    /// M⁻¹ of the last `fd_into`/`minv_into` call.
    pub mi: DMat,
    /// CRBA composite-inertia scratch (aliases nothing else).
    pub ic: Vec<M6>,
    /// ABA scratch for the oracle/simulator fast path.
    pub aba_scratch: AbaScratch,
}

impl DynWorkspace {
    pub fn new(robot: &Robot) -> DynWorkspace {
        let n = robot.dof();
        DynWorkspace {
            n,
            kin: Kin::empty(n),
            topo: Topology::new(robot),
            a: vec![SV::ZERO; n],
            f: vec![SV::ZERO; n],
            bias: vec![0.0; n],
            minv_scratch: MinvScratch::new(n),
            divq: DividerQueue::default(),
            mi: DMat::zeros(n, n),
            ic: vec![[0.0; 36]; n],
            aba_scratch: AbaScratch::new(n),
        }
    }

    /// DOF the workspace was sized for.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Inverse dynamics: τ = RNEA(q, q̇, q̈, f_ext), written into `tau`.
    pub fn rnea_into(
        &mut self,
        robot: &Robot,
        q: &[f64],
        qd: &[f64],
        qdd: &[f64],
        fext: Option<&[SV]>,
        tau: &mut [f64],
    ) {
        self.kin.recompute(robot, q, qd);
        rnea_into(robot, &self.kin, qdd, fext, &mut self.a, &mut self.f, tau);
    }

    /// Mass matrix M(q), written into `m` (N×N).
    pub fn crba_into(&mut self, robot: &Robot, q: &[f64], m: &mut DMat) {
        self.kin.recompute_positions(robot, q);
        crba_into(robot, &self.kin, &mut self.ic, m);
    }

    /// Analytical M⁻¹(q) via the division-deferring sweep, written into
    /// `out` (N×N). The divider trace is left in `self.divq`.
    pub fn minv_into(&mut self, robot: &Robot, q: &[f64], out: &mut DMat) {
        self.kin.recompute_positions(robot, q);
        minv_dd_into(
            robot,
            &self.kin,
            &self.topo,
            &mut self.minv_scratch,
            &mut self.divq,
            out,
        );
    }

    /// Fused forward dynamics q̈ = M⁻¹(q)·(τ − C(q, q̇, f_ext)): one
    /// kinematics pass shared by the RNEA bias sweep and the
    /// division-deferring Minv sweep, with τ − C folded into the final
    /// matvec. Writes q̈ into `qdd`; leaves C in `self.bias` and M⁻¹ in
    /// `self.mi` for callers that want the byproducts.
    pub fn fd_into(
        &mut self,
        robot: &Robot,
        q: &[f64],
        qd: &[f64],
        tau: &[f64],
        fext: Option<&[SV]>,
        qdd: &mut [f64],
    ) {
        let n = self.n;
        assert_eq!(tau.len(), n);
        assert_eq!(qdd.len(), n);
        self.kin.recompute(robot, q, qd);
        bias_into(robot, &self.kin, fext, &mut self.a, &mut self.f, &mut self.bias);
        // Minv only reads positions (xup, s); the velocity entries in the
        // shared cache are simply ignored, so no second kinematics pass.
        minv_dd_into(
            robot,
            &self.kin,
            &self.topo,
            &mut self.minv_scratch,
            &mut self.divq,
            &mut self.mi,
        );
        fold_rhs_matvec(&self.mi, tau, &self.bias, qdd);
    }

    /// Fused multi-output dynamics: one kinematics pass feeds the RNEA
    /// bias sweep, the division-deferring M⁻¹ sweep, and the FD τ-fold,
    /// and all three results leave in one flat egress slice:
    ///
    /// ```text
    /// out = [ q̈ (N) | M⁻¹ (N×N row-major) | C (N) ]      len = N² + 2N
    /// ```
    ///
    /// This is the [`fd_into`](Self::fd_into) fusion generalized to
    /// multi-output egress — the CPU analog of the paper's inter-module
    /// DSP reuse: an MPC/RL client wanting FD *and* M⁻¹ *and* C at the
    /// same `(q, q̇)` pays one sweep instead of three routes. Each
    /// section is bitwise identical to what the separate `fd` / `minv` /
    /// `rnea(q̈=0)` routes produce at the same inputs.
    pub fn dyn_all_into(
        &mut self,
        robot: &Robot,
        q: &[f64],
        qd: &[f64],
        tau: &[f64],
        fext: Option<&[SV]>,
        out: &mut [f64],
    ) {
        let n = self.n;
        assert_eq!(out.len(), n * n + 2 * n, "dyn_all egress is qdd|minv|bias");
        let (qdd, rest) = out.split_at_mut(n);
        self.fd_into(robot, q, qd, tau, fext, qdd);
        let (mi, bias) = rest.split_at_mut(n * n);
        mi.copy_from_slice(&self.mi.d);
        bias.copy_from_slice(&self.bias);
    }

    /// [`dyn_all_into`](Self::dyn_all_into) with a cross-request
    /// kinematics memo: the sweep outputs `(M⁻¹, C)` are keyed by the
    /// exact bit patterns of `(q, q̇)` plus `robot_fp`
    /// ([`Robot::fingerprint`]), so a repeated linearization point skips
    /// the kinematics/bias/M⁻¹ sweeps and re-runs only the τ-fold
    /// matvec. A hit is bitwise identical to a cold miss by
    /// construction — the cached words are exactly the sweep outputs —
    /// so memo state never changes results, only cost. External forces
    /// are not part of the key, so this entry point is `fext = None`
    /// only (the serving route's shape).
    #[allow(clippy::too_many_arguments)]
    pub fn dyn_all_memo_into(
        &mut self,
        robot: &Robot,
        robot_fp: u64,
        q: &[f64],
        qd: &[f64],
        tau: &[f64],
        memo: &mut super::memo::FloatMemo,
        out: &mut [f64],
    ) {
        let n = self.n;
        assert_eq!(tau.len(), n);
        assert_eq!(out.len(), n * n + 2 * n, "dyn_all egress is qdd|minv|bias");
        memo.begin();
        memo.stage_f64(q);
        memo.stage_f64(qd);
        if memo.lookup(robot_fp) {
            let (mi, bias) = memo.front();
            self.mi.d.copy_from_slice(mi);
            self.bias.copy_from_slice(bias);
        } else {
            self.kin.recompute(robot, q, qd);
            bias_into(robot, &self.kin, None, &mut self.a, &mut self.f, &mut self.bias);
            minv_dd_into(
                robot,
                &self.kin,
                &self.topo,
                &mut self.minv_scratch,
                &mut self.divq,
                &mut self.mi,
            );
            memo.insert(robot_fp, (self.mi.d.clone(), self.bias.clone()));
        }
        let (qdd, rest) = out.split_at_mut(n);
        fold_rhs_matvec(&self.mi, tau, &self.bias, qdd);
        let (mi, bias) = rest.split_at_mut(n * n);
        mi.copy_from_slice(&self.mi.d);
        bias.copy_from_slice(&self.bias);
    }

    /// Forward dynamics via the O(N) Articulated Body Algorithm — the
    /// motion-simulator fast path. Writes q̈ into `qdd`.
    pub fn aba_into(
        &mut self,
        robot: &Robot,
        q: &[f64],
        qd: &[f64],
        tau: &[f64],
        fext: Option<&[SV]>,
        qdd: &mut [f64],
    ) {
        self.kin.recompute(robot, q, qd);
        aba_into(robot, &self.kin, tau, fext, &mut self.aba_scratch, qdd);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dynamics::{aba, crba, fd, minv, rnea};
    use crate::model::{builtin, State};
    use crate::util::check::assert_slices_close;
    use crate::util::rng::Rng;

    #[test]
    fn workspace_kernels_match_allocating_paths() {
        for robot in [builtin::iiwa(), builtin::hyq(), builtin::atlas(), builtin::baxter()] {
            let n = robot.dof();
            let mut ws = DynWorkspace::new(&robot);
            let mut rng = Rng::new(500);
            // Reuse the same workspace across iterations: stale state from
            // one task must never leak into the next.
            for _ in 0..4 {
                let s = State::random(&robot, &mut rng);
                let qdd_in = rng.vec_range(n, -3.0, 3.0);
                let tau_ref = rnea(&robot, &s.q, &s.qd, &qdd_in, None);
                let mut tau_ws = vec![0.0; n];
                ws.rnea_into(&robot, &s.q, &s.qd, &qdd_in, None, &mut tau_ws);
                assert_slices_close(&tau_ws, &tau_ref, 1e-12, &format!("{} rnea", robot.name));

                let mut qdd_ws = vec![0.0; n];
                ws.fd_into(&robot, &s.q, &s.qd, &tau_ref, None, &mut qdd_ws);
                let qdd_ref = fd(&robot, &s.q, &s.qd, &tau_ref, None);
                assert_slices_close(&qdd_ws, &qdd_ref, 1e-9, &format!("{} fd", robot.name));
                // fd(rnea(q̈)) round-trip against the requested q̈.
                assert_slices_close(&qdd_ws, &qdd_in, 1e-7, &format!("{} fd∘id", robot.name));

                let mut qdd_aba = vec![0.0; n];
                ws.aba_into(&robot, &s.q, &s.qd, &tau_ref, None, &mut qdd_aba);
                let aba_ref = aba(&robot, &s.q, &s.qd, &tau_ref, None);
                assert_slices_close(&qdd_aba, &aba_ref, 1e-12, &format!("{} aba", robot.name));

                let mut m = DMat::zeros(n, n);
                ws.crba_into(&robot, &s.q, &mut m);
                let m_ref = crba(&robot, &s.q);
                let err = m.sub(&m_ref).max_abs();
                assert!(err < 1e-12, "{}: crba workspace err {err}", robot.name);

                let mut mi = DMat::zeros(n, n);
                ws.minv_into(&robot, &s.q, &mut mi);
                let mi_ref = minv(&robot, &s.q);
                let err = mi.sub(&mi_ref).max_abs();
                assert!(err < 1e-9, "{}: minv workspace err {err}", robot.name);
                assert_eq!(ws.divq.requests.len(), n, "one divider request per joint");
            }
        }
    }

    #[test]
    fn dyn_all_sections_match_separate_routes_bitwise() {
        // The fused multi-output egress must be *bitwise* what the
        // separate fd / minv / rnea(q̈=0) kernels produce — that is the
        // contract the DynAll route's differential tests build on.
        for robot in [builtin::iiwa(), builtin::hyq(), builtin::atlas(), builtin::baxter()] {
            let n = robot.dof();
            let mut ws = DynWorkspace::new(&robot);
            let mut sep = DynWorkspace::new(&robot);
            let mut rng = Rng::new(502);
            for _ in 0..3 {
                let s = State::random(&robot, &mut rng);
                let tau = rng.vec_range(n, -10.0, 10.0);
                let mut out = vec![0.0; n * n + 2 * n];
                ws.dyn_all_into(&robot, &s.q, &s.qd, &tau, None, &mut out);

                let mut qdd = vec![0.0; n];
                sep.fd_into(&robot, &s.q, &s.qd, &tau, None, &mut qdd);
                assert_eq!(&out[..n], &qdd[..], "{}: fused q̈ != fd route", robot.name);

                let mut mi = DMat::zeros(n, n);
                sep.minv_into(&robot, &s.q, &mut mi);
                assert_eq!(&out[n..n + n * n], &mi.d[..], "{}: fused M⁻¹ != minv route", robot.name);

                let zero = vec![0.0; n];
                let mut bias = vec![0.0; n];
                sep.rnea_into(&robot, &s.q, &s.qd, &zero, None, &mut bias);
                assert_eq!(&out[n + n * n..], &bias[..], "{}: fused C != rnea(0) route", robot.name);
            }
        }
    }

    #[test]
    fn dyn_all_memo_hit_is_bitwise_identical_to_miss() {
        use crate::dynamics::memo::FloatMemo;
        let robot = builtin::iiwa();
        let fp = robot.fingerprint();
        let n = robot.dof();
        let mut ws = DynWorkspace::new(&robot);
        let mut memo = FloatMemo::new(8);
        let mut rng = Rng::new(503);
        let s = State::random(&robot, &mut rng);
        let tau_a = rng.vec_range(n, -10.0, 10.0);
        let tau_b = rng.vec_range(n, -10.0, 10.0);
        let per = n * n + 2 * n;

        let mut cold = vec![0.0; per];
        ws.dyn_all_memo_into(&robot, fp, &s.q, &s.qd, &tau_a, &mut memo, &mut cold);
        assert_eq!(memo.counters(), (0, 1));

        // Same (q, q̇), new τ: the sweeps are skipped, only the τ-fold
        // reruns — and the result is bitwise what a memo-less call gives.
        let mut warm = vec![0.0; per];
        ws.dyn_all_memo_into(&robot, fp, &s.q, &s.qd, &tau_b, &mut memo, &mut warm);
        assert_eq!(memo.counters(), (1, 1));
        let mut plain = vec![0.0; per];
        ws.dyn_all_into(&robot, &s.q, &s.qd, &tau_b, None, &mut plain);
        assert_eq!(warm, plain, "memo hit must be bitwise identical to cold compute");

        // Exact repeat hits again and reproduces the first answer bitwise.
        let mut again = vec![0.0; per];
        ws.dyn_all_memo_into(&robot, fp, &s.q, &s.qd, &tau_a, &mut memo, &mut again);
        assert_eq!(again, cold);
        assert_eq!(memo.counters(), (2, 1));
    }

    #[test]
    fn dyn_all_memo_adjacent_states_never_alias() {
        use crate::dynamics::memo::FloatMemo;
        let robot = builtin::iiwa();
        let fp = robot.fingerprint();
        let n = robot.dof();
        let mut ws = DynWorkspace::new(&robot);
        let mut memo = FloatMemo::new(8);
        let mut rng = Rng::new(504);
        let s = State::random(&robot, &mut rng);
        let tau = rng.vec_range(n, -5.0, 5.0);
        let per = n * n + 2 * n;

        // One-ulp-apart q: distinct keys, distinct (correct) answers.
        let mut q_adj = s.q.clone();
        q_adj[0] = f64::from_bits(q_adj[0].to_bits() + 1);
        let mut out_a = vec![0.0; per];
        let mut out_b = vec![0.0; per];
        ws.dyn_all_memo_into(&robot, fp, &s.q, &s.qd, &tau, &mut memo, &mut out_a);
        ws.dyn_all_memo_into(&robot, fp, &q_adj, &s.qd, &tau, &mut memo, &mut out_b);
        assert_eq!(memo.counters(), (0, 2), "adjacent state must miss, not alias");

        // Each key replays its own cached sweep, bitwise.
        let mut ref_a = vec![0.0; per];
        let mut ref_b = vec![0.0; per];
        ws.dyn_all_into(&robot, &s.q, &s.qd, &tau, None, &mut ref_a);
        ws.dyn_all_into(&robot, &q_adj, &s.qd, &tau, None, &mut ref_b);
        let mut hit_a = vec![0.0; per];
        let mut hit_b = vec![0.0; per];
        ws.dyn_all_memo_into(&robot, fp, &s.q, &s.qd, &tau, &mut memo, &mut hit_a);
        ws.dyn_all_memo_into(&robot, fp, &q_adj, &s.qd, &tau, &mut memo, &mut hit_b);
        assert_eq!(memo.counters(), (2, 2));
        assert_eq!(hit_a, ref_a);
        assert_eq!(hit_b, ref_b);
    }

    #[test]
    fn dyn_all_memo_seeded_sweep_with_eviction() {
        // Proptest-style randomized traffic: a tiny-capacity memo under
        // a revisit-heavy seeded stream must (a) always produce output
        // bitwise equal to the memo-less kernel, (b) keep counters
        // monotone with exactly one increment per call, and (c) never
        // exceed capacity even as evictions churn.
        use crate::dynamics::memo::FloatMemo;
        let robot = builtin::iiwa();
        let fp = robot.fingerprint();
        let n = robot.dof();
        let mut ws = DynWorkspace::new(&robot);
        let mut plain_ws = DynWorkspace::new(&robot);
        let mut memo = FloatMemo::new(3);
        let mut rng = Rng::new(505);
        let per = n * n + 2 * n;

        // A pool of 6 operating points against capacity 3 forces both
        // hits (revisits while resident) and evictions (working set > cap).
        let states: Vec<State> = (0..6).map(|_| State::random(&robot, &mut rng)).collect();
        let mut pick = 0x2545_f491_4f6c_dd1d_u64;
        let mut prev = (0u64, 0u64);
        for step in 0..64 {
            pick = pick.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let s = &states[(pick >> 59) as usize % states.len()];
            let tau = rng.vec_range(n, -8.0, 8.0);
            let mut got = vec![0.0; per];
            ws.dyn_all_memo_into(&robot, fp, &s.q, &s.qd, &tau, &mut memo, &mut got);
            let mut want = vec![0.0; per];
            plain_ws.dyn_all_into(&robot, &s.q, &s.qd, &tau, None, &mut want);
            assert_eq!(got, want, "step {step}: memo path diverged from plain kernel");
            let now = memo.counters();
            assert_eq!(now.0 + now.1, prev.0 + prev.1 + 1, "one counter per call");
            assert!(now.0 >= prev.0 && now.1 >= prev.1, "counters monotone");
            assert!(memo.len() <= memo.cap(), "eviction keeps len within cap");
            prev = now;
        }
        let (hits, misses) = memo.counters();
        assert!(hits > 0, "revisit-heavy stream must hit");
        assert!(misses > 3, "working set > cap must keep evicting/missing");
    }

    #[test]
    fn fused_fd_byproducts_are_consistent() {
        let robot = builtin::iiwa();
        let n = robot.dof();
        let mut ws = DynWorkspace::new(&robot);
        let mut rng = Rng::new(501);
        let s = State::random(&robot, &mut rng);
        let tau = rng.vec_range(n, -10.0, 10.0);
        let mut qdd = vec![0.0; n];
        ws.fd_into(&robot, &s.q, &s.qd, &tau, None, &mut qdd);
        // bias == RNEA(q, q̇, 0) and mi == M⁻¹ are left behind.
        let bias_ref = crate::dynamics::bias_forces(&robot, &s.q, &s.qd, None);
        assert_slices_close(&ws.bias, &bias_ref, 1e-12, "fd bias byproduct");
        let mi_ref = minv(&robot, &s.q);
        assert!(ws.mi.sub(&mi_ref).max_abs() < 1e-9, "fd minv byproduct");
    }
}
