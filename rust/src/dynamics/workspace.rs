//! Allocation-free dynamics workspace — the CPU analogue of the
//! accelerator's resident task state. Dadu-RBD/DRACO keep all per-task
//! intermediates (transforms, link velocities, articulated inertias, the
//! shared-divider queue) in on-chip buffers so back-to-back tasks pay no
//! setup cost; `DynWorkspace` does the same for the native serving path:
//! every buffer any kernel needs is allocated once per (robot, worker
//! thread) and overwritten per task.
//!
//! The fused [`DynWorkspace::fd_into`] additionally mirrors the RTP
//! pipeline structure of FD = M⁻¹·ID: one kinematics pass feeds both the
//! RNEA bias sweep and the division-deferring Minv sweep, and τ − C is
//! folded directly into the M⁻¹ matvec — no intermediate vectors, no
//! recomputed shared state.

use super::crba::crba_into;
use super::fd::{aba_into, fold_rhs_matvec, AbaScratch};
use super::kinematics::Kin;
use super::minv::{minv_dd_into, DividerQueue, MinvScratch, Topology};
use super::rnea::{bias_into, rnea_into};
use crate::model::Robot;
use crate::spatial::{DMat, M6, SV};

/// Preallocated, n-sized buffers for every dynamics kernel: the kinematic
/// cache, RNEA link accelerations/forces, articulated inertias, the
/// [`DividerQueue`], M⁻¹ scratch, and the per-robot topology index lists.
///
/// One workspace serves one robot; `new` sizes every buffer from the
/// robot's DOF and precomputes the subtree/branch column lists that the
/// masked Minv sweeps otherwise rebuild on every call.
#[derive(Debug, Clone)]
pub struct DynWorkspace {
    n: usize,
    /// Kinematic cache (transforms, subspaces, velocities) for the
    /// current task; recomputed in place per call.
    pub kin: Kin,
    /// Precomputed subtree/branch column lists.
    pub topo: Topology,
    /// RNEA scratch: link accelerations and forces.
    pub a: Vec<SV>,
    pub f: Vec<SV>,
    /// Bias torques C(q, q̇, f_ext) of the last `fd_into`/`bias` pass.
    pub bias: Vec<f64>,
    /// Minv scratch: articulated inertias, U/D, flattened accumulators.
    pub minv_scratch: MinvScratch,
    /// Shared-divider request trace of the last Minv sweep.
    pub divq: DividerQueue,
    /// M⁻¹ of the last `fd_into`/`minv_into` call.
    pub mi: DMat,
    /// CRBA composite-inertia scratch (aliases nothing else).
    pub ic: Vec<M6>,
    /// ABA scratch for the oracle/simulator fast path.
    pub aba_scratch: AbaScratch,
}

impl DynWorkspace {
    pub fn new(robot: &Robot) -> DynWorkspace {
        let n = robot.dof();
        DynWorkspace {
            n,
            kin: Kin::empty(n),
            topo: Topology::new(robot),
            a: vec![SV::ZERO; n],
            f: vec![SV::ZERO; n],
            bias: vec![0.0; n],
            minv_scratch: MinvScratch::new(n),
            divq: DividerQueue::default(),
            mi: DMat::zeros(n, n),
            ic: vec![[0.0; 36]; n],
            aba_scratch: AbaScratch::new(n),
        }
    }

    /// DOF the workspace was sized for.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Inverse dynamics: τ = RNEA(q, q̇, q̈, f_ext), written into `tau`.
    pub fn rnea_into(
        &mut self,
        robot: &Robot,
        q: &[f64],
        qd: &[f64],
        qdd: &[f64],
        fext: Option<&[SV]>,
        tau: &mut [f64],
    ) {
        self.kin.recompute(robot, q, qd);
        rnea_into(robot, &self.kin, qdd, fext, &mut self.a, &mut self.f, tau);
    }

    /// Mass matrix M(q), written into `m` (N×N).
    pub fn crba_into(&mut self, robot: &Robot, q: &[f64], m: &mut DMat) {
        self.kin.recompute_positions(robot, q);
        crba_into(robot, &self.kin, &mut self.ic, m);
    }

    /// Analytical M⁻¹(q) via the division-deferring sweep, written into
    /// `out` (N×N). The divider trace is left in `self.divq`.
    pub fn minv_into(&mut self, robot: &Robot, q: &[f64], out: &mut DMat) {
        self.kin.recompute_positions(robot, q);
        minv_dd_into(
            robot,
            &self.kin,
            &self.topo,
            &mut self.minv_scratch,
            &mut self.divq,
            out,
        );
    }

    /// Fused forward dynamics q̈ = M⁻¹(q)·(τ − C(q, q̇, f_ext)): one
    /// kinematics pass shared by the RNEA bias sweep and the
    /// division-deferring Minv sweep, with τ − C folded into the final
    /// matvec. Writes q̈ into `qdd`; leaves C in `self.bias` and M⁻¹ in
    /// `self.mi` for callers that want the byproducts.
    pub fn fd_into(
        &mut self,
        robot: &Robot,
        q: &[f64],
        qd: &[f64],
        tau: &[f64],
        fext: Option<&[SV]>,
        qdd: &mut [f64],
    ) {
        let n = self.n;
        assert_eq!(tau.len(), n);
        assert_eq!(qdd.len(), n);
        self.kin.recompute(robot, q, qd);
        bias_into(robot, &self.kin, fext, &mut self.a, &mut self.f, &mut self.bias);
        // Minv only reads positions (xup, s); the velocity entries in the
        // shared cache are simply ignored, so no second kinematics pass.
        minv_dd_into(
            robot,
            &self.kin,
            &self.topo,
            &mut self.minv_scratch,
            &mut self.divq,
            &mut self.mi,
        );
        fold_rhs_matvec(&self.mi, tau, &self.bias, qdd);
    }

    /// Forward dynamics via the O(N) Articulated Body Algorithm — the
    /// motion-simulator fast path. Writes q̈ into `qdd`.
    pub fn aba_into(
        &mut self,
        robot: &Robot,
        q: &[f64],
        qd: &[f64],
        tau: &[f64],
        fext: Option<&[SV]>,
        qdd: &mut [f64],
    ) {
        self.kin.recompute(robot, q, qd);
        aba_into(robot, &self.kin, tau, fext, &mut self.aba_scratch, qdd);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dynamics::{aba, crba, fd, minv, rnea};
    use crate::model::{builtin, State};
    use crate::util::check::assert_slices_close;
    use crate::util::rng::Rng;

    #[test]
    fn workspace_kernels_match_allocating_paths() {
        for robot in [builtin::iiwa(), builtin::hyq(), builtin::atlas(), builtin::baxter()] {
            let n = robot.dof();
            let mut ws = DynWorkspace::new(&robot);
            let mut rng = Rng::new(500);
            // Reuse the same workspace across iterations: stale state from
            // one task must never leak into the next.
            for _ in 0..4 {
                let s = State::random(&robot, &mut rng);
                let qdd_in = rng.vec_range(n, -3.0, 3.0);
                let tau_ref = rnea(&robot, &s.q, &s.qd, &qdd_in, None);
                let mut tau_ws = vec![0.0; n];
                ws.rnea_into(&robot, &s.q, &s.qd, &qdd_in, None, &mut tau_ws);
                assert_slices_close(&tau_ws, &tau_ref, 1e-12, &format!("{} rnea", robot.name));

                let mut qdd_ws = vec![0.0; n];
                ws.fd_into(&robot, &s.q, &s.qd, &tau_ref, None, &mut qdd_ws);
                let qdd_ref = fd(&robot, &s.q, &s.qd, &tau_ref, None);
                assert_slices_close(&qdd_ws, &qdd_ref, 1e-9, &format!("{} fd", robot.name));
                // fd(rnea(q̈)) round-trip against the requested q̈.
                assert_slices_close(&qdd_ws, &qdd_in, 1e-7, &format!("{} fd∘id", robot.name));

                let mut qdd_aba = vec![0.0; n];
                ws.aba_into(&robot, &s.q, &s.qd, &tau_ref, None, &mut qdd_aba);
                let aba_ref = aba(&robot, &s.q, &s.qd, &tau_ref, None);
                assert_slices_close(&qdd_aba, &aba_ref, 1e-12, &format!("{} aba", robot.name));

                let mut m = DMat::zeros(n, n);
                ws.crba_into(&robot, &s.q, &mut m);
                let m_ref = crba(&robot, &s.q);
                let err = m.sub(&m_ref).max_abs();
                assert!(err < 1e-12, "{}: crba workspace err {err}", robot.name);

                let mut mi = DMat::zeros(n, n);
                ws.minv_into(&robot, &s.q, &mut mi);
                let mi_ref = minv(&robot, &s.q);
                let err = mi.sub(&mi_ref).max_abs();
                assert!(err < 1e-9, "{}: minv workspace err {err}", robot.name);
                assert_eq!(ws.divq.requests.len(), n, "one divider request per joint");
            }
        }
    }

    #[test]
    fn fused_fd_byproducts_are_consistent() {
        let robot = builtin::iiwa();
        let n = robot.dof();
        let mut ws = DynWorkspace::new(&robot);
        let mut rng = Rng::new(501);
        let s = State::random(&robot, &mut rng);
        let tau = rng.vec_range(n, -10.0, 10.0);
        let mut qdd = vec![0.0; n];
        ws.fd_into(&robot, &s.q, &s.qd, &tau, None, &mut qdd);
        // bias == RNEA(q, q̇, 0) and mi == M⁻¹ are left behind.
        let bias_ref = crate::dynamics::bias_forces(&robot, &s.q, &s.qd, None);
        assert_slices_close(&ws.bias, &bias_ref, 1e-12, "fd bias byproduct");
        let mi_ref = minv(&robot, &s.q);
        assert!(ws.mi.sub(&mi_ref).max_abs() < 1e-9, "fd minv byproduct");
    }
}
