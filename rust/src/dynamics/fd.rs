//! Forward dynamics. Two routes, matching the paper's Fig. 3(a):
//!
//! * `fd` — the accelerator's formulation `q̈ = M⁻¹ (τ − C)` (Eq. 2 in the
//!   paper: FD = M⁻¹·ID), built from the Minv + RNEA modules.
//! * `aba` — the O(N) Articulated Body Algorithm, used as an independent
//!   correctness oracle and as the ICMS motion-simulator fast path.

use super::kinematics::Kin;
use super::minv::minv_with_kin;
use super::rnea::bias_into;
use crate::model::Robot;
use crate::spatial::mat6::{matvec6, outer6, scale6, sub6, xtax, M6};
use crate::spatial::SV;

/// q̈ = M⁻¹(q) · (τ − C(q, q̇, f_ext)) — the composition the accelerator
/// computes with its RNEA and Minv RTP modules. One shared `Kin` feeds
/// both passes, and τ − C is folded directly into the M⁻¹ matvec (no
/// intermediate right-hand-side vector).
///
/// Allocating path; the serving hot path is
/// [`crate::dynamics::DynWorkspace::fd_into`], which reuses buffers
/// across calls and defers the Minv divisions.
pub fn fd(robot: &Robot, q: &[f64], qd: &[f64], tau: &[f64], fext: Option<&[SV]>) -> Vec<f64> {
    let n = robot.dof();
    assert_eq!(tau.len(), n);
    let kin = Kin::new(robot, q, qd);
    let mut a = vec![SV::ZERO; n];
    let mut f = vec![SV::ZERO; n];
    let mut bias = vec![0.0; n];
    bias_into(robot, &kin, fext, &mut a, &mut f, &mut bias);
    let mi = minv_with_kin(robot, &kin);
    let mut qdd = vec![0.0; n];
    fold_rhs_matvec(&mi, tau, &bias, &mut qdd);
    qdd
}

/// q̈ = M⁻¹·(τ − C) with the subtraction folded into the matvec — the
/// shared final stage of both the allocating [`fd`] and the workspace
/// [`crate::dynamics::DynWorkspace::fd_into`] (keep them byte-identical:
/// the equivalence tests assume the two paths agree).
pub fn fold_rhs_matvec(mi: &crate::spatial::DMat, tau: &[f64], bias: &[f64], qdd: &mut [f64]) {
    let n = qdd.len();
    assert_eq!((mi.rows, mi.cols), (n, n));
    assert_eq!(tau.len(), n);
    assert_eq!(bias.len(), n);
    for i in 0..n {
        let row = &mi.d[i * n..(i + 1) * n];
        let mut acc = 0.0;
        for j in 0..n {
            acc += row[j] * (tau[j] - bias[j]);
        }
        qdd[i] = acc;
    }
}

/// Reusable buffers for the Articulated Body Algorithm sweeps.
#[derive(Debug, Clone)]
pub struct AbaScratch {
    /// Velocity-product accelerations.
    pub c: Vec<SV>,
    /// Bias forces.
    pub pa: Vec<SV>,
    /// Articulated inertias.
    pub ia: Vec<M6>,
    pub u: Vec<SV>,
    pub dinv: Vec<f64>,
    pub uu: Vec<f64>,
    /// Link accelerations.
    pub a: Vec<SV>,
}

impl AbaScratch {
    pub fn new(n: usize) -> AbaScratch {
        AbaScratch {
            c: vec![SV::ZERO; n],
            pa: vec![SV::ZERO; n],
            ia: vec![[0.0; 36]; n],
            u: vec![SV::ZERO; n],
            dinv: vec![0.0; n],
            uu: vec![0.0; n],
            a: vec![SV::ZERO; n],
        }
    }
}

/// Allocation-free ABA kernel (Featherstone RBDA Table 7.1): writes q̈
/// into `qdd` using a precomputed kinematic cache and caller-owned
/// scratch.
pub fn aba_into(
    robot: &Robot,
    kin: &Kin,
    tau: &[f64],
    fext: Option<&[SV]>,
    scr: &mut AbaScratch,
    qdd: &mut [f64],
) {
    let n = robot.dof();
    assert_eq!(tau.len(), n);
    assert_eq!(qdd.len(), n);
    assert_eq!(scr.c.len(), n, "scratch sized for a different robot");
    let a0 = SV::new(crate::spatial::V3::ZERO, -robot.gravity);

    // Forward: bias accelerations and forces.
    for i in 0..n {
        let link = &robot.links[i];
        let vi = kin.v[i];
        scr.c[i] = vi.crm(&kin.s[i].scale(kin.qd[i]));
        let mut pi = vi.crf(&link.inertia.apply(&vi));
        if let Some(fe) = fext {
            pi = pi - fe[i];
        }
        scr.pa[i] = pi;
        scr.ia[i] = link.inertia.to_mat6();
    }

    // Backward: articulated inertias.
    for i in (0..n).rev() {
        let s = kin.s[i];
        let ui = matvec6(&scr.ia[i], &s);
        let di = s.dot(&ui);
        let di_inv = 1.0 / di;
        scr.u[i] = ui;
        scr.dinv[i] = di_inv;
        scr.uu[i] = tau[i] - s.dot(&scr.pa[i]);
        if let Some(p) = robot.links[i].parent {
            let ia_art = sub6(&scr.ia[i], &scale6(&outer6(&ui, &ui), di_inv));
            let contrib = xtax(&kin.xup[i].to_mat6(), &ia_art);
            for (dst, c) in scr.ia[p].iter_mut().zip(&contrib) {
                *dst += c;
            }
            let pa_art = scr.pa[i]
                + matvec6(&ia_art, &scr.c[i])
                + ui.scale(di_inv * scr.uu[i]);
            let upd = kin.xup[i].inv_apply_force(&pa_art);
            scr.pa[p] = scr.pa[p] + upd;
        }
    }

    // Forward: accelerations.
    for i in 0..n {
        let a_parent = match robot.links[i].parent {
            Some(p) => scr.a[p],
            None => a0,
        };
        let ap = kin.xup[i].apply(&a_parent) + scr.c[i];
        qdd[i] = scr.dinv[i] * (scr.uu[i] - scr.u[i].dot(&ap));
        scr.a[i] = ap + kin.s[i].scale(qdd[i]);
    }
}

/// Articulated Body Algorithm. Thin allocating wrapper over [`aba_into`].
pub fn aba(robot: &Robot, q: &[f64], qd: &[f64], tau: &[f64], fext: Option<&[SV]>) -> Vec<f64> {
    let n = robot.dof();
    let kin = Kin::new(robot, q, qd);
    let mut scr = AbaScratch::new(n);
    let mut qdd = vec![0.0; n];
    aba_into(robot, &kin, tau, fext, &mut scr, &mut qdd);
    qdd
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dynamics::rnea::rnea;
    use crate::model::{builtin, State};
    use crate::util::rng::Rng;

    /// FD(ID(q̈)) = q̈ — the paper's Eq. 2 round-trip, across all robots.
    #[test]
    fn fd_inverts_id() {
        for robot in [builtin::iiwa(), builtin::hyq(), builtin::atlas(), builtin::baxter()] {
            let mut rng = Rng::new(300);
            for _ in 0..3 {
                let s = State::random(&robot, &mut rng);
                let n = robot.dof();
                let qdd_in = rng.vec_range(n, -4.0, 4.0);
                let tau = rnea(&robot, &s.q, &s.qd, &qdd_in, None);
                let qdd_out = fd(&robot, &s.q, &s.qd, &tau, None);
                for i in 0..n {
                    assert!(
                        (qdd_out[i] - qdd_in[i]).abs() < 1e-7 * (1.0 + qdd_in[i].abs()),
                        "{}: joint {i}: {} vs {}",
                        robot.name,
                        qdd_out[i],
                        qdd_in[i]
                    );
                }
            }
        }
    }

    /// ABA (O(N)) and Minv·(τ−C) (O(N²)) must agree — two independent
    /// formulations of the same dynamics.
    #[test]
    fn aba_matches_minv_route() {
        for robot in [builtin::iiwa(), builtin::hyq(), builtin::atlas()] {
            let mut rng = Rng::new(301);
            for _ in 0..3 {
                let s = State::random(&robot, &mut rng);
                let n = robot.dof();
                let tau = rng.vec_range(n, -20.0, 20.0);
                let q1 = fd(&robot, &s.q, &s.qd, &tau, None);
                let q2 = aba(&robot, &s.q, &s.qd, &tau, None);
                for i in 0..n {
                    assert!(
                        (q1[i] - q2[i]).abs() < 1e-6 * (1.0 + q1[i].abs()),
                        "{}: joint {i}: {} vs {}",
                        robot.name,
                        q1[i],
                        q2[i]
                    );
                }
            }
        }
    }

    #[test]
    fn external_forces_consistent_between_routes() {
        let robot = builtin::iiwa();
        let mut rng = Rng::new(302);
        let s = State::random(&robot, &mut rng);
        let n = robot.dof();
        let tau = rng.vec_range(n, -10.0, 10.0);
        let fe: Vec<SV> = (0..n).map(|_| SV::from_slice(&rng.vec_range(6, -4.0, 4.0))).collect();
        let q1 = fd(&robot, &s.q, &s.qd, &tau, Some(&fe));
        let q2 = aba(&robot, &s.q, &s.qd, &tau, Some(&fe));
        for i in 0..n {
            assert!((q1[i] - q2[i]).abs() < 1e-6 * (1.0 + q1[i].abs()), "joint {i}");
        }
    }

    /// Free fall: τ=0 at rest ⇒ gravity accelerations; feeding those back
    /// into RNEA must return ~zero torque.
    #[test]
    fn free_fall_fixed_point() {
        let robot = builtin::atlas();
        let n = robot.dof();
        let q = vec![0.1; n];
        let qdd = aba(&robot, &q, &vec![0.0; n], &vec![0.0; n], None);
        let tau = rnea(&robot, &q, &vec![0.0; n], &qdd, None);
        for (i, t) in tau.iter().enumerate() {
            assert!(t.abs() < 1e-8, "joint {i}: residual τ = {t}");
        }
    }
}
