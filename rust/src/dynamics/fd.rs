//! Forward dynamics. Two routes, matching the paper's Fig. 3(a):
//!
//! * `fd` — the accelerator's formulation `q̈ = M⁻¹ (τ − C)` (Eq. 2 in the
//!   paper: FD = M⁻¹·ID), built from the Minv + RNEA modules.
//! * `aba` — the O(N) Articulated Body Algorithm, used as an independent
//!   correctness oracle and as the ICMS motion-simulator fast path.

use super::kinematics::Kin;
use super::minv::minv_with_kin;
use super::rnea::rnea_with_kin;
use crate::model::Robot;
use crate::spatial::mat6::{matvec6, mul6, outer6, scale6, sub6, t6, M6};
use crate::spatial::SV;

/// q̈ = M⁻¹(q) · (τ − C(q, q̇, f_ext)) — the composition the accelerator
/// computes with its RNEA and Minv RTP modules.
pub fn fd(robot: &Robot, q: &[f64], qd: &[f64], tau: &[f64], fext: Option<&[SV]>) -> Vec<f64> {
    let n = robot.dof();
    assert_eq!(tau.len(), n);
    let kin = Kin::new(robot, q, qd);
    let bias = rnea_with_kin(robot, &kin, &vec![0.0; n], fext);
    let mi = minv_with_kin(robot, &kin);
    let rhs: Vec<f64> = tau.iter().zip(&bias).map(|(t, c)| t - c).collect();
    mi.matvec(&rhs)
}

/// Articulated Body Algorithm (Featherstone RBDA Table 7.1).
pub fn aba(robot: &Robot, q: &[f64], qd: &[f64], tau: &[f64], fext: Option<&[SV]>) -> Vec<f64> {
    let n = robot.dof();
    let kin = Kin::new(robot, q, qd);
    let a0 = SV::new(crate::spatial::V3::ZERO, -robot.gravity);

    // Forward: bias accelerations and forces.
    let mut c: Vec<SV> = Vec::with_capacity(n); // velocity-product accel
    let mut pa: Vec<SV> = Vec::with_capacity(n); // bias force
    let mut ia: Vec<M6> = Vec::with_capacity(n);
    for i in 0..n {
        let link = &robot.links[i];
        let vi = kin.v[i];
        let ci = vi.crm(&kin.s[i].scale(kin.qd[i]));
        let mut pi = vi.crf(&link.inertia.apply(&vi));
        if let Some(fe) = fext {
            pi = pi - fe[i];
        }
        c.push(ci);
        pa.push(pi);
        ia.push(link.inertia.to_mat6());
    }

    // Backward: articulated inertias.
    let mut u: Vec<SV> = vec![SV::ZERO; n];
    let mut dinv = vec![0.0; n];
    let mut uu = vec![0.0; n];
    for i in (0..n).rev() {
        let s = kin.s[i];
        let ui = matvec6(&ia[i], &s);
        let di = s.dot(&ui);
        let di_inv = 1.0 / di;
        u[i] = ui;
        dinv[i] = di_inv;
        uu[i] = tau[i] - s.dot(&pa[i]);
        if let Some(p) = robot.links[i].parent {
            let ia_art = sub6(&ia[i], &scale6(&outer6(&ui, &ui), di_inv));
            let xm = kin.xup[i].to_mat6();
            let contrib = mul6(&t6(&xm), &mul6(&ia_art, &xm));
            for r in 0..6 {
                for cc in 0..6 {
                    ia[p][r][cc] += contrib[r][cc];
                }
            }
            let pa_art = pa[i]
                + matvec6(&ia_art, &c[i])
                + ui.scale(di_inv * uu[i]);
            pa[p] = pa[p] + kin.xup[i].inv_apply_force(&pa_art);
        }
    }

    // Forward: accelerations.
    let mut qdd = vec![0.0; n];
    let mut a: Vec<SV> = vec![SV::ZERO; n];
    for i in 0..n {
        let a_parent = match robot.links[i].parent {
            Some(p) => a[p],
            None => a0,
        };
        let ap = kin.xup[i].apply(&a_parent) + c[i];
        qdd[i] = dinv[i] * (uu[i] - u[i].dot(&ap));
        a[i] = ap + kin.s[i].scale(qdd[i]);
    }
    qdd
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dynamics::rnea::rnea;
    use crate::model::{builtin, State};
    use crate::util::rng::Rng;

    /// FD(ID(q̈)) = q̈ — the paper's Eq. 2 round-trip, across all robots.
    #[test]
    fn fd_inverts_id() {
        for robot in [builtin::iiwa(), builtin::hyq(), builtin::atlas(), builtin::baxter()] {
            let mut rng = Rng::new(300);
            for _ in 0..3 {
                let s = State::random(&robot, &mut rng);
                let n = robot.dof();
                let qdd_in = rng.vec_range(n, -4.0, 4.0);
                let tau = rnea(&robot, &s.q, &s.qd, &qdd_in, None);
                let qdd_out = fd(&robot, &s.q, &s.qd, &tau, None);
                for i in 0..n {
                    assert!(
                        (qdd_out[i] - qdd_in[i]).abs() < 1e-7 * (1.0 + qdd_in[i].abs()),
                        "{}: joint {i}: {} vs {}",
                        robot.name,
                        qdd_out[i],
                        qdd_in[i]
                    );
                }
            }
        }
    }

    /// ABA (O(N)) and Minv·(τ−C) (O(N²)) must agree — two independent
    /// formulations of the same dynamics.
    #[test]
    fn aba_matches_minv_route() {
        for robot in [builtin::iiwa(), builtin::hyq(), builtin::atlas()] {
            let mut rng = Rng::new(301);
            for _ in 0..3 {
                let s = State::random(&robot, &mut rng);
                let n = robot.dof();
                let tau = rng.vec_range(n, -20.0, 20.0);
                let q1 = fd(&robot, &s.q, &s.qd, &tau, None);
                let q2 = aba(&robot, &s.q, &s.qd, &tau, None);
                for i in 0..n {
                    assert!(
                        (q1[i] - q2[i]).abs() < 1e-6 * (1.0 + q1[i].abs()),
                        "{}: joint {i}: {} vs {}",
                        robot.name,
                        q1[i],
                        q2[i]
                    );
                }
            }
        }
    }

    #[test]
    fn external_forces_consistent_between_routes() {
        let robot = builtin::iiwa();
        let mut rng = Rng::new(302);
        let s = State::random(&robot, &mut rng);
        let n = robot.dof();
        let tau = rng.vec_range(n, -10.0, 10.0);
        let fe: Vec<SV> = (0..n).map(|_| SV::from_slice(&rng.vec_range(6, -4.0, 4.0))).collect();
        let q1 = fd(&robot, &s.q, &s.qd, &tau, Some(&fe));
        let q2 = aba(&robot, &s.q, &s.qd, &tau, Some(&fe));
        for i in 0..n {
            assert!((q1[i] - q2[i]).abs() < 1e-6 * (1.0 + q1[i].abs()), "joint {i}");
        }
    }

    /// Free fall: τ=0 at rest ⇒ gravity accelerations; feeding those back
    /// into RNEA must return ~zero torque.
    #[test]
    fn free_fall_fixed_point() {
        let robot = builtin::atlas();
        let n = robot.dof();
        let q = vec![0.1; n];
        let qdd = aba(&robot, &q, &vec![0.0; n], &vec![0.0; n], None);
        let tau = rnea(&robot, &q, &vec![0.0; n], &qdd, None);
        for (i, t) in tau.iter().enumerate() {
            assert!(t.abs() < 1e-8, "joint {i}: residual τ = {t}");
        }
    }
}
