//! Analytical derivatives of RNEA (ΔRNEA / ΔID) and of forward dynamics
//! (ΔFD), following Carpentier & Mansard (RSS 2018): the paper's ΔID and
//! ΔFD functions (Fig. 3(a)), with
//!
//! ```text
//!   ∂FD/∂x = −M⁻¹ · ∂ID/∂x |_{q̈ = FD}          (paper Eq. 2)
//! ```
//!
//! Derivatives are organized as N tangent sweeps of the RNEA recursion —
//! the directional (forward-mode) derivative along each coordinate. This
//! is algebraically identical to ΔRNEA's fpass/bpass (the Df/Db pipeline
//! units in the accelerator) and costs the same O(N²).

use super::kinematics::Kin;
use crate::model::Robot;
use crate::spatial::{DMat, SV};

/// Partial derivatives of inverse dynamics: (∂τ/∂q, ∂τ/∂q̇), each N×N.
/// ∂τ/∂q̈ is the mass matrix (available from CRBA) and is not recomputed.
pub fn rnea_derivatives(robot: &Robot, q: &[f64], qd: &[f64], qdd: &[f64]) -> (DMat, DMat) {
    let n = robot.dof();
    let kin = Kin::new(robot, q, qd);
    let a0 = SV::new(crate::spatial::V3::ZERO, -robot.gravity);

    // Nominal forward quantities (v from kin, a and f recomputed here).
    let mut a: Vec<SV> = Vec::with_capacity(n);
    let mut f: Vec<SV> = Vec::with_capacity(n);
    for i in 0..n {
        let link = &robot.links[i];
        let s = kin.s[i];
        let vi = kin.v[i];
        let ap = match link.parent {
            Some(p) => a[p],
            None => a0,
        };
        let ai = kin.xup[i].apply(&ap) + s.scale(qdd[i]) + vi.crm(&s.scale(qd[i]));
        let fi = link.inertia.apply(&ai) + vi.crf(&link.inertia.apply(&vi));
        a.push(ai);
        f.push(fi);
    }
    // Accumulate the nominal backward pass: f[i] becomes the total force
    // transmitted through joint i (link force + subtree contributions).
    // The q-derivative of the backward recursion differentiates X_iᵀ
    // applied to THIS accumulated force.
    for i in (0..n).rev() {
        if let Some(p) = robot.links[i].parent {
            let fp = kin.xup[i].inv_apply_force(&f[i]);
            f[p] = f[p] + fp;
        }
    }

    let mut dtau_dq = DMat::zeros(n, n);
    let mut dtau_dqd = DMat::zeros(n, n);

    // Sparsity: perturbing coordinate j only disturbs the tangent state
    // of subtree(j); outside it the forward tangents are identically
    // zero, and the backward tangent force only flows from j up the
    // ancestor path. Restricting both sweeps accordingly turns the dense
    // O(N²·c) tangent pass into O(Σ|subtree| + Σdepth) — the same
    // sparsity the accelerator's Df/Db units exploit (EXPERIMENTS §Perf).
    let subtrees: Vec<Vec<usize>> = (0..n).map(|j| robot.subtree(j)).collect();

    let mut dv: Vec<SV> = vec![SV::ZERO; n];
    let mut da: Vec<SV> = vec![SV::ZERO; n];
    let mut dfacc: Vec<SV> = vec![SV::ZERO; n];

    // One tangent sweep per differentiation direction.
    for j in 0..n {
        let members = &subtrees[j];

        // ---- ∂/∂q_j ----
        {
            for &i in members {
                let link = &robot.links[i];
                let s = kin.s[i];
                let in_sub = |k: usize| members.binary_search(&k).is_ok();
                let (dvp, dap) = match link.parent {
                    Some(p) if in_sub(p) => (dv[p], da[p]),
                    _ => (SV::ZERO, SV::ZERO),
                };
                let mut dvi = kin.xup[i].apply(&dvp);
                let mut dai = kin.xup[i].apply(&dap);
                if i == j {
                    // d(X_i y)/dq_i = −S_i × (X_i y) from jcalc.
                    let vp_term = match link.parent {
                        Some(p) => kin.xup[i].apply(&kin.v[p]),
                        None => SV::ZERO,
                    };
                    let ap = match link.parent {
                        Some(p) => a[p],
                        None => a0,
                    };
                    dvi = dvi - s.crm(&vp_term);
                    dai = dai - s.crm(&kin.xup[i].apply(&ap));
                }
                dai = dai + dvi.crm(&s.scale(qd[i]));
                let iv = link.inertia.apply(&kin.v[i]);
                dfacc[i] = link.inertia.apply(&dai)
                    + dvi.crf(&iv)
                    + kin.v[i].crf(&link.inertia.apply(&dvi));
                dv[i] = dvi;
                da[i] = dai;
            }
            // Backward within the subtree (descending order).
            for &i in members.iter().rev() {
                dtau_dq[(i, j)] = kin.s[i].dot(&dfacc[i]);
                if let Some(p) = robot.links[i].parent {
                    let mut dfp = kin.xup[i].inv_apply_force(&dfacc[i]);
                    if i == j {
                        // d(X_jᵀ f_j)/dq_j = X_treeᵀ (S ×* (XJᵀ f_j)),
                        // applied to the ACCUMULATED nominal force.
                        let fj = kin.xj[i].inv_apply_force(&f[i]);
                        dfp = dfp
                            + robot.links[i].x_tree.inv_apply_force(&kin.s[i].crf(&fj));
                    }
                    if members.binary_search(&p).is_ok() {
                        dfacc[p] = dfacc[p] + dfp;
                    } else {
                        // Left the subtree: walk the remaining ancestor
                        // path, projecting as we go.
                        let mut carried = dfp;
                        let mut cur = p;
                        loop {
                            dtau_dq[(cur, j)] += kin.s[cur].dot(&carried);
                            match robot.links[cur].parent {
                                Some(pp) => {
                                    carried = kin.xup[cur].inv_apply_force(&carried);
                                    cur = pp;
                                }
                                None => break,
                            }
                        }
                    }
                }
            }
            for &i in members {
                dv[i] = SV::ZERO;
                da[i] = SV::ZERO;
                dfacc[i] = SV::ZERO;
            }
        }

        // ---- ∂/∂q̇_j ----
        {
            for &i in members {
                let link = &robot.links[i];
                let s = kin.s[i];
                let in_sub = |k: usize| members.binary_search(&k).is_ok();
                let (dvp, dap) = match link.parent {
                    Some(p) if in_sub(p) => (dv[p], da[p]),
                    _ => (SV::ZERO, SV::ZERO),
                };
                let mut dvi = kin.xup[i].apply(&dvp);
                if i == j {
                    dvi = dvi + s;
                }
                let mut dai = kin.xup[i].apply(&dap) + dvi.crm(&s.scale(qd[i]));
                if i == j {
                    dai = dai + kin.v[i].crm(&s);
                }
                let iv = link.inertia.apply(&kin.v[i]);
                dfacc[i] = link.inertia.apply(&dai)
                    + dvi.crf(&iv)
                    + kin.v[i].crf(&link.inertia.apply(&dvi));
                dv[i] = dvi;
                da[i] = dai;
            }
            for &i in members.iter().rev() {
                dtau_dqd[(i, j)] = kin.s[i].dot(&dfacc[i]);
                if let Some(p) = robot.links[i].parent {
                    let dfp = kin.xup[i].inv_apply_force(&dfacc[i]);
                    if members.binary_search(&p).is_ok() {
                        dfacc[p] = dfacc[p] + dfp;
                    } else {
                        let mut carried = dfp;
                        let mut cur = p;
                        loop {
                            dtau_dqd[(cur, j)] += kin.s[cur].dot(&carried);
                            match robot.links[cur].parent {
                                Some(pp) => {
                                    carried = kin.xup[cur].inv_apply_force(&carried);
                                    cur = pp;
                                }
                                None => break,
                            }
                        }
                    }
                }
            }
            for &i in members {
                dv[i] = SV::ZERO;
                da[i] = SV::ZERO;
                dfacc[i] = SV::ZERO;
            }
        }
    }
    (dtau_dq, dtau_dqd)
}

/// ΔFD: (∂q̈/∂q, ∂q̈/∂q̇, ∂q̈/∂τ = M⁻¹), via the paper's Eq. 2:
/// ∂q̈/∂x = −M⁻¹ ∂ID/∂x evaluated at q̈ = FD(q, q̇, τ).
pub fn fd_derivatives(
    robot: &Robot,
    q: &[f64],
    qd: &[f64],
    tau: &[f64],
) -> (DMat, DMat, DMat) {
    let qdd = super::fd::fd(robot, q, qd, tau, None);
    let (did_dq, did_dqd) = rnea_derivatives(robot, q, qd, &qdd);
    let mi = super::minv::minv(robot, q);
    let dq = mi.matmul(&did_dq).scale(-1.0);
    let dqd = mi.matmul(&did_dqd).scale(-1.0);
    (dq, dqd, mi)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dynamics::fd::fd;
    use crate::dynamics::rnea::rnea;
    use crate::model::{builtin, State};
    use crate::util::rng::Rng;

    fn fd_check(
        robot: &Robot,
        eval: impl Fn(&[f64], &[f64]) -> Vec<f64>,
        q: &[f64],
        qd: &[f64],
        analytic_dq: &DMat,
        analytic_dqd: &DMat,
        tol: f64,
        what: &str,
    ) {
        let n = robot.dof();
        let h = 1e-6;
        for j in 0..n {
            let mut qp = q.to_vec();
            let mut qm = q.to_vec();
            qp[j] += h;
            qm[j] -= h;
            let tp = eval(&qp, qd);
            let tm = eval(&qm, qd);
            for i in 0..n {
                let fdiff = (tp[i] - tm[i]) / (2.0 * h);
                let ana = analytic_dq[(i, j)];
                assert!(
                    (fdiff - ana).abs() < tol * (1.0 + fdiff.abs()),
                    "{what} ∂/∂q: ({i},{j}): fd {fdiff} vs analytic {ana}"
                );
            }
            let mut vp = qd.to_vec();
            let mut vm = qd.to_vec();
            vp[j] += h;
            vm[j] -= h;
            let tp = eval(q, &vp);
            let tm = eval(q, &vm);
            for i in 0..n {
                let fdiff = (tp[i] - tm[i]) / (2.0 * h);
                let ana = analytic_dqd[(i, j)];
                assert!(
                    (fdiff - ana).abs() < tol * (1.0 + fdiff.abs()),
                    "{what} ∂/∂q̇: ({i},{j}): fd {fdiff} vs analytic {ana}"
                );
            }
        }
    }

    #[test]
    fn rnea_derivatives_match_finite_differences() {
        for robot in [builtin::iiwa(), builtin::hyq(), builtin::baxter()] {
            let mut rng = Rng::new(400);
            let s = State::random(&robot, &mut rng);
            let n = robot.dof();
            let qdd = rng.vec_range(n, -2.0, 2.0);
            let (dq, dqd) = rnea_derivatives(&robot, &s.q, &s.qd, &qdd);
            let r = robot.clone();
            let qdd2 = qdd.clone();
            fd_check(
                &robot,
                move |q, qd| rnea(&r, q, qd, &qdd2, None),
                &s.q,
                &s.qd,
                &dq,
                &dqd,
                2e-4,
                &robot.name,
            );
        }
    }

    #[test]
    fn rnea_derivatives_atlas() {
        let robot = builtin::atlas();
        let mut rng = Rng::new(401);
        let s = State::random(&robot, &mut rng);
        let n = robot.dof();
        let qdd = rng.vec_range(n, -1.0, 1.0);
        let (dq, dqd) = rnea_derivatives(&robot, &s.q, &s.qd, &qdd);
        let r = robot.clone();
        fd_check(
            &robot,
            move |q, qd| rnea(&r, q, qd, &qdd, None),
            &s.q,
            &s.qd,
            &dq,
            &dqd,
            5e-4,
            "atlas",
        );
    }

    #[test]
    fn fd_derivatives_match_finite_differences() {
        let robot = builtin::iiwa();
        let mut rng = Rng::new(402);
        let s = State::random(&robot, &mut rng);
        let n = robot.dof();
        let tau = rng.vec_range(n, -10.0, 10.0);
        let (dq, dqd, dtau) = fd_derivatives(&robot, &s.q, &s.qd, &tau);
        let r = robot.clone();
        let t2 = tau.clone();
        fd_check(
            &robot,
            move |q, qd| fd(&r, q, qd, &t2, None),
            &s.q,
            &s.qd,
            &dq,
            &dqd,
            5e-4,
            "iiwa ΔFD",
        );
        // ∂q̈/∂τ = M⁻¹ exactly.
        let h = 1e-6;
        for j in 0..n {
            let mut tp = tau.clone();
            let mut tm = tau.clone();
            tp[j] += h;
            tm[j] -= h;
            let qp = fd(&robot, &s.q, &s.qd, &tp, None);
            let qm = fd(&robot, &s.q, &s.qd, &tm, None);
            for i in 0..n {
                let fdiff = (qp[i] - qm[i]) / (2.0 * h);
                assert!(
                    (fdiff - dtau[(i, j)]).abs() < 1e-4 * (1.0 + fdiff.abs()),
                    "∂q̈/∂τ ({i},{j})"
                );
            }
        }
    }
}
