//! Per-state kinematic cache shared by the dynamics algorithms: joint
//! transforms, link spatial velocities, and the motion subspaces.

use crate::model::Robot;
use crate::spatial::{SV, Xform};

/// Everything the recursive algorithms need that depends only on (q, q̇).
#[derive(Debug, Clone)]
pub struct Kin {
    /// X_up[i]: parent(i) frame → link-i frame (XJ ∘ X_tree).
    pub xup: Vec<Xform>,
    /// Joint transform alone (XJ), needed by the q-derivative pass.
    pub xj: Vec<Xform>,
    /// Motion subspace S_i in link-i coordinates.
    pub s: Vec<SV>,
    /// Link spatial velocity v_i (body coordinates).
    pub v: Vec<SV>,
    /// Joint velocities the cache was built with.
    pub qd: Vec<f64>,
}

impl Kin {
    /// Preallocate an n-joint cache filled with identity/zero entries.
    /// Pair with [`Kin::recompute`] for the allocation-free hot path.
    pub fn empty(n: usize) -> Kin {
        Kin {
            xup: vec![Xform::identity(); n],
            xj: vec![Xform::identity(); n],
            s: vec![SV::ZERO; n],
            v: vec![SV::ZERO; n],
            qd: vec![0.0; n],
        }
    }

    /// Recompute transforms and velocities for state (q, q̇) in place —
    /// the `kin_into` kernel. No allocation: all buffers are overwritten.
    pub fn recompute(&mut self, robot: &Robot, q: &[f64], qd: &[f64]) {
        let n = robot.dof();
        assert_eq!(q.len(), n);
        assert_eq!(qd.len(), n);
        assert_eq!(self.v.len(), n, "workspace sized for a different robot");
        for i in 0..n {
            let link = &robot.links[i];
            let xji = link.joint.xform(q[i]);
            let x = xji.compose(&link.x_tree);
            let si = link.joint.motion_subspace();
            let vj = si.scale(qd[i]);
            let vi = match link.parent {
                Some(p) => {
                    let vp = self.v[p];
                    x.apply(&vp) + vj
                }
                None => vj,
            };
            self.xup[i] = x;
            self.xj[i] = xji;
            self.s[i] = si;
            self.v[i] = vi;
            self.qd[i] = qd[i];
        }
    }

    /// Compute transforms and velocities for state (q, q̇).
    /// Thin allocating wrapper over [`Kin::recompute`].
    pub fn new(robot: &Robot, q: &[f64], qd: &[f64]) -> Kin {
        let mut kin = Kin::empty(robot.dof());
        kin.recompute(robot, q, qd);
        kin
    }

    /// Position-only variant (velocities zero); used by CRBA/Minv.
    pub fn positions(robot: &Robot, q: &[f64]) -> Kin {
        let zeros = vec![0.0; robot.dof()];
        Kin::new(robot, q, &zeros)
    }

    /// Position-only in-place recompute (velocities zeroed).
    pub fn recompute_positions(&mut self, robot: &Robot, q: &[f64]) {
        let n = robot.dof();
        assert_eq!(q.len(), n);
        assert_eq!(self.v.len(), n, "workspace sized for a different robot");
        for i in 0..n {
            let link = &robot.links[i];
            let xji = link.joint.xform(q[i]);
            self.xup[i] = xji.compose(&link.x_tree);
            self.xj[i] = xji;
            self.s[i] = link.joint.motion_subspace();
            self.v[i] = SV::ZERO;
            self.qd[i] = 0.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::builtin;

    #[test]
    fn chain_velocity_accumulates() {
        let r = builtin::iiwa();
        let n = r.dof();
        let q = vec![0.0; n];
        let mut qd = vec![0.0; n];
        qd[0] = 1.0;
        let k = Kin::new(&r, &q, &qd);
        // With only joint 0 moving, every link sees nonzero velocity.
        for i in 0..n {
            assert!(k.v[i].norm() > 1e-9, "link {i} should move");
        }
        // The angular speed magnitude is preserved down the chain
        // (pure rotation transforms preserve the angular norm).
        for i in 0..n {
            assert!((k.v[i].ang.norm() - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn branch_isolation() {
        // Moving one HyQ leg leaves the other legs' links at rest.
        let r = builtin::hyq();
        let mut qd = vec![0.0; r.dof()];
        qd[0] = 1.0; // lf_haa
        let k = Kin::new(&r, &vec![0.0; r.dof()], &qd);
        for i in 0..r.dof() {
            let moving = i < 3; // lf leg occupies indices 0..3
            assert_eq!(k.v[i].norm() > 1e-9, moving, "link {i}");
        }
    }
}
