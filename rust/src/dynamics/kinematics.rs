//! Per-state kinematic cache shared by the dynamics algorithms: joint
//! transforms, link spatial velocities, and the motion subspaces.

use crate::model::Robot;
use crate::spatial::{SV, Xform};

/// Everything the recursive algorithms need that depends only on (q, q̇).
#[derive(Debug, Clone)]
pub struct Kin {
    /// X_up[i]: parent(i) frame → link-i frame (XJ ∘ X_tree).
    pub xup: Vec<Xform>,
    /// Joint transform alone (XJ), needed by the q-derivative pass.
    pub xj: Vec<Xform>,
    /// Motion subspace S_i in link-i coordinates.
    pub s: Vec<SV>,
    /// Link spatial velocity v_i (body coordinates).
    pub v: Vec<SV>,
    /// Joint velocities the cache was built with.
    pub qd: Vec<f64>,
}

impl Kin {
    /// Compute transforms and velocities for state (q, q̇).
    pub fn new(robot: &Robot, q: &[f64], qd: &[f64]) -> Kin {
        let n = robot.dof();
        assert_eq!(q.len(), n);
        assert_eq!(qd.len(), n);
        let mut xup = Vec::with_capacity(n);
        let mut xj = Vec::with_capacity(n);
        let mut s = Vec::with_capacity(n);
        let mut v: Vec<SV> = Vec::with_capacity(n);
        for i in 0..n {
            let link = &robot.links[i];
            let xji = link.joint.xform(q[i]);
            let x = xji.compose(&link.x_tree);
            let si = link.joint.motion_subspace();
            let vj = si.scale(qd[i]);
            let vi = match link.parent {
                Some(p) => x.apply(&v[p]) + vj,
                None => vj,
            };
            xup.push(x);
            xj.push(xji);
            s.push(si);
            v.push(vi);
        }
        Kin { xup, xj, s, v, qd: qd.to_vec() }
    }

    /// Position-only variant (velocities zero); used by CRBA/Minv.
    pub fn positions(robot: &Robot, q: &[f64]) -> Kin {
        let zeros = vec![0.0; robot.dof()];
        Kin::new(robot, q, &zeros)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::builtin;

    #[test]
    fn chain_velocity_accumulates() {
        let r = builtin::iiwa();
        let n = r.dof();
        let q = vec![0.0; n];
        let mut qd = vec![0.0; n];
        qd[0] = 1.0;
        let k = Kin::new(&r, &q, &qd);
        // With only joint 0 moving, every link sees nonzero velocity.
        for i in 0..n {
            assert!(k.v[i].norm() > 1e-9, "link {i} should move");
        }
        // The angular speed magnitude is preserved down the chain
        // (pure rotation transforms preserve the angular norm).
        for i in 0..n {
            assert!((k.v[i].ang.norm() - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn branch_isolation() {
        // Moving one HyQ leg leaves the other legs' links at rest.
        let r = builtin::hyq();
        let mut qd = vec![0.0; r.dof()];
        qd[0] = 1.0; // lf_haa
        let k = Kin::new(&r, &vec![0.0; r.dof()], &qd);
        for i in 0..r.dof() {
            let moving = i < 3; // lf leg occupies indices 0..3
            assert_eq!(k.v[i].norm() > 1e-9, moving, "link {i}");
        }
    }
}
