//! Recursive Newton–Euler Algorithm (RNEA): inverse dynamics
//! τ = ID(q, q̇, q̈, f_ext), Featherstone RBDA Table 5.1.
//!
//! This is the paper's ID function and the Uf/Ub pipeline content of the
//! RNEA module in the accelerator: a forward (base→tip) pass propagating
//! velocities/accelerations/forces, then a backward (tip→base) pass
//! projecting forces onto joint axes.

use super::kinematics::Kin;
use crate::model::Robot;
use crate::spatial::SV;

/// Inverse dynamics. `fext` (if given) holds one spatial force per link,
/// expressed in *link-local* coordinates (the convention the accelerator
/// uses: forces arrive pre-transformed with the task).
pub fn rnea(robot: &Robot, q: &[f64], qd: &[f64], qdd: &[f64], fext: Option<&[SV]>) -> Vec<f64> {
    let n = robot.dof();
    assert_eq!(qdd.len(), n);
    let kin = Kin::new(robot, q, qd);
    rnea_with_kin(robot, &kin, qdd, fext)
}

/// RNEA reusing a precomputed kinematic cache (hot path for derivatives).
/// Thin allocating wrapper over [`rnea_into`].
pub fn rnea_with_kin(robot: &Robot, kin: &Kin, qdd: &[f64], fext: Option<&[SV]>) -> Vec<f64> {
    let n = robot.dof();
    let mut a = vec![SV::ZERO; n];
    let mut f = vec![SV::ZERO; n];
    let mut tau = vec![0.0; n];
    rnea_core(robot, kin, Some(qdd), fext, &mut a, &mut f, &mut tau);
    tau
}

/// Allocation-free RNEA kernel: writes τ into `tau`, using caller-owned
/// scratch for link accelerations (`a`) and forces (`f`). All slices must
/// have length `robot.dof()`.
pub fn rnea_into(
    robot: &Robot,
    kin: &Kin,
    qdd: &[f64],
    fext: Option<&[SV]>,
    a: &mut [SV],
    f: &mut [SV],
    tau: &mut [f64],
) {
    rnea_core(robot, kin, Some(qdd), fext, a, f, tau);
}

/// Bias-force kernel: RNEA with q̈ = 0, without materializing a zero
/// vector. Writes C(q, q̇, f_ext) into `tau`.
pub fn bias_into(
    robot: &Robot,
    kin: &Kin,
    fext: Option<&[SV]>,
    a: &mut [SV],
    f: &mut [SV],
    tau: &mut [f64],
) {
    rnea_core(robot, kin, None, fext, a, f, tau);
}

/// Shared forward/backward sweep. `qdd = None` means q̈ ≡ 0 (the bias
/// pass), avoiding both the zero vector and the S·q̈ multiply-add.
fn rnea_core(
    robot: &Robot,
    kin: &Kin,
    qdd: Option<&[f64]>,
    fext: Option<&[SV]>,
    a: &mut [SV],
    f: &mut [SV],
    tau: &mut [f64],
) {
    let n = robot.dof();
    assert_eq!(a.len(), n);
    assert_eq!(f.len(), n);
    assert_eq!(tau.len(), n);
    if let Some(acc) = qdd {
        assert_eq!(acc.len(), n);
    }
    // a0 = -a_gravity: simulate gravity by accelerating the base upward.
    let a0 = SV::new(crate::spatial::V3::ZERO, -robot.gravity);

    for i in 0..n {
        let link = &robot.links[i];
        let si = kin.s[i];
        let vi = kin.v[i];
        let a_parent = match link.parent {
            Some(p) => a[p],
            None => a0,
        };
        let mut ai = kin.xup[i].apply(&a_parent) + vi.crm(&si.scale(kin.qd[i]));
        if let Some(acc) = qdd {
            ai = ai + si.scale(acc[i]);
        }
        let mut fi = link.inertia.apply(&ai) + vi.crf(&link.inertia.apply(&vi));
        if let Some(fe) = fext {
            fi = fi - fe[i];
        }
        a[i] = ai;
        f[i] = fi;
    }

    for i in (0..n).rev() {
        tau[i] = kin.s[i].dot(&f[i]);
        if let Some(p) = robot.links[i].parent {
            let fp = kin.xup[i].inv_apply_force(&f[i]);
            f[p] = f[p] + fp;
        }
    }
}

/// Generalized bias forces C(q, q̇, f_ext) = RNEA(q, q̇, 0, f_ext):
/// Coriolis + centrifugal + gravity − external.
pub fn bias_forces(robot: &Robot, q: &[f64], qd: &[f64], fext: Option<&[SV]>) -> Vec<f64> {
    let n = robot.dof();
    let kin = Kin::new(robot, q, qd);
    let mut a = vec![SV::ZERO; n];
    let mut f = vec![SV::ZERO; n];
    let mut tau = vec![0.0; n];
    rnea_core(robot, &kin, None, fext, &mut a, &mut f, &mut tau);
    tau
}

/// Gravity-only torques: RNEA(q, 0, 0).
pub fn gravity_torques(robot: &Robot, q: &[f64]) -> Vec<f64> {
    let n = robot.dof();
    rnea(robot, q, &vec![0.0; n], &vec![0.0; n], None)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{builtin, State};
    use crate::util::rng::Rng;

    #[test]
    fn static_chain_matches_gravity_load() {
        // A single vertical revolute joint about z under gravity along -z
        // carries no torque.
        let r = builtin::iiwa();
        let tau = gravity_torques(&r, &vec![0.0; r.dof()]);
        // Joint 0 axis is z, gravity is -z: torque 0 at home pose.
        assert!(tau[0].abs() < 1e-10, "tau0={}", tau[0]);
    }

    #[test]
    fn zero_gravity_zero_state_zero_torque() {
        let mut r = builtin::baxter();
        r.gravity = crate::spatial::V3::ZERO;
        let n = r.dof();
        let tau = rnea(&r, &vec![0.0; n], &vec![0.0; n], &vec![0.0; n], None);
        for t in tau {
            assert!(t.abs() < 1e-12);
        }
    }

    #[test]
    fn tau_linear_in_qdd() {
        // τ(q, qd, a1+a2) - τ(q, qd, a2) = τ(q, qd, a1) - τ(q, qd, 0)
        let r = builtin::hyq();
        let mut rng = Rng::new(77);
        let s = State::random(&r, &mut rng);
        let n = r.dof();
        let a1 = rng.vec_range(n, -3.0, 3.0);
        let a2 = rng.vec_range(n, -3.0, 3.0);
        let a12: Vec<f64> = a1.iter().zip(&a2).map(|(x, y)| x + y).collect();
        let t12 = rnea(&r, &s.q, &s.qd, &a12, None);
        let t2 = rnea(&r, &s.q, &s.qd, &a2, None);
        let t1 = rnea(&r, &s.q, &s.qd, &a1, None);
        let t0 = rnea(&r, &s.q, &s.qd, &vec![0.0; n], None);
        for i in 0..n {
            let lhs = t12[i] - t2[i];
            let rhs = t1[i] - t0[i];
            assert!((lhs - rhs).abs() < 1e-8, "joint {i}: {lhs} vs {rhs}");
        }
    }

    #[test]
    fn external_force_superposition() {
        let r = builtin::iiwa();
        let mut rng = Rng::new(78);
        let s = State::random(&r, &mut rng);
        let n = r.dof();
        let qdd = rng.vec_range(n, -2.0, 2.0);
        let fe: Vec<crate::spatial::SV> =
            (0..n).map(|_| crate::spatial::SV::from_slice(&rng.vec_range(6, -5.0, 5.0))).collect();
        let with = rnea(&r, &s.q, &s.qd, &qdd, Some(&fe));
        let without = rnea(&r, &s.q, &s.qd, &qdd, None);
        let zero_qdd_with = rnea(&r, &s.q, &s.qd, &vec![0.0; n], Some(&fe));
        let zero_qdd_without = rnea(&r, &s.q, &s.qd, &vec![0.0; n], None);
        // f_ext enters linearly and independently of qdd.
        for i in 0..n {
            let d1 = with[i] - without[i];
            let d2 = zero_qdd_with[i] - zero_qdd_without[i];
            assert!((d1 - d2).abs() < 1e-9, "joint {i}");
        }
    }

    /// Work-energy check: with zero gravity and no external forces, the
    /// instantaneous joint power q̇ᵀτ(q, q̇, q̈) equals the derivative of
    /// kinetic energy  d/dt(½ q̇ᵀM q̇) — verified by finite differences
    /// along an integrated trajectory snippet.
    #[test]
    fn power_balance() {
        let mut r = builtin::iiwa();
        r.gravity = crate::spatial::V3::ZERO;
        let n = r.dof();
        let mut rng = Rng::new(79);
        let s = State::random(&r, &mut rng);
        let qdd = rng.vec_range(n, -1.0, 1.0);
        let tau = rnea(&r, &s.q, &s.qd, &qdd, None);
        let power: f64 = s.qd.iter().zip(&tau).map(|(v, t)| v * t).sum();

        // Kinetic energy along the motion: T(t) with q(t) = q + t q̇,
        // q̇(t) = q̇ + t q̈. dT/dt at t=0 via central differences.
        let h = 1e-6;
        let energy = |t: f64| -> f64 {
            let qt: Vec<f64> = s.q.iter().zip(&s.qd).map(|(q, v)| q + t * v).collect();
            let vt: Vec<f64> = s.qd.iter().zip(&qdd).map(|(v, a)| v + t * a).collect();
            let kin = Kin::new(&r, &qt, &vt);
            (0..n).map(|i| r.links[i].inertia.kinetic_energy(&kin.v[i])).sum()
        };
        let dt_fd = (energy(h) - energy(-h)) / (2.0 * h);
        assert!(
            (power - dt_fd).abs() < 1e-4 * (1.0 + power.abs()),
            "power {power} vs dT/dt {dt_fd}"
        );
    }
}
