//! Analytical mass-matrix inverse (Carpentier's Minv algorithm) and the
//! paper's **division-deferring** reformulation (Algorithm 2, Fig. 6).
//!
//! Both compute M⁻¹(q) directly in O(N²) as a batched, zero-velocity
//! articulated-body sweep: a backward pass builds articulated inertias
//! `IA_i`, the per-joint scalars `D_i = SᵀIA S`, and a 6×N force
//! accumulator `F`; a forward pass propagates accelerations per unit
//! torque. `M⁻¹[i][j] = ∂q̈_i/∂τ_j`.
//!
//! **Original (Alg. 1)** uses `1/D_i` *inline* in the backward recurrence
//!
//! ```text
//!   IA_λ += Xᵀ (IA_i − U_i U_iᵀ / D_i) X        ← reciprocal on the
//!   F_λ  += Xᵀ (F_i + U_i u_i / D_i)              longest latency path
//! ```
//!
//! **Division-deferring (Alg. 2)** multiplies both updates through by the
//! *holding factor* `D_i`, propagating the scaled numerators and a
//! transfer coefficient, so every reciprocal moves off the backward
//! recurrence and into a shared, fully-pipelined divider that runs in
//! parallel (`DividerQueue`); the forward pass then consumes `1/D_i`:
//!
//! ```text
//!   N_i  = D_i·IA_i − U_i U_iᵀ            (extra scalar·matrix MACs)
//!   G_i  = D_i·F_i  + U_i u_i
//!   IA_λ += (Xᵀ N_i X) · inv_i           inv_i fetched from the divider,
//!   F_λ  += (Xᵀ G_i)  · inv_i            computed concurrently with MACs
//! ```

use super::kinematics::Kin;
use crate::model::Robot;
use crate::spatial::mat6::{matvec6, mul6, outer6, scale6, sub6, t6, M6};
use crate::spatial::{DMat, SV};

/// Shared-divider model: requests are enqueued during the backward pass
/// and results consumed later, mirroring the staggered schedule of
/// Fig. 6(b). Kept as an explicit structure so the accelerator cycle
/// model (and its tests) can replay the schedule.
#[derive(Debug, Default, Clone)]
pub struct DividerQueue {
    /// (joint id, dividend enqueued during backward pass).
    pub requests: Vec<(usize, f64)>,
}

impl DividerQueue {
    pub fn push(&mut self, joint: usize, d: f64) {
        self.requests.push((joint, d));
    }

    /// Execute all divisions "in parallel" (one pipelined unit in HW).
    pub fn resolve(&self) -> Vec<(usize, f64)> {
        self.requests.iter().map(|&(j, d)| (j, 1.0 / d)).collect()
    }
}

/// Original analytical Minv (reciprocals inline, Algorithm 1).
pub fn minv(robot: &Robot, q: &[f64]) -> DMat {
    let kin = Kin::positions(robot, q);
    minv_with_kin(robot, &kin)
}

pub fn minv_with_kin(robot: &Robot, kin: &Kin) -> DMat {
    let n = robot.dof();
    let mut ia: Vec<M6> = (0..n).map(|i| robot.links[i].inertia.to_mat6()).collect();
    let mut u: Vec<SV> = vec![SV::ZERO; n];
    let mut dinv = vec![0.0; n];
    // F columns are restricted to each joint's subtree (the accumulator
    // F_i[:, j] is nonzero only for j ∈ subtree(i)), and the forward
    // acceleration responses to each joint's base-branch: M(q) of a
    // fixed-base tree is block-diagonal per base branch, hence so is
    // M⁻¹. Exploiting both cuts the hot path ~2–3× on high-DOF robots
    // (EXPERIMENTS.md §Perf).
    let (sub, br) = topology_masks(robot);
    let mut f: Vec<Vec<SV>> = vec![vec![SV::ZERO; n]; n];
    let mut minv = DMat::zeros(n, n);

    // -------- backward pass (tip → base) --------
    for i in (0..n).rev() {
        let s = kin.s[i];
        let ui = matvec6(&ia[i], &s);
        let di = s.dot(&ui);
        let di_inv = 1.0 / di; // ← inline reciprocal (longest path)
        u[i] = ui;
        dinv[i] = di_inv;

        // u row: unit torque at i minus what the subtree already carries.
        minv[(i, i)] += di_inv;
        for j in 0..n {
            if !sub[i * n + j] {
                continue;
            }
            let sf = s.dot(&f[i][j]);
            if sf != 0.0 {
                minv[(i, j)] -= di_inv * sf;
            }
        }

        if let Some(p) = robot.links[i].parent {
            // IA_λ += Xᵀ (IA − U Uᵀ/D) X
            let uut = outer6(&ui, &ui);
            let ia_art = sub6(&ia[i], &scale6(&uut, di_inv));
            let xm = kin.xup[i].to_mat6();
            let contrib = mul6(&t6(&xm), &mul6(&ia_art, &xm));
            for r in 0..6 {
                for c in 0..6 {
                    ia[p][r][c] += contrib[r][c];
                }
            }
            // F_λ += Xᵀ (F_i + U_i · minv_row_i) — subtree columns only.
            for j in 0..n {
                if !sub[i * n + j] {
                    continue;
                }
                let fij = f[i][j] + ui.scale(minv[(i, j)]);
                f[p][j] = f[p][j] + kin.xup[i].inv_apply_force(&fij);
            }
        }
    }

    // -------- forward pass (base → tip) --------
    // A[j] per link: spatial acceleration response per unit τ_j; only
    // columns in link i's base branch can be nonzero.
    let mut a: Vec<Vec<SV>> = vec![vec![SV::ZERO; n]; n];
    for i in 0..n {
        let s = kin.s[i];
        match robot.links[i].parent {
            None => {
                for j in 0..n {
                    if br[i * n + j] {
                        a[i][j] = s.scale(minv[(i, j)]);
                    }
                }
            }
            Some(p) => {
                for j in 0..n {
                    if !br[i * n + j] {
                        continue;
                    }
                    let xa = kin.xup[i].apply(&a[p][j]);
                    // q̈ correction: −(Uᵀ X a_λ)/D
                    let corr = dinv[i] * u[i].dot(&xa);
                    if corr != 0.0 {
                        minv[(i, j)] -= corr;
                    }
                    a[i][j] = xa + s.scale(minv[(i, j)]);
                }
            }
        }
    }
    minv
}

/// Flat topology masks, built with two allocations (per-call cost is
/// negligible even for 7-DOF arms — see EXPERIMENTS.md §Perf for the
/// failed Vec<Vec<usize>> variant):
/// `sub[i*n+j]` — j ∈ subtree(i);
/// `br[i*n+j]`  — i and j share a base branch (M⁻¹ block support).
fn topology_masks(robot: &Robot) -> (Vec<bool>, Vec<bool>) {
    let n = robot.dof();
    let mut sub = vec![false; n * n];
    let mut root = vec![0usize; n];
    for i in 0..n {
        sub[i * n + i] = true;
        root[i] = match robot.links[i].parent {
            Some(p) => root[p],
            None => i,
        };
    }
    // j descends from i iff i's flag is set along j's ancestor chain;
    // fill by propagating each j up once (paths are short).
    for j in 0..n {
        let mut cur = robot.links[j].parent;
        while let Some(p) = cur {
            sub[p * n + j] = true;
            cur = robot.links[p].parent;
        }
    }
    let mut br = vec![false; n * n];
    for i in 0..n {
        for j in 0..n {
            br[i * n + j] = root[i] == root[j];
        }
    }
    (sub, br)
}

/// Division-deferring Minv (Algorithm 2 + Fig. 6(c) architecture).
/// Returns the same matrix as [`minv`] (verified to f64 precision) while
/// keeping every reciprocal off the backward recurrence: reciprocals are
/// enqueued on a [`DividerQueue`] and consumed one stage later, exactly
/// as the shared pipelined divider does in hardware.
pub fn minv_dd(robot: &Robot, q: &[f64]) -> DMat {
    minv_dd_traced(robot, q).0
}

/// As [`minv_dd`] but also returns the divider request trace (used by the
/// accel model to validate the staggered divider schedule).
pub fn minv_dd_traced(robot: &Robot, q: &[f64]) -> (DMat, DividerQueue) {
    let kin = Kin::positions(robot, q);
    let n = robot.dof();
    let mut ia: Vec<M6> = (0..n).map(|i| robot.links[i].inertia.to_mat6()).collect();
    let mut u: Vec<SV> = vec![SV::ZERO; n];
    let mut queue = DividerQueue::default();

    // Stage Mb (backward): NO reciprocal anywhere in this loop. The
    // scaled numerators N_i, G_i are formed with the extra multiplies the
    // paper highlights (purple box), and the division result needed by
    // the *parent* stage is modeled as arriving from the shared divider
    // before the parent's accumulate executes (it runs concurrently with
    // the Xᵀ·X MAC work).
    //
    // row[i][j] accumulates Sᵀ F terms in *scaled* form; we keep the
    // per-joint scale explicit via the holding factor: each child hands
    // the parent (N_i, G_i, D_i) and the parent applies inv(D_i) fetched
    // from the divider output port.
    let (sub, br) = topology_masks(robot);
    let mut f: Vec<Vec<SV>> = vec![vec![SV::ZERO; n]; n];
    let mut raw_row: Vec<Vec<f64>> = vec![vec![0.0; n]; n]; // D_i·minv_row_i (deferred form)

    // Backward sweep. The divider queue mirrors Fig. 6(b): requests are
    // staggered by joint so one fully-pipelined divider serves all Mb
    // units; `resolve()` happens conceptually in parallel, we simply may
    // not use 1/D_i *within* joint i's own stage.
    for i in (0..n).rev() {
        let s = kin.s[i];
        let ui = matvec6(&ia[i], &s);
        let di = s.dot(&ui);
        u[i] = ui;
        queue.push(i, di);

        // Deferred row update: raw_row_i = e_i − Sᵀ F_i. The original
        // algorithm divides this row by D_i here; deferring leaves the
        // row unscaled and the 1/D_i lands after the shared divider.
        raw_row[i][i] += 1.0;
        for j in 0..n {
            if !sub[i * n + j] {
                continue;
            }
            let sf = s.dot(&f[i][j]);
            if sf != 0.0 {
                raw_row[i][j] -= sf;
            }
        }

        if let Some(p) = robot.links[i].parent {
            // N_i = D_i·IA_i − U U ᵀ  (scalar·matrix + rank-1: extra MACs)
            let uut = outer6(&ui, &ui);
            let ni = sub6(&scale6(&ia[i], di), &uut);
            let xm = kin.xup[i].to_mat6();
            let contrib = mul6(&t6(&xm), &mul6(&ni, &xm));
            // Parent stage consumes inv_i from the divider (concurrent):
            let inv_i = 1.0 / di; // value identical; latency modeled in accel
            for r in 0..6 {
                for c in 0..6 {
                    ia[p][r][c] += contrib[r][c] * inv_i;
                }
            }
            // G_i = D_i·F_i + U_i·raw_row_i ; F_λ += Xᵀ G_i · inv_i
            for j in 0..n {
                if !sub[i * n + j] {
                    continue;
                }
                let gij = f[i][j].scale(di) + ui.scale(raw_row[i][j]);
                f[p][j] = f[p][j] + kin.xup[i].inv_apply_force(&gij).scale(inv_i);
            }
        }
    }

    // Shared divider resolves all reciprocals (one pipelined unit).
    let mut dinv = vec![0.0; n];
    for (j, inv) in queue.resolve() {
        dinv[j] = inv;
    }

    // Forward pass (Mf units): consume divider outputs.
    let mut minv = DMat::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            minv[(i, j)] = raw_row[i][j] * dinv[i];
        }
    }
    let mut a: Vec<Vec<SV>> = vec![vec![SV::ZERO; n]; n];
    for i in 0..n {
        let s = kin.s[i];
        match robot.links[i].parent {
            None => {
                for j in 0..n {
                    if br[i * n + j] {
                        a[i][j] = s.scale(minv[(i, j)]);
                    }
                }
            }
            Some(p) => {
                for j in 0..n {
                    if !br[i * n + j] {
                        continue;
                    }
                    let xa = kin.xup[i].apply(&a[p][j]);
                    let corr = dinv[i] * u[i].dot(&xa);
                    if corr != 0.0 {
                        minv[(i, j)] -= corr;
                    }
                    a[i][j] = xa + s.scale(minv[(i, j)]);
                }
            }
        }
    }
    (minv, queue)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dynamics::crba::crba;
    use crate::model::{builtin, State};
    use crate::util::rng::Rng;

    #[test]
    fn minv_times_m_is_identity() {
        for robot in [builtin::iiwa(), builtin::hyq(), builtin::atlas(), builtin::baxter()] {
            let mut rng = Rng::new(200);
            for _ in 0..3 {
                let s = State::random(&robot, &mut rng);
                let m = crba(&robot, &s.q);
                let mi = minv(&robot, &s.q);
                let prod = mi.matmul(&m);
                let err = prod.sub(&DMat::identity(robot.dof())).max_abs();
                assert!(err < 1e-8, "{}: |M⁻¹M − I| = {err}", robot.name);
            }
        }
    }

    #[test]
    fn division_deferring_matches_original() {
        for robot in [builtin::iiwa(), builtin::hyq(), builtin::atlas(), builtin::baxter()] {
            let mut rng = Rng::new(201);
            for _ in 0..3 {
                let s = State::random(&robot, &mut rng);
                let a = minv(&robot, &s.q);
                let b = minv_dd(&robot, &s.q);
                let err = a.sub(&b).max_abs();
                assert!(err < 1e-9, "{}: |minv − minv_dd| = {err}", robot.name);
            }
        }
    }

    #[test]
    fn divider_queue_one_request_per_joint() {
        let robot = builtin::atlas();
        let mut rng = Rng::new(202);
        let s = State::random(&robot, &mut rng);
        let (_, q) = minv_dd_traced(&robot, &s.q);
        assert_eq!(q.requests.len(), robot.dof());
        // Requests arrive tip→base (staggered schedule) and all dividends
        // are positive (M SPD ⇒ D_i > 0).
        for (j, (joint, d)) in q.requests.iter().enumerate() {
            assert_eq!(*joint, robot.dof() - 1 - j);
            assert!(*d > 0.0, "D_{joint} = {d} must be positive");
        }
    }

    #[test]
    fn minv_symmetric() {
        let robot = builtin::iiwa();
        let mut rng = Rng::new(203);
        let s = State::random(&robot, &mut rng);
        let mi = minv(&robot, &s.q);
        let err = mi.sub(&mi.t()).max_abs();
        assert!(err < 1e-9, "M⁻¹ should be symmetric, err={err}");
    }

    #[test]
    fn matches_dense_lu_inverse() {
        let robot = builtin::baxter();
        let mut rng = Rng::new(204);
        let s = State::random(&robot, &mut rng);
        let dense = crba(&robot, &s.q).inverse().unwrap();
        let mi = minv(&robot, &s.q);
        let err = dense.sub(&mi).max_abs();
        assert!(err < 1e-7, "analytical vs LU inverse: {err}");
    }
}
