//! Analytical mass-matrix inverse (Carpentier's Minv algorithm) and the
//! paper's **division-deferring** reformulation (Algorithm 2, Fig. 6).
//!
//! Both compute M⁻¹(q) directly in O(N²) as a batched, zero-velocity
//! articulated-body sweep: a backward pass builds articulated inertias
//! `IA_i`, the per-joint scalars `D_i = SᵀIA S`, and a 6×N force
//! accumulator `F`; a forward pass propagates accelerations per unit
//! torque. `M⁻¹[i][j] = ∂q̈_i/∂τ_j`.
//!
//! **Original (Alg. 1)** uses `1/D_i` *inline* in the backward recurrence
//!
//! ```text
//!   IA_λ += Xᵀ (IA_i − U_i U_iᵀ / D_i) X        ← reciprocal on the
//!   F_λ  += Xᵀ (F_i + U_i u_i / D_i)              longest latency path
//! ```
//!
//! **Division-deferring (Alg. 2)** multiplies both updates through by the
//! *holding factor* `D_i`, propagating the scaled numerators and a
//! transfer coefficient, so every reciprocal moves off the backward
//! recurrence and into a shared, fully-pipelined divider that runs in
//! parallel (`DividerQueue`); the forward pass then consumes `1/D_i`:
//!
//! ```text
//!   N_i  = D_i·IA_i − U_i U_iᵀ            (extra scalar·matrix MACs)
//!   G_i  = D_i·F_i  + U_i u_i
//!   IA_λ += (Xᵀ N_i X) · inv_i           inv_i fetched from the divider,
//!   F_λ  += (Xᵀ G_i)  · inv_i            computed concurrently with MACs
//! ```

use super::kinematics::Kin;
use crate::model::Robot;
use crate::spatial::mat6::{matvec6, outer6, scale6, sub6, xtax, M6};
use crate::spatial::{DMat, SV};

/// Shared-divider model: requests are enqueued during the backward pass
/// and results consumed later, mirroring the staggered schedule of
/// Fig. 6(b). Kept as an explicit structure so the accelerator cycle
/// model (and its tests) can replay the schedule.
#[derive(Debug, Default, Clone)]
pub struct DividerQueue {
    /// (joint id, dividend enqueued during backward pass).
    pub requests: Vec<(usize, f64)>,
}

impl DividerQueue {
    pub fn push(&mut self, joint: usize, d: f64) {
        self.requests.push((joint, d));
    }

    /// Execute all divisions "in parallel" (one pipelined unit in HW).
    pub fn resolve(&self) -> Vec<(usize, f64)> {
        self.requests.iter().map(|&(j, d)| (j, 1.0 / d)).collect()
    }

    /// Allocation-free resolve: scatter 1/D_j into `dinv[j]`.
    pub fn resolve_into(&self, dinv: &mut [f64]) {
        for &(j, d) in &self.requests {
            dinv[j] = 1.0 / d;
        }
    }
}

/// Per-robot topology index lists, precomputed once (e.g. when building a
/// [`crate::dynamics::DynWorkspace`]) so the O(N²) mask construction and
/// the mask *scans* both leave the per-call hot path:
/// `subcols[i]` — columns j ∈ subtree(i), ascending;
/// `brcols[i]`  — columns j sharing i's base branch (M⁻¹ block support).
#[derive(Debug, Clone)]
pub struct Topology {
    pub subcols: Vec<Vec<usize>>,
    pub brcols: Vec<Vec<usize>>,
}

impl Topology {
    pub fn new(robot: &Robot) -> Topology {
        let n = robot.dof();
        let (sub, br) = topology_masks(robot);
        let subcols = (0..n)
            .map(|i| (0..n).filter(|&j| sub[i * n + j]).collect())
            .collect();
        let brcols = (0..n)
            .map(|i| (0..n).filter(|&j| br[i * n + j]).collect())
            .collect();
        Topology { subcols, brcols }
    }
}

/// Reusable buffers for the analytical-M⁻¹ sweeps: articulated inertias,
/// the 6×N force/acceleration accumulators (flattened n×n), and the
/// deferred row storage. Allocated once, reused per call.
#[derive(Debug, Clone)]
pub struct MinvScratch {
    pub ia: Vec<M6>,
    pub u: Vec<SV>,
    pub dinv: Vec<f64>,
    /// F accumulator, flattened: f[i*n + j].
    pub f: Vec<SV>,
    /// Acceleration responses, flattened: a[i*n + j].
    pub a: Vec<SV>,
    /// Deferred rows D_i·minv_row_i, flattened: row[i*n + j].
    pub row: Vec<f64>,
}

impl MinvScratch {
    pub fn new(n: usize) -> MinvScratch {
        MinvScratch {
            ia: vec![[0.0; 36]; n],
            u: vec![SV::ZERO; n],
            dinv: vec![0.0; n],
            f: vec![SV::ZERO; n * n],
            a: vec![SV::ZERO; n * n],
            row: vec![0.0; n * n],
        }
    }
}

/// Allocation-free division-deferring Minv kernel (Algorithm 2): writes
/// M⁻¹(q) into `out` using caller-owned scratch and the precomputed
/// topology. The divider trace is left in `queue` (cleared on entry),
/// exactly one request per joint, tip→base.
///
/// Numerically identical to [`minv_dd`]: the per-entry accumulation
/// order matches the mask-scan implementation it replaces.
pub fn minv_dd_into(
    robot: &Robot,
    kin: &Kin,
    topo: &Topology,
    scr: &mut MinvScratch,
    queue: &mut DividerQueue,
    out: &mut DMat,
) {
    let n = robot.dof();
    assert_eq!((out.rows, out.cols), (n, n));
    assert_eq!(scr.f.len(), n * n, "scratch sized for a different robot");
    queue.requests.clear();
    scr.f.fill(SV::ZERO);
    scr.a.fill(SV::ZERO);
    scr.row.fill(0.0);
    for i in 0..n {
        scr.ia[i] = robot.links[i].inertia.to_mat6();
    }

    // Backward sweep (stage Mb): scaled numerators only; reciprocals go
    // through the shared divider queue (see module docs).
    for i in (0..n).rev() {
        let s = kin.s[i];
        let ui = matvec6(&scr.ia[i], &s);
        let di = s.dot(&ui);
        scr.u[i] = ui;
        queue.push(i, di);

        scr.row[i * n + i] += 1.0;
        for &j in &topo.subcols[i] {
            let sf = s.dot(&scr.f[i * n + j]);
            if sf != 0.0 {
                scr.row[i * n + j] -= sf;
            }
        }

        if let Some(p) = robot.links[i].parent {
            // N_i = D_i·IA_i − U Uᵀ  (scalar·matrix + rank-1: extra MACs)
            let uut = outer6(&ui, &ui);
            let ni = sub6(&scale6(&scr.ia[i], di), &uut);
            let contrib = xtax(&kin.xup[i].to_mat6(), &ni);
            // Parent stage consumes inv_i from the divider (concurrent):
            let inv_i = 1.0 / di;
            for (dst, c) in scr.ia[p].iter_mut().zip(&contrib) {
                *dst += c * inv_i;
            }
            // G_i = D_i·F_i + U_i·row_i ; F_λ += Xᵀ G_i · inv_i
            for &j in &topo.subcols[i] {
                let gij = scr.f[i * n + j].scale(di) + ui.scale(scr.row[i * n + j]);
                let upd = kin.xup[i].inv_apply_force(&gij).scale(inv_i);
                scr.f[p * n + j] = scr.f[p * n + j] + upd;
            }
        }
    }

    // Shared divider resolves all reciprocals (one pipelined unit).
    queue.resolve_into(&mut scr.dinv);

    // Forward pass (Mf units): consume divider outputs.
    for i in 0..n {
        let di = scr.dinv[i];
        for j in 0..n {
            out[(i, j)] = scr.row[i * n + j] * di;
        }
    }
    for i in 0..n {
        let s = kin.s[i];
        match robot.links[i].parent {
            None => {
                for &j in &topo.brcols[i] {
                    scr.a[i * n + j] = s.scale(out[(i, j)]);
                }
            }
            Some(p) => {
                for &j in &topo.brcols[i] {
                    let xa = kin.xup[i].apply(&scr.a[p * n + j]);
                    let corr = scr.dinv[i] * scr.u[i].dot(&xa);
                    if corr != 0.0 {
                        out[(i, j)] -= corr;
                    }
                    scr.a[i * n + j] = xa + s.scale(out[(i, j)]);
                }
            }
        }
    }
}

/// Original analytical Minv (reciprocals inline, Algorithm 1).
pub fn minv(robot: &Robot, q: &[f64]) -> DMat {
    let kin = Kin::positions(robot, q);
    minv_with_kin(robot, &kin)
}

pub fn minv_with_kin(robot: &Robot, kin: &Kin) -> DMat {
    let n = robot.dof();
    let mut ia: Vec<M6> = (0..n).map(|i| robot.links[i].inertia.to_mat6()).collect();
    let mut u: Vec<SV> = vec![SV::ZERO; n];
    let mut dinv = vec![0.0; n];
    // F columns are restricted to each joint's subtree (the accumulator
    // F_i[:, j] is nonzero only for j ∈ subtree(i)), and the forward
    // acceleration responses to each joint's base-branch: M(q) of a
    // fixed-base tree is block-diagonal per base branch, hence so is
    // M⁻¹. Exploiting both cuts the hot path ~2–3× on high-DOF robots
    // (EXPERIMENTS.md §Perf).
    let (sub, br) = topology_masks(robot);
    let mut f: Vec<Vec<SV>> = vec![vec![SV::ZERO; n]; n];
    let mut minv = DMat::zeros(n, n);

    // -------- backward pass (tip → base) --------
    for i in (0..n).rev() {
        let s = kin.s[i];
        let ui = matvec6(&ia[i], &s);
        let di = s.dot(&ui);
        let di_inv = 1.0 / di; // ← inline reciprocal (longest path)
        u[i] = ui;
        dinv[i] = di_inv;

        // u row: unit torque at i minus what the subtree already carries.
        minv[(i, i)] += di_inv;
        for j in 0..n {
            if !sub[i * n + j] {
                continue;
            }
            let sf = s.dot(&f[i][j]);
            if sf != 0.0 {
                minv[(i, j)] -= di_inv * sf;
            }
        }

        if let Some(p) = robot.links[i].parent {
            // IA_λ += Xᵀ (IA − U Uᵀ/D) X
            let uut = outer6(&ui, &ui);
            let ia_art = sub6(&ia[i], &scale6(&uut, di_inv));
            let contrib = xtax(&kin.xup[i].to_mat6(), &ia_art);
            for (dst, c) in ia[p].iter_mut().zip(&contrib) {
                *dst += c;
            }
            // F_λ += Xᵀ (F_i + U_i · minv_row_i) — subtree columns only.
            for j in 0..n {
                if !sub[i * n + j] {
                    continue;
                }
                let fij = f[i][j] + ui.scale(minv[(i, j)]);
                f[p][j] = f[p][j] + kin.xup[i].inv_apply_force(&fij);
            }
        }
    }

    // -------- forward pass (base → tip) --------
    // A[j] per link: spatial acceleration response per unit τ_j; only
    // columns in link i's base branch can be nonzero.
    let mut a: Vec<Vec<SV>> = vec![vec![SV::ZERO; n]; n];
    for i in 0..n {
        let s = kin.s[i];
        match robot.links[i].parent {
            None => {
                for j in 0..n {
                    if br[i * n + j] {
                        a[i][j] = s.scale(minv[(i, j)]);
                    }
                }
            }
            Some(p) => {
                for j in 0..n {
                    if !br[i * n + j] {
                        continue;
                    }
                    let xa = kin.xup[i].apply(&a[p][j]);
                    // q̈ correction: −(Uᵀ X a_λ)/D
                    let corr = dinv[i] * u[i].dot(&xa);
                    if corr != 0.0 {
                        minv[(i, j)] -= corr;
                    }
                    a[i][j] = xa + s.scale(minv[(i, j)]);
                }
            }
        }
    }
    minv
}

/// Flat topology masks, built with two allocations (per-call cost is
/// negligible even for 7-DOF arms — see EXPERIMENTS.md §Perf for the
/// failed Vec<Vec<usize>> variant):
/// `sub[i*n+j]` — j ∈ subtree(i);
/// `br[i*n+j]`  — i and j share a base branch (M⁻¹ block support).
fn topology_masks(robot: &Robot) -> (Vec<bool>, Vec<bool>) {
    let n = robot.dof();
    let mut sub = vec![false; n * n];
    let mut root = vec![0usize; n];
    for i in 0..n {
        sub[i * n + i] = true;
        root[i] = match robot.links[i].parent {
            Some(p) => root[p],
            None => i,
        };
    }
    // j descends from i iff i's flag is set along j's ancestor chain;
    // fill by propagating each j up once (paths are short).
    for j in 0..n {
        let mut cur = robot.links[j].parent;
        while let Some(p) = cur {
            sub[p * n + j] = true;
            cur = robot.links[p].parent;
        }
    }
    let mut br = vec![false; n * n];
    for i in 0..n {
        for j in 0..n {
            br[i * n + j] = root[i] == root[j];
        }
    }
    (sub, br)
}

/// Division-deferring Minv (Algorithm 2 + Fig. 6(c) architecture).
/// Returns the same matrix as [`minv`] (verified to f64 precision) while
/// keeping every reciprocal off the backward recurrence: reciprocals are
/// enqueued on a [`DividerQueue`] and consumed one stage later, exactly
/// as the shared pipelined divider does in hardware.
pub fn minv_dd(robot: &Robot, q: &[f64]) -> DMat {
    minv_dd_traced(robot, q).0
}

/// As [`minv_dd`] but also returns the divider request trace (used by the
/// accel model to validate the staggered divider schedule). Thin
/// allocating wrapper over [`minv_dd_into`].
pub fn minv_dd_traced(robot: &Robot, q: &[f64]) -> (DMat, DividerQueue) {
    let n = robot.dof();
    let kin = Kin::positions(robot, q);
    let topo = Topology::new(robot);
    let mut scr = MinvScratch::new(n);
    let mut queue = DividerQueue::default();
    let mut out = DMat::zeros(n, n);
    minv_dd_into(robot, &kin, &topo, &mut scr, &mut queue, &mut out);
    (out, queue)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dynamics::crba::crba;
    use crate::model::{builtin, State};
    use crate::util::rng::Rng;

    #[test]
    fn minv_times_m_is_identity() {
        for robot in [builtin::iiwa(), builtin::hyq(), builtin::atlas(), builtin::baxter()] {
            let mut rng = Rng::new(200);
            for _ in 0..3 {
                let s = State::random(&robot, &mut rng);
                let m = crba(&robot, &s.q);
                let mi = minv(&robot, &s.q);
                let prod = mi.matmul(&m);
                let err = prod.sub(&DMat::identity(robot.dof())).max_abs();
                assert!(err < 1e-8, "{}: |M⁻¹M − I| = {err}", robot.name);
            }
        }
    }

    #[test]
    fn division_deferring_matches_original() {
        for robot in [builtin::iiwa(), builtin::hyq(), builtin::atlas(), builtin::baxter()] {
            let mut rng = Rng::new(201);
            for _ in 0..3 {
                let s = State::random(&robot, &mut rng);
                let a = minv(&robot, &s.q);
                let b = minv_dd(&robot, &s.q);
                let err = a.sub(&b).max_abs();
                assert!(err < 1e-9, "{}: |minv − minv_dd| = {err}", robot.name);
            }
        }
    }

    #[test]
    fn divider_queue_one_request_per_joint() {
        let robot = builtin::atlas();
        let mut rng = Rng::new(202);
        let s = State::random(&robot, &mut rng);
        let (_, q) = minv_dd_traced(&robot, &s.q);
        assert_eq!(q.requests.len(), robot.dof());
        // Requests arrive tip→base (staggered schedule) and all dividends
        // are positive (M SPD ⇒ D_i > 0).
        for (j, (joint, d)) in q.requests.iter().enumerate() {
            assert_eq!(*joint, robot.dof() - 1 - j);
            assert!(*d > 0.0, "D_{joint} = {d} must be positive");
        }
    }

    #[test]
    fn minv_symmetric() {
        let robot = builtin::iiwa();
        let mut rng = Rng::new(203);
        let s = State::random(&robot, &mut rng);
        let mi = minv(&robot, &s.q);
        let err = mi.sub(&mi.t()).max_abs();
        assert!(err < 1e-9, "M⁻¹ should be symmetric, err={err}");
    }

    #[test]
    fn matches_dense_lu_inverse() {
        let robot = builtin::baxter();
        let mut rng = Rng::new(204);
        let s = State::random(&robot, &mut rng);
        let dense = crba(&robot, &s.q).inverse().unwrap();
        let mi = minv(&robot, &s.q);
        let err = dense.sub(&mi).max_abs();
        assert!(err < 1e-7, "analytical vs LU inverse: {err}");
    }
}
