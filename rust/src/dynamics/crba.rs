//! Composite Rigid Body Algorithm: the joint-space mass matrix M(q)
//! (Featherstone RBDA Table 6.2).

use super::kinematics::Kin;
use crate::model::Robot;
use crate::spatial::mat6::{add6, matvec6, transform_inertia_to_parent, M6};
use crate::spatial::DMat;

/// Mass matrix M(q): symmetric positive definite, N×N.
pub fn crba(robot: &Robot, q: &[f64]) -> DMat {
    let kin = Kin::positions(robot, q);
    crba_with_kin(robot, &kin)
}

/// Thin allocating wrapper over [`crba_into`].
pub fn crba_with_kin(robot: &Robot, kin: &Kin) -> DMat {
    let n = robot.dof();
    let mut ic: Vec<M6> = vec![[0.0; 36]; n];
    let mut m = DMat::zeros(n, n);
    crba_into(robot, kin, &mut ic, &mut m);
    m
}

/// Allocation-free CRBA kernel: writes M(q) into `m` (N×N, zero-filled by
/// the kernel) using caller-owned composite-inertia scratch `ic`.
pub fn crba_into(robot: &Robot, kin: &Kin, ic: &mut [M6], m: &mut DMat) {
    let n = robot.dof();
    assert_eq!(ic.len(), n);
    assert_eq!((m.rows, m.cols), (n, n));
    // Composite inertias: start from the link's own inertia, accumulate
    // children tip→base.
    for i in 0..n {
        ic[i] = robot.links[i].inertia.to_mat6();
    }
    for i in (0..n).rev() {
        if let Some(p) = robot.links[i].parent {
            let contrib = transform_inertia_to_parent(&kin.xup[i], &ic[i]);
            ic[p] = add6(&ic[p], &contrib);
        }
    }

    m.d.fill(0.0);
    for i in (0..n).rev() {
        // F = IC_i S_i
        let mut f = matvec6(&ic[i], &kin.s[i]);
        m[(i, i)] = kin.s[i].dot(&f);
        let mut j = i;
        while let Some(p) = robot.links[j].parent {
            f = kin.xup[j].inv_apply_force(&f);
            j = p;
            let mij = f.dot(&kin.s[j]);
            m[(i, j)] = mij;
            m[(j, i)] = mij;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dynamics::rnea::rnea;
    use crate::model::{builtin, State};
    use crate::util::rng::Rng;

    /// The fundamental consistency check tying CRBA to RNEA:
    /// τ(q,q̇,q̈) − τ(q,q̇,0) = M(q)·q̈ for any q̈.
    #[test]
    fn mass_matrix_matches_rnea_difference() {
        for robot in [builtin::iiwa(), builtin::hyq(), builtin::atlas(), builtin::baxter()] {
            let mut rng = Rng::new(100);
            for _ in 0..4 {
                let s = State::random(&robot, &mut rng);
                let n = robot.dof();
                let qdd = rng.vec_range(n, -3.0, 3.0);
                let m = crba(&robot, &s.q);
                let t1 = rnea(&robot, &s.q, &s.qd, &qdd, None);
                let t0 = rnea(&robot, &s.q, &s.qd, &vec![0.0; n], None);
                let mq = m.matvec(&qdd);
                for i in 0..n {
                    let want = t1[i] - t0[i];
                    assert!(
                        (mq[i] - want).abs() < 1e-8 * (1.0 + want.abs()),
                        "{}: joint {i}: {} vs {}",
                        robot.name,
                        mq[i],
                        want
                    );
                }
            }
        }
    }

    #[test]
    fn symmetric_positive_definite() {
        for robot in [builtin::iiwa(), builtin::atlas()] {
            let mut rng = Rng::new(101);
            let s = State::random(&robot, &mut rng);
            let m = crba(&robot, &s.q);
            let n = robot.dof();
            for i in 0..n {
                for j in 0..n {
                    assert!(
                        (m[(i, j)] - m[(j, i)]).abs() < 1e-10,
                        "asymmetry at ({i},{j})"
                    );
                }
            }
            // PD via random quadratic forms.
            for _ in 0..16 {
                let x = rng.vec_range(n, -1.0, 1.0);
                let quad: f64 = m.matvec(&x).iter().zip(&x).map(|(a, b)| a * b).sum();
                assert!(quad > 0.0, "xᵀMx = {quad} not positive");
            }
        }
    }

    #[test]
    fn diagonal_dominance_of_leaf_joints() {
        // Leaf joints couple to nothing below them: their row support is
        // exactly their ancestor path. Check zero entries across branches.
        let robot = builtin::hyq();
        let mut rng = Rng::new(102);
        let s = State::random(&robot, &mut rng);
        let m = crba(&robot, &s.q);
        // joints 0..3 (lf leg) vs 3..6 (rf leg) are decoupled.
        for i in 0..3 {
            for j in 3..6 {
                assert!(m[(i, j)].abs() < 1e-12, "({i},{j}) = {}", m[(i, j)]);
            }
        }
    }
}
