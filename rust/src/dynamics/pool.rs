//! Persistent worker pool for batched dynamics evaluation.
//!
//! `eval_batch_par` used to spawn fresh threads per batch via
//! `std::thread::scope`; at serving rates the respawn cost (tens of µs
//! per thread, every batch) dwarfs small-robot kernel time. The pool
//! keeps a fixed set of workers alive for the process lifetime — the CPU
//! analogue of the accelerator's resident RTP pipelines, which exist
//! once and have tasks streamed through them.
//!
//! Work items are contiguous chunks of a shared task slice
//! (`Arc<Vec<BatchTask>>`), pulled from one injector queue; each worker
//! caches the `DynWorkspace` for the robot it saw last (compared by
//! `Arc` identity), so all chunks of one batch reuse a single workspace
//! per worker with no rebuild.

use super::batch::{eval_batch, BatchKernel, BatchOutput, BatchTask};
use super::workspace::DynWorkspace;
use crate::model::Robot;
use std::ops::Range;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, OnceLock};

/// One chunk of a batch, evaluated by whichever worker pulls it first.
struct PoolJob {
    robot: Arc<Robot>,
    kernel: BatchKernel,
    tasks: Arc<Vec<BatchTask>>,
    range: Range<usize>,
    /// (chunk ordinal, outputs or panic message) back to the caller.
    out: Sender<(usize, Result<Vec<BatchOutput>, String>)>,
    ordinal: usize,
}

/// A fixed set of persistent worker threads evaluating dynamics batches.
///
/// Workers exit when the pool (and every in-flight sender clone) is
/// dropped; the global instance lives for the process lifetime.
pub struct WorkerPool {
    injector: Mutex<Sender<PoolJob>>,
    threads: usize,
}

impl WorkerPool {
    /// Spawn a pool with `threads` persistent workers.
    pub fn new(threads: usize) -> WorkerPool {
        let threads = threads.max(1);
        let (tx, rx) = channel::<PoolJob>();
        let shared: Arc<Mutex<Receiver<PoolJob>>> = Arc::new(Mutex::new(rx));
        for _ in 0..threads {
            let q = Arc::clone(&shared);
            // Detached: each worker exits when every sender is gone.
            std::thread::spawn(move || worker(q));
        }
        WorkerPool { injector: Mutex::new(tx), threads }
    }

    /// Number of workers.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// The process-wide pool, sized to the machine's parallelism; created
    /// on first use.
    pub fn global() -> &'static WorkerPool {
        static POOL: OnceLock<WorkerPool> = OnceLock::new();
        POOL.get_or_init(|| {
            let threads =
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
            WorkerPool::new(threads)
        })
    }

    /// Evaluate `tasks` split into at most `max_chunks` contiguous chunks
    /// across the pool. Outputs are returned in task order; results are
    /// identical to [`eval_batch`] (same kernels, same workspace
    /// semantics).
    pub fn eval(
        &self,
        robot: &Robot,
        kernel: BatchKernel,
        tasks: &[BatchTask],
        max_chunks: usize,
    ) -> Vec<BatchOutput> {
        if tasks.is_empty() {
            return Vec::new();
        }
        let chunks = max_chunks.max(1).min(self.threads).min(tasks.len());
        if chunks <= 1 {
            return eval_batch(robot, kernel, tasks);
        }
        let robot = Arc::new(robot.clone());
        let tasks = Arc::new(tasks.to_vec());
        let chunk = tasks.len().div_ceil(chunks);
        let (tx, rx) = channel();
        let mut sent = 0usize;
        {
            let injector = self.injector.lock().unwrap();
            let mut start = 0;
            while start < tasks.len() {
                let end = (start + chunk).min(tasks.len());
                injector
                    .send(PoolJob {
                        robot: Arc::clone(&robot),
                        kernel,
                        tasks: Arc::clone(&tasks),
                        range: start..end,
                        out: tx.clone(),
                        ordinal: sent,
                    })
                    .expect("worker pool alive");
                sent += 1;
                start = end;
            }
        }
        drop(tx);
        let mut parts: Vec<Option<Vec<BatchOutput>>> = (0..sent).map(|_| None).collect();
        let mut panic_msg: Option<String> = None;
        for _ in 0..sent {
            let (ordinal, outs) = rx.recv().expect("pool worker answered");
            match outs {
                Ok(outs) => parts[ordinal] = Some(outs),
                Err(msg) => panic_msg = Some(msg),
            }
        }
        // Propagate task panics to the caller (as the old scoped-thread
        // implementation did via join) — the workers themselves survive.
        if let Some(msg) = panic_msg {
            panic!("worker pool task panicked: {msg}");
        }
        parts.into_iter().flat_map(|p| p.expect("every chunk answered")).collect()
    }
}

/// Whether a workspace built for `a` can serve `b`: every buffer in
/// [`DynWorkspace`] is sized from the DOF and the precomputed topology
/// column lists depend only on the parent structure, so equal parents ⇒
/// reusable workspace (inertias/limits don't matter — they are read from
/// the robot per task).
fn same_structure(a: &Robot, b: &Robot) -> bool {
    a.dof() == b.dof()
        && a.links.iter().zip(&b.links).all(|(x, y)| x.parent == y.parent)
}

/// Worker loop: pull chunks from the shared queue until the pool drops.
fn worker(queue: Arc<Mutex<Receiver<PoolJob>>>) {
    // Workspace cached by robot structure: `Arc::ptr_eq` is the fast
    // path (all chunks of one `eval` call share the robot Arc); the
    // structural check keeps the cache warm across successive batches
    // for the same robot, which is the serving steady state.
    let mut cached: Option<(Arc<Robot>, DynWorkspace)> = None;
    loop {
        let job = {
            let rx = queue.lock().unwrap();
            rx.recv()
        };
        let job = match job {
            Ok(j) => j,
            Err(_) => return, // pool dropped
        };
        let rebuild = match &cached {
            Some((robot, _)) => {
                !Arc::ptr_eq(robot, &job.robot) && !same_structure(robot, &job.robot)
            }
            None => true,
        };
        if rebuild {
            cached = Some((Arc::clone(&job.robot), DynWorkspace::new(&job.robot)));
        } else if let Some((robot, _)) = &mut cached {
            // Remember the newest Arc so the fast path keeps hitting.
            *robot = Arc::clone(&job.robot);
        }
        let (_, ws) = cached.as_mut().expect("workspace cached above");
        // Contain task panics (malformed tasks assert inside the
        // kernels): the caller gets the panic re-raised by `eval`, but
        // this worker — shared process-wide — stays alive for later
        // batches. AssertUnwindSafe is sound because the workspace is
        // dropped below on panic and kernels overwrite it per task
        // anyway.
        let outs = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            job.tasks[job.range.clone()]
                .iter()
                .map(|t| super::batch::eval_one(&job.robot, job.kernel, ws, t))
                .collect::<Vec<BatchOutput>>()
        }));
        let outs = match outs {
            Ok(outs) => Ok(outs),
            Err(p) => {
                cached = None; // discard possibly half-written workspace
                Err(panic_message(&p))
            }
        };
        // The caller may have gone away (it never does today — eval()
        // blocks); dropping the result is then harmless.
        let _ = job.out.send((job.ordinal, outs));
    }
}

/// Best-effort extraction of a panic payload's message.
fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "task panicked".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{builtin, State};
    use crate::util::rng::Rng;

    fn random_tasks(robot: &Robot, count: usize, seed: u64) -> Vec<BatchTask> {
        let n = robot.dof();
        let mut rng = Rng::new(seed);
        (0..count)
            .map(|_| {
                let s = State::random(robot, &mut rng);
                BatchTask { q: s.q, qd: s.qd, u: rng.vec_range(n, -8.0, 8.0) }
            })
            .collect()
    }

    #[test]
    fn pool_matches_single_thread_bitwise() {
        let pool = WorkerPool::new(3);
        let robot = builtin::iiwa();
        let tasks = random_tasks(&robot, 25, 900);
        let single = eval_batch(&robot, BatchKernel::Fd, &tasks);
        for chunks in [1, 2, 3, 16] {
            let par = pool.eval(&robot, BatchKernel::Fd, &tasks, chunks);
            assert_eq!(par.len(), single.len());
            for (a, b) in single.iter().zip(&par) {
                assert_eq!(a.as_vector().unwrap(), b.as_vector().unwrap());
            }
        }
    }

    #[test]
    fn pool_survives_robot_switches() {
        let pool = WorkerPool::new(2);
        for (robot, seed) in [(builtin::iiwa(), 901), (builtin::hyq(), 902), (builtin::iiwa(), 903)]
        {
            let tasks = random_tasks(&robot, 9, seed);
            let got = pool.eval(&robot, BatchKernel::Rnea, &tasks, 2);
            let want = eval_batch(&robot, BatchKernel::Rnea, &tasks);
            for (a, b) in want.iter().zip(&got) {
                assert_eq!(a.as_vector().unwrap(), b.as_vector().unwrap());
            }
        }
    }

    #[test]
    fn pool_contains_task_panics() {
        let pool = WorkerPool::new(2);
        let robot = builtin::iiwa();
        let mut tasks = random_tasks(&robot, 4, 905);
        tasks[2].q.truncate(2); // malformed: the kernel asserts on length
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.eval(&robot, BatchKernel::Rnea, &tasks, 2)
        }));
        assert!(res.is_err(), "malformed task must propagate a panic to the caller");
        // The workers survive: a healthy batch still evaluates afterwards.
        let good = random_tasks(&robot, 6, 906);
        assert_eq!(pool.eval(&robot, BatchKernel::Rnea, &good, 2).len(), 6);
    }

    #[test]
    fn global_pool_is_shared_and_alive() {
        let p1 = WorkerPool::global();
        let p2 = WorkerPool::global();
        assert!(std::ptr::eq(p1, p2));
        assert!(p1.threads() >= 1);
        let robot = builtin::iiwa();
        let tasks = random_tasks(&robot, 5, 904);
        assert_eq!(p1.eval(&robot, BatchKernel::Fd, &tasks, 4).len(), 5);
    }
}
