//! Persistent worker pool for batched dynamics evaluation.
//!
//! `eval_batch_par` used to spawn fresh threads per batch via
//! `std::thread::scope`; at serving rates the respawn cost (tens of µs
//! per thread, every batch) dwarfs small-robot kernel time. The pool
//! keeps a fixed set of workers alive for the process lifetime — the CPU
//! analogue of the accelerator's resident RTP pipelines, which exist
//! once and have tasks streamed through them.
//!
//! Two job shapes flow through the same injector queue:
//!
//! * **task chunks** — contiguous ranges of a shared `Arc<[BatchTask]>`
//!   slice (the f64 batch API);
//! * **flat chunks** ([`WorkerPool::eval_flat`]) — *borrowed* views into
//!   a caller's flat-f32 serving batch, written in place. Nothing is
//!   copied or allocated per batch: the coordinator's route worker hands
//!   the pool pointers into the operand arrays it already assembled and
//!   blocks until every chunk has answered, which is exactly what makes
//!   the borrow sound.
//!
//! The pool is **engine-generic**: every flat job carries a
//! [`PoolBackend`] descriptor — the f64 workspace kernels or the
//! quantized fixed-point kernels at a [`QFormat`] — so a registry's
//! quantized routes fan out across the same worker set as the f64 ones
//! ([`WorkerPool::eval_flat_quant`]), with the identical zero-copy
//! handoff and the identical bitwise-equals-serial guarantee (each
//! worker runs the exact decode→kernel→encode loop the serial engines
//! run).
//!
//! Each worker keeps a small MRU set of workspaces (plus flat-path
//! staging buffers), keyed by **(robot structure, backend)** — a
//! [`DynWorkspace`] per f64 structure, a [`QuantScratch`] per rounded
//! (structure, format), a [`QuantIntScratch`] per integer (structure,
//! format). Robots are matched by `Arc` identity with a structural
//! fallback; backends by exact equality, so cache entries never alias
//! across formats or lanes (the integer and rounded lanes at the SAME
//! format are distinct backends). Integer jobs additionally carry the
//! `Arc<ShiftSchedule>` their engine validated, so pooled
//! division-deferring sweeps consume identical holding shifts. All chunks of one batch reuse a
//! single workspace per worker with no rebuild, and a multi-robot
//! registry's parallel routes can interleave batches of different
//! robots and precisions (the serving steady state) without ever
//! rebuilding a workspace.

use super::batch::{eval_batch, BatchKernel, BatchOutput, BatchTask};
use super::memo::{FloatMemo, IntMemo};
use super::workspace::DynWorkspace;
use crate::model::Robot;
use crate::quant::scaling::ShiftSchedule;
use crate::quant::{QFormat, QuantIntScratch, QuantScratch};
use crate::spatial::DMat;
use std::ops::Range;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Total chunks evaluated by pool workers, process-wide.
static POOL_CHUNKS: AtomicU64 = AtomicU64::new(0);
/// Total worker-busy nanoseconds across the pool, process-wide.
static POOL_BUSY_NS: AtomicU64 = AtomicU64::new(0);

/// Process-wide worker-pool activity: `(chunks evaluated, busy µs)`.
/// Both counters are monotone and cover every pool instance; the busy
/// time is the summed wall-clock each worker spent inside chunk
/// evaluation, so `busy µs / elapsed µs` estimates effective pool
/// parallelism. Snapshotted into the observability metrics
/// (`pool_chunks_total` / `pool_busy_us_total` — see
/// [`crate::obs::ObsHub::snapshot`]).
pub fn pool_activity() -> (u64, u64) {
    (POOL_CHUNKS.load(Ordering::Relaxed), POOL_BUSY_NS.load(Ordering::Relaxed) / 1_000)
}

/// Numeric datapath a pool job runs — the pool's per-job engine
/// descriptor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PoolBackend {
    /// f64 workspace kernels (the default serving lane).
    F64,
    /// Emulated fixed point at this format (`quant::qrbd` kernels) —
    /// what [`crate::runtime::QuantEngine`] serves.
    Quant(QFormat),
    /// True-integer `i64` lane at this format (`quant::qint` kernels,
    /// division-deferring M⁻¹ under the job's shift schedule) — what
    /// [`crate::runtime::QIntEngine`] serves.
    Int(QFormat),
}

/// Borrowed view of one contiguous chunk of a flat-f32 batch: `rows`
/// input rows of length `n` starting at `q`/`qd`/`u`, outputs written in
/// place to `out` (`rows · out_per_task` values). The raw pointers stay
/// valid because [`WorkerPool::eval_flat`] blocks until every chunk has
/// answered (the per-worker `catch_unwind` guarantees an answer even
/// when a task panics), and chunks never overlap.
struct FlatChunk {
    q: *const f32,
    qd: *const f32,
    u: *const f32,
    out: *mut f32,
    rows: usize,
    n: usize,
    out_per_task: usize,
}

// SAFETY: the pointers reference disjoint chunk ranges of buffers that
// outlive the blocking eval_flat call that created this job.
unsafe impl Send for FlatChunk {}

/// What one pool job evaluates.
enum PoolWork {
    /// A contiguous range of a shared task slice (f64 batch API).
    Tasks { tasks: Arc<[BatchTask]>, range: Range<usize> },
    /// A borrowed flat-f32 chunk written in place (serving hot path).
    Flat(FlatChunk),
}

/// What a finished job reports back.
enum PoolPart {
    /// Outputs of a task chunk, in task order.
    Outputs(Vec<BatchOutput>),
    /// A flat chunk wrote into the caller's buffer; the payload is the
    /// kinematics-memo `(hits, misses)` delta this chunk produced on its
    /// worker (zero for every kernel but `DynAll`), so the caller's
    /// engine can keep cumulative cache counters without any shared
    /// state between workers.
    Done { hits: u64, misses: u64 },
}

/// One chunk of a batch, evaluated by whichever worker pulls it first.
struct PoolJob {
    robot: Arc<Robot>,
    kernel: BatchKernel,
    /// Which datapath evaluates this chunk (task chunks are always f64).
    backend: PoolBackend,
    /// Shift schedule for `PoolBackend::Int` jobs: shared from the
    /// engine that validated the format, so pooled execution consumes
    /// the exact schedule the serial path does (bitwise identity needs
    /// identical holding shifts, not merely equivalent ones).
    sched: Option<Arc<ShiftSchedule>>,
    work: PoolWork,
    /// (chunk ordinal, result or panic message) back to the caller.
    out: Sender<(usize, Result<PoolPart, String>)>,
    ordinal: usize,
}

/// A fixed set of persistent worker threads evaluating dynamics batches.
///
/// Workers exit when the pool (and every in-flight sender clone) is
/// dropped; the global instance lives for the process lifetime.
pub struct WorkerPool {
    injector: Mutex<Sender<PoolJob>>,
    threads: usize,
}

impl WorkerPool {
    /// Spawn a pool with `threads` persistent workers.
    pub fn new(threads: usize) -> WorkerPool {
        let threads = threads.max(1);
        let (tx, rx) = channel::<PoolJob>();
        let shared: Arc<Mutex<Receiver<PoolJob>>> = Arc::new(Mutex::new(rx));
        for _ in 0..threads {
            let q = Arc::clone(&shared);
            // Detached: each worker exits when every sender is gone.
            std::thread::spawn(move || worker(q));
        }
        WorkerPool { injector: Mutex::new(tx), threads }
    }

    /// Number of workers.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// The process-wide pool, sized to the machine's parallelism; created
    /// on first use.
    pub fn global() -> &'static WorkerPool {
        static POOL: OnceLock<WorkerPool> = OnceLock::new();
        POOL.get_or_init(|| {
            let threads =
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
            WorkerPool::new(threads)
        })
    }

    /// Evaluate `tasks` split into at most `max_chunks` contiguous chunks
    /// across the pool. Outputs are returned in task order; results are
    /// identical to [`eval_batch`] (same kernels, same workspace
    /// semantics). Convenience wrapper over [`WorkerPool::eval_shared`]
    /// that pays one robot clone and one slice copy; callers that hold
    /// `Arc`s already should use `eval_shared` directly.
    pub fn eval(
        &self,
        robot: &Robot,
        kernel: BatchKernel,
        tasks: &[BatchTask],
        max_chunks: usize,
    ) -> Vec<BatchOutput> {
        if tasks.is_empty() {
            return Vec::new();
        }
        let chunks = max_chunks.max(1).min(self.threads).min(tasks.len());
        if chunks <= 1 {
            return eval_batch(robot, kernel, tasks);
        }
        self.eval_shared(&Arc::new(robot.clone()), kernel, &Arc::from(tasks), chunks)
    }

    /// Evaluate a shared task slice split into at most `max_chunks`
    /// contiguous chunks. Allocation per call is limited to the channel
    /// and the reassembly vector — the robot and tasks travel as `Arc`
    /// clones.
    pub fn eval_shared(
        &self,
        robot: &Arc<Robot>,
        kernel: BatchKernel,
        tasks: &Arc<[BatchTask]>,
        max_chunks: usize,
    ) -> Vec<BatchOutput> {
        if tasks.is_empty() {
            return Vec::new();
        }
        let chunks = max_chunks.max(1).min(self.threads).min(tasks.len());
        if chunks <= 1 {
            return eval_batch(robot, kernel, tasks);
        }
        let chunk = tasks.len().div_ceil(chunks);
        let (tx, rx) = channel();
        let mut sent = 0usize;
        {
            let injector = self.injector.lock().unwrap();
            let mut start = 0;
            while start < tasks.len() {
                let end = (start + chunk).min(tasks.len());
                injector
                    .send(PoolJob {
                        robot: Arc::clone(robot),
                        kernel,
                        backend: PoolBackend::F64,
                        sched: None,
                        work: PoolWork::Tasks { tasks: Arc::clone(tasks), range: start..end },
                        out: tx.clone(),
                        ordinal: sent,
                    })
                    .expect("worker pool alive");
                sent += 1;
                start = end;
            }
        }
        drop(tx);
        let mut parts: Vec<Option<Vec<BatchOutput>>> = (0..sent).map(|_| None).collect();
        let mut panic_msg: Option<String> = None;
        for _ in 0..sent {
            let (ordinal, res) = rx.recv().expect("pool worker answered");
            match res {
                Ok(PoolPart::Outputs(outs)) => parts[ordinal] = Some(outs),
                Ok(PoolPart::Done { .. }) => {} // not produced by task chunks
                Err(msg) => panic_msg = Some(msg),
            }
        }
        // Propagate task panics to the caller (as the old scoped-thread
        // implementation did via join) — the workers themselves survive.
        if let Some(msg) = panic_msg {
            panic!("worker pool task panicked: {msg}");
        }
        parts.into_iter().flat_map(|p| p.expect("every chunk answered")).collect()
    }

    /// Evaluate a flat-f32 serving batch across the pool, writing the
    /// outputs in place — the zero-copy handoff of the coordinator's
    /// parallel routes. `q`/`qd`/`u` each hold `q.len() / n` rows of
    /// length `n` (pass `q` again for the unused operands of M⁻¹);
    /// `out` must hold `rows · out_per_task` values (`out_per_task` = n
    /// for RNEA/FD, n² for M⁻¹). The batch splits into at most
    /// `max_chunks` contiguous chunks; per-task results are bitwise
    /// identical to a serial decode→kernel→encode loop because the
    /// workers run exactly that loop. Panics from malformed tasks are
    /// re-raised here after every chunk has answered.
    ///
    /// Returns the summed kinematics-memo `(hits, misses)` delta across
    /// every worker that served a chunk — nonzero only for
    /// [`BatchKernel::DynAll`], whose per-worker memos skip repeated
    /// sweeps across requests. Memo hits replay the cached sweep through
    /// the identical egress tail, so the bitwise-equals-serial guarantee
    /// holds regardless of each worker's memo state.
    #[allow(clippy::too_many_arguments)]
    pub fn eval_flat(
        &self,
        robot: &Arc<Robot>,
        kernel: BatchKernel,
        q: &[f32],
        qd: &[f32],
        u: &[f32],
        n: usize,
        out_per_task: usize,
        out: &mut [f32],
        max_chunks: usize,
    ) -> (u64, u64) {
        self.eval_flat_backend(
            robot,
            kernel,
            PoolBackend::F64,
            None,
            q,
            qd,
            u,
            n,
            out_per_task,
            out,
            max_chunks,
        )
    }

    /// As [`WorkerPool::eval_flat`], but every task runs the quantized
    /// fixed-point kernels at `fmt` — the engine-generic handoff for
    /// quantized routes. Per-task results are bitwise identical to the
    /// serial [`crate::runtime::QuantEngine`] loop (same decode →
    /// `QuantScratch` kernel → encode chain); workers cache one
    /// `QuantScratch` per (robot structure, format). Returns the memo
    /// `(hits, misses)` delta as [`WorkerPool::eval_flat`] does.
    #[allow(clippy::too_many_arguments)]
    pub fn eval_flat_quant(
        &self,
        robot: &Arc<Robot>,
        kernel: BatchKernel,
        fmt: QFormat,
        q: &[f32],
        qd: &[f32],
        u: &[f32],
        n: usize,
        out_per_task: usize,
        out: &mut [f32],
        max_chunks: usize,
    ) -> (u64, u64) {
        self.eval_flat_backend(
            robot,
            kernel,
            PoolBackend::Quant(fmt),
            None,
            q,
            qd,
            u,
            n,
            out_per_task,
            out,
            max_chunks,
        )
    }

    /// As [`WorkerPool::eval_flat`], but every task runs the
    /// **true-integer** `i64` lane at `fmt` under `sched` — the fan-out
    /// of [`crate::runtime::QIntEngine`]. The schedule travels with the
    /// job (shared `Arc`), so every worker consumes the exact per-joint
    /// holding shifts the serial engine validated at construction and
    /// per-task results are bitwise identical to the serial
    /// decode→`QuantIntScratch`→encode loop. Workers cache one
    /// `QuantIntScratch` per (robot structure, format) — never aliasing
    /// the rounded-f64 `Quant` lane's entries at the same format.
    /// Returns the memo `(hits, misses)` delta as
    /// [`WorkerPool::eval_flat`] does.
    #[allow(clippy::too_many_arguments)]
    pub fn eval_flat_int(
        &self,
        robot: &Arc<Robot>,
        kernel: BatchKernel,
        fmt: QFormat,
        sched: &Arc<ShiftSchedule>,
        q: &[f32],
        qd: &[f32],
        u: &[f32],
        n: usize,
        out_per_task: usize,
        out: &mut [f32],
        max_chunks: usize,
    ) -> (u64, u64) {
        self.eval_flat_backend(
            robot,
            kernel,
            PoolBackend::Int(fmt),
            Some(Arc::clone(sched)),
            q,
            qd,
            u,
            n,
            out_per_task,
            out,
            max_chunks,
        )
    }

    /// Backend-generic flat fan-out; see [`WorkerPool::eval_flat`] for
    /// the layout/borrowing contract and the returned memo-counter
    /// delta.
    #[allow(clippy::too_many_arguments)]
    fn eval_flat_backend(
        &self,
        robot: &Arc<Robot>,
        kernel: BatchKernel,
        backend: PoolBackend,
        sched: Option<Arc<ShiftSchedule>>,
        q: &[f32],
        qd: &[f32],
        u: &[f32],
        n: usize,
        out_per_task: usize,
        out: &mut [f32],
        max_chunks: usize,
    ) -> (u64, u64) {
        assert!(n > 0, "flat batches need a positive row length");
        let rows = q.len() / n;
        assert_eq!(q.len(), rows * n, "q rows misaligned");
        assert_eq!(qd.len(), rows * n, "qd rows misaligned");
        assert_eq!(u.len(), rows * n, "u rows misaligned");
        assert_eq!(out.len(), rows * out_per_task, "output rows misaligned");
        if rows == 0 {
            return (0, 0);
        }
        let chunks = max_chunks.max(1).min(self.threads).min(rows);
        let per = rows.div_ceil(chunks);
        let (tx, rx) = channel();
        let mut sent = 0usize;
        {
            let injector = self.injector.lock().unwrap();
            let mut start = 0usize;
            while start < rows {
                let end = (start + per).min(rows);
                let chunk = FlatChunk {
                    q: q[start * n..].as_ptr(),
                    qd: qd[start * n..].as_ptr(),
                    u: u[start * n..].as_ptr(),
                    // SAFETY: chunk output ranges are disjoint; the &mut
                    // borrow of `out` is held for the whole blocking call.
                    out: unsafe { out.as_mut_ptr().add(start * out_per_task) },
                    rows: end - start,
                    n,
                    out_per_task,
                };
                injector
                    .send(PoolJob {
                        robot: Arc::clone(robot),
                        kernel,
                        backend,
                        sched: sched.clone(),
                        work: PoolWork::Flat(chunk),
                        out: tx.clone(),
                        ordinal: sent,
                    })
                    .expect("worker pool alive");
                sent += 1;
                start = end;
            }
        }
        drop(tx);
        // Block until EVERY chunk has answered — the borrows handed out
        // above must not outlive this frame while a worker still holds
        // them. A recv error means all job senders are gone (every chunk
        // finished or was dropped by a dying worker), so unwinding is
        // sound there too.
        let mut panic_msg: Option<String> = None;
        let (mut hits, mut misses) = (0u64, 0u64);
        for _ in 0..sent {
            let (_, res) = rx.recv().expect("pool worker answered");
            match res {
                Ok(PoolPart::Done { hits: h, misses: m }) => {
                    hits += h;
                    misses += m;
                }
                Ok(PoolPart::Outputs(_)) => {} // not produced by flat chunks
                Err(msg) => panic_msg = Some(msg),
            }
        }
        if let Some(msg) = panic_msg {
            panic!("worker pool task panicked: {msg}");
        }
        (hits, misses)
    }
}

/// Whether a workspace built for `a` can serve `b`: every buffer in
/// [`DynWorkspace`] is sized from the DOF and the precomputed topology
/// column lists depend only on the parent structure, so equal link
/// counts + equal parents ⇒ reusable workspace (inertias/limits don't
/// matter — they are read from the robot per task). The explicit length
/// check keeps `zip` honest: without it a robot whose links are a strict
/// prefix of the cached robot's would alias the cached workspace.
fn same_structure(a: &Robot, b: &Robot) -> bool {
    a.dof() == b.dof()
        && a.links.len() == b.links.len()
        && a.links.iter().zip(&b.links).all(|(x, y)| x.parent == y.parent)
}

/// The lane-specific workspace a cache entry holds: one per
/// (structure, backend) pair. Boxed: the workspaces are large and a
/// worker's MRU set stores several entries inline.
enum LaneScratch {
    F64(Box<DynWorkspace>),
    Quant(Box<QuantScratch>),
    Int(Box<QuantIntScratch>),
}

/// Per-worker cached state: the lane workspace for the
/// (robot structure, backend) pair last seen plus the flat-path staging
/// buffers, all sized from the DOF. `DynAll` jobs additionally consult
/// the cache's cross-request kinematics memo (`fmemo` for the f64 and
/// rounded lanes, `imemo` for the integer lane — only the entry's own
/// lane ever populates, the other stays empty) so repeated
/// linearizations at the same quantized state skip the sweep. Memos are
/// per-worker, so the hot path stays lock-free; they are discarded with
/// the cache on task panic (sound: a memo only ever holds results of
/// completed sweeps).
struct WorkerCache {
    robot: Arc<Robot>,
    backend: PoolBackend,
    lane: LaneScratch,
    q: Vec<f64>,
    qd: Vec<f64>,
    u: Vec<f64>,
    out_vec: Vec<f64>,
    out_mat: DMat,
    /// Fused-egress staging for `DynAll` rows (`n² + 2n` values).
    out_all: Vec<f64>,
    fmemo: FloatMemo,
    imemo: IntMemo,
}

impl WorkerCache {
    fn new(robot: &Arc<Robot>, backend: PoolBackend) -> WorkerCache {
        let n = robot.dof();
        let lane = match backend {
            PoolBackend::F64 => LaneScratch::F64(Box::new(DynWorkspace::new(robot))),
            PoolBackend::Quant(_) => LaneScratch::Quant(Box::new(QuantScratch::new(n))),
            PoolBackend::Int(_) => LaneScratch::Int(Box::new(QuantIntScratch::new(n))),
        };
        WorkerCache {
            robot: Arc::clone(robot),
            backend,
            lane,
            q: vec![0.0; n],
            qd: vec![0.0; n],
            u: vec![0.0; n],
            out_vec: vec![0.0; n],
            out_mat: DMat::zeros(n, n),
            out_all: vec![0.0; n * n + 2 * n],
            fmemo: FloatMemo::with_default_cap(),
            imemo: IntMemo::with_default_cap(),
        }
    }

    /// Combined memo counters across both lanes (only one is ever
    /// nonzero for a given cache entry).
    fn memo_counters(&self) -> (u64, u64) {
        let (fh, fm) = self.fmemo.counters();
        let (ih, im) = self.imemo.counters();
        (fh + ih, fm + im)
    }
}

/// Whether a cache entry can serve a `(robot, backend)` job: the backend
/// must match **exactly** — a `Quant` entry never serves another format
/// or the f64 lane (and vice versa) — and the robot must match by `Arc`
/// identity or by structure (see [`same_structure`]).
fn cache_serves(cache: &WorkerCache, backend: PoolBackend, robot: &Arc<Robot>) -> bool {
    cache.backend == backend
        && (Arc::ptr_eq(&cache.robot, robot) || same_structure(&cache.robot, robot))
}

fn decode32(src: &[f32], dst: &mut [f64]) {
    for (d, s) in dst.iter_mut().zip(src) {
        *d = *s as f64;
    }
}

fn encode32(src: &[f64], dst: &mut [f32]) {
    for (d, s) in dst.iter_mut().zip(src) {
        *d = *s as f32;
    }
}

/// Evaluate one flat chunk exactly as the serial engine for its lane
/// does — decode each f32 row into f64 staging, run the lane's workspace
/// kernel (f64 `DynWorkspace`, or `QuantScratch` at the job's format),
/// encode the f64 result back — so per-task outputs are bitwise
/// identical to serial execution. Returns the kinematics-memo
/// `(hits, misses)` delta this chunk produced (zero for every kernel
/// but [`BatchKernel::DynAll`]).
///
/// # Safety
/// The chunk's pointers must reference live, disjoint buffers of the
/// advertised lengths; [`WorkerPool::eval_flat`] /
/// [`WorkerPool::eval_flat_quant`] guarantee this by blocking until the
/// chunk answers.
unsafe fn eval_flat_chunk(
    robot: &Robot,
    kernel: BatchKernel,
    cache: &mut WorkerCache,
    sched: Option<&ShiftSchedule>,
    c: &FlatChunk,
) -> (u64, u64) {
    let n = c.n;
    assert_eq!(robot.dof(), n, "flat chunk row length != robot DOF");
    let (hits0, misses0) = cache.memo_counters();
    // The memo partitions entries by robot fingerprint; only the fused
    // route consults it, so skip the hash for the single-output kernels.
    let robot_fp =
        if kernel == BatchKernel::DynAll { robot.fingerprint() } else { 0 };
    let WorkerCache { backend, lane, q, qd, u, out_vec, out_mat, out_all, fmemo, imemo, .. } =
        cache;
    for k in 0..c.rows {
        let qrow = std::slice::from_raw_parts(c.q.add(k * n), n);
        let out = std::slice::from_raw_parts_mut(c.out.add(k * c.out_per_task), c.out_per_task);
        decode32(qrow, q);
        match lane {
            LaneScratch::F64(ws) => match kernel {
                BatchKernel::Rnea => {
                    decode32(std::slice::from_raw_parts(c.qd.add(k * n), n), qd);
                    decode32(std::slice::from_raw_parts(c.u.add(k * n), n), u);
                    ws.rnea_into(robot, q, qd, u, None, out_vec);
                    encode32(out_vec, out);
                }
                BatchKernel::Fd => {
                    decode32(std::slice::from_raw_parts(c.qd.add(k * n), n), qd);
                    decode32(std::slice::from_raw_parts(c.u.add(k * n), n), u);
                    ws.fd_into(robot, q, qd, u, None, out_vec);
                    encode32(out_vec, out);
                }
                BatchKernel::Minv => {
                    ws.minv_into(robot, q, out_mat);
                    encode32(&out_mat.d, out);
                }
                BatchKernel::DynAll => {
                    decode32(std::slice::from_raw_parts(c.qd.add(k * n), n), qd);
                    decode32(std::slice::from_raw_parts(c.u.add(k * n), n), u);
                    ws.dyn_all_memo_into(robot, robot_fp, q, qd, u, fmemo, out_all);
                    encode32(out_all, out);
                }
            },
            LaneScratch::Quant(ws) => {
                let PoolBackend::Quant(fmt) = *backend else {
                    unreachable!("quant scratch cached under a non-quant backend")
                };
                match kernel {
                    BatchKernel::Rnea => {
                        decode32(std::slice::from_raw_parts(c.qd.add(k * n), n), qd);
                        decode32(std::slice::from_raw_parts(c.u.add(k * n), n), u);
                        ws.rnea_into(robot, q, qd, u, fmt, out_vec);
                        encode32(out_vec, out);
                    }
                    BatchKernel::Fd => {
                        decode32(std::slice::from_raw_parts(c.qd.add(k * n), n), qd);
                        decode32(std::slice::from_raw_parts(c.u.add(k * n), n), u);
                        ws.fd_into(robot, q, qd, u, fmt, out_vec);
                        encode32(out_vec, out);
                    }
                    BatchKernel::Minv => {
                        ws.minv_into(robot, q, fmt, out_mat);
                        encode32(&out_mat.d, out);
                    }
                    BatchKernel::DynAll => {
                        decode32(std::slice::from_raw_parts(c.qd.add(k * n), n), qd);
                        decode32(std::slice::from_raw_parts(c.u.add(k * n), n), u);
                        ws.dyn_all_memo_into(robot, robot_fp, q, qd, u, fmt, fmemo, out_all);
                        encode32(out_all, out);
                    }
                }
            }
            LaneScratch::Int(ws) => {
                let PoolBackend::Int(fmt) = *backend else {
                    unreachable!("int scratch cached under a non-int backend")
                };
                match kernel {
                    BatchKernel::Rnea => {
                        decode32(std::slice::from_raw_parts(c.qd.add(k * n), n), qd);
                        decode32(std::slice::from_raw_parts(c.u.add(k * n), n), u);
                        ws.rnea_into(robot, q, qd, u, fmt, out_vec);
                        encode32(out_vec, out);
                    }
                    BatchKernel::Fd => {
                        let sched = sched.expect("int pool jobs carry a shift schedule");
                        decode32(std::slice::from_raw_parts(c.qd.add(k * n), n), qd);
                        decode32(std::slice::from_raw_parts(c.u.add(k * n), n), u);
                        ws.fd_dd_into(robot, q, qd, u, sched, out_vec);
                        encode32(out_vec, out);
                    }
                    BatchKernel::Minv => {
                        let sched = sched.expect("int pool jobs carry a shift schedule");
                        ws.minv_dd_into(robot, q, sched, out_mat);
                        encode32(&out_mat.d, out);
                    }
                    BatchKernel::DynAll => {
                        let sched = sched.expect("int pool jobs carry a shift schedule");
                        decode32(std::slice::from_raw_parts(c.qd.add(k * n), n), qd);
                        decode32(std::slice::from_raw_parts(c.u.add(k * n), n), u);
                        ws.dyn_all_dd_memo_into(robot, q, qd, u, sched, imemo, out_all);
                        encode32(out_all, out);
                    }
                }
            }
        }
    }
    let (hits1, misses1) = cache.memo_counters();
    (hits1 - hits0, misses1 - misses0)
}

/// (Robot structure, backend) pairs each pool worker keeps warm
/// workspaces for (MRU): bounds worker memory while letting a
/// multi-robot registry's parallel routes interleave batches without
/// rebuilding — one slot per resident (structure, lane) pair in the
/// steady state. Sized for the backend-keyed cache across all THREE
/// lanes: every builtin robot served simultaneously on f64, a quant
/// format, and a qint format is 12 pairs; the 24-slot cap leaves room
/// for imported robots and per-robot format variants without
/// thrashing.
const WORKER_CACHE_SLOTS: usize = 24;

/// Worker loop: pull chunks from the shared queue until the pool drops.
fn worker(queue: Arc<Mutex<Receiver<PoolJob>>>) {
    // MRU cache keyed by (robot structure, backend), most recent first:
    // `Arc::ptr_eq` is the fast path (all chunks of one batch share the
    // robot Arc, and a serving engine holds one Arc across batches); the
    // structural check keeps slots warm across robot clones with
    // identical topology. Backends match exactly, so a format never
    // borrows another format's (or the f64 lane's) slot.
    let mut cached: Vec<WorkerCache> = Vec::new();
    loop {
        let job = {
            let rx = queue.lock().unwrap();
            rx.recv()
        };
        let job = match job {
            Ok(j) => j,
            Err(_) => return, // pool dropped
        };
        let hit = cached.iter().position(|c| cache_serves(c, job.backend, &job.robot));
        let mut cache = match hit {
            Some(i) => {
                let mut c = cached.remove(i);
                // Remember the newest Arc so the fast path keeps hitting.
                c.robot = Arc::clone(&job.robot);
                c
            }
            None => WorkerCache::new(&job.robot, job.backend),
        };
        // Contain task panics (malformed tasks assert inside the
        // kernels): the caller gets the panic re-raised by the eval
        // entry point, but this worker — shared process-wide — stays
        // alive for later batches. AssertUnwindSafe is sound because the
        // cache is dropped below on panic and kernels overwrite it per
        // task anyway.
        let t_busy = Instant::now();
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| match &job.work {
            PoolWork::Tasks { tasks, range } => {
                // Task chunks are injected by the f64 batch API only.
                let ws = match &mut cache.lane {
                    LaneScratch::F64(ws) => ws,
                    LaneScratch::Quant(_) | LaneScratch::Int(_) => {
                        unreachable!("task chunks always run the f64 lane")
                    }
                };
                PoolPart::Outputs(
                    tasks[range.clone()]
                        .iter()
                        .map(|t| super::batch::eval_one(&job.robot, job.kernel, ws, t))
                        .collect(),
                )
            }
            PoolWork::Flat(chunk) => {
                // SAFETY: the caller blocks in eval_flat until this job
                // answers, so the borrowed rows outlive the evaluation.
                let (hits, misses) = unsafe {
                    eval_flat_chunk(&job.robot, job.kernel, &mut cache, job.sched.as_deref(), chunk)
                };
                PoolPart::Done { hits, misses }
            }
        }));
        POOL_CHUNKS.fetch_add(1, Ordering::Relaxed);
        POOL_BUSY_NS.fetch_add(t_busy.elapsed().as_nanos() as u64, Ordering::Relaxed);
        let result = match result {
            Ok(part) => {
                // Return the workspace to the front of the MRU set.
                cached.insert(0, cache);
                cached.truncate(WORKER_CACHE_SLOTS);
                Ok(part)
            }
            Err(p) => {
                // Discard the possibly half-written workspace.
                drop(cache);
                Err(panic_message(&p))
            }
        };
        // The caller may have gone away (it never does today — the eval
        // entry points block); dropping the result is then harmless.
        let _ = job.out.send((job.ordinal, result));
    }
}

/// Best-effort extraction of a panic payload's message. Shared with the
/// coordinator's batch-boundary catch (`coordinator::batcher`), which
/// reports caught engine panics through the same text.
pub(crate) fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "task panicked".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{builtin, State};
    use crate::util::rng::Rng;

    fn random_tasks(robot: &Robot, count: usize, seed: u64) -> Vec<BatchTask> {
        let n = robot.dof();
        let mut rng = Rng::new(seed);
        (0..count)
            .map(|_| {
                let s = State::random(robot, &mut rng);
                BatchTask { q: s.q, qd: s.qd, u: rng.vec_range(n, -8.0, 8.0) }
            })
            .collect()
    }

    #[test]
    fn pool_matches_single_thread_bitwise() {
        let pool = WorkerPool::new(3);
        let robot = builtin::iiwa();
        let tasks = random_tasks(&robot, 25, 900);
        let single = eval_batch(&robot, BatchKernel::Fd, &tasks);
        for chunks in [1, 2, 3, 16] {
            let par = pool.eval(&robot, BatchKernel::Fd, &tasks, chunks);
            assert_eq!(par.len(), single.len());
            for (a, b) in single.iter().zip(&par) {
                assert_eq!(a.as_vector().unwrap(), b.as_vector().unwrap());
            }
        }
    }

    #[test]
    fn pool_survives_robot_switches() {
        let pool = WorkerPool::new(2);
        for (robot, seed) in [(builtin::iiwa(), 901), (builtin::hyq(), 902), (builtin::iiwa(), 903)]
        {
            let tasks = random_tasks(&robot, 9, seed);
            let got = pool.eval(&robot, BatchKernel::Rnea, &tasks, 2);
            let want = eval_batch(&robot, BatchKernel::Rnea, &tasks);
            for (a, b) in want.iter().zip(&got) {
                assert_eq!(a.as_vector().unwrap(), b.as_vector().unwrap());
            }
        }
    }

    /// Same DOF, different topology: the structural cache must rebuild,
    /// not alias. (Regression: `same_structure` also checks link-count
    /// equality so a prefix-parent robot can never alias either.)
    #[test]
    fn structural_cache_rejects_same_dof_different_topology() {
        let chain = builtin::iiwa();
        let mut branched = builtin::iiwa();
        branched.name = "iiwa-branched".to_string();
        // Re-root the outer arm: links 4..7 hang off link 2 instead of
        // continuing the chain (still topologically ordered).
        branched.links[4].parent = Some(2);
        assert!(same_structure(&chain, &builtin::iiwa()));
        assert!(!same_structure(&chain, &branched));

        // Interleave the two robots through one small pool: every batch
        // must match its own serial reference (an aliased workspace
        // would reuse the wrong topology column lists).
        let pool = WorkerPool::new(2);
        for (robot, seed) in [(&chain, 910u64), (&branched, 911), (&chain, 912)] {
            let tasks = random_tasks(robot, 12, seed);
            let got = pool.eval(robot, BatchKernel::Fd, &tasks, 2);
            let want = eval_batch(robot, BatchKernel::Fd, &tasks);
            for (a, b) in want.iter().zip(&got) {
                assert_eq!(a.as_vector().unwrap(), b.as_vector().unwrap());
            }
        }
    }

    /// The zero-copy flat path must agree bitwise with the f64 batch API
    /// evaluated on the f32-rounded operands (both run the same
    /// decode→kernel chain).
    #[test]
    fn flat_batch_matches_task_batch_bitwise() {
        let pool = WorkerPool::new(3);
        let robot = Arc::new(builtin::iiwa());
        let n = robot.dof();
        let rows = 13;
        let mut rng = Rng::new(920);
        let mut q32 = Vec::with_capacity(rows * n);
        let mut qd32 = Vec::with_capacity(rows * n);
        let mut u32 = Vec::with_capacity(rows * n);
        for _ in 0..rows {
            let s = State::random(&robot, &mut rng);
            q32.extend(s.q.iter().map(|&x| x as f32));
            qd32.extend(s.qd.iter().map(|&x| x as f32));
            u32.extend(rng.vec_range(n, -8.0, 8.0).iter().map(|&x| x as f32));
        }
        // Reference: serial f64 batch on the rounded operands, encoded.
        let tasks: Vec<BatchTask> = (0..rows)
            .map(|k| BatchTask {
                q: q32[k * n..(k + 1) * n].iter().map(|&x| x as f64).collect(),
                qd: qd32[k * n..(k + 1) * n].iter().map(|&x| x as f64).collect(),
                u: u32[k * n..(k + 1) * n].iter().map(|&x| x as f64).collect(),
            })
            .collect();
        for (kernel, per_task) in [(BatchKernel::Fd, n), (BatchKernel::Minv, n * n)] {
            let want: Vec<f32> = eval_batch(&robot, kernel, &tasks)
                .iter()
                .flat_map(|o| match o {
                    BatchOutput::Vector(v) => v.iter().map(|&x| x as f32).collect::<Vec<f32>>(),
                    BatchOutput::Matrix(m) => m.d.iter().map(|&x| x as f32).collect(),
                })
                .collect();
            let mut got = vec![0.0f32; rows * per_task];
            for chunks in [2, 3, 16] {
                got.fill(0.0);
                let _ = match kernel {
                    BatchKernel::Minv => pool.eval_flat(
                        &robot,
                        kernel,
                        &q32,
                        &q32,
                        &q32,
                        n,
                        per_task,
                        &mut got,
                        chunks,
                    ),
                    _ => pool.eval_flat(
                        &robot,
                        kernel,
                        &q32,
                        &qd32,
                        &u32,
                        n,
                        per_task,
                        &mut got,
                        chunks,
                    ),
                };
                assert_eq!(got, want, "kernel {kernel:?} chunks {chunks}");
            }
        }
    }

    #[test]
    fn pool_contains_task_panics() {
        let pool = WorkerPool::new(2);
        let robot = builtin::iiwa();
        let mut tasks = random_tasks(&robot, 4, 905);
        tasks[2].q.truncate(2); // malformed: the kernel asserts on length
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.eval(&robot, BatchKernel::Rnea, &tasks, 2)
        }));
        assert!(res.is_err(), "malformed task must propagate a panic to the caller");
        // The workers survive: a healthy batch still evaluates afterwards.
        let good = random_tasks(&robot, 6, 906);
        assert_eq!(pool.eval(&robot, BatchKernel::Rnea, &good, 2).len(), 6);
    }

    /// (structure, format) cache keying: a cache entry serves only its
    /// exact backend — different formats (and the f64 lane) never alias
    /// one another's workspaces.
    #[test]
    fn cache_entries_do_not_alias_across_formats() {
        let robot = Arc::new(builtin::iiwa());
        let fa = PoolBackend::Quant(QFormat::new(12, 12));
        let fb = PoolBackend::Quant(QFormat::new(12, 14));
        let entry = WorkerCache::new(&robot, fa);
        assert!(cache_serves(&entry, fa, &robot), "exact (structure, format) must hit");
        assert!(!cache_serves(&entry, fb, &robot), "another format must miss");
        assert!(!cache_serves(&entry, PoolBackend::F64, &robot), "the f64 lane must miss");
        let f64_entry = WorkerCache::new(&robot, PoolBackend::F64);
        assert!(!cache_serves(&f64_entry, fa, &robot), "f64 entry must not serve quant jobs");
        // Structural fallback still applies within one backend.
        let clone = Arc::new(builtin::iiwa());
        assert!(cache_serves(&entry, fa, &clone));
    }

    /// The integer lane is its own backend: `Int(fmt)` and `Quant(fmt)`
    /// at the SAME format (and the same structure) must never share a
    /// cache slot — their scratches hold different ingested state
    /// (rounded-f64 staging vs scaled-once i64 constants).
    #[test]
    fn int_lane_never_aliases_quant_lane_at_same_format() {
        let robot = Arc::new(builtin::iiwa());
        let fmt = QFormat::new(12, 12);
        let int_b = PoolBackend::Int(fmt);
        let quant_b = PoolBackend::Quant(fmt);
        let int_entry = WorkerCache::new(&robot, int_b);
        assert!(cache_serves(&int_entry, int_b, &robot), "exact int (structure, format) hits");
        assert!(!cache_serves(&int_entry, quant_b, &robot), "quant at same format must miss");
        assert!(!cache_serves(&int_entry, PoolBackend::F64, &robot));
        assert!(!cache_serves(&int_entry, PoolBackend::Int(QFormat::new(12, 14)), &robot));
        let quant_entry = WorkerCache::new(&robot, quant_b);
        assert!(!cache_serves(&quant_entry, int_b, &robot), "int at same format must miss");
        assert!(matches!(int_entry.lane, LaneScratch::Int(_)));
        assert!(matches!(quant_entry.lane, LaneScratch::Quant(_)));
    }

    /// Interleaving the INT lane with the quant lane and the f64 lane
    /// for the same robot and format through a single-worker pool (one
    /// MRU set sees every job) must reproduce each serial reference
    /// bitwise — the schedule travels with the job, so pooled deferred
    /// M⁻¹ consumes the identical holding shifts.
    #[test]
    fn interleaved_int_lane_matches_serial_bitwise() {
        use crate::quant::scaling::{analyze, ScalingConfig};
        use crate::quant::QuantIntScratch;
        let pool = WorkerPool::new(1);
        let robot = Arc::new(builtin::iiwa());
        let n = robot.dof();
        let fmt = QFormat::new(12, 12);
        let sched = Arc::new(analyze(&robot, fmt, &ScalingConfig::default()).expect("schedule"));
        let rows = 7;
        let mut rng = Rng::new(940);
        let mut q32 = Vec::with_capacity(rows * n);
        let mut qd32 = Vec::with_capacity(rows * n);
        let mut u32 = Vec::with_capacity(rows * n);
        for _ in 0..rows {
            let s = State::random(&robot, &mut rng);
            q32.extend(s.q.iter().map(|&x| x as f32));
            qd32.extend(s.qd.iter().map(|&x| x as f32));
            u32.extend(rng.vec_range(n, -8.0, 8.0).iter().map(|&x| x as f32));
        }
        // Serial int references: the exact decode→kernel→encode loops.
        let mut ws = QuantIntScratch::new(n);
        let (mut q, mut qd, mut u, mut o) =
            (vec![0.0; n], vec![0.0; n], vec![0.0; n], vec![0.0; n]);
        let mut want_fd = vec![0.0f32; rows * n];
        for k in 0..rows {
            decode32(&q32[k * n..(k + 1) * n], &mut q);
            decode32(&qd32[k * n..(k + 1) * n], &mut qd);
            decode32(&u32[k * n..(k + 1) * n], &mut u);
            ws.fd_dd_into(&robot, &q, &qd, &u, &sched, &mut o);
            encode32(&o, &mut want_fd[k * n..(k + 1) * n]);
        }
        let mut mi = DMat::zeros(n, n);
        let mut want_mi = vec![0.0f32; rows * n * n];
        for k in 0..rows {
            decode32(&q32[k * n..(k + 1) * n], &mut q);
            ws.minv_dd_into(&robot, &q, &sched, &mut mi);
            encode32(&mi.d, &mut want_mi[k * n * n..(k + 1) * n * n]);
        }
        let mut got = vec![0.0f32; rows * n];
        let mut got_mi = vec![0.0f32; rows * n * n];
        // Two rounds with a quant job interleaved so the second int
        // visit must REUSE (and never mistake) a cached entry.
        for _ in 0..2 {
            got.fill(0.0);
            pool.eval_flat_int(
                &robot, BatchKernel::Fd, fmt, &sched, &q32, &qd32, &u32, n, n, &mut got, 4,
            );
            assert_eq!(got, want_fd, "pooled int FD diverged");
            got_mi.fill(0.0);
            pool.eval_flat_int(
                &robot, BatchKernel::Minv, fmt, &sched, &q32, &q32, &q32, n, n * n, &mut got_mi, 3,
            );
            assert_eq!(got_mi, want_mi, "pooled int M⁻¹ diverged");
            // A quant job at the SAME format between int rounds: must
            // not disturb (or borrow) the int lane's scratch.
            got.fill(0.0);
            pool.eval_flat_quant(&robot, BatchKernel::Fd, fmt, &q32, &qd32, &u32, n, n, &mut got, 4);
        }
    }

    /// Interleaving two quantized formats and the f64 lane for the SAME
    /// robot through a single-worker pool (so one worker's MRU set sees
    /// every job) must reproduce each serial reference bitwise.
    #[test]
    fn interleaved_formats_match_serial_bitwise() {
        use crate::quant::QuantScratch;
        let pool = WorkerPool::new(1);
        let robot = Arc::new(builtin::iiwa());
        let n = robot.dof();
        let rows = 9;
        let mut rng = Rng::new(930);
        let mut q32 = Vec::with_capacity(rows * n);
        let mut qd32 = Vec::with_capacity(rows * n);
        let mut u32 = Vec::with_capacity(rows * n);
        for _ in 0..rows {
            let s = State::random(&robot, &mut rng);
            q32.extend(s.q.iter().map(|&x| x as f32));
            qd32.extend(s.qd.iter().map(|&x| x as f32));
            u32.extend(rng.vec_range(n, -8.0, 8.0).iter().map(|&x| x as f32));
        }
        // Serial references: the exact decode→kernel→encode loop.
        let serial_quant = |fmt: QFormat| -> Vec<f32> {
            let mut ws = QuantScratch::new(n);
            let (mut q, mut qd, mut u, mut o) =
                (vec![0.0; n], vec![0.0; n], vec![0.0; n], vec![0.0; n]);
            let mut out = vec![0.0f32; rows * n];
            for k in 0..rows {
                decode32(&q32[k * n..(k + 1) * n], &mut q);
                decode32(&qd32[k * n..(k + 1) * n], &mut qd);
                decode32(&u32[k * n..(k + 1) * n], &mut u);
                ws.fd_into(&robot, &q, &qd, &u, fmt, &mut o);
                encode32(&o, &mut out[k * n..(k + 1) * n]);
            }
            out
        };
        let fa = QFormat::new(12, 12);
        let fb = QFormat::new(12, 14);
        let want_a = serial_quant(fa);
        let want_b = serial_quant(fb);
        let want_f64: Vec<f32> = {
            let mut ws = DynWorkspace::new(&robot);
            let (mut q, mut qd, mut u, mut o) =
                (vec![0.0; n], vec![0.0; n], vec![0.0; n], vec![0.0; n]);
            let mut out = vec![0.0f32; rows * n];
            for k in 0..rows {
                decode32(&q32[k * n..(k + 1) * n], &mut q);
                decode32(&qd32[k * n..(k + 1) * n], &mut qd);
                decode32(&u32[k * n..(k + 1) * n], &mut u);
                ws.fd_into(&robot, &q, &qd, &u, None, &mut o);
                encode32(&o, &mut out[k * n..(k + 1) * n]);
            }
            out
        };
        let mut got = vec![0.0f32; rows * n];
        // Two rounds so the second visit of each backend reuses (never
        // mistakes) a cached entry.
        for _ in 0..2 {
            got.fill(0.0);
            pool.eval_flat_quant(&robot, BatchKernel::Fd, fa, &q32, &qd32, &u32, n, n, &mut got, 4);
            assert_eq!(got, want_a, "format A diverged");
            got.fill(0.0);
            pool.eval_flat_quant(&robot, BatchKernel::Fd, fb, &q32, &qd32, &u32, n, n, &mut got, 4);
            assert_eq!(got, want_b, "format B diverged");
            got.fill(0.0);
            pool.eval_flat(&robot, BatchKernel::Fd, &q32, &qd32, &u32, n, n, &mut got, 4);
            assert_eq!(got, want_f64, "f64 lane diverged");
        }
    }

    /// The fused DynAll kernel through the pool: every lane must match
    /// its memo-less serial reference bitwise (memo hits replay the
    /// cached sweep through the identical egress tail), and the
    /// per-worker memo deltas must surface through the eval_flat return
    /// — repeated rows hit, a warm second batch hits everywhere.
    #[test]
    fn pooled_dyn_all_matches_serial_and_counts_memo_hits() {
        use crate::quant::scaling::{analyze, ScalingConfig};
        let pool = WorkerPool::new(1); // one worker ⇒ deterministic memo accounting
        let robot = Arc::new(builtin::iiwa());
        let n = robot.dof();
        let fmt = QFormat::new(12, 12);
        let sched = Arc::new(analyze(&robot, fmt, &ScalingConfig::default()).expect("schedule"));
        let per = n * n + 2 * n;
        // 3 distinct states, then bit-exact repeats of all 3 — the
        // repeats must be memo hits on every lane.
        let mut rng = Rng::new(950);
        let (mut q32, mut qd32, mut u32) = (Vec::new(), Vec::new(), Vec::new());
        for _ in 0..3 {
            let s = State::random(&robot, &mut rng);
            q32.extend(s.q.iter().map(|&x| x as f32));
            qd32.extend(s.qd.iter().map(|&x| x as f32));
            u32.extend(rng.vec_range(n, -8.0, 8.0).iter().map(|&x| x as f32));
        }
        let (qq, dd, uu) = (q32.clone(), qd32.clone(), u32.clone());
        q32.extend(qq);
        qd32.extend(dd);
        u32.extend(uu);
        let rows = 6;
        let (mut q, mut qd, mut u) = (vec![0.0; n], vec![0.0; n], vec![0.0; n]);
        let mut want = vec![0.0f64; per];

        // f64 lane.
        let mut ws = DynWorkspace::new(&robot);
        let mut want32 = vec![0.0f32; rows * per];
        for k in 0..rows {
            decode32(&q32[k * n..(k + 1) * n], &mut q);
            decode32(&qd32[k * n..(k + 1) * n], &mut qd);
            decode32(&u32[k * n..(k + 1) * n], &mut u);
            ws.dyn_all_into(&robot, &q, &qd, &u, None, &mut want);
            encode32(&want, &mut want32[k * per..(k + 1) * per]);
        }
        let mut got = vec![0.0f32; rows * per];
        let (h, m) =
            pool.eval_flat(&robot, BatchKernel::DynAll, &q32, &qd32, &u32, n, per, &mut got, 1);
        assert_eq!(got, want32, "pooled f64 dyn_all diverged from serial");
        assert_eq!((h, m), (3, 3), "repeated rows must hit the worker memo");
        got.fill(0.0);
        let (h, m) =
            pool.eval_flat(&robot, BatchKernel::DynAll, &q32, &qd32, &u32, n, per, &mut got, 1);
        assert_eq!(got, want32, "warm-memo batch diverged from serial");
        assert_eq!((h, m), (6, 0), "a warm second batch hits everywhere");

        // Rounded quant lane.
        let mut qws = QuantScratch::new(n);
        for k in 0..rows {
            decode32(&q32[k * n..(k + 1) * n], &mut q);
            decode32(&qd32[k * n..(k + 1) * n], &mut qd);
            decode32(&u32[k * n..(k + 1) * n], &mut u);
            qws.dyn_all_into(&robot, &q, &qd, &u, fmt, &mut want);
            encode32(&want, &mut want32[k * per..(k + 1) * per]);
        }
        got.fill(0.0);
        let (h, m) = pool.eval_flat_quant(
            &robot,
            BatchKernel::DynAll,
            fmt,
            &q32,
            &qd32,
            &u32,
            n,
            per,
            &mut got,
            1,
        );
        assert_eq!(got, want32, "pooled quant dyn_all diverged from serial");
        assert_eq!((h, m), (3, 3));

        // True-integer lane.
        let mut iws = QuantIntScratch::new(n);
        for k in 0..rows {
            decode32(&q32[k * n..(k + 1) * n], &mut q);
            decode32(&qd32[k * n..(k + 1) * n], &mut qd);
            decode32(&u32[k * n..(k + 1) * n], &mut u);
            iws.dyn_all_dd_into(&robot, &q, &qd, &u, &sched, &mut want);
            encode32(&want, &mut want32[k * per..(k + 1) * per]);
        }
        got.fill(0.0);
        let (h, m) = pool.eval_flat_int(
            &robot,
            BatchKernel::DynAll,
            fmt,
            &sched,
            &q32,
            &qd32,
            &u32,
            n,
            per,
            &mut got,
            1,
        );
        assert_eq!(got, want32, "pooled qint dyn_all diverged from serial");
        assert_eq!((h, m), (3, 3));
    }

    #[test]
    fn global_pool_is_shared_and_alive() {
        let p1 = WorkerPool::global();
        let p2 = WorkerPool::global();
        assert!(std::ptr::eq(p1, p2));
        assert!(p1.threads() >= 1);
        let robot = builtin::iiwa();
        let tasks = random_tasks(&robot, 5, 904);
        assert_eq!(p1.eval(&robot, BatchKernel::Fd, &tasks, 4).len(), 5);
    }
}
