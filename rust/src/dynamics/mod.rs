//! Rigid-body dynamics algorithms — the paper's RBD function suite
//! (Fig. 3(a)): ID/RNEA, M(q) via CRBA, the analytical M⁻¹ (original and
//! division-deferring), FD = M⁻¹·ID, and the analytical derivatives
//! ΔID/ΔFD. Doubles as the measured CPU baseline (Pinocchio stand-in).

pub mod crba;
pub mod deriv;
pub mod fd;
pub mod kinematics;
pub mod minv;
pub mod rnea;

pub use crba::crba;
pub use deriv::{fd_derivatives, rnea_derivatives};
pub use fd::{aba, fd};
pub use kinematics::Kin;
pub use minv::{minv, minv_dd, minv_dd_traced, DividerQueue};
pub use rnea::{bias_forces, gravity_torques, rnea};
