//! Rigid-body dynamics algorithms — the paper's RBD function suite
//! (Fig. 3(a)): ID/RNEA, M(q) via CRBA, the analytical M⁻¹ (original and
//! division-deferring), FD = M⁻¹·ID, and the analytical derivatives
//! ΔID/ΔFD. Doubles as the measured CPU baseline (Pinocchio stand-in).

pub mod batch;
pub mod crba;
pub mod deriv;
pub mod fd;
pub mod kinematics;
pub mod memo;
pub mod minv;
pub mod pool;
pub mod rnea;
pub mod workspace;

pub use batch::{eval_batch, eval_batch_par, BatchKernel, BatchOutput, BatchTask};
pub use memo::{FloatMemo, IntMemo, KinMemo, DEFAULT_MEMO_CAP};
pub use pool::{pool_activity, WorkerPool};
pub use crba::{crba, crba_into};
pub use deriv::{fd_derivatives, rnea_derivatives};
pub use fd::{aba, aba_into, fd, AbaScratch};
pub use kinematics::Kin;
pub use minv::{minv, minv_dd, minv_dd_into, minv_dd_traced, DividerQueue, MinvScratch, Topology};
pub use rnea::{bias_forces, bias_into, gravity_torques, rnea, rnea_into};
pub use workspace::DynWorkspace;
