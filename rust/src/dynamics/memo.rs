//! Cross-request kinematics memo — the CPU analog of the paper's
//! inter-module DSP-reuse pillar, applied across *requests* instead of
//! across hardware modules.
//!
//! An MPC or RL client linearizing around an operating point sends many
//! `dyn_all` requests at the same (or quantization-identical) joint
//! state. The expensive shared work — the kinematics pass, the RNEA
//! bias sweep, and the division-deferring M⁻¹ sweep — is a pure
//! function of the ingested joint words, so its outputs can be
//! memoized and only the cheap τ-fold matvec rerun per request.
//!
//! Correctness is by construction: entries are keyed by the **exact bit
//! patterns** of the post-ingest joint words (`f64::to_bits` for the
//! float lanes, the quantized `i64` words for the integer lane) plus
//! the [`Robot::fingerprint`](crate::model::Robot::fingerprint), so a
//! hit replays precisely the sweep outputs a cold evaluation would
//! recompute — a memo hit is bitwise identical to a miss. The u64 hash
//! is only a fast reject; every candidate hit compares the full key
//! word-for-word, so adjacent quantized states (one lsb apart) can
//! never alias, even under a hash collision.
//!
//! The memo is a small bounded LRU kept as an MRU-ordered vector —
//! entry counts are tens, not thousands, so a linear scan beats a hash
//! map and its allocation churn — and it is held **per worker** (each
//! serial engine and each pool worker owns one), so the serving hot
//! path takes no lock.

/// Default entry capacity used by the serving engines and pool workers.
///
/// Sized for the serving shape the memo targets: a handful of clients
/// each linearizing around a few operating points. Larger working sets
/// degrade gracefully to the cold path (every call is a miss plus one
/// bounded insert), never to unbounded memory.
pub const DEFAULT_MEMO_CAP: usize = 64;

/// Memo value for the float lanes: `(M⁻¹ flat row-major, bias)`.
pub type FloatMemo = KinMemo<(Vec<f64>, Vec<f64>)>;

/// Memo value for the integer lane: `(held M⁻¹ rows as i64, bias as i64)`.
///
/// The integer lane caches the *pre-egress* fixed-point words (`irow`,
/// `tfix`), so a hit re-runs the same integer τ-fold and the same exact
/// `from_fix` egress a cold evaluation would.
pub type IntMemo = KinMemo<(Vec<i64>, Vec<i64>)>;

#[derive(Debug, Clone)]
struct Entry<V> {
    robot_fp: u64,
    hash: u64,
    key: Vec<u64>,
    value: V,
}

/// Bounded per-worker LRU over kinematic-sweep outputs.
///
/// Usage is a three-step staging protocol, allocation-free on the hot
/// path (the key is built in a reused buffer; only a cold-path
/// [`insert`](Self::insert) clones it):
///
/// 1. [`begin`](Self::begin), then [`stage_f64`](Self::stage_f64) /
///    [`stage_i64`](Self::stage_i64) / [`stage_word`](Self::stage_word)
///    the post-ingest joint words;
/// 2. [`lookup`](Self::lookup) — on `true` the entry has been promoted
///    to the front and [`front`](Self::front) returns its value;
/// 3. on `false`, compute the sweeps and [`insert`](Self::insert) the
///    result under the staged key.
#[derive(Debug, Clone)]
pub struct KinMemo<V> {
    cap: usize,
    /// MRU order: `entries[0]` is the most recently used.
    entries: Vec<Entry<V>>,
    hits: u64,
    misses: u64,
    key_buf: Vec<u64>,
}

impl<V> KinMemo<V> {
    /// New memo holding at most `cap` entries (`cap` must be nonzero).
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0, "memo capacity must be nonzero");
        KinMemo { cap, entries: Vec::new(), hits: 0, misses: 0, key_buf: Vec::new() }
    }

    /// New memo at [`DEFAULT_MEMO_CAP`].
    pub fn with_default_cap() -> Self {
        Self::new(DEFAULT_MEMO_CAP)
    }

    /// Entry capacity.
    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Live entry count (`<= cap`).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when no entry has been inserted yet.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// `(hits, misses)` since construction. Monotone non-decreasing;
    /// every [`lookup`](Self::lookup) increments exactly one side.
    pub fn counters(&self) -> (u64, u64) {
        (self.hits, self.misses)
    }

    /// Start staging a key: clears the reused key buffer.
    pub fn begin(&mut self) {
        self.key_buf.clear();
    }

    /// Stage `f64` words by exact bit pattern (`-0.0 != 0.0`, and every
    /// NaN payload is its own key — bitwise faithfulness over numeric
    /// equality, since the sweeps themselves are bit-deterministic).
    pub fn stage_f64(&mut self, xs: &[f64]) {
        for &x in xs {
            self.key_buf.push(x.to_bits());
        }
    }

    /// Stage `i64` words (the integer lane's quantized joint state).
    pub fn stage_i64(&mut self, xs: &[i64]) {
        for &x in xs {
            self.key_buf.push(x as u64);
        }
    }

    /// Stage one raw word (e.g. a packed format descriptor).
    pub fn stage_word(&mut self, w: u64) {
        self.key_buf.push(w);
    }

    /// FNV-1a over the robot fingerprint and the staged key words.
    fn hash_key(robot_fp: u64, key: &[u64]) -> u64 {
        const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h = (OFFSET ^ robot_fp).wrapping_mul(PRIME);
        for &w in key {
            h = (h ^ w).wrapping_mul(PRIME);
        }
        h
    }

    /// Probe for the staged key. On a hit the entry is promoted to the
    /// MRU front (read it with [`front`](Self::front)) and `hits`
    /// increments; on a miss `misses` increments. The hash is a fast
    /// reject only — a hit additionally requires `robot_fp` equality
    /// and full word-for-word key equality.
    pub fn lookup(&mut self, robot_fp: u64) -> bool {
        let h = Self::hash_key(robot_fp, &self.key_buf);
        let pos = self
            .entries
            .iter()
            .position(|e| e.hash == h && e.robot_fp == robot_fp && e.key == self.key_buf);
        match pos {
            Some(i) => {
                let e = self.entries.remove(i);
                self.entries.insert(0, e);
                self.hits += 1;
                true
            }
            None => {
                self.misses += 1;
                false
            }
        }
    }

    /// Value of the MRU entry — the one a `true` [`lookup`](Self::lookup)
    /// just promoted. Panics if the memo is empty.
    pub fn front(&self) -> &V {
        &self.entries.first().expect("front() on an empty memo").value
    }

    /// Insert `value` under the staged key, evicting from the LRU tail
    /// past capacity. The caller stages the same key it looked up with;
    /// the key buffer is left intact (cloned, not drained).
    pub fn insert(&mut self, robot_fp: u64, value: V) {
        let hash = Self::hash_key(robot_fp, &self.key_buf);
        self.entries.insert(0, Entry { robot_fp, hash, key: self.key_buf.clone(), value });
        self.entries.truncate(self.cap);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stage(memo: &mut KinMemo<u32>, words: &[u64]) {
        memo.begin();
        for &w in words {
            memo.stage_word(w);
        }
    }

    #[test]
    fn hit_returns_inserted_value_and_counts() {
        let mut m: KinMemo<u32> = KinMemo::new(4);
        stage(&mut m, &[1, 2, 3]);
        assert!(!m.lookup(7), "cold lookup must miss");
        m.insert(7, 42);
        stage(&mut m, &[1, 2, 3]);
        assert!(m.lookup(7), "same key must hit");
        assert_eq!(*m.front(), 42);
        assert_eq!(m.counters(), (1, 1));
    }

    #[test]
    fn adjacent_keys_never_alias() {
        // One-lsb-apart quantized states are distinct keys even though
        // their hashes could in principle collide: the full-key compare
        // is what decides a hit.
        let mut m: KinMemo<u32> = KinMemo::new(8);
        stage(&mut m, &[100, 200]);
        m.lookup(1);
        m.insert(1, 10);
        stage(&mut m, &[100, 201]);
        assert!(!m.lookup(1), "adjacent key must not alias");
        m.insert(1, 11);
        stage(&mut m, &[100, 200]);
        assert!(m.lookup(1));
        assert_eq!(*m.front(), 10);
        stage(&mut m, &[100, 201]);
        assert!(m.lookup(1));
        assert_eq!(*m.front(), 11);
    }

    #[test]
    fn robot_fingerprint_partitions_entries() {
        // Same joint words under two different robots (the pool worker
        // cache serves structure-compatible robots) must not alias.
        let mut m: KinMemo<u32> = KinMemo::new(8);
        stage(&mut m, &[5, 6]);
        m.lookup(0xAA);
        m.insert(0xAA, 1);
        stage(&mut m, &[5, 6]);
        assert!(!m.lookup(0xBB), "different robot_fp must miss");
        m.insert(0xBB, 2);
        stage(&mut m, &[5, 6]);
        assert!(m.lookup(0xAA));
        assert_eq!(*m.front(), 1);
    }

    #[test]
    fn evicts_least_recently_used_at_capacity() {
        let mut m: KinMemo<u32> = KinMemo::new(2);
        stage(&mut m, &[1]);
        m.lookup(0);
        m.insert(0, 1);
        stage(&mut m, &[2]);
        m.lookup(0);
        m.insert(0, 2);
        // Touch key [1] so key [2] becomes the LRU tail.
        stage(&mut m, &[1]);
        assert!(m.lookup(0));
        stage(&mut m, &[3]);
        m.lookup(0);
        m.insert(0, 3);
        assert_eq!(m.len(), 2, "capacity bound holds");
        stage(&mut m, &[2]);
        assert!(!m.lookup(0), "LRU entry [2] was evicted");
        stage(&mut m, &[1]);
        assert!(m.lookup(0), "recently-touched entry [1] survived");
        stage(&mut m, &[3]);
        assert!(m.lookup(0), "fresh entry [3] present");
    }

    #[test]
    fn counters_are_monotone_over_random_traffic() {
        // Seeded pseudo-random probe/insert traffic: counters never
        // decrease, exactly one side moves per lookup, and len stays
        // within cap.
        let mut m: KinMemo<u32> = KinMemo::new(3);
        let mut state = 0x9e37_79b9_u64;
        let mut prev = (0u64, 0u64);
        for step in 0..500 {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let key = state >> 56; // small space forces hits AND evictions
            stage(&mut m, &[key]);
            let hit = m.lookup(0);
            if !hit {
                m.insert(0, step as u32);
            }
            let now = m.counters();
            assert!(now.0 >= prev.0 && now.1 >= prev.1, "counters monotone");
            assert_eq!(now.0 + now.1, prev.0 + prev.1 + 1, "one side per lookup");
            assert!(m.len() <= m.cap(), "len within cap");
            prev = now;
        }
        assert!(prev.0 > 0, "small key space must produce some hits");
        assert!(prev.1 > 0, "and some misses");
    }

    #[test]
    fn stage_f64_distinguishes_bit_patterns() {
        let mut m: KinMemo<u32> = KinMemo::new(4);
        m.begin();
        m.stage_f64(&[0.0]);
        m.lookup(0);
        m.insert(0, 1);
        m.begin();
        m.stage_f64(&[-0.0]);
        assert!(!m.lookup(0), "-0.0 is a distinct bit pattern from 0.0");
    }
}
