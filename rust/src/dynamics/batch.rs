//! Batched evaluation over the workspace core — the CPU counterpart of
//! the accelerator's batched RTP operation (tasks streamed back-to-back
//! through resident pipelines). One [`DynWorkspace`] is reused for a whole
//! batch; the threaded variant gives each worker thread its own
//! workspace, so the hot loop performs zero heap allocation per task.

use super::workspace::DynWorkspace;
use crate::model::Robot;
use crate::spatial::DMat;

/// Which RBD function a batch evaluates (mirrors the servable artifact
/// functions of the PJRT path).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchKernel {
    /// τ = RNEA(q, q̇, q̈): `u` holds q̈.
    Rnea,
    /// q̈ = FD(q, q̇, τ): `u` holds τ.
    Fd,
    /// M⁻¹(q): `u` ignored.
    Minv,
    /// Fused multi-output dynamics at one (q, q̇): `u` holds τ; the
    /// output is the flat `[q̈ (N) | M⁻¹ (N·N) | C (N)]` egress of
    /// [`DynWorkspace::dyn_all_into`].
    DynAll,
}

/// One task: a joint state plus the third operand (`u` = q̈ for RNEA,
/// τ for FD, ignored for Minv).
#[derive(Debug, Clone)]
pub struct BatchTask {
    pub q: Vec<f64>,
    pub qd: Vec<f64>,
    pub u: Vec<f64>,
}

/// Per-task result: a joint-space vector (RNEA/FD) or matrix (Minv).
#[derive(Debug, Clone)]
pub enum BatchOutput {
    Vector(Vec<f64>),
    Matrix(DMat),
}

impl BatchOutput {
    pub fn as_vector(&self) -> Option<&[f64]> {
        match self {
            BatchOutput::Vector(v) => Some(v),
            BatchOutput::Matrix(_) => None,
        }
    }

    pub fn as_matrix(&self) -> Option<&DMat> {
        match self {
            BatchOutput::Matrix(m) => Some(m),
            BatchOutput::Vector(_) => None,
        }
    }
}

/// Evaluate one task into a fresh output, reusing `ws` for all scratch.
pub(crate) fn eval_one(
    robot: &Robot,
    kernel: BatchKernel,
    ws: &mut DynWorkspace,
    task: &BatchTask,
) -> BatchOutput {
    let n = robot.dof();
    match kernel {
        BatchKernel::Rnea => {
            let mut tau = vec![0.0; n];
            ws.rnea_into(robot, &task.q, &task.qd, &task.u, None, &mut tau);
            BatchOutput::Vector(tau)
        }
        BatchKernel::Fd => {
            let mut qdd = vec![0.0; n];
            ws.fd_into(robot, &task.q, &task.qd, &task.u, None, &mut qdd);
            BatchOutput::Vector(qdd)
        }
        BatchKernel::Minv => {
            let mut out = DMat::zeros(n, n);
            ws.minv_into(robot, &task.q, &mut out);
            BatchOutput::Matrix(out)
        }
        BatchKernel::DynAll => {
            let mut out = vec![0.0; n * n + 2 * n];
            ws.dyn_all_into(robot, &task.q, &task.qd, &task.u, None, &mut out);
            BatchOutput::Vector(out)
        }
    }
}

/// Evaluate a batch of tasks on the calling thread with one reused
/// workspace. Output order matches task order.
pub fn eval_batch(robot: &Robot, kernel: BatchKernel, tasks: &[BatchTask]) -> Vec<BatchOutput> {
    let mut ws = DynWorkspace::new(robot);
    tasks.iter().map(|t| eval_one(robot, kernel, &mut ws, t)).collect()
}

/// Evaluate a batch across the **persistent** worker pool
/// ([`super::pool::WorkerPool`]), split into at most `threads` contiguous
/// chunks so outputs land in task order without any post-hoc sort.
///
/// Earlier revisions spawned fresh threads per batch via
/// `std::thread::scope`; the pool removes that per-batch respawn from
/// the serving hot path. This convenience entry pays one copy of `tasks`
/// into a shared `Arc<[BatchTask]>` — callers that already hold `Arc`s
/// (or flat f32 operands) should use [`super::pool::WorkerPool`]'s
/// `eval_shared` / `eval_flat` directly. Results are identical to
/// [`eval_batch`] (same kernels, one workspace per worker).
pub fn eval_batch_par(
    robot: &Robot,
    kernel: BatchKernel,
    tasks: &[BatchTask],
    threads: usize,
) -> Vec<BatchOutput> {
    let threads = threads.max(1).min(tasks.len().max(1));
    if threads <= 1 {
        return eval_batch(robot, kernel, tasks);
    }
    super::pool::WorkerPool::global().eval(robot, kernel, tasks, threads)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dynamics::{fd, minv, rnea};
    use crate::model::{builtin, State};
    use crate::util::check::assert_slices_close;
    use crate::util::rng::Rng;

    fn random_tasks(robot: &Robot, count: usize, seed: u64) -> Vec<BatchTask> {
        let n = robot.dof();
        let mut rng = Rng::new(seed);
        (0..count)
            .map(|_| {
                let s = State::random(robot, &mut rng);
                BatchTask { q: s.q, qd: s.qd, u: rng.vec_range(n, -8.0, 8.0) }
            })
            .collect()
    }

    #[test]
    fn batch_matches_per_task_eval() {
        let robot = builtin::hyq();
        let tasks = random_tasks(&robot, 17, 600);
        let out = eval_batch(&robot, BatchKernel::Fd, &tasks);
        assert_eq!(out.len(), tasks.len());
        for (task, got) in tasks.iter().zip(&out) {
            let want = fd(&robot, &task.q, &task.qd, &task.u, None);
            assert_slices_close(got.as_vector().unwrap(), &want, 1e-9, "batch fd");
        }
        let out = eval_batch(&robot, BatchKernel::Rnea, &tasks);
        for (task, got) in tasks.iter().zip(&out) {
            let want = rnea(&robot, &task.q, &task.qd, &task.u, None);
            assert_slices_close(got.as_vector().unwrap(), &want, 1e-12, "batch rnea");
        }
        let out = eval_batch(&robot, BatchKernel::Minv, &tasks);
        for (task, got) in tasks.iter().zip(&out) {
            let want = minv(&robot, &task.q);
            let err = got.as_matrix().unwrap().sub(&want).max_abs();
            assert!(err < 1e-9, "batch minv err {err}");
        }
    }

    #[test]
    fn threaded_batch_matches_single_thread() {
        let robot = builtin::iiwa();
        let tasks = random_tasks(&robot, 33, 601);
        let single = eval_batch(&robot, BatchKernel::Fd, &tasks);
        for threads in [2, 3, 8, 64] {
            let par = eval_batch_par(&robot, BatchKernel::Fd, &tasks, threads);
            assert_eq!(par.len(), single.len());
            for (a, b) in single.iter().zip(&par) {
                // Same kernel, same workspace semantics ⇒ bitwise equal.
                assert_eq!(a.as_vector().unwrap(), b.as_vector().unwrap());
            }
        }
    }

    #[test]
    fn dyn_all_batch_matches_fused_kernel() {
        let robot = builtin::iiwa();
        let n = robot.dof();
        let tasks = random_tasks(&robot, 9, 602);
        let out = eval_batch(&robot, BatchKernel::DynAll, &tasks);
        let mut ws = DynWorkspace::new(&robot);
        for (task, got) in tasks.iter().zip(&out) {
            let mut want = vec![0.0; n * n + 2 * n];
            ws.dyn_all_into(&robot, &task.q, &task.qd, &task.u, None, &mut want);
            assert_eq!(got.as_vector().unwrap(), &want[..]);
        }
        let par = eval_batch_par(&robot, BatchKernel::DynAll, &tasks, 4);
        for (a, b) in out.iter().zip(&par) {
            assert_eq!(a.as_vector().unwrap(), b.as_vector().unwrap());
        }
    }

    #[test]
    fn empty_batch_is_fine() {
        let robot = builtin::iiwa();
        assert!(eval_batch(&robot, BatchKernel::Fd, &[]).is_empty());
        assert!(eval_batch_par(&robot, BatchKernel::Fd, &[], 8).is_empty());
    }
}
