//! # DRACO reproduction library
//!
//! A three-layer (Rust + JAX + Pallas) reproduction of *DRACO: Co-design
//! for DSP-Efficient Rigid Body Dynamics Accelerator* (CS.AR 2025).
//!
//! * [`spatial`] / [`model`] / [`dynamics`] — a from-scratch rigid-body-
//!   dynamics library (the Pinocchio-equivalent substrate + CPU baseline),
//!   including the allocation-free workspace core
//!   ([`dynamics::DynWorkspace`]) and the batched evaluation API.
//! * [`quant`] — the paper's precision-aware quantization framework,
//!   including the true-integer `i64` kernel lane and the fixed-point
//!   scaling analysis ([`quant::scaling`]) that certifies per-joint
//!   shift schedules for the division-deferring integer M⁻¹.
//! * [`control`] / [`sim`] — PID/LQR/MPC controllers and the ICMS
//!   closed-loop control & motion simulator.
//! * [`accel`] — the FPGA accelerator cycle model (RTP pipelines, division
//!   deferring, inter-module DSP reuse) used to regenerate the paper's
//!   evaluation figures.
//! * [`runtime`] / [`coordinator`] — the serving path: a multi-robot
//!   registry routing to per-robot backends (the f64 native workspace
//!   engine, the rounded fixed-point engine at a per-robot `QFormat`,
//!   the true-integer `qint` engine gated by the scaling analysis, or
//!   AOT-compiled HLO artifacts via PJRT behind the `pjrt` feature),
//!   with dynamic batching and server-side trajectory rollouts. See
//!   `docs/architecture.md` and `docs/serving.md`.
//! * [`net`] — the streaming JSONL TCP front-end: lazy hot-field request
//!   parsing, chunked trajectory egress, raw-JSONL record (`--tee`) and
//!   bitwise replay (`draco replay`).
//! * [`obs`] — observability: per-request spans exported as Chrome
//!   trace JSON (`serve --trace`), the atomic metrics registry with
//!   per-stage latency histograms, and the live `stats` wire route
//!   (`draco stats`). See `docs/observability.md`.
//! * [`util`] — offline substrates (JSON, RNG, property tests, CLI, bench).

pub mod accel;
pub mod coordinator;
pub mod control;
pub mod dynamics;
pub mod model;
pub mod net;
pub mod obs;
pub mod quant;
pub mod runtime;
pub mod sim;
pub mod spatial;
pub mod util;
