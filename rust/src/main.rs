//! `draco` CLI — leader entrypoint for the reproduction.
//!
//! Subcommands:
//! * `export-robots [--out DIR]` — write the builtin robot descriptions
//!   as JSON (consumed by the Python compile path).
//! * `info --robot NAME` — topology/inertia summary.
//! * `estimate [--robot NAME]` — accelerator cycle-model estimates for
//!   every design × function (Fig. 10-style table).
//! * `quantize --robot NAME --controller pid|lqr|mpc [--tol MET]
//!   [--emit-spec]` — run the bit-width search (paper §III);
//!   `--emit-spec` closes the search → serving loop by printing a
//!   ready-to-paste registry spec line: `NAME:qint@I.F` when the
//!   fixed-point scaling analysis proves the chosen format for the
//!   integer lane, `NAME:quant@I.F` (rounded-f64 lane) when it rejects
//!   it — with the overflow witness explaining why.
//! * `rates [--robot NAME]` — estimated control rates (Fig. 13).
//! * `serve [--robots SPEC] [--backend native|pjrt] [--batch B]
//!   [--traj H] [--par P]` — start the batched serving coordinator and
//!   run a synthetic workload through it. `--robots` takes a registry
//!   spec such as `iiwa,atlas:qint@12.14,hyq:quant@12.10+comp,arm=path.urdf`:
//!   one coordinator serves all listed robots concurrently, each on its
//!   own backend (f64 native, the rounded quantized engine at a
//!   per-robot Q-format with `+comp` adding the fitted M⁻¹ error
//!   compensation, or the true-integer `qint` engine — gated by the
//!   fixed-point scaling analysis at registration);
//!   `name=path.urdf` entries load robots through the URDF-lite
//!   importer. Every robot gets the rnea/fd/minv step routes plus the
//!   fused `dyn_all` route (q̈ ‖ M⁻¹ ‖ C from one kinematics pass,
//!   with a cross-request kinematics memo whose hit/miss counters the
//!   workload prints) and a trajectory route. `--traj H` additionally
//!   exercises trajectory batch
//!   requests (H-step rollouts unrolled server-side); `--par P` fans
//!   each step route's batches — native and quantized alike — out
//!   across the worker pool (0 = one chunk per core; rollouts stay
//!   serial). The default `native` backend
//!   serves from the allocation-free workspace cores (no artifacts
//!   needed); `pjrt` executes AOT artifacts and requires
//!   `--features pjrt` plus `--artifacts DIR`. Registry entries take an
//!   optional `!control`/`!interactive`/`!bulk` suffix selecting the
//!   route's QoS class (e.g. `iiwa!control,atlas:quant@12.12!bulk`).
//!   See docs/serving.md.
//! * `loadgen [--rate R] [--ramp] [--classes MIX] [--smoke] [--faults]`
//!   — open-loop Poisson overload harness against a capacity-pinned
//!   route: per-class p50/p99/p99.9, shed rate, retry counts, goodput
//!   vs offered load; writes `rust/BENCH_serve.json`. `--smoke` is the
//!   short CI mode asserting the overload invariants (no expired job
//!   executed, monotone shedding, Control-p99 bound, breaker
//!   recovery). Network scenarios drive the JSONL wire over real
//!   sockets: single-connection Poisson arrivals, multi-client
//!   overlapping-id routing, seeded fault injection, and retry/backoff
//!   recovery; `--faults` runs only the fault suite (the CI fault
//!   gate).
//! * `serve --listen ADDR [--tee PATH]` — additionally bring up the
//!   streaming JSONL TCP front-end (chunked trajectory egress, lazy
//!   hot-field parsing) and self-drive it; `--tee` records the raw
//!   wire traffic for `draco replay`.
//! * `replay LOG` — re-execute a `--tee` capture offline and assert the
//!   replayed response payloads are bitwise identical to the recorded
//!   ones (timing-dependent refusals are skipped). See docs/serving.md.
//! * `stats ADDR | stats --trace-file PATH` — live-metrics client for a
//!   serving `--listen` endpoint (requests a `stats` frame over the
//!   JSONL wire and renders it Prometheus-style), or validator for a
//!   `serve --trace PATH` Chrome trace-event export (counts complete
//!   job spans; nonzero exit on invalid/empty traces — the CI trace
//!   smoke gate). See docs/observability.md.

use draco::accel::{self, designs::RbdFn, Design};
use draco::model::{builtin_robot, robot_registry};
use draco::quant::search::{search, Requirements};
use draco::sim::icms::ControllerKind;
use draco::util::bench::Table;
use draco::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let code = match args.subcommand.as_deref() {
        Some("export-robots") => cmd_export(&args),
        Some("info") => cmd_info(&args),
        Some("estimate") => cmd_estimate(&args),
        Some("quantize") => cmd_quantize(&args),
        Some("rates") => cmd_rates(&args),
        Some("serve") => draco::coordinator::serve_cli(&args),
        Some("loadgen") => draco::coordinator::loadgen::loadgen_cli(&args),
        Some("replay") => draco::net::replay_cli(&args),
        Some("stats") => draco::obs::stats_cli(&args),
        _ => {
            eprintln!(
                "usage: draco <export-robots|info|estimate|quantize|rates|serve|loadgen|replay|stats> [options]"
            );
            2
        }
    };
    std::process::exit(code);
}

fn robot_or_die(args: &Args) -> draco::model::Robot {
    let name = args.opt_or("robot", "iiwa");
    builtin_robot(name).unwrap_or_else(|| {
        eprintln!("unknown robot '{name}' (try iiwa|hyq|atlas|baxter)");
        std::process::exit(2);
    })
}

fn cmd_export(args: &Args) -> i32 {
    let out = args.opt_or("out", "data/robots");
    std::fs::create_dir_all(out).expect("mkdir");
    for (name, f) in robot_registry() {
        let path = format!("{out}/{name}.json");
        std::fs::write(&path, f().to_json().pretty()).expect("write robot json");
        println!("wrote {path}");
    }
    0
}

fn cmd_info(args: &Args) -> i32 {
    let r = robot_or_die(args);
    println!("robot: {} — {} DOF, max chain {}", r.name, r.dof(), r.max_chain_len());
    let mut t = Table::new(&["#", "link", "parent", "type", "mass", "depth"]);
    for (i, l) in r.links.iter().enumerate() {
        t.row(&[
            i.to_string(),
            l.name.clone(),
            l.parent.map(|p| p.to_string()).unwrap_or_else(|| "base".into()),
            l.joint.type_name().to_string(),
            format!("{:.2}", l.inertia.mass),
            r.depth(i).to_string(),
        ]);
    }
    t.print("topology");
    0
}

fn cmd_estimate(args: &Args) -> i32 {
    let r = robot_or_die(args);
    let mut t = Table::new(&["design", "fn", "lat(us)", "tput(k/s)", "batch256(us)", "dsp"]);
    for design in [Design::draco(&r), Design::dadu_rbd(&r), Design::roboshape(&r)] {
        for f in RbdFn::ALL {
            let p = accel::estimate(&design, &r, f);
            t.row(&[
                design.name.to_string(),
                f.name().to_string(),
                format!("{:.2}", p.latency_us),
                format!("{:.0}", p.throughput / 1e3),
                format!("{:.1}", p.batch256_us),
                p.dsp_active.to_string(),
            ]);
        }
    }
    t.print(&format!("cycle-model estimates — {}", r.name));
    let rr = accel::reuse_report(&Design::draco(&r), &r);
    println!(
        "\ninter-module DSP reuse: {} DSPs with reuse, {} without ({:.1}% saved)",
        rr.dsp_with,
        rr.dsp_without,
        rr.savings_frac * 100.0
    );
    0
}

fn cmd_quantize(args: &Args) -> i32 {
    let r = robot_or_die(args);
    let controller = match args.opt_or("controller", "pid") {
        "lqr" => ControllerKind::Lqr,
        "mpc" => ControllerKind::Mpc,
        _ => ControllerKind::Pid,
    };
    let req = Requirements {
        traj_tol: args.opt_f64("tol", 5e-4),
        ..Default::default()
    };
    let steps = args.opt_usize("steps", 800);
    println!(
        "searching bit-widths for {} / {} (tol {} m, {} sim steps)…",
        r.name,
        controller.name(),
        req.traj_tol,
        steps
    );
    let out = search(&r, controller, &req, steps, 7);
    let mut t = Table::new(&["format", "gate rms", "traj err(mm)", "verdict"]);
    for (fmt, gate, sim, ok) in &out.trials {
        t.row(&[
            fmt.label(),
            format!("{gate:.4}"),
            sim.map(|e| format!("{:.4}", e * 1e3)).unwrap_or_else(|| "pruned".into()),
            if *ok { "ACCEPT".into() } else { "reject".into() },
        ]);
    }
    t.print("bit-width search");
    match out.chosen {
        Some(f) => println!("chosen format: {}", f.label()),
        None => println!("no candidate met the tolerance; fall back to float"),
    }
    if args.flag("emit-spec") {
        // Close the search → serving loop: print the spec line `serve
        // --robots` accepts verbatim. The integer lane wins when the
        // scaling analysis proves the format; otherwise the rounded-f64
        // lane serves it and the witness says why.
        match out.chosen {
            Some(f) => match draco::quant::scaling::validate_int_backend(&r, f) {
                Ok(sched) => {
                    println!(
                        "\nregistry spec (integer lane; max hold shift {}):",
                        sched.max_hold_shift()
                    );
                    println!("{}:qint@{}.{}", r.name, f.int_bits, f.frac_bits);
                }
                Err(e) => {
                    println!("\nregistry spec (rounded-f64 lane — integer lane rejected: {e}):");
                    println!("{}:quant@{}.{}", r.name, f.int_bits, f.frac_bits);
                }
            },
            None => {
                println!("\nregistry spec (no format met the tolerance; serve f64):");
                println!("{}:native", r.name);
            }
        }
    }
    0
}

fn cmd_rates(args: &Args) -> i32 {
    let r = robot_or_die(args);
    let iters = args.opt_usize("iters", 10);
    let mut t = Table::new(&["platform", "traj=10", "traj=20", "traj=40", "traj=80"]);
    let rows: Vec<(&str, accel::control_rate::PlatformTimes)> = vec![
        ("cpu", accel::control_rate::PlatformTimes::cpu_default(&r)),
        (
            "dadu-rbd(v80)",
            accel::control_rate::PlatformTimes::from_design(&Design::dadu_rbd_on_v80(&r), &r),
        ),
        ("draco", accel::control_rate::PlatformTimes::from_design(&Design::draco(&r), &r)),
    ];
    for (name, times) in rows {
        t.row(&[
            name.to_string(),
            format!("{:.0}", accel::control_rate::control_rate_hz(&times, 10, iters)),
            format!("{:.0}", accel::control_rate::control_rate_hz(&times, 20, iters)),
            format!("{:.0}", accel::control_rate::control_rate_hz(&times, 40, iters)),
            format!("{:.0}", accel::control_rate::control_rate_hz(&times, 80, iters)),
        ]);
    }
    t.print(&format!("estimated control rates [Hz] — {} ({} MPC iters)", r.name, iters));
    0
}
