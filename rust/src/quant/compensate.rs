//! Quantization error compensation (paper §III-C "Error Compensation",
//! Fig. 5(d)): fixed-pattern corrections for operations whose numerical
//! distortion is structural rather than trajectory-dependent.
//!
//! The representative case is Minv: the quantized reciprocal of D_i
//! biases the *diagonal* of M⁻¹, and the off-diagonals inherit that bias
//! because they are computed from the diagonal terms. The compensation is
//! a per-robot offset matrix fitted over sampled configurations inside
//! the simulation loop and exported with the bit-width configuration for
//! RTL integration.

use super::qformat::QFormat;
use super::qrbd::quant_minv;
use crate::dynamics::minv;
use crate::model::{Robot, State};
use crate::spatial::DMat;
use crate::util::rng::Rng;

/// Fitted compensation: an additive offset applied to quantized M⁻¹.
/// `diagonal_only` reflects the paper's targeted correction.
#[derive(Debug, Clone)]
pub struct MinvCompensation {
    pub offset: DMat,
    pub fmt: QFormat,
}

impl MinvCompensation {
    /// Fit the offset as the mean signed error E[M⁻¹_exact − M⁻¹_quant]
    /// over `samples` random configurations, restricted to the diagonal
    /// (the main error-propagation source; see Fig. 5(d) discussion).
    pub fn fit(robot: &Robot, fmt: QFormat, samples: usize, rng: &mut Rng) -> MinvCompensation {
        let n = robot.dof();
        let mut acc = DMat::zeros(n, n);
        for _ in 0..samples {
            let s = State::random(robot, rng);
            let exact = minv(robot, &s.q);
            let quant = quant_minv(robot, &s.q, fmt);
            let err = exact.sub(&quant);
            acc = acc.add(&err);
        }
        acc = acc.scale(1.0 / samples as f64);
        // Keep only the diagonal: targeted correction.
        let mut offset = DMat::zeros(n, n);
        for i in 0..n {
            offset[(i, i)] = acc[(i, i)];
        }
        MinvCompensation { offset, fmt }
    }

    /// Apply: M̂⁻¹ = quantized M⁻¹ + offset.
    pub fn apply(&self, quant_minv: &DMat) -> DMat {
        quant_minv.add(&self.offset)
    }
}

/// Before/after error summary for one configuration (drives Fig. 5(d)).
#[derive(Debug, Clone, Copy)]
pub struct CompensationReport {
    pub frobenius_before: f64,
    pub frobenius_after: f64,
    pub offdiag_mean_before: f64,
    pub offdiag_mean_after: f64,
    pub diag_mean_before: f64,
    pub diag_mean_after: f64,
}

pub fn evaluate_compensation(
    robot: &Robot,
    comp: &MinvCompensation,
    samples: usize,
    rng: &mut Rng,
) -> CompensationReport {
    let n = robot.dof();
    let mut fro_b = 0.0;
    let mut fro_a = 0.0;
    let (mut ob, mut oa, mut db, mut da) = (0.0, 0.0, 0.0, 0.0);
    let offdiag_count = (n * n - n) as f64;
    for _ in 0..samples {
        let s = State::random(robot, rng);
        let exact = minv(robot, &s.q);
        let quant = quant_minv(robot, &s.q, comp.fmt);
        let fixed = comp.apply(&quant);
        let err_b = exact.sub(&quant);
        let err_a = exact.sub(&fixed);
        fro_b += err_b.frobenius();
        fro_a += err_a.frobenius();
        for i in 0..n {
            for j in 0..n {
                if i == j {
                    db += err_b[(i, j)].abs();
                    da += err_a[(i, j)].abs();
                } else {
                    ob += err_b[(i, j)].abs();
                    oa += err_a[(i, j)].abs();
                }
            }
        }
    }
    let s = samples as f64;
    CompensationReport {
        frobenius_before: fro_b / s,
        frobenius_after: fro_a / s,
        offdiag_mean_before: ob / (s * offdiag_count),
        offdiag_mean_after: oa / (s * offdiag_count),
        diag_mean_before: db / (s * n as f64),
        diag_mean_after: da / (s * n as f64),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::builtin;

    /// The paper's headline compensation result (Fig. 5(d)): Frobenius
    /// error drops substantially (4.97 → 1.65 in the paper); a small
    /// off-diagonal increase is acceptable.
    #[test]
    fn compensation_reduces_frobenius_error() {
        let robot = builtin::iiwa();
        let fmt = QFormat::new(10, 8); // coarse: visible reciprocal error
        let mut rng = Rng::new(700);
        let comp = MinvCompensation::fit(&robot, fmt, 24, &mut rng);
        let rep = evaluate_compensation(&robot, &comp, 16, &mut rng);
        assert!(
            rep.frobenius_after < rep.frobenius_before,
            "Frobenius {} → {} must improve",
            rep.frobenius_before,
            rep.frobenius_after
        );
        assert!(
            rep.diag_mean_after < rep.diag_mean_before,
            "diagonal error must shrink (targeted correction)"
        );
    }

    #[test]
    fn offset_is_diagonal() {
        let robot = builtin::iiwa();
        let mut rng = Rng::new(701);
        let comp = MinvCompensation::fit(&robot, QFormat::new(10, 8), 8, &mut rng);
        let n = robot.dof();
        for i in 0..n {
            for j in 0..n {
                if i != j {
                    assert_eq!(comp.offset[(i, j)], 0.0);
                }
            }
        }
    }

    #[test]
    fn compensation_nearly_noop_at_high_precision() {
        let robot = builtin::iiwa();
        let mut rng = Rng::new(702);
        let comp = MinvCompensation::fit(&robot, QFormat::new(16, 24), 8, &mut rng);
        // Offset scales with the reciprocal error ~ (1/D)²·ε; for the
        // iiwa wrist (1/D ≈ 5e2) and 24 frac bits that is ≲ 2e-2.
        assert!(comp.offset.max_abs() < 2e-2, "fine format ⇒ tiny offset: {}", comp.offset.max_abs());
    }
}
