//! Fixed-point Q-format emulation.
//!
//! A `QFormat { int_bits, frac_bits }` value models signed fixed point
//! with `int_bits` integer bits (sign included) and `frac_bits`
//! fractional bits — total word width `int_bits + frac_bits`, matching
//! the paper's notation ("24-bit (12 int / 12 frac)"). Because every
//! representable value is a dyadic rational with ≤ 53 significant bits,
//! f64 emulation of round-to-nearest + saturation is *exact*.

/// A fixed-point format.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QFormat {
    /// Integer bits, sign included.
    pub int_bits: u32,
    /// Fractional bits.
    pub frac_bits: u32,
}

impl QFormat {
    pub const fn new(int_bits: u32, frac_bits: u32) -> QFormat {
        QFormat { int_bits, frac_bits }
    }

    /// Total word width in bits.
    pub fn width(&self) -> u32 {
        self.int_bits + self.frac_bits
    }

    /// Quantization step 2^-frac.
    pub fn step(&self) -> f64 {
        (2.0_f64).powi(-(self.frac_bits as i32))
    }

    /// Worst-case rounding error ε = 2^-(frac+1)  (paper Eq. 3).
    pub fn eps(&self) -> f64 {
        0.5 * self.step()
    }

    /// Largest representable magnitude.
    pub fn max_val(&self) -> f64 {
        (2.0_f64).powi(self.int_bits as i32 - 1) - self.step()
    }

    /// Round-to-nearest + saturate.
    pub fn q(&self, x: f64) -> f64 {
        let scaled = (x * (1u64 << self.frac_bits) as f64).round();
        let v = scaled * self.step();
        v.clamp(-self.max_val() - self.step(), self.max_val())
    }

    /// Quantize a slice in place.
    pub fn q_slice(&self, xs: &mut [f64]) {
        for x in xs {
            *x = self.q(*x);
        }
    }

    pub fn q_vec(&self, xs: &[f64]) -> Vec<f64> {
        xs.iter().map(|&x| self.q(x)).collect()
    }

    /// DSP cost per MAC for this word width, per the paper §III-A/§V-B:
    /// ≤18-bit → 1 DSP48; ≤24-bit → 1 DSP58 (V80) but 2 DSP48;
    /// 25–32-bit → 4 DSP48 slices (the baselines' 32-bit fixed point).
    pub fn dsp_per_mac(&self, dsp58: bool) -> u32 {
        let w = self.width();
        if w <= 18 {
            1
        } else if w <= 24 {
            if dsp58 { 1 } else { 2 }
        } else {
            4
        }
    }

    pub fn label(&self) -> String {
        format!("{}b({}.{})", self.width(), self.int_bits, self.frac_bits)
    }
}

/// The formats the paper's framework prioritizes for FPGA DSP word sizes.
pub const FPGA_FORMATS: &[QFormat] = &[
    QFormat::new(10, 8),  // 18-bit
    QFormat::new(12, 12), // 24-bit
    QFormat::new(16, 16), // 32-bit (baseline precision)
];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::Config;

    #[test]
    fn rounding_error_bounded_by_eps() {
        let f = QFormat::new(12, 12);
        crate::util::check::forall(
            "quant-eps",
            Config::default(),
            |r| r.range(-100.0, 100.0),
            |&x| (x - f.q(x)).abs() <= f.eps() + 1e-15,
        );
    }

    #[test]
    fn representable_values_fixed_points() {
        let f = QFormat::new(8, 8);
        for x in [-1.0, 0.0, 0.5, 1.25, -3.75, 127.0] {
            assert_eq!(f.q(x), x, "{x} is exactly representable");
            assert_eq!(f.q(f.q(x)), f.q(x), "idempotent");
        }
    }

    #[test]
    fn saturation() {
        let f = QFormat::new(8, 8); // max ≈ 127.996
        assert!(f.q(1e6) <= f.max_val());
        assert!(f.q(-1e6) >= -f.max_val() - f.step());
        assert_eq!(f.q(1e6), f.max_val());
    }

    #[test]
    fn monotone() {
        let f = QFormat::new(10, 6);
        let mut r = crate::util::rng::Rng::new(50);
        for _ in 0..1000 {
            let a = r.range(-500.0, 500.0);
            let b = r.range(-500.0, 500.0);
            let (lo, hi) = if a < b { (a, b) } else { (b, a) };
            assert!(f.q(lo) <= f.q(hi), "quantization must be monotone");
        }
    }

    #[test]
    fn dsp_costs_match_paper() {
        assert_eq!(QFormat::new(10, 8).dsp_per_mac(false), 1); // 18b DSP48
        assert_eq!(QFormat::new(12, 12).dsp_per_mac(true), 1); // 24b DSP58
        assert_eq!(QFormat::new(12, 12).dsp_per_mac(false), 2);
        assert_eq!(QFormat::new(16, 16).dsp_per_mac(false), 4); // 32b: 4 DSP48
    }

    #[test]
    fn finer_format_never_worse() {
        let coarse = QFormat::new(12, 8);
        let fine = QFormat::new(12, 16);
        let mut r = crate::util::rng::Rng::new(51);
        for _ in 0..1000 {
            let x = r.range(-100.0, 100.0);
            assert!((x - fine.q(x)).abs() <= (x - coarse.q(x)).abs() + fine.eps());
        }
    }
}
