//! True-integer fixed-point lane: the RBD kernels evaluated over `i64`
//! words instead of rounded f64s.
//!
//! The legacy lane ([`super::qrbd`]) *emulates* fixed point by rounding
//! f64 intermediates — faithful error behaviour, but every "cheap" MAC
//! still runs the full double-precision datapath plus a rounding call.
//! This lane is the software analogue of actually running narrow: values
//! are **scaled once on ingest** (`x → round(x·2^f)` as `i64`), every
//! inner loop is integer multiply + shift-renormalize over flat
//! `[i64; 36]` 6×6 blocks (mirroring [`crate::spatial::mat6`]) and
//! `[i64; 6]` spatial vectors, and results are **dequantized once on
//! egress**. Per-robot constants (inertia blocks, the gravity
//! acceleration) are quantized once per `(robot, format)` and cached in
//! the scratch — the BRAM/LUT constants of the accelerator, written once
//! — instead of being re-rounded on every task like the legacy lane.
//!
//! Numerics: each block operation accumulates exact `i64` products at
//! 2f fractional bits and renormalizes once per output entry with
//! **round-half-away-from-zero** — bit-compatible with
//! [`QFormat::q`]'s rounding (see the boundary-value regression tests:
//! a naive `(p + half) >> f` would truncate negative ties toward −∞ and
//! silently diverge from the legacy lane on shared vectors). Because the
//! datapath renormalizes after every operation (as a width-f register
//! file forces in hardware) rather than once per f64 expression group,
//! the lane's trajectories differ from the legacy lane in the last
//! units — but the error *envelope* matches the same format, which is
//! what the bit-width search certifies.
//!
//! Supported word widths are capped at [`MAX_INT_WIDTH`] bits so that a
//! 6-term accumulation of 2f-bit products can never overflow `i64` (and
//! products stay exactly representable for the f64 cross-checks); the
//! paper's DSP-friendly 18/24-bit formats sit comfortably inside. Wider
//! formats (e.g. the 32-bit baseline) keep using the legacy lane.
//!
//! Two integer M⁻¹ sweeps exist. [`QuantIntScratch::minv_into`] keeps
//! the reciprocal on Algorithm 1's inline path through
//! [`QInt::recip_fix`] — the shared-divider emulation (dequantize, one
//! f64 reciprocal, requantize). [`QuantIntScratch::minv_dd_into`] is the
//! **division-deferring** Algorithm 2 port: the backward sweep carries
//! the holding products `N = D·IA − U Uᵀ` and `G = D·F + U·row`, every
//! reciprocal moves off the recurrence onto the shared divider, and the
//! deferred multiply by `1/D` restores the format one stage later. The
//! holding products are `Λ²`-sized and would overflow narrow words, so
//! each joint renormalizes them to `frac − g` bits using the per-joint
//! shifts of a [`super::scaling::ShiftSchedule`] — the word reinterpreted
//! as `Q(int+g).(frac−g)` for exactly the holding stage, as a DSP
//! datapath would re-scale its product register. The schedule is the
//! proof that every such stage fits; callers obtain one from
//! [`super::scaling::analyze`] (serving backends validate at
//! registration and panic-free-ness follows).

use super::qformat::QFormat;
use super::scaling::ShiftSchedule;
use crate::dynamics::kinematics::Kin;
use crate::dynamics::minv::Topology;
use crate::model::Robot;
use crate::spatial::mat6::M6;
use crate::spatial::{DMat, SV, V3};

/// Widest supported word (int + frac bits). 6-term accumulations of
/// 2f-bit products need `2·width + 3 ≤ 63` bits; capping at 26 also
/// keeps every product ≤ 2^52, exactly representable in f64 for the
/// equivalence tests.
pub const MAX_INT_WIDTH: u32 = 26;

/// Integer quantization context for one [`QFormat`]: ingest/egress
/// scaling, saturation bounds, and the 2f→f renormalization.
#[derive(Debug, Clone, Copy)]
pub struct QInt {
    /// The format this context realizes.
    pub fmt: QFormat,
    f: u32,
    min: i64,
    max: i64,
    scale: f64,
    inv_scale: f64,
}

impl QInt {
    /// Build a context; panics on formats the integer lane cannot carry
    /// (see [`MAX_INT_WIDTH`]).
    pub fn new(fmt: QFormat) -> QInt {
        let w = fmt.width();
        assert!(fmt.int_bits >= 1, "need at least a sign bit");
        assert!(
            (2..=MAX_INT_WIDTH).contains(&w),
            "integer lane supports 2..={MAX_INT_WIDTH}-bit words, got {w}; \
             use the rounded-f64 lane (quant::qrbd) for wider formats"
        );
        let f = fmt.frac_bits;
        QInt {
            fmt,
            f,
            min: -(1i64 << (w - 1)),
            max: (1i64 << (w - 1)) - 1,
            scale: (1i64 << f) as f64,
            inv_scale: (2.0f64).powi(-(f as i32)),
        }
    }

    /// Ingest: round-half-away-from-zero + saturate, identical to
    /// [`QFormat::q`] on every finite input (regression-tested at the
    /// tie and saturation boundaries).
    #[inline]
    pub fn to_fix(&self, x: f64) -> i64 {
        // `as i64` saturates on overflow/NaN per Rust cast semantics;
        // the clamp then enforces the word width.
        let v = (x * self.scale).round() as i64;
        v.clamp(self.min, self.max)
    }

    /// Egress: exact (every word is a ≤53-bit dyadic rational).
    #[inline]
    pub fn from_fix(&self, v: i64) -> f64 {
        v as f64 * self.inv_scale
    }

    /// Saturate an f-scaled sum to the word width.
    #[inline]
    pub fn sat(&self, v: i64) -> i64 {
        v.clamp(self.min, self.max)
    }

    /// Renormalize a 2f-scaled product/accumulator to f bits with
    /// round-half-away-from-zero + saturation (the sign-split of
    /// [`QInt::rshift_round`] keeps negative ties rounding away from
    /// zero — an arithmetic `(p + half) >> f` would floor them toward
    /// −∞, the asymmetry the regression tests pin down).
    #[inline]
    pub fn rnorm(&self, p: i64) -> i64 {
        self.rshift_round(p, self.f)
    }

    /// Round-half-away-from-zero right shift by `sh` bits + word
    /// saturation — the one renormalizer behind [`QInt::rnorm`] and the
    /// holding-stage variants below.
    #[inline]
    fn rshift_round(&self, p: i64, sh: u32) -> i64 {
        let half = if sh == 0 { 0 } else { 1i64 << (sh - 1) };
        let q = if p >= 0 {
            (p + half) >> sh
        } else {
            -((-p + half) >> sh)
        };
        self.sat(q)
    }

    /// **Holding-stage** renormalization: reduce a 2f-scaled product to
    /// `f − g` fractional bits — the same physical word reinterpreted as
    /// `Q(int+g).(frac−g)`, trading `g` fraction bits for the integer
    /// headroom the division-deferring products `D·IA` / `D·F + U·row`
    /// need (the per-joint `g` comes from the
    /// [`super::scaling::ShiftSchedule`]; negative `g` instead gains
    /// fraction bits for light distal joints whose tiny held products
    /// would round to zero at the route lsb). Round-half-away +
    /// saturate, boundary-tested like [`QInt::rnorm`].
    #[inline]
    pub fn rnorm_hold(&self, p: i64, g: i32) -> i64 {
        let sh = self.f as i32 + g;
        debug_assert!((0..=62).contains(&sh), "hold shift out of range");
        self.rshift_round(p, sh as u32)
    }

    /// Consume a held product: a `(f − g)`-scaled word multiplied by an
    /// f-scaled word (the deferred `1/D` from the shared divider) sits
    /// at `2f − g` bits; shifting by `f − g` restores the route format.
    /// Requires `|g| ≤ frac_bits` (the schedule guarantees it).
    #[inline]
    pub fn rnorm_unhold(&self, p: i64, g: i32) -> i64 {
        let sh = self.f as i32 - g;
        debug_assert!((0..=62).contains(&sh), "hold shift out of range");
        self.rshift_round(p, sh as u32)
    }

    /// Shared-divider emulation: the quantized reciprocal of an f-scaled
    /// word (dequantize, one f64 division, requantize) — the same
    /// divider output the legacy lane's `ctx.s(1/d)` models.
    #[inline]
    pub fn recip_fix(&self, d: i64) -> i64 {
        self.to_fix(1.0 / self.from_fix(d))
    }
}

/// Flat int 6×6 block, row-major like [`M6`]: entry (i, j) at `i*6 + j`.
pub type I6 = [i64; 36];
/// Int spatial vector: angular part 0..3, linear part 3..6.
pub type IV6 = [i64; 6];

/// Quantized spatial transform: row-major 3×3 rotation + translation.
#[derive(Debug, Clone, Copy)]
pub struct IXform {
    e: [i64; 9],
    r: [i64; 3],
}

impl IXform {
    const ZERO: IXform = IXform { e: [0; 9], r: [0; 3] };
}

#[inline]
fn to_fix_sv(ctx: &QInt, v: &SV) -> IV6 {
    let a = v.to_array();
    [
        ctx.to_fix(a[0]),
        ctx.to_fix(a[1]),
        ctx.to_fix(a[2]),
        ctx.to_fix(a[3]),
        ctx.to_fix(a[4]),
        ctx.to_fix(a[5]),
    ]
}

fn to_fix_m6(ctx: &QInt, m: &M6) -> I6 {
    let mut out = [0i64; 36];
    for (o, x) in out.iter_mut().zip(m) {
        *o = ctx.to_fix(*x);
    }
    out
}

/// Cross product of f-scaled 3-vectors, renormalized per component.
#[inline]
fn icross3(ctx: &QInt, a: &[i64; 3], b: &[i64; 3]) -> [i64; 3] {
    [
        ctx.rnorm(a[1] * b[2] - a[2] * b[1]),
        ctx.rnorm(a[2] * b[0] - a[0] * b[2]),
        ctx.rnorm(a[0] * b[1] - a[1] * b[0]),
    ]
}

/// Motion cross product v × m (int twin of [`SV::crm`]); the linear part
/// accumulates all four products at 2f and renormalizes once.
#[inline]
fn icrm(ctx: &QInt, v: &IV6, m: &IV6) -> IV6 {
    let (w, vl) = ([v[0], v[1], v[2]], [v[3], v[4], v[5]]);
    let (mw, ml) = ([m[0], m[1], m[2]], [m[3], m[4], m[5]]);
    [
        ctx.rnorm(w[1] * mw[2] - w[2] * mw[1]),
        ctx.rnorm(w[2] * mw[0] - w[0] * mw[2]),
        ctx.rnorm(w[0] * mw[1] - w[1] * mw[0]),
        ctx.rnorm(w[1] * ml[2] - w[2] * ml[1] + vl[1] * mw[2] - vl[2] * mw[1]),
        ctx.rnorm(w[2] * ml[0] - w[0] * ml[2] + vl[2] * mw[0] - vl[0] * mw[2]),
        ctx.rnorm(w[0] * ml[1] - w[1] * ml[0] + vl[0] * mw[1] - vl[1] * mw[0]),
    ]
}

/// Force cross product v ×* f (int twin of [`SV::crf`]).
#[inline]
fn icrf(ctx: &QInt, v: &IV6, f: &IV6) -> IV6 {
    let (w, vl) = ([v[0], v[1], v[2]], [v[3], v[4], v[5]]);
    let (fa, fl) = ([f[0], f[1], f[2]], [f[3], f[4], f[5]]);
    [
        ctx.rnorm(w[1] * fa[2] - w[2] * fa[1] + vl[1] * fl[2] - vl[2] * fl[1]),
        ctx.rnorm(w[2] * fa[0] - w[0] * fa[2] + vl[2] * fl[0] - vl[0] * fl[2]),
        ctx.rnorm(w[0] * fa[1] - w[1] * fa[0] + vl[0] * fl[1] - vl[1] * fl[0]),
        ctx.rnorm(w[1] * fl[2] - w[2] * fl[1]),
        ctx.rnorm(w[2] * fl[0] - w[0] * fl[2]),
        ctx.rnorm(w[0] * fl[1] - w[1] * fl[0]),
    ]
}

/// a · v over a flat int block: 6 MACs per row, one renorm per entry.
#[inline]
fn imatvec6(ctx: &QInt, a: &I6, v: &IV6) -> IV6 {
    let mut out = [0i64; 6];
    for (i, o) in out.iter_mut().enumerate() {
        let mut acc = 0i64;
        for (j, x) in v.iter().enumerate() {
            acc += a[i * 6 + j] * x;
        }
        *o = ctx.rnorm(acc);
    }
    out
}

/// aᵀ b with one renorm (the Sᵀf joint projection).
#[inline]
fn idot6(ctx: &QInt, a: &IV6, b: &IV6) -> i64 {
    let mut acc = 0i64;
    for (x, y) in a.iter().zip(b) {
        acc += x * y;
    }
    ctx.rnorm(acc)
}

#[inline]
fn iscale6(ctx: &QInt, v: &IV6, s: i64) -> IV6 {
    let mut out = *v;
    for x in out.iter_mut() {
        *x = ctx.rnorm(*x * s);
    }
    out
}

#[inline]
fn iadd6(ctx: &QInt, a: &IV6, b: &IV6) -> IV6 {
    let mut out = *a;
    for (o, x) in out.iter_mut().zip(b) {
        *o = ctx.sat(*o + x);
    }
    out
}

/// Fused congruence transform XᵀAX on int blocks — the hot op of the
/// articulated-inertia propagation, mirroring [`crate::spatial::mat6::xtax`]
/// with a width-f renormalization between the two passes (the register
/// file a hardware pipeline would have there).
fn ixtax(ctx: &QInt, x: &I6, a: &I6) -> I6 {
    let mut t = [0i64; 36];
    for i in 0..6 {
        for j in 0..6 {
            let mut acc = 0i64;
            for k in 0..6 {
                acc += a[i * 6 + k] * x[k * 6 + j];
            }
            t[i * 6 + j] = ctx.rnorm(acc);
        }
    }
    let mut out = [0i64; 36];
    for i in 0..6 {
        for j in 0..6 {
            let mut acc = 0i64;
            for k in 0..6 {
                acc += x[k * 6 + i] * t[k * 6 + j];
            }
            out[i * 6 + j] = ctx.rnorm(acc);
        }
    }
    out
}

/// Motion transform X·v: ang = E·w, lin = E·(l − r × w).
#[inline]
fn ixf_apply(ctx: &QInt, x: &IXform, v: &IV6) -> IV6 {
    let w = [v[0], v[1], v[2]];
    let l = [v[3], v[4], v[5]];
    let rxw = icross3(ctx, &x.r, &w);
    let t = [
        ctx.sat(l[0] - rxw[0]),
        ctx.sat(l[1] - rxw[1]),
        ctx.sat(l[2] - rxw[2]),
    ];
    let mut out = [0i64; 6];
    for i in 0..3 {
        let (mut aw, mut al) = (0i64, 0i64);
        for j in 0..3 {
            aw += x.e[i * 3 + j] * w[j];
            al += x.e[i * 3 + j] * t[j];
        }
        out[i] = ctx.rnorm(aw);
        out[i + 3] = ctx.rnorm(al);
    }
    out
}

/// Inverse force transform Xᵀf: lin = Eᵀf_lin, ang = Eᵀf_ang + r × lin —
/// RNEA's backward-pass `X_λ(i)^T f_i`.
#[inline]
fn ixf_inv_apply_force(ctx: &QInt, x: &IXform, f: &IV6) -> IV6 {
    let fa = [f[0], f[1], f[2]];
    let fl = [f[3], f[4], f[5]];
    let (mut ang, mut lin) = ([0i64; 3], [0i64; 3]);
    for i in 0..3 {
        let (mut aa, mut al) = (0i64, 0i64);
        for j in 0..3 {
            aa += x.e[j * 3 + i] * fa[j];
            al += x.e[j * 3 + i] * fl[j];
        }
        ang[i] = ctx.rnorm(aa);
        lin[i] = ctx.rnorm(al);
    }
    let rxl = icross3(ctx, &x.r, &lin);
    [
        ctx.sat(ang[0] + rxl[0]),
        ctx.sat(ang[1] + rxl[1]),
        ctx.sat(ang[2] + rxl[2]),
        lin[0],
        lin[1],
        lin[2],
    ]
}

/// Int 6×6 motion matrix of a quantized transform: `[E 0; −E·r̃ E]` with
/// the bottom-left block's products renormalized to f bits (the DSP
/// result register), mirroring [`crate::spatial::Xform::to_mat6`].
fn ixf_to_mat6(ctx: &QInt, x: &IXform) -> I6 {
    let mut m = [0i64; 36];
    for i in 0..3 {
        for j in 0..3 {
            m[i * 6 + j] = x.e[i * 3 + j];
            m[(i + 3) * 6 + (j + 3)] = x.e[i * 3 + j];
        }
    }
    let r = x.r;
    let skew = [[0, -r[2], r[1]], [r[2], 0, -r[0]], [-r[1], r[0], 0]];
    for i in 0..3 {
        for j in 0..3 {
            let mut acc = 0i64;
            for (k, row) in skew.iter().enumerate() {
                acc += x.e[i * 3 + k] * row[j];
            }
            m[(i + 3) * 6 + j] = ctx.rnorm(-acc);
        }
    }
    m
}

/// Preallocated buffers + per-`(robot, format)` ingested constants for
/// the integer kernels — the int twin of [`super::qrbd::QuantScratch`].
/// One scratch serves one robot DOF; `rnea_into` / `minv_into` /
/// `fd_into` perform zero heap allocation per task, and the quantized
/// inertia constants, gravity word, and topology column lists are built
/// once per `(robot fingerprint, format)` and reused across tasks (the "scale
/// once on ingest" half of the lane's contract).
#[derive(Debug, Clone)]
pub struct QuantIntScratch {
    n: usize,
    ctx: QInt,
    /// Ingest cache key: constants below are valid for the robot with
    /// this [`Robot::fingerprint`] at this format. Keyed by fingerprint
    /// — not by name — so robots that share a name but differ
    /// inertially (e.g. a payload variant served through the same pool)
    /// can never be served with one another's ingested constants.
    const_key: Option<(u64, QFormat)>,
    topo: Topology,
    /// Quantized inertia blocks (BRAM constants), one per link.
    ic: Vec<I6>,
    /// Quantized base acceleration (gravity trick), ingested once.
    ia0: IV6,
    // f64 staging for the per-task kinematics (sin/cos "LUT" pass).
    kin: Kin,
    qq: Vec<f64>,
    qdq: Vec<f64>,
    // Quantized per-task state.
    qfix: Vec<i64>,
    qdfix: Vec<i64>,
    ufix: Vec<i64>,
    tfix: Vec<i64>,
    irhs: Vec<i64>,
    // Int kinematic cache.
    ixup: Vec<IXform>,
    x6: Vec<I6>,
    is: Vec<IV6>,
    iv: Vec<IV6>,
    // RNEA sweeps.
    ia_acc: Vec<IV6>,
    ifo: Vec<IV6>,
    // Minv sweeps.
    iart: Vec<I6>,
    iu: Vec<IV6>,
    idinv: Vec<i64>,
    /// Force columns, flattened `i*n + j`.
    ifcol: Vec<IV6>,
    /// Acceleration responses, flattened `i*n + j`.
    iacol: Vec<IV6>,
    /// M⁻¹ in fixed point, flattened `i*n + j`.
    irow: Vec<i64>,
}

impl QuantIntScratch {
    /// Allocate every buffer for an `n`-DOF robot. The format is bound
    /// lazily on the first kernel call (and rebound when it changes).
    pub fn new(n: usize) -> QuantIntScratch {
        QuantIntScratch {
            n,
            // Placeholder context; replaced on first ingest (const_key
            // is None so every kernel re-ingests before reading it).
            ctx: QInt::new(QFormat::new(12, 12)),
            const_key: None,
            topo: Topology { subcols: Vec::new(), brcols: Vec::new() },
            ic: vec![[0; 36]; n],
            ia0: [0; 6],
            kin: Kin::empty(n),
            qq: vec![0.0; n],
            qdq: vec![0.0; n],
            qfix: vec![0; n],
            qdfix: vec![0; n],
            ufix: vec![0; n],
            tfix: vec![0; n],
            irhs: vec![0; n],
            ixup: vec![IXform::ZERO; n],
            x6: vec![[0; 36]; n],
            is: vec![[0; 6]; n],
            iv: vec![[0; 6]; n],
            ia_acc: vec![[0; 6]; n],
            ifo: vec![[0; 6]; n],
            iart: vec![[0; 36]; n],
            iu: vec![[0; 6]; n],
            idinv: vec![0; n],
            ifcol: vec![[0; 6]; n * n],
            iacol: vec![[0; 6]; n * n],
            irow: vec![0; n * n],
        }
    }

    /// DOF the scratch was sized for.
    pub fn n(&self) -> usize {
        self.n
    }

    /// (Re)ingest per-robot constants when the `(robot, format)` pair
    /// changes: quantize the inertia blocks and the gravity word once,
    /// rebuild the topology column lists. Keyed by
    /// [`Robot::fingerprint`] (cheap word-level hash of the full
    /// model), so mutated or same-name-but-different robots always
    /// re-ingest instead of aliasing cached constants.
    fn ensure_ingest(&mut self, robot: &Robot, fmt: QFormat) {
        self.ensure_ingest_keyed(robot, fmt, robot.fingerprint());
    }

    /// As [`Self::ensure_ingest`] with the fingerprint precomputed —
    /// the deferred kernels already hash the model in
    /// [`Self::check_schedule`] and must not pay for it twice per task.
    fn ensure_ingest_keyed(&mut self, robot: &Robot, fmt: QFormat, fp: u64) {
        assert_eq!(robot.dof(), self.n, "scratch sized for a different robot");
        if self.const_key.is_some_and(|(key, f)| f == fmt && key == fp) {
            return;
        }
        let ctx = QInt::new(fmt);
        for (block, link) in self.ic.iter_mut().zip(&robot.links) {
            *block = to_fix_m6(&ctx, &link.inertia.to_mat6());
        }
        self.ia0 = to_fix_sv(&ctx, &SV::new(V3::ZERO, -robot.gravity));
        self.topo = Topology::new(robot);
        self.ctx = ctx;
        self.const_key = Some((fp, fmt));
    }

    /// Rebuild the int kinematic cache for the quantized state held in
    /// `qfix` (+ `qdfix` when `with_vel`): one f64 transform pass from
    /// the dequantized (exact) inputs — the sin/cos LUT lookup — then
    /// quantized E/r entries, an integer velocity propagation, and (only
    /// when `need_x6`, i.e. an M⁻¹ sweep follows) the int 6×6 motion
    /// blocks that `ixtax` consumes — the RNEA-only path skips them.
    fn ikin(&mut self, robot: &Robot, with_vel: bool, need_x6: bool) {
        let ctx = self.ctx;
        let n = self.n;
        for i in 0..n {
            self.qq[i] = ctx.from_fix(self.qfix[i]);
            self.qdq[i] = if with_vel { ctx.from_fix(self.qdfix[i]) } else { 0.0 };
        }
        if with_vel {
            self.kin.recompute(robot, &self.qq, &self.qdq);
        } else {
            self.kin.recompute_positions(robot, &self.qq);
        }
        for i in 0..n {
            let x = &self.kin.xup[i];
            let mut e = [0i64; 9];
            for r in 0..3 {
                for c in 0..3 {
                    e[r * 3 + c] = ctx.to_fix(x.e.0[r][c]);
                }
            }
            let r3 = [
                ctx.to_fix(x.r.0[0]),
                ctx.to_fix(x.r.0[1]),
                ctx.to_fix(x.r.0[2]),
            ];
            self.ixup[i] = IXform { e, r: r3 };
            if need_x6 {
                self.x6[i] = ixf_to_mat6(&ctx, &self.ixup[i]);
            }
            self.is[i] = to_fix_sv(&ctx, &self.kin.s[i]);
        }
        if with_vel {
            for i in 0..n {
                let vj = iscale6(&ctx, &self.is[i], self.qdfix[i]);
                self.iv[i] = match robot.links[i].parent {
                    Some(p) => {
                        let vp = self.iv[p];
                        iadd6(&ctx, &ixf_apply(&ctx, &self.ixup[i], &vp), &vj)
                    }
                    None => vj,
                };
            }
        } else {
            self.iv.fill([0; 6]);
        }
    }

    /// Forward + backward RNEA sweeps over the current int kin cache;
    /// `with_qdd` adds the S·q̈ term (reads `ufix`), otherwise this is
    /// the bias pass. Joint torques land in `tfix` (f-scaled).
    fn rnea_fix(&mut self, robot: &Robot, with_qdd: bool) {
        let ctx = self.ctx;
        let n = self.n;
        for i in 0..n {
            let ap = match robot.links[i].parent {
                Some(p) => self.ia_acc[p],
                None => self.ia0,
            };
            let mut ai = ixf_apply(&ctx, &self.ixup[i], &ap);
            if with_qdd {
                ai = iadd6(&ctx, &ai, &iscale6(&ctx, &self.is[i], self.ufix[i]));
            }
            let vdot = icrm(&ctx, &self.iv[i], &iscale6(&ctx, &self.is[i], self.qdfix[i]));
            let ai = iadd6(&ctx, &ai, &vdot);
            let iai = imatvec6(&ctx, &self.ic[i], &ai);
            let ivi = imatvec6(&ctx, &self.ic[i], &self.iv[i]);
            let fi = iadd6(&ctx, &iai, &icrf(&ctx, &self.iv[i], &ivi));
            self.ia_acc[i] = ai;
            self.ifo[i] = fi;
        }
        for i in (0..n).rev() {
            self.tfix[i] = idot6(&ctx, &self.is[i], &self.ifo[i]);
            if let Some(p) = robot.links[i].parent {
                let up = ixf_inv_apply_force(&ctx, &self.ixup[i], &self.ifo[i]);
                self.ifo[p] = iadd6(&ctx, &self.ifo[p], &up);
            }
        }
    }

    /// Analytical M⁻¹ sweeps over the current int kin cache (Algorithm 1
    /// with the reciprocal through the shared-divider emulation). The
    /// fixed-point matrix lands in `irow` (f-scaled, flattened `i·n+j`).
    fn minv_fix(&mut self, robot: &Robot) {
        let ctx = self.ctx;
        let n = self.n;
        self.iart.copy_from_slice(&self.ic);
        self.ifcol.fill([0; 6]);
        self.iacol.fill([0; 6]);
        self.irow.fill(0);

        for i in (0..n).rev() {
            let s = self.is[i];
            let ui = imatvec6(&ctx, &self.iart[i], &s);
            let di = idot6(&ctx, &s, &ui);
            let dinv = ctx.recip_fix(di);
            self.iu[i] = ui;
            self.idinv[i] = dinv;
            self.irow[i * n + i] = ctx.sat(self.irow[i * n + i] + dinv);
            for &j in &self.topo.subcols[i] {
                let sf = idot6(&ctx, &s, &self.ifcol[i * n + j]);
                if sf != 0 {
                    self.irow[i * n + j] = ctx.sat(self.irow[i * n + j] - ctx.rnorm(dinv * sf));
                }
            }
            if let Some(p) = robot.links[i].parent {
                // IA_art = IA − (U Uᵀ)·D⁻¹, each product renormalized.
                let mut ia_art = [0i64; 36];
                for a in 0..6 {
                    for b in 0..6 {
                        let uu = ctx.rnorm(ui[a] * ui[b]);
                        ia_art[a * 6 + b] =
                            ctx.sat(self.iart[i][a * 6 + b] - ctx.rnorm(uu * dinv));
                    }
                }
                let contrib = ixtax(&ctx, &self.x6[i], &ia_art);
                for e in 0..36 {
                    self.iart[p][e] = ctx.sat(self.iart[p][e] + contrib[e]);
                }
                for &j in &self.topo.subcols[i] {
                    let fij =
                        iadd6(&ctx, &self.ifcol[i * n + j], &iscale6(&ctx, &ui, self.irow[i * n + j]));
                    let up = ixf_inv_apply_force(&ctx, &self.ixup[i], &fij);
                    self.ifcol[p * n + j] = iadd6(&ctx, &self.ifcol[p * n + j], &up);
                }
            }
        }

        for i in 0..n {
            let s = self.is[i];
            match robot.links[i].parent {
                None => {
                    for &j in &self.topo.brcols[i] {
                        self.iacol[i * n + j] = iscale6(&ctx, &s, self.irow[i * n + j]);
                    }
                }
                Some(p) => {
                    for &j in &self.topo.brcols[i] {
                        let ap = self.iacol[p * n + j];
                        let xa = ixf_apply(&ctx, &self.ixup[i], &ap);
                        let corr = ctx.rnorm(self.idinv[i] * idot6(&ctx, &self.iu[i], &xa));
                        if corr != 0 {
                            self.irow[i * n + j] = ctx.sat(self.irow[i * n + j] - corr);
                        }
                        self.iacol[i * n + j] =
                            iadd6(&ctx, &xa, &iscale6(&ctx, &s, self.irow[i * n + j]));
                    }
                }
            }
        }
    }

    /// Division-deferring M⁻¹ sweeps (Algorithm 2) over the current int
    /// kin cache, driven by the schedule's per-joint holding shifts.
    /// Mirrors [`crate::dynamics::minv::minv_dd_into`]'s recurrences:
    /// the backward pass carries held `N`/`G` products at `frac − g`
    /// bits, the shared divider resolves every `1/D` off the recurrence
    /// ([`QInt::recip_fix`], consumed one stage later), and the deferred
    /// rows are divided once before the forward response sweep.
    fn minv_fix_dd(&mut self, robot: &Robot, hold: &[i32]) {
        let ctx = self.ctx;
        let n = self.n;
        self.iart.copy_from_slice(&self.ic);
        self.ifcol.fill([0; 6]);
        self.iacol.fill([0; 6]);
        self.irow.fill(0);
        let one = ctx.to_fix(1.0);

        // Backward sweep (stage Mb): scaled numerators only; divider
        // outputs are consumed a stage later (parent updates), exactly
        // the staggered schedule of the f64 kernel.
        for i in (0..n).rev() {
            let s = self.is[i];
            let ui = imatvec6(&ctx, &self.iart[i], &s);
            let di = idot6(&ctx, &s, &ui);
            let dinv = ctx.recip_fix(di);
            self.iu[i] = ui;
            self.idinv[i] = dinv;
            self.irow[i * n + i] = ctx.sat(self.irow[i * n + i] + one);
            for &j in &self.topo.subcols[i] {
                let sf = idot6(&ctx, &s, &self.ifcol[i * n + j]);
                if sf != 0 {
                    self.irow[i * n + j] = ctx.sat(self.irow[i * n + j] - sf);
                }
            }
            if let Some(p) = robot.links[i].parent {
                let g = hold[i];
                // N = D·IA − U Uᵀ, held at frac − g (both products are
                // exact at 2f; one renorm per entry).
                let mut nh = [0i64; 36];
                for a in 0..6 {
                    for b in 0..6 {
                        nh[a * 6 + b] =
                            ctx.rnorm_hold(di * self.iart[i][a * 6 + b] - ui[a] * ui[b], g);
                    }
                }
                // XᵀNX stays in the held domain (X entries are f-scaled,
                // so ixtax's per-pass `>> f` renorms preserve the scale);
                // the deferred multiply by 1/D restores the format.
                let contrib = ixtax(&ctx, &self.x6[i], &nh);
                for e in 0..36 {
                    self.iart[p][e] =
                        ctx.sat(self.iart[p][e] + ctx.rnorm_unhold(contrib[e] * dinv, g));
                }
                for &j in &self.topo.subcols[i] {
                    // G = D·F + U·row, held; F_λ += (Xᵀ G)·D⁻¹.
                    let f0 = self.ifcol[i * n + j];
                    let r = self.irow[i * n + j];
                    let mut gh = [0i64; 6];
                    for (k, gk) in gh.iter_mut().enumerate() {
                        *gk = ctx.rnorm_hold(di * f0[k] + ui[k] * r, g);
                    }
                    let up = ixf_inv_apply_force(&ctx, &self.ixup[i], &gh);
                    for k in 0..6 {
                        self.ifcol[p * n + j][k] =
                            ctx.sat(self.ifcol[p * n + j][k] + ctx.rnorm_unhold(up[k] * dinv, g));
                    }
                }
            }
        }

        // Divider outputs consumed: one multiply turns every deferred
        // row D_i·M⁻¹_row into the M⁻¹ row.
        for i in 0..n {
            let dinv = self.idinv[i];
            for j in 0..n {
                let v = self.irow[i * n + j];
                if v != 0 {
                    self.irow[i * n + j] = ctx.rnorm(v * dinv);
                }
            }
        }

        // Forward pass (Mf): identical to the inline-divider sweep.
        for i in 0..n {
            let s = self.is[i];
            match robot.links[i].parent {
                None => {
                    for &j in &self.topo.brcols[i] {
                        self.iacol[i * n + j] = iscale6(&ctx, &s, self.irow[i * n + j]);
                    }
                }
                Some(p) => {
                    for &j in &self.topo.brcols[i] {
                        let ap = self.iacol[p * n + j];
                        let xa = ixf_apply(&ctx, &self.ixup[i], &ap);
                        let corr = ctx.rnorm(self.idinv[i] * idot6(&ctx, &self.iu[i], &xa));
                        if corr != 0 {
                            self.irow[i * n + j] = ctx.sat(self.irow[i * n + j] - corr);
                        }
                        self.iacol[i * n + j] =
                            iadd6(&ctx, &xa, &iscale6(&ctx, &s, self.irow[i * n + j]));
                    }
                }
            }
        }
    }

    /// Bind a schedule to this scratch: the schedule must have been
    /// derived for exactly this robot (by fingerprint, not name) and
    /// the lane must carry its format. Serving backends validate at
    /// registration ([`super::scaling::validate_int_backend`]), so
    /// these assertions never fire on a served route. Returns the
    /// model fingerprint so callers can reuse it for the ingest key.
    fn check_schedule(&self, robot: &Robot, sched: &ShiftSchedule) -> u64 {
        let fp = robot.fingerprint();
        assert_eq!(
            sched.fingerprint, fp,
            "shift schedule derived for a different robot (or the model changed \
             since analysis): schedule is for '{}', kernel got '{}'",
            sched.robot, robot.name
        );
        assert_eq!(sched.hold_shift.len(), robot.dof(), "schedule joint count mismatch");
        assert!(
            sched.hold_shift.iter().all(|&g| g.unsigned_abs() <= sched.fmt.frac_bits),
            "schedule holds more bits than the format has"
        );
        fp
    }

    /// Clamp one joint position into the joint-limit box. The schedule
    /// is proved over that box (certified translation bounds, sampled
    /// extrema), so the deferred kernels saturate out-of-box positions
    /// on ingest — the joint-space twin of the word's rail saturation —
    /// instead of running the held products outside their proof. In-box
    /// inputs (every valid serve request; integrator drift past a limit
    /// is the exception) pass through untouched.
    #[inline]
    fn q_boxed(robot: &Robot, i: usize, q: f64) -> f64 {
        q.clamp(robot.links[i].q_min, robot.links[i].q_max)
    }

    /// Integer **division-deferring** analytical M⁻¹(q) (Algorithm 2)
    /// under a proved [`ShiftSchedule`], dequantized into `out` (N×N).
    /// Positions are clamped to the joint-limit box the schedule was
    /// proved over (see [`Self::q_boxed`]).
    pub fn minv_dd_into(
        &mut self,
        robot: &Robot,
        q: &[f64],
        sched: &ShiftSchedule,
        out: &mut DMat,
    ) {
        let fp = self.check_schedule(robot, sched);
        self.ensure_ingest_keyed(robot, sched.fmt, fp);
        let ctx = self.ctx;
        let n = self.n;
        assert_eq!(out.d.len(), n * n, "output sized for a different robot");
        for i in 0..n {
            self.qfix[i] = ctx.to_fix(Self::q_boxed(robot, i, q[i]));
        }
        self.ikin(robot, false, true);
        self.minv_fix_dd(robot, &sched.hold_shift);
        for (o, v) in out.d.iter_mut().zip(&self.irow) {
            *o = ctx.from_fix(*v);
        }
    }

    /// Fused integer forward dynamics through the **division-deferring**
    /// M⁻¹: one int kinematics pass shared by the bias sweep and the
    /// deferred M⁻¹ sweep, τ − C folded into the fixed-point matvec —
    /// the serving kernel of the `qint` backend
    /// ([`crate::runtime::QIntEngine`]).
    pub fn fd_dd_into(
        &mut self,
        robot: &Robot,
        q: &[f64],
        qd: &[f64],
        tau: &[f64],
        sched: &ShiftSchedule,
        qdd: &mut [f64],
    ) {
        let fp = self.check_schedule(robot, sched);
        self.ensure_ingest_keyed(robot, sched.fmt, fp);
        let ctx = self.ctx;
        let n = self.n;
        assert_eq!(tau.len(), n);
        assert_eq!(qdd.len(), n);
        for i in 0..n {
            self.qfix[i] = ctx.to_fix(Self::q_boxed(robot, i, q[i]));
            self.qdfix[i] = ctx.to_fix(qd[i]);
            self.ufix[i] = ctx.to_fix(tau[i]);
        }
        self.ikin(robot, true, true);
        self.rnea_fix(robot, false); // bias: q̈ ≡ 0, tfix ← C
        self.minv_fix_dd(robot, &sched.hold_shift);
        for i in 0..n {
            self.irhs[i] = ctx.sat(self.ufix[i] - self.tfix[i]);
        }
        for i in 0..n {
            let mut acc = 0i64;
            for j in 0..n {
                acc += self.irow[i * n + j] * self.irhs[j];
            }
            qdd[i] = ctx.from_fix(ctx.rnorm(acc));
        }
    }

    /// Fused integer multi-output dynamics through the
    /// **division-deferring** M⁻¹: one int kinematics pass feeds the
    /// bias sweep, the deferred M⁻¹ sweep, and the FD τ-fold, with flat
    /// egress `out = [q̈ (N) | M⁻¹ (N×N row-major) | C (N)]` (`N² + 2N`
    /// entries, each dequantized exactly on egress) — the integer twin
    /// of [`crate::dynamics::DynWorkspace::dyn_all_into`]. Each section
    /// is bitwise what the separate `fd_dd_into` / `minv_dd_into` /
    /// `rnea_into(q̈=0)` calls produce at the same in-box inputs.
    pub fn dyn_all_dd_into(
        &mut self,
        robot: &Robot,
        q: &[f64],
        qd: &[f64],
        tau: &[f64],
        sched: &ShiftSchedule,
        out: &mut [f64],
    ) {
        let fp = self.check_schedule(robot, sched);
        self.ensure_ingest_keyed(robot, sched.fmt, fp);
        let ctx = self.ctx;
        let n = self.n;
        assert_eq!(tau.len(), n);
        assert_eq!(out.len(), n * n + 2 * n, "dyn_all egress is qdd|minv|bias");
        for i in 0..n {
            self.qfix[i] = ctx.to_fix(Self::q_boxed(robot, i, q[i]));
            self.qdfix[i] = ctx.to_fix(qd[i]);
            self.ufix[i] = ctx.to_fix(tau[i]);
        }
        self.ikin(robot, true, true);
        self.rnea_fix(robot, false); // bias: q̈ ≡ 0, tfix ← C
        self.minv_fix_dd(robot, &sched.hold_shift);
        self.dyn_all_dd_finish(out);
    }

    /// [`dyn_all_dd_into`](Self::dyn_all_dd_into) with a cross-request
    /// memo of the fixed-point sweep outputs (`irow`, `tfix`). The key
    /// is the **quantized** joint words `(qfix, q̇fix)` plus a packed
    /// format word and the robot fingerprint, so any raw state that
    /// ingests onto a cached operating point hits; a hit skips the
    /// int kinematics/bias/deferred-M⁻¹ sweeps and re-runs only the
    /// integer τ-fold and the exact `from_fix` egress — bitwise
    /// identical to a cold miss.
    #[allow(clippy::too_many_arguments)]
    pub fn dyn_all_dd_memo_into(
        &mut self,
        robot: &Robot,
        q: &[f64],
        qd: &[f64],
        tau: &[f64],
        sched: &ShiftSchedule,
        memo: &mut crate::dynamics::memo::IntMemo,
        out: &mut [f64],
    ) {
        let fp = self.check_schedule(robot, sched);
        self.ensure_ingest_keyed(robot, sched.fmt, fp);
        let ctx = self.ctx;
        let n = self.n;
        assert_eq!(tau.len(), n);
        assert_eq!(out.len(), n * n + 2 * n, "dyn_all egress is qdd|minv|bias");
        for i in 0..n {
            self.qfix[i] = ctx.to_fix(Self::q_boxed(robot, i, q[i]));
            self.qdfix[i] = ctx.to_fix(qd[i]);
            self.ufix[i] = ctx.to_fix(tau[i]);
        }
        memo.begin();
        memo.stage_word(((sched.fmt.int_bits as u64) << 32) | sched.fmt.frac_bits as u64);
        memo.stage_i64(&self.qfix);
        memo.stage_i64(&self.qdfix);
        if memo.lookup(fp) {
            let (mi, bias) = memo.front();
            self.irow.copy_from_slice(mi);
            self.tfix.copy_from_slice(bias);
        } else {
            self.ikin(robot, true, true);
            self.rnea_fix(robot, false);
            self.minv_fix_dd(robot, &sched.hold_shift);
            memo.insert(fp, (self.irow.clone(), self.tfix.clone()));
        }
        self.dyn_all_dd_finish(out);
    }

    /// Shared tail of the `dyn_all` paths: integer τ − C fold, the
    /// fixed-point matvec, and the exact `from_fix` egress of all three
    /// sections. Reads the (recomputed or replayed) `irow` / `tfix`
    /// words, so memo hits and cold computes take literally the same
    /// instructions from here on.
    fn dyn_all_dd_finish(&mut self, out: &mut [f64]) {
        let ctx = self.ctx;
        let n = self.n;
        for i in 0..n {
            self.irhs[i] = ctx.sat(self.ufix[i] - self.tfix[i]);
        }
        let (qdd, rest) = out.split_at_mut(n);
        for i in 0..n {
            let mut acc = 0i64;
            for j in 0..n {
                acc += self.irow[i * n + j] * self.irhs[j];
            }
            qdd[i] = ctx.from_fix(ctx.rnorm(acc));
        }
        let (mi, bias) = rest.split_at_mut(n * n);
        for (o, v) in mi.iter_mut().zip(&self.irow) {
            *o = ctx.from_fix(*v);
        }
        for i in 0..n {
            bias[i] = ctx.from_fix(self.tfix[i]);
        }
    }

    /// Integer RNEA (ID): τ = ID(q, q̇, q̈), dequantized into `tau`.
    pub fn rnea_into(
        &mut self,
        robot: &Robot,
        q: &[f64],
        qd: &[f64],
        qdd: &[f64],
        fmt: QFormat,
        tau: &mut [f64],
    ) {
        self.ensure_ingest(robot, fmt);
        let ctx = self.ctx;
        let n = self.n;
        assert_eq!(tau.len(), n);
        for i in 0..n {
            self.qfix[i] = ctx.to_fix(q[i]);
            self.qdfix[i] = ctx.to_fix(qd[i]);
            self.ufix[i] = ctx.to_fix(qdd[i]);
        }
        self.ikin(robot, true, false);
        self.rnea_fix(robot, true);
        for i in 0..n {
            tau[i] = ctx.from_fix(self.tfix[i]);
        }
    }

    /// Integer analytical M⁻¹(q), dequantized into `out` (N×N).
    pub fn minv_into(&mut self, robot: &Robot, q: &[f64], fmt: QFormat, out: &mut DMat) {
        self.ensure_ingest(robot, fmt);
        let ctx = self.ctx;
        let n = self.n;
        assert_eq!(out.d.len(), n * n, "output sized for a different robot");
        for i in 0..n {
            self.qfix[i] = ctx.to_fix(q[i]);
        }
        self.ikin(robot, false, true);
        self.minv_fix(robot);
        for (o, v) in out.d.iter_mut().zip(&self.irow) {
            *o = ctx.from_fix(*v);
        }
    }

    /// Fused integer forward dynamics q̈ = M⁻¹(q)·(τ − C(q, q̇)): **one**
    /// int kinematics pass shared by the bias sweep and the M⁻¹ sweep
    /// (which reads only the position entries), with τ − C folded into
    /// the fixed-point matvec and a single dequantization on egress —
    /// the integer twin of [`crate::dynamics::DynWorkspace::fd_into`].
    pub fn fd_into(
        &mut self,
        robot: &Robot,
        q: &[f64],
        qd: &[f64],
        tau: &[f64],
        fmt: QFormat,
        qdd: &mut [f64],
    ) {
        self.ensure_ingest(robot, fmt);
        let ctx = self.ctx;
        let n = self.n;
        assert_eq!(tau.len(), n);
        assert_eq!(qdd.len(), n);
        for i in 0..n {
            self.qfix[i] = ctx.to_fix(q[i]);
            self.qdfix[i] = ctx.to_fix(qd[i]);
            self.ufix[i] = ctx.to_fix(tau[i]);
        }
        self.ikin(robot, true, true);
        self.rnea_fix(robot, false); // bias: q̈ ≡ 0, tfix ← C
        self.minv_fix(robot); // reads ixup/x6/is only — same kin pass
        for i in 0..n {
            self.irhs[i] = ctx.sat(self.ufix[i] - self.tfix[i]);
        }
        for i in 0..n {
            let mut acc = 0i64;
            for j in 0..n {
                acc += self.irow[i * n + j] * self.irhs[j];
            }
            qdd[i] = ctx.from_fix(ctx.rnorm(acc));
        }
    }
}

/// Integer RNEA, allocating wrapper over [`QuantIntScratch::rnea_into`].
pub fn quant_rnea_i64(robot: &Robot, q: &[f64], qd: &[f64], qdd: &[f64], fmt: QFormat) -> Vec<f64> {
    let n = robot.dof();
    let mut ws = QuantIntScratch::new(n);
    let mut tau = vec![0.0; n];
    ws.rnea_into(robot, q, qd, qdd, fmt, &mut tau);
    tau
}

/// Integer M⁻¹, allocating wrapper over [`QuantIntScratch::minv_into`].
pub fn quant_minv_i64(robot: &Robot, q: &[f64], fmt: QFormat) -> DMat {
    let n = robot.dof();
    let mut ws = QuantIntScratch::new(n);
    let mut out = DMat::zeros(n, n);
    ws.minv_into(robot, q, fmt, &mut out);
    out
}

/// Integer FD, allocating wrapper over [`QuantIntScratch::fd_into`].
pub fn quant_fd_i64(robot: &Robot, q: &[f64], qd: &[f64], tau: &[f64], fmt: QFormat) -> Vec<f64> {
    let n = robot.dof();
    let mut ws = QuantIntScratch::new(n);
    let mut qdd = vec![0.0; n];
    ws.fd_into(robot, q, qd, tau, fmt, &mut qdd);
    qdd
}

/// Division-deferring integer M⁻¹, allocating wrapper over
/// [`QuantIntScratch::minv_dd_into`].
pub fn quant_minv_dd_i64(robot: &Robot, q: &[f64], sched: &ShiftSchedule) -> DMat {
    let n = robot.dof();
    let mut ws = QuantIntScratch::new(n);
    let mut out = DMat::zeros(n, n);
    ws.minv_dd_into(robot, q, sched, &mut out);
    out
}

/// Division-deferring integer FD, allocating wrapper over
/// [`QuantIntScratch::fd_dd_into`].
pub fn quant_fd_dd_i64(
    robot: &Robot,
    q: &[f64],
    qd: &[f64],
    tau: &[f64],
    sched: &ShiftSchedule,
) -> Vec<f64> {
    let n = robot.dof();
    let mut ws = QuantIntScratch::new(n);
    let mut qdd = vec![0.0; n];
    ws.fd_dd_into(robot, q, qd, tau, sched, &mut qdd);
    qdd
}

/// Fused division-deferring integer multi-output dynamics, flat egress
/// `[q̈ | M⁻¹ | C]` (`N² + 2N` entries). Allocating wrapper over
/// [`QuantIntScratch::dyn_all_dd_into`].
pub fn quant_dyn_all_dd_i64(
    robot: &Robot,
    q: &[f64],
    qd: &[f64],
    tau: &[f64],
    sched: &ShiftSchedule,
) -> Vec<f64> {
    let n = robot.dof();
    let mut ws = QuantIntScratch::new(n);
    let mut out = vec![0.0; n * n + 2 * n];
    ws.dyn_all_dd_into(robot, q, qd, tau, sched, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dynamics::{minv, rnea};
    use crate::model::{builtin, State};
    use crate::util::rng::Rng;

    /// The satellite bugfix regression: ingest rounding must agree with
    /// the legacy `QFormat::q` on every shared vector, in particular at
    /// negative half-step ties (round-half-away-from-zero, never
    /// truncation) and at both saturation rails.
    #[test]
    fn ingest_rounding_matches_legacy_q_at_boundaries() {
        for fmt in [
            QFormat::new(8, 8),
            QFormat::new(12, 12),
            QFormat::new(10, 16),
            QFormat::new(12, 0),
        ] {
            let ctx = QInt::new(fmt);
            let step = fmt.step();
            let mut xs = vec![0.0, step, -step, 0.3, -0.3, 1.75, -1.75];
            for k in 0..8 {
                // Exact half-step ties on both sides of zero.
                xs.push((k as f64 + 0.5) * step);
                xs.push(-(k as f64 + 0.5) * step);
            }
            xs.extend([
                fmt.max_val(),
                fmt.max_val() + step,
                fmt.max_val() + 0.5 * step,
                -fmt.max_val() - step,
                -fmt.max_val() - 2.0 * step,
                -fmt.max_val() - 1.5 * step,
                1e12,
                -1e12,
            ]);
            for &x in &xs {
                assert_eq!(
                    ctx.from_fix(ctx.to_fix(x)),
                    fmt.q(x),
                    "x = {x} fmt = {}",
                    fmt.label()
                );
            }
        }
    }

    /// Renormalization ties: a 2f-scaled product at exactly ±half must
    /// round away from zero like `q()` of the exact real value. An
    /// arithmetic-shift implementation fails the negative cases
    /// (−0.5·step would land on 0 instead of −step).
    #[test]
    fn renorm_rounds_negative_ties_away_from_zero() {
        for fmt in [QFormat::new(8, 8), QFormat::new(12, 12), QFormat::new(10, 16)] {
            let ctx = QInt::new(fmt);
            let two_f = fmt.step() * fmt.step(); // 2^-2f, exact
            let h = 1i64 << (fmt.frac_bits - 1);
            for m in [1i64, -1, 3, -3, 7, -7, 101, -101] {
                let p = m * h; // (m/2)·step as a 2f-scaled word
                let real = p as f64 * two_f;
                assert_eq!(
                    ctx.from_fix(ctx.rnorm(p)),
                    fmt.q(real),
                    "tie p = {p} fmt = {}",
                    fmt.label()
                );
            }
            // And across random (non-tie) products.
            let mut rng = Rng::new(42);
            for _ in 0..500 {
                let p = rng.range(-1e6, 1e6) as i64;
                let real = p as f64 * two_f;
                assert_eq!(ctx.from_fix(ctx.rnorm(p)), fmt.q(real), "p = {p}");
            }
        }
    }

    #[test]
    fn fine_format_tracks_float_rnea() {
        // 26-bit (12.14): per-op rounding is ~6e-5 with headroom to
        // ±2048; amplified through the sweeps the torque error stays
        // well under engineering tolerance.
        let robot = builtin::iiwa();
        let mut rng = Rng::new(900);
        let s = State::random(&robot, &mut rng);
        let n = robot.dof();
        let qdd = rng.vec_range(n, -2.0, 2.0);
        let exact = rnea(&robot, &s.q, &s.qd, &qdd, None);
        let quant = quant_rnea_i64(&robot, &s.q, &s.qd, &qdd, QFormat::new(12, 14));
        for i in 0..n {
            assert!(
                (exact[i] - quant[i]).abs() < 5e-2 * (1.0 + exact[i].abs()),
                "joint {i}: {} vs {}",
                exact[i],
                quant[i]
            );
        }
    }

    #[test]
    fn int_error_grows_as_precision_drops() {
        let robot = builtin::iiwa();
        let mut rng = Rng::new(901);
        let n = robot.dof();
        let mut errs = Vec::new();
        for frac in [16u32, 12, 8] {
            let mut total = 0.0;
            let mut cases = 0;
            let mut ws = QuantIntScratch::new(n);
            let mut tau = vec![0.0; n];
            for _ in 0..8 {
                let s = State::random(&robot, &mut rng);
                let qdd = rng.vec_range(n, -2.0, 2.0);
                let exact = rnea(&robot, &s.q, &s.qd, &qdd, None);
                ws.rnea_into(&robot, &s.q, &s.qd, &qdd, QFormat::new(10, frac), &mut tau);
                for i in 0..n {
                    total += (exact[i] - tau[i]).abs();
                    cases += 1;
                }
            }
            errs.push(total / cases as f64);
        }
        assert!(errs[0] < errs[1] && errs[1] < errs[2], "mean errors {errs:?} must increase");
    }

    #[test]
    fn int_minv_close_to_exact_at_fine_format() {
        let robot = builtin::iiwa();
        let mut rng = Rng::new(902);
        let s = State::random(&robot, &mut rng);
        // 12 integer bits: the iiwa wrist diagonal (~1/D ≈ 5e2) must not
        // saturate the word.
        let exact = minv(&robot, &s.q);
        let quant = quant_minv_i64(&robot, &s.q, QFormat::new(12, 14));
        let rel = exact.sub(&quant).max_abs() / exact.max_abs();
        assert!(rel < 5e-2, "relative error {rel}");
    }

    #[test]
    fn int_fd_roundtrip_error_bounded() {
        // FD(ID(q̈)) at the paper's 24-bit format stays close to q̈.
        let robot = builtin::iiwa();
        let mut rng = Rng::new(903);
        let s = State::random(&robot, &mut rng);
        let n = robot.dof();
        let qdd = rng.vec_range(n, -1.0, 1.0);
        let tau = rnea(&robot, &s.q, &s.qd, &qdd, None);
        let back = quant_fd_i64(&robot, &s.q, &s.qd, &tau, QFormat::new(12, 12));
        for i in 0..n {
            assert!(
                (back[i] - qdd[i]).abs() < 0.5,
                "joint {i}: {} vs {}",
                back[i],
                qdd[i]
            );
        }
    }

    /// One scratch reused across tasks, robots, and formats must match
    /// fresh scratches bitwise — the ingest cache may never leak stale
    /// constants across a (robot, format) switch.
    #[test]
    fn scratch_reuse_and_ingest_rebind_match_fresh() {
        let iiwa = builtin::iiwa();
        let n = iiwa.dof();
        let fa = QFormat::new(12, 12);
        let fb = QFormat::new(10, 14);
        let mut ws = QuantIntScratch::new(n);
        let mut rng = Rng::new(904);
        for fmt in [fa, fb, fa] {
            for _ in 0..2 {
                let s = State::random(&iiwa, &mut rng);
                let qdd = rng.vec_range(n, -2.0, 2.0);
                let tau = rng.vec_range(n, -8.0, 8.0);

                let mut tau_ws = vec![0.0; n];
                ws.rnea_into(&iiwa, &s.q, &s.qd, &qdd, fmt, &mut tau_ws);
                assert_eq!(tau_ws, quant_rnea_i64(&iiwa, &s.q, &s.qd, &qdd, fmt));

                let mut mi_ws = DMat::zeros(n, n);
                ws.minv_into(&iiwa, &s.q, fmt, &mut mi_ws);
                assert_eq!(mi_ws.d, quant_minv_i64(&iiwa, &s.q, fmt).d);

                let mut qdd_ws = vec![0.0; n];
                ws.fd_into(&iiwa, &s.q, &s.qd, &tau, fmt, &mut qdd_ws);
                assert_eq!(qdd_ws, quant_fd_i64(&iiwa, &s.q, &s.qd, &tau, fmt));
            }
        }
    }

    /// Robots with the same DOF count — and even the same NAME — but
    /// different inertias must not share ingested constants: the cache
    /// is keyed by the full model fingerprint, not the routing name (a
    /// name-keyed cache would serve a payload variant with the base
    /// robot's inertia blocks through a shared pool worker).
    #[test]
    fn ingest_cache_keyed_by_robot() {
        let a = builtin::iiwa();
        let mut b = builtin::iiwa(); // same name "iiwa", heavier links
        for l in &mut b.links {
            l.inertia.mass *= 2.0;
        }
        let fmt = QFormat::new(12, 12);
        let n = a.dof();
        let mut rng = Rng::new(905);
        let s = State::random(&a, &mut rng);
        let qdd = rng.vec_range(n, -1.0, 1.0);
        let mut ws = QuantIntScratch::new(n);
        let mut t1 = vec![0.0; n];
        let mut t2 = vec![0.0; n];
        ws.rnea_into(&a, &s.q, &s.qd, &qdd, fmt, &mut t1);
        ws.rnea_into(&b, &s.q, &s.qd, &qdd, fmt, &mut t2);
        assert_eq!(t2, quant_rnea_i64(&b, &s.q, &s.qd, &qdd, fmt));
        assert_ne!(t1, t2, "doubled masses must change the torques");
    }

    #[test]
    fn int_lane_error_envelope_matches_legacy_lane() {
        // Both lanes realize the same format; their mean errors against
        // the exact kernels should sit in the same decade.
        let robot = builtin::hyq();
        let n = robot.dof();
        let fmt = QFormat::new(12, 12);
        let mut rng = Rng::new(906);
        let (mut e_int, mut e_leg) = (0.0f64, 0.0f64);
        for _ in 0..6 {
            let s = State::random(&robot, &mut rng);
            let qdd = rng.vec_range(n, -2.0, 2.0);
            let exact = rnea(&robot, &s.q, &s.qd, &qdd, None);
            let ti = quant_rnea_i64(&robot, &s.q, &s.qd, &qdd, fmt);
            let tl = super::super::qrbd::quant_rnea(&robot, &s.q, &s.qd, &qdd, fmt);
            for i in 0..n {
                e_int += (ti[i] - exact[i]).abs();
                e_leg += (tl[i] - exact[i]).abs();
            }
        }
        assert!(e_int > 0.0 && e_leg > 0.0);
        let ratio = e_int / e_leg;
        assert!(
            (0.05..=20.0).contains(&ratio),
            "lanes diverged: int {e_int} vs legacy {e_leg}"
        );
    }

    #[test]
    #[should_panic(expected = "integer lane supports")]
    fn wide_formats_are_rejected() {
        QInt::new(QFormat::new(16, 16)); // 32-bit: legacy lane only
    }

    // ---------------- division-deferring lane ----------------

    use super::super::scaling::{analyze, ScalingConfig, ShiftSchedule};

    fn sched(robot: &crate::model::Robot, fmt: QFormat) -> ShiftSchedule {
        analyze(robot, fmt, &ScalingConfig::default())
            .unwrap_or_else(|w| panic!("schedule for {}: {w}", robot.name))
    }

    /// The fused multi-output egress must be bitwise the three separate
    /// integer routes: q̈ from the deferred FD, M⁻¹ from the deferred
    /// sweep, C from the integer RNEA at q̈ = 0.
    #[test]
    fn dyn_all_dd_sections_match_separate_int_routes_bitwise() {
        for robot in [builtin::iiwa(), builtin::hyq()] {
            let n = robot.dof();
            let fmt = QFormat::new(12, 12);
            let sc = sched(&robot, fmt);
            let mut rng = Rng::new(915);
            for _ in 0..3 {
                let s = State::random(&robot, &mut rng);
                let tau = rng.vec_range(n, -8.0, 8.0);
                let out = quant_dyn_all_dd_i64(&robot, &s.q, &s.qd, &tau, &sc);
                assert_eq!(&out[..n], &quant_fd_dd_i64(&robot, &s.q, &s.qd, &tau, &sc)[..]);
                assert_eq!(&out[n..n + n * n], &quant_minv_dd_i64(&robot, &s.q, &sc).d[..]);
                let zero = vec![0.0; n];
                assert_eq!(
                    &out[n + n * n..],
                    &quant_rnea_i64(&robot, &s.q, &s.qd, &zero, fmt)[..]
                );
            }
        }
    }

    /// A memo hit replays the cached fixed-point sweeps bitwise, keys on
    /// the quantized joint words (sub-quantum perturbations hit), and
    /// adjacent quantized states never alias.
    #[test]
    fn dyn_all_dd_memo_hit_matches_cold_and_keys_on_quantized_words() {
        use crate::dynamics::memo::IntMemo;
        let robot = builtin::iiwa();
        let n = robot.dof();
        let fmt = QFormat::new(12, 12);
        let sc = sched(&robot, fmt);
        let ctx = QInt::new(fmt);
        let mut ws = QuantIntScratch::new(n);
        let mut memo = IntMemo::new(8);
        let mut rng = Rng::new(916);
        let s = State::random(&robot, &mut rng);
        let tau = rng.vec_range(n, -8.0, 8.0);
        let per = n * n + 2 * n;

        let mut cold = vec![0.0; per];
        ws.dyn_all_dd_memo_into(&robot, &s.q, &s.qd, &tau, &sc, &mut memo, &mut cold);
        assert_eq!(cold, quant_dyn_all_dd_i64(&robot, &s.q, &s.qd, &tau, &sc));
        assert_eq!(memo.counters(), (0, 1));

        // Quarter-quantum perturbation from a representable point:
        // same quantized word → hit, bitwise the same answer.
        let mut q_near = s.q.clone();
        q_near[0] = ctx.from_fix(ctx.to_fix(s.q[0])) + 0.25 * fmt.step();
        let mut warm = vec![0.0; per];
        ws.dyn_all_dd_memo_into(&robot, &q_near, &s.qd, &tau, &sc, &mut memo, &mut warm);
        assert_eq!(memo.counters(), (1, 1));
        assert_eq!(warm, cold);

        // One full quantum: adjacent operating point, must miss and get
        // its own correct answer.
        let mut q_adj = s.q.clone();
        q_adj[0] += fmt.step();
        let mut other = vec![0.0; per];
        ws.dyn_all_dd_memo_into(&robot, &q_adj, &s.qd, &tau, &sc, &mut memo, &mut other);
        assert_eq!(memo.counters(), (1, 2));
        assert_eq!(other, quant_dyn_all_dd_i64(&robot, &q_adj, &s.qd, &tau, &sc));
        assert_ne!(other, cold, "adjacent quantized q must not alias");
    }

    /// Holding-stage renorm boundary behaviour: for every shift `g` the
    /// held word is the same physical word at format `Q(int+g).(frac−g)`,
    /// so `rnorm_hold` of a 2f-scaled product must agree with that
    /// virtual format's round-half-away `q()` — including negative ties
    /// and both saturation rails (the new renorm stage of the deferred
    /// sweep, pinned like the base lane's `rnorm` boundaries).
    #[test]
    fn hold_renorm_matches_virtual_format_at_boundaries() {
        for fmt in [QFormat::new(12, 12), QFormat::new(10, 14), QFormat::new(8, 8)] {
            let ctx = QInt::new(fmt);
            for g in [0i32, 1, 3, 5, -2, -4] {
                let held = QFormat::new(
                    (fmt.int_bits as i32 + g) as u32,
                    (fmt.frac_bits as i32 - g) as u32,
                );
                let held_step = held.step();
                let two_f = fmt.step() * fmt.step();
                let mut ps: Vec<i64> = Vec::new();
                // Exact half-step ties of the HELD lsb on both sides,
                // plus values around both saturation rails.
                let h = 1i64 << (fmt.frac_bits as i32 + g - 1);
                for m in [1i64, -1, 3, -3, 9, -9, 255, -255] {
                    ps.push(m * h);
                }
                let rail = (held.max_val() / two_f) as i64;
                ps.extend([rail, rail + h, -rail - h, -rail - 4 * h, i64::MAX / 4, i64::MIN / 4]);
                for &p in &ps {
                    let real = p as f64 * two_f;
                    let got = ctx.rnorm_hold(p, g) as f64 * held_step;
                    assert_eq!(
                        got,
                        held.q(real),
                        "hold p = {p} g = {g} fmt = {}",
                        fmt.label()
                    );
                }
            }
        }
    }

    /// Unhold renorm boundary behaviour: a held·(f-scaled) product sits
    /// at `2f − g` bits; restoring the route format must round half away
    /// from zero at the route lsb and saturate at the route rails.
    #[test]
    fn unhold_renorm_matches_route_format_at_boundaries() {
        for fmt in [QFormat::new(12, 12), QFormat::new(10, 14)] {
            let ctx = QInt::new(fmt);
            for g in [0i32, 2, 4, -3] {
                let scale = (2.0f64).powi(-(2 * fmt.frac_bits as i32 - g));
                let h = 1i64 << (fmt.frac_bits as i32 - g - 1);
                let rail = (fmt.max_val() / scale) as i64;
                for p in [h, -h, 3 * h, -3 * h, 101 * h, -101 * h, rail, rail + h, -rail - 4 * h]
                {
                    let real = p as f64 * scale;
                    assert_eq!(
                        ctx.from_fix(ctx.rnorm_unhold(p, g)),
                        fmt.q(real),
                        "unhold p = {p} g = {g} fmt = {}",
                        fmt.label()
                    );
                }
            }
        }
    }

    /// The deferred integer M⁻¹ under its proved schedule tracks the
    /// exact f64 division-deferring kernel at the paper's fine format.
    #[test]
    fn int_minv_dd_close_to_exact_at_fine_format() {
        for robot in [builtin::iiwa(), builtin::hyq()] {
            let fmt = QFormat::new(12, 14);
            let sc = sched(&robot, fmt);
            let mut rng = Rng::new(910);
            let s = State::random(&robot, &mut rng);
            let exact = crate::dynamics::minv_dd(&robot, &s.q);
            let quant = quant_minv_dd_i64(&robot, &s.q, &sc);
            let rel = exact.sub(&quant).max_abs() / exact.max_abs();
            assert!(rel < 8e-2, "{}: relative error {rel}", robot.name);
        }
    }

    /// The schedule's holding shifts are real: the deferred sweep runs
    /// with g > 0 at 12 integer bits (the very products that used to
    /// overflow) and still stays within the error envelope of the
    /// inline-divider integer sweep.
    #[test]
    fn deferred_and_inline_int_minv_share_an_error_envelope() {
        let robot = builtin::iiwa();
        let fmt = QFormat::new(12, 12);
        let sc = sched(&robot, fmt);
        assert!(sc.max_hold_shift() > 0, "no holding shift exercised");
        let mut rng = Rng::new(911);
        let (mut e_dd, mut e_in) = (0.0f64, 0.0f64);
        for _ in 0..4 {
            let s = State::random(&robot, &mut rng);
            let exact = minv(&robot, &s.q);
            let dd = quant_minv_dd_i64(&robot, &s.q, &sc);
            let inl = quant_minv_i64(&robot, &s.q, fmt);
            e_dd += exact.sub(&dd).max_abs();
            e_in += exact.sub(&inl).max_abs();
        }
        assert!(e_dd.is_finite() && e_dd > 0.0);
        let ratio = e_dd / e_in;
        assert!(
            (0.02..=50.0).contains(&ratio),
            "deferred lane diverged: dd {e_dd} vs inline {e_in}"
        );
    }

    /// FD through the deferred M⁻¹ roundtrips ID within tolerance.
    #[test]
    fn int_fd_dd_roundtrip_error_bounded() {
        let robot = builtin::iiwa();
        let fmt = QFormat::new(12, 12);
        let sc = sched(&robot, fmt);
        let mut rng = Rng::new(912);
        let s = State::random(&robot, &mut rng);
        let n = robot.dof();
        let qdd = rng.vec_range(n, -1.0, 1.0);
        let tau = rnea(&robot, &s.q, &s.qd, &qdd, None);
        let back = quant_fd_dd_i64(&robot, &s.q, &s.qd, &tau, &sc);
        for i in 0..n {
            assert!(
                (back[i] - qdd[i]).abs() < 0.5,
                "joint {i}: {} vs {}",
                back[i],
                qdd[i]
            );
        }
    }

    /// One scratch reused across tasks and formats on the deferred path
    /// matches fresh scratches bitwise (ingest rebinding included), and
    /// a 30-DOF humanoid's schedule drives the sweep without overflowing
    /// the word (outputs stay on the rails).
    #[test]
    fn deferred_scratch_reuse_matches_fresh_bitwise() {
        let robot = builtin::iiwa();
        let n = robot.dof();
        let fa = QFormat::new(12, 12);
        let fb = QFormat::new(12, 14);
        let (sa, sb) = (sched(&robot, fa), sched(&robot, fb));
        let mut ws = QuantIntScratch::new(n);
        let mut rng = Rng::new(913);
        for sc in [&sa, &sb, &sa] {
            let s = State::random(&robot, &mut rng);
            let tau = rng.vec_range(n, -8.0, 8.0);
            let mut mi = DMat::zeros(n, n);
            ws.minv_dd_into(&robot, &s.q, sc, &mut mi);
            assert_eq!(mi.d, quant_minv_dd_i64(&robot, &s.q, sc).d);
            let mut qdd = vec![0.0; n];
            ws.fd_dd_into(&robot, &s.q, &s.qd, &tau, sc, &mut qdd);
            assert_eq!(qdd, quant_fd_dd_i64(&robot, &s.q, &s.qd, &tau, sc));
        }

        let atlas = builtin::atlas();
        let fmt = QFormat::new(12, 14);
        let sc = sched(&atlas, fmt);
        let s = State::random(&atlas, &mut rng);
        let mi = quant_minv_dd_i64(&atlas, &s.q, &sc);
        assert!(mi.d.iter().all(|x| x.is_finite() && x.abs() <= fmt.max_val() + fmt.step()));
    }

    /// Out-of-box positions saturate into the joint-limit box the
    /// schedule was proved over — the deferred sweeps never run outside
    /// their proof.
    #[test]
    fn deferred_kernels_clamp_positions_to_the_proved_box() {
        let robot = builtin::iiwa();
        let n = robot.dof();
        let sc = sched(&robot, QFormat::new(12, 12));
        let wild: Vec<f64> = robot.links.iter().map(|l| l.q_max + 3.0).collect();
        let boxed: Vec<f64> = robot.links.iter().map(|l| l.q_max).collect();
        assert_eq!(
            quant_minv_dd_i64(&robot, &wild, &sc).d,
            quant_minv_dd_i64(&robot, &boxed, &sc).d
        );
        let qd = vec![0.3; n];
        let tau = vec![1.0; n];
        assert_eq!(
            quant_fd_dd_i64(&robot, &wild, &qd, &tau, &sc),
            quant_fd_dd_i64(&robot, &boxed, &qd, &tau, &sc)
        );
    }

    /// A schedule never transfers across robots.
    #[test]
    #[should_panic(expected = "different robot")]
    fn schedule_is_robot_keyed() {
        let iiwa = builtin::iiwa();
        let hyq = builtin::hyq();
        let sc = sched(&iiwa, QFormat::new(12, 12));
        let mut rng = Rng::new(914);
        let s = State::random(&hyq, &mut rng);
        quant_minv_dd_i64(&hyq, &s.q, &sc);
    }
}
