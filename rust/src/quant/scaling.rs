//! Fixed-point scaling analysis: per-stage magnitude bounds for the
//! integer (`i64`) lane over a robot's joint-limit box, and the
//! [`ShiftSchedule`] that makes the **division-deferring** integer M⁻¹
//! possible.
//!
//! The division-deferring reformulation (Algorithm 2, see
//! [`crate::dynamics::minv`]) multiplies the articulated-inertia and
//! force updates through by the holding factor `D_i`, so the backward
//! sweep carries `N_i = D_i·IA_i − U_i U_iᵀ` and `G_i = D_i·F_i +
//! U_i·row_i` instead of their divided forms. Those holding products are
//! `|D|·|IA| ≈ Λ²`-sized — far above what a narrow word's integer bits
//! can hold — which is why the integer lane historically fell back to
//! Algorithm 1 (ROADMAP "holding factors D·IA overflow narrow words").
//! The fix is per-stage rescaling: joint `i`'s held quantities are
//! stored with `hold_shift[i]` fractional bits *moved into* integer
//! headroom (the word is reinterpreted as `Q(int+g).(frac−g)` for the
//! holding stage only), and the later multiply by `1/D_i` renormalizes
//! back to the route format. This module computes those shifts and
//! proves they fit — or rejects the format with a concrete
//! [`OverflowWitness`] naming the overflowing stage and joint.
//!
//! ## How each stage is bounded
//!
//! * **Certified stages** (`certified: true`) use interval/norm
//!   propagation that is sound over the whole joint-limit box:
//!   - kinematic constants: rotation entries lie in `[−1, 1]`; the
//!     translation of `X_up` is bounded by `‖x_tree.r‖` (plus the joint
//!     range for prismatic joints) because rotations preserve norms;
//!   - articulated inertias: the zero-velocity articulated inertia
//!     `IA_i` is PSD-dominated by the **composite rigid-body inertia**
//!     of `subtree(i)` (locking joints can only increase apparent
//!     inertia), whose λ_max is bounded by its trace
//!     `Λ_i = Σ_j tr(I_com_j) + 2 m_j d_ij² + 3 m_j` with `d_ij` the
//!     worst-case origin-to-CoM distance along the path — so every
//!     entry of `IA_i`, `‖U_i‖`, and `D_i` is `≤ Λ_i`, and the holding
//!     product `N_i` (PSD times transform congruence) is
//!     `≤ (1+t_i)²·Λ_i²`;
//!   - the divider: `D_i ≥ Sᵢᵀ I_i Sᵢ` (articulated ⪰ own link rigid
//!     inertia), a constant computable exactly per link, so
//!     `1/D_i ≤ 1/d_lo_i` bounds the divider output word.
//! * **Sampled stages** (`certified: false`) — the deferred rows, the
//!   per-column force accumulators `F`/`G`, the forward acceleration
//!   responses, and the M⁻¹ entries themselves — depend on M⁻¹(q)
//!   magnitudes for which no useful closed-form interval exists. They
//!   are bounded by replaying the f64 division-deferring sweep at the
//!   box corners + center + seeded random interior states and recording
//!   per-stage extrema; the stages that feed the *recursion* (deferred
//!   rows, `F` columns, the held `G`) gate with
//!   [`ScalingConfig::margin`] headroom on top. The M⁻¹ egress and the
//!   forward responses (`minv.out` / `minv.acol`) instead saturate
//!   gracefully at the rail — exactly the clamp the rounded-f64 lane's
//!   `QFormat::q` applies to its own output — so they are reported as
//!   saturation risks, never rejections.
//! * **Velocity-dependent diagnostics** (`gating: false`) — the RNEA
//!   velocity/bias sweep bounds over the *velocity* box are reported
//!   (they tell you when a serving envelope can saturate) but do not
//!   gate registration: torque-side saturation is input-magnitude
//!   behaviour already validated by the bit-width search's closed loop,
//!   not a structural property of the datapath like the holding
//!   factors.

use super::qformat::QFormat;
use super::qint::MAX_INT_WIDTH;
use crate::dynamics::kinematics::Kin;
use crate::dynamics::minv::Topology;
use crate::model::{JointType, Robot};
use crate::spatial::mat6::{matvec6, outer6, scale6, sub6, xtax, M6};
use crate::spatial::SV;
use crate::util::rng::Rng;
use std::collections::HashMap;
use std::fmt;
use std::sync::{Arc, Mutex, OnceLock};

/// Operating envelope + sampling knobs for [`analyze`]. The joint
/// position/velocity boxes come from the robot model; torque and
/// acceleration operands are client-supplied at serve time, so their
/// assumed bounds are part of the analysis contract (inputs beyond them
/// saturate on ingest, as any fixed-point frontend does).
#[derive(Debug, Clone, Copy)]
pub struct ScalingConfig {
    /// Assumed |τ| bound on FD torque / RNEA output operands.
    pub tau_max: f64,
    /// Assumed |q̈| bound on RNEA acceleration operands.
    pub qdd_max: f64,
    /// States sampled for the non-certified sweep stages (box corners +
    /// center always included on top of the random interior draws).
    pub samples: usize,
    /// Safety factor applied to sampled bounds of *internal* sweep
    /// stages (deferred rows, F/G accumulators) before gating.
    pub margin: f64,
    /// Seed for the interior-state draws (deterministic analysis).
    pub seed: u64,
}

impl Default for ScalingConfig {
    fn default() -> Self {
        ScalingConfig { tau_max: 16.0, qdd_max: 4.0, samples: 24, margin: 2.0, seed: 0x5CA7ED }
    }
}

/// One analyzed pipeline stage: its worst-case magnitude over the
/// operating box, which joint attains it, and how the bound was
/// obtained.
#[derive(Debug, Clone, PartialEq)]
pub struct StageBound {
    /// Stage name (e.g. `minv.hold`, `minv.Dinv`, `rnea.f`).
    pub stage: &'static str,
    /// Joint attaining the worst bound, when the stage is per-joint.
    pub joint: Option<usize>,
    /// Magnitude bound (margin included for sampled gating stages).
    pub bound: f64,
    /// Whether the bound is certified (interval/norm propagation) or
    /// sampled over the box.
    pub certified: bool,
    /// Whether exceeding the word's range at this stage rejects the
    /// format (diagnostics report saturation risk instead).
    pub gating: bool,
}

/// The proof object [`analyze`] produces for an accepted format:
/// per-joint holding-stage shifts plus every analyzed stage bound.
#[derive(Debug, Clone, PartialEq)]
pub struct ShiftSchedule {
    /// Robot the schedule was derived for (the registry routing key —
    /// schedules never transfer across robots).
    pub robot: String,
    /// [`Robot::fingerprint`] of the analyzed model: binds the schedule
    /// to the exact inertial parameters it was proved over, so a
    /// same-name payload variant can never run under another robot's
    /// shifts.
    pub fingerprint: u64,
    /// Format the schedule proves safe.
    pub fmt: QFormat,
    /// Per-joint holding-stage shift `g_i`: joint `i`'s deferred
    /// products `N_i`/`G_i` are renormalized to `frac_bits − g_i`
    /// fractional bits (integer headroom `int_bits + g_i`), restored to
    /// the route format by the deferred multiply with `1/D_i`. Positive
    /// shifts buy the headroom heavy proximal joints need (the `D·IA`
    /// overflow); **negative** shifts spend unused headroom on extra
    /// fraction bits for light distal joints, whose tiny `D` would
    /// otherwise round their held products to zero. Always in
    /// `[−frac_bits, frac_bits]`.
    pub hold_shift: Vec<i32>,
    /// Every analyzed stage, worst joint first within each stage.
    pub stages: Vec<StageBound>,
}

impl ShiftSchedule {
    /// Largest holding-stage shift in the schedule.
    pub fn max_hold_shift(&self) -> i32 {
        self.hold_shift.iter().copied().max().unwrap_or(0)
    }

    /// Non-gating stages whose worst-case bound exceeds the format's
    /// representable range: the serving envelope under which this
    /// format starts saturating (diagnostic, not a rejection).
    pub fn saturation_risks(&self) -> Vec<&StageBound> {
        let rail = self.fmt.max_val();
        self.stages.iter().filter(|s| !s.gating && s.bound > rail).collect()
    }
}

/// Why a format was rejected: the first pipeline stage whose bound
/// exceeds what the word can represent, with the joint that attains it.
#[derive(Debug, Clone)]
pub struct OverflowWitness {
    /// Robot the analysis ran for.
    pub robot: String,
    /// Rejected format.
    pub fmt: QFormat,
    /// Overflowing stage name.
    pub stage: &'static str,
    /// Joint attaining the overflow, when per-joint.
    pub joint: Option<usize>,
    /// Name of that joint's link (empty when not per-joint).
    pub joint_name: String,
    /// The stage's magnitude bound.
    pub bound: f64,
    /// What the word (plus any admissible holding shift) can represent.
    pub limit: f64,
}

impl fmt::Display for OverflowWitness {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let at = match self.joint {
            Some(j) => format!(" at joint {j} ({})", self.joint_name),
            None => String::new(),
        };
        write!(
            f,
            "scaling analysis rejects {} for '{}': stage '{}'{} needs |x| <= {:.4} \
             but the bound is {:.4}",
            self.fmt.label(),
            self.robot,
            self.stage,
            at,
            self.limit,
            self.bound
        )
    }
}

impl std::error::Error for OverflowWitness {}

/// Per-robot constants the certified propagation derives once.
struct RobotBounds {
    /// Worst-case ‖r‖ of `X_up[i]` over the joint box.
    t: Vec<f64>,
    /// Λ_i: trace bound on the subtree composite spatial inertia —
    /// dominates λ_max(IA_i), ‖U_i‖, D_i.
    lambda: Vec<f64>,
    /// Certified lower bound on the divider input: D_i ≥ Sᵢᵀ I_i Sᵢ.
    d_lo: Vec<f64>,
    /// λ_max trace bound of each link's own spatial inertia.
    lam_own: Vec<f64>,
    /// max(|q_min|, |q_max|) per joint.
    q_abs: Vec<f64>,
}

fn robot_bounds(robot: &Robot) -> RobotBounds {
    let n = robot.dof();
    let mut t = Vec::with_capacity(n);
    let mut d_lo = Vec::with_capacity(n);
    let mut lam_own = Vec::with_capacity(n);
    let mut q_abs = Vec::with_capacity(n);
    for l in &robot.links {
        let q_mag = l.q_min.abs().max(l.q_max.abs());
        q_abs.push(q_mag);
        let slide = match l.joint.jtype {
            JointType::Prismatic => q_mag,
            JointType::Revolute => 0.0,
        };
        t.push(l.x_tree.r.norm() + slide);
        // Sᵀ I S of the link's own rigid inertia: axisᵀ Ī_o axis for a
        // revolute joint (S = (axis, 0)), the mass for a prismatic one.
        let own = match l.joint.jtype {
            JointType::Revolute => l.joint.axis.dot(&l.inertia.i_o.mul_v(&l.joint.axis)),
            JointType::Prismatic => l.inertia.mass,
        };
        d_lo.push(own);
        // tr of the 6×6 spatial inertia = tr(Ī_o) + 3m bounds its λ_max.
        let i_o = &l.inertia.i_o.0;
        lam_own.push(i_o[0][0] + i_o[1][1] + i_o[2][2] + 3.0 * l.inertia.mass);
    }
    // Λ_i: for every j in subtree(i), the body-j inertia expressed at
    // frame i has trace tr(I_com_j) + 2 m_j d² + 3 m_j with d ≤ (path
    // translation norms) + ‖com_j‖ — rotations preserve norms, so the
    // origin-to-CoM distance can never exceed the summed offsets.
    let mut lambda = vec![0.0; n];
    for i in 0..n {
        let mut d_path = vec![f64::NAN; n];
        d_path[i] = 0.0;
        for j in i..n {
            if j > i {
                match robot.links[j].parent {
                    Some(p) if !d_path[p].is_nan() => d_path[j] = d_path[p] + t[j],
                    _ => continue, // not in subtree(i)
                }
            }
            let ine = &robot.links[j].inertia;
            let com = ine.com.norm();
            let i_o = &ine.i_o.0;
            // tr(I_com) = tr(Ī_o) − 2 m ‖com‖² (parallel axis), kept ≥ 0.
            let tr_com = (i_o[0][0] + i_o[1][1] + i_o[2][2] - 2.0 * ine.mass * com * com).max(0.0);
            let d = d_path[j] + com;
            lambda[i] += tr_com + 2.0 * ine.mass * d * d + 3.0 * ine.mass;
        }
    }
    RobotBounds { t, lambda, d_lo, lam_own, q_abs }
}

/// Sampled extrema of the division-deferring sweep's column stages.
struct ProbeMax {
    /// Deferred rows D_i·M⁻¹_row (before the divider multiply).
    row: f64,
    /// Per-column force accumulators F.
    fcol: f64,
    /// Per-joint max over the held G_i = D_i·F + U_i·row entries and
    /// their Xᵀ-transformed updates.
    g: Vec<f64>,
    /// Forward acceleration responses.
    acol: f64,
    /// M⁻¹ entries.
    out: f64,
}

/// Replay the f64 division-deferring M⁻¹ sweep at one state, folding
/// per-stage magnitudes into `mx`. Mirrors
/// [`crate::dynamics::minv::minv_dd_into`] (same recurrences, same
/// accumulation order) with instrumentation instead of an output matrix.
fn probe_minv_dd(robot: &Robot, topo: &Topology, q: &[f64], mx: &mut ProbeMax) {
    let n = robot.dof();
    let kin = Kin::positions(robot, q);
    let mut ia: Vec<M6> = robot.links.iter().map(|l| l.inertia.to_mat6()).collect();
    let mut u = vec![SV::ZERO; n];
    let mut dinv = vec![0.0; n];
    let mut f = vec![SV::ZERO; n * n];
    let mut row = vec![0.0; n * n];

    for i in (0..n).rev() {
        let s = kin.s[i];
        let ui = matvec6(&ia[i], &s);
        let di = s.dot(&ui);
        u[i] = ui;
        dinv[i] = 1.0 / di;
        row[i * n + i] += 1.0;
        for &j in &topo.subcols[i] {
            let sf = s.dot(&f[i * n + j]);
            if sf != 0.0 {
                row[i * n + j] -= sf;
            }
            mx.row = mx.row.max(row[i * n + j].abs());
        }
        mx.row = mx.row.max(row[i * n + i].abs());
        if let Some(p) = robot.links[i].parent {
            let uut = outer6(&ui, &ui);
            let ni = sub6(&scale6(&ia[i], di), &uut);
            let contrib = xtax(&kin.xup[i].to_mat6(), &ni);
            for (dst, c) in ia[p].iter_mut().zip(&contrib) {
                *dst += c * dinv[i];
            }
            for &j in &topo.subcols[i] {
                let gij = f[i * n + j].scale(di) + ui.scale(row[i * n + j]);
                let up = kin.xup[i].inv_apply_force(&gij);
                for v in gij.to_array().iter().chain(up.to_array().iter()) {
                    mx.g[i] = mx.g[i].max(v.abs());
                }
                f[p * n + j] = f[p * n + j] + up.scale(dinv[i]);
                for v in f[p * n + j].to_array() {
                    mx.fcol = mx.fcol.max(v.abs());
                }
            }
        }
    }

    let mut a = vec![SV::ZERO; n * n];
    for i in 0..n {
        for j in 0..n {
            row[i * n + j] *= dinv[i];
            mx.out = mx.out.max(row[i * n + j].abs());
        }
    }
    for i in 0..n {
        let s = kin.s[i];
        match robot.links[i].parent {
            None => {
                for &j in &topo.brcols[i] {
                    a[i * n + j] = s.scale(row[i * n + j]);
                }
            }
            Some(p) => {
                for &j in &topo.brcols[i] {
                    let xa = kin.xup[i].apply(&a[p * n + j]);
                    let corr = dinv[i] * u[i].dot(&xa);
                    if corr != 0.0 {
                        row[i * n + j] -= corr;
                        mx.out = mx.out.max(row[i * n + j].abs());
                    }
                    a[i * n + j] = xa + s.scale(row[i * n + j]);
                }
            }
        }
        for &j in &topo.brcols[i] {
            for v in a[i * n + j].to_array() {
                mx.acol = mx.acol.max(v.abs());
            }
        }
    }
}

/// Sample the joint-limit box: both full corners, the center, then
/// seeded uniform interior states.
fn sampled_extrema(robot: &Robot, cfg: &ScalingConfig) -> ProbeMax {
    let n = robot.dof();
    let topo = Topology::new(robot);
    let mut mx = ProbeMax { row: 0.0, fcol: 0.0, g: vec![0.0; n], acol: 0.0, out: 0.0 };
    let lo: Vec<f64> = robot.links.iter().map(|l| l.q_min).collect();
    let hi: Vec<f64> = robot.links.iter().map(|l| l.q_max).collect();
    let mid: Vec<f64> = lo.iter().zip(&hi).map(|(a, b)| 0.5 * (a + b)).collect();
    probe_minv_dd(robot, &topo, &lo, &mut mx);
    probe_minv_dd(robot, &topo, &hi, &mut mx);
    probe_minv_dd(robot, &topo, &mid, &mut mx);
    let mut rng = Rng::new(cfg.seed);
    for _ in 0..cfg.samples.saturating_sub(3) {
        let q: Vec<f64> = robot.links.iter().map(|l| rng.range(l.q_min, l.q_max)).collect();
        probe_minv_dd(robot, &topo, &q, &mut mx);
    }
    mx
}

/// Argmax helper: (worst joint, worst bound) over a per-joint slice.
fn worst(vals: &[f64]) -> (Option<usize>, f64) {
    let mut j = 0;
    let mut b = f64::NEG_INFINITY;
    for (i, &v) in vals.iter().enumerate() {
        if v > b {
            b = v;
            j = i;
        }
    }
    (Some(j), b)
}

/// Analyze one (robot, format) pair over the operating box: returns the
/// per-joint [`ShiftSchedule`] when every gating stage fits the word, or
/// the worst [`OverflowWitness`] otherwise. Deterministic for fixed
/// inputs (the engines and pool workers rely on recomputed schedules
/// being identical).
pub fn analyze(
    robot: &Robot,
    fmt: QFormat,
    cfg: &ScalingConfig,
) -> Result<ShiftSchedule, OverflowWitness> {
    let n = robot.dof();
    let rail = fmt.max_val();
    let rb = robot_bounds(robot);
    let mx = sampled_extrema(robot, cfg);

    // ---- certified per-joint bounds for the deferred backward sweep.
    let inv_hi: Vec<f64> = rb
        .d_lo
        .iter()
        .map(|&d| if d > 0.0 { 1.0 / d } else { f64::INFINITY })
        .collect();
    // Holding stage: the largest quantity carried at frac−g bits is the
    // congruence-transformed N_i ≤ (1+t)²·Λ² (certified; N PSD with
    // λ_max ≤ D·λ_max(IA) ≤ Λ², times ‖X‖² ≤ (1+t)²), or the sampled
    // G_i/XᵀG_i with margin.
    let held: Vec<f64> = (0..n)
        .map(|i| {
            let s = 1.0 + rb.t[i];
            (s * s * rb.lambda[i] * rb.lambda[i]).max(cfg.margin * mx.g[i])
        })
        .collect();
    // Smallest shift whose reinterpreted rail `max_val·2^g` still holds
    // the bound; negative when the bound leaves headroom to spare (light
    // distal joints gain fraction bits instead of losing them).
    let hold_shift: Vec<i32> = held
        .iter()
        .map(|&h| {
            let g = (h / rail).log2().ceil();
            let g = if g.is_finite() { g as i32 } else { 0 };
            g.max(-(fmt.frac_bits as i32))
        })
        .collect();

    // ---- certified velocity/bias diagnostics (reported, non-gating).
    let mut vw = vec![0.0; n];
    let mut vl = vec![0.0; n];
    let mut aw = vec![0.0; n];
    let mut al = vec![0.0; n];
    let g_norm = robot.gravity.norm();
    for i in 0..n {
        let l = &robot.links[i];
        let (pvw, pvl, paw, pal) = match l.parent {
            Some(p) => (vw[p], vl[p], aw[p], al[p]),
            None => (0.0, 0.0, 0.0, g_norm),
        };
        let (rev_qd, pri_qd) = match l.joint.jtype {
            JointType::Revolute => (l.qd_max, 0.0),
            JointType::Prismatic => (0.0, l.qd_max),
        };
        vw[i] = pvw + rev_qd;
        vl[i] = pvl + rb.t[i] * pvw + pri_qd;
        let (rev_u, pri_u) = match l.joint.jtype {
            JointType::Revolute => (cfg.qdd_max, 0.0),
            JointType::Prismatic => (0.0, cfg.qdd_max),
        };
        aw[i] = paw + rev_u + vw[i] * l.qd_max;
        al[i] = pal + rb.t[i] * paw + pri_u + vw[i].max(vl[i]) * l.qd_max;
    }
    // Link forces f = I a + v ×* (I v), accumulated tip → base.
    let mut f_acc: Vec<f64> = (0..n)
        .map(|i| rb.lam_own[i] * (aw[i] + al[i] + (vw[i] + vl[i]) * (vw[i] + vl[i])))
        .collect();
    for i in (0..n).rev() {
        if let Some(p) = robot.links[i].parent {
            let up = (1.0 + rb.t[i]) * f_acc[i];
            f_acc[p] += up;
        }
    }

    // ---- stage table: gating stages first, diagnostics after.
    let (tj, tb) = worst(&rb.t);
    let (lj, lb) = worst(&rb.lambda);
    let (ij, ib) = worst(&inv_hi);
    let (qj, qb) = worst(&rb.q_abs);
    let qd_all: Vec<f64> = robot.links.iter().map(|l| l.qd_max).collect();
    let (dj, db) = worst(&qd_all);
    let (hj, hb) = worst(&held);
    let (vj, vb) = worst(&vw.iter().zip(&vl).map(|(a, b)| a.max(*b)).collect::<Vec<f64>>());
    let (aj, ab) = worst(&aw.iter().zip(&al).map(|(a, b)| a.max(*b)).collect::<Vec<f64>>());
    let (fj, fb) = worst(&f_acc);
    let cert = |stage, joint, bound| StageBound { stage, joint, bound, certified: true, gating: true };
    let samp = |stage, bound| StageBound { stage, joint: None, bound, certified: false, gating: true };
    let diag = |stage, joint, bound| StageBound { stage, joint, bound, certified: true, gating: false };
    let stages = vec![
        cert("input.q", qj, qb),
        cert("input.qd", dj, db),
        cert("input.tau", None, cfg.tau_max),
        cert("kin.xform", tj, tb.max(1.0)),
        cert("kin.gravity", None, g_norm),
        cert("minv.unit", None, 1.0),
        cert("minv.U", lj, lb),
        cert("minv.D", lj, lb),
        cert("minv.Dinv", ij, ib),
        // The holding stage gates through its shift (checked below); its
        // bound records the worst held magnitude.
        StageBound { stage: "minv.hold", joint: hj, bound: hb, certified: true, gating: true },
        samp("minv.row", cfg.margin * mx.row),
        samp("minv.F", cfg.margin * mx.fcol),
        // Egress/forward-sweep stages carry M⁻¹-scale values that clamp
        // at the rail EXACTLY like the rounded-f64 lane's `QFormat::q`
        // (whose output saturates too): overflow there is a bounded,
        // monotone distortion shared by both lanes, not recursion
        // corruption — reported as saturation risk, never a rejection.
        // The stages that feed the recursion (U/D/divider, holding
        // products, deferred rows, F columns) are the gating set.
        StageBound { stage: "minv.out", joint: None, bound: mx.out, certified: false, gating: false },
        StageBound { stage: "minv.acol", joint: None, bound: mx.acol, certified: false, gating: false },
        diag("rnea.v", vj, vb),
        diag("rnea.a", aj, ab),
        diag("rnea.f", fj, fb),
        diag("rnea.tau", fj, fb),
        diag("fd.rhs", fj, cfg.tau_max + fb),
    ];

    // ---- gate: pick the worst violation as the witness.
    let mut witness: Option<OverflowWitness> = None;
    let mut consider = |stage: &'static str, joint: Option<usize>, bound: f64, limit: f64| {
        if bound > limit {
            let ratio = bound / limit;
            let cur = witness.as_ref().map(|w| w.bound / w.limit).unwrap_or(0.0);
            if ratio > cur {
                witness = Some(OverflowWitness {
                    robot: robot.name.clone(),
                    fmt,
                    stage,
                    joint,
                    joint_name: joint.map(|j| robot.links[j].name.clone()).unwrap_or_default(),
                    bound,
                    limit,
                });
            }
        }
    };
    for s in &stages {
        if !s.gating || s.stage == "minv.hold" {
            continue;
        }
        consider(s.stage, s.joint, s.bound, rail);
    }
    // Holding shifts may not eat more headroom than the format has
    // fractional bits (g > frac would leave the held word with negative
    // precision).
    for (i, (&g, &h)) in hold_shift.iter().zip(&held).enumerate() {
        if g > fmt.frac_bits as i32 {
            let limit = rail * (2.0f64).powi(fmt.frac_bits as i32);
            consider("minv.hold", Some(i), h, limit);
        }
    }
    match witness {
        Some(w) => Err(w),
        None => Ok(ShiftSchedule {
            robot: robot.name.clone(),
            fingerprint: robot.fingerprint(),
            fmt,
            hold_shift,
            stages,
        }),
    }
}

/// Process-wide memo of accepted default-config schedules keyed by
/// (robot fingerprint, format): one serve startup validates a `qint`
/// robot at registration and again in each of its four route engines —
/// the analysis (robot bounds + ~24 sampled f64 sweeps) should run
/// once per (robot, format), not once per route. Determinism makes the
/// memo purely a cost optimization; the modest cap below only guards a
/// pathological churn of distinct robots.
fn schedule_memo() -> &'static Mutex<HashMap<(u64, u32, u32), Arc<ShiftSchedule>>> {
    static MEMO: OnceLock<Mutex<HashMap<(u64, u32, u32), Arc<ShiftSchedule>>>> = OnceLock::new();
    MEMO.get_or_init(|| Mutex::new(HashMap::new()))
}

const SCHEDULE_MEMO_CAP: usize = 64;

/// Registration-time gate for the `qint` serving backend: word-width
/// checks plus [`analyze`] under the default [`ScalingConfig`],
/// memoized per (robot fingerprint, format). The error string names
/// the failure (width cap or overflow witness) so registries can
/// surface it verbatim — an explicit `qint` spec must never silently
/// degrade to the rounded-f64 lane.
pub fn validate_int_backend(robot: &Robot, fmt: QFormat) -> Result<Arc<ShiftSchedule>, String> {
    let w = fmt.width();
    if !(2..=MAX_INT_WIDTH).contains(&w) {
        return Err(format!(
            "the integer lane carries 2..={MAX_INT_WIDTH}-bit words, got {} ({}-bit); \
             use the rounded-f64 'quant' backend for wider formats",
            fmt.label(),
            w
        ));
    }
    if fmt.int_bits < 2 {
        return Err(format!(
            "{} has {} integer bit(s); the integer lane needs a sign bit plus headroom \
             (int_bits >= 2)",
            fmt.label(),
            fmt.int_bits
        ));
    }
    let key = (robot.fingerprint(), fmt.int_bits, fmt.frac_bits);
    if let Some(s) = schedule_memo().lock().unwrap().get(&key) {
        return Ok(Arc::clone(s));
    }
    let sched =
        Arc::new(analyze(robot, fmt, &ScalingConfig::default()).map_err(|e| e.to_string())?);
    let mut memo = schedule_memo().lock().unwrap();
    if memo.len() >= SCHEDULE_MEMO_CAP {
        memo.clear();
    }
    memo.insert(key, Arc::clone(&sched));
    Ok(sched)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::builtin;

    #[test]
    fn paper_formats_accepted_for_showcase_robots() {
        for robot in [builtin::iiwa(), builtin::hyq(), builtin::atlas()] {
            for fmt in [QFormat::new(12, 12), QFormat::new(12, 14)] {
                let sched = analyze(&robot, fmt, &ScalingConfig::default())
                    .unwrap_or_else(|w| panic!("{} {}: {w}", robot.name, fmt.label()));
                assert_eq!(sched.hold_shift.len(), robot.dof());
                assert!(sched
                    .hold_shift
                    .iter()
                    .all(|&g| g.unsigned_abs() <= fmt.frac_bits));
                // Every gating stage fits the word.
                for s in sched.stages.iter().filter(|s| s.gating && s.stage != "minv.hold") {
                    assert!(s.bound <= fmt.max_val(), "{}: {} = {}", robot.name, s.stage, s.bound);
                }
            }
        }
    }

    #[test]
    fn holding_factors_need_real_shifts() {
        // The whole point of the schedule: D·IA-scale products do NOT fit
        // the paper's 24-bit words directly — some joint must hold with
        // g > 0, and the certified Λ bound grows toward the base.
        let robot = builtin::iiwa();
        let sched = analyze(&robot, QFormat::new(12, 12), &ScalingConfig::default()).unwrap();
        assert!(
            sched.max_hold_shift() > 0,
            "iiwa holding products fit 12 integer bits without a shift? {:?}",
            sched.hold_shift
        );
        // Base joints articulate the whole arm: their shift can't be
        // smaller than the wrist's — and the light wrist should *gain*
        // fraction bits (negative shift), else its tiny D·IA products
        // round to zero at the route lsb.
        assert!(sched.hold_shift[0] >= sched.hold_shift[robot.dof() - 1]);
        assert!(
            sched.hold_shift[robot.dof() - 1] < 0,
            "wrist holding shift should be negative: {:?}",
            sched.hold_shift
        );
    }

    #[test]
    fn narrow_divider_word_rejected_with_witness() {
        // Baxter's wrist roll projects ~4.5e-4 kg·m² on its own axis:
        // 1/D exceeds 12 integer bits, so 24-bit formats must be
        // rejected naming the divider stage and the joint.
        let robot = builtin::baxter();
        let w = analyze(&robot, QFormat::new(12, 12), &ScalingConfig::default())
            .expect_err("baxter@12.12 must reject");
        assert_eq!(w.stage, "minv.Dinv");
        assert!(w.joint_name.contains("w2"), "worst joint: {}", w.joint_name);
        assert!(w.bound > w.limit);
        let msg = w.to_string();
        assert!(msg.contains("minv.Dinv") && msg.contains("baxter") && msg.contains("24b(12.12)"));
        // One more integer bit clears the divider: 13.13 is accepted.
        analyze(&robot, QFormat::new(13, 13), &ScalingConfig::default())
            .expect("baxter@13.13 fits");
    }

    #[test]
    fn eighteen_bit_words_reject_heavy_humanoids() {
        let atlas = builtin::atlas();
        let w = analyze(&atlas, QFormat::new(10, 8), &ScalingConfig::default())
            .expect_err("atlas@10.8 must reject");
        assert_eq!(w.stage, "minv.Dinv");
        // ... while the 7-DOF arm still fits the 18-bit DSP word.
        analyze(&builtin::iiwa(), QFormat::new(10, 8), &ScalingConfig::default())
            .expect("iiwa@10.8 fits");
    }

    #[test]
    fn analysis_is_deterministic() {
        let robot = builtin::atlas();
        let cfg = ScalingConfig::default();
        let a = analyze(&robot, QFormat::new(12, 14), &cfg).unwrap();
        let b = analyze(&robot, QFormat::new(12, 14), &cfg).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn validate_rejects_wide_and_degenerate_formats() {
        let robot = builtin::iiwa();
        let err = validate_int_backend(&robot, QFormat::new(16, 16)).unwrap_err();
        assert!(err.contains("26"), "width cap not named: {err}");
        let err = validate_int_backend(&robot, QFormat::new(1, 20)).unwrap_err();
        assert!(err.contains("int_bits"), "{err}");
        validate_int_backend(&robot, QFormat::new(12, 14)).expect("accepted");
    }

    #[test]
    fn velocity_diagnostics_are_reported_not_gating() {
        // Atlas at 12 m/s joint speed has worst-case Coriolis torques far
        // over any 12-integer-bit rail — the analysis must report that as
        // saturation risk, not reject the format.
        let robot = builtin::atlas();
        let sched = analyze(&robot, QFormat::new(12, 14), &ScalingConfig::default()).unwrap();
        let risks = sched.saturation_risks();
        assert!(
            risks.iter().any(|s| s.stage.starts_with("rnea.")),
            "expected velocity-box saturation diagnostics, got {risks:?}"
        );
    }
}
