//! Precision-aware quantization framework (paper §III, Fig. 4): Q-format
//! emulation, quantized RBD functions (the rounded-f64 lane in [`qrbd`]
//! and the true-integer `i64` lane in [`qint`]), the fixed-point scaling
//! analysis that certifies integer shift schedules ([`scaling`]), the
//! error analyzer with the three amplification heuristics, Minv error
//! compensation, and the bit-width search driven by the ICMS closed loop.

pub mod analyzer;
pub mod compensate;
pub mod qformat;
pub mod qint;
pub mod qrbd;
pub mod scaling;
pub mod search;

pub use qformat::QFormat;
pub use qint::{QInt, QuantIntScratch};
pub use qrbd::QuantScratch;
pub use scaling::{OverflowWitness, ScalingConfig, ShiftSchedule};
