//! Precision-aware quantization framework (paper §III, Fig. 4): Q-format
//! emulation, quantized RBD functions, the error analyzer with the three
//! amplification heuristics, Minv error compensation, and the bit-width
//! search driven by the ICMS closed loop.

pub mod analyzer;
pub mod compensate;
pub mod qformat;
pub mod qrbd;
pub mod search;

pub use qformat::QFormat;
pub use qrbd::QuantScratch;
