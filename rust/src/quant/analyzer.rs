//! Quantization Error Analyzer (the ICMS component of Fig. 4).
//!
//! Monte-Carlo error statistics for quantized RBD plus the three
//! error-amplification heuristics of §III-C that order the search:
//!
//! 1. **Joint-depth accumulation** — errors accumulate base→tip
//!    (Fig. 5(c)), so deeper joints are evaluated first.
//! 2. **Inertia-induced amplification** — joints with large ‖I_i‖ amplify
//!    multiplicative error terms.
//! 3. **High-speed amplification** — high-velocity states excite the
//!    velocity-dependent error terms, so they are simulated first.

use super::qformat::QFormat;
use super::qrbd::{quant_kin, quant_rnea, Q};
use crate::dynamics::Kin;
use crate::model::{Robot, State};
use crate::util::rng::Rng;

/// Per-joint velocity quantization error profile (regenerates Fig. 5(c)).
#[derive(Debug, Clone)]
pub struct VelocityErrorProfile {
    /// mean |δv_i| per joint over the sampled states.
    pub mean_abs_err: Vec<f64>,
    pub max_abs_err: Vec<f64>,
}

/// Mean/max per-joint error of quantized link velocities vs exact.
pub fn velocity_error_profile(
    robot: &Robot,
    fmt: QFormat,
    samples: usize,
    rng: &mut Rng,
) -> VelocityErrorProfile {
    let n = robot.dof();
    let ctx = Q::new(fmt);
    let mut mean = vec![0.0f64; n];
    let mut maxe = vec![0.0f64; n];
    for _ in 0..samples {
        let s = State::random(robot, rng);
        let exact = Kin::new(robot, &s.q, &s.qd);
        let quant = quant_kin(robot, &s.q, &s.qd, &ctx);
        for i in 0..n {
            let e = (exact.v[i] - quant.v[i]).norm();
            mean[i] += e;
            maxe[i] = maxe[i].max(e);
        }
    }
    for m in &mut mean {
        *m /= samples as f64;
    }
    VelocityErrorProfile { mean_abs_err: mean, max_abs_err: maxe }
}

/// Torque error statistics of quantized RNEA.
#[derive(Debug, Clone, Copy)]
pub struct TorqueErrorStats {
    pub mean_abs: f64,
    pub max_abs: f64,
    pub rms: f64,
}

pub fn rnea_error_stats(
    robot: &Robot,
    fmt: QFormat,
    samples: usize,
    rng: &mut Rng,
    high_speed: bool,
) -> TorqueErrorStats {
    let n = robot.dof();
    let mut sum = 0.0;
    let mut sumsq = 0.0;
    let mut maxe: f64 = 0.0;
    let mut count = 0usize;
    for _ in 0..samples {
        let mut s = State::random(robot, rng);
        if high_speed {
            // Heuristic ❸: drive each joint at its velocity limit.
            for (i, l) in robot.links.iter().enumerate() {
                s.qd[i] = l.qd_max * if rng.bool() { 1.0 } else { -1.0 };
            }
        }
        let qdd = rng.vec_range(n, -2.0, 2.0);
        let exact = crate::dynamics::rnea(robot, &s.q, &s.qd, &qdd, None);
        let quant = quant_rnea(robot, &s.q, &s.qd, &qdd, fmt);
        for i in 0..n {
            let e = (exact[i] - quant[i]).abs();
            sum += e;
            sumsq += e * e;
            maxe = maxe.max(e);
            count += 1;
        }
    }
    TorqueErrorStats {
        mean_abs: sum / count as f64,
        max_abs: maxe,
        rms: (sumsq / count as f64).sqrt(),
    }
}

/// Evaluation priority order for joints (heuristics ❶ + ❷): sort by
/// depth descending, tie-broken by the Frobenius norm of the link
/// inertia descending. The search evaluates error on these joints first
/// to reject bad formats early.
pub fn joint_priority(robot: &Robot) -> Vec<usize> {
    let n = robot.dof();
    let mut idx: Vec<usize> = (0..n).collect();
    let score: Vec<(usize, f64)> = (0..n)
        .map(|i| {
            let m6 = robot.links[i].inertia.to_mat6();
            let fro: f64 = m6.iter().map(|x| x * x).sum::<f64>().sqrt();
            (robot.depth(i), fro)
        })
        .collect();
    idx.sort_by(|&a, &b| {
        score[b].0.cmp(&score[a].0).then(
            score[b].1.partial_cmp(&score[a].1).unwrap_or(std::cmp::Ordering::Equal),
        )
    });
    idx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::builtin;

    /// Fig. 5(c): on a serial chain, velocity quantization error grows
    /// with joint depth (monotone in aggregate: tip ≥ base).
    #[test]
    fn depth_accumulation_on_iiwa() {
        let robot = builtin::iiwa();
        let mut rng = Rng::new(600);
        let p = velocity_error_profile(&robot, QFormat::new(10, 8), 64, &mut rng);
        let base_err = p.mean_abs_err[0];
        let tip_err = p.mean_abs_err[robot.dof() - 1];
        assert!(
            tip_err > base_err,
            "tip error {tip_err} should exceed base error {base_err} (Fig 5c)"
        );
    }

    /// Heuristic ❸: high-speed states produce larger torque errors.
    #[test]
    fn high_speed_amplification() {
        let robot = builtin::iiwa();
        let fmt = QFormat::new(12, 10);
        let mut r1 = Rng::new(601);
        let mut r2 = Rng::new(601);
        let normal = rnea_error_stats(&robot, fmt, 48, &mut r1, false);
        let fast = rnea_error_stats(&robot, fmt, 48, &mut r2, true);
        assert!(
            fast.rms > normal.rms,
            "high-speed rms {} should exceed normal {}",
            fast.rms,
            normal.rms
        );
    }

    #[test]
    fn priority_prefers_deep_joints() {
        let robot = builtin::iiwa();
        let p = joint_priority(&robot);
        // iiwa is a chain: priority must be exactly reversed indices.
        assert_eq!(p[0], robot.dof() - 1);
        assert_eq!(*p.last().unwrap(), 0);
    }

    #[test]
    fn priority_is_permutation() {
        for robot in [builtin::hyq(), builtin::atlas()] {
            let mut p = joint_priority(&robot);
            p.sort_unstable();
            assert_eq!(p, (0..robot.dof()).collect::<Vec<_>>());
        }
    }

    #[test]
    fn finer_formats_reduce_torque_error() {
        let robot = builtin::hyq();
        let mut errs = Vec::new();
        for frac in [8u32, 12, 16] {
            let mut rng = Rng::new(602);
            let st = rnea_error_stats(&robot, QFormat::new(12, frac), 32, &mut rng, false);
            errs.push(st.rms);
        }
        assert!(errs[0] > errs[1] && errs[1] > errs[2], "{errs:?}");
    }
}
