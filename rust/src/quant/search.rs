//! Bit-width search (the outer loop of the quantization framework,
//! Fig. 4): walk candidate Q-formats from coarse to fine, prune with the
//! cheap error-amplification heuristics (§III-C), validate survivors in
//! the full closed-loop ICMS, and return the narrowest format meeting the
//! user's trajectory-error tolerance. FPGA mode restricts candidates to
//! DSP word sizes (18/24-bit, then 32-bit fallback) per §III-B "Outputs".

use super::analyzer::{joint_priority, rnea_error_stats};
use super::qformat::QFormat;
use crate::model::Robot;
use crate::sim::icms::{evaluate_quantization, ControllerKind, IcmsConfig};
use crate::util::rng::Rng;

/// User-facing precision requirements (§III-B "Inputs").
#[derive(Debug, Clone, Copy)]
pub struct Requirements {
    /// Trajectory error tolerance [m] (e.g. 0.5 mm for iiwa).
    pub traj_tol: f64,
    /// Quick-reject threshold on open-loop RNEA torque RMS error [Nm]:
    /// candidates worse than this never reach the simulator.
    pub torque_rms_gate: f64,
    /// Restrict the search to FPGA DSP word sizes.
    pub fpga_word_sizes: bool,
}

impl Default for Requirements {
    fn default() -> Self {
        Requirements { traj_tol: 5e-4, torque_rms_gate: 5.0, fpga_word_sizes: true }
    }
}

#[derive(Debug, Clone)]
pub struct SearchOutcome {
    pub chosen: Option<QFormat>,
    /// (format, gate RMS error, closed-loop trajectory error, accepted).
    pub trials: Vec<(QFormat, f64, Option<f64>, bool)>,
    /// Joint evaluation priority used for pruning (heuristics ❶+❷).
    pub priority: Vec<usize>,
}

/// Candidate ladder, coarse → fine.
pub fn candidates(fpga_word_sizes: bool) -> Vec<QFormat> {
    if fpga_word_sizes {
        // 18-bit and 24-bit words with a couple of int/frac splits, then
        // the 32-bit fallback. Sub-18 and 19–23-bit widths are excluded
        // (§III-B: no DSP saving).
        vec![
            QFormat::new(10, 8),
            QFormat::new(8, 10),
            QFormat::new(12, 12),
            QFormat::new(10, 14),
            QFormat::new(16, 16),
        ]
    } else {
        // ASIC mode: finer-grained ladder (§III-B "Beyond FPGAs").
        let mut v = Vec::new();
        for total in [14u32, 16, 18, 20, 22, 24, 28, 32] {
            for int_bits in [total / 2, total / 2 + 2] {
                if int_bits < total {
                    v.push(QFormat::new(int_bits, total - int_bits));
                }
            }
        }
        v
    }
}

/// Run the search for one robot/controller pair.
pub fn search(
    robot: &Robot,
    controller: ControllerKind,
    req: &Requirements,
    icms_steps: usize,
    seed: u64,
) -> SearchOutcome {
    let mut rng = Rng::new(seed);
    let priority = joint_priority(robot);
    let mut trials = Vec::new();
    let mut chosen = None;

    for fmt in candidates(req.fpga_word_sizes) {
        // ---- cheap gate: high-speed open-loop RNEA error (heuristic ❸:
        // evaluate the aggressive states first; prune without simulating).
        let stats = rnea_error_stats(robot, fmt, 16, &mut rng, true);
        if stats.rms > req.torque_rms_gate {
            trials.push((fmt, stats.rms, None, false));
            continue;
        }
        // ---- full ICMS validation.
        let mut cfg = IcmsConfig::default_for(robot, controller);
        cfg.steps = icms_steps;
        let metrics = evaluate_quantization(robot, &cfg, fmt);
        let ok = metrics.traj_err_max <= req.traj_tol;
        trials.push((fmt, stats.rms, Some(metrics.traj_err_max), ok));
        if ok {
            chosen = Some(fmt);
            break; // ladder is coarse→fine: first pass is the narrowest
        }
    }
    SearchOutcome { chosen, trials, priority }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::builtin;

    #[test]
    fn ladder_is_coarse_to_fine() {
        let c = candidates(true);
        for w in c.windows(2) {
            assert!(w[0].width() <= w[1].width());
        }
        // FPGA ladder only contains DSP word sizes.
        for f in &c {
            assert!([18, 24, 32].contains(&f.width()), "{}", f.label());
        }
    }

    #[test]
    fn search_finds_format_for_relaxed_tolerance() {
        let robot = builtin::iiwa();
        let req = Requirements { traj_tol: 5e-3, ..Default::default() };
        let out = search(&robot, ControllerKind::Pid, &req, 300, 42);
        assert!(out.chosen.is_some(), "a 5mm tolerance must be satisfiable: {:?}", out.trials);
        // And the accepted trial is marked accordingly.
        let last = out.trials.last().unwrap();
        assert!(last.3);
    }

    #[test]
    fn impossible_tolerance_chooses_nothing() {
        let robot = builtin::iiwa();
        let req = Requirements { traj_tol: 1e-12, ..Default::default() };
        let out = search(&robot, ControllerKind::Pid, &req, 200, 43);
        assert!(out.chosen.is_none());
        assert_eq!(out.trials.len(), candidates(true).len(), "all candidates tried");
    }

    #[test]
    fn gate_prunes_without_simulation() {
        // With a torque gate of ~0, every candidate is pruned at the
        // cheap stage and no closed loop runs (all sim results None).
        let robot = builtin::atlas();
        let req =
            Requirements { traj_tol: 1e-3, torque_rms_gate: 1e-9, fpga_word_sizes: true };
        let out = search(&robot, ControllerKind::Pid, &req, 100, 44);
        assert!(out.chosen.is_none());
        for (_, _, sim, _) in &out.trials {
            assert!(sim.is_none(), "gate must prune before ICMS");
        }
    }

    #[test]
    fn asic_ladder_is_finer_grained() {
        assert!(candidates(false).len() > candidates(true).len());
    }
}
