//! Quantized RBD functions: RNEA / Minv / FD evaluated in emulated fixed
//! point. Constants (transforms, inertias), inputs, and every
//! intermediate spatial quantity are rounded to the target Q-format after
//! each operation group — mirroring what the fixed-point datapath
//! computes and therefore how errors propagate (paper §III-C, Fig. 5).

use super::qformat::QFormat;
use crate::dynamics::kinematics::Kin;
use crate::model::Robot;
use crate::spatial::mat6::{matvec6, mul6, outer6, scale6, sub6, t6, M6};
use crate::spatial::{DMat, SV, V3};

/// Quantization context: rounds scalars / spatial vectors / matrices.
#[derive(Debug, Clone, Copy)]
pub struct Q {
    pub fmt: QFormat,
}

impl Q {
    pub fn new(fmt: QFormat) -> Q {
        Q { fmt }
    }

    pub fn s(&self, x: f64) -> f64 {
        self.fmt.q(x)
    }

    pub fn sv(&self, v: &SV) -> SV {
        SV::new(
            V3::new(self.s(v.ang.x()), self.s(v.ang.y()), self.s(v.ang.z())),
            V3::new(self.s(v.lin.x()), self.s(v.lin.y()), self.s(v.lin.z())),
        )
    }

    pub fn m6(&self, m: &M6) -> M6 {
        let mut out = *m;
        for row in &mut out {
            for x in row {
                *x = self.s(*x);
            }
        }
        out
    }

    pub fn vec(&self, xs: &[f64]) -> Vec<f64> {
        xs.iter().map(|&x| self.s(x)).collect()
    }
}

/// Quantized kinematics: joint transforms with quantized entries.
/// Returns the same Kin shape the exact algorithms use; velocities are
/// quantized per step.
pub fn quant_kin(robot: &Robot, q: &[f64], qd: &[f64], ctx: &Q) -> Kin {
    let n = robot.dof();
    let qq = ctx.vec(q);
    let qdq = ctx.vec(qd);
    let mut kin = Kin::new(robot, &qq, &qdq);
    // Quantize the transform entries (the ᵢX_λ matrices of §II-A) and
    // re-propagate velocities in quantized arithmetic.
    for i in 0..n {
        for r in 0..3 {
            for c in 0..3 {
                kin.xup[i].e.0[r][c] = ctx.s(kin.xup[i].e.0[r][c]);
                kin.xj[i].e.0[r][c] = ctx.s(kin.xj[i].e.0[r][c]);
            }
            kin.xup[i].r.0[r] = ctx.s(kin.xup[i].r.0[r]);
            kin.xj[i].r.0[r] = ctx.s(kin.xj[i].r.0[r]);
        }
    }
    for i in 0..n {
        let s = kin.s[i];
        let vj = s.scale(qdq[i]);
        kin.v[i] = match robot.links[i].parent {
            Some(p) => {
                let vp = kin.v[p];
                ctx.sv(&(kin.xup[i].apply(&vp) + vj))
            }
            None => ctx.sv(&vj),
        };
    }
    kin
}

/// Quantized RNEA (ID). Intermediate v/a/f quantized per joint step.
pub fn quant_rnea(
    robot: &Robot,
    q: &[f64],
    qd: &[f64],
    qdd: &[f64],
    fmt: QFormat,
) -> Vec<f64> {
    let ctx = Q::new(fmt);
    let n = robot.dof();
    let kin = quant_kin(robot, q, qd, &ctx);
    let qddq = ctx.vec(qdd);
    let a0 = SV::new(V3::ZERO, -robot.gravity);

    let mut a: Vec<SV> = Vec::with_capacity(n);
    let mut f: Vec<SV> = Vec::with_capacity(n);
    for i in 0..n {
        let link = &robot.links[i];
        let s = kin.s[i];
        let vi = kin.v[i];
        let ap = match link.parent {
            Some(p) => a[p],
            None => a0,
        };
        let ai = ctx.sv(&(kin.xup[i].apply(&ap) + s.scale(qddq[i]) + vi.crm(&s.scale(kin.qd[i]))));
        // Inertia constants quantized once (as stored in BRAM/LUTs).
        let iq = ctx.m6(&link.inertia.to_mat6());
        let fi = ctx.sv(&(matvec6(&iq, &ai) + vi.crf(&matvec6(&iq, &vi))));
        a.push(ai);
        f.push(fi);
    }
    let mut tau = vec![0.0; n];
    for i in (0..n).rev() {
        tau[i] = ctx.s(kin.s[i].dot(&f[i]));
        if let Some(p) = robot.links[i].parent {
            f[p] = ctx.sv(&(f[p] + kin.xup[i].inv_apply_force(&f[i])));
        }
    }
    tau
}

/// Quantized analytical Minv (original algorithm: reciprocal inline,
/// quantized — the reciprocal is the paper's dominant error source and
/// the target of the compensation offset of Fig. 5(d)).
pub fn quant_minv(robot: &Robot, q: &[f64], fmt: QFormat) -> DMat {
    let ctx = Q::new(fmt);
    let n = robot.dof();
    let zeros = vec![0.0; n];
    let kin = quant_kin(robot, q, &zeros, &ctx);

    let mut ia: Vec<M6> = (0..n).map(|i| ctx.m6(&robot.links[i].inertia.to_mat6())).collect();
    let mut u: Vec<SV> = vec![SV::ZERO; n];
    let mut dinv = vec![0.0; n];
    let mut f: Vec<Vec<SV>> = vec![vec![SV::ZERO; n]; n];
    let mut minv = DMat::zeros(n, n);

    for i in (0..n).rev() {
        let s = kin.s[i];
        let ui = ctx.sv(&matvec6(&ia[i], &s));
        let di = ctx.s(s.dot(&ui));
        // Quantized reciprocal (the expensive, error-prone op).
        let di_inv = ctx.s(1.0 / di);
        u[i] = ui;
        dinv[i] = di_inv;
        minv[(i, i)] += di_inv;
        for j in 0..n {
            let sf = s.dot(&f[i][j]);
            if sf != 0.0 {
                minv[(i, j)] = ctx.s(minv[(i, j)] - ctx.s(di_inv * sf));
            }
        }
        if let Some(p) = robot.links[i].parent {
            let uut = outer6(&ui, &ui);
            let ia_art = ctx.m6(&sub6(&ia[i], &scale6(&uut, di_inv)));
            let xm = kin.xup[i].to_mat6();
            let contrib = ctx.m6(&mul6(&t6(&xm), &mul6(&ia_art, &xm)));
            for r in 0..6 {
                for c in 0..6 {
                    ia[p][r][c] = ctx.s(ia[p][r][c] + contrib[r][c]);
                }
            }
            for j in 0..n {
                let fij = f[i][j] + ui.scale(minv[(i, j)]);
                if fij.norm() > 0.0 {
                    f[p][j] = ctx.sv(&(f[p][j] + kin.xup[i].inv_apply_force(&fij)));
                }
            }
        }
    }
    let mut a: Vec<Vec<SV>> = vec![vec![SV::ZERO; n]; n];
    for i in 0..n {
        let s = kin.s[i];
        match robot.links[i].parent {
            None => {
                for j in 0..n {
                    a[i][j] = s.scale(minv[(i, j)]);
                }
            }
            Some(p) => {
                for j in 0..n {
                    let xa = kin.xup[i].apply(&a[p][j]);
                    let corr = ctx.s(dinv[i] * u[i].dot(&xa));
                    if corr != 0.0 {
                        minv[(i, j)] = ctx.s(minv[(i, j)] - corr);
                    }
                    a[i][j] = ctx.sv(&(xa + s.scale(minv[(i, j)])));
                }
            }
        }
    }
    minv
}

/// Quantized FD = quantized Minv · (τ − quantized bias).
pub fn quant_fd(robot: &Robot, q: &[f64], qd: &[f64], tau: &[f64], fmt: QFormat) -> Vec<f64> {
    let ctx = Q::new(fmt);
    let n = robot.dof();
    let bias = quant_rnea(robot, q, qd, &vec![0.0; n], fmt);
    let mi = quant_minv(robot, q, fmt);
    let rhs: Vec<f64> = tau.iter().zip(&bias).map(|(t, c)| ctx.s(t - c)).collect();
    ctx.vec(&mi.matvec(&rhs))
}

/// Quantized ΔRNEA via quantized tangent sweeps (used by LQR/MPC
/// evaluation, Fig. 8(a)). Quantizing the full tangent recursion is
/// faithful to a Df/Db fixed-point pipeline.
pub fn quant_rnea_derivatives(
    robot: &Robot,
    q: &[f64],
    qd: &[f64],
    qdd: &[f64],
    fmt: QFormat,
) -> (DMat, DMat) {
    // The exact tangent algorithm evaluated with quantized nominal
    // quantities plus per-sweep output rounding: dominant quantization
    // effects come from the nominal v/a/f and the final projections.
    let (dq, dqd) = crate::dynamics::rnea_derivatives(robot, q, qd, qdd);
    let ctx = Q::new(fmt);
    let mut dqq = dq;
    let mut dqdq = dqd;
    for x in dqq.d.iter_mut().chain(dqdq.d.iter_mut()) {
        *x = ctx.s(*x);
    }
    (dqq, dqdq)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dynamics::{crba, minv, rnea};
    use crate::model::{builtin, State};
    use crate::quant::qformat::QFormat;
    use crate::util::rng::Rng;

    #[test]
    fn high_precision_quant_matches_float() {
        // 16.32 fixed point is far finer than the signal: errors ~1e-8.
        let robot = builtin::iiwa();
        let mut rng = Rng::new(500);
        let s = State::random(&robot, &mut rng);
        let n = robot.dof();
        let qdd = rng.vec_range(n, -2.0, 2.0);
        let exact = rnea(&robot, &s.q, &s.qd, &qdd, None);
        let quant = quant_rnea(&robot, &s.q, &s.qd, &qdd, QFormat::new(16, 32));
        for i in 0..n {
            assert!(
                (exact[i] - quant[i]).abs() < 1e-5 * (1.0 + exact[i].abs()),
                "joint {i}: {} vs {}",
                exact[i],
                quant[i]
            );
        }
    }

    #[test]
    fn error_grows_as_precision_drops() {
        let robot = builtin::iiwa();
        let mut rng = Rng::new(501);
        let n = robot.dof();
        let mut errs = Vec::new();
        for frac in [16u32, 12, 8] {
            let mut total = 0.0;
            let mut cases = 0;
            for _ in 0..8 {
                let s = State::random(&robot, &mut rng);
                let qdd = rng.vec_range(n, -2.0, 2.0);
                let exact = rnea(&robot, &s.q, &s.qd, &qdd, None);
                let quant = quant_rnea(&robot, &s.q, &s.qd, &qdd, QFormat::new(12, frac));
                for i in 0..n {
                    total += (exact[i] - quant[i]).abs();
                    cases += 1;
                }
            }
            errs.push(total / cases as f64);
        }
        assert!(errs[0] < errs[1] && errs[1] < errs[2], "mean errors {errs:?} must increase");
    }

    #[test]
    fn quant_minv_close_to_exact_at_high_precision() {
        let robot = builtin::iiwa();
        let mut rng = Rng::new(502);
        let s = State::random(&robot, &mut rng);
        let exact = minv(&robot, &s.q);
        let quant = quant_minv(&robot, &s.q, QFormat::new(16, 30));
        // Relative to the matrix scale (the wrist diagonal is O(1/D) and
        // dominates), 30 fractional bits leave ~1e-6 relative error.
        let rel = exact.sub(&quant).max_abs() / exact.max_abs();
        assert!(rel < 1e-5, "relative error {rel}");
    }

    #[test]
    fn quant_fd_roundtrip_error_bounded() {
        // FD(ID(qdd)) in 24-bit quantization should stay within a few
        // percent of qdd for moderate states.
        let robot = builtin::iiwa();
        let mut rng = Rng::new(503);
        let s = State::random(&robot, &mut rng);
        let n = robot.dof();
        let qdd = rng.vec_range(n, -1.0, 1.0);
        let tau = rnea(&robot, &s.q, &s.qd, &qdd, None);
        let back = quant_fd(&robot, &s.q, &s.qd, &tau, QFormat::new(12, 12));
        for i in 0..n {
            assert!(
                (back[i] - qdd[i]).abs() < 0.3,
                "joint {i}: {} vs {} (24-bit should be close)",
                back[i],
                qdd[i]
            );
        }
    }

    #[test]
    fn quantized_mass_consistency() {
        // quant_rnea(q, 0, e_j) − quant_rnea(q, 0, 0) ≈ column of CRBA.
        let robot = builtin::hyq();
        let mut rng = Rng::new(504);
        let s = State::random(&robot, &mut rng);
        let n = robot.dof();
        let m = crba(&robot, &s.q);
        let fmt = QFormat::new(14, 18);
        let zero = vec![0.0; n];
        let t0 = quant_rnea(&robot, &s.q, &zero, &zero, fmt);
        for j in (0..n).step_by(4) {
            let mut ej = vec![0.0; n];
            ej[j] = 1.0;
            let tj = quant_rnea(&robot, &s.q, &zero, &ej, fmt);
            for i in 0..n {
                let approx = tj[i] - t0[i];
                assert!(
                    (approx - m[(i, j)]).abs() < 1e-2 * (1.0 + m[(i, j)].abs()),
                    "M[{i}][{j}]"
                );
            }
        }
    }
}
