//! Quantized RBD functions: RNEA / Minv / FD evaluated in emulated fixed
//! point. Constants (transforms, inertias), inputs, and every
//! intermediate spatial quantity are rounded to the target Q-format after
//! each operation group — mirroring what the fixed-point datapath
//! computes and therefore how errors propagate (paper §III-C, Fig. 5).
//!
//! Two entry styles exist for each function:
//!
//! * allocating (`quant_rnea`, `quant_minv`, `quant_fd`) — convenient
//!   one-shot calls used by the analyzer and the bit-width search;
//! * workspace (`QuantScratch::{rnea_into, minv_into, fd_into}`) — the
//!   serving hot path. A [`QuantScratch`] is the quantized counterpart of
//!   [`crate::dynamics::DynWorkspace`]: every buffer any quantized kernel
//!   needs (the kinematic cache, the per-column Minv propagation state,
//!   staging for quantized inputs) is allocated once per (robot DOF,
//!   worker thread) and overwritten per task, so the quantized native
//!   backend runs allocation-free exactly like the f64 one.
//!
//! The allocating functions are thin wrappers over a fresh scratch, so
//! both styles are numerically identical bit for bit. The fused
//! [`QuantScratch::fd_into`] shares **one** quantized kinematics pass
//! between the RNEA bias sweep and the Minv sweep (which reads only the
//! position entries), like its f64 twin
//! [`crate::dynamics::DynWorkspace::fd_into`].
//!
//! This module is the *rounded-f64* lane: faithful error behaviour at
//! any format ≤ 53 bits, f64 datapath underneath. The true-integer
//! `i64` lane — same algorithms over flat `[i64; 36]` blocks, constants
//! scaled once on ingest — lives in [`super::qint`] and is the faster
//! choice for the paper's ≤ 26-bit DSP formats.

use super::qformat::QFormat;
use crate::dynamics::kinematics::Kin;
use crate::model::Robot;
use crate::spatial::mat6::{matvec6, outer6, scale6, sub6, xtax, M6};
use crate::spatial::{DMat, SV, V3};

/// Quantization context: rounds scalars / spatial vectors / matrices.
#[derive(Debug, Clone, Copy)]
pub struct Q {
    pub fmt: QFormat,
}

impl Q {
    pub fn new(fmt: QFormat) -> Q {
        Q { fmt }
    }

    pub fn s(&self, x: f64) -> f64 {
        self.fmt.q(x)
    }

    pub fn sv(&self, v: &SV) -> SV {
        SV::new(
            V3::new(self.s(v.ang.x()), self.s(v.ang.y()), self.s(v.ang.z())),
            V3::new(self.s(v.lin.x()), self.s(v.lin.y()), self.s(v.lin.z())),
        )
    }

    pub fn m6(&self, m: &M6) -> M6 {
        let mut out = *m;
        for x in out.iter_mut() {
            *x = self.s(*x);
        }
        out
    }

    pub fn vec(&self, xs: &[f64]) -> Vec<f64> {
        xs.iter().map(|&x| self.s(x)).collect()
    }
}

/// Recompute `kin` in place for an **already quantized** state
/// (`qq`, `qdq`): joint transforms with quantized entries (the ᵢX_λ
/// matrices of §II-A as stored in BRAM/LUTs) and link velocities
/// re-propagated in quantized arithmetic. Allocation-free counterpart of
/// [`quant_kin`].
pub fn quant_kin_into(robot: &Robot, qq: &[f64], qdq: &[f64], ctx: &Q, kin: &mut Kin) {
    let n = robot.dof();
    kin.recompute(robot, qq, qdq);
    for i in 0..n {
        for r in 0..3 {
            for c in 0..3 {
                kin.xup[i].e.0[r][c] = ctx.s(kin.xup[i].e.0[r][c]);
                kin.xj[i].e.0[r][c] = ctx.s(kin.xj[i].e.0[r][c]);
            }
            kin.xup[i].r.0[r] = ctx.s(kin.xup[i].r.0[r]);
            kin.xj[i].r.0[r] = ctx.s(kin.xj[i].r.0[r]);
        }
    }
    for i in 0..n {
        let s = kin.s[i];
        let vj = s.scale(qdq[i]);
        kin.v[i] = match robot.links[i].parent {
            Some(p) => {
                let vp = kin.v[p];
                ctx.sv(&(kin.xup[i].apply(&vp) + vj))
            }
            None => ctx.sv(&vj),
        };
    }
}

/// Quantized kinematics: joint transforms with quantized entries.
/// Returns the same Kin shape the exact algorithms use; velocities are
/// quantized per step. Allocating wrapper over [`quant_kin_into`].
pub fn quant_kin(robot: &Robot, q: &[f64], qd: &[f64], ctx: &Q) -> Kin {
    let mut kin = Kin::empty(robot.dof());
    quant_kin_into(robot, &ctx.vec(q), &ctx.vec(qd), ctx, &mut kin);
    kin
}

/// Preallocated buffers for the quantized kernels — the fixed-point
/// counterpart of [`crate::dynamics::DynWorkspace`]. One scratch serves
/// one robot DOF; `new` sizes every buffer so `rnea_into` / `minv_into` /
/// `fd_into` perform zero heap allocation per task.
#[derive(Debug, Clone)]
pub struct QuantScratch {
    n: usize,
    /// Quantized kinematic cache, recomputed in place per task.
    kin: Kin,
    // Quantized-input staging.
    qq: Vec<f64>,
    qdq: Vec<f64>,
    uq: Vec<f64>,
    zero: Vec<f64>,
    // RNEA sweeps: link accelerations and forces.
    a: Vec<SV>,
    f: Vec<SV>,
    // Minv articulated sweep.
    ia: Vec<M6>,
    u: Vec<SV>,
    dinv: Vec<f64>,
    // Minv per-(link, column) force / acceleration propagation.
    fcol: Vec<Vec<SV>>,
    acol: Vec<Vec<SV>>,
    // FD composition byproducts.
    bias: Vec<f64>,
    rhs: Vec<f64>,
    mi: DMat,
}

impl QuantScratch {
    /// Allocate every buffer for an `n`-DOF robot.
    pub fn new(n: usize) -> QuantScratch {
        QuantScratch {
            n,
            kin: Kin::empty(n),
            qq: vec![0.0; n],
            qdq: vec![0.0; n],
            uq: vec![0.0; n],
            zero: vec![0.0; n],
            a: vec![SV::ZERO; n],
            f: vec![SV::ZERO; n],
            ia: vec![[0.0; 36]; n],
            u: vec![SV::ZERO; n],
            dinv: vec![0.0; n],
            fcol: vec![vec![SV::ZERO; n]; n],
            acol: vec![vec![SV::ZERO; n]; n],
            bias: vec![0.0; n],
            rhs: vec![0.0; n],
            mi: DMat::zeros(n, n),
        }
    }

    /// DOF the scratch was sized for.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Forward + backward RNEA sweeps over the scratch's **current**
    /// quantized kinematic cache. `use_qdd` adds the S·q̈ term (reading
    /// `self.uq`); without it this is the bias pass — bitwise identical
    /// to running with an explicit zero q̈, since adding the zero motion
    /// vector never changes a sum's bits.
    fn rnea_sweeps(&mut self, robot: &Robot, ctx: &Q, use_qdd: bool, tau: &mut [f64]) {
        let n = self.n;
        let a0 = SV::new(V3::ZERO, -robot.gravity);
        for i in 0..n {
            let link = &robot.links[i];
            let s = self.kin.s[i];
            let vi = self.kin.v[i];
            let ap = match link.parent {
                Some(p) => self.a[p],
                None => a0,
            };
            let ai = if use_qdd {
                ctx.sv(
                    &(self.kin.xup[i].apply(&ap)
                        + s.scale(self.uq[i])
                        + vi.crm(&s.scale(self.kin.qd[i]))),
                )
            } else {
                ctx.sv(&(self.kin.xup[i].apply(&ap) + vi.crm(&s.scale(self.kin.qd[i]))))
            };
            // Inertia constants quantized once (as stored in BRAM/LUTs).
            let iq = ctx.m6(&link.inertia.to_mat6());
            let fi = ctx.sv(&(matvec6(&iq, &ai) + vi.crf(&matvec6(&iq, &vi))));
            self.a[i] = ai;
            self.f[i] = fi;
        }
        for i in (0..n).rev() {
            tau[i] = ctx.s(self.kin.s[i].dot(&self.f[i]));
            if let Some(p) = robot.links[i].parent {
                self.f[p] = ctx.sv(&(self.f[p] + self.kin.xup[i].inv_apply_force(&self.f[i])));
            }
        }
    }

    /// Quantized RNEA (ID), written into `tau`. Intermediate v/a/f are
    /// quantized per joint step; see [`quant_rnea`].
    pub fn rnea_into(
        &mut self,
        robot: &Robot,
        q: &[f64],
        qd: &[f64],
        qdd: &[f64],
        fmt: QFormat,
        tau: &mut [f64],
    ) {
        let ctx = Q::new(fmt);
        let n = self.n;
        assert_eq!(robot.dof(), n, "scratch sized for a different robot");
        assert_eq!(tau.len(), n);
        for i in 0..n {
            self.qq[i] = ctx.s(q[i]);
            self.qdq[i] = ctx.s(qd[i]);
            self.uq[i] = ctx.s(qdd[i]);
        }
        quant_kin_into(robot, &self.qq, &self.qdq, &ctx, &mut self.kin);
        self.rnea_sweeps(robot, &ctx, true, tau);
    }

    /// Quantized analytical Minv (original algorithm: reciprocal inline,
    /// quantized), written into `out` (N×N); see [`quant_minv`].
    pub fn minv_into(&mut self, robot: &Robot, q: &[f64], fmt: QFormat, out: &mut DMat) {
        let ctx = Q::new(fmt);
        let n = self.n;
        assert_eq!(robot.dof(), n, "scratch sized for a different robot");
        for i in 0..n {
            self.qq[i] = ctx.s(q[i]);
        }
        quant_kin_into(robot, &self.qq, &self.zero, &ctx, &mut self.kin);
        self.minv_sweeps(robot, &ctx, out);
    }

    /// Backward + forward Minv sweeps over the scratch's **current**
    /// quantized kinematic cache. Reads only the position-dependent
    /// entries (`kin.xup`, `kin.s`), so a cache built *with* velocities
    /// (the fused FD path) yields bitwise the same matrix as the
    /// zero-velocity cache `minv_into` builds.
    fn minv_sweeps(&mut self, robot: &Robot, ctx: &Q, out: &mut DMat) {
        let n = self.n;
        assert_eq!(out.d.len(), n * n, "output sized for a different robot");
        for i in 0..n {
            self.ia[i] = ctx.m6(&robot.links[i].inertia.to_mat6());
        }
        for col in &mut self.fcol {
            col.fill(SV::ZERO);
        }
        for col in &mut self.acol {
            col.fill(SV::ZERO);
        }
        out.d.fill(0.0);

        for i in (0..n).rev() {
            let s = self.kin.s[i];
            let ui = ctx.sv(&matvec6(&self.ia[i], &s));
            let di = ctx.s(s.dot(&ui));
            // Quantized reciprocal (the expensive, error-prone op — the
            // paper's dominant error source, Fig. 5(d)).
            let di_inv = ctx.s(1.0 / di);
            self.u[i] = ui;
            self.dinv[i] = di_inv;
            out[(i, i)] += di_inv;
            for j in 0..n {
                let sf = s.dot(&self.fcol[i][j]);
                if sf != 0.0 {
                    out[(i, j)] = ctx.s(out[(i, j)] - ctx.s(di_inv * sf));
                }
            }
            if let Some(p) = robot.links[i].parent {
                let uut = outer6(&ui, &ui);
                let ia_art = ctx.m6(&sub6(&self.ia[i], &scale6(&uut, di_inv)));
                let contrib = ctx.m6(&xtax(&self.kin.xup[i].to_mat6(), &ia_art));
                for e in 0..36 {
                    self.ia[p][e] = ctx.s(self.ia[p][e] + contrib[e]);
                }
                for j in 0..n {
                    let fij = self.fcol[i][j] + ui.scale(out[(i, j)]);
                    if fij.norm() > 0.0 {
                        self.fcol[p][j] =
                            ctx.sv(&(self.fcol[p][j] + self.kin.xup[i].inv_apply_force(&fij)));
                    }
                }
            }
        }
        for i in 0..n {
            let s = self.kin.s[i];
            match robot.links[i].parent {
                None => {
                    for j in 0..n {
                        self.acol[i][j] = s.scale(out[(i, j)]);
                    }
                }
                Some(p) => {
                    for j in 0..n {
                        let xa = self.kin.xup[i].apply(&self.acol[p][j]);
                        let corr = ctx.s(self.dinv[i] * self.u[i].dot(&xa));
                        if corr != 0.0 {
                            out[(i, j)] = ctx.s(out[(i, j)] - corr);
                        }
                        self.acol[i][j] = ctx.sv(&(xa + s.scale(out[(i, j)])));
                    }
                }
            }
        }
    }

    /// Fused quantized FD = quantized Minv · (τ − quantized bias),
    /// written into `qdd`: **one** quantized kinematics pass feeds both
    /// the RNEA bias sweep and the Minv sweep (which reads only the
    /// position entries), mirroring [`crate::dynamics::DynWorkspace::fd_into`].
    /// Bitwise identical to composing `rnea_into(q̈=0)` + `minv_into` +
    /// the rounded matvec (the pre-fusion implementation; see the
    /// `fused_fd_matches_unfused_composition_bitwise` test). Leaves the
    /// bias in scratch and M⁻¹ in the internal matrix buffer; see
    /// [`quant_fd`].
    pub fn fd_into(
        &mut self,
        robot: &Robot,
        q: &[f64],
        qd: &[f64],
        tau: &[f64],
        fmt: QFormat,
        qdd: &mut [f64],
    ) {
        let ctx = Q::new(fmt);
        let n = self.n;
        assert_eq!(robot.dof(), n, "scratch sized for a different robot");
        assert_eq!(tau.len(), n);
        assert_eq!(qdd.len(), n);
        for i in 0..n {
            self.qq[i] = ctx.s(q[i]);
            self.qdq[i] = ctx.s(qd[i]);
        }
        // One shared quantized kinematics pass (the Minv sweep ignores
        // the velocity entries, so the q̇-bearing cache serves both).
        quant_kin_into(robot, &self.qq, &self.qdq, &ctx, &mut self.kin);
        // Temporarily take the buffers the sub-sweeps must not alias.
        let mut bias = std::mem::take(&mut self.bias);
        let mut mi = std::mem::replace(&mut self.mi, DMat::zeros(0, 0));
        self.rnea_sweeps(robot, &ctx, false, &mut bias);
        self.minv_sweeps(robot, &ctx, &mut mi);
        for i in 0..n {
            self.rhs[i] = ctx.s(tau[i] - bias[i]);
        }
        self.bias = bias;
        for i in 0..n {
            let mut acc = 0.0;
            for j in 0..n {
                acc += mi[(i, j)] * self.rhs[j];
            }
            qdd[i] = ctx.s(acc);
        }
        self.mi = mi;
    }

    /// Fused quantized multi-output dynamics: one quantized kinematics
    /// pass feeds the bias sweep, the Minv sweep, and the FD τ-fold,
    /// with flat egress `out = [q̈ (N) | M⁻¹ (N×N row-major) | C (N)]`
    /// (`N² + 2N` entries) — the quantized mirror of
    /// [`crate::dynamics::DynWorkspace::dyn_all_into`]. Each section is
    /// bitwise what the separate `fd_into` / `minv_into` /
    /// `rnea_into(q̈=0)` calls produce at the same inputs.
    pub fn dyn_all_into(
        &mut self,
        robot: &Robot,
        q: &[f64],
        qd: &[f64],
        tau: &[f64],
        fmt: QFormat,
        out: &mut [f64],
    ) {
        let ctx = Q::new(fmt);
        let n = self.n;
        assert_eq!(robot.dof(), n, "scratch sized for a different robot");
        assert_eq!(tau.len(), n);
        assert_eq!(out.len(), n * n + 2 * n, "dyn_all egress is qdd|minv|bias");
        for i in 0..n {
            self.qq[i] = ctx.s(q[i]);
            self.qdq[i] = ctx.s(qd[i]);
        }
        quant_kin_into(robot, &self.qq, &self.qdq, &ctx, &mut self.kin);
        let mut bias = std::mem::take(&mut self.bias);
        let mut mi = std::mem::replace(&mut self.mi, DMat::zeros(0, 0));
        self.rnea_sweeps(robot, &ctx, false, &mut bias);
        self.minv_sweeps(robot, &ctx, &mut mi);
        self.bias = bias;
        self.mi = mi;
        self.dyn_all_finish(&ctx, tau, out);
    }

    /// [`dyn_all_into`](Self::dyn_all_into) with a cross-request memo of
    /// the sweep outputs `(M⁻¹, C)`. The key is the **post-quantization**
    /// joint words (so any raw state that quantizes onto a cached
    /// operating point hits) plus a packed format word and the robot
    /// fingerprint; a hit skips the kinematics/bias/Minv sweeps and
    /// re-runs only the rounded τ-fold, bitwise identical to a cold miss.
    #[allow(clippy::too_many_arguments)]
    pub fn dyn_all_memo_into(
        &mut self,
        robot: &Robot,
        robot_fp: u64,
        q: &[f64],
        qd: &[f64],
        tau: &[f64],
        fmt: QFormat,
        memo: &mut crate::dynamics::memo::FloatMemo,
        out: &mut [f64],
    ) {
        let ctx = Q::new(fmt);
        let n = self.n;
        assert_eq!(robot.dof(), n, "scratch sized for a different robot");
        assert_eq!(tau.len(), n);
        assert_eq!(out.len(), n * n + 2 * n, "dyn_all egress is qdd|minv|bias");
        for i in 0..n {
            self.qq[i] = ctx.s(q[i]);
            self.qdq[i] = ctx.s(qd[i]);
        }
        memo.begin();
        memo.stage_word(((fmt.int_bits as u64) << 32) | fmt.frac_bits as u64);
        memo.stage_f64(&self.qq);
        memo.stage_f64(&self.qdq);
        if memo.lookup(robot_fp) {
            let (mi, bias) = memo.front();
            self.mi.d.copy_from_slice(mi);
            self.bias.copy_from_slice(bias);
        } else {
            quant_kin_into(robot, &self.qq, &self.qdq, &ctx, &mut self.kin);
            let mut bias = std::mem::take(&mut self.bias);
            let mut mi = std::mem::replace(&mut self.mi, DMat::zeros(0, 0));
            self.rnea_sweeps(robot, &ctx, false, &mut bias);
            self.minv_sweeps(robot, &ctx, &mut mi);
            self.bias = bias;
            self.mi = mi;
            memo.insert(robot_fp, (self.mi.d.clone(), self.bias.clone()));
        }
        self.dyn_all_finish(&ctx, tau, out);
    }

    /// Shared tail of the `dyn_all` paths: rounded τ − C fold, rounded
    /// matvec, flat egress. Reads the (restored or replayed) `self.bias`
    /// / `self.mi` byproducts, so memo hits and cold computes take
    /// literally the same instructions from here on.
    fn dyn_all_finish(&mut self, ctx: &Q, tau: &[f64], out: &mut [f64]) {
        let n = self.n;
        for i in 0..n {
            self.rhs[i] = ctx.s(tau[i] - self.bias[i]);
        }
        let (qdd, rest) = out.split_at_mut(n);
        for i in 0..n {
            let mut acc = 0.0;
            for j in 0..n {
                acc += self.mi[(i, j)] * self.rhs[j];
            }
            qdd[i] = ctx.s(acc);
        }
        let (mi_out, bias_out) = rest.split_at_mut(n * n);
        mi_out.copy_from_slice(&self.mi.d);
        bias_out.copy_from_slice(&self.bias);
    }
}

/// Quantized RNEA (ID). Intermediate v/a/f quantized per joint step.
/// Allocating wrapper over [`QuantScratch::rnea_into`].
pub fn quant_rnea(
    robot: &Robot,
    q: &[f64],
    qd: &[f64],
    qdd: &[f64],
    fmt: QFormat,
) -> Vec<f64> {
    let n = robot.dof();
    let mut ws = QuantScratch::new(n);
    let mut tau = vec![0.0; n];
    ws.rnea_into(robot, q, qd, qdd, fmt, &mut tau);
    tau
}

/// Quantized analytical Minv (original algorithm: reciprocal inline,
/// quantized — the reciprocal is the paper's dominant error source and
/// the target of the compensation offset of Fig. 5(d)). Allocating
/// wrapper over [`QuantScratch::minv_into`].
pub fn quant_minv(robot: &Robot, q: &[f64], fmt: QFormat) -> DMat {
    let n = robot.dof();
    let mut ws = QuantScratch::new(n);
    let mut out = DMat::zeros(n, n);
    ws.minv_into(robot, q, fmt, &mut out);
    out
}

/// Quantized FD = quantized Minv · (τ − quantized bias). Allocating
/// wrapper over [`QuantScratch::fd_into`].
pub fn quant_fd(robot: &Robot, q: &[f64], qd: &[f64], tau: &[f64], fmt: QFormat) -> Vec<f64> {
    let n = robot.dof();
    let mut ws = QuantScratch::new(n);
    let mut qdd = vec![0.0; n];
    ws.fd_into(robot, q, qd, tau, fmt, &mut qdd);
    qdd
}

/// Fused quantized multi-output dynamics, flat egress
/// `[q̈ | M⁻¹ | C]` (`N² + 2N` entries). Allocating wrapper over
/// [`QuantScratch::dyn_all_into`].
pub fn quant_dyn_all(
    robot: &Robot,
    q: &[f64],
    qd: &[f64],
    tau: &[f64],
    fmt: QFormat,
) -> Vec<f64> {
    let n = robot.dof();
    let mut ws = QuantScratch::new(n);
    let mut out = vec![0.0; n * n + 2 * n];
    ws.dyn_all_into(robot, q, qd, tau, fmt, &mut out);
    out
}

/// Quantized ΔRNEA via quantized tangent sweeps (used by LQR/MPC
/// evaluation, Fig. 8(a)). Quantizing the full tangent recursion is
/// faithful to a Df/Db fixed-point pipeline.
pub fn quant_rnea_derivatives(
    robot: &Robot,
    q: &[f64],
    qd: &[f64],
    qdd: &[f64],
    fmt: QFormat,
) -> (DMat, DMat) {
    // The exact tangent algorithm evaluated with quantized nominal
    // quantities plus per-sweep output rounding: dominant quantization
    // effects come from the nominal v/a/f and the final projections.
    let (dq, dqd) = crate::dynamics::rnea_derivatives(robot, q, qd, qdd);
    let ctx = Q::new(fmt);
    let mut dqq = dq;
    let mut dqdq = dqd;
    for x in dqq.d.iter_mut().chain(dqdq.d.iter_mut()) {
        *x = ctx.s(*x);
    }
    (dqq, dqdq)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dynamics::{crba, minv, rnea};
    use crate::model::{builtin, State};
    use crate::quant::qformat::QFormat;
    use crate::util::rng::Rng;

    #[test]
    fn high_precision_quant_matches_float() {
        // 16.32 fixed point is far finer than the signal: errors ~1e-8.
        let robot = builtin::iiwa();
        let mut rng = Rng::new(500);
        let s = State::random(&robot, &mut rng);
        let n = robot.dof();
        let qdd = rng.vec_range(n, -2.0, 2.0);
        let exact = rnea(&robot, &s.q, &s.qd, &qdd, None);
        let quant = quant_rnea(&robot, &s.q, &s.qd, &qdd, QFormat::new(16, 32));
        for i in 0..n {
            assert!(
                (exact[i] - quant[i]).abs() < 1e-5 * (1.0 + exact[i].abs()),
                "joint {i}: {} vs {}",
                exact[i],
                quant[i]
            );
        }
    }

    #[test]
    fn error_grows_as_precision_drops() {
        let robot = builtin::iiwa();
        let mut rng = Rng::new(501);
        let n = robot.dof();
        let mut errs = Vec::new();
        for frac in [16u32, 12, 8] {
            let mut total = 0.0;
            let mut cases = 0;
            for _ in 0..8 {
                let s = State::random(&robot, &mut rng);
                let qdd = rng.vec_range(n, -2.0, 2.0);
                let exact = rnea(&robot, &s.q, &s.qd, &qdd, None);
                let quant = quant_rnea(&robot, &s.q, &s.qd, &qdd, QFormat::new(12, frac));
                for i in 0..n {
                    total += (exact[i] - quant[i]).abs();
                    cases += 1;
                }
            }
            errs.push(total / cases as f64);
        }
        assert!(errs[0] < errs[1] && errs[1] < errs[2], "mean errors {errs:?} must increase");
    }

    #[test]
    fn quant_minv_close_to_exact_at_high_precision() {
        let robot = builtin::iiwa();
        let mut rng = Rng::new(502);
        let s = State::random(&robot, &mut rng);
        let exact = minv(&robot, &s.q);
        let quant = quant_minv(&robot, &s.q, QFormat::new(16, 30));
        // Relative to the matrix scale (the wrist diagonal is O(1/D) and
        // dominates), 30 fractional bits leave ~1e-6 relative error.
        let rel = exact.sub(&quant).max_abs() / exact.max_abs();
        assert!(rel < 1e-5, "relative error {rel}");
    }

    #[test]
    fn quant_fd_roundtrip_error_bounded() {
        // FD(ID(qdd)) in 24-bit quantization should stay within a few
        // percent of qdd for moderate states.
        let robot = builtin::iiwa();
        let mut rng = Rng::new(503);
        let s = State::random(&robot, &mut rng);
        let n = robot.dof();
        let qdd = rng.vec_range(n, -1.0, 1.0);
        let tau = rnea(&robot, &s.q, &s.qd, &qdd, None);
        let back = quant_fd(&robot, &s.q, &s.qd, &tau, QFormat::new(12, 12));
        for i in 0..n {
            assert!(
                (back[i] - qdd[i]).abs() < 0.3,
                "joint {i}: {} vs {} (24-bit should be close)",
                back[i],
                qdd[i]
            );
        }
    }

    #[test]
    fn quantized_mass_consistency() {
        // quant_rnea(q, 0, e_j) − quant_rnea(q, 0, 0) ≈ column of CRBA.
        let robot = builtin::hyq();
        let mut rng = Rng::new(504);
        let s = State::random(&robot, &mut rng);
        let n = robot.dof();
        let m = crba(&robot, &s.q);
        let fmt = QFormat::new(14, 18);
        let zero = vec![0.0; n];
        let t0 = quant_rnea(&robot, &s.q, &zero, &zero, fmt);
        for j in (0..n).step_by(4) {
            let mut ej = vec![0.0; n];
            ej[j] = 1.0;
            let tj = quant_rnea(&robot, &s.q, &zero, &ej, fmt);
            for i in 0..n {
                let approx = tj[i] - t0[i];
                assert!(
                    (approx - m[(i, j)]).abs() < 1e-2 * (1.0 + m[(i, j)].abs()),
                    "M[{i}][{j}]"
                );
            }
        }
    }

    /// The fused `fd_into` (one shared quantized kinematics pass) must be
    /// bitwise identical to the pre-fusion composition it replaced:
    /// quantized bias (RNEA at q̈ = 0), quantized Minv, rounded τ − C,
    /// rounded matvec.
    #[test]
    fn fused_fd_matches_unfused_composition_bitwise() {
        for robot in [builtin::iiwa(), builtin::hyq()] {
            let n = robot.dof();
            let fmt = QFormat::new(12, 14);
            let ctx = Q::new(fmt);
            let mut rng = Rng::new(506);
            for _ in 0..3 {
                let s = State::random(&robot, &mut rng);
                let tau = rng.vec_range(n, -8.0, 8.0);
                let zero = vec![0.0; n];
                let bias = quant_rnea(&robot, &s.q, &s.qd, &zero, fmt);
                let mi = quant_minv(&robot, &s.q, fmt);
                let rhs: Vec<f64> = (0..n).map(|i| ctx.s(tau[i] - bias[i])).collect();
                let want: Vec<f64> = (0..n)
                    .map(|i| {
                        let mut acc = 0.0;
                        for j in 0..n {
                            acc += mi[(i, j)] * rhs[j];
                        }
                        ctx.s(acc)
                    })
                    .collect();
                assert_eq!(quant_fd(&robot, &s.q, &s.qd, &tau, fmt), want);
            }
        }
    }

    /// The fused multi-output egress must be bitwise the three separate
    /// quantized routes: q̈ from `quant_fd`, M⁻¹ from `quant_minv`, C
    /// from `quant_rnea(q̈ = 0)`.
    #[test]
    fn dyn_all_sections_match_separate_quant_routes_bitwise() {
        for robot in [builtin::iiwa(), builtin::hyq()] {
            let n = robot.dof();
            let fmt = QFormat::new(12, 14);
            let mut rng = Rng::new(507);
            for _ in 0..3 {
                let s = State::random(&robot, &mut rng);
                let tau = rng.vec_range(n, -8.0, 8.0);
                let out = quant_dyn_all(&robot, &s.q, &s.qd, &tau, fmt);
                assert_eq!(&out[..n], &quant_fd(&robot, &s.q, &s.qd, &tau, fmt)[..]);
                assert_eq!(&out[n..n + n * n], &quant_minv(&robot, &s.q, fmt).d[..]);
                let zero = vec![0.0; n];
                assert_eq!(&out[n + n * n..], &quant_rnea(&robot, &s.q, &s.qd, &zero, fmt)[..]);
            }
        }
    }

    /// A memo hit must replay the cached sweeps bitwise — and because
    /// the key is the post-quantization words, a *different raw* state
    /// that quantizes onto the same operating point hits too.
    #[test]
    fn dyn_all_memo_hit_matches_cold_and_keys_on_quantized_words() {
        use crate::dynamics::memo::FloatMemo;
        let robot = builtin::iiwa();
        let fp = robot.fingerprint();
        let n = robot.dof();
        let fmt = QFormat::new(12, 12);
        let mut ws = QuantScratch::new(n);
        let mut memo = FloatMemo::new(8);
        let mut rng = Rng::new(508);
        let s = State::random(&robot, &mut rng);
        let tau = rng.vec_range(n, -8.0, 8.0);
        let per = n * n + 2 * n;

        let mut cold = vec![0.0; per];
        ws.dyn_all_memo_into(&robot, fp, &s.q, &s.qd, &tau, fmt, &mut memo, &mut cold);
        assert_eq!(cold, quant_dyn_all(&robot, &s.q, &s.qd, &tau, fmt));
        assert_eq!(memo.counters(), (0, 1));

        // Perturb q below half a quantum: same quantized words → hit,
        // bitwise the same answer.
        let ctx = Q::new(fmt);
        let mut q_near = s.q.clone();
        q_near[0] = ctx.s(s.q[0]) + 0.25 * fmt.step();
        assert_eq!(ctx.s(q_near[0]), ctx.s(s.q[0]), "perturbation must round away");
        let mut warm = vec![0.0; per];
        ws.dyn_all_memo_into(&robot, fp, &q_near, &s.qd, &tau, fmt, &mut memo, &mut warm);
        assert_eq!(memo.counters(), (1, 1));
        assert_eq!(warm, cold);

        // One full quantum is an adjacent operating point: miss, and its
        // own correct answer.
        let mut q_adj = s.q.clone();
        q_adj[0] += fmt.step();
        let mut other = vec![0.0; per];
        ws.dyn_all_memo_into(&robot, fp, &q_adj, &s.qd, &tau, fmt, &mut memo, &mut other);
        assert_eq!(memo.counters(), (1, 2));
        assert_eq!(other, quant_dyn_all(&robot, &q_adj, &s.qd, &tau, fmt));
        assert_ne!(other, cold, "adjacent quantized q must not alias");
    }

    /// Reusing one scratch across tasks (and interleaving the three
    /// kernels) must give bitwise the same answers as fresh scratches —
    /// no state may leak between calls.
    #[test]
    fn scratch_reuse_matches_fresh() {
        for robot in [builtin::iiwa(), builtin::hyq()] {
            let n = robot.dof();
            let fmt = QFormat::new(12, 14);
            let mut ws = QuantScratch::new(n);
            let mut rng = Rng::new(505);
            for _ in 0..3 {
                let s = State::random(&robot, &mut rng);
                let qdd = rng.vec_range(n, -2.0, 2.0);
                let tau = rng.vec_range(n, -8.0, 8.0);

                let mut tau_ws = vec![0.0; n];
                ws.rnea_into(&robot, &s.q, &s.qd, &qdd, fmt, &mut tau_ws);
                assert_eq!(tau_ws, quant_rnea(&robot, &s.q, &s.qd, &qdd, fmt));

                let mut mi_ws = DMat::zeros(n, n);
                ws.minv_into(&robot, &s.q, fmt, &mut mi_ws);
                assert_eq!(mi_ws.d, quant_minv(&robot, &s.q, fmt).d);

                let mut qdd_ws = vec![0.0; n];
                ws.fd_into(&robot, &s.q, &s.qd, &tau, fmt, &mut qdd_ws);
                assert_eq!(qdd_ws, quant_fd(&robot, &s.q, &s.qd, &tau, fmt));
            }
        }
    }
}
