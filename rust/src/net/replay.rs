//! Offline replay of a `--tee` capture.
//!
//! A tee log is a single JSONL file: a `hello` frame naming the serving
//! config (registry spec, batch, window), then every inbound request
//! line verbatim interleaved with every outbound response frame.
//! [`replay_log`] rebuilds the same registry, re-drives each request
//! sequentially through a fresh [`Coordinator`], and checks that the
//! replayed payloads are **bitwise identical** to the captured `chunk`
//! frames — the end-to-end proof that text framing, lazy parsing, and
//! the streaming sinks are all lossless.
//!
//! Two classes of capture are excluded from the bitwise comparison:
//!
//! * timing-dependent refusals (`rejected` / `shed` / `expired`) — a
//!   quiet replay machine admits what a loaded server refused, so these
//!   are counted, not compared (replay also strips deadlines);
//! * requests with no terminal frame (client disconnected mid-stream).
//!
//! Each request line is additionally parsed twice — lazily
//! ([`LazyReq::scan`], the path the live server used) and through the
//! full [`Json`](crate::util::json::Json) tree ([`Frame::parse`]) — and
//! the two must agree on every hot field and every payload value,
//! bit for bit. Request ids are namespaced per connection: the server
//! tags every teed line with its connection id (`{"conn":N,…}`, see
//! [`frame::conn_tag`]), and replay keys its bookkeeping by
//! `(connection, id)` — multi-client captures with overlapping ids
//! replay fine, as long as each connection's own ids are unique.
//! Untagged lines (pre-tagging captures, hand-written logs) fall back
//! to connection 0.

use super::frame::{self, Frame, NetReq};
use super::lazy::{self, LazyReq};
use crate::coordinator::{
    Coordinator, QosClass, ResponseSink, RobotRegistry, ServeError, SubmitOptions, TrajRequest,
};
use crate::runtime::ArtifactFn;
use crate::util::cli::Args;
use std::collections::{BTreeMap, BTreeSet};
use std::sync::mpsc::{channel, Sender};

/// Terminal state a request reached in the live capture.
enum Out {
    Done,
    Refused,
    Errored,
}

/// Everything the log recorded about one request id.
struct Live {
    chunks: Vec<f32>,
    outcome: Option<Out>,
}

/// Replay tallies; `is_clean` is the CI gate.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct ReplayReport {
    /// Request lines found in the log.
    pub requests: usize,
    /// Requests with a deterministic terminal outcome, re-driven.
    pub compared: usize,
    /// Re-driven requests whose outcome matched bitwise.
    pub matched: usize,
    /// Live refusals (rejected/shed/expired) — timing-dependent, skipped.
    pub timing_skipped: usize,
    /// Requests with no terminal frame in the log, skipped.
    pub incomplete: usize,
    /// Request lines where lazy and full parsing were cross-checked.
    pub lazy_checked: usize,
    /// Cross-checks where the lazy scanner disagreed with the full parser.
    pub lazy_mismatches: usize,
    /// Lines neither parser could route (answered `err` live), skipped.
    pub malformed: usize,
}

impl ReplayReport {
    /// True when every comparable request replayed bitwise-identical
    /// and lazy/full parsing agreed on every checked line.
    pub fn is_clean(&self) -> bool {
        self.requests > 0 && self.matched == self.compared && self.lazy_mismatches == 0
    }
}

/// Sink that concatenates chunk payloads in emission order — exactly
/// the byte stream a [`SocketSink`](super::server) would have framed.
struct CollectSink {
    data: Vec<f32>,
    tx: Sender<(Vec<f32>, Result<(), ServeError>)>,
}

impl ResponseSink for CollectSink {
    fn chunk(&mut self, data: &[f32]) {
        self.data.extend_from_slice(data);
    }

    fn done(&mut self, result: Result<(), ServeError>) {
        let _ = self.tx.send((std::mem::take(&mut self.data), result));
    }
}

/// Re-drive one lazily parsed request (deadline stripped) and block for
/// its payload. Any failure — missing field, unknown route, refusal,
/// engine error — collapses to `Err`, mirroring a live `err` frame.
fn redrive(coord: &Coordinator, r: &LazyReq<'_>) -> Result<Vec<f32>, String> {
    let robot = r.robot.ok_or("req has no robot")?;
    let route = r.route.ok_or("req has no route")?;
    let mut opts = SubmitOptions::default();
    if let Some(c) = r.class {
        opts.class = Some(QosClass::parse(c).ok_or_else(|| format!("unknown class '{c}'"))?);
    }
    let (tx, rx) = channel();
    let sink = Box::new(CollectSink { data: Vec::new(), tx });
    if route == "traj" {
        let q0 = lazy::parse_f32_array(r.q0.ok_or("traj req has no q0")?)?;
        let qd0 = lazy::parse_f32_array(r.qd0.ok_or("traj req has no qd0")?)?;
        let tau = lazy::parse_f32_array(r.tau.ok_or("traj req has no tau")?)?;
        let dt = r.dt.ok_or("traj req has no dt")?;
        coord.submit_traj_sink(robot, TrajRequest { q0, qd0, tau, dt }, opts, sink);
    } else {
        let f = ArtifactFn::parse(route).ok_or_else(|| format!("unknown route '{route}'"))?;
        let ops = lazy::parse_f32_matrix(r.ops.ok_or("step req has no ops")?)?;
        coord.submit_to_sink(robot, f, ops, opts, sink);
    }
    let (data, result) = rx.recv().map_err(|_| "sink dropped without done".to_string())?;
    result.map_err(|e| e.to_string())?;
    Ok(data)
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Field-by-field agreement between the lazy scan and the full parse of
/// the same line (payload spans decoded and compared bitwise).
fn agree(l: &LazyReq<'_>, full: &NetReq) -> Result<(), String> {
    if l.id != full.id {
        return Err("id differs".into());
    }
    if l.robot.unwrap_or("") != full.robot {
        return Err("robot differs".into());
    }
    if l.route.unwrap_or("") != full.route {
        return Err("route differs".into());
    }
    if l.class != full.class.as_deref() {
        return Err("class differs".into());
    }
    if l.deadline_us != full.deadline_us {
        return Err("deadline_us differs".into());
    }
    if l.dt.map(f64::to_bits) != full.dt.map(f64::to_bits) {
        return Err("dt differs".into());
    }
    match (l.ops, &full.ops) {
        (Some(span), Some(mat)) => {
            let lm = lazy::parse_f32_matrix(span).map_err(|e| format!("ops: {e}"))?;
            if lm.len() != mat.len() || lm.iter().zip(mat).any(|(a, b)| bits(a) != bits(b)) {
                return Err("ops values differ".into());
            }
        }
        (None, None) => {}
        _ => return Err("ops presence differs".into()),
    }
    for (span, arr, name) in
        [(l.q0, &full.q0, "q0"), (l.qd0, &full.qd0, "qd0"), (l.tau, &full.tau, "tau")]
    {
        match (span, arr) {
            (Some(sp), Some(a)) => {
                let lv = lazy::parse_f32_array(sp).map_err(|e| format!("{name}: {e}"))?;
                if bits(&lv) != bits(a) {
                    return Err(format!("{name} values differ"));
                }
            }
            (None, None) => {}
            _ => return Err(format!("{name} presence differs")),
        }
    }
    Ok(())
}

/// Parse, re-drive, and verify one capture file. Errors are structural
/// (unreadable file, bad hello, duplicate ids); per-request divergences
/// land in the report instead.
pub fn replay_log(path: &str) -> Result<ReplayReport, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
    let mut lines = text.lines().filter(|l| !l.trim().is_empty());
    let hello = lines.next().ok_or("log is empty")?;
    let (spec, batch, window_us) = match Frame::parse(hello)? {
        Frame::Hello { spec, batch, window_us } => (spec, batch, window_us),
        other => return Err(format!("log does not start with a hello frame: {other:?}")),
    };
    let registry = RobotRegistry::from_cli_spec(&spec, batch)?;

    let mut reqs: Vec<(u64, &str)> = Vec::new();
    let mut seen: BTreeSet<(u64, u64)> = BTreeSet::new();
    let mut live: BTreeMap<(u64, u64), Live> = BTreeMap::new();
    let mut report = ReplayReport::default();
    for line in lines {
        // Connection tag injected by the tee; untagged lines → conn 0.
        let conn = frame::conn_tag(line).unwrap_or(0);
        if let Ok(l) = LazyReq::scan(line) {
            if l.typ == "req" {
                if !seen.insert((conn, l.id)) {
                    return Err(format!(
                        "duplicate request id {} on connection {conn} — captures must be \
                         id-unique per connection",
                        l.id
                    ));
                }
                reqs.push((conn, line));
                continue;
            }
        }
        match Frame::parse(line) {
            Ok(f) => {
                let Some(id) = f.id() else { continue };
                let entry = live
                    .entry((conn, id))
                    .or_insert_with(|| Live { chunks: Vec::new(), outcome: None });
                match f {
                    Frame::Chunk { data, .. } => entry.chunks.extend_from_slice(&data),
                    Frame::Done { .. } => entry.outcome = Some(Out::Done),
                    Frame::Rejected { .. } | Frame::Shed { .. } | Frame::Expired { .. } => {
                        entry.outcome = Some(Out::Refused)
                    }
                    Frame::Err { .. } => entry.outcome = Some(Out::Errored),
                    _ => {}
                }
            }
            Err(_) => report.malformed += 1,
        }
    }

    report.requests = reqs.len();
    let coord = Coordinator::start_registry(&registry, window_us);
    for (conn, raw) in reqs {
        let l = LazyReq::scan(raw).expect("req lines were lazily scanned once already");
        if let Ok(Frame::Req(full)) = Frame::parse(raw) {
            report.lazy_checked += 1;
            if let Err(e) = agree(&l, &full) {
                eprintln!("replay: lazy/full parse disagree on id {}: {e}", l.id);
                report.lazy_mismatches += 1;
            }
        }
        match live.get(&(conn, l.id)) {
            None => report.incomplete += 1,
            Some(Live { outcome: None, .. }) => report.incomplete += 1,
            Some(Live { outcome: Some(Out::Refused), .. }) => report.timing_skipped += 1,
            Some(Live { outcome: Some(Out::Errored), .. }) => {
                report.compared += 1;
                match redrive(&coord, &l) {
                    Err(_) => report.matched += 1,
                    Ok(_) => eprintln!("replay: id {} errored live but replayed cleanly", l.id),
                }
            }
            Some(Live { outcome: Some(Out::Done), chunks }) => {
                report.compared += 1;
                match redrive(&coord, &l) {
                    Ok(data) if bits(&data) == bits(chunks) => report.matched += 1,
                    Ok(data) => eprintln!(
                        "replay: id {} payload diverged ({} replayed vs {} captured values)",
                        l.id,
                        data.len(),
                        chunks.len()
                    ),
                    Err(e) => eprintln!("replay: id {} failed to replay: {e}", l.id),
                }
            }
        }
    }
    coord.shutdown();
    Ok(report)
}

/// `draco replay LOG` — exit 0 iff the capture replays clean.
pub fn replay_cli(args: &Args) -> i32 {
    let Some(path) = args.positional.first() else {
        eprintln!("usage: draco replay LOG.jsonl");
        return 2;
    };
    match replay_log(path) {
        Ok(r) => {
            println!(
                "replay: {} requests — {}/{} replayed bitwise-identical, {} timing-dependent \
                 refusals skipped, {} incomplete, lazy/full parse agreed on {}/{} lines, \
                 {} malformed lines",
                r.requests,
                r.matched,
                r.compared,
                r.timing_skipped,
                r.incomplete,
                r.lazy_checked - r.lazy_mismatches,
                r.lazy_checked,
                r.malformed
            );
            if r.is_clean() {
                println!("replay: OK");
                0
            } else {
                eprintln!("replay: FAILED");
                1
            }
        }
        Err(e) => {
            eprintln!("replay: {e}");
            1
        }
    }
}
