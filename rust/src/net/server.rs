//! JSONL TCP front-end.
//!
//! One thread per accepted connection reads newline-delimited request
//! frames, routes them through the [`Coordinator`]'s sink submit paths
//! (so admission, QoS classes, deadlines, and circuit breakers apply
//! exactly as for in-process callers), and a [`SocketSink`] frames the
//! response event stream — `ack`, `chunk`…, `done`/refusal — back to
//! the client as the batcher produces it. Trajectory rows hit the wire
//! mid-horizon; nothing is buffered server-side beyond the bounded
//! egress queue.
//!
//! Connection lifecycle hardening:
//!
//! * **Bounded egress queues.** Every connection owns a dedicated
//!   writer thread fed by a [`EGRESS_QUEUE_LINES`]-deep queue. Batcher
//!   workers enqueue and move on; a reader too slow to drain its queue
//!   within a short grace window is disconnected instead of stalling
//!   jobs bound for other connections.
//! * **Prompt cancellation.** Peer EOF, a socket error, or an egress
//!   overflow latches the connection `dead` and shuts the socket down.
//!   Streaming sinks observe it via [`ResponseSink::alive`] (chunk
//!   *production* stops mid-horizon), and jobs still queued for a dead
//!   connection are dropped at batch formation as
//!   [`ServeError::Cancelled`] — a vanished client cannot leave stuck
//!   batches behind.
//! * **Reliable stop.** The listener runs nonblocking with a stop-flag
//!   poll (no self-connect unblock hack), connection readers use read
//!   timeouts so they observe the flag, and [`NetServer::stop`]
//!   force-disconnects any peer that outlives the drain grace.
//!
//! Malformed traffic never kills a connection: an unparseable,
//! non-UTF-8, or oversized line (cap [`MAX_LINE_BYTES`]) is answered
//! with an `err` frame and the reader resynchronises at the next
//! newline. Only socket EOF/errors (or server stop) end a connection.
//!
//! With `--tee PATH` the server appends every *inbound request line*
//! and every *outbound frame* to a JSONL log headed by a `hello` frame,
//! each line tagged with its connection id (`{"conn":N,…}` — see
//! [`frame::tag_conn`]) so multi-client captures keep per-connection
//! request-id namespaces separable and `draco replay` can re-drive
//! them without collisions (see [`super::replay`]). A failed tee write
//! disables the capture with a warning and serving continues.

use super::frame::{self, Frame};
use super::lazy::{self, LazyReq};
use crate::coordinator::{
    Coordinator, QosClass, ResponseSink, RobotRegistry, ServeError, SubmitOptions, TrajRequest,
};
use crate::obs::{Counter, Gauge, MetricsRegistry};
use crate::runtime::ArtifactFn;
use crate::util::rng::Rng;
use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, ErrorKind, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::{Arc, Mutex, Weak};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Hard cap on one wire line. A 64-DoF, 1024-step trajectory request is
/// ~1.5 MiB of decimal text, so 4 MiB leaves headroom; anything larger
/// is answered with an `err` frame and skipped to the next newline.
pub const MAX_LINE_BYTES: usize = 4 << 20;

/// Depth of each connection's bounded egress queue, in wire lines. One
/// full step batch is at most `batch` lines and a long trajectory
/// streams one line per row, so 1024 absorbs healthy bursts while
/// keeping a dead-slow reader's memory bill bounded.
pub const EGRESS_QUEUE_LINES: usize = 1024;

/// How long a producer may wait on a full egress queue before the
/// connection is declared dead and disconnected [ms]. This bounds the
/// stall one misbehaving reader can impose on jobs bound for other
/// connections.
const EGRESS_GRACE_MS: u64 = 500;

/// Poll interval of the nonblocking accept loop and the per-connection
/// read timeout [ms] — the latency bound on observing the stop flag or
/// a dead connection while idle.
const POLL_INTERVAL_MS: u64 = 50;

/// Default grace [`NetServer::stop`] allows connections to drain before
/// force-disconnecting them [ms].
const STOP_GRACE_MS: u64 = 2000;

/// Append-only tee log shared by every connection. The first failed
/// append (disk full, path truncated underneath us) permanently
/// disables the tee with a one-line warning — capture is best-effort,
/// serving is not allowed to degrade because of it.
struct Tee {
    file: Mutex<std::fs::File>,
    disabled: AtomicBool,
}

impl Tee {
    fn new(file: std::fs::File) -> Tee {
        Tee { file: Mutex::new(file), disabled: AtomicBool::new(false) }
    }

    fn append(&self, line: &str) {
        if self.disabled.load(Ordering::Acquire) {
            return;
        }
        let mut f = match self.file.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        let mut buf = Vec::with_capacity(line.len() + 1);
        buf.extend_from_slice(line.as_bytes());
        buf.push(b'\n');
        if f.write_all(&buf).is_err() && !self.disabled.swap(true, Ordering::AcqRel) {
            eprintln!("serve: tee write failed — capture disabled, serving continues");
        }
    }

    /// Append one wire line under `conn`'s namespace tag.
    fn append_tagged(&self, conn: u64, line: &str) {
        self.append(&frame::tag_conn(conn, line));
    }
}

/// Connection-layer metric handles, resolved once from the
/// coordinator's registry at server start and shared by every
/// connection (the previously invisible failure modes of the front
/// end, now countable over the `stats` route).
#[derive(Clone)]
struct NetCounters {
    /// Lines refused before dispatch: oversized, invalid UTF-8, or
    /// unscannable JSON.
    malformed: Arc<Counter>,
    /// Connections killed because the peer stopped draining its egress
    /// queue within the grace window.
    slow_kills: Arc<Counter>,
    /// High-water mark of any connection's egress-queue depth [lines].
    egress_hw: Arc<Gauge>,
}

impl NetCounters {
    fn new(m: &MetricsRegistry) -> NetCounters {
        NetCounters {
            malformed: m.counter("net_malformed_lines_total"),
            slow_kills: m.counter("net_slow_reader_kills_total"),
            egress_hw: m.gauge("net_egress_queue_highwater"),
        }
    }
}

/// Producer-side handle of one connection's write path, shared between
/// the reader thread (for `ack`/`err`) and the batcher workers (for
/// `chunk`/`done`/refusals). Lines go into a bounded queue drained by
/// the connection's writer thread; nobody holds a socket under a lock.
struct Wire {
    /// Bounded egress queue into the writer thread.
    tx: SyncSender<String>,
    /// Latched on peer EOF, socket error, egress overflow, or server
    /// stop. Streaming sinks observe it via [`ResponseSink::alive`];
    /// the batcher drops still-queued jobs for a dead wire at batch
    /// formation.
    dead: Arc<AtomicBool>,
    /// This connection's id-namespace tag (used by the tee).
    conn_id: u64,
    /// Socket handle used to force the connection down from any thread
    /// (unblocks a reader mid-`recv` and a writer mid-`send`).
    sock: TcpStream,
    /// Lines enqueued but not yet written (shared with the writer
    /// thread, which decrements as it drains).
    depth: Arc<AtomicU64>,
    /// Connection-layer metric handles.
    counters: NetCounters,
}

impl Wire {
    fn dead(&self) -> bool {
        self.dead.load(Ordering::SeqCst)
    }

    /// Declare the connection dead and shut the socket both ways. Safe
    /// to call from any thread, any number of times.
    fn kill(&self) {
        self.dead.store(true, Ordering::SeqCst);
        let _ = self.sock.shutdown(Shutdown::Both);
    }

    /// Enqueue one outbound line. A full queue blocks briefly (the
    /// reader may merely be busy); a queue still full after
    /// [`EGRESS_GRACE_MS`] means the peer has stopped draining, and the
    /// connection is killed so the producing worker can move on.
    fn send(&self, line: &str) {
        if self.dead() {
            return;
        }
        let mut line = line.to_string();
        let deadline = Instant::now() + Duration::from_millis(EGRESS_GRACE_MS);
        loop {
            match self.tx.try_send(line) {
                Ok(()) => {
                    let d = self.depth.fetch_add(1, Ordering::Relaxed) + 1;
                    self.counters.egress_hw.record_max(d);
                    return;
                }
                Err(TrySendError::Disconnected(_)) => {
                    self.kill();
                    return;
                }
                Err(TrySendError::Full(back)) => {
                    if self.dead() {
                        self.kill();
                        return;
                    }
                    if Instant::now() >= deadline {
                        // The peer stopped draining: this is the
                        // slow-reader kill, distinct from EOF/error
                        // deaths, and is counted as such.
                        self.counters.slow_kills.inc();
                        self.kill();
                        return;
                    }
                    line = back;
                    std::thread::sleep(Duration::from_micros(500));
                }
            }
        }
    }
}

/// Per-connection writer thread: drains the egress queue onto the
/// socket, teeing each line (under the connection tag) after a
/// successful write so the capture reflects what actually reached the
/// wire. Exits when the connection dies, every sender is gone, or a
/// socket write fails.
fn writer_loop(
    rx: Receiver<String>,
    mut sock: TcpStream,
    tee: Option<Arc<Tee>>,
    conn_id: u64,
    dead: Arc<AtomicBool>,
    depth: Arc<AtomicU64>,
) {
    loop {
        let line = match rx.recv_timeout(Duration::from_millis(POLL_INTERVAL_MS)) {
            Ok(l) => l,
            Err(RecvTimeoutError::Timeout) => {
                if dead.load(Ordering::SeqCst) {
                    return;
                }
                continue;
            }
            Err(RecvTimeoutError::Disconnected) => return,
        };
        depth.fetch_sub(1, Ordering::Relaxed);
        if dead.load(Ordering::SeqCst) {
            // Connection already declared dead: drop queued output.
            return;
        }
        let mut buf = Vec::with_capacity(line.len() + 1);
        buf.extend_from_slice(line.as_bytes());
        buf.push(b'\n');
        if sock.write_all(&buf).is_err() {
            dead.store(true, Ordering::SeqCst);
            return;
        }
        if let Some(t) = &tee {
            t.append_tagged(conn_id, &line);
        }
    }
}

/// [`ResponseSink`] that frames batcher output onto the client socket.
struct SocketSink {
    wire: Arc<Wire>,
    id: u64,
    seq: u64,
    /// `dyn_all` answers split into their natural q̈ | M⁻¹ | C segments,
    /// one `chunk` frame each.
    segments: Option<Vec<usize>>,
}

impl SocketSink {
    fn new(wire: Arc<Wire>, id: u64, segments: Option<Vec<usize>>) -> SocketSink {
        SocketSink { wire, id, seq: 0, segments }
    }

    fn emit(&mut self, data: &[f32]) {
        let line = frame::chunk_line(self.id, self.seq, data);
        self.seq += 1;
        self.wire.send(&line);
    }
}

impl ResponseSink for SocketSink {
    fn accepted(&mut self) {
        self.wire.send(&frame::ack_line(self.id));
    }

    fn chunk(&mut self, data: &[f32]) {
        match self.segments.clone() {
            Some(segs) => {
                let mut off = 0;
                for len in segs {
                    let end = (off + len).min(data.len());
                    self.emit(&data[off..end]);
                    off = end;
                }
                if off < data.len() {
                    self.emit(&data[off..]);
                }
            }
            None => self.emit(data),
        }
    }

    fn done(&mut self, result: Result<(), ServeError>) {
        match result {
            Ok(()) => self.wire.send(&frame::done_line(self.id, self.seq)),
            Err(e) => self.wire.send(&frame::serve_error_line(self.id, &e)),
        }
    }

    fn alive(&self) -> bool {
        !self.wire.dead()
    }
}

/// Bounded line reads: the distinction the fuzz tests care about.
pub(crate) enum LineRead {
    /// Peer closed the socket cleanly.
    Eof,
    /// One complete line (newline stripped) within the cap.
    Line,
    /// Line exceeded the cap; the remainder was discarded up to the
    /// next newline so the stream is resynchronised.
    Oversized,
}

/// Read one `\n`-terminated line into `buf`, never buffering more than
/// `cap + 1` bytes of a runaway line.
///
/// **Resumable across timeouts:** on a stream with a read timeout, a
/// `WouldBlock`/`TimedOut` error propagates with the partial line (or
/// the oversized-discard state) preserved in `buf`; calling again with
/// the same `buf` continues where the read left off, and the byte
/// budget accounts for what is already buffered — a line dripped
/// across many timeouts still respects the cap.
pub(crate) fn read_line_bounded<R: BufRead>(
    r: &mut R,
    buf: &mut Vec<u8>,
    cap: usize,
) -> std::io::Result<LineRead> {
    if buf.len() <= cap {
        let had = buf.len();
        let budget = (cap + 1 - had) as u64;
        let n = r.by_ref().take(budget).read_until(b'\n', buf)?;
        if n == 0 && had == 0 {
            return Ok(LineRead::Eof);
        }
        if buf.last() == Some(&b'\n') {
            buf.pop();
            return Ok(LineRead::Line);
        }
        if buf.len() <= cap {
            // EOF before a newline: treat the tail as a final line.
            return Ok(LineRead::Line);
        }
    }
    // Over the cap: discard to the next newline so the stream is
    // resynchronised. A timeout mid-discard propagates with `buf` still
    // oversized, so a resumed call re-enters this loop directly.
    loop {
        let (skip, found) = {
            let avail = r.fill_buf()?;
            if avail.is_empty() {
                return Ok(LineRead::Oversized);
            }
            match avail.iter().position(|&c| c == b'\n') {
                Some(p) => (p + 1, true),
                None => (avail.len(), false),
            }
        };
        r.consume(skip);
        if found {
            return Ok(LineRead::Oversized);
        }
    }
}

/// Listening JSONL server. [`NetServer::stop`] halts the accept loop
/// via its stop flag (nonblocking accept — no self-connect needed),
/// force-disconnects connections that outlive the drain grace, and
/// joins every connection thread.
pub struct NetServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    /// Live connections' write handles, for the force-drain in
    /// [`NetServer::stop_within`]. Weak: a connection that ended on its
    /// own is pruned, not kept alive by this registry.
    wires: Arc<Mutex<Vec<Weak<Wire>>>>,
}

impl NetServer {
    /// Bind `listen` (e.g. `127.0.0.1:0` for an ephemeral port) and
    /// serve `coord` on it. `dims` maps robot name → DoF (for `dyn_all`
    /// segment framing); `spec`/`batch`/`window_us` describe the
    /// serving config and head the tee log as a `hello` frame.
    pub fn start(
        coord: Arc<Coordinator>,
        dims: BTreeMap<String, usize>,
        listen: &str,
        tee: Option<&str>,
        spec: &str,
        batch: usize,
        window_us: u64,
    ) -> std::io::Result<NetServer> {
        let listener = TcpListener::bind(listen)?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let tee = match tee {
            Some(path) => {
                let t = Tee::new(std::fs::File::create(path)?);
                t.append(&frame::hello_line(spec, batch, window_us));
                Some(Arc::new(t))
            }
            None => None,
        };
        let stop = Arc::new(AtomicBool::new(false));
        let wires: Arc<Mutex<Vec<Weak<Wire>>>> = Arc::new(Mutex::new(Vec::new()));
        let counters = NetCounters::new(coord.obs().metrics());
        let stop2 = Arc::clone(&stop);
        let wires2 = Arc::clone(&wires);
        let accept = std::thread::spawn(move || {
            let mut conns: Vec<JoinHandle<()>> = Vec::new();
            let mut next_conn: u64 = 1;
            while !stop2.load(Ordering::SeqCst) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        // Accepted sockets go back to blocking reads
                        // (with a timeout, set in serve_conn) — only
                        // the listener itself polls.
                        let _ = stream.set_nonblocking(false);
                        let conn_id = next_conn;
                        next_conn += 1;
                        let coord = Arc::clone(&coord);
                        let dims = dims.clone();
                        let tee = tee.clone();
                        let stop = Arc::clone(&stop2);
                        let wires = Arc::clone(&wires2);
                        let counters = counters.clone();
                        conns.push(std::thread::spawn(move || {
                            serve_conn(&coord, &dims, tee, stream, conn_id, &stop, &wires, counters)
                        }));
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => {
                        // Dropping a finished handle detaches nothing —
                        // the thread already exited.
                        conns.retain(|c| !c.is_finished());
                        std::thread::sleep(Duration::from_millis(POLL_INTERVAL_MS));
                    }
                    Err(_) => break,
                }
            }
            for c in conns {
                let _ = c.join();
            }
        });
        Ok(NetServer { addr, stop, accept: Some(accept), wires })
    }

    /// The bound address (resolves the ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop with the default drain grace: clients that already hung up
    /// cost one poll interval; a peer still connected after ~2 s is
    /// force-disconnected.
    pub fn stop(self) {
        self.stop_within(Duration::from_millis(STOP_GRACE_MS));
    }

    /// Stop accepting, wait up to `grace` for connections to drain on
    /// their own, then force-disconnect the stragglers and join every
    /// thread. Never waits on client goodwill: a peer that ignores the
    /// shutdown is killed server-side and its in-flight streams cancel
    /// at the next `alive()` poll.
    pub fn stop_within(mut self, grace: Duration) {
        self.stop.store(true, Ordering::SeqCst);
        let deadline = Instant::now() + grace;
        while Instant::now() < deadline {
            match &self.accept {
                Some(h) if !h.is_finished() => std::thread::sleep(Duration::from_millis(5)),
                _ => break,
            }
        }
        {
            let wires = match self.wires.lock() {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
            for w in wires.iter() {
                if let Some(wire) = w.upgrade() {
                    wire.kill();
                }
            }
        }
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

/// Register a connection's wire for the stop-time force-drain, pruning
/// entries whose connections already ended.
fn register_wire(wires: &Mutex<Vec<Weak<Wire>>>, wire: &Arc<Wire>) {
    let mut g = match wires.lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    };
    g.retain(|w| w.strong_count() > 0);
    g.push(Arc::downgrade(wire));
}

#[allow(clippy::too_many_arguments)]
fn serve_conn(
    coord: &Coordinator,
    dims: &BTreeMap<String, usize>,
    tee: Option<Arc<Tee>>,
    stream: TcpStream,
    conn_id: u64,
    stop: &AtomicBool,
    wires: &Mutex<Vec<Weak<Wire>>>,
    counters: NetCounters,
) {
    let Ok(read_half) = stream.try_clone() else { return };
    let Ok(write_half) = stream.try_clone() else { return };
    // The read timeout is how this thread observes the stop flag and a
    // dead wire while the peer is idle.
    let _ = read_half.set_read_timeout(Some(Duration::from_millis(POLL_INTERVAL_MS)));
    let dead = Arc::new(AtomicBool::new(false));
    let depth = Arc::new(AtomicU64::new(0));
    let (tx, rx) = sync_channel(EGRESS_QUEUE_LINES);
    let writer = {
        let tee = tee.clone();
        let dead = Arc::clone(&dead);
        let depth = Arc::clone(&depth);
        std::thread::spawn(move || writer_loop(rx, write_half, tee, conn_id, dead, depth))
    };
    let wire = Arc::new(Wire { tx, dead, conn_id, sock: stream, depth, counters });
    register_wire(wires, &wire);
    let mut reader = BufReader::new(read_half);
    let mut buf = Vec::with_capacity(4096);
    'conn: loop {
        buf.clear();
        // Read one line, resuming across read timeouts.
        let status = loop {
            if wire.dead() || stop.load(Ordering::SeqCst) {
                break 'conn;
            }
            match read_line_bounded(&mut reader, &mut buf, MAX_LINE_BYTES) {
                Ok(s) => break s,
                Err(e)
                    if matches!(
                        e.kind(),
                        ErrorKind::WouldBlock | ErrorKind::TimedOut | ErrorKind::Interrupted
                    ) =>
                {
                    continue
                }
                Err(_) => break 'conn,
            }
        };
        match status {
            LineRead::Eof => break 'conn,
            LineRead::Oversized => {
                wire.counters.malformed.inc();
                wire.send(&frame::err_line(
                    0,
                    &format!("request line exceeds {MAX_LINE_BYTES} bytes"),
                ));
                continue 'conn;
            }
            LineRead::Line => {}
        }
        if buf.last() == Some(&b'\r') {
            buf.pop();
        }
        if buf.iter().all(u8::is_ascii_whitespace) {
            continue 'conn;
        }
        let Ok(line) = core::str::from_utf8(&buf) else {
            // Not teed: an invalid-UTF-8 line would corrupt the JSONL
            // log for replay.
            wire.counters.malformed.inc();
            wire.send(&frame::err_line(0, "request line is not valid UTF-8"));
            continue 'conn;
        };
        if let Some(t) = &tee {
            t.append_tagged(conn_id, line);
        }
        handle_line(coord, dims, &wire, line);
    }
    // Peer gone (or the server is stopping): latch the connection dead
    // so queued jobs cancel at their next alive() poll and in-flight
    // streams stop producing, then release our queue sender and join
    // the writer (it exits within one poll interval of `dead`).
    wire.kill();
    drop(wire);
    let _ = writer.join();
}

fn handle_line(
    coord: &Coordinator,
    dims: &BTreeMap<String, usize>,
    wire: &Arc<Wire>,
    line: &str,
) {
    let req = match LazyReq::scan(line) {
        Ok(r) => r,
        Err(e) => {
            wire.counters.malformed.inc();
            wire.send(&frame::err_line(0, &format!("bad frame: {e}")));
            return;
        }
    };
    let id = req.id;
    let fail = |msg: &str| wire.send(&frame::err_line(id, msg));
    if req.typ == "stats" {
        // Live metrics snapshot — answered inline by the connection
        // reader (the batcher is not involved), so it works even while
        // every route is saturated or breaker-open.
        let (counters, gauges) = stats_body(coord);
        wire.send(&frame::stats_line(id, &counters, &gauges));
        return;
    }
    if req.typ != "req" {
        fail(&format!("unsupported frame type '{}'", req.typ));
        return;
    }
    let Some(robot) = req.robot else {
        fail("req has no robot");
        return;
    };
    let Some(route) = req.route else {
        fail("req has no route");
        return;
    };
    let mut opts = SubmitOptions::default();
    if let Some(c) = req.class {
        match QosClass::parse(c) {
            Some(cl) => opts.class = Some(cl),
            None => {
                fail(&format!("unknown class '{c}'"));
                return;
            }
        }
    }
    opts.deadline_us = req.deadline_us;
    if route == "traj" {
        let (Some(q0), Some(qd0), Some(tau), Some(dt)) = (req.q0, req.qd0, req.tau, req.dt)
        else {
            fail("traj req needs q0, qd0, tau, dt");
            return;
        };
        let parse = |span: &str, what: &str| match lazy::parse_f32_array(span) {
            Ok(v) => Some(v),
            Err(e) => {
                fail(&format!("{what}: {e}"));
                None
            }
        };
        let (Some(q0), Some(qd0), Some(tau)) =
            (parse(q0, "q0"), parse(qd0, "qd0"), parse(tau, "tau"))
        else {
            return;
        };
        let sink = SocketSink::new(Arc::clone(wire), id, None);
        coord.submit_traj_sink(robot, TrajRequest { q0, qd0, tau, dt }, opts, Box::new(sink));
    } else {
        let Some(f) = ArtifactFn::parse(route) else {
            fail(&format!("unknown route '{route}'"));
            return;
        };
        let Some(span) = req.ops else {
            fail("step req has no ops");
            return;
        };
        let ops = match lazy::parse_f32_matrix(span) {
            Ok(m) => m,
            Err(e) => {
                fail(&format!("ops: {e}"));
                return;
            }
        };
        let segments = if f == ArtifactFn::DynAll {
            dims.get(robot).map(|&n| vec![n, n * n, n])
        } else {
            None
        };
        let sink = SocketSink::new(Arc::clone(wire), id, segments);
        coord.submit_to_sink(robot, f, ops, opts, Box::new(sink));
    }
}

/// The flat counter/gauge maps of a `stats` wire frame: the obs-hub
/// snapshot plus the terminal serving counters under `serve_*` names,
/// and derived p50/p99 gauges (integer µs / %) for every unlabelled
/// histogram — labelled per-`(robot, route, class)` histograms stay
/// available via the Prometheus rendering of `draco stats ADDR`, but
/// the wire frame carries only the compact aggregate view.
pub(crate) fn stats_body(
    coord: &Coordinator,
) -> (BTreeMap<String, u64>, BTreeMap<String, u64>) {
    let snap = coord.obs().snapshot();
    let st = coord.stats();
    let mut counters = snap.counters;
    for (name, v) in [
        ("serve_completed", st.completed),
        ("serve_batches", st.batches),
        ("serve_rejected", st.rejected),
        ("serve_expired", st.expired),
        ("serve_shed", st.shed),
        ("serve_cancelled", st.cancelled),
        ("serve_breaker_trips", st.breaker_trips),
        ("serve_memo_hits", st.memo_hits),
        ("serve_memo_misses", st.memo_misses),
    ] {
        counters.insert(name.to_string(), v);
    }
    let mut gauges = snap.gauges;
    for (name, h) in &snap.hists {
        if !name.contains('{') {
            gauges.insert(format!("{name}_p50"), h.percentile(0.50).round() as u64);
            gauges.insert(format!("{name}_p99"), h.percentile(0.99).round() as u64);
        }
    }
    (counters, gauges)
}

/// Blocking line-oriented client for tests, the self-drive smoke, and
/// the loadgen network mode.
pub struct NetClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl NetClient {
    /// Connect to a [`NetServer`].
    pub fn connect(addr: SocketAddr) -> std::io::Result<NetClient> {
        NetClient::from_stream(TcpStream::connect(addr)?)
    }

    /// Wrap an existing stream (e.g. the read half of a cloned socket
    /// when sending and receiving happen on different threads).
    pub fn from_stream(stream: TcpStream) -> std::io::Result<NetClient> {
        let reader = BufReader::new(stream.try_clone()?);
        Ok(NetClient { reader, writer: stream })
    }

    /// Send one raw line (newline appended).
    pub fn send_line(&mut self, line: &str) -> std::io::Result<()> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")
    }

    /// Read and parse the next frame, skipping blank lines.
    pub fn read_frame(&mut self) -> std::io::Result<Frame> {
        use std::io::Error;
        let mut buf = Vec::new();
        loop {
            buf.clear();
            match read_line_bounded(&mut self.reader, &mut buf, MAX_LINE_BYTES)? {
                LineRead::Eof => {
                    return Err(Error::new(ErrorKind::UnexpectedEof, "server closed connection"))
                }
                LineRead::Oversized => {
                    return Err(Error::new(ErrorKind::InvalidData, "oversized frame"))
                }
                LineRead::Line => {}
            }
            let line = core::str::from_utf8(&buf)
                .map_err(|_| Error::new(ErrorKind::InvalidData, "frame is not UTF-8"))?;
            if line.trim().is_empty() {
                continue;
            }
            return Frame::parse(line).map_err(|e| Error::new(ErrorKind::InvalidData, e));
        }
    }
}

/// `ack`-wait helper shared by the smoke driver.
fn expect_ack(c: &mut NetClient, id: u64) -> Result<(), String> {
    match c.read_frame().map_err(|e| e.to_string())? {
        Frame::Ack { id: got } if got == id => Ok(()),
        other => Err(format!("expected ack for id {id}, got {other:?}")),
    }
}

/// Read `chunk` frames until `done`, returning the chunks in order plus
/// the delay to the first chunk. Refusal or `err` frames become errors.
fn read_stream(c: &mut NetClient, id: u64) -> Result<(Vec<Vec<f32>>, Duration), String> {
    let t0 = Instant::now();
    let mut first = Duration::ZERO;
    let mut chunks: Vec<Vec<f32>> = Vec::new();
    loop {
        match c.read_frame().map_err(|e| e.to_string())? {
            Frame::Chunk { id: got, seq, data } if got == id => {
                if seq != chunks.len() as u64 {
                    return Err(format!("id {id}: chunk seq {seq}, expected {}", chunks.len()));
                }
                if chunks.is_empty() {
                    first = t0.elapsed();
                }
                chunks.push(data);
            }
            Frame::Done { id: got, chunks: n } if got == id => {
                if n != chunks.len() as u64 {
                    return Err(format!("id {id}: done says {n} chunks, saw {}", chunks.len()));
                }
                return Ok((chunks, first));
            }
            other => return Err(format!("id {id}: unexpected frame {other:?}")),
        }
    }
}

/// End-to-end smoke of the wire protocol against a live server: per
/// robot it checks a step route, the three-segment `dyn_all` framing, a
/// mid-horizon-streamed trajectory (compared bitwise against the
/// in-process rollout), and a deadline-0 expiry; then it verifies that
/// unknown routes and robots produce `err` frames without dropping the
/// connection. Returns a process exit code.
pub fn self_drive(
    addr: SocketAddr,
    registry: &RobotRegistry,
    coord: &Coordinator,
    dt: f64,
) -> i32 {
    match drive(addr, registry, coord, dt) {
        Ok(()) => {
            println!("self-drive: OK");
            0
        }
        Err(e) => {
            eprintln!("self-drive: FAILED: {e}");
            1
        }
    }
}

fn drive(
    addr: SocketAddr,
    registry: &RobotRegistry,
    coord: &Coordinator,
    dt: f64,
) -> Result<(), String> {
    let io = |e: std::io::Error| e.to_string();
    let mut c = NetClient::connect(addr).map_err(io)?;
    let mut rng = Rng::new(0x5eed);
    let mut id = 0u64;
    let names = registry.names();
    for name in &names {
        let n = registry.get(name).ok_or("registry lookup failed")?.robot.dof();
        let mut vecf =
            |len: usize| (0..len).map(|_| (rng.f64() * 2.0 - 1.0) as f32).collect::<Vec<f32>>();

        // Step route: one chunk of N.
        id += 1;
        let ops = vec![vecf(n), vecf(n), vecf(n)];
        c.send_line(&frame::req_step_line(id, name, "fd", None, None, &ops)).map_err(io)?;
        expect_ack(&mut c, id)?;
        let (chunks, _) = read_stream(&mut c, id)?;
        if chunks.len() != 1 || chunks[0].len() != n {
            return Err(format!("{name} fd: expected 1 chunk of {n} values"));
        }

        // dyn_all: three segments q̈ (N) | M⁻¹ (N²) | C (N).
        id += 1;
        let ops = vec![vecf(n), vecf(n), vecf(n)];
        c.send_line(&frame::req_step_line(id, name, "dynall", None, None, &ops)).map_err(io)?;
        expect_ack(&mut c, id)?;
        let (chunks, _) = read_stream(&mut c, id)?;
        let lens: Vec<usize> = chunks.iter().map(Vec::len).collect();
        if lens != [n, n * n, n] {
            return Err(format!("{name} dyn_all: segment lengths {lens:?}, expected [{n}, {}, {n}]", n * n));
        }

        // Trajectory: H rows streamed mid-horizon, bitwise-identical to
        // the buffered in-process rollout.
        let h = 32;
        id += 1;
        let (q0, qd0, tau) = (vecf(n), vecf(n), vecf(h * n));
        c.send_line(&frame::req_traj_line(id, name, None, None, &q0, &qd0, &tau, dt))
            .map_err(io)?;
        expect_ack(&mut c, id)?;
        let t0 = Instant::now();
        let (rows, first) = read_stream(&mut c, id)?;
        let total = t0.elapsed();
        if rows.len() != h {
            return Err(format!("{name} traj: {} rows, expected {h}", rows.len()));
        }
        let legacy = coord
            .submit_traj(name, TrajRequest { q0, qd0, tau, dt })
            .recv()
            .map_err(|_| "traj channel closed")?
            .map_err(|e| e.to_string())?;
        for (t, row) in rows.iter().enumerate() {
            if row.len() != 2 * n {
                return Err(format!("{name} traj row {t}: {} values, expected {}", row.len(), 2 * n));
            }
            for j in 0..n {
                let (wq, wqd) = (legacy[t * n + j], legacy[(h + t) * n + j]);
                if row[j].to_bits() != wq.to_bits() || row[n + j].to_bits() != wqd.to_bits() {
                    return Err(format!("{name} traj row {t} differs from in-process rollout"));
                }
            }
        }
        println!(
            "  {name}: traj h={h} streamed over TCP, first row after {first:?} \
             (full horizon after {total:?}), rows bitwise == in-process rollout"
        );

        // Deadline 0: admitted (ack) then expired at batch formation.
        id += 1;
        let ops = vec![vecf(n), vecf(n), vecf(n)];
        c.send_line(&frame::req_step_line(id, name, "fd", Some("bulk"), Some(0), &ops))
            .map_err(io)?;
        expect_ack(&mut c, id)?;
        match c.read_frame().map_err(io)? {
            Frame::Expired { id: got, deadline_us: 0, .. } if got == id => {}
            other => {
                return Err(format!("{name}: deadline-0 req answered {other:?}, expected expired"))
            }
        }
    }

    // Malformed traffic keeps the connection alive.
    let first = names.first().ok_or("empty registry")?;
    id += 1;
    c.send_line(&frame::req_step_line(id, first, "warp", None, None, &[vec![0.0]]))
        .map_err(io)?;
    match c.read_frame().map_err(io)? {
        Frame::Err { id: got, .. } if got == id => {}
        other => return Err(format!("unknown route answered {other:?}, expected err")),
    }
    id += 1;
    c.send_line(&frame::req_step_line(id, "no-such-robot", "fd", None, None, &[vec![0.0]]))
        .map_err(io)?;
    match c.read_frame().map_err(io)? {
        Frame::Err { id: got, .. } if got == id => {}
        other => return Err(format!("unknown robot answered {other:?}, expected err")),
    }
    println!("  wire: deadline expiry, unknown route/robot all answered in-band");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Satellite (a): a tee whose file cannot be written disables
    /// itself after one failed append instead of failing (or poisoning
    /// a lock for) every later connection.
    #[test]
    fn tee_disables_itself_on_write_error() {
        // A read-only open of /dev/null fails every write on Unix; on
        // other platforms open a fresh read-only temp file.
        let path = if cfg!(unix) {
            std::path::PathBuf::from("/dev/null")
        } else {
            let p = std::env::temp_dir().join("draco_tee_readonly_test");
            std::fs::write(&p, b"").unwrap();
            p
        };
        let file = std::fs::OpenOptions::new().read(true).open(&path).unwrap();
        let tee = Tee::new(file);
        assert!(!tee.disabled.load(Ordering::Acquire));
        tee.append("{\"type\":\"hello\"}");
        assert!(tee.disabled.load(Ordering::Acquire), "failed append must disable the tee");
        // Later appends are silent no-ops — serving continues.
        tee.append_tagged(3, "{\"id\":1,\"type\":\"ack\"}");
        assert!(tee.disabled.load(Ordering::Acquire));
    }

    /// The bounded reader is resumable: a line split across timeouts
    /// (simulated with chunked readers) still respects the cap, and an
    /// oversized line resynchronises at the newline.
    #[test]
    fn read_line_bounded_budgets_across_resumes() {
        use std::io::Cursor;
        // Whole-line happy path.
        let mut r = BufReader::new(Cursor::new(b"abc\ndef".to_vec()));
        let mut buf = Vec::new();
        assert!(matches!(read_line_bounded(&mut r, &mut buf, 16).unwrap(), LineRead::Line));
        assert_eq!(buf, b"abc");
        buf.clear();
        // EOF tail counts as a final line.
        assert!(matches!(read_line_bounded(&mut r, &mut buf, 16).unwrap(), LineRead::Line));
        assert_eq!(buf, b"def");
        buf.clear();
        assert!(matches!(read_line_bounded(&mut r, &mut buf, 16).unwrap(), LineRead::Eof));
        // Resumed partial reads share one budget: a 10-byte line against
        // an 8-byte cap is oversized even when it arrives 4 bytes at a
        // time (each call sees a pre-filled `buf`).
        let mut r = BufReader::new(Cursor::new(b"0123456789\nok\n".to_vec()));
        let mut buf = Vec::new();
        buf.extend_from_slice(b"0123");
        // Simulate the resume by pre-loading what a timed-out call
        // would have left behind; the budget must subtract it.
        assert!(matches!(read_line_bounded(&mut r, &mut buf, 8).unwrap(), LineRead::Oversized));
        buf.clear();
        assert!(matches!(read_line_bounded(&mut r, &mut buf, 8).unwrap(), LineRead::Line));
        assert_eq!(buf, b"ok");
    }
}
