//! JSONL TCP front-end.
//!
//! One thread per accepted connection reads newline-delimited request
//! frames, routes them through the [`Coordinator`]'s sink submit paths
//! (so admission, QoS classes, deadlines, and circuit breakers apply
//! exactly as for in-process callers), and a [`SocketSink`] writes the
//! response event stream — `ack`, `chunk`…, `done`/refusal — straight
//! back to the socket as the batcher produces it. Trajectory rows hit
//! the wire mid-horizon; nothing is buffered server-side.
//!
//! Malformed traffic never kills a connection: an unparseable,
//! non-UTF-8, or oversized line (cap [`MAX_LINE_BYTES`]) is answered
//! with an `err` frame and the reader resynchronises at the next
//! newline. Only socket EOF/errors end a connection.
//!
//! With `--tee PATH` the server appends every *inbound request line
//! verbatim* and every *outbound frame* to a JSONL log headed by a
//! `hello` frame — enough for `draco replay` to rebuild the registry,
//! re-drive each request, and compare payloads bitwise (see
//! [`super::replay`]).

use super::frame::{self, Frame};
use super::lazy::{self, LazyReq};
use crate::coordinator::{
    Coordinator, QosClass, ResponseSink, RobotRegistry, ServeError, SubmitOptions, TrajRequest,
};
use crate::runtime::ArtifactFn;
use crate::util::rng::Rng;
use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Hard cap on one wire line. A 64-DoF, 1024-step trajectory request is
/// ~1.5 MiB of decimal text, so 4 MiB leaves headroom; anything larger
/// is answered with an `err` frame and skipped to the next newline.
pub const MAX_LINE_BYTES: usize = 4 << 20;

/// Append-only tee log shared by every connection.
struct Tee(Mutex<std::fs::File>);

impl Tee {
    fn append(&self, line: &str) {
        let mut f = match self.0.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        let _ = f.write_all(line.as_bytes());
        let _ = f.write_all(b"\n");
    }
}

/// Write half of one connection, shared between the reader thread (for
/// `ack`/`err`) and the batcher workers (for `chunk`/`done`). The first
/// socket write error latches `dead`, which streaming sinks observe via
/// [`ResponseSink::alive`] to cancel mid-horizon work.
struct Wire {
    w: Mutex<TcpStream>,
    dead: AtomicBool,
    tee: Option<Arc<Tee>>,
}

impl Wire {
    fn dead(&self) -> bool {
        self.dead.load(Ordering::SeqCst)
    }

    fn send(&self, line: &str) {
        if self.dead() {
            return;
        }
        let mut w = match self.w.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        let mut buf = Vec::with_capacity(line.len() + 1);
        buf.extend_from_slice(line.as_bytes());
        buf.push(b'\n');
        if w.write_all(&buf).is_err() {
            self.dead.store(true, Ordering::SeqCst);
            return;
        }
        // Tee under the write lock so the log preserves wire order.
        if let Some(t) = &self.tee {
            t.append(line);
        }
    }
}

/// [`ResponseSink`] that frames batcher output onto the client socket.
struct SocketSink {
    wire: Arc<Wire>,
    id: u64,
    seq: u64,
    /// `dyn_all` answers split into their natural q̈ | M⁻¹ | C segments,
    /// one `chunk` frame each.
    segments: Option<Vec<usize>>,
}

impl SocketSink {
    fn new(wire: Arc<Wire>, id: u64, segments: Option<Vec<usize>>) -> SocketSink {
        SocketSink { wire, id, seq: 0, segments }
    }

    fn emit(&mut self, data: &[f32]) {
        let line = frame::chunk_line(self.id, self.seq, data);
        self.seq += 1;
        self.wire.send(&line);
    }
}

impl ResponseSink for SocketSink {
    fn accepted(&mut self) {
        self.wire.send(&frame::ack_line(self.id));
    }

    fn chunk(&mut self, data: &[f32]) {
        match self.segments.clone() {
            Some(segs) => {
                let mut off = 0;
                for len in segs {
                    let end = (off + len).min(data.len());
                    self.emit(&data[off..end]);
                    off = end;
                }
                if off < data.len() {
                    self.emit(&data[off..]);
                }
            }
            None => self.emit(data),
        }
    }

    fn done(&mut self, result: Result<(), ServeError>) {
        match result {
            Ok(()) => self.wire.send(&frame::done_line(self.id, self.seq)),
            Err(e) => self.wire.send(&frame::serve_error_line(self.id, &e)),
        }
    }

    fn alive(&self) -> bool {
        !self.wire.dead()
    }
}

/// Bounded line reads: the distinction the fuzz tests care about.
pub(crate) enum LineRead {
    /// Peer closed the socket cleanly.
    Eof,
    /// One complete line (newline stripped) within the cap.
    Line,
    /// Line exceeded the cap; the remainder was discarded up to the
    /// next newline so the stream is resynchronised.
    Oversized,
}

/// Read one `\n`-terminated line into `buf`, never buffering more than
/// `cap + 1` bytes of a runaway line.
pub(crate) fn read_line_bounded<R: BufRead>(
    r: &mut R,
    buf: &mut Vec<u8>,
    cap: usize,
) -> std::io::Result<LineRead> {
    let n = r.by_ref().take(cap as u64 + 1).read_until(b'\n', buf)?;
    if n == 0 {
        return Ok(LineRead::Eof);
    }
    if buf.last() == Some(&b'\n') {
        buf.pop();
        return Ok(LineRead::Line);
    }
    if buf.len() <= cap {
        // EOF before a newline: treat the tail as a final line.
        return Ok(LineRead::Line);
    }
    loop {
        let (skip, found) = {
            let avail = r.fill_buf()?;
            if avail.is_empty() {
                return Ok(LineRead::Oversized);
            }
            match avail.iter().position(|&c| c == b'\n') {
                Some(p) => (p + 1, true),
                None => (avail.len(), false),
            }
        };
        r.consume(skip);
        if found {
            return Ok(LineRead::Oversized);
        }
    }
}

/// Listening JSONL server. [`NetServer::stop`] unblocks the accept loop
/// and joins every connection thread.
pub struct NetServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
}

impl NetServer {
    /// Bind `listen` (e.g. `127.0.0.1:0` for an ephemeral port) and
    /// serve `coord` on it. `dims` maps robot name → DoF (for `dyn_all`
    /// segment framing); `spec`/`batch`/`window_us` describe the
    /// serving config and head the tee log as a `hello` frame.
    pub fn start(
        coord: Arc<Coordinator>,
        dims: BTreeMap<String, usize>,
        listen: &str,
        tee: Option<&str>,
        spec: &str,
        batch: usize,
        window_us: u64,
    ) -> std::io::Result<NetServer> {
        let listener = TcpListener::bind(listen)?;
        let addr = listener.local_addr()?;
        let tee = match tee {
            Some(path) => {
                let t = Tee(Mutex::new(std::fs::File::create(path)?));
                t.append(&frame::hello_line(spec, batch, window_us));
                Some(Arc::new(t))
            }
            None => None,
        };
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let accept = std::thread::spawn(move || {
            let mut conns = Vec::new();
            for stream in listener.incoming() {
                if stop2.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = stream else { break };
                let coord = Arc::clone(&coord);
                let dims = dims.clone();
                let tee = tee.clone();
                conns.push(std::thread::spawn(move || serve_conn(&coord, &dims, tee, stream)));
            }
            for c in conns {
                let _ = c.join();
            }
        });
        Ok(NetServer { addr, stop, accept: Some(accept) })
    }

    /// The bound address (resolves the ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting and join all connection threads. Connections end
    /// when their client disconnects, so call this after clients close.
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Self-connect to unblock the accept loop.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

fn serve_conn(
    coord: &Coordinator,
    dims: &BTreeMap<String, usize>,
    tee: Option<Arc<Tee>>,
    stream: TcpStream,
) {
    let Ok(read_half) = stream.try_clone() else { return };
    let wire = Arc::new(Wire { w: Mutex::new(stream), dead: AtomicBool::new(false), tee });
    let mut reader = BufReader::new(read_half);
    let mut buf = Vec::with_capacity(4096);
    loop {
        if wire.dead() {
            return;
        }
        buf.clear();
        match read_line_bounded(&mut reader, &mut buf, MAX_LINE_BYTES) {
            Ok(LineRead::Eof) | Err(_) => return,
            Ok(LineRead::Oversized) => {
                wire.send(&frame::err_line(
                    0,
                    &format!("request line exceeds {MAX_LINE_BYTES} bytes"),
                ));
                continue;
            }
            Ok(LineRead::Line) => {}
        }
        if buf.last() == Some(&b'\r') {
            buf.pop();
        }
        if buf.iter().all(u8::is_ascii_whitespace) {
            continue;
        }
        let Ok(line) = core::str::from_utf8(&buf) else {
            // Not teed: an invalid-UTF-8 line would corrupt the JSONL
            // log for replay.
            wire.send(&frame::err_line(0, "request line is not valid UTF-8"));
            continue;
        };
        if let Some(t) = &wire.tee {
            t.append(line);
        }
        handle_line(coord, dims, &wire, line);
    }
}

fn handle_line(
    coord: &Coordinator,
    dims: &BTreeMap<String, usize>,
    wire: &Arc<Wire>,
    line: &str,
) {
    let req = match LazyReq::scan(line) {
        Ok(r) => r,
        Err(e) => {
            wire.send(&frame::err_line(0, &format!("bad frame: {e}")));
            return;
        }
    };
    let id = req.id;
    let fail = |msg: &str| wire.send(&frame::err_line(id, msg));
    if req.typ != "req" {
        fail(&format!("unsupported frame type '{}'", req.typ));
        return;
    }
    let Some(robot) = req.robot else {
        fail("req has no robot");
        return;
    };
    let Some(route) = req.route else {
        fail("req has no route");
        return;
    };
    let mut opts = SubmitOptions::default();
    if let Some(c) = req.class {
        match QosClass::parse(c) {
            Some(cl) => opts.class = Some(cl),
            None => {
                fail(&format!("unknown class '{c}'"));
                return;
            }
        }
    }
    opts.deadline_us = req.deadline_us;
    if route == "traj" {
        let (Some(q0), Some(qd0), Some(tau), Some(dt)) = (req.q0, req.qd0, req.tau, req.dt)
        else {
            fail("traj req needs q0, qd0, tau, dt");
            return;
        };
        let parse = |span: &str, what: &str| match lazy::parse_f32_array(span) {
            Ok(v) => Some(v),
            Err(e) => {
                fail(&format!("{what}: {e}"));
                None
            }
        };
        let (Some(q0), Some(qd0), Some(tau)) =
            (parse(q0, "q0"), parse(qd0, "qd0"), parse(tau, "tau"))
        else {
            return;
        };
        let sink = SocketSink::new(Arc::clone(wire), id, None);
        coord.submit_traj_sink(robot, TrajRequest { q0, qd0, tau, dt }, opts, Box::new(sink));
    } else {
        let Some(f) = ArtifactFn::parse(route) else {
            fail(&format!("unknown route '{route}'"));
            return;
        };
        let Some(span) = req.ops else {
            fail("step req has no ops");
            return;
        };
        let ops = match lazy::parse_f32_matrix(span) {
            Ok(m) => m,
            Err(e) => {
                fail(&format!("ops: {e}"));
                return;
            }
        };
        let segments = if f == ArtifactFn::DynAll {
            dims.get(robot).map(|&n| vec![n, n * n, n])
        } else {
            None
        };
        let sink = SocketSink::new(Arc::clone(wire), id, segments);
        coord.submit_to_sink(robot, f, ops, opts, Box::new(sink));
    }
}

/// Blocking line-oriented client for tests, the self-drive smoke, and
/// the loadgen network mode.
pub struct NetClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl NetClient {
    /// Connect to a [`NetServer`].
    pub fn connect(addr: SocketAddr) -> std::io::Result<NetClient> {
        NetClient::from_stream(TcpStream::connect(addr)?)
    }

    /// Wrap an existing stream (e.g. the read half of a cloned socket
    /// when sending and receiving happen on different threads).
    pub fn from_stream(stream: TcpStream) -> std::io::Result<NetClient> {
        let reader = BufReader::new(stream.try_clone()?);
        Ok(NetClient { reader, writer: stream })
    }

    /// Send one raw line (newline appended).
    pub fn send_line(&mut self, line: &str) -> std::io::Result<()> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")
    }

    /// Read and parse the next frame, skipping blank lines.
    pub fn read_frame(&mut self) -> std::io::Result<Frame> {
        use std::io::{Error, ErrorKind};
        let mut buf = Vec::new();
        loop {
            buf.clear();
            match read_line_bounded(&mut self.reader, &mut buf, MAX_LINE_BYTES)? {
                LineRead::Eof => {
                    return Err(Error::new(ErrorKind::UnexpectedEof, "server closed connection"))
                }
                LineRead::Oversized => {
                    return Err(Error::new(ErrorKind::InvalidData, "oversized frame"))
                }
                LineRead::Line => {}
            }
            let line = core::str::from_utf8(&buf)
                .map_err(|_| Error::new(ErrorKind::InvalidData, "frame is not UTF-8"))?;
            if line.trim().is_empty() {
                continue;
            }
            return Frame::parse(line).map_err(|e| Error::new(ErrorKind::InvalidData, e));
        }
    }
}

/// `ack`-wait helper shared by the smoke driver.
fn expect_ack(c: &mut NetClient, id: u64) -> Result<(), String> {
    match c.read_frame().map_err(|e| e.to_string())? {
        Frame::Ack { id: got } if got == id => Ok(()),
        other => Err(format!("expected ack for id {id}, got {other:?}")),
    }
}

/// Read `chunk` frames until `done`, returning the chunks in order plus
/// the delay to the first chunk. Refusal or `err` frames become errors.
fn read_stream(c: &mut NetClient, id: u64) -> Result<(Vec<Vec<f32>>, Duration), String> {
    let t0 = Instant::now();
    let mut first = Duration::ZERO;
    let mut chunks: Vec<Vec<f32>> = Vec::new();
    loop {
        match c.read_frame().map_err(|e| e.to_string())? {
            Frame::Chunk { id: got, seq, data } if got == id => {
                if seq != chunks.len() as u64 {
                    return Err(format!("id {id}: chunk seq {seq}, expected {}", chunks.len()));
                }
                if chunks.is_empty() {
                    first = t0.elapsed();
                }
                chunks.push(data);
            }
            Frame::Done { id: got, chunks: n } if got == id => {
                if n != chunks.len() as u64 {
                    return Err(format!("id {id}: done says {n} chunks, saw {}", chunks.len()));
                }
                return Ok((chunks, first));
            }
            other => return Err(format!("id {id}: unexpected frame {other:?}")),
        }
    }
}

/// End-to-end smoke of the wire protocol against a live server: per
/// robot it checks a step route, the three-segment `dyn_all` framing, a
/// mid-horizon-streamed trajectory (compared bitwise against the
/// in-process rollout), and a deadline-0 expiry; then it verifies that
/// unknown routes and robots produce `err` frames without dropping the
/// connection. Returns a process exit code.
pub fn self_drive(
    addr: SocketAddr,
    registry: &RobotRegistry,
    coord: &Coordinator,
    dt: f64,
) -> i32 {
    match drive(addr, registry, coord, dt) {
        Ok(()) => {
            println!("self-drive: OK");
            0
        }
        Err(e) => {
            eprintln!("self-drive: FAILED: {e}");
            1
        }
    }
}

fn drive(
    addr: SocketAddr,
    registry: &RobotRegistry,
    coord: &Coordinator,
    dt: f64,
) -> Result<(), String> {
    let io = |e: std::io::Error| e.to_string();
    let mut c = NetClient::connect(addr).map_err(io)?;
    let mut rng = Rng::new(0x5eed);
    let mut id = 0u64;
    let names = registry.names();
    for name in &names {
        let n = registry.get(name).ok_or("registry lookup failed")?.robot.dof();
        let mut vecf =
            |len: usize| (0..len).map(|_| (rng.f64() * 2.0 - 1.0) as f32).collect::<Vec<f32>>();

        // Step route: one chunk of N.
        id += 1;
        let ops = vec![vecf(n), vecf(n), vecf(n)];
        c.send_line(&frame::req_step_line(id, name, "fd", None, None, &ops)).map_err(io)?;
        expect_ack(&mut c, id)?;
        let (chunks, _) = read_stream(&mut c, id)?;
        if chunks.len() != 1 || chunks[0].len() != n {
            return Err(format!("{name} fd: expected 1 chunk of {n} values"));
        }

        // dyn_all: three segments q̈ (N) | M⁻¹ (N²) | C (N).
        id += 1;
        let ops = vec![vecf(n), vecf(n), vecf(n)];
        c.send_line(&frame::req_step_line(id, name, "dynall", None, None, &ops)).map_err(io)?;
        expect_ack(&mut c, id)?;
        let (chunks, _) = read_stream(&mut c, id)?;
        let lens: Vec<usize> = chunks.iter().map(Vec::len).collect();
        if lens != [n, n * n, n] {
            return Err(format!("{name} dyn_all: segment lengths {lens:?}, expected [{n}, {}, {n}]", n * n));
        }

        // Trajectory: H rows streamed mid-horizon, bitwise-identical to
        // the buffered in-process rollout.
        let h = 32;
        id += 1;
        let (q0, qd0, tau) = (vecf(n), vecf(n), vecf(h * n));
        c.send_line(&frame::req_traj_line(id, name, None, None, &q0, &qd0, &tau, dt))
            .map_err(io)?;
        expect_ack(&mut c, id)?;
        let t0 = Instant::now();
        let (rows, first) = read_stream(&mut c, id)?;
        let total = t0.elapsed();
        if rows.len() != h {
            return Err(format!("{name} traj: {} rows, expected {h}", rows.len()));
        }
        let legacy = coord
            .submit_traj(name, TrajRequest { q0, qd0, tau, dt })
            .recv()
            .map_err(|_| "traj channel closed")?
            .map_err(|e| e.to_string())?;
        for (t, row) in rows.iter().enumerate() {
            if row.len() != 2 * n {
                return Err(format!("{name} traj row {t}: {} values, expected {}", row.len(), 2 * n));
            }
            for j in 0..n {
                let (wq, wqd) = (legacy[t * n + j], legacy[(h + t) * n + j]);
                if row[j].to_bits() != wq.to_bits() || row[n + j].to_bits() != wqd.to_bits() {
                    return Err(format!("{name} traj row {t} differs from in-process rollout"));
                }
            }
        }
        println!(
            "  {name}: traj h={h} streamed over TCP, first row after {first:?} \
             (full horizon after {total:?}), rows bitwise == in-process rollout"
        );

        // Deadline 0: admitted (ack) then expired at batch formation.
        id += 1;
        let ops = vec![vecf(n), vecf(n), vecf(n)];
        c.send_line(&frame::req_step_line(id, name, "fd", Some("bulk"), Some(0), &ops))
            .map_err(io)?;
        expect_ack(&mut c, id)?;
        match c.read_frame().map_err(io)? {
            Frame::Expired { id: got, deadline_us: 0, .. } if got == id => {}
            other => {
                return Err(format!("{name}: deadline-0 req answered {other:?}, expected expired"))
            }
        }
    }

    // Malformed traffic keeps the connection alive.
    let first = names.first().ok_or("empty registry")?;
    id += 1;
    c.send_line(&frame::req_step_line(id, first, "warp", None, None, &[vec![0.0]]))
        .map_err(io)?;
    match c.read_frame().map_err(io)? {
        Frame::Err { id: got, .. } if got == id => {}
        other => return Err(format!("unknown route answered {other:?}, expected err")),
    }
    id += 1;
    c.send_line(&frame::req_step_line(id, "no-such-robot", "fd", None, None, &[vec![0.0]]))
        .map_err(io)?;
    match c.read_frame().map_err(io)? {
        Frame::Err { id: got, .. } if got == id => {}
        other => return Err(format!("unknown robot answered {other:?}, expected err")),
    }
    println!("  wire: deadline expiry, unknown route/robot all answered in-band");
    Ok(())
}
