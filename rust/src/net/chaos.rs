//! Deterministic wire-level fault injection.
//!
//! [`FaultyClient`] wraps a raw TCP stream to the server and corrupts
//! its *outbound* traffic according to a seeded [`FaultPlan`]: standalone
//! garbage lines between requests, writes torn into delayed fragments
//! (exercising the server's resumable bounded reader), and a mid-line
//! disconnect after a configured number of sends (exercising dead-wire
//! cancellation). Every fault is drawn from a [`Rng`] seeded by the
//! plan, so a scenario replays byte-identically: the fault suite can
//! assert exact server behaviour, not just "something went wrong".
//!
//! The shim only perturbs the client→server direction. Responses are
//! read with a plain [`NetClient`](super::NetClient) over the same
//! socket (or the reading half is simply abandoned for disconnect
//! scenarios); server→client faults are equivalent to a slow or dead
//! reader, which the egress-queue grace in [`super::server`] covers.

use crate::util::rng::Rng;
use std::io::Write;
use std::net::{Shutdown, SocketAddr, TcpStream};
use std::time::Duration;

/// What to inject, and how often. All probabilities are per sent line;
/// `0.0` disables that fault class. Two clients driving the same plan
/// (same seed) against the same request sequence emit identical bytes.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    /// Seed for the fault RNG.
    pub seed: u64,
    /// Probability of emitting one standalone garbage line before a
    /// request line.
    pub garbage_every: f64,
    /// Probability of tearing a request line into several separately
    /// flushed fragments.
    pub tear_writes: f64,
    /// Pause between torn fragments [µs] — dribbles a line across the
    /// server's read timeouts.
    pub fragment_delay_us: u64,
    /// Disconnect mid-line on the Nth send (1-based); `0` never
    /// disconnects.
    pub disconnect_after: u64,
}

impl Default for FaultPlan {
    /// A moderately hostile peer: occasional garbage, frequent torn
    /// writes with a short dribble, no disconnect.
    fn default() -> FaultPlan {
        FaultPlan {
            seed: 0xFA_17,
            garbage_every: 0.25,
            tear_writes: 0.5,
            fragment_delay_us: 200,
            disconnect_after: 0,
        }
    }
}

/// A client whose writes misbehave per a [`FaultPlan`]. See the module
/// docs for the fault classes.
pub struct FaultyClient {
    sock: TcpStream,
    rng: Rng,
    plan: FaultPlan,
    sent: u64,
    disconnected: bool,
}

impl FaultyClient {
    /// Connect to `addr` and fault per `plan`.
    pub fn connect(addr: SocketAddr, plan: FaultPlan) -> std::io::Result<FaultyClient> {
        FaultyClient::from_stream(TcpStream::connect(addr)?, plan)
    }

    /// Wrap an existing stream (e.g. the write half of a cloned socket
    /// whose read half feeds a [`NetClient`](super::NetClient)).
    pub fn from_stream(sock: TcpStream, plan: FaultPlan) -> std::io::Result<FaultyClient> {
        let rng = Rng::new(plan.seed);
        Ok(FaultyClient { sock, rng, plan, sent: 0, disconnected: false })
    }

    /// Whether the plan's mid-line disconnect has fired.
    pub fn disconnected(&self) -> bool {
        self.disconnected
    }

    /// Lines fully sent so far (garbage and torn-off partials excluded).
    pub fn sent(&self) -> u64 {
        self.sent
    }

    /// Send one request line through the fault shim. Returns `Ok(true)`
    /// if the line reached the socket intact (possibly torn into
    /// fragments), `Ok(false)` if the plan disconnected mid-line
    /// instead — after which every call is a no-op `Ok(false)`.
    pub fn send_line(&mut self, line: &str) -> std::io::Result<bool> {
        if self.disconnected {
            return Ok(false);
        }
        if self.rng.f64() < self.plan.garbage_every {
            let junk = self.garbage_line();
            self.sock.write_all(junk.as_bytes())?;
            self.sock.write_all(b"\n")?;
        }
        if self.plan.disconnect_after > 0 && self.sent + 1 >= self.plan.disconnect_after {
            // Tear the connection down mid-line: the server must treat
            // the torn prefix as noise and cancel anything this
            // connection still has queued or streaming.
            let bytes = line.as_bytes();
            let cut = 1 + self.rng.below(bytes.len().saturating_sub(1).max(1));
            self.sock.write_all(&bytes[..cut.min(bytes.len())])?;
            let _ = self.sock.flush();
            let _ = self.sock.shutdown(Shutdown::Both);
            self.disconnected = true;
            return Ok(false);
        }
        if self.rng.f64() < self.plan.tear_writes {
            let mut rest = line.as_bytes();
            while !rest.is_empty() {
                let take = 1 + self.rng.below(rest.len());
                self.sock.write_all(&rest[..take])?;
                self.sock.flush()?;
                rest = &rest[take..];
                if !rest.is_empty() && self.plan.fragment_delay_us > 0 {
                    std::thread::sleep(Duration::from_micros(self.plan.fragment_delay_us));
                }
            }
            self.sock.write_all(b"\n")?;
        } else {
            self.sock.write_all(line.as_bytes())?;
            self.sock.write_all(b"\n")?;
        }
        self.sent += 1;
        Ok(true)
    }

    /// One standalone garbage line: never valid JSON-with-a-known-type,
    /// never containing an interior newline, so the server must answer
    /// `err` and resynchronise on the next real line.
    fn garbage_line(&mut self) -> String {
        match self.rng.below(4) {
            0 => "}{not json at all".to_string(),
            1 => "{\"type\":\"req\",\"id\":".to_string(),
            2 => {
                let n = 1 + self.rng.below(32);
                let mut s = String::with_capacity(n);
                for _ in 0..n {
                    // Printable-ish noise plus the odd control byte the
                    // UTF-8 check still accepts.
                    let c = (0x20 + self.rng.below(0x5e)) as u8 as char;
                    s.push(c);
                }
                s
            }
            _ => "{\"id\":true,\"robot\":7,\"route\":[],\"type\":\"req\"}".to_string(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Read;
    use std::net::TcpListener;

    fn pump(plan: FaultPlan, lines: &[&str]) -> Vec<u8> {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let sink = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let mut got = Vec::new();
            let _ = s.read_to_end(&mut got);
            got
        });
        let mut c = FaultyClient::connect(addr, plan).unwrap();
        for l in lines {
            let _ = c.send_line(l).unwrap();
        }
        drop(c);
        sink.join().unwrap()
    }

    /// The same plan (same seed) against the same lines yields an
    /// identical byte stream — faults are reproducible, not flaky.
    #[test]
    fn same_seed_same_bytes() {
        let plan = FaultPlan { fragment_delay_us: 0, ..FaultPlan::default() };
        let lines = ["{\"id\":1,\"type\":\"req\"}", "{\"id\":2,\"type\":\"req\"}"];
        let a = pump(plan.clone(), &lines);
        let b = pump(plan.clone(), &lines);
        assert!(!a.is_empty());
        assert_eq!(a, b, "seeded fault plan must be byte-deterministic");
        // A different seed takes a different path.
        let c = pump(FaultPlan { seed: 99, ..plan }, &lines);
        assert_ne!(a, c);
    }

    /// `disconnect_after` cuts mid-line exactly once, then every send
    /// is a no-op.
    #[test]
    fn disconnects_once_mid_line() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let sink = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let mut got = Vec::new();
            let _ = s.read_to_end(&mut got);
            got
        });
        let plan = FaultPlan {
            garbage_every: 0.0,
            tear_writes: 0.0,
            disconnect_after: 2,
            ..FaultPlan::default()
        };
        let mut c = FaultyClient::connect(addr, plan).unwrap();
        let line = "{\"id\":1,\"route\":\"fd\",\"type\":\"req\"}";
        assert!(c.send_line(line).unwrap(), "first send is intact");
        assert!(!c.send_line(line).unwrap(), "second send disconnects");
        assert!(c.disconnected());
        assert_eq!(c.sent(), 1);
        assert!(!c.send_line(line).unwrap(), "after disconnect: no-op");
        let got = sink.join().unwrap();
        // One full line, then a strict prefix of the second.
        let nl = got.iter().position(|&b| b == b'\n').unwrap();
        assert_eq!(&got[..nl], line.as_bytes());
        let tail = &got[nl + 1..];
        assert!(tail.len() < line.len(), "second line must be torn");
        assert_eq!(tail, &line.as_bytes()[..tail.len()]);
    }
}
