//! Lazy hot-field request parsing.
//!
//! The serving front-end only needs a handful of fields to *route* a
//! request — `robot`, `route`, `class`, `deadline_us`, `id` — while the
//! payload arrays (`ops`, `q0`, `qd0`, `tau`) dominate the line's byte
//! count. Building a full [`Json`](crate::util::json::Json) tree heap-
//! allocates every number twice (tree node + later flat vector).
//! [`LazyReq::scan`] instead makes one pass over the top-level object,
//! decoding only the hot scalar fields and recording the payload values
//! as *byte spans* into the original line; [`parse_f32_array`] /
//! [`parse_f32_matrix`] then convert a span straight into the flat
//! `Vec<f32>` the batcher wants.
//!
//! Agreement contract (checked by tests here and by `draco replay` on
//! every captured corpus line): for any line the full parser accepts,
//! the lazy scanner extracts identical field values, with one narrowing
//! — hot *string* fields must be escape-free (robot names, routes and
//! classes are plain identifiers; a `\u`-escaped robot name is a scan
//! error, not a silent mismatch). Numbers are parsed text → f64 → f32,
//! the same pipeline the full parser uses, so payloads agree bitwise.

/// Cursor over the raw line bytes.
struct Scan<'a> {
    b: &'a [u8],
    i: usize,
}

/// Hot fields of a `req` line, payload arrays left as unparsed spans.
#[derive(Debug, Default, Clone, PartialEq)]
pub struct LazyReq<'a> {
    /// Frame type tag (callers expect `"req"`).
    pub typ: &'a str,
    /// Request id.
    pub id: u64,
    /// Target robot name.
    pub robot: Option<&'a str>,
    /// Route tag.
    pub route: Option<&'a str>,
    /// QoS class override.
    pub class: Option<&'a str>,
    /// Relative deadline [µs].
    pub deadline_us: Option<u64>,
    /// Integration step [s] (trajectory requests).
    pub dt: Option<f64>,
    /// Unparsed span of the `ops` matrix.
    pub ops: Option<&'a str>,
    /// Unparsed span of the `q0` array.
    pub q0: Option<&'a str>,
    /// Unparsed span of the `qd0` array.
    pub qd0: Option<&'a str>,
    /// Unparsed span of the `tau` array.
    pub tau: Option<&'a str>,
}

impl<'a> Scan<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    /// Consume a string token and return its raw contents (between the
    /// quotes, escapes NOT decoded — hot fields must be escape-free).
    fn string_raw(&mut self, src: &'a str) -> Result<&'a str, String> {
        self.expect(b'"')?;
        let start = self.i;
        while let Some(c) = self.peek() {
            match c {
                b'"' => {
                    let s = &src[start..self.i];
                    self.i += 1;
                    return Ok(s);
                }
                b'\\' => {
                    // Skip the escape introducer and its single-byte
                    // tail; \uXXXX tails are ASCII hex so byte-wise
                    // skipping stays inside the string.
                    self.i += 2;
                }
                _ => self.i += 1,
            }
        }
        Err("unterminated string".into())
    }

    /// Skip one JSON value of any type, strings-and-nesting aware.
    fn skip_value(&mut self) -> Result<(), String> {
        match self.peek().ok_or("unexpected end of line")? {
            b'"' => {
                // Reuse the raw-string walk; contents discarded.
                let src = core::str::from_utf8(self.b).map_err(|_| "invalid UTF-8")?;
                self.string_raw(src)?;
                Ok(())
            }
            b'{' | b'[' => {
                let mut depth = 0usize;
                while let Some(c) = self.peek() {
                    match c {
                        b'{' | b'[' => {
                            depth += 1;
                            self.i += 1;
                        }
                        b'}' | b']' => {
                            depth -= 1;
                            self.i += 1;
                            if depth == 0 {
                                return Ok(());
                            }
                        }
                        b'"' => {
                            let src =
                                core::str::from_utf8(self.b).map_err(|_| "invalid UTF-8")?;
                            self.string_raw(src)?;
                        }
                        _ => self.i += 1,
                    }
                }
                Err("unterminated container".into())
            }
            b't' => self.literal(b"true"),
            b'f' => self.literal(b"false"),
            b'n' => self.literal(b"null"),
            b'-' | b'0'..=b'9' => {
                while let Some(c) = self.peek() {
                    if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                        self.i += 1;
                    } else {
                        break;
                    }
                }
                Ok(())
            }
            other => Err(format!("unexpected byte '{}' at {}", other as char, self.i)),
        }
    }

    fn literal(&mut self, lit: &[u8]) -> Result<(), String> {
        if self.b[self.i..].starts_with(lit) {
            self.i += lit.len();
            Ok(())
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }
}

/// Decode an unsigned integer span the way the full parser does
/// (f64 parse, then an exact-integer check).
fn span_u64(span: &str) -> Result<u64, String> {
    let n: f64 = span.trim().parse().map_err(|_| format!("'{span}' is not a number"))?;
    if n >= 0.0 && n.fract() == 0.0 && n <= u64::MAX as f64 {
        Ok(n as u64)
    } else {
        Err(format!("'{span}' is not an unsigned integer"))
    }
}

fn unquote(span: &str) -> Result<&str, String> {
    let inner = span
        .trim()
        .strip_prefix('"')
        .and_then(|s| s.strip_suffix('"'))
        .ok_or_else(|| format!("'{span}' is not a string"))?;
    if inner.contains('\\') {
        Err(format!("hot string field contains escapes: '{span}'"))
    } else {
        Ok(inner)
    }
}

impl<'a> LazyReq<'a> {
    /// Single-pass scan of one request line. Hot scalar fields are
    /// decoded; payload arrays are kept as spans; unknown keys are
    /// skipped structurally.
    pub fn scan(line: &'a str) -> Result<LazyReq<'a>, String> {
        let mut s = Scan { b: line.as_bytes(), i: 0 };
        let mut out = LazyReq::default();
        s.ws();
        s.expect(b'{')?;
        s.ws();
        if s.peek() == Some(b'}') {
            s.i += 1;
        } else {
            loop {
                s.ws();
                let key = s.string_raw(line)?;
                s.ws();
                s.expect(b':')?;
                s.ws();
                let vstart = s.i;
                s.skip_value()?;
                let span = &line[vstart..s.i];
                match key {
                    "type" => out.typ = unquote(span)?,
                    "id" => out.id = span_u64(span)?,
                    "robot" => out.robot = Some(unquote(span)?),
                    "route" => out.route = Some(unquote(span)?),
                    "class" => out.class = Some(unquote(span)?),
                    "deadline_us" => out.deadline_us = Some(span_u64(span)?),
                    "dt" => {
                        out.dt = Some(
                            span.trim()
                                .parse::<f64>()
                                .map_err(|_| format!("dt '{span}' is not a number"))?,
                        );
                    }
                    "ops" => out.ops = Some(span),
                    "q0" => out.q0 = Some(span),
                    "qd0" => out.qd0 = Some(span),
                    "tau" => out.tau = Some(span),
                    _ => {}
                }
                s.ws();
                match s.peek() {
                    Some(b',') => s.i += 1,
                    Some(b'}') => {
                        s.i += 1;
                        break;
                    }
                    _ => return Err(format!("expected ',' or '}}' at byte {}", s.i)),
                }
            }
        }
        s.ws();
        if s.i != s.b.len() {
            return Err(format!("trailing bytes after object at byte {}", s.i));
        }
        if out.typ.is_empty() {
            return Err("frame has no \"type\"".into());
        }
        Ok(out)
    }
}

/// Parse a recorded array span (e.g. `[1.5,-2,null]`) straight into a
/// flat f32 vector. Numbers go text → f64 → f32, identical to the full
/// parser's pipeline, so values agree bitwise; `null` becomes NaN.
pub fn parse_f32_array(span: &str) -> Result<Vec<f32>, String> {
    let mut s = Scan { b: span.as_bytes(), i: 0 };
    let mut out = Vec::new();
    parse_f32_array_at(&mut s, span, &mut out)?;
    s.ws();
    if s.i != s.b.len() {
        return Err("trailing bytes after array".into());
    }
    Ok(out)
}

fn parse_f32_array_at(s: &mut Scan<'_>, src: &str, out: &mut Vec<f32>) -> Result<(), String> {
    s.ws();
    s.expect(b'[')?;
    s.ws();
    if s.peek() == Some(b']') {
        s.i += 1;
        return Ok(());
    }
    loop {
        s.ws();
        if s.b[s.i..].starts_with(b"null") {
            out.push(f32::NAN);
            s.i += 4;
        } else {
            let start = s.i;
            while let Some(c) = s.peek() {
                if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                    s.i += 1;
                } else {
                    break;
                }
            }
            let tok = &src[start..s.i];
            let v: f64 = tok.parse().map_err(|_| format!("'{tok}' is not a number"))?;
            out.push(v as f32);
        }
        s.ws();
        match s.peek() {
            Some(b',') => s.i += 1,
            Some(b']') => {
                s.i += 1;
                return Ok(());
            }
            _ => return Err(format!("expected ',' or ']' at byte {}", s.i)),
        }
    }
}

/// Parse a recorded matrix span (array of arrays) into row vectors.
pub fn parse_f32_matrix(span: &str) -> Result<Vec<Vec<f32>>, String> {
    let mut s = Scan { b: span.as_bytes(), i: 0 };
    s.ws();
    s.expect(b'[')?;
    s.ws();
    let mut rows = Vec::new();
    if s.peek() == Some(b']') {
        s.i += 1;
    } else {
        loop {
            let mut row = Vec::new();
            parse_f32_array_at(&mut s, span, &mut row)?;
            rows.push(row);
            s.ws();
            match s.peek() {
                Some(b',') => s.i += 1,
                Some(b']') => {
                    s.i += 1;
                    break;
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", s.i)),
            }
        }
    }
    s.ws();
    if s.i != s.b.len() {
        return Err("trailing bytes after matrix".into());
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::frame::{req_step_line, req_traj_line, Frame};
    use crate::util::rng::Rng;

    /// Lazy scan must agree with the full Json-tree parse on every
    /// field of a generated corpus — the ISSUE acceptance property.
    #[test]
    fn lazy_scan_agrees_with_full_parse() {
        let mut rng = Rng::new(8080);
        for k in 0..64u64 {
            let n = 3 + (k as usize % 5);
            let mk = |rng: &mut Rng, len: usize| -> Vec<f32> {
                (0..len).map(|_| (rng.f64() * 4.0 - 2.0) as f32).collect()
            };
            let line = if k % 3 == 0 {
                let tau = mk(&mut rng, n * 8);
                req_traj_line(
                    k,
                    "iiwa",
                    (k % 2 == 0).then_some("bulk"),
                    (k % 4 == 0).then_some(k * 10),
                    &mk(&mut rng, n),
                    &mk(&mut rng, n),
                    &tau,
                    1e-3,
                )
            } else {
                let route = ["rnea", "fd", "minv", "dynall"][k as usize % 4];
                let ops = vec![mk(&mut rng, n), mk(&mut rng, n), mk(&mut rng, n)];
                req_step_line(k, "atlas", route, None, None, &ops)
            };
            let lazy = LazyReq::scan(&line).unwrap();
            let full = match Frame::parse(&line).unwrap() {
                Frame::Req(r) => r,
                other => panic!("expected req, got {other:?}"),
            };
            assert_eq!(lazy.typ, "req");
            assert_eq!(lazy.id, full.id);
            assert_eq!(lazy.robot.unwrap(), full.robot);
            assert_eq!(lazy.route.unwrap(), full.route);
            assert_eq!(lazy.class.map(str::to_string), full.class);
            assert_eq!(lazy.deadline_us, full.deadline_us);
            assert_eq!(lazy.dt, full.dt);
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            match (lazy.ops, full.ops) {
                (Some(span), Some(mat)) => {
                    let lm = parse_f32_matrix(span).unwrap();
                    assert_eq!(lm.len(), mat.len());
                    for (a, b) in lm.iter().zip(&mat) {
                        assert_eq!(bits(a), bits(b));
                    }
                }
                (None, None) => {}
                other => panic!("ops presence disagrees: {other:?}"),
            }
            for (span, arr) in [(lazy.q0, full.q0), (lazy.qd0, full.qd0), (lazy.tau, full.tau)] {
                match (span, arr) {
                    (Some(sp), Some(a)) => {
                        assert_eq!(bits(&parse_f32_array(sp).unwrap()), bits(&a));
                    }
                    (None, None) => {}
                    other => panic!("array presence disagrees: {other:?}"),
                }
            }
        }
    }

    #[test]
    fn scan_skips_unknown_keys_and_nested_values() {
        let line = r#"{"extra":{"a":[1,{"b":"}]"}],"c":null},"id":3,"robot":"iiwa","route":"fd","type":"req","z":"tail"}"#;
        let r = LazyReq::scan(line).unwrap();
        assert_eq!(r.id, 3);
        assert_eq!(r.robot, Some("iiwa"));
        assert_eq!(r.route, Some("fd"));
    }

    #[test]
    fn malformed_lines_error_not_panic() {
        let bad = [
            "",
            "{",
            "[1,2,3]",
            "{\"id\":}",
            "{\"id\":1",
            "{\"id\":1} trailing",
            "{\"type\":\"req\",\"id\":\"x\"}",
            "{\"robot\":\"a\\\"b\",\"type\":\"req\"}", // escaped hot field
            "{\"id\":1,\"type\":\"req\"}{}",
            "{\"unterminated\":\"abc",
        ];
        for line in bad {
            assert!(LazyReq::scan(line).is_err(), "accepted: {line}");
        }
        assert!(parse_f32_array("[1,2,").is_err());
        assert!(parse_f32_array("[1,2]x").is_err());
        assert!(parse_f32_matrix("[[1],[2]").is_err());
    }
}
