//! Typed JSONL wire frames.
//!
//! Every frame is one JSON object on one line, tagged by a `"type"`
//! field. Client → server traffic is a single frame type (`req`); the
//! server answers with an event stream per request id:
//!
//! * `ack` — the request passed admission and was enqueued.
//! * `rejected` / `shed` / `expired` — the structured QoS refusals from
//!   [`ServeError`], carrying the same retry hints as the in-process
//!   API (`retry_after_us`, queue depth, breaker failure count, waited
//!   time).
//! * `chunk` — one flat f32 payload fragment with a per-request
//!   sequence number. Step answers are one chunk (`dyn_all` splits into
//!   its three segments q̈ | M⁻¹ | C); trajectory responses are one
//!   chunk per integrated row `q_t ‖ q̇_t`, flushed mid-horizon.
//! * `done` — terminal success, naming the chunk count.
//! * `err` — terminal failure with a message (engine errors, malformed
//!   frames, unknown routes).
//!
//! Writers are hand-rolled (alphabetical keys, matching the
//! deterministic [`Json`] object serialization) because chunk egress is
//! the serving hot path; parsing goes through the full [`Json`] tree —
//! the *lazy* request path lives in [`super::lazy`]. f32 payloads are
//! written with the shortest round-trip decimal (`{}` formatting), so
//! text → f64 → f32 recovers every value bitwise; non-finite values
//! serialize as `null` and parse back as NaN (JSON has no Inf/NaN).

use crate::coordinator::ServeError;
use crate::util::json::Json;
use std::fmt::Write as _;

/// One parsed wire frame (any direction).
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// Session header, first line of a tee log: enough to rebuild the
    /// serving registry for an offline replay.
    Hello {
        /// The `--robots` registry spec the server was started with.
        spec: String,
        /// Per-route batch size.
        batch: usize,
        /// Batching window [µs].
        window_us: u64,
    },
    /// A client request (step or trajectory).
    Req(NetReq),
    /// Request admitted and enqueued.
    Ack {
        /// Request id this acknowledges.
        id: u64,
    },
    /// Admission refusal: class queue full ([`ServeError::Rejected`]).
    Rejected {
        /// Request id.
        id: u64,
        /// Class whose queue was full.
        class: String,
        /// Queue depth observed at admission.
        depth: usize,
        /// Retry hint [µs].
        retry_after_us: u64,
    },
    /// Circuit breaker open ([`ServeError::Shed`]).
    Shed {
        /// Request id.
        id: u64,
        /// Consecutive batch failures that opened the breaker.
        consecutive_failures: u32,
        /// Retry hint [µs].
        retry_after_us: u64,
    },
    /// Deadline passed while queued ([`ServeError::Expired`]).
    Expired {
        /// Request id.
        id: u64,
        /// The deadline the request carried [µs].
        deadline_us: u64,
        /// How long it actually waited [µs].
        waited_us: u64,
    },
    /// One payload fragment.
    Chunk {
        /// Request id.
        id: u64,
        /// 0-based fragment sequence number within the request.
        seq: u64,
        /// Flat f32 payload values.
        data: Vec<f32>,
    },
    /// Terminal success.
    Done {
        /// Request id.
        id: u64,
        /// Total `chunk` frames sent for this request.
        chunks: u64,
    },
    /// Terminal failure.
    Err {
        /// Request id (`0` when the line was too malformed to carry one).
        id: u64,
        /// Human-readable reason.
        msg: String,
    },
    /// Live metrics snapshot, answering a `stats` request: every
    /// counter and gauge of the server's observability hub plus the
    /// terminal serving counters, as flat name → integer maps.
    Stats {
        /// Request id this answers.
        id: u64,
        /// Counter values by metric name.
        counters: std::collections::BTreeMap<String, u64>,
        /// Gauge values by metric name (includes derived histogram
        /// percentiles, pre-rounded to integer µs).
        gauges: std::collections::BTreeMap<String, u64>,
    },
}

/// A fully parsed `req` frame (the [`Json`]-tree path; the lazy scanner
/// in [`super::lazy`] extracts the same hot fields without a tree).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct NetReq {
    /// Client-chosen request id; response frames echo it.
    pub id: u64,
    /// Target robot name.
    pub robot: String,
    /// Route tag: `rnea` | `fd` | `minv` | `dynall` | `traj`.
    pub route: String,
    /// Optional QoS class override (`control`/`interactive`/`bulk`).
    pub class: Option<String>,
    /// Optional relative deadline [µs].
    pub deadline_us: Option<u64>,
    /// Step operands (arity × N), step routes only.
    pub ops: Option<Vec<Vec<f32>>>,
    /// Initial joint positions, trajectory routes only.
    pub q0: Option<Vec<f32>>,
    /// Initial joint velocities, trajectory routes only.
    pub qd0: Option<Vec<f32>>,
    /// Flat torque rows (H·N), trajectory routes only.
    pub tau: Option<Vec<f32>>,
    /// Integration step [s], trajectory routes only.
    pub dt: Option<f64>,
}

/// Append one f32 in its shortest round-trip decimal form (`null` for
/// non-finite values — the documented lossy case).
fn push_f32(out: &mut String, v: f32) {
    if v.is_finite() {
        let _ = write!(out, "{v}");
    } else {
        out.push_str("null");
    }
}

fn push_f32_arr(out: &mut String, data: &[f32]) {
    out.push('[');
    for (i, v) in data.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        push_f32(out, *v);
    }
    out.push(']');
}

/// Inject a connection-namespace tag into a captured wire line:
/// `{"x":1}` → `{"conn":N,"x":1}`. Tee-only — frames on the live socket
/// never carry it. The tag deliberately *leads* the object (the one
/// documented exception to alphabetical key order) so [`conn_tag`] can
/// extract it without parsing the rest of the line; both the lazy
/// scanner and the full parser skip unknown keys, so tagged request
/// lines stay parseable. Non-object lines pass through untouched (they
/// are counted malformed at replay anyway).
pub fn tag_conn(conn: u64, line: &str) -> String {
    match line.strip_prefix('{') {
        Some(rest) if rest.trim_start() == "}" => format!("{{\"conn\":{conn}}}"),
        Some(rest) => format!("{{\"conn\":{conn},{rest}"),
        None => line.to_string(),
    }
}

/// Extract the connection tag of a teed line, if present. Untagged
/// lines (the `hello` header, pre-namespacing captures) belong to
/// connection 0.
pub fn conn_tag(line: &str) -> Option<u64> {
    let rest = line.strip_prefix("{\"conn\":")?;
    let end = rest.find(|c: char| !c.is_ascii_digit())?;
    if end == 0 || !matches!(rest.as_bytes()[end], b',' | b'}') {
        return None;
    }
    rest[..end].parse().ok()
}

/// `hello` line (keys alphabetical, like every writer here).
pub fn hello_line(spec: &str, batch: usize, window_us: u64) -> String {
    format!(
        "{{\"batch\":{batch},\"spec\":{},\"type\":\"hello\",\"window_us\":{window_us}}}",
        Json::Str(spec.to_string()).dump()
    )
}

/// `ack` line.
pub fn ack_line(id: u64) -> String {
    format!("{{\"id\":{id},\"type\":\"ack\"}}")
}

/// `chunk` line.
pub fn chunk_line(id: u64, seq: u64, data: &[f32]) -> String {
    let mut s = String::with_capacity(48 + 12 * data.len());
    s.push_str("{\"data\":");
    push_f32_arr(&mut s, data);
    let _ = write!(s, ",\"id\":{id},\"seq\":{seq},\"type\":\"chunk\"}}");
    s
}

/// `done` line.
pub fn done_line(id: u64, chunks: u64) -> String {
    format!("{{\"chunks\":{chunks},\"id\":{id},\"type\":\"done\"}}")
}

/// `err` line (message JSON-escaped).
pub fn err_line(id: u64, msg: &str) -> String {
    format!("{{\"id\":{id},\"msg\":{},\"type\":\"err\"}}", Json::Str(msg.to_string()).dump())
}

/// Client → server `stats` request line.
pub fn stats_req_line(id: u64) -> String {
    format!("{{\"id\":{id},\"type\":\"stats\"}}")
}

/// Server → client `stats` snapshot line. Built through the [`Json`]
/// tree (the stats route is cold — determinism over speed): keys come
/// out alphabetical like every hand-rolled writer here.
pub fn stats_line(
    id: u64,
    counters: &std::collections::BTreeMap<String, u64>,
    gauges: &std::collections::BTreeMap<String, u64>,
) -> String {
    let to_obj = |m: &std::collections::BTreeMap<String, u64>| {
        Json::Obj(m.iter().map(|(k, v)| (k.clone(), Json::Num(*v as f64))).collect())
    };
    let mut obj = std::collections::BTreeMap::new();
    obj.insert("counters".to_string(), to_obj(counters));
    obj.insert("gauges".to_string(), to_obj(gauges));
    obj.insert("id".to_string(), Json::Num(id as f64));
    obj.insert("type".to_string(), Json::Str("stats".to_string()));
    Json::Obj(obj).dump()
}

/// Map a [`ServeError`] to its wire frame: the three structured QoS
/// refusals keep their fields (the retry hints cross the wire intact);
/// everything else becomes an `err` frame with the display message.
pub fn serve_error_line(id: u64, err: &ServeError) -> String {
    match err {
        ServeError::Rejected { class, depth, retry_after_us } => format!(
            "{{\"class\":\"{}\",\"depth\":{depth},\"id\":{id},\"retry_after_us\":{retry_after_us},\"type\":\"rejected\"}}",
            class.name()
        ),
        ServeError::Shed { consecutive_failures, retry_after_us } => format!(
            "{{\"consecutive_failures\":{consecutive_failures},\"id\":{id},\"retry_after_us\":{retry_after_us},\"type\":\"shed\"}}"
        ),
        ServeError::Expired { deadline_us, waited_us } => format!(
            "{{\"deadline_us\":{deadline_us},\"id\":{id},\"type\":\"expired\",\"waited_us\":{waited_us}}}"
        ),
        other => err_line(id, &other.to_string()),
    }
}

/// Build a step `req` line.
pub fn req_step_line(
    id: u64,
    robot: &str,
    route: &str,
    class: Option<&str>,
    deadline_us: Option<u64>,
    ops: &[Vec<f32>],
) -> String {
    let mut s = String::with_capacity(64 + ops.iter().map(|o| 12 * o.len() + 2).sum::<usize>());
    s.push('{');
    if let Some(c) = class {
        let _ = write!(s, "\"class\":\"{c}\",");
    }
    if let Some(d) = deadline_us {
        let _ = write!(s, "\"deadline_us\":{d},");
    }
    let _ = write!(s, "\"id\":{id},\"ops\":[");
    for (i, op) in ops.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        push_f32_arr(&mut s, op);
    }
    let _ = write!(
        s,
        "],\"robot\":{},\"route\":\"{route}\",\"type\":\"req\"}}",
        Json::Str(robot.to_string()).dump()
    );
    s
}

/// Build a trajectory `req` line.
#[allow(clippy::too_many_arguments)]
pub fn req_traj_line(
    id: u64,
    robot: &str,
    class: Option<&str>,
    deadline_us: Option<u64>,
    q0: &[f32],
    qd0: &[f32],
    tau: &[f32],
    dt: f64,
) -> String {
    let mut s = String::with_capacity(96 + 12 * (q0.len() + qd0.len() + tau.len()));
    s.push('{');
    if let Some(c) = class {
        let _ = write!(s, "\"class\":\"{c}\",");
    }
    if let Some(d) = deadline_us {
        let _ = write!(s, "\"deadline_us\":{d},");
    }
    let _ = write!(s, "\"dt\":{dt},\"id\":{id},\"q0\":");
    push_f32_arr(&mut s, q0);
    s.push_str(",\"qd0\":");
    push_f32_arr(&mut s, qd0);
    let _ = write!(s, ",\"robot\":{},\"route\":\"traj\",\"tau\":", Json::Str(robot.to_string()).dump());
    push_f32_arr(&mut s, tau);
    s.push_str(",\"type\":\"req\"}");
    s
}

fn get_u64(v: &Json, key: &str) -> Option<u64> {
    let n = v.get(key)?.as_f64()?;
    (n >= 0.0 && n.fract() == 0.0).then_some(n as u64)
}

/// Parse a JSON f32 array; `null` elements become NaN (matching the
/// writer's lossy non-finite case).
fn f32_vec(v: &Json) -> Option<Vec<f32>> {
    v.as_arr()?
        .iter()
        .map(|x| match x {
            Json::Null => Some(f32::NAN),
            _ => x.as_f64().map(|n| n as f32),
        })
        .collect()
}

impl Frame {
    /// Parse one wire line through the full [`Json`] parser.
    pub fn parse(line: &str) -> Result<Frame, String> {
        let v = Json::parse(line).map_err(|e| e.to_string())?;
        let typ = v.get("type").and_then(Json::as_str).ok_or("frame has no \"type\"")?;
        let id = || get_u64(&v, "id").ok_or_else(|| format!("{typ} frame has no integer \"id\""));
        match typ {
            "hello" => Ok(Frame::Hello {
                spec: v.get("spec").and_then(Json::as_str).ok_or("hello has no spec")?.into(),
                batch: v.get("batch").and_then(Json::as_usize).ok_or("hello has no batch")?,
                window_us: get_u64(&v, "window_us").ok_or("hello has no window_us")?,
            }),
            "req" => {
                let ops = match v.get("ops") {
                    None => None,
                    Some(a) => Some(
                        a.as_arr()
                            .ok_or("ops is not an array")?
                            .iter()
                            .map(|op| f32_vec(op).ok_or("ops row is not a number array"))
                            .collect::<Result<Vec<_>, _>>()?,
                    ),
                };
                let arr = |key: &str| -> Result<Option<Vec<f32>>, String> {
                    match v.get(key) {
                        None => Ok(None),
                        Some(a) => Ok(Some(
                            f32_vec(a).ok_or_else(|| format!("{key} is not a number array"))?,
                        )),
                    }
                };
                Ok(Frame::Req(NetReq {
                    id: id()?,
                    robot: v.get("robot").and_then(Json::as_str).unwrap_or("").into(),
                    route: v.get("route").and_then(Json::as_str).unwrap_or("").into(),
                    class: v.get("class").and_then(Json::as_str).map(str::to_string),
                    deadline_us: get_u64(&v, "deadline_us"),
                    ops,
                    q0: arr("q0")?,
                    qd0: arr("qd0")?,
                    tau: arr("tau")?,
                    dt: v.get("dt").and_then(Json::as_f64),
                }))
            }
            "ack" => Ok(Frame::Ack { id: id()? }),
            "rejected" => Ok(Frame::Rejected {
                id: id()?,
                class: v.get("class").and_then(Json::as_str).unwrap_or("").into(),
                depth: v.get("depth").and_then(Json::as_usize).unwrap_or(0),
                retry_after_us: get_u64(&v, "retry_after_us").unwrap_or(0),
            }),
            "shed" => Ok(Frame::Shed {
                id: id()?,
                consecutive_failures: get_u64(&v, "consecutive_failures").unwrap_or(0) as u32,
                retry_after_us: get_u64(&v, "retry_after_us").unwrap_or(0),
            }),
            "expired" => Ok(Frame::Expired {
                id: id()?,
                deadline_us: get_u64(&v, "deadline_us").unwrap_or(0),
                waited_us: get_u64(&v, "waited_us").unwrap_or(0),
            }),
            "chunk" => Ok(Frame::Chunk {
                id: id()?,
                seq: get_u64(&v, "seq").ok_or("chunk has no seq")?,
                data: v.get("data").and_then(f32_vec).ok_or("chunk has no data array")?,
            }),
            "done" => Ok(Frame::Done { id: id()?, chunks: get_u64(&v, "chunks").unwrap_or(0) }),
            "err" => Ok(Frame::Err {
                id: id()?,
                msg: v.get("msg").and_then(Json::as_str).unwrap_or("").into(),
            }),
            "stats" => {
                let map = |key: &str| -> std::collections::BTreeMap<String, u64> {
                    match v.get(key) {
                        Some(Json::Obj(m)) => m
                            .iter()
                            .filter_map(|(k, x)| {
                                x.as_f64().map(|n| (k.clone(), n.max(0.0) as u64))
                            })
                            .collect(),
                        _ => Default::default(),
                    }
                };
                Ok(Frame::Stats { id: id()?, counters: map("counters"), gauges: map("gauges") })
            }
            other => Err(format!("unknown frame type '{other}'")),
        }
    }

    /// The request id this frame refers to (`None` for `hello`).
    pub fn id(&self) -> Option<u64> {
        match self {
            Frame::Hello { .. } => None,
            Frame::Req(r) => Some(r.id),
            Frame::Ack { id }
            | Frame::Rejected { id, .. }
            | Frame::Shed { id, .. }
            | Frame::Expired { id, .. }
            | Frame::Chunk { id, .. }
            | Frame::Done { id, .. }
            | Frame::Err { id, .. }
            | Frame::Stats { id, .. } => Some(*id),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::QosClass;

    #[test]
    fn response_frames_round_trip() {
        let cases = vec![
            (ack_line(7), Frame::Ack { id: 7 }),
            (done_line(7, 32), Frame::Done { id: 7, chunks: 32 }),
            (
                chunk_line(9, 2, &[1.5, -0.25, 3.0e-7]),
                Frame::Chunk { id: 9, seq: 2, data: vec![1.5, -0.25, 3.0e-7] },
            ),
            (err_line(1, "bad \"x\"\n"), Frame::Err { id: 1, msg: "bad \"x\"\n".into() }),
            (
                serve_error_line(
                    3,
                    &ServeError::Rejected {
                        class: QosClass::Bulk,
                        depth: 12,
                        retry_after_us: 400,
                    },
                ),
                Frame::Rejected { id: 3, class: "bulk".into(), depth: 12, retry_after_us: 400 },
            ),
            (
                serve_error_line(
                    4,
                    &ServeError::Shed { consecutive_failures: 5, retry_after_us: 100_000 },
                ),
                Frame::Shed { id: 4, consecutive_failures: 5, retry_after_us: 100_000 },
            ),
            (
                serve_error_line(5, &ServeError::Expired { deadline_us: 10, waited_us: 220 }),
                Frame::Expired { id: 5, deadline_us: 10, waited_us: 220 },
            ),
            (
                hello_line("iiwa,atlas:qint@12.14", 8, 200),
                Frame::Hello { spec: "iiwa,atlas:qint@12.14".into(), batch: 8, window_us: 200 },
            ),
        ];
        for (line, want) in cases {
            assert_eq!(Frame::parse(&line).unwrap(), want, "{line}");
        }
    }

    /// `stats` frames round-trip: the bare request parses (empty maps),
    /// and a snapshot line recovers every counter and gauge.
    #[test]
    fn stats_frames_round_trip() {
        match Frame::parse(&stats_req_line(42)).unwrap() {
            Frame::Stats { id, counters, gauges } => {
                assert_eq!(id, 42);
                assert!(counters.is_empty());
                assert!(gauges.is_empty());
            }
            other => panic!("expected stats, got {other:?}"),
        }
        let mut counters = std::collections::BTreeMap::new();
        counters.insert("serve_completed".to_string(), 128u64);
        counters.insert("net_malformed_lines_total".to_string(), 3u64);
        let mut gauges = std::collections::BTreeMap::new();
        gauges.insert("net_egress_queue_highwater".to_string(), 17u64);
        let line = stats_line(9, &counters, &gauges);
        assert!(line.starts_with("{\"counters\":"), "alphabetical keys: {line}");
        assert_eq!(
            Frame::parse(&line).unwrap(),
            Frame::Stats { id: 9, counters, gauges },
            "{line}"
        );
    }

    /// Every f32 bit pattern that is finite must survive text framing
    /// bitwise — the property the replay comparison rests on.
    #[test]
    fn f32_payloads_round_trip_bitwise() {
        let vals: Vec<f32> = vec![
            0.0,
            -0.0,
            1.0,
            -1.5,
            f32::MIN_POSITIVE,
            f32::MAX,
            f32::MIN,
            1.0e-45,        // smallest subnormal
            3.4028233e38,   // near MAX
            0.1,
            -0.30000001,
            core::f32::consts::PI,
        ];
        let line = chunk_line(1, 0, &vals);
        match Frame::parse(&line).unwrap() {
            Frame::Chunk { data, .. } => {
                assert_eq!(data.len(), vals.len());
                for (a, b) in data.iter().zip(&vals) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{b} corrupted to {a}");
                }
            }
            other => panic!("expected chunk, got {other:?}"),
        }
    }

    /// Connection tags round-trip through tag/extract, tagged frames
    /// still parse (unknown keys are skipped), and untagged lines read
    /// back as connection 0 at the caller's default.
    #[test]
    fn conn_tags_round_trip_and_stay_parseable() {
        let line = ack_line(7);
        let tagged = tag_conn(3, &line);
        assert_eq!(tagged, "{\"conn\":3,\"id\":7,\"type\":\"ack\"}");
        assert_eq!(conn_tag(&tagged), Some(3));
        assert_eq!(conn_tag(&line), None, "untagged lines have no tag");
        assert_eq!(Frame::parse(&tagged).unwrap(), Frame::Ack { id: 7 }, "tag is skipped");
        // Request lines survive tagging for both parsers.
        let req = req_step_line(11, "iiwa", "fd", None, None, &[vec![1.5f32; 2]]);
        let tagged = tag_conn(42, &req);
        assert_eq!(conn_tag(&tagged), Some(42));
        match Frame::parse(&tagged).unwrap() {
            Frame::Req(r) => {
                assert_eq!(r.id, 11);
                assert_eq!(r.ops.unwrap(), vec![vec![1.5f32; 2]]);
            }
            other => panic!("expected req, got {other:?}"),
        }
        let lazy = crate::net::LazyReq::scan(&tagged).expect("lazy scan skips the tag");
        assert_eq!(lazy.id, 11);
        assert_eq!(lazy.robot, Some("iiwa"));
        // Degenerate inputs: empty object, non-object garbage.
        assert_eq!(tag_conn(1, "{}"), "{\"conn\":1}");
        assert_eq!(conn_tag("{\"conn\":1}"), Some(1));
        assert_eq!(tag_conn(1, "not json"), "not json");
        assert_eq!(conn_tag("{\"conn\":x}"), None);
        assert_eq!(conn_tag("{\"connive\":3}"), None);
    }

    #[test]
    fn req_lines_parse_back() {
        let ops = vec![vec![0.5f32; 3], vec![-1.25; 3], vec![2.0; 3]];
        let line = req_step_line(11, "iiwa", "fd", Some("control"), Some(500), &ops);
        match Frame::parse(&line).unwrap() {
            Frame::Req(r) => {
                assert_eq!(r.id, 11);
                assert_eq!(r.robot, "iiwa");
                assert_eq!(r.route, "fd");
                assert_eq!(r.class.as_deref(), Some("control"));
                assert_eq!(r.deadline_us, Some(500));
                assert_eq!(r.ops.unwrap(), ops);
            }
            other => panic!("expected req, got {other:?}"),
        }
        let line = req_traj_line(12, "atlas", None, None, &[0.1; 4], &[0.0; 4], &[0.2; 8], 1e-3);
        match Frame::parse(&line).unwrap() {
            Frame::Req(r) => {
                assert_eq!(r.route, "traj");
                assert_eq!(r.q0.unwrap().len(), 4);
                assert_eq!(r.tau.unwrap().len(), 8);
                assert_eq!(r.dt, Some(1e-3));
            }
            other => panic!("expected req, got {other:?}"),
        }
    }
}
