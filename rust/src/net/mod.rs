//! Streaming JSONL network front-end.
//!
//! Wire model: one TCP connection per client carrying newline-delimited
//! JSON frames in both directions (grammar in `docs/serving.md`). A
//! request names a robot, a route, and optionally a QoS class and
//! deadline; the server answers with an event stream per request id —
//! `ack` on admission, zero or more `chunk` payload frames, then
//! exactly one terminal frame (`done`, a structured refusal carrying
//! PR 6's retry hints, or `err`). Trajectory and `dyn_all` responses
//! are *chunked*: rows hit the socket as the integrator produces them,
//! so a client consumes `q_t ‖ q̇_t` while the remaining horizon is
//! still being computed.
//!
//! Layers:
//!
//! * [`frame`] — typed frames, deterministic writers (alphabetical
//!   keys, shortest-round-trip f32 text), full-tree parser.
//! * [`lazy`] — single-pass hot-field scanner used on the request path;
//!   payload arrays stay byte spans until the batcher needs them.
//! * [`server`] — the TCP listener, per-connection reader, socket-
//!   backed [`ResponseSink`](crate::coordinator::ResponseSink), raw
//!   JSONL tee, and an end-to-end self-drive smoke.
//! * [`replay`] — offline re-execution of a tee capture with bitwise
//!   payload comparison (`draco replay LOG`).

pub mod frame;
pub mod lazy;
pub mod replay;
pub mod server;

pub use frame::{Frame, NetReq};
pub use lazy::LazyReq;
pub use replay::{replay_cli, replay_log, ReplayReport};
pub use server::{self_drive, NetClient, NetServer, MAX_LINE_BYTES};
