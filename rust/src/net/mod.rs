//! Streaming JSONL network front-end.
//!
//! Wire model: one TCP connection per client carrying newline-delimited
//! JSON frames in both directions (grammar in `docs/serving.md`). A
//! request names a robot, a route, and optionally a QoS class and
//! deadline; the server answers with an event stream per request id —
//! `ack` on admission, zero or more `chunk` payload frames, then
//! exactly one terminal frame (`done`, a structured refusal carrying
//! PR 6's retry hints, or `err`). Trajectory and `dyn_all` responses
//! are *chunked*: rows hit the socket as the integrator produces them,
//! so a client consumes `q_t ‖ q̇_t` while the remaining horizon is
//! still being computed.
//!
//! Request ids are namespaced *per connection*: two clients may use
//! overlapping ids freely, and tee captures tag every line with its
//! connection (`{"conn":N,…}`) so replay keeps them separate.
//!
//! Layers:
//!
//! * [`frame`] — typed frames, deterministic writers (alphabetical
//!   keys, shortest-round-trip f32 text), full-tree parser, connection
//!   tagging for multi-client captures.
//! * [`lazy`] — single-pass hot-field scanner used on the request path;
//!   payload arrays stay byte spans until the batcher needs them.
//! * [`server`] — the TCP listener, per-connection reader + bounded
//!   egress writer, socket-backed
//!   [`ResponseSink`](crate::coordinator::ResponseSink), raw JSONL tee
//!   (self-disabling on write error), and an end-to-end self-drive
//!   smoke. Dead connections cancel their queued and streaming work.
//! * [`chaos`] — seeded fault-injection client (garbage lines, torn
//!   writes, mid-line disconnects) for the fault suite.
//! * [`retry`] — client-side retry/backoff loop honouring the server's
//!   `retry_after_us` hints under a per-request budget.
//! * [`replay`] — offline re-execution of a tee capture (single- or
//!   multi-connection) with bitwise payload comparison
//!   (`draco replay LOG`).

pub mod chaos;
pub mod frame;
pub mod lazy;
pub mod replay;
pub mod retry;
pub mod server;

pub use chaos::{FaultPlan, FaultyClient};
pub use frame::{Frame, NetReq};
pub use lazy::LazyReq;
pub use replay::{replay_cli, replay_log, ReplayReport};
pub use retry::{RetryClient, RetryOutcome, RetryPolicy, RetryStats};
pub use server::{self_drive, NetClient, NetServer, MAX_LINE_BYTES};
