//! Retrying wire client: jittered exponential backoff under a deadline
//! budget.
//!
//! The server's refusal frames are *hints, not errors*: `rejected`
//! (queue full) and `shed` (breaker open) carry a `retry_after_us`
//! sized from the live queue depth and batching window, and `expired`
//! means the request itself waited too long. [`RetryClient`] closes the
//! loop: it resubmits on any of the three, waiting the larger of the
//! server's hint and its own exponential schedule (±jitter so N clients
//! refused together don't re-collide), and gives up with
//! [`RetryOutcome::Exhausted`] once the per-request budget cannot fund
//! the next wait. `err` frames are terminal — retrying a malformed or
//! unroutable request can never succeed.
//!
//! Every retry decision draws from a seeded [`Rng`], so a loadgen
//! scenario's retry schedule is reproducible run-to-run.

use super::frame::{self, Frame};
use super::NetClient;
use crate::util::rng::Rng;
use std::net::SocketAddr;
use std::time::Duration;

/// Backoff schedule and budget for one [`RetryClient`].
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    /// First backoff [µs].
    pub base_us: u64,
    /// Multiplier per attempt.
    pub factor: f64,
    /// Ceiling on a single backoff (and on honoured server hints) [µs].
    pub max_backoff_us: u64,
    /// Jitter fraction: the wait is scaled by a uniform draw from
    /// `[1 − jitter, 1 + jitter]`.
    pub jitter: f64,
    /// Total per-request budget across all waits [µs]; when the next
    /// wait does not fit in what remains, the client gives up.
    pub budget_us: u64,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy {
            base_us: 200,
            factor: 2.0,
            max_backoff_us: 50_000,
            jitter: 0.25,
            budget_us: 2_000_000,
        }
    }
}

/// Counters a [`RetryClient`] accumulates across requests; loadgen
/// reports them per scenario.
#[derive(Debug, Clone, Copy, Default)]
pub struct RetryStats {
    /// Resubmissions performed (first attempts not counted).
    pub retries: u64,
    /// Total time spent backing off [µs].
    pub backoff_us: u64,
}

/// Terminal result of one retried request.
#[derive(Debug, Clone, PartialEq)]
pub enum RetryOutcome {
    /// Completed: concatenated payload of every `chunk`, in order.
    Ok(Vec<f32>),
    /// Non-retryable failure (an `err` frame, e.g. bad route).
    Err(String),
    /// Retryable refusals kept coming until the backoff budget was
    /// spent; carries the last refusal's description.
    Exhausted(String),
}

/// Compute the next backoff wait [µs]: the larger of the exponential
/// schedule and the server's hint (both clamped to `max_backoff_us`),
/// scaled by the jitter draw, never zero. `attempt` counts completed
/// attempts (0 → first retry).
pub fn backoff(policy: &RetryPolicy, rng: &mut Rng, attempt: u32, hint_us: u64) -> u64 {
    let exp = (policy.base_us as f64) * policy.factor.powi(attempt as i32);
    let exp = (exp as u64).min(policy.max_backoff_us);
    let hint = hint_us.min(policy.max_backoff_us);
    let wait = exp.max(hint) as f64;
    let scale = 1.0 + policy.jitter * (2.0 * rng.f64() - 1.0);
    ((wait * scale) as u64).max(1)
}

/// What one attempt's response stream amounted to.
enum Attempt {
    Done(Vec<f32>),
    Fatal(String),
    Recoverable { hint_us: u64, what: String },
}

/// A [`NetClient`] that honours the server's retry contract. Not
/// pipelined: one request in flight at a time (frames for other ids,
/// e.g. stragglers from an abandoned attempt, are skipped).
pub struct RetryClient {
    client: NetClient,
    policy: RetryPolicy,
    rng: Rng,
    stats: RetryStats,
}

impl RetryClient {
    /// Connect to a server; `seed` fixes the jitter schedule.
    pub fn connect(
        addr: SocketAddr,
        policy: RetryPolicy,
        seed: u64,
    ) -> std::io::Result<RetryClient> {
        Ok(RetryClient {
            client: NetClient::connect(addr)?,
            policy,
            rng: Rng::new(seed),
            stats: RetryStats::default(),
        })
    }

    /// Counters accumulated so far.
    pub fn stats(&self) -> RetryStats {
        self.stats
    }

    /// Submit a step request and retry refusals until it completes, the
    /// server answers a terminal `err`, or the backoff budget runs out.
    /// `Err` is reserved for transport failures (broken socket).
    pub fn step(
        &mut self,
        id: u64,
        robot: &str,
        route: &str,
        class: Option<&str>,
        ops: &[Vec<f32>],
    ) -> std::io::Result<RetryOutcome> {
        let mut attempt: u32 = 0;
        let mut spent_us: u64 = 0;
        loop {
            self.client.send_line(&frame::req_step_line(id, robot, route, class, None, ops))?;
            let wait = match self.collect(id)? {
                Attempt::Done(payload) => return Ok(RetryOutcome::Ok(payload)),
                Attempt::Fatal(msg) => return Ok(RetryOutcome::Err(msg)),
                Attempt::Recoverable { hint_us, what } => {
                    let wait = backoff(&self.policy, &mut self.rng, attempt, hint_us);
                    if spent_us + wait > self.policy.budget_us {
                        return Ok(RetryOutcome::Exhausted(what));
                    }
                    wait
                }
            };
            attempt += 1;
            spent_us += wait;
            self.stats.retries += 1;
            self.stats.backoff_us += wait;
            std::thread::sleep(Duration::from_micros(wait));
        }
    }

    /// Read frames for `id` until its terminal frame.
    fn collect(&mut self, id: u64) -> std::io::Result<Attempt> {
        let mut payload: Vec<f32> = Vec::new();
        loop {
            match self.client.read_frame()? {
                Frame::Ack { id: got } if got == id => {}
                Frame::Chunk { id: got, data, .. } if got == id => payload.extend(data),
                Frame::Done { id: got, .. } if got == id => return Ok(Attempt::Done(payload)),
                Frame::Rejected { id: got, class, depth, retry_after_us } if got == id => {
                    return Ok(Attempt::Recoverable {
                        hint_us: retry_after_us,
                        what: format!("rejected: {class} queue full (depth {depth})"),
                    })
                }
                Frame::Shed { id: got, consecutive_failures, retry_after_us } if got == id => {
                    return Ok(Attempt::Recoverable {
                        hint_us: retry_after_us,
                        what: format!("shed: breaker open after {consecutive_failures} failures"),
                    })
                }
                Frame::Expired { id: got, deadline_us, waited_us } if got == id => {
                    return Ok(Attempt::Recoverable {
                        hint_us: 0,
                        what: format!("expired: waited {waited_us}µs against {deadline_us}µs"),
                    })
                }
                Frame::Err { id: got, msg } if got == id || got == 0 => {
                    return Ok(Attempt::Fatal(msg))
                }
                // Frames for other ids (stragglers from an abandoned
                // attempt on this connection) are skipped.
                _ => {}
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> RetryPolicy {
        RetryPolicy { jitter: 0.0, ..RetryPolicy::default() }
    }

    /// No jitter: the wait is exactly max(exponential, hint), clamped.
    #[test]
    fn backoff_honours_hint_and_clamp() {
        let p = policy();
        let mut rng = Rng::new(1);
        assert_eq!(backoff(&p, &mut rng, 0, 0), 200);
        assert_eq!(backoff(&p, &mut rng, 1, 0), 400);
        assert_eq!(backoff(&p, &mut rng, 0, 5_000), 5_000, "server hint dominates");
        assert_eq!(backoff(&p, &mut rng, 20, 0), p.max_backoff_us, "exponent clamps");
        assert_eq!(
            backoff(&p, &mut rng, 0, 10_000_000),
            p.max_backoff_us,
            "absurd hints clamp too"
        );
    }

    /// Jitter stays within ±fraction and the wait is never zero.
    #[test]
    fn backoff_jitter_bounded_and_nonzero() {
        let p = RetryPolicy { jitter: 0.25, ..policy() };
        let mut rng = Rng::new(42);
        for attempt in 0..8 {
            let w = backoff(&p, &mut rng, attempt, 0);
            let nominal = (200.0 * 2.0f64.powi(attempt as i32)).min(50_000.0);
            assert!(w as f64 >= nominal * 0.74 && w as f64 <= nominal * 1.26, "wait {w} outside jitter band around {nominal}");
            assert!(w >= 1);
        }
        // Same seed → same schedule.
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for attempt in 0..8 {
            assert_eq!(backoff(&p, &mut a, attempt, 300), backoff(&p, &mut b, attempt, 300));
        }
    }
}
