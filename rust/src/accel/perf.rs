//! Function-level performance estimation: latency and throughput of each
//! RBD function under a design point, including composite functions
//! (FD/ΔID/ΔFD) and the dynamic module-activation / DSP-donation rules of
//! inter-module reuse (Fig. 7(c)).

use super::designs::{BasicModule, Design, RbdFn};
use super::ops;
use super::pipeline::{DividerModel, Module, Stage};
use crate::model::Robot;

/// Estimated performance of one function on one design.
#[derive(Debug, Clone)]
pub struct FnPerf {
    pub design: &'static str,
    pub function: RbdFn,
    /// Single-task latency [µs].
    pub latency_us: f64,
    /// Saturated throughput [tasks/s].
    pub throughput: f64,
    /// Time to process a batch of `b` tasks [µs] (reported for b=256).
    pub batch256_us: f64,
    /// DSPs active while this function runs.
    pub dsp_active: u64,
}

/// Engine split across *active* modules. Without reuse the split is the
/// static proportional one (idle modules' DSPs sit idle); with reuse the
/// shared groups are donated to the active set (Fig. 7(c)).
fn active_split(design: &Design, robot: &Robot, func: RbdFn) -> Vec<(BasicModule, u64)> {
    let active = func.modules();
    let full = design.engine_split(robot);
    // Static multi-function split (Dadu-RBD): idle modules' DSPs idle.
    // Reuse (DRACO) redistributes through the shared groups; Roboshape
    // builds one dedicated accelerator per function, so the whole budget
    // serves the active set in both of those cases.
    if !design.reuse && !design.latency_first {
        return full.into_iter().filter(|(m, _)| active.contains(m)).collect();
    }
    let totals: Vec<(BasicModule, u64)> = active
        .iter()
        .map(|&m| (m, ops::module_total_macs(&design.module_units(robot, m))))
        .collect();
    let grand: u64 = totals.iter().map(|(_, t)| t).sum();
    let budget = design.engine_budget();
    totals
        .into_iter()
        .map(|(m, t)| (m, (budget as f64 * t as f64 / grand as f64).max(2.0) as u64))
        .collect()
}

/// Build the active modules with their (possibly donated) engine shares.
fn active_modules(design: &Design, robot: &Robot, func: RbdFn) -> Vec<Module> {
    active_split(design, robot, func)
        .into_iter()
        .map(|(m, share)| {
            let units = design.module_units(robot, m);
            let alloc = super::designs::latency_first_alloc(
                &units,
                share,
                design.latency_first,
                design.engine_cap,
            );
            let stages: Vec<Stage> =
                units.into_iter().zip(alloc).map(|(ops, dsps)| Stage { ops, dsps }).collect();
            let divider = match m {
                BasicModule::Minv => design.divider,
                _ => DividerModel::None,
            };
            Module {
                name: format!("{}/{}", design.name, m.name()),
                stages,
                divider,
                freq_hz: design.freq_hz,
                stage_overhead: design.stage_overhead,
            }
        })
        .collect()
}

/// Glue stage for composites: FD multiplies M⁻¹·(τ−C) (N² MACs); ΔFD
/// multiplies M⁻¹·ΔID over 2N columns (2N³ MACs). Modeled as one extra
/// pipeline stage with a 10% engine share.
fn glue_ops(robot: &Robot, func: RbdFn) -> u64 {
    let n = robot.dof() as u64;
    match func {
        RbdFn::Fd => n * n,
        RbdFn::DeltaFd => 2 * n * n * n,
        _ => 0,
    }
}

/// Estimate one (design, robot, function) point.
pub fn estimate(design: &Design, robot: &Robot, func: RbdFn) -> FnPerf {
    let modules = active_modules(design, robot, func);
    let glue = glue_ops(robot, func);
    let glue_engines = (design.engine_budget() / 10).max(1);
    let glue_ii = glue.div_ceil(glue_engines).max(1);
    let glue_latency = glue_ii + 4; // + adder tree depth

    let (ii, mut latency_cycles) = if design.latency_first {
        // Roboshape executes one task at a time on dual cores: no
        // cross-task pipelining. Effective II is the whole latency / 2.
        let lat: u64 = modules.iter().map(Module::latency_cycles).sum::<u64>()
            + if glue > 0 { glue_latency } else { 0 };
        (lat / 2, lat)
    } else {
        let ii = modules
            .iter()
            .map(Module::ii)
            .chain(if glue > 0 { Some(glue_ii) } else { None })
            .max()
            .unwrap_or(1);
        let lat: u64 = modules.iter().map(Module::latency_cycles).sum::<u64>()
            + if glue > 0 { glue_latency } else { 0 };
        (ii, lat)
    };
    // Composite dataflow: modules chain through FIFOs (RNEA feeds Minv
    // etc.), already summed; add one hop per junction.
    latency_cycles += (modules.len() as u64 - 1) * 2;

    let dsp_active: u64 = modules.iter().map(Module::total_dsps).sum::<u64>()
        * design.dsp_per_mac()
        + if glue > 0 { glue_engines * design.dsp_per_mac() } else { 0 };

    let latency_us = latency_cycles as f64 / design.freq_hz * 1e6;
    let throughput = design.freq_hz / ii as f64;
    let batch256_us = (latency_cycles + 255 * ii) as f64 / design.freq_hz * 1e6;
    FnPerf {
        design: design.name,
        function: func,
        latency_us,
        throughput,
        batch256_us,
        dsp_active,
    }
}

/// CPU/GPU baseline models. The CPU numbers are *measured* on this
/// machine by the bench harness and passed in; the GPU numbers are
/// modeled from GRiD's published characteristics (high batch throughput,
/// poor single-task response; see DESIGN.md Substitutions).
pub fn gpu_model(robot: &Robot, func: RbdFn) -> FnPerf {
    let n = robot.dof() as f64;
    // Kernel-launch dominated latency + per-joint work; batch hides it.
    let latency_us = 160.0 + 1.5 * n;
    let per_task_us = match func {
        RbdFn::Id => 0.012 * n,
        RbdFn::Minv => 0.03 * n,
        RbdFn::Fd => 0.045 * n,
        RbdFn::DeltaId => 0.05 * n,
        RbdFn::DeltaFd => 0.08 * n,
    };
    let batch256_us = latency_us + 256.0 * per_task_us;
    FnPerf {
        design: "gpu-grid",
        function: func,
        latency_us,
        throughput: 256.0 / (batch256_us * 1e-6),
        batch256_us,
        dsp_active: 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::builtin;

    #[test]
    fn draco_beats_dadu_on_every_function() {
        // Fig. 10 headline: 2.2–8× throughput, 2.3–7.4× latency across
        // functions/robots. Check the ordering and the broad band.
        for robot in [builtin::iiwa(), builtin::hyq(), builtin::atlas()] {
            let draco = Design::draco(&robot);
            let dadu = Design::dadu_rbd(&robot);
            for f in RbdFn::ALL {
                let a = estimate(&draco, &robot, f);
                let b = estimate(&dadu, &robot, f);
                let tput = a.throughput / b.throughput;
                let lat = b.latency_us / a.latency_us;
                assert!(
                    tput > 1.5 && tput < 30.0,
                    "{} {}: throughput ratio {tput:.2}",
                    robot.name,
                    f.name()
                );
                assert!(
                    lat > 1.2 && lat < 30.0,
                    "{} {}: latency ratio {lat:.2}",
                    robot.name,
                    f.name()
                );
            }
        }
    }

    #[test]
    fn roboshape_latency_competitive_but_low_throughput() {
        let robot = builtin::iiwa();
        let rs = Design::roboshape(&robot);
        let dadu = Design::dadu_rbd(&robot);
        let a = estimate(&rs, &robot, RbdFn::Id);
        let b = estimate(&dadu, &robot, RbdFn::Id);
        assert!(a.latency_us < b.latency_us, "Roboshape is the latency SOTA");
        assert!(a.throughput < b.throughput, "…but RTP wins throughput");
    }

    #[test]
    fn reuse_accelerates_solo_id() {
        // Fig. 7(c) upper-left: with reuse, ID running alone receives the
        // shared DSP groups and beats the static-split configuration.
        let robot = builtin::atlas();
        let with = Design::draco(&robot);
        let mut without = with.clone();
        without.reuse = false;
        let a = estimate(&with, &robot, RbdFn::Id);
        let b = estimate(&without, &robot, RbdFn::Id);
        assert!(
            a.throughput > b.throughput,
            "donated DSPs must raise solo-ID throughput: {} vs {}",
            a.throughput,
            b.throughput
        );
    }

    #[test]
    fn gpu_latency_worse_throughput_better_than_cpu_scale() {
        let robot = builtin::iiwa();
        let g = gpu_model(&robot, RbdFn::Id);
        assert!(g.latency_us > 100.0, "GPU per-task response is poor");
        assert!(g.throughput > 1e5, "GPU batch throughput is decent");
    }

    #[test]
    fn composite_latency_exceeds_parts() {
        let robot = builtin::iiwa();
        let d = Design::draco(&robot);
        let id = estimate(&d, &robot, RbdFn::Id);
        let minv = estimate(&d, &robot, RbdFn::Minv);
        let fd = estimate(&d, &robot, RbdFn::Fd);
        assert!(fd.latency_us > id.latency_us.max(minv.latency_us));
    }

    #[test]
    fn scalability_atlas_vs_iiwa() {
        // Challenge-1: DRACO keeps Atlas within a small factor of iiwa
        // (the paper's Fig. 10(c)(f): comparable speedups for Atlas).
        let iiwa = builtin::iiwa();
        let atlas = builtin::atlas();
        let t_iiwa = estimate(&Design::draco(&iiwa), &iiwa, RbdFn::DeltaFd).throughput;
        let t_atlas = estimate(&Design::draco(&atlas), &atlas, RbdFn::DeltaFd).throughput;
        let ratio = t_iiwa / t_atlas;
        assert!(
            ratio < 40.0,
            "Atlas ΔFD should stay within ~an order of magnitude ({ratio:.1})"
        );
    }
}
