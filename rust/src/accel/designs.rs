//! Design points: DRACO and the two FPGA baselines (Dadu-RBD, Roboshape)
//! instantiated over the cycle model, plus resource/power estimation.
//!
//! Published design parameters (paper Table I/II and §V-B):
//! * Dadu-RBD — 32-bit fixed (16/16), 4 DSP48 per MAC, inline
//!   fixed→float→fixed division, 125 MHz, throughput-oriented RTP.
//! * Roboshape — 32-bit fixed, latency-first: fully parallel units
//!   (II≈1) with dual cores, 56 MHz.
//! * DRACO — quantized per robot (24-bit DSP58 on V80 for iiwa/Atlas,
//!   18-bit DSP48 on U50 for HyQ), division-deferring Minv with a shared
//!   pipelined divider, inter-module DSP reuse, 228 MHz.

use super::ops::{self, UnitOps};
use super::pipeline::{best_ii_with_cap, DividerModel, Module, Stage};
use crate::model::Robot;
use crate::quant::QFormat;

/// The RBD functions served by the multi-function architecture (Fig. 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum RbdFn {
    Id,
    Minv,
    Fd,
    DeltaId,
    DeltaFd,
}

impl RbdFn {
    pub const ALL: [RbdFn; 5] = [RbdFn::Id, RbdFn::Minv, RbdFn::Fd, RbdFn::DeltaId, RbdFn::DeltaFd];

    pub fn name(&self) -> &'static str {
        match self {
            RbdFn::Id => "ID",
            RbdFn::Minv => "Minv",
            RbdFn::Fd => "FD",
            RbdFn::DeltaId => "dID",
            RbdFn::DeltaFd => "dFD",
        }
    }

    /// Which basic modules a function activates (Fig. 7(c)).
    pub fn modules(&self) -> &'static [BasicModule] {
        match self {
            RbdFn::Id => &[BasicModule::Rnea],
            RbdFn::Minv => &[BasicModule::Minv],
            RbdFn::Fd => &[BasicModule::Rnea, BasicModule::Minv],
            RbdFn::DeltaId => &[BasicModule::Rnea, BasicModule::Drnea],
            RbdFn::DeltaFd => &[BasicModule::Rnea, BasicModule::Drnea, BasicModule::Minv],
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BasicModule {
    Rnea,
    Drnea,
    Minv,
}

impl BasicModule {
    pub const ALL: [BasicModule; 3] = [BasicModule::Rnea, BasicModule::Drnea, BasicModule::Minv];

    pub fn name(&self) -> &'static str {
        match self {
            BasicModule::Rnea => "RNEA",
            BasicModule::Drnea => "dRNEA",
            BasicModule::Minv => "Minv",
        }
    }
}

/// A named accelerator design point.
#[derive(Debug, Clone)]
pub struct Design {
    pub name: &'static str,
    pub fmt: QFormat,
    /// DSP58 (V80) vs DSP48 (U50/VCU118) target.
    pub dsp58: bool,
    pub freq_hz: f64,
    pub divider: DividerModel,
    /// Inter-module DSP reuse enabled (DRACO contribution #3).
    pub reuse: bool,
    /// Latency-first allocation (Roboshape) vs throughput-first RTP.
    pub latency_first: bool,
    /// Total DSP budget available to the multi-function accelerator.
    pub dsp_budget: u64,
    /// Per-stage pipeline overhead in cycles (see pipeline::Module).
    pub stage_overhead: u64,
    /// Max MAC engines a single unit can absorb (DSP column / routing
    /// limit); floors the achievable II of heavy units.
    pub engine_cap: u32,
}

/// Allocation helper: latency-first designs (Roboshape) give every unit
/// as many engines as the budget allows, proportional to its MAC count
/// (full unroll when the budget covers it — the dual-core, single-task
/// parallelism that makes Roboshape the latency SOTA and DSP-hungry);
/// throughput-first designs use the balanced-II allocator.
pub fn latency_first_alloc(
    units: &[UnitOps],
    budget: u64,
    latency_first: bool,
    cap: u32,
) -> Vec<u32> {
    if !latency_first {
        return best_ii_with_cap(units, budget, cap).1;
    }
    let total: u64 = units.iter().map(|u| u.macs.max(1)).sum();
    let scale = (budget as f64 / total as f64).min(1.0);
    units.iter().map(|u| ((u.macs.max(1) as f64 * scale) as u32).max(1)).collect()
}

/// Published/derived DSP budgets (Table II; entries the paper marks N/A
/// are scaled from iiwa by relative workload size).
fn budget_for(robot: &Robot, design: &'static str) -> u64 {
    let scale = total_macs(robot) as f64 / 11_000.0; // iiwa ≈ 11k MACs
    match (design, robot.name.as_str()) {
        ("draco", "iiwa") => 5073,
        ("draco", "hyq") => 4002,
        ("draco", "atlas") => 6301,
        ("draco", _) => (5073.0 * scale) as u64,
        ("dadu-rbd", "iiwa") => 4241,
        ("dadu-rbd", _) => (4241.0 * scale) as u64,
        ("roboshape", "iiwa") => 5448,
        ("roboshape", "hyq") => 3008,
        ("roboshape", _) => (5448.0 * scale) as u64,
        _ => (5000.0 * scale) as u64,
    }
}

fn total_macs(robot: &Robot) -> u64 {
    let n = robot.dof();
    (0..n)
        .map(|i| {
            ops::rnea_fwd(robot, i).macs
                + ops::rnea_bwd(robot, i).macs
                + ops::minv_bwd(robot, i, false).macs
                + ops::minv_fwd(robot, i).macs
                + ops::drnea_fwd(robot, i).macs
                + ops::drnea_bwd(robot, i).macs
        })
        .sum()
}

impl Design {
    pub fn draco(robot: &Robot) -> Design {
        // 18-bit for HyQ on U50/DSP48; 24-bit on V80/DSP58 otherwise
        // (paper §V-A quantization outcomes).
        let (fmt, dsp58) = if robot.name == "hyq" {
            (QFormat::new(10, 8), false)
        } else {
            (QFormat::new(12, 12), true)
        };
        Design {
            name: "draco",
            fmt,
            dsp58,
            freq_hz: 228e6,
            divider: DividerModel::SharedDeferred { latency: 26 },
            reuse: true,
            latency_first: false,
            dsp_budget: budget_for(robot, "draco"),
            // Narrower 24/18-bit datapaths retire in shallower pipelines
            // than the 32-bit baselines (fewer register stages/MAC array).
            stage_overhead: 8,
            engine_cap: 96,
        }
    }

    pub fn dadu_rbd(robot: &Robot) -> Design {
        Design {
            name: "dadu-rbd",
            fmt: QFormat::new(16, 16),
            dsp58: false,
            freq_hz: 125e6,
            // fixed→float (4) + FP div (28) + float→fixed (4): §IV-A.
            divider: DividerModel::InlineFloatConverted { latency: 36 },
            reuse: false,
            latency_first: false,
            dsp_budget: budget_for(robot, "dadu-rbd"),
            stage_overhead: 12,
            engine_cap: 48,
        }
    }

    pub fn dadu_rbd_on_v80(robot: &Robot) -> Design {
        // Fig. 13 fairness setup: Dadu-RBD re-implemented on the V80.
        let mut d = Design::dadu_rbd(robot);
        d.name = "dadu-rbd-v80";
        d.freq_hz = 228e6;
        d.dsp_budget = budget_for(robot, "draco");
        d
    }

    pub fn roboshape(robot: &Robot) -> Design {
        Design {
            name: "roboshape",
            fmt: QFormat::new(16, 16),
            dsp58: false,
            freq_hz: 56e6,
            divider: DividerModel::InlineFixed { latency: 20 },
            reuse: false,
            latency_first: true,
            dsp_budget: budget_for(robot, "roboshape"),
            stage_overhead: 0,
            engine_cap: u32::MAX,
        }
    }

    /// A DRACO variant with division deferring disabled (Fig. 12(a)
    /// ablation): reciprocals return to the Mb critical path.
    pub fn draco_no_dd(robot: &Robot) -> Design {
        let mut d = Design::draco(robot);
        d.name = "draco-no-dd";
        // Fixed-point division with a *fractional* quotient needs
        // int+frac iterations (24+24) plus control ≈ 52 cycles at 228 MHz,
        // inline on every Mb unit's critical path (Challenge-2: the
        // reciprocal consumes over half the Minv runtime).
        d.divider = DividerModel::InlineFixed { latency: 52 };
        d
    }

    /// DSP-per-MAC under this design's format and device.
    pub fn dsp_per_mac(&self) -> u64 {
        self.fmt.dsp_per_mac(self.dsp58) as u64
    }

    /// MAC-engine budget = DSP budget / DSPs-per-MAC.
    pub fn engine_budget(&self) -> u64 {
        (self.dsp_budget / self.dsp_per_mac()).max(1)
    }

    /// Unit op lists for one basic module (forward stages then backward,
    /// the RTP round trip).
    pub fn module_units(&self, robot: &Robot, m: BasicModule) -> Vec<UnitOps> {
        let n = robot.dof();
        let deferred = matches!(self.divider, DividerModel::SharedDeferred { .. });
        let mut units = Vec::with_capacity(2 * n);
        match m {
            BasicModule::Rnea => {
                for i in 0..n {
                    units.push(ops::rnea_fwd(robot, i));
                }
                for i in (0..n).rev() {
                    units.push(ops::rnea_bwd(robot, i));
                }
            }
            BasicModule::Drnea => {
                for i in 0..n {
                    units.push(ops::drnea_fwd(robot, i));
                }
                for i in (0..n).rev() {
                    units.push(ops::drnea_bwd(robot, i));
                }
            }
            BasicModule::Minv => {
                for i in (0..n).rev() {
                    units.push(ops::minv_bwd(robot, i, deferred));
                }
                for i in 0..n {
                    units.push(ops::minv_fwd(robot, i));
                }
            }
        }
        units
    }

    /// Engine share for each basic module: proportional to module MACs
    /// (the multi-function architecture hosts all three).
    pub fn engine_split(&self, robot: &Robot) -> Vec<(BasicModule, u64)> {
        let totals: Vec<(BasicModule, u64)> = BasicModule::ALL
            .iter()
            .map(|&m| (m, ops::module_total_macs(&self.module_units(robot, m))))
            .collect();
        let grand: u64 = totals.iter().map(|(_, t)| t).sum();
        let budget = self.engine_budget();
        totals
            .into_iter()
            .map(|(m, t)| (m, (budget as f64 * t as f64 / grand as f64).max(2.0) as u64))
            .collect()
    }

    /// Build an allocated [`Module`] for one basic module.
    pub fn build_module(&self, robot: &Robot, m: BasicModule) -> Module {
        let units = self.module_units(robot, m);
        let share = self
            .engine_split(robot)
            .into_iter()
            .find(|(mm, _)| *mm == m)
            .map(|(_, s)| s)
            .unwrap();
        let alloc = latency_first_alloc(&units, share, self.latency_first, self.engine_cap);
        let stages: Vec<Stage> = units
            .into_iter()
            .zip(alloc)
            .map(|(ops, dsps)| Stage { ops, dsps })
            .collect();
        let divider = match m {
            BasicModule::Minv => self.divider,
            _ => DividerModel::None,
        };
        Module {
            name: format!("{}/{}", self.name, m.name()),
            stages,
            divider,
            freq_hz: self.freq_hz,
            stage_overhead: self.stage_overhead,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::builtin;

    #[test]
    fn draco_has_more_engines_than_dadu() {
        let r = builtin::iiwa();
        let draco = Design::draco(&r);
        let dadu = Design::dadu_rbd(&r);
        // 24-bit/DSP58 vs 32-bit/4-DSP48: ~4.8× engine advantage at
        // similar DSP budgets — the quantization payoff (Challenge-1).
        assert!(draco.engine_budget() > 4 * dadu.engine_budget());
    }

    #[test]
    fn modules_build_and_have_sane_ii() {
        let r = builtin::iiwa();
        for design in [Design::draco(&r), Design::dadu_rbd(&r), Design::roboshape(&r)] {
            for m in BasicModule::ALL {
                let module = design.build_module(&r, m);
                assert!(module.ii() >= 1);
                assert!(module.latency_cycles() > 0);
                assert!(module.total_dsps() > 0);
            }
        }
    }

    #[test]
    fn roboshape_fully_unrolls_within_budget() {
        // Per-function accelerator: the whole budget serves one module;
        // iiwa RNEA fits fully (II = 1 on every unit).
        let r = builtin::iiwa();
        let rs = Design::roboshape(&r);
        let units = rs.module_units(&r, BasicModule::Rnea);
        let alloc = latency_first_alloc(&units, rs.engine_budget(), true, rs.engine_cap);
        for (u, d) in units.iter().zip(&alloc) {
            assert_eq!(u.macs.div_ceil(*d as u64), 1, "unit must reach II=1");
        }
    }

    #[test]
    fn draco_minv_ii_better_than_dadu() {
        let r = builtin::iiwa();
        let draco = Design::draco(&r).build_module(&r, BasicModule::Minv);
        let dadu = Design::dadu_rbd(&r).build_module(&r, BasicModule::Minv);
        assert!(draco.throughput() > 2.0 * dadu.throughput());
        assert!(draco.latency_us() < dadu.latency_us());
    }

    #[test]
    fn division_deferring_cuts_minv_latency() {
        // Fig. 12(a): >2× standalone Minv latency improvement with the
        // same DSP/MAC configuration.
        let r = builtin::iiwa();
        let with_dd = Design::draco(&r).build_module(&r, BasicModule::Minv);
        let without = Design::draco_no_dd(&r).build_module(&r, BasicModule::Minv);
        let speedup = without.latency_us() / with_dd.latency_us();
        assert!(
            speedup > 1.8,
            "division deferring speedup {speedup:.2} (paper: >2x)"
        );
    }

    #[test]
    fn budgets_match_table2_where_published() {
        let iiwa = builtin::iiwa();
        assert_eq!(Design::draco(&iiwa).dsp_budget, 5073);
        assert_eq!(Design::dadu_rbd(&iiwa).dsp_budget, 4241);
        assert_eq!(Design::roboshape(&iiwa).dsp_budget, 5448);
        let hyq = builtin::hyq();
        assert_eq!(Design::draco(&hyq).dsp_budget, 4002);
        let atlas = builtin::atlas();
        assert_eq!(Design::draco(&atlas).dsp_budget, 6301);
    }
}
