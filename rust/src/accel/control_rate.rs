//! Estimated control-rate model (Fig. 13), following the analytical model
//! of Robomorphic [39]: one MPC control step runs `iters` optimization
//! iterations, each sweeping the trajectory of `traj_len` time steps
//! through FD and ΔFD (plus a fixed QP/bookkeeping overhead per step).
//! RBD is ~90% of the controller runtime, so the achievable control rate
//! is set by how fast the accelerator streams those batched tasks.

use super::designs::{Design, RbdFn};
use super::perf::{estimate, FnPerf};
use crate::model::Robot;

/// Per-task times [µs] for a platform serving FD and ΔFD.
#[derive(Debug, Clone, Copy)]
pub struct PlatformTimes {
    /// Pipeline fill / call latency [µs].
    pub fd_latency_us: f64,
    pub dfd_latency_us: f64,
    /// Marginal per-task time at saturation [µs] (1/throughput).
    pub fd_per_task_us: f64,
    pub dfd_per_task_us: f64,
}

impl PlatformTimes {
    pub fn from_design(design: &Design, robot: &Robot) -> PlatformTimes {
        let fd: FnPerf = estimate(design, robot, RbdFn::Fd);
        let dfd: FnPerf = estimate(design, robot, RbdFn::DeltaFd);
        PlatformTimes {
            fd_latency_us: fd.latency_us,
            dfd_latency_us: dfd.latency_us,
            fd_per_task_us: 1e6 / fd.throughput,
            dfd_per_task_us: 1e6 / dfd.throughput,
        }
    }

    /// CPU single-thread times (measured by the bench harness; defaults
    /// here follow [50]-style analytical-derivative implementations).
    pub fn cpu_default(robot: &Robot) -> PlatformTimes {
        let n = robot.dof() as f64;
        PlatformTimes {
            fd_latency_us: 0.55 * n,
            dfd_latency_us: 2.6 * n,
            fd_per_task_us: 0.55 * n,
            dfd_per_task_us: 2.6 * n,
        }
    }
}

/// Time for one MPC control step [µs]: `iters` sweeps over the horizon,
/// each streaming `traj_len` FD and ΔFD tasks, plus per-iteration QP
/// overhead (line search + gains), overlapped on the accelerator but
/// serial on a CPU.
pub fn mpc_step_time_us(times: &PlatformTimes, traj_len: usize, iters: usize) -> f64 {
    let t = traj_len as f64;
    let per_iter = times.fd_latency_us
        + times.dfd_latency_us
        + (t - 1.0).max(0.0) * (times.fd_per_task_us + times.dfd_per_task_us)
        + 8.0; // QP/backward-pass overhead per iteration [µs]
    iters as f64 * per_iter
}

/// Estimated control rate [Hz].
pub fn control_rate_hz(times: &PlatformTimes, traj_len: usize, iters: usize) -> f64 {
    1e6 / mpc_step_time_us(times, traj_len, iters)
}

/// Max trajectory length sustaining `target_hz` (the paper's "54 time
/// steps at 250 Hz for Atlas" style number).
pub fn max_traj_len(times: &PlatformTimes, target_hz: f64, iters: usize) -> usize {
    let mut t = 1;
    while t < 4096 && control_rate_hz(times, t + 1, iters) >= target_hz {
        t += 1;
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::builtin;

    #[test]
    fn rate_decreases_with_horizon() {
        let robot = builtin::iiwa();
        let d = Design::draco(&robot);
        let times = PlatformTimes::from_design(&d, &robot);
        let r10 = control_rate_hz(&times, 10, 10);
        let r50 = control_rate_hz(&times, 50, 10);
        assert!(r10 > r50);
    }

    /// Fig. 13 shape: DRACO sustains longer horizons than Dadu-RBD at the
    /// same target rate, and both beat the CPU.
    #[test]
    fn horizon_ordering_at_250hz() {
        let robot = builtin::atlas();
        let draco = PlatformTimes::from_design(&Design::draco(&robot), &robot);
        let dadu = PlatformTimes::from_design(&Design::dadu_rbd_on_v80(&robot), &robot);
        let cpu = PlatformTimes::cpu_default(&robot);
        let h_draco = max_traj_len(&draco, 250.0, 10);
        let h_dadu = max_traj_len(&dadu, 250.0, 10);
        let h_cpu = max_traj_len(&cpu, 250.0, 10);
        assert!(
            h_draco > h_dadu && h_dadu > h_cpu,
            "horizons: draco {h_draco} > dadu {h_dadu} > cpu {h_cpu}"
        );
    }

    /// The paper's headline: Atlas fails 1 kHz direct MPC on the
    /// baselines for long horizons, DRACO extends the feasible region.
    #[test]
    fn iiwa_reaches_1khz_for_short_horizons() {
        let robot = builtin::iiwa();
        let draco = PlatformTimes::from_design(&Design::draco(&robot), &robot);
        assert!(
            control_rate_hz(&draco, 10, 10) > 1000.0,
            "iiwa @ 10 steps must exceed 1 kHz: {}",
            control_rate_hz(&draco, 10, 10)
        );
    }
}
