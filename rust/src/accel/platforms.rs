//! Hardware platform catalogue (paper Table I).

/// One Table I row.
#[derive(Debug, Clone, Copy)]
pub struct Platform {
    pub kind: &'static str,
    pub name: &'static str,
    pub freq_hz: f64,
    pub evaluated_in: &'static str,
}

pub const TABLE1: &[Platform] = &[
    Platform { kind: "CPU", name: "Jetson AGX Orin", freq_hz: 2.2e9, evaluated_in: "[15],[43]" },
    Platform { kind: "CPU", name: "Core i9-12900", freq_hz: 5.1e9, evaluated_in: "[15],[43]" },
    Platform { kind: "GPU", name: "Jetson AGX Orin", freq_hz: 1.3e9, evaluated_in: "[44]" },
    Platform { kind: "GPU", name: "RTX 4090M", freq_hz: 1.8e9, evaluated_in: "[44]" },
    Platform { kind: "FPGA", name: "XCVU9P (Roboshape)", freq_hz: 56e6, evaluated_in: "[38]" },
    Platform { kind: "FPGA", name: "XCVU9P (Dadu-RBD)", freq_hz: 125e6, evaluated_in: "[57]" },
    Platform { kind: "FPGA", name: "XCV80 & U50 (DRACO)", freq_hz: 228e6, evaluated_in: "this work" },
];

#[cfg(test)]
mod tests {
    #[test]
    fn table1_has_all_rows() {
        assert_eq!(super::TABLE1.len(), 7);
        assert!(super::TABLE1.iter().any(|p| p.name.contains("DRACO")));
    }
}
