//! Inter-module DSP reuse accounting (paper §IV-B, Fig. 12(b)).
//!
//! The mechanism: per-unit engine caps (DSP column / routing limits)
//! floor the II of heavy modules — on high-DOF robots the tip-heavy
//! ΔRNEA and subtree-heavy Minv units cannot be parallelized below
//! `macs/cap` cycles, while the light RNEA units could run much faster.
//! Coordinated functions therefore run at the slow modules' II, and the
//! engines RNEA holds beyond what that matched rate needs are *shared*
//! (DSP_DR / DSP_MR): they serve RNEA when ID runs alone and the heavy
//! modules otherwise. A design **without** reuse must duplicate that
//! surplus to offer the same per-function performance.

use super::designs::{BasicModule, Design, RbdFn};
use super::ops;
use super::pipeline::{best_ii_with_cap, total_dsps_for_ii};
use crate::model::Robot;

#[derive(Debug, Clone)]
pub struct ReuseReport {
    pub robot: String,
    /// DSPs with inter-module reuse (= the design budget, shared pools).
    pub dsp_with: u64,
    /// DSPs without reuse (shared surplus duplicated).
    pub dsp_without: u64,
    /// Fractional saving (paper: 2.7% iiwa, 16.1% Atlas).
    pub savings_frac: f64,
    /// Engines in the shared groups (DSP_DR + DSP_MR).
    pub shared_engines: u64,
    /// Matched composite II (slowest module at its pool + cap).
    pub ii_composite: u64,
    /// RNEA's standalone II at its full static pool.
    pub ii_rnea_solo: u64,
}

/// Compute the reuse accounting for a design.
pub fn reuse_report(design: &Design, robot: &Robot) -> ReuseReport {
    let split = design.engine_split(robot);
    let pool = |m: BasicModule| split.iter().find(|(mm, _)| *mm == m).unwrap().1;
    let units = |m: BasicModule| design.module_units(robot, m);

    // Cap floor of a module: the best II it can reach when shared
    // engines flow in (solo activation, Fig. 7(c) upper row).
    let floor = |m: BasicModule| {
        units(m)
            .iter()
            .map(|u| u.macs.div_ceil(design.engine_cap.max(1) as u64))
            .max()
            .unwrap_or(1)
            .max(1)
    };
    // Matched composite rate: the slowest module at its static pool.
    let ii_of = |m: BasicModule| best_ii_with_cap(&units(m), pool(m), design.engine_cap).0;
    let ii_rnea_solo = floor(BasicModule::Rnea);
    let ii_composite = BasicModule::ALL.iter().map(|&m| ii_of(m)).max().unwrap_or(1);

    // Shared groups (DSP_DR + DSP_MR): the engines RNEA and Minv need in
    // their *solo* modes (cap-floor II) beyond what the matched composite
    // rate requires. With reuse these are borrowed from modules idle in
    // the solo activation; without reuse they are dedicated silicon.
    let mut shared_engines = 0u64;
    for m in [BasicModule::Rnea, BasicModule::Minv] {
        let e_solo = total_dsps_for_ii(&units(m), floor(m));
        let e_comp = total_dsps_for_ii(&units(m), ii_composite.max(floor(m)).max(1));
        shared_engines += e_solo.saturating_sub(e_comp);
    }

    let dsp_with = design.dsp_budget;
    let dsp_without = dsp_with + shared_engines * design.dsp_per_mac();
    ReuseReport {
        robot: robot.name.clone(),
        dsp_with,
        dsp_without,
        savings_frac: 1.0 - dsp_with as f64 / dsp_without as f64,
        shared_engines,
        ii_composite,
        ii_rnea_solo,
    }
}

/// Guideline 1 of §IV-B: shared-group size tracks the II mismatch
/// between the coordinated modules.
pub fn ii_mismatch(design: &Design, robot: &Robot) -> f64 {
    let r = reuse_report(design, robot);
    r.ii_composite as f64 / r.ii_rnea_solo.max(1) as f64
}

/// Total MACs per module — exposed for the benches' workload tables.
pub fn module_macs(design: &Design, robot: &Robot) -> Vec<(&'static str, u64)> {
    BasicModule::ALL
        .iter()
        .map(|&m| (m.name(), ops::module_total_macs(&design.module_units(robot, m))))
        .collect()
}

/// Which functions activate which modules — Fig. 7(c) as data.
pub fn activation_table() -> Vec<(RbdFn, Vec<&'static str>)> {
    RbdFn::ALL
        .iter()
        .map(|&f| (f, f.modules().iter().map(|m| m.name()).collect()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::builtin;

    #[test]
    fn savings_positive_and_bounded() {
        for robot in [builtin::iiwa(), builtin::hyq(), builtin::atlas()] {
            let d = Design::draco(&robot);
            let r = reuse_report(&d, &robot);
            assert!(r.savings_frac >= 0.0 && r.savings_frac < 0.6, "{}: {r:?}", robot.name);
            assert!(r.dsp_without >= r.dsp_with);
        }
    }

    /// Fig. 12(b) shape: Atlas saves a much larger fraction than iiwa
    /// (paper: 16.1% vs 2.7%) because its heavier ΔRNEA/Minv loads widen
    /// the inter-module II mismatch.
    #[test]
    fn atlas_saves_more_than_iiwa() {
        let iiwa = builtin::iiwa();
        let atlas = builtin::atlas();
        let s_iiwa = reuse_report(&Design::draco(&iiwa), &iiwa).savings_frac;
        let s_atlas = reuse_report(&Design::draco(&atlas), &atlas).savings_frac;
        assert!(
            s_atlas > s_iiwa,
            "atlas {s_atlas:.3} must exceed iiwa {s_iiwa:.3} (Fig 12b)"
        );
    }

    #[test]
    fn mismatch_drives_sharing() {
        // Guideline 1: bigger II mismatch ⇒ more shared engines.
        let iiwa = builtin::iiwa();
        let atlas = builtin::atlas();
        let m_iiwa = ii_mismatch(&Design::draco(&iiwa), &iiwa);
        let m_atlas = ii_mismatch(&Design::draco(&atlas), &atlas);
        assert!(m_atlas > m_iiwa, "mismatch atlas {m_atlas:.2} vs iiwa {m_iiwa:.2}");
    }

    #[test]
    fn activation_table_matches_fig7c() {
        let t = activation_table();
        let get = |f: RbdFn| t.iter().find(|(ff, _)| *ff == f).unwrap().1.clone();
        assert_eq!(get(RbdFn::Id), vec!["RNEA"]);
        assert_eq!(get(RbdFn::Minv), vec!["Minv"]);
        assert_eq!(get(RbdFn::Fd), vec!["RNEA", "Minv"]);
        assert_eq!(get(RbdFn::DeltaFd), vec!["RNEA", "dRNEA", "Minv"]);
    }
}
