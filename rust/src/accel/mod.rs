//! FPGA accelerator cycle model — the substitution for real Alveo
//! V80/U50 hardware (see DESIGN.md): Round-Trip-Pipeline modules with
//! MAC/DSP/II accounting, divider models (inline vs division-deferring
//! shared divider), inter-module DSP reuse, resource/power estimation,
//! and the Fig. 13 control-rate model.

pub mod control_rate;
pub mod designs;
pub mod ops;
pub mod perf;
pub mod pipeline;
pub mod platforms;
pub mod resources;
pub mod reuse;

pub use designs::{BasicModule, Design, RbdFn};
pub use perf::{estimate, gpu_model, FnPerf};
pub use reuse::reuse_report;
