//! Operation-count model: MACs (and reciprocals) per pipeline unit,
//! derived from the structure of the *executable* algorithms in
//! [`crate::dynamics`]. These counts drive II/latency/DSP numbers in the
//! cycle model, so the figures inherit the real workload shape
//! (tip-heavy ΔRNEA units, subtree-heavy Minv backward units, …).

use crate::model::Robot;

/// Dense-op MAC costs for the spatial primitives (multiply-accumulate
/// pairs; adds ride along with the MACs in DSP slices).
pub mod cost {
    /// Apply a Plücker transform to a motion/force vector:
    /// two 3×3 mat-vecs (18) + one cross product (6).
    pub const X_APPLY: u64 = 24;
    /// v × m or v ×* f: two cross products.
    pub const CROSS: u64 = 12;
    /// Spatial inertia times motion vector (symmetric 6×6, CoM form):
    /// 3×3 matvec (9) + 2 crosses (12) + scale (3).
    pub const I_APPLY: u64 = 24;
    /// Dense 6-vector dot product.
    pub const DOT6: u64 = 6;
    /// Rank-1 update U·Uᵀ on a symmetric 6×6 (upper triangle).
    pub const OUTER6_SYM: u64 = 21;
    /// Congruence transform Xᵀ·A·X of a symmetric 6×6 exploiting the
    /// Plücker block structure (two block products @ ~108 each).
    pub const CONGRUENCE6: u64 = 216;
    /// Scalar × symmetric 6×6.
    pub const SCALE6_SYM: u64 = 21;
    /// jcalc: sin/cos via CORDIC/LUT + building E (counted as MACs).
    pub const JCALC: u64 = 16;
}

/// Per-unit op counts for one pipeline stage (one joint, one direction).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct UnitOps {
    pub macs: u64,
    /// Reciprocal/divide operations executed by this unit *inline*.
    pub divs: u64,
}

/// RNEA forward unit (Uf_i): v, a, f updates.
pub fn rnea_fwd(_robot: &Robot, _i: usize) -> UnitOps {
    let macs = cost::JCALC          // joint transform
        + cost::X_APPLY             // X v_λ
        + cost::CROSS               // v × S q̇
        + cost::X_APPLY             // X a_λ
        + cost::I_APPLY             // I a
        + cost::I_APPLY             // I v
        + cost::CROSS;              // v ×* (I v)
    UnitOps { macs, divs: 0 }
}

/// RNEA backward unit (Ub_i): τ projection + force propagation.
pub fn rnea_bwd(_robot: &Robot, _i: usize) -> UnitOps {
    UnitOps { macs: cost::DOT6 + cost::X_APPLY, divs: 0 }
}

/// Minv backward unit (Mb_i). `deferred` selects the division-deferring
/// formulation: the reciprocal leaves the unit (handled by the shared
/// divider) at the price of the extra holding-factor multiplies
/// (purple box of Algorithm 2).
pub fn minv_bwd(robot: &Robot, i: usize, deferred: bool) -> UnitOps {
    let cols = robot.subtree(i).len() as u64;
    let mut macs = cost::I_APPLY          // U = IA S (column gather + mac)
        + cost::DOT6                      // D = Sᵀ U
        + cost::OUTER6_SYM                // U Uᵀ
        + cost::SCALE6_SYM                // (1/D)·UUᵀ  or D·IA
        + cost::CONGRUENCE6               // Xᵀ (…) X
        + cols * (cost::DOT6 + cost::DOT6 + cost::X_APPLY); // row + F prop
    let divs = if deferred {
        // Holding-factor multiplies: D·IA (symmetric scale) and D·F per
        // column; reciprocal exported to the shared divider.
        macs += cost::SCALE6_SYM + cols * cost::DOT6;
        0
    } else {
        1
    };
    UnitOps { macs, divs }
}

/// Minv forward unit (Mf_i): acceleration propagation per column.
pub fn minv_fwd(robot: &Robot, i: usize) -> UnitOps {
    let cols = robot.subtree(i).len().max(1) as u64;
    UnitOps {
        macs: cols * (cost::X_APPLY + cost::DOT6 + cost::DOT6),
        divs: 0,
    }
}

/// ΔRNEA forward unit (Df_i): tangent propagation. Work scales with the
/// number of differentiation directions that reach joint i — its ancestor
/// path — making tip units heavier (paper §IV-B, [38]).
pub fn drnea_fwd(robot: &Robot, i: usize) -> UnitOps {
    let dirs = (robot.depth(i) + 1) as u64 * 2; // ∂q and ∂q̇ sweeps
    UnitOps {
        macs: dirs * (cost::X_APPLY + cost::CROSS + cost::I_APPLY + cost::CROSS),
        divs: 0,
    }
}

/// ΔRNEA backward unit (Db_i).
pub fn drnea_bwd(robot: &Robot, i: usize) -> UnitOps {
    let dirs = (robot.depth(i) + 1) as u64 * 2;
    UnitOps { macs: dirs * (cost::DOT6 + cost::X_APPLY + cost::CROSS / 2), divs: 0 }
}

/// Total MACs of a whole module (all units, fwd+bwd).
pub fn module_total_macs(units: &[UnitOps]) -> u64 {
    units.iter().map(|u| u.macs).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::builtin;

    #[test]
    fn minv_units_subtree_heavy_at_base() {
        let r = builtin::iiwa();
        // Chain: base joint sees the full subtree → heaviest Mb unit.
        let base = minv_bwd(&r, 0, false).macs;
        let tip = minv_bwd(&r, r.dof() - 1, false).macs;
        assert!(base > tip, "base {base} vs tip {tip}");
    }

    #[test]
    fn drnea_units_tip_heavy() {
        let r = builtin::iiwa();
        let tip = drnea_fwd(&r, r.dof() - 1).macs;
        let base = drnea_fwd(&r, 0).macs;
        assert!(tip > base, "ΔRNEA tip units must be heavier (paper §IV-B)");
    }

    #[test]
    fn deferring_trades_div_for_macs() {
        let r = builtin::iiwa();
        for i in 0..r.dof() {
            let orig = minv_bwd(&r, i, false);
            let dd = minv_bwd(&r, i, true);
            assert_eq!(orig.divs, 1);
            assert_eq!(dd.divs, 0);
            assert!(dd.macs > orig.macs, "holding factors cost extra MACs");
            // "minimal DSP overhead": < 15% extra.
            assert!((dd.macs as f64) < orig.macs as f64 * 1.15);
        }
    }

    #[test]
    fn rnea_unit_costs_constant_across_joints() {
        let r = builtin::atlas();
        let u0 = rnea_fwd(&r, 0);
        for i in 1..r.dof() {
            assert_eq!(rnea_fwd(&r, i), u0);
        }
    }

    #[test]
    fn atlas_heavier_than_iiwa_overall() {
        let iiwa = builtin::iiwa();
        let atlas = builtin::atlas();
        let total = |r: &crate::model::Robot| -> u64 {
            (0..r.dof())
                .map(|i| {
                    rnea_fwd(r, i).macs
                        + rnea_bwd(r, i).macs
                        + minv_bwd(r, i, false).macs
                        + minv_fwd(r, i).macs
                        + drnea_fwd(r, i).macs
                        + drnea_bwd(r, i).macs
                })
                .sum()
        };
        assert!(total(&atlas) > 3 * total(&iiwa));
    }
}
