//! Round-Trip-Pipeline (RTP) cycle model: units, initiation intervals,
//! DSP allocation, dividers, and module latency/throughput.
//!
//! Modeling rules (one DSP retires one MAC per cycle; the RTP chains
//! 2·N_units stages with FIFO coupling, Fig. 3(b)):
//!
//! * unit II        = ⌈macs / dsps⌉                       (cycles/task)
//! * unit latency   = II + ⌈log₂(dsps+1)⌉ (adder tree) + divider latency
//! * module II      = max over units (pipeline bottleneck)
//! * module latency = Σ stage latencies + FIFO hop / stage
//! * throughput     = f_clk / module II        (tasks/s, saturated pipe)

use super::ops::UnitOps;

/// Divider handling for units that perform reciprocals.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DividerModel {
    /// No divisions in this module.
    None,
    /// Inline fixed-point divider on the unit's critical path
    /// (e.g. 32-bit at 200 MHz ≈ 20 cycles; scales with width).
    InlineFixed { latency: u64 },
    /// Dadu-RBD's fixed→float→fixed conversion around an FP divider:
    /// longer latency, extra LUT cost, still on the critical path.
    InlineFloatConverted { latency: u64 },
    /// DRACO division deferring: a shared fully-pipelined divider off the
    /// critical path; units only pay a FIFO hop. `latency` is the divider
    /// pipeline depth (affects fill latency once, not II).
    SharedDeferred { latency: u64 },
}

/// One pipeline stage with its DSP allocation.
#[derive(Debug, Clone)]
pub struct Stage {
    pub ops: UnitOps,
    pub dsps: u32,
}

impl Stage {
    pub fn ii(&self) -> u64 {
        if self.ops.macs == 0 {
            1
        } else {
            self.ops.macs.div_ceil(self.dsps.max(1) as u64)
        }
    }

    /// II including the divider: a plain fixed-point divider is
    /// *iterative* (one result per ~`latency` cycles), so it throttles
    /// the unit's issue rate — this is why Dadu-RBD converts to floating
    /// point (pipelined FP divider, II=1) and why DRACO defers divisions
    /// to a shared pipelined divider instead.
    pub fn ii_with_div(&self, div: DividerModel) -> u64 {
        let base = self.ii();
        match div {
            DividerModel::InlineFixed { latency } if self.ops.divs > 0 => base.max(latency),
            _ => base,
        }
    }

    pub fn latency(&self, div: DividerModel) -> u64 {
        let tree = (64 - u64::from(self.dsps.max(1)).leading_zeros()) as u64; // ⌈log2⌉+1
        let div_lat = match div {
            DividerModel::None => 0,
            DividerModel::InlineFixed { latency } => latency * self.ops.divs,
            DividerModel::InlineFloatConverted { latency } => latency * self.ops.divs,
            // Deferred: the division overlaps the MAC work; only a FIFO
            // hop (2 cycles) shows up, once, if the unit had divisions
            // before deferring (divs==0 now, so charge via the module).
            DividerModel::SharedDeferred { .. } => 0,
        };
        self.ii() + tree + div_lat
    }
}

/// A module: a full RTP (forward units then backward units) plus its
/// divider model and clock.
#[derive(Debug, Clone)]
pub struct Module {
    pub name: String,
    pub stages: Vec<Stage>,
    pub divider: DividerModel,
    pub freq_hz: f64,
    /// Fixed per-stage pipeline overhead (MAC-array register stages +
    /// FIFO hop). Deeply-pipelined RTP designs (Dadu-RBD, DRACO) pay
    /// ~12 cycles/stage and clock high; Roboshape's shallow datapath
    /// pays ~0 but clocks at 56 MHz.
    pub stage_overhead: u64,
}

impl Module {
    /// Module initiation interval (cycles between task completions).
    pub fn ii(&self) -> u64 {
        self.stages.iter().map(|s| s.ii_with_div(self.divider)).max().unwrap_or(1)
    }

    /// End-to-end latency for one task (cycles).
    pub fn latency_cycles(&self) -> u64 {
        let base: u64 = self
            .stages
            .iter()
            .map(|s| s.latency(self.divider) + self.stage_overhead)
            .sum();
        match self.divider {
            // Shared divider: one extra fill of the divider pipeline plus
            // the Mb1→Mf1 holding FIFO (paper §IV-A overhead note).
            DividerModel::SharedDeferred { latency } => base + latency + 2,
            _ => base,
        }
    }

    pub fn latency_us(&self) -> f64 {
        self.latency_cycles() as f64 / self.freq_hz * 1e6
    }

    /// Saturated-pipeline throughput in tasks/s.
    pub fn throughput(&self) -> f64 {
        self.freq_hz / self.ii() as f64
    }

    /// Latency to drain a batch of `b` tasks (for batched workloads):
    /// fill latency + (b−1)·II.
    pub fn batch_time_us(&self, b: usize) -> f64 {
        (self.latency_cycles() + (b as u64 - 1) * self.ii()) as f64 / self.freq_hz * 1e6
    }

    pub fn total_dsps(&self) -> u64 {
        self.stages.iter().map(|s| s.dsps as u64).sum()
    }

    /// Number of shared dividers needed under the staggered schedule of
    /// Fig. 6(b): one pipelined divider serves ⌈units_with_div / II⌉…
    /// inverted: units issue one divide every II cycles, so a single
    /// divider (II ≥ 1 per issue) covers `min(units, II)`… the paper's
    /// example: II=3 ⇒ 3 Mb units share one divider.
    pub fn shared_dividers(&self, units_with_div: usize) -> u64 {
        let ii = self.ii().max(1);
        (units_with_div as u64).div_ceil(ii)
    }
}

/// Optimal balanced DSP allocation: the minimum-total-DSP assignment
/// achieving a target II, or the best II under a DSP budget. Exact via
/// monotone search: dsps(u, II) = ⌈macs_u / II⌉.
pub fn dsps_for_ii(ops: &[UnitOps], target_ii: u64) -> Vec<u32> {
    ops.iter()
        .map(|o| {
            if o.macs == 0 {
                1
            } else {
                o.macs.div_ceil(target_ii.max(1)) as u32
            }
        })
        .collect()
}

pub fn total_dsps_for_ii(ops: &[UnitOps], target_ii: u64) -> u64 {
    dsps_for_ii(ops, target_ii).iter().map(|&d| d as u64).sum()
}

/// Best (smallest) achievable II under a total-DSP budget; returns
/// (ii, allocation). Binary search over II.
pub fn best_ii_under_budget(ops: &[UnitOps], budget: u64) -> (u64, Vec<u32>) {
    best_ii_with_cap(ops, budget, u32::MAX)
}

/// As [`best_ii_under_budget`] but with a per-unit engine cap modeling
/// DSP-column/routing limits: a single pipeline unit cannot absorb more
/// than `cap` MAC engines, so heavily-loaded units (tip ΔRNEA units on
/// high-DOF robots) floor the achievable II — the source of the
/// inter-module II mismatch that DSP reuse exploits (paper §IV-B).
pub fn best_ii_with_cap(ops: &[UnitOps], budget: u64, cap: u32) -> (u64, Vec<u32>) {
    let floor = ops
        .iter()
        .map(|o| o.macs.div_ceil(cap.max(1) as u64))
        .max()
        .unwrap_or(1)
        .max(1);
    let max_macs = ops.iter().map(|o| o.macs).max().unwrap_or(1).max(1);
    let (mut lo, mut hi) = (floor, max_macs.max(floor));
    while lo < hi {
        let mid = (lo + hi) / 2;
        if total_dsps_for_ii(ops, mid) <= budget {
            hi = mid;
        } else {
            lo = mid + 1;
        }
    }
    (lo, dsps_for_ii(ops, lo))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{forall_res, Config};

    fn mk_ops(macs: &[u64]) -> Vec<UnitOps> {
        macs.iter().map(|&m| UnitOps { macs: m, divs: 0 }).collect()
    }

    #[test]
    fn stage_ii_is_ceiling() {
        let s = Stage { ops: UnitOps { macs: 10, divs: 0 }, dsps: 3 };
        assert_eq!(s.ii(), 4);
        let s = Stage { ops: UnitOps { macs: 12, divs: 0 }, dsps: 3 };
        assert_eq!(s.ii(), 4);
    }

    #[test]
    fn module_ii_is_bottleneck() {
        let m = Module {
            name: "t".into(),
            stages: vec![
                Stage { ops: UnitOps { macs: 8, divs: 0 }, dsps: 4 },
                Stage { ops: UnitOps { macs: 30, divs: 0 }, dsps: 5 },
            ],
            divider: DividerModel::None,
            freq_hz: 2e8,
            stage_overhead: 2,
        };
        assert_eq!(m.ii(), 6);
        assert!((m.throughput() - 2e8 / 6.0).abs() < 1.0);
    }

    #[test]
    fn divider_models_shape_ii_and_latency() {
        let mk = |div| Module {
            name: "m".into(),
            stages: vec![Stage { ops: UnitOps { macs: 20, divs: 1 }, dsps: 5 }],
            divider: div,
            freq_hz: 2e8,
            stage_overhead: 2,
        };
        let none = mk(DividerModel::None);
        let fixed = mk(DividerModel::InlineFixed { latency: 20 });
        let float_conv = mk(DividerModel::InlineFloatConverted { latency: 36 });
        let shared = mk(DividerModel::SharedDeferred { latency: 24 });
        // Iterative fixed divider throttles the issue rate…
        assert_eq!(fixed.ii(), 20);
        // …while the pipelined FP and shared dividers keep II at the MAC bound.
        assert_eq!(float_conv.ii(), none.ii());
        assert_eq!(shared.ii(), none.ii());
        // Both inline forms pay latency on the critical path; deferring does not.
        assert!(fixed.latency_cycles() >= none.latency_cycles() + 20);
        assert!(float_conv.latency_cycles() >= none.latency_cycles() + 36);
        assert!(shared.latency_cycles() < float_conv.latency_cycles());
    }

    #[test]
    fn allocation_achieves_target_ii() {
        forall_res(
            "alloc-ii",
            Config { cases: 128, ..Default::default() },
            |r| {
                let n = 1 + r.below(20);
                let macs: Vec<u64> = (0..n).map(|_| 1 + r.below(500) as u64).collect();
                let ii = 1 + r.below(40) as u64;
                (macs, ii)
            },
            |(macs, ii)| {
                let ops = mk_ops(macs);
                let alloc = dsps_for_ii(&ops, *ii);
                for (o, d) in ops.iter().zip(&alloc) {
                    let got = o.macs.div_ceil(*d as u64);
                    if got > *ii {
                        return Err(format!("unit ii {got} > target {ii}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn budget_search_is_optimal_boundary() {
        forall_res(
            "alloc-budget",
            Config { cases: 128, ..Default::default() },
            |r| {
                let n = 1 + r.below(12);
                let macs: Vec<u64> = (0..n).map(|_| 1 + r.below(300) as u64).collect();
                let budget = n as u64 + r.below(600) as u64;
                (macs, budget)
            },
            |(macs, budget)| {
                let ops = mk_ops(macs);
                let (ii, alloc) = best_ii_under_budget(&ops, *budget);
                let total: u64 = alloc.iter().map(|&d| d as u64).sum();
                if total > *budget {
                    return Err(format!("allocation {total} exceeds budget {budget}"));
                }
                if ii > 1 && total_dsps_for_ii(&ops, ii - 1) <= *budget {
                    return Err(format!("ii {ii} not minimal"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn shared_divider_count_matches_fig6b() {
        // Paper example: Mb II of 3 ⇒ 3 Mb units per divider.
        let m = Module {
            name: "minv".into(),
            stages: vec![Stage { ops: UnitOps { macs: 9, divs: 0 }, dsps: 3 }],
            divider: DividerModel::SharedDeferred { latency: 24 },
            freq_hz: 2.28e8,
            stage_overhead: 2,
        };
        assert_eq!(m.ii(), 3);
        assert_eq!(m.shared_dividers(3), 1);
        assert_eq!(m.shared_dividers(7), 3);
    }
}
