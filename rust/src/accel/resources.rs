//! Resource (DSP/LUT/FF/BRAM) and power estimation — Table II and the
//! §V-B power paragraph. The LUT/power coefficients are calibrated so the
//! published Table II points land within ~15% (this is a model of
//! synthesis results, not synthesis).

use super::designs::{BasicModule, Design};
use crate::model::Robot;

#[derive(Debug, Clone)]
pub struct Resources {
    pub dsp: u64,
    pub lut: u64,
    pub ff: u64,
    pub bram: u64,
    /// Total on-chip power [W] (static + dynamic).
    pub power_w: f64,
}

/// Per-design LUT cost per DSP slice: the 32-bit datapaths and the
/// float-conversion divider push Dadu-RBD's LUT/DSP ratio up; DRACO's
/// narrower datapaths need less routing/glue per slice.
fn lut_per_dsp(design: &Design) -> f64 {
    match design.name {
        "draco" | "draco-no-dd" => 100.0,
        "dadu-rbd" | "dadu-rbd-v80" => 115.0,
        "roboshape" => 82.0,
        _ => 110.0,
    }
}

pub fn estimate_resources(design: &Design, robot: &Robot) -> Resources {
    let dsp = design.dsp_budget;
    let stages: u64 = BasicModule::ALL
        .iter()
        .map(|&m| design.module_units(robot, m).len() as u64)
        .sum();
    // FIFOs between stages + control FSMs + (for Dadu) FP converters.
    let fifo_lut = 800 * stages;
    let divider_lut = match design.divider {
        super::pipeline::DividerModel::InlineFloatConverted { .. } => 6000 * robot.dof() as u64,
        super::pipeline::DividerModel::InlineFixed { .. } => 2500 * robot.dof() as u64,
        super::pipeline::DividerModel::SharedDeferred { .. } => {
            // Shared pipelined dividers: one per ceil(units/II).
            2500 * (robot.dof() as u64).div_ceil(3)
        }
        super::pipeline::DividerModel::None => 0,
    };
    let lut = 30_000 + (lut_per_dsp(design) * dsp as f64) as u64 + fifo_lut + divider_lut;
    let ff = lut * 2 / 3 + 60_000;
    let bram = 40 + 2 * robot.dof() as u64 + stages / 2;
    // Power: static floor + dynamic ∝ DSP·f_clk (calibrated to the
    // paper's 33.5 W total / 9 W dynamic for iiwa-DRACO at 228 MHz).
    let dynamic = 9.0 * (dsp as f64 / 5073.0) * (design.freq_hz / 228e6);
    let power_w = 24.5 + dynamic;
    Resources { dsp, lut, ff, bram, power_w }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::builtin;

    /// Table II anchor points within a modeling tolerance.
    #[test]
    fn table2_anchors() {
        let iiwa = builtin::iiwa();
        let r = estimate_resources(&Design::draco(&iiwa), &iiwa);
        assert_eq!(r.dsp, 5073);
        let lut_err = (r.lut as f64 - 584_000.0).abs() / 584_000.0;
        assert!(lut_err < 0.15, "DRACO iiwa LUT {} vs 584k", r.lut);

        let d = estimate_resources(&Design::dadu_rbd(&iiwa), &iiwa);
        let lut_err = (d.lut as f64 - 638_000.0).abs() / 638_000.0;
        assert!(lut_err < 0.15, "Dadu iiwa LUT {} vs 638k", d.lut);

        let rs = estimate_resources(&Design::roboshape(&iiwa), &iiwa);
        let lut_err = (rs.lut as f64 - 515_000.0).abs() / 515_000.0;
        assert!(lut_err < 0.15, "Roboshape iiwa LUT {} vs 515k", rs.lut);
    }

    #[test]
    fn power_close_to_paper() {
        let iiwa = builtin::iiwa();
        let p = estimate_resources(&Design::draco(&iiwa), &iiwa).power_w;
        assert!((p - 33.5).abs() < 2.0, "DRACO iiwa power {p} vs 33.5W");
        let pd = estimate_resources(&Design::dadu_rbd(&iiwa), &iiwa).power_w;
        assert!(pd < 40.0 && pd > 24.0, "Dadu power {pd} should be comparable");
    }

    #[test]
    fn atlas_uses_more_of_everything_than_hyq() {
        let hyq = builtin::hyq();
        let atlas = builtin::atlas();
        let rh = estimate_resources(&Design::draco(&hyq), &hyq);
        let ra = estimate_resources(&Design::draco(&atlas), &atlas);
        assert!(ra.dsp > rh.dsp && ra.lut > rh.lut && ra.bram > rh.bram);
    }
}
