//! Spatial rigid-body inertia.
//!
//! Stored in "mass / first moment / rotational inertia at frame origin"
//! form. The dense block form (Featherstone RBDA eq. 2.63):
//!
//! ```text
//!   I = [ Ī_o     m c̃  ]      Ī_o = Ī_com + m c̃ c̃ᵀ
//!       [ m c̃ᵀ    m 1  ]
//! ```

use super::mat6::M6;
use super::v3m3::{M3, V3};
use super::vec::SV;

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Inertia {
    pub mass: f64,
    /// Centre of mass in link coordinates.
    pub com: V3,
    /// Rotational inertia about the frame ORIGIN (not the CoM): Ī_o.
    pub i_o: M3,
}

impl Inertia {
    pub fn zero() -> Inertia {
        Inertia { mass: 0.0, com: V3::ZERO, i_o: M3::ZERO }
    }

    /// Build from CoM-centred rotational inertia (the URDF convention):
    /// Ī_o = Ī_com + m c̃ c̃ᵀ.
    pub fn from_com_inertia(mass: f64, com: V3, i_com: M3) -> Inertia {
        let cx = com.skew();
        let shift = cx.mul_m(&cx.transpose()).scale(mass);
        Inertia { mass, com, i_o: i_com.add_m(&shift) }
    }

    /// f = I v (motion → force).
    pub fn apply(&self, v: &SV) -> SV {
        let mc = self.com.scale(self.mass);
        SV {
            ang: self.i_o.mul_v(&v.ang) + mc.cross(&v.lin),
            lin: v.lin.scale(self.mass) - mc.cross(&v.ang),
        }
    }

    /// Dense symmetric 6×6 (flat row-major [`M6`], blocks as documented
    /// above).
    pub fn to_mat6(&self) -> M6 {
        let mut m = [0.0; 36];
        let mcx = self.com.skew().scale(self.mass).0;
        for i in 0..3 {
            for j in 0..3 {
                m[i * 6 + j] = self.i_o.0[i][j];
                m[i * 6 + (j + 3)] = mcx[i][j];
                m[(i + 3) * 6 + j] = -mcx[i][j]; // (m c̃)ᵀ = -m c̃
            }
            m[(i + 3) * 6 + (i + 3)] = self.mass;
        }
        m
    }

    /// Kinetic energy ½ vᵀ I v.
    pub fn kinetic_energy(&self, v: &SV) -> f64 {
        0.5 * v.dot(&self.apply(v))
    }
}

/// Test-only helpers shared across modules.
#[cfg(test)]
pub mod tests_support {
    use super::*;
    use crate::util::rng::Rng;

    /// Physically valid random inertia: positive mass, SPD rotational
    /// inertia about the CoM built as A Aᵀ + εI, then shifted to origin.
    pub fn rand_inertia(r: &mut Rng) -> Inertia {
        let mass = r.range(0.2, 8.0);
        let com = V3::new(r.range(-0.2, 0.2), r.range(-0.2, 0.2), r.range(-0.2, 0.2));
        let mut a = M3::ZERO;
        for i in 0..3 {
            for j in 0..3 {
                a.0[i][j] = r.range(-0.3, 0.3);
            }
        }
        let mut i_com = a.mul_m(&a.transpose());
        for i in 0..3 {
            i_com.0[i][i] += 0.05;
        }
        Inertia::from_com_inertia(mass, com, i_com)
    }
}

#[cfg(test)]
mod tests {
    use super::tests_support::rand_inertia;
    use super::*;
    use crate::util::check::close;
    use crate::util::rng::Rng;

    #[test]
    fn apply_matches_dense() {
        let mut r = Rng::new(20);
        for _ in 0..64 {
            let ine = rand_inertia(&mut r);
            let v = SV::from_slice(&r.vec_range(6, -2.0, 2.0));
            let f = ine.apply(&v).to_array();
            let m = ine.to_mat6();
            let va = v.to_array();
            for i in 0..6 {
                let mut acc = 0.0;
                for j in 0..6 {
                    acc += m[i * 6 + j] * va[j];
                }
                assert!(close(acc, f[i], 1e-12));
            }
        }
    }

    #[test]
    fn dense_is_symmetric() {
        let mut r = Rng::new(21);
        let ine = rand_inertia(&mut r);
        let m = ine.to_mat6();
        for i in 0..6 {
            for j in 0..6 {
                assert!(close(m[i * 6 + j], m[j * 6 + i], 1e-13));
            }
        }
    }

    #[test]
    fn kinetic_energy_positive() {
        let mut r = Rng::new(22);
        for _ in 0..64 {
            let ine = rand_inertia(&mut r);
            let v = SV::from_slice(&r.vec_range(6, -2.0, 2.0));
            if v.norm() > 1e-6 {
                assert!(ine.kinetic_energy(&v) > 0.0, "inertia must be positive definite");
            }
        }
    }

    #[test]
    fn point_mass_linear_only() {
        let ine = Inertia::from_com_inertia(2.0, V3::ZERO, M3::ZERO);
        let v = SV::new(V3::ZERO, V3::new(1.0, 0.0, 0.0));
        let f = ine.apply(&v);
        assert!(close(f.lin.x(), 2.0, 1e-14));
        assert!(f.ang.norm() < 1e-14);
    }
}
