//! Plücker spatial transforms between link coordinate frames.
//!
//! `Xform { e, r }` represents the motion transform `X` from frame A to
//! frame B where `e` rotates A-coordinates into B-coordinates and `r` is
//! the position of B's origin expressed in A. In block form
//! (Featherstone, RBDA eq. 2.24):
//!
//! ```text
//!   X  = [  E        0 ]        X* = [ E   -E r̃ ]
//!        [ -E r̃      E ]             [ 0      E ]
//! ```

use super::mat6::M6;
use super::v3m3::{M3, V3};
use super::vec::SV;

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Xform {
    /// Rotation A→B.
    pub e: M3,
    /// Origin of B in A coordinates.
    pub r: V3,
}

impl Xform {
    pub fn identity() -> Xform {
        Xform { e: M3::identity(), r: V3::ZERO }
    }

    pub fn rotation(e: M3) -> Xform {
        Xform { e, r: V3::ZERO }
    }

    pub fn translation(r: V3) -> Xform {
        Xform { e: M3::identity(), r }
    }

    /// Motion-vector transform: v_B = X v_A.
    pub fn apply(&self, v: &SV) -> SV {
        let ang = self.e.mul_v(&v.ang);
        let lin = self.e.mul_v(&(v.lin - self.r.cross(&v.ang)));
        SV { ang, lin }
    }

    /// Force-vector transform: f_B = X* f_A.
    pub fn apply_force(&self, f: &SV) -> SV {
        let lin = self.e.mul_v(&f.lin);
        let ang = self.e.mul_v(&(f.ang - self.r.cross(&f.lin)));
        SV { ang, lin }
    }

    /// Inverse motion transform: v_A = X⁻¹ v_B.
    pub fn inv_apply(&self, v: &SV) -> SV {
        let ang = self.e.tmul_v(&v.ang);
        let lin = self.e.tmul_v(&v.lin) + self.r.cross(&ang);
        SV { ang, lin }
    }

    /// Inverse force transform: f_A = X*⁻¹ f_B = Xᵀ f_B.
    /// This is the `X_λ(i)^T f_i` operation of RNEA's backward pass.
    pub fn inv_apply_force(&self, f: &SV) -> SV {
        let lin = self.e.tmul_v(&f.lin);
        let ang = self.e.tmul_v(&f.ang) + self.r.cross(&lin);
        SV { ang, lin }
    }

    /// Composition: `self ∘ first` maps A→C when `first` maps A→B and
    /// `self` maps B→C.
    pub fn compose(&self, first: &Xform) -> Xform {
        Xform {
            e: self.e.mul_m(&first.e),
            r: first.r + first.e.tmul_v(&self.r),
        }
    }

    pub fn inverse(&self) -> Xform {
        Xform { e: self.e.transpose(), r: -self.e.mul_v(&self.r) }
    }

    /// Dense 6×6 motion-transform matrix (flat row-major [`M6`]), used by
    /// the articulated-inertia propagation and exported to the JAX layer.
    pub fn to_mat6(&self) -> M6 {
        let e = self.e.0;
        let erx = self.e.mul_m(&self.r.skew()).0; // E r̃
        let mut m = [0.0; 36];
        for i in 0..3 {
            for j in 0..3 {
                m[i * 6 + j] = e[i][j];
                m[(i + 3) * 6 + (j + 3)] = e[i][j];
                m[(i + 3) * 6 + j] = -erx[i][j];
            }
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::close;
    use crate::util::rng::Rng;

    fn rand_xform(r: &mut Rng) -> Xform {
        let axis = V3::new(r.range(-1.0, 1.0), r.range(-1.0, 1.0), r.range(0.1, 1.0));
        Xform {
            e: M3::rot_axis(&axis, r.range(-3.0, 3.0)),
            r: V3::new(r.range(-1.0, 1.0), r.range(-1.0, 1.0), r.range(-1.0, 1.0)),
        }
    }

    fn rand_sv(r: &mut Rng) -> SV {
        SV::new(
            V3::new(r.range(-2.0, 2.0), r.range(-2.0, 2.0), r.range(-2.0, 2.0)),
            V3::new(r.range(-2.0, 2.0), r.range(-2.0, 2.0), r.range(-2.0, 2.0)),
        )
    }

    #[test]
    fn inverse_roundtrip() {
        let mut r = Rng::new(10);
        for _ in 0..64 {
            let x = rand_xform(&mut r);
            let v = rand_sv(&mut r);
            let back = x.inv_apply(&x.apply(&v));
            assert!((back - v).norm() < 1e-12);
            let f = rand_sv(&mut r);
            let backf = x.inv_apply_force(&x.apply_force(&f));
            assert!((backf - f).norm() < 1e-12);
        }
    }

    /// Power invariance: a force and motion pair under a frame change
    /// must preserve their scalar product: (Xv)·(X*f) = v·f.
    #[test]
    fn power_invariance() {
        let mut r = Rng::new(11);
        for _ in 0..64 {
            let x = rand_xform(&mut r);
            let v = rand_sv(&mut r);
            let f = rand_sv(&mut r);
            assert!(close(x.apply(&v).dot(&x.apply_force(&f)), v.dot(&f), 1e-11));
        }
    }

    #[test]
    fn compose_matches_sequential_apply() {
        let mut r = Rng::new(12);
        for _ in 0..64 {
            let x1 = rand_xform(&mut r); // A->B
            let x2 = rand_xform(&mut r); // B->C
            let v = rand_sv(&mut r);
            let seq = x2.apply(&x1.apply(&v));
            let comp = x2.compose(&x1).apply(&v);
            assert!((seq - comp).norm() < 1e-11);
        }
    }

    #[test]
    fn mat6_matches_apply() {
        let mut r = Rng::new(13);
        for _ in 0..32 {
            let x = rand_xform(&mut r);
            let v = rand_sv(&mut r);
            let m = x.to_mat6();
            let va = v.to_array();
            let mut out = [0.0; 6];
            for i in 0..6 {
                for j in 0..6 {
                    out[i] += m[i * 6 + j] * va[j];
                }
            }
            let want = x.apply(&v).to_array();
            for i in 0..6 {
                assert!(close(out[i], want[i], 1e-12));
            }
        }
    }

    #[test]
    fn inverse_compose_is_identity() {
        let mut r = Rng::new(14);
        for _ in 0..32 {
            let x = rand_xform(&mut r);
            let id = x.compose(&x.inverse());
            let v = rand_sv(&mut r);
            assert!((id.apply(&v) - v).norm() < 1e-11);
        }
    }

    /// Cross products commute with frame changes:
    /// X(v × m) = (Xv) × (Xm) and X*(v ×* f) = (Xv) ×* (X*f).
    #[test]
    fn cross_products_are_equivariant() {
        let mut r = Rng::new(15);
        for _ in 0..48 {
            let x = rand_xform(&mut r);
            let v = rand_sv(&mut r);
            let m = rand_sv(&mut r);
            let f = rand_sv(&mut r);
            let a = x.apply(&v.crm(&m));
            let b = x.apply(&v).crm(&x.apply(&m));
            assert!((a - b).norm() < 1e-10);
            let c = x.apply_force(&v.crf(&f));
            let d = x.apply(&v).crf(&x.apply_force(&f));
            assert!((c - d).norm() < 1e-10);
        }
    }
}
