//! Spatial (6-D) motion and force vectors, Featherstone convention:
//! the angular part occupies components 0..3, the linear part 3..6.

use super::v3m3::V3;
use std::ops::{Add, Neg, Sub};

/// A spatial vector. Whether it is a *motion* or a *force* vector is a
/// matter of which operations you apply (crm vs crf, X vs X*).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct SV {
    pub ang: V3,
    pub lin: V3,
}

impl SV {
    pub const ZERO: SV = SV { ang: V3([0.0; 3]), lin: V3([0.0; 3]) };

    pub fn new(ang: V3, lin: V3) -> SV {
        SV { ang, lin }
    }

    pub fn from_slice(x: &[f64]) -> SV {
        assert_eq!(x.len(), 6);
        SV { ang: V3([x[0], x[1], x[2]]), lin: V3([x[3], x[4], x[5]]) }
    }

    pub fn to_array(&self) -> [f64; 6] {
        let a = self.ang.0;
        let l = self.lin.0;
        [a[0], a[1], a[2], l[0], l[1], l[2]]
    }

    pub fn scale(&self, s: f64) -> SV {
        SV { ang: self.ang.scale(s), lin: self.lin.scale(s) }
    }

    /// Scalar product mᵀf — pairing of a motion with a force vector
    /// (e.g. Sᵀ f to project a force onto a joint axis).
    pub fn dot(&self, o: &SV) -> f64 {
        self.ang.dot(&o.ang) + self.lin.dot(&o.lin)
    }

    /// Spatial cross product for MOTION vectors: self × m.
    /// (w,v) × (mw,mv) = (w×mw, w×mv + v×mw)
    pub fn crm(&self, m: &SV) -> SV {
        SV {
            ang: self.ang.cross(&m.ang),
            lin: self.ang.cross(&m.lin) + self.lin.cross(&m.ang),
        }
    }

    /// Spatial cross product for FORCE vectors: self ×* f = -crm(self)ᵀ f.
    /// (w,v) ×* (n,f) = (w×n + v×f, w×f)
    pub fn crf(&self, f: &SV) -> SV {
        SV {
            ang: self.ang.cross(&f.ang) + self.lin.cross(&f.lin),
            lin: self.ang.cross(&f.lin),
        }
    }

    pub fn norm(&self) -> f64 {
        self.dot(self).sqrt()
    }
}

impl Add for SV {
    type Output = SV;
    fn add(self, o: SV) -> SV {
        SV { ang: self.ang + o.ang, lin: self.lin + o.lin }
    }
}

impl Sub for SV {
    type Output = SV;
    fn sub(self, o: SV) -> SV {
        SV { ang: self.ang - o.ang, lin: self.lin - o.lin }
    }
}

impl Neg for SV {
    type Output = SV;
    fn neg(self) -> SV {
        SV { ang: -self.ang, lin: -self.lin }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::close;
    use crate::util::rng::Rng;

    fn rand_sv(r: &mut Rng) -> SV {
        SV::new(
            V3::new(r.range(-2.0, 2.0), r.range(-2.0, 2.0), r.range(-2.0, 2.0)),
            V3::new(r.range(-2.0, 2.0), r.range(-2.0, 2.0), r.range(-2.0, 2.0)),
        )
    }

    #[test]
    fn crm_self_is_zero() {
        let mut r = Rng::new(1);
        for _ in 0..32 {
            let v = rand_sv(&mut r);
            assert!(v.crm(&v).norm() < 1e-12);
        }
    }

    /// Duality: (v × m) · f = -m · (v ×* f). This is the defining relation
    /// crf = -crmᵀ and catches sign errors that silently corrupt RNEA.
    #[test]
    fn crm_crf_duality() {
        let mut r = Rng::new(2);
        for _ in 0..64 {
            let v = rand_sv(&mut r);
            let m = rand_sv(&mut r);
            let f = rand_sv(&mut r);
            let lhs = v.crm(&m).dot(&f);
            let rhs = -m.dot(&v.crf(&f));
            assert!(close(lhs, rhs, 1e-12), "{lhs} vs {rhs}");
        }
    }

    #[test]
    fn array_roundtrip() {
        let v = SV::from_slice(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(v.to_array(), [1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(v.ang.z(), 3.0);
        assert_eq!(v.lin.x(), 4.0);
    }

    #[test]
    fn jacobi_identity_for_crm() {
        // a×(b×c) + b×(c×a) + c×(a×b) = 0 for the motion algebra se(3).
        let mut r = Rng::new(3);
        for _ in 0..32 {
            let a = rand_sv(&mut r);
            let b = rand_sv(&mut r);
            let c = rand_sv(&mut r);
            let s = a.crm(&b.crm(&c)) + b.crm(&c.crm(&a)) + c.crm(&a.crm(&b));
            assert!(s.norm() < 1e-11, "{}", s.norm());
        }
    }
}
