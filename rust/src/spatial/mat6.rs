//! Dense 6×6 matrix ops for articulated-body quantities.
//!
//! `M6` is stored **flat row-major** (`[f64; 36]`, entry (i, j) at
//! `i * 6 + j`) rather than as nested `[[f64; 6]; 6]` rows: the kernels
//! below are straight-line loops over contiguous lanes with no
//! data-dependent branches, which is what the autovectorizer needs to
//! turn `mul6`/`outer6` — the ops that dominate the Minv/CRBA sweeps —
//! into packed FMA streams (the CPU analogue of the accelerator's
//! MAC-array RTP datapath).

use super::vec::SV;
use super::xform::Xform;

/// Flat row-major 6×6 matrix: entry (i, j) lives at `i * 6 + j`.
pub type M6 = [f64; 36];

pub fn zero6() -> M6 {
    [0.0; 36]
}

pub fn ident6() -> M6 {
    let mut m = zero6();
    for i in 0..6 {
        m[i * 6 + i] = 1.0;
    }
    m
}

pub fn add6(a: &M6, b: &M6) -> M6 {
    let mut out = *a;
    for (o, x) in out.iter_mut().zip(b) {
        *o += x;
    }
    out
}

pub fn sub6(a: &M6, b: &M6) -> M6 {
    let mut out = *a;
    for (o, x) in out.iter_mut().zip(b) {
        *o -= x;
    }
    out
}

pub fn scale6(a: &M6, s: f64) -> M6 {
    let mut out = *a;
    for x in out.iter_mut() {
        *x *= s;
    }
    out
}

/// Branch-free row-major product: for each (i, k) the scalar `a[i][k]`
/// streams across a contiguous row of `b`, so the j-loop vectorizes.
pub fn mul6(a: &M6, b: &M6) -> M6 {
    let mut out = zero6();
    for i in 0..6 {
        for k in 0..6 {
            let aik = a[i * 6 + k];
            for j in 0..6 {
                out[i * 6 + j] += aik * b[k * 6 + j];
            }
        }
    }
    out
}

pub fn t6(a: &M6) -> M6 {
    let mut out = zero6();
    for i in 0..6 {
        for j in 0..6 {
            out[i * 6 + j] = a[j * 6 + i];
        }
    }
    out
}

pub fn matvec6(a: &M6, v: &SV) -> SV {
    let x = v.to_array();
    let mut y = [0.0; 6];
    for i in 0..6 {
        let mut acc = 0.0;
        for j in 0..6 {
            acc += a[i * 6 + j] * x[j];
        }
        y[i] = acc;
    }
    SV::from_slice(&y)
}

/// Outer product u vᵀ.
pub fn outer6(u: &SV, v: &SV) -> M6 {
    let ua = u.to_array();
    let va = v.to_array();
    let mut out = zero6();
    for i in 0..6 {
        for j in 0..6 {
            out[i * 6 + j] = ua[i] * va[j];
        }
    }
    out
}

/// Fused congruence transform XᵀAX — the hot inner operation of every
/// articulated-inertia propagation. Accumulates each entry in the same
/// k-ascending order as `mul6(&t6(x), &mul6(a, x))` (so results are
/// bitwise identical to the composed form) but without materializing the
/// transpose or an extra intermediate, and with both passes running over
/// contiguous rows.
pub fn xtax(x: &M6, a: &M6) -> M6 {
    // t = A X
    let mut t = zero6();
    for i in 0..6 {
        for k in 0..6 {
            let aik = a[i * 6 + k];
            for j in 0..6 {
                t[i * 6 + j] += aik * x[k * 6 + j];
            }
        }
    }
    // out = Xᵀ t: out[i][j] = Σ_k x[k][i] · t[k][j]; k outermost keeps
    // both operand rows contiguous and the per-entry addition order
    // identical to mul6's.
    let mut out = zero6();
    for k in 0..6 {
        for i in 0..6 {
            let xki = x[k * 6 + i];
            for j in 0..6 {
                out[i * 6 + j] += xki * t[k * 6 + j];
            }
        }
    }
    out
}

/// Articulated-inertia frame change: given `x` mapping parent→child
/// motion coordinates and `ia` expressed in the child frame, returns the
/// parent-frame contribution `Xᵀ I X` (Featherstone RBDA eq. 7.23 term).
pub fn transform_inertia_to_parent(x: &Xform, ia: &M6) -> M6 {
    xtax(&x.to_mat6(), ia)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spatial::inertia::tests_support::rand_inertia;
    use crate::spatial::v3m3::{M3, V3};
    use crate::util::check::close;
    use crate::util::rng::Rng;

    fn rand_m6(r: &mut Rng) -> M6 {
        let mut a = zero6();
        for x in a.iter_mut() {
            *x = r.range(-1.0, 1.0);
        }
        a
    }

    #[test]
    fn mul_identity() {
        let mut r = Rng::new(30);
        let a = rand_m6(&mut r);
        let out = mul6(&a, &ident6());
        for i in 0..36 {
            assert!(close(out[i], a[i], 1e-14));
        }
    }

    #[test]
    fn transpose_involution() {
        let mut r = Rng::new(31);
        let a = rand_m6(&mut r);
        assert_eq!(t6(&t6(&a)), a);
    }

    /// The fused congruence transform must agree bitwise with the
    /// composed `Xᵀ (A X)` it replaced (same per-entry addition order).
    #[test]
    fn fused_xtax_matches_composed() {
        let mut r = Rng::new(33);
        for _ in 0..16 {
            let x = rand_m6(&mut r);
            let a = rand_m6(&mut r);
            assert_eq!(xtax(&x, &a), mul6(&t6(&x), &mul6(&a, &x)));
        }
    }

    /// Inertia transformed to the parent frame must agree with computing
    /// the force response through the transform chain:
    /// (Xᵀ I X) v = Xᵀ (I (X v)) = X*⁻¹ applied to I(Xv).
    #[test]
    fn inertia_transform_consistent() {
        let mut r = Rng::new(32);
        for _ in 0..32 {
            let ine = rand_inertia(&mut r);
            let x = Xform {
                e: M3::rot_axis(&V3::new(0.1, 0.7, 0.4), r.range(-2.0, 2.0)),
                r: V3::new(r.range(-0.5, 0.5), r.range(-0.5, 0.5), r.range(-0.5, 0.5)),
            };
            let ia = ine.to_mat6();
            let ip = transform_inertia_to_parent(&x, &ia);
            let v = SV::from_slice(&r.vec_range(6, -1.0, 1.0));
            let lhs = matvec6(&ip, &v);
            let rhs = x.inv_apply_force(&ine.apply(&x.apply(&v)));
            assert!((lhs - rhs).norm() < 1e-10);
        }
    }

    #[test]
    fn outer_rank_one() {
        let u = SV::from_slice(&[1.0, 0.0, 2.0, 0.0, -1.0, 0.5]);
        let v = SV::from_slice(&[0.5, 1.0, 0.0, 3.0, 0.0, -2.0]);
        let m = outer6(&u, &v);
        let w = SV::from_slice(&[1.0, 1.0, 1.0, 1.0, 1.0, 1.0]);
        // (u vᵀ) w = u (v·w)
        let got = matvec6(&m, &w);
        let want = u.scale(v.dot(&w));
        assert!((got - want).norm() < 1e-12);
    }
}
