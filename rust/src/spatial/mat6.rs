//! Dense 6×6 matrix ops for articulated-body quantities.

use super::vec::SV;
use super::xform::Xform;

pub type M6 = [[f64; 6]; 6];

pub fn zero6() -> M6 {
    [[0.0; 6]; 6]
}

pub fn ident6() -> M6 {
    let mut m = zero6();
    for i in 0..6 {
        m[i][i] = 1.0;
    }
    m
}

pub fn add6(a: &M6, b: &M6) -> M6 {
    let mut out = *a;
    for i in 0..6 {
        for j in 0..6 {
            out[i][j] += b[i][j];
        }
    }
    out
}

pub fn sub6(a: &M6, b: &M6) -> M6 {
    let mut out = *a;
    for i in 0..6 {
        for j in 0..6 {
            out[i][j] -= b[i][j];
        }
    }
    out
}

pub fn scale6(a: &M6, s: f64) -> M6 {
    let mut out = *a;
    for row in &mut out {
        for x in row {
            *x *= s;
        }
    }
    out
}

pub fn mul6(a: &M6, b: &M6) -> M6 {
    let mut out = zero6();
    for i in 0..6 {
        for k in 0..6 {
            let aik = a[i][k];
            if aik == 0.0 {
                continue;
            }
            for j in 0..6 {
                out[i][j] += aik * b[k][j];
            }
        }
    }
    out
}

pub fn t6(a: &M6) -> M6 {
    let mut out = zero6();
    for i in 0..6 {
        for j in 0..6 {
            out[i][j] = a[j][i];
        }
    }
    out
}

pub fn matvec6(a: &M6, v: &SV) -> SV {
    let x = v.to_array();
    let mut y = [0.0; 6];
    for i in 0..6 {
        for j in 0..6 {
            y[i] += a[i][j] * x[j];
        }
    }
    SV::from_slice(&y)
}

/// Outer product u vᵀ.
pub fn outer6(u: &SV, v: &SV) -> M6 {
    let ua = u.to_array();
    let va = v.to_array();
    let mut out = zero6();
    for i in 0..6 {
        for j in 0..6 {
            out[i][j] = ua[i] * va[j];
        }
    }
    out
}

/// Articulated-inertia frame change: given `x` mapping parent→child
/// motion coordinates and `ia` expressed in the child frame, returns the
/// parent-frame contribution `Xᵀ I X` (Featherstone RBDA eq. 7.23 term).
pub fn transform_inertia_to_parent(x: &Xform, ia: &M6) -> M6 {
    let xm = x.to_mat6();
    mul6(&t6(&xm), &mul6(ia, &xm))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spatial::inertia::tests_support::rand_inertia;
    use crate::spatial::v3m3::{M3, V3};
    use crate::util::check::close;
    use crate::util::rng::Rng;

    #[test]
    fn mul_identity() {
        let mut r = Rng::new(30);
        let mut a = zero6();
        for i in 0..6 {
            for j in 0..6 {
                a[i][j] = r.range(-1.0, 1.0);
            }
        }
        let out = mul6(&a, &ident6());
        for i in 0..6 {
            for j in 0..6 {
                assert!(close(out[i][j], a[i][j], 1e-14));
            }
        }
    }

    #[test]
    fn transpose_involution() {
        let mut r = Rng::new(31);
        let mut a = zero6();
        for i in 0..6 {
            for j in 0..6 {
                a[i][j] = r.range(-1.0, 1.0);
            }
        }
        assert_eq!(t6(&t6(&a)), a);
    }

    /// Inertia transformed to the parent frame must agree with computing
    /// the force response through the transform chain:
    /// (Xᵀ I X) v = Xᵀ (I (X v)) = X*⁻¹ applied to I(Xv).
    #[test]
    fn inertia_transform_consistent() {
        let mut r = Rng::new(32);
        for _ in 0..32 {
            let ine = rand_inertia(&mut r);
            let x = Xform {
                e: M3::rot_axis(&V3::new(0.1, 0.7, 0.4), r.range(-2.0, 2.0)),
                r: V3::new(r.range(-0.5, 0.5), r.range(-0.5, 0.5), r.range(-0.5, 0.5)),
            };
            let ia = ine.to_mat6();
            let ip = transform_inertia_to_parent(&x, &ia);
            let v = SV::from_slice(&r.vec_range(6, -1.0, 1.0));
            let lhs = matvec6(&ip, &v);
            let rhs = x.inv_apply_force(&ine.apply(&x.apply(&v)));
            assert!((lhs - rhs).norm() < 1e-10);
        }
    }

    #[test]
    fn outer_rank_one() {
        let u = SV::from_slice(&[1.0, 0.0, 2.0, 0.0, -1.0, 0.5]);
        let v = SV::from_slice(&[0.5, 1.0, 0.0, 3.0, 0.0, -2.0]);
        let m = outer6(&u, &v);
        let w = SV::from_slice(&[1.0, 1.0, 1.0, 1.0, 1.0, 1.0]);
        // (u vᵀ) w = u (v·w)
        let got = matvec6(&m, &w);
        let want = u.scale(v.dot(&w));
        assert!((got - want).norm() < 1e-12);
    }
}
