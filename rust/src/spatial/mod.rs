//! Spatial vector algebra (Featherstone) — the numerical substrate for
//! all RBD computation: 6-D motion/force vectors, Plücker transforms,
//! spatial inertia, and small dense matrices.

pub mod dmat;
pub mod inertia;
pub mod mat6;
pub mod v3m3;
pub mod vec;
pub mod xform;

pub use dmat::DMat;
pub use inertia::Inertia;
pub use mat6::M6;
pub use v3m3::{M3, V3};
pub use vec::SV;
pub use xform::Xform;
