//! 3-vectors and 3×3 matrices (column-free, plain arrays, zero alloc).

use std::ops::{Add, Mul, Neg, Sub};

/// A 3-vector.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct V3(pub [f64; 3]);

/// A 3×3 matrix, row-major.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct M3(pub [[f64; 3]; 3]);

impl V3 {
    pub const ZERO: V3 = V3([0.0; 3]);

    pub fn new(x: f64, y: f64, z: f64) -> V3 {
        V3([x, y, z])
    }

    pub fn x(&self) -> f64 {
        self.0[0]
    }
    pub fn y(&self) -> f64 {
        self.0[1]
    }
    pub fn z(&self) -> f64 {
        self.0[2]
    }

    pub fn dot(&self, o: &V3) -> f64 {
        self.0[0] * o.0[0] + self.0[1] * o.0[1] + self.0[2] * o.0[2]
    }

    pub fn cross(&self, o: &V3) -> V3 {
        V3([
            self.0[1] * o.0[2] - self.0[2] * o.0[1],
            self.0[2] * o.0[0] - self.0[0] * o.0[2],
            self.0[0] * o.0[1] - self.0[1] * o.0[0],
        ])
    }

    pub fn norm(&self) -> f64 {
        self.dot(self).sqrt()
    }

    pub fn scale(&self, s: f64) -> V3 {
        V3([self.0[0] * s, self.0[1] * s, self.0[2] * s])
    }

    pub fn normalized(&self) -> V3 {
        let n = self.norm();
        assert!(n > 1e-12, "cannot normalize near-zero vector");
        self.scale(1.0 / n)
    }

    /// Skew-symmetric cross-product matrix: skew(v) * w == v × w.
    pub fn skew(&self) -> M3 {
        let [x, y, z] = self.0;
        M3([[0.0, -z, y], [z, 0.0, -x], [-y, x, 0.0]])
    }
}

impl Add for V3 {
    type Output = V3;
    fn add(self, o: V3) -> V3 {
        V3([self.0[0] + o.0[0], self.0[1] + o.0[1], self.0[2] + o.0[2]])
    }
}

impl Sub for V3 {
    type Output = V3;
    fn sub(self, o: V3) -> V3 {
        V3([self.0[0] - o.0[0], self.0[1] - o.0[1], self.0[2] - o.0[2]])
    }
}

impl Neg for V3 {
    type Output = V3;
    fn neg(self) -> V3 {
        V3([-self.0[0], -self.0[1], -self.0[2]])
    }
}

impl M3 {
    pub const ZERO: M3 = M3([[0.0; 3]; 3]);

    pub fn identity() -> M3 {
        M3([[1.0, 0.0, 0.0], [0.0, 1.0, 0.0], [0.0, 0.0, 1.0]])
    }

    pub fn diag(x: f64, y: f64, z: f64) -> M3 {
        M3([[x, 0.0, 0.0], [0.0, y, 0.0], [0.0, 0.0, z]])
    }

    pub fn transpose(&self) -> M3 {
        let m = &self.0;
        M3([[m[0][0], m[1][0], m[2][0]], [m[0][1], m[1][1], m[2][1]], [m[0][2], m[1][2], m[2][2]]])
    }

    pub fn mul_v(&self, v: &V3) -> V3 {
        let m = &self.0;
        V3([
            m[0][0] * v.0[0] + m[0][1] * v.0[1] + m[0][2] * v.0[2],
            m[1][0] * v.0[0] + m[1][1] * v.0[1] + m[1][2] * v.0[2],
            m[2][0] * v.0[0] + m[2][1] * v.0[1] + m[2][2] * v.0[2],
        ])
    }

    /// vᵀ M (equivalently Mᵀ v).
    pub fn tmul_v(&self, v: &V3) -> V3 {
        self.transpose().mul_v(v)
    }

    pub fn scale(&self, s: f64) -> M3 {
        let mut out = *self;
        for r in &mut out.0 {
            for x in r {
                *x *= s;
            }
        }
        out
    }

    /// Rotation matrix that maps coordinates through a rotation of `angle`
    /// about `axis` (Rodrigues). This is the *coordinate transform* E used
    /// in Featherstone's jcalc: E = exp(-angle * skew(axis)) expresses a
    /// vector of the predecessor frame in the successor frame.
    pub fn rot_axis(axis: &V3, angle: f64) -> M3 {
        let a = axis.normalized();
        let (s, c) = angle.sin_cos();
        let k = a.skew();
        // E = I - sin(q) K + (1-cos(q)) K^2   (transpose of the rotation
        // that moves vectors by +q about the axis)
        let k2 = k.mul_m(&k);
        let mut e = M3::identity();
        for i in 0..3 {
            for j in 0..3 {
                e.0[i][j] += -s * k.0[i][j] + (1.0 - c) * k2.0[i][j];
            }
        }
        e
    }

    pub fn mul_m(&self, o: &M3) -> M3 {
        let mut out = M3::ZERO;
        for i in 0..3 {
            for j in 0..3 {
                let mut acc = 0.0;
                for k in 0..3 {
                    acc += self.0[i][k] * o.0[k][j];
                }
                out.0[i][j] = acc;
            }
        }
        out
    }

    pub fn add_m(&self, o: &M3) -> M3 {
        let mut out = *self;
        for i in 0..3 {
            for j in 0..3 {
                out.0[i][j] += o.0[i][j];
            }
        }
        out
    }

    pub fn sub_m(&self, o: &M3) -> M3 {
        let mut out = *self;
        for i in 0..3 {
            for j in 0..3 {
                out.0[i][j] -= o.0[i][j];
            }
        }
        out
    }

    pub fn det(&self) -> f64 {
        let m = &self.0;
        m[0][0] * (m[1][1] * m[2][2] - m[1][2] * m[2][1])
            - m[0][1] * (m[1][0] * m[2][2] - m[1][2] * m[2][0])
            + m[0][2] * (m[1][0] * m[2][1] - m[1][1] * m[2][0])
    }
}

impl Mul for M3 {
    type Output = M3;
    fn mul(self, o: M3) -> M3 {
        self.mul_m(&o)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::close;

    #[test]
    fn cross_anticommutes() {
        let a = V3::new(1.0, 2.0, 3.0);
        let b = V3::new(-0.5, 4.0, 0.25);
        let ab = a.cross(&b);
        let ba = b.cross(&a);
        for i in 0..3 {
            assert!(close(ab.0[i], -ba.0[i], 1e-14));
        }
    }

    #[test]
    fn skew_matches_cross() {
        let a = V3::new(0.3, -1.2, 2.0);
        let b = V3::new(5.0, 0.1, -0.7);
        let s = a.skew().mul_v(&b);
        let c = a.cross(&b);
        for i in 0..3 {
            assert!(close(s.0[i], c.0[i], 1e-14));
        }
    }

    #[test]
    fn rotation_is_orthonormal() {
        let e = M3::rot_axis(&V3::new(0.0, 0.0, 1.0), 0.73);
        let ete = e.transpose().mul_m(&e);
        let id = M3::identity();
        for i in 0..3 {
            for j in 0..3 {
                assert!(close(ete.0[i][j], id.0[i][j], 1e-12));
            }
        }
        assert!(close(e.det(), 1.0, 1e-12));
    }

    #[test]
    fn rotation_about_z_convention() {
        // Featherstone rz(q): E maps old-frame coords into a frame rotated
        // by +q about z. A point on +x axis expressed in rotated frame has
        // negative y... specifically E = [[c, s, 0], [-s, c, 0], [0,0,1]].
        let q = 0.3_f64;
        let e = M3::rot_axis(&V3::new(0.0, 0.0, 1.0), q);
        assert!(close(e.0[0][0], q.cos(), 1e-14));
        assert!(close(e.0[0][1], q.sin(), 1e-14));
        assert!(close(e.0[1][0], -q.sin(), 1e-14));
    }

    #[test]
    fn rot_compose_matches_angle_sum() {
        let ax = V3::new(0.0, 1.0, 0.0);
        let e1 = M3::rot_axis(&ax, 0.4);
        let e2 = M3::rot_axis(&ax, 0.5);
        let e12 = M3::rot_axis(&ax, 0.9);
        let prod = e2.mul_m(&e1);
        for i in 0..3 {
            for j in 0..3 {
                assert!(close(prod.0[i][j], e12.0[i][j], 1e-12));
            }
        }
    }

    #[test]
    fn tmul_is_transpose_mul() {
        let m = M3([[1.0, 2.0, 3.0], [4.0, 5.0, 6.0], [7.0, 8.0, 10.0]]);
        let v = V3::new(-1.0, 0.5, 2.0);
        let a = m.tmul_v(&v);
        let b = m.transpose().mul_v(&v);
        for i in 0..3 {
            assert!(close(a.0[i], b.0[i], 1e-14));
        }
    }
}
