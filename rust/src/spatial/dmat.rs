//! Dynamically-sized dense matrices (row-major), with LU factorization.
//!
//! Used for joint-space quantities: the mass matrix M(q) ∈ R^{N×N}, its
//! inverse, dynamics derivative blocks, and the LQR/MPC Riccati algebra.

use crate::util::rng::Rng;

#[derive(Debug, Clone, PartialEq)]
pub struct DMat {
    pub rows: usize,
    pub cols: usize,
    pub d: Vec<f64>,
}

impl DMat {
    pub fn zeros(rows: usize, cols: usize) -> DMat {
        DMat { rows, cols, d: vec![0.0; rows * cols] }
    }

    pub fn identity(n: usize) -> DMat {
        let mut m = DMat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> DMat {
        let r = rows.len();
        let c = if r > 0 { rows[0].len() } else { 0 };
        let mut m = DMat::zeros(r, c);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(row.len(), c, "ragged rows");
            m.d[i * c..(i + 1) * c].copy_from_slice(row);
        }
        m
    }

    pub fn random(rows: usize, cols: usize, rng: &mut Rng, lo: f64, hi: f64) -> DMat {
        DMat { rows, cols, d: rng.vec_range(rows * cols, lo, hi) }
    }

    pub fn t(&self) -> DMat {
        let mut out = DMat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }

    pub fn matmul(&self, o: &DMat) -> DMat {
        assert_eq!(self.cols, o.rows, "matmul dim mismatch");
        let mut out = DMat::zeros(self.rows, o.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let aik = self[(i, k)];
                if aik == 0.0 {
                    continue;
                }
                let orow = &o.d[k * o.cols..(k + 1) * o.cols];
                let out_row = &mut out.d[i * o.cols..(i + 1) * o.cols];
                for (oo, &b) in out_row.iter_mut().zip(orow) {
                    *oo += aik * b;
                }
            }
        }
        out
    }

    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, v.len());
        let mut out = vec![0.0; self.rows];
        for i in 0..self.rows {
            let row = &self.d[i * self.cols..(i + 1) * self.cols];
            out[i] = row.iter().zip(v).map(|(a, b)| a * b).sum();
        }
        out
    }

    pub fn add(&self, o: &DMat) -> DMat {
        assert_eq!((self.rows, self.cols), (o.rows, o.cols));
        DMat {
            rows: self.rows,
            cols: self.cols,
            d: self.d.iter().zip(&o.d).map(|(a, b)| a + b).collect(),
        }
    }

    pub fn sub(&self, o: &DMat) -> DMat {
        assert_eq!((self.rows, self.cols), (o.rows, o.cols));
        DMat {
            rows: self.rows,
            cols: self.cols,
            d: self.d.iter().zip(&o.d).map(|(a, b)| a - b).collect(),
        }
    }

    pub fn scale(&self, s: f64) -> DMat {
        DMat { rows: self.rows, cols: self.cols, d: self.d.iter().map(|x| x * s).collect() }
    }

    pub fn frobenius(&self) -> f64 {
        self.d.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    pub fn max_abs(&self) -> f64 {
        self.d.iter().fold(0.0, |m, x| m.max(x.abs()))
    }

    /// Symmetrize in place: (A + Aᵀ)/2. Used to keep Riccati iterates SPD.
    pub fn symmetrize(&self) -> DMat {
        self.add(&self.t()).scale(0.5)
    }

    /// LU factorization with partial pivoting. Returns (LU, perm) or None
    /// if singular to machine precision.
    pub fn lu(&self) -> Option<(DMat, Vec<usize>)> {
        assert_eq!(self.rows, self.cols, "lu requires square");
        let n = self.rows;
        let mut a = self.clone();
        let mut perm: Vec<usize> = (0..n).collect();
        for k in 0..n {
            // Pivot
            let mut p = k;
            let mut best = a[(k, k)].abs();
            for i in k + 1..n {
                let v = a[(i, k)].abs();
                if v > best {
                    best = v;
                    p = i;
                }
            }
            if best < 1e-13 {
                return None;
            }
            if p != k {
                for j in 0..n {
                    a.d.swap(k * n + j, p * n + j);
                }
                perm.swap(k, p);
            }
            let pivot = a[(k, k)];
            for i in k + 1..n {
                let l = a[(i, k)] / pivot;
                a[(i, k)] = l;
                for j in k + 1..n {
                    a[(i, j)] -= l * a[(k, j)];
                }
            }
        }
        Some((a, perm))
    }

    /// Solve A x = b via LU.
    pub fn solve(&self, b: &[f64]) -> Option<Vec<f64>> {
        let n = self.rows;
        assert_eq!(b.len(), n);
        let (lu, perm) = self.lu()?;
        let mut x: Vec<f64> = perm.iter().map(|&p| b[p]).collect();
        // Forward substitution (L unit-diagonal)
        for i in 0..n {
            for j in 0..i {
                x[i] -= lu[(i, j)] * x[j];
            }
        }
        // Back substitution
        for i in (0..n).rev() {
            for j in i + 1..n {
                x[i] -= lu[(i, j)] * x[j];
            }
            x[i] /= lu[(i, i)];
        }
        Some(x)
    }

    /// Dense inverse via LU column solves. O(n^3); fine for N ≤ 64.
    pub fn inverse(&self) -> Option<DMat> {
        let n = self.rows;
        let mut out = DMat::zeros(n, n);
        let (lu, perm) = self.lu()?;
        for c in 0..n {
            // b = e_c permuted
            let mut x: Vec<f64> = perm.iter().map(|&p| if p == c { 1.0 } else { 0.0 }).collect();
            for i in 0..n {
                for j in 0..i {
                    x[i] -= lu[(i, j)] * x[j];
                }
            }
            for i in (0..n).rev() {
                for j in i + 1..n {
                    x[i] -= lu[(i, j)] * x[j];
                }
                x[i] /= lu[(i, i)];
            }
            for r in 0..n {
                out[(r, c)] = x[r];
            }
        }
        Some(out)
    }
}

impl std::ops::Index<(usize, usize)> for DMat {
    type Output = f64;
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.d[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for DMat {
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.d[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{close, Config};

    #[test]
    fn identity_solve() {
        let m = DMat::identity(4);
        let b = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(m.solve(&b).unwrap(), b);
    }

    #[test]
    fn solve_known_system() {
        let a = DMat::from_rows(&[vec![2.0, 1.0], vec![1.0, 3.0]]);
        let x = a.solve(&[5.0, 10.0]).unwrap();
        assert!(close(x[0], 1.0, 1e-12));
        assert!(close(x[1], 3.0, 1e-12));
    }

    #[test]
    fn inverse_times_self_is_identity() {
        crate::util::check::forall_res(
            "dmat-inverse",
            Config { cases: 64, ..Default::default() },
            |r| {
                let n = 1 + r.below(8);
                // Diagonally-dominant => invertible.
                let mut m = DMat::random(n, n, r, -1.0, 1.0);
                for i in 0..n {
                    m[(i, i)] += n as f64;
                }
                m
            },
            |m| {
                let inv = m.inverse().ok_or_else(|| "singular".to_string())?;
                let prod = m.matmul(&inv);
                let id = DMat::identity(m.rows);
                let err = prod.sub(&id).max_abs();
                if err < 1e-9 {
                    Ok(())
                } else {
                    Err(format!("max err {err}"))
                }
            },
        );
    }

    #[test]
    fn singular_detected() {
        let m = DMat::from_rows(&[vec![1.0, 2.0], vec![2.0, 4.0]]);
        assert!(m.lu().is_none());
        assert!(m.inverse().is_none());
    }

    #[test]
    fn matmul_assoc() {
        let mut r = Rng::new(40);
        let a = DMat::random(3, 4, &mut r, -1.0, 1.0);
        let b = DMat::random(4, 2, &mut r, -1.0, 1.0);
        let c = DMat::random(2, 5, &mut r, -1.0, 1.0);
        let l = a.matmul(&b).matmul(&c);
        let rr = a.matmul(&b.matmul(&c));
        assert!(l.sub(&rr).max_abs() < 1e-12);
    }

    #[test]
    fn transpose_of_product() {
        let mut r = Rng::new(41);
        let a = DMat::random(3, 4, &mut r, -1.0, 1.0);
        let b = DMat::random(4, 2, &mut r, -1.0, 1.0);
        let lhs = a.matmul(&b).t();
        let rhs = b.t().matmul(&a.t());
        assert!(lhs.sub(&rhs).max_abs() < 1e-13);
    }
}
