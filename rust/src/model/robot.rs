//! Robot model: a topology tree of rigid links connected by 1-DOF joints,
//! plus JSON (de)serialization shared with the Python compile path.

use super::joint::{Joint, JointType};
use crate::spatial::{Inertia, M3, V3, Xform};
use crate::util::json::{self, Json};
use std::collections::BTreeMap;

/// One link and its inboard joint.
#[derive(Debug, Clone)]
pub struct Link {
    pub name: String,
    /// Parent link index; `None` for children of the fixed base.
    pub parent: Option<usize>,
    pub joint: Joint,
    /// Fixed tree transform: parent frame → joint (pre-rotation) frame.
    pub x_tree: Xform,
    pub inertia: Inertia,
    /// Joint limits (position), used by workload generators.
    pub q_min: f64,
    pub q_max: f64,
    /// Velocity limit magnitude.
    pub qd_max: f64,
}

/// An open-chain robot with N_B links / joints (1 DOF each ⇒ N = N_B).
#[derive(Debug, Clone)]
pub struct Robot {
    pub name: String,
    pub links: Vec<Link>,
    /// Gravity vector in base coordinates (world), usually (0,0,-9.81).
    pub gravity: V3,
}

impl Robot {
    /// Number of joints == number of position/velocity coordinates.
    pub fn dof(&self) -> usize {
        self.links.len()
    }

    pub fn parent(&self, i: usize) -> Option<usize> {
        self.links[i].parent
    }

    /// Children of link `i` (or of the base when `i == usize::MAX`).
    pub fn children(&self, i: Option<usize>) -> Vec<usize> {
        (0..self.dof()).filter(|&c| self.links[c].parent == i).collect()
    }

    /// Depth of joint i (distance from base; base children have depth 0).
    pub fn depth(&self, i: usize) -> usize {
        let mut d = 0;
        let mut cur = self.links[i].parent;
        while let Some(p) = cur {
            d += 1;
            cur = self.links[p].parent;
        }
        d
    }

    /// Indices in the subtree rooted at i (including i), ascending.
    pub fn subtree(&self, i: usize) -> Vec<usize> {
        let mut mark = vec![false; self.dof()];
        mark[i] = true;
        for j in i + 1..self.dof() {
            if let Some(p) = self.links[j].parent {
                if mark[p] {
                    mark[j] = true;
                }
            }
        }
        (0..self.dof()).filter(|&j| mark[j]).collect()
    }

    /// Validate topological ordering (parent index < link index) and
    /// basic physical sanity. Called by loaders.
    pub fn validate(&self) -> Result<(), String> {
        for (i, l) in self.links.iter().enumerate() {
            if let Some(p) = l.parent {
                if p >= i {
                    return Err(format!("link {i} has parent {p} >= itself (not topo-ordered)"));
                }
            }
            if !(l.inertia.mass > 0.0) {
                return Err(format!("link {i} has non-positive mass"));
            }
            if l.q_min >= l.q_max {
                return Err(format!("link {i} has empty joint range"));
            }
        }
        Ok(())
    }

    /// Maximum depth over all joints + 1 (pipeline length in the paper's
    /// RTP architecture is governed by chain length).
    pub fn max_chain_len(&self) -> usize {
        (0..self.dof()).map(|i| self.depth(i) + 1).max().unwrap_or(0)
    }

    /// Order-sensitive FNV-style fingerprint of everything the dynamics
    /// kernels and the fixed-point analyses read from the model:
    /// topology, joint types/axes, tree transforms, inertial
    /// parameters, joint/velocity limits, gravity, and the robot name.
    /// Robots with equal fingerprints are interchangeable for cached
    /// per-robot derived state (the integer lane's ingested constants,
    /// shift schedules); robots that merely share a *name* are not —
    /// keying caches by name would serve one robot with another's
    /// constants. Word-level mixing keeps it cheap enough for per-task
    /// cache checks.
    pub fn fingerprint(&self) -> u64 {
        const PRIME: u64 = 0x0000_0100_0000_01b3;
        fn word(h: u64, w: u64) -> u64 {
            (h ^ w).wrapping_mul(PRIME)
        }
        fn f(h: u64, x: f64) -> u64 {
            word(h, x.to_bits())
        }
        fn v(h: u64, x: &V3) -> u64 {
            x.0.iter().fold(h, |h, &c| f(h, c))
        }
        fn m(h: u64, x: &M3) -> u64 {
            x.0.iter().flatten().fold(h, |h, &c| f(h, c))
        }
        let mut h = self
            .name
            .as_bytes()
            .iter()
            .fold(0xcbf2_9ce4_8422_2325u64, |h, &b| word(h, b as u64));
        h = v(h, &self.gravity);
        for l in &self.links {
            h = word(h, l.parent.map(|p| p as u64 + 1).unwrap_or(0));
            h = word(h, matches!(l.joint.jtype, JointType::Prismatic) as u64);
            h = v(h, &l.joint.axis);
            h = m(h, &l.x_tree.e);
            h = v(h, &l.x_tree.r);
            h = f(h, l.inertia.mass);
            h = v(h, &l.inertia.com);
            h = m(h, &l.inertia.i_o);
            h = f(h, l.q_min);
            h = f(h, l.q_max);
            h = f(h, l.qd_max);
        }
        h
    }

    // ---------------- JSON ----------------

    pub fn to_json(&self) -> Json {
        let links: Vec<Json> = self
            .links
            .iter()
            .map(|l| {
                let i = &l.inertia;
                json::obj(vec![
                    ("name", json::s(&l.name)),
                    (
                        "parent",
                        match l.parent {
                            Some(p) => json::num(p as f64),
                            None => Json::Num(-1.0),
                        },
                    ),
                    ("joint_type", json::s(l.joint.type_name())),
                    ("axis", json::arr_f64(&l.joint.axis.0)),
                    ("tree_rot", rot_to_json(&l.x_tree.e)),
                    ("tree_xyz", json::arr_f64(&l.x_tree.r.0)),
                    ("mass", json::num(i.mass)),
                    ("com", json::arr_f64(&i.com.0)),
                    ("inertia_o", mat3_rows(&i.i_o)),
                    ("q_min", json::num(l.q_min)),
                    ("q_max", json::num(l.q_max)),
                    ("qd_max", json::num(l.qd_max)),
                ])
            })
            .collect();
        json::obj(vec![
            ("name", json::s(&self.name)),
            ("gravity", json::arr_f64(&self.gravity.0)),
            ("links", Json::Arr(links)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Robot, String> {
        let name = j.get("name").and_then(Json::as_str).ok_or("missing name")?.to_string();
        let g = j.get("gravity").and_then(Json::as_f64_vec).ok_or("missing gravity")?;
        let links_json = j.get("links").and_then(Json::as_arr).ok_or("missing links")?;
        let mut links = Vec::with_capacity(links_json.len());
        for (idx, lj) in links_json.iter().enumerate() {
            links.push(link_from_json(lj).map_err(|e| format!("link {idx}: {e}"))?);
        }
        let robot = Robot {
            name,
            links,
            gravity: V3::new(g[0], g[1], g[2]),
        };
        robot.validate()?;
        Ok(robot)
    }

    pub fn from_json_str(s: &str) -> Result<Robot, String> {
        let j = Json::parse(s).map_err(|e| e.to_string())?;
        Robot::from_json(&j)
    }

    pub fn load(path: &str) -> Result<Robot, String> {
        let s = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        Robot::from_json_str(&s)
    }
}

fn rot_to_json(m: &M3) -> Json {
    mat3_rows(m)
}

fn mat3_rows(m: &M3) -> Json {
    Json::Arr(m.0.iter().map(|r| json::arr_f64(r)).collect())
}

fn mat3_from_json(j: &Json) -> Result<M3, String> {
    let rows = j.as_arr().ok_or("expected 3x3 array")?;
    if rows.len() != 3 {
        return Err("expected 3 rows".into());
    }
    let mut m = M3::ZERO;
    for (i, r) in rows.iter().enumerate() {
        let v = r.as_f64_vec().ok_or("bad row")?;
        if v.len() != 3 {
            return Err("expected 3 cols".into());
        }
        m.0[i].copy_from_slice(&v);
    }
    Ok(m)
}

fn link_from_json(j: &Json) -> Result<Link, String> {
    let get = |k: &str| j.get(k).ok_or_else(|| format!("missing field '{k}'"));
    let name = get("name")?.as_str().ok_or("name not a string")?.to_string();
    let parent_raw = get("parent")?.as_i64().ok_or("parent not an int")?;
    let parent = if parent_raw < 0 { None } else { Some(parent_raw as usize) };
    let jt = match get("joint_type")?.as_str().ok_or("joint_type not a string")? {
        "revolute" => JointType::Revolute,
        "prismatic" => JointType::Prismatic,
        other => return Err(format!("unknown joint type '{other}'")),
    };
    let axis = get("axis")?.as_f64_vec().ok_or("bad axis")?;
    let xyz = get("tree_xyz")?.as_f64_vec().ok_or("bad tree_xyz")?;
    let rot = mat3_from_json(get("tree_rot")?)?;
    let mass = get("mass")?.as_f64().ok_or("bad mass")?;
    let com = get("com")?.as_f64_vec().ok_or("bad com")?;
    let i_o = mat3_from_json(get("inertia_o")?)?;
    let joint = Joint {
        jtype: jt,
        axis: V3::new(axis[0], axis[1], axis[2]).normalized(),
    };
    Ok(Link {
        name,
        parent,
        joint,
        x_tree: Xform { e: rot, r: V3::new(xyz[0], xyz[1], xyz[2]) },
        inertia: Inertia { mass, com: V3::new(com[0], com[1], com[2]), i_o },
        q_min: get("q_min")?.as_f64().ok_or("bad q_min")?,
        q_max: get("q_max")?.as_f64().ok_or("bad q_max")?,
        qd_max: get("qd_max")?.as_f64().ok_or("bad qd_max")?,
    })
}

/// A joint-space state (q, q̇) plus optionally commanded q̈ / τ.
#[derive(Debug, Clone, PartialEq)]
pub struct State {
    pub q: Vec<f64>,
    pub qd: Vec<f64>,
}

impl State {
    pub fn zero(n: usize) -> State {
        State { q: vec![0.0; n], qd: vec![0.0; n] }
    }

    /// Random state within the robot's joint and velocity limits.
    pub fn random(robot: &Robot, rng: &mut crate::util::rng::Rng) -> State {
        let q = robot.links.iter().map(|l| rng.range(l.q_min, l.q_max)).collect();
        let qd = robot.links.iter().map(|l| rng.range(-l.qd_max, l.qd_max)).collect();
        State { q, qd }
    }
}

/// Named registry mapping robot name → loader, for CLI/bench plumbing.
pub fn robot_registry() -> BTreeMap<&'static str, fn() -> Robot> {
    use super::builtin;
    let mut m: BTreeMap<&'static str, fn() -> Robot> = BTreeMap::new();
    m.insert("iiwa", builtin::iiwa as fn() -> Robot);
    m.insert("hyq", builtin::hyq as fn() -> Robot);
    m.insert("atlas", builtin::atlas as fn() -> Robot);
    m.insert("baxter", builtin::baxter as fn() -> Robot);
    m
}

/// Look a builtin robot up by name.
pub fn builtin_robot(name: &str) -> Option<Robot> {
    robot_registry().get(name).map(|f| f())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::builtin;

    #[test]
    fn json_roundtrip_all_builtins() {
        for (name, f) in robot_registry() {
            let r = f();
            let j = r.to_json().pretty();
            let r2 = Robot::from_json_str(&j).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(r.dof(), r2.dof());
            for (a, b) in r.links.iter().zip(&r2.links) {
                assert_eq!(a.name, b.name);
                assert_eq!(a.parent, b.parent);
                assert!((a.inertia.mass - b.inertia.mass).abs() < 1e-12);
                assert!((a.x_tree.r - b.x_tree.r).norm() < 1e-12);
            }
        }
    }

    #[test]
    fn subtree_contains_self_and_descendants() {
        let r = builtin::hyq();
        for i in 0..r.dof() {
            let st = r.subtree(i);
            assert!(st.contains(&i));
            for &j in &st {
                // every member's path to root passes through i
                if j != i {
                    let mut cur = r.parent(j);
                    let mut found = false;
                    while let Some(p) = cur {
                        if p == i {
                            found = true;
                            break;
                        }
                        cur = r.parent(p);
                    }
                    assert!(found, "{j} in subtree({i}) but no path");
                }
            }
        }
    }

    #[test]
    fn depth_of_chain_robot_is_index() {
        let r = builtin::iiwa();
        for i in 0..r.dof() {
            assert_eq!(r.depth(i), i, "iiwa is a serial chain");
        }
        assert_eq!(r.max_chain_len(), 7);
    }

    #[test]
    fn validate_rejects_bad_topology() {
        let mut r = builtin::iiwa();
        r.links[2].parent = Some(5);
        assert!(r.validate().is_err());
    }

    /// The fingerprint distinguishes robots that share a name but
    /// differ inertially (the cache-aliasing hazard), is stable across
    /// clones, and reacts to every parameter class it claims to cover.
    #[test]
    fn fingerprint_tracks_inertial_identity_not_just_name() {
        let a = builtin::iiwa();
        assert_eq!(a.fingerprint(), builtin::iiwa().fingerprint(), "deterministic");
        let mut heavier = builtin::iiwa();
        heavier.links[6].inertia.mass *= 2.0;
        assert_ne!(a.fingerprint(), heavier.fingerprint(), "same name, new payload");
        let mut renamed = builtin::iiwa();
        renamed.name = "iiwa-b".to_string();
        assert_ne!(a.fingerprint(), renamed.fingerprint());
        let mut limits = builtin::iiwa();
        limits.links[0].qd_max *= 0.5;
        assert_ne!(a.fingerprint(), limits.fingerprint(), "limits feed the analyses");
        let mut rerooted = builtin::iiwa();
        rerooted.links[4].parent = Some(2);
        assert_ne!(a.fingerprint(), rerooted.fingerprint());
    }

    #[test]
    fn random_state_respects_limits() {
        let r = builtin::atlas();
        let mut rng = crate::util::rng::Rng::new(3);
        for _ in 0..16 {
            let s = State::random(&r, &mut rng);
            for (i, l) in r.links.iter().enumerate() {
                assert!(s.q[i] >= l.q_min && s.q[i] <= l.q_max);
                assert!(s.qd[i].abs() <= l.qd_max);
            }
        }
    }
}
