//! Built-in robot descriptions for the four platforms evaluated in the
//! paper: KUKA iiwa (7-DOF arm), HyQ (12-DOF quadruped), Atlas (30-DOF
//! humanoid), Baxter (14-DOF dual-arm).
//!
//! Inertial parameters are physically plausible approximations assembled
//! from public spec sheets / URDFs (masses, segment lengths, cylinder/box
//! inertia models). The paper's evaluation quantities depend on topology
//! (DOF, depth, branching) — see DESIGN.md "Substitutions".

use super::joint::Joint;
use super::robot::{Link, Robot};
use crate::spatial::{Inertia, M3, V3, Xform};

/// URDF-style fixed transform: child origin at `xyz` with `rpy`
/// orientation, both relative to the parent frame. Returns the
/// parent→child *coordinate* transform.
pub fn tree_xform(xyz: [f64; 3], rpy: [f64; 3]) -> Xform {
    // R maps child coords → parent coords: R = Rz(y) Ry(p) Rx(r).
    // Coordinate transform E = Rᵀ. rot_axis(axis, q) already returns the
    // E-style (transposed) rotation, so compose transposes in reverse.
    let ex = M3::rot_axis(&V3::new(1.0, 0.0, 0.0), rpy[0]);
    let ey = M3::rot_axis(&V3::new(0.0, 1.0, 0.0), rpy[1]);
    let ez = M3::rot_axis(&V3::new(0.0, 0.0, 1.0), rpy[2]);
    // E = (Rz Ry Rx)ᵀ = Rxᵀ Ryᵀ Rzᵀ = ex·ey·ez (each rot_axis is already
    // the transpose of the corresponding standard rotation).
    let e = ex.mul_m(&ey).mul_m(&ez);
    Xform { e, r: V3::new(xyz[0], xyz[1], xyz[2]) }
}

/// Solid-cylinder inertia about its CoM, axis along z.
fn cylinder_inertia(mass: f64, radius: f64, length: f64) -> M3 {
    let ixx = mass * (3.0 * radius * radius + length * length) / 12.0;
    let izz = 0.5 * mass * radius * radius;
    M3::diag(ixx, ixx, izz)
}

/// Solid-box inertia about its CoM.
fn box_inertia(mass: f64, x: f64, y: f64, z: f64) -> M3 {
    M3::diag(
        mass * (y * y + z * z) / 12.0,
        mass * (x * x + z * z) / 12.0,
        mass * (x * x + y * y) / 12.0,
    )
}

#[allow(clippy::too_many_arguments)]
fn link(
    name: &str,
    parent: i64,
    axis: [f64; 3],
    xyz: [f64; 3],
    rpy: [f64; 3],
    mass: f64,
    com: [f64; 3],
    i_com: M3,
    q_lim: f64,
    qd_max: f64,
) -> Link {
    Link {
        name: name.to_string(),
        parent: if parent < 0 { None } else { Some(parent as usize) },
        joint: Joint::revolute(V3::new(axis[0], axis[1], axis[2])),
        x_tree: tree_xform(xyz, rpy),
        inertia: Inertia::from_com_inertia(mass, V3::new(com[0], com[1], com[2]), i_com),
        q_min: -q_lim,
        q_max: q_lim,
        qd_max,
    }
}

const G: [f64; 3] = [0.0, 0.0, -9.81];

/// KUKA LBR iiwa 14 — 7-DOF serial arm, alternating z/y axes.
/// Masses/lengths follow the public iiwa14 URDF to ~10%.
pub fn iiwa() -> Robot {
    let z = [0.0, 0.0, 1.0];
    let y = [0.0, 1.0, 0.0];
    let links = vec![
        link("link1", -1, z, [0.0, 0.0, 0.1575], [0.0; 3], 3.95, [0.0, -0.03, 0.12], cylinder_inertia(3.95, 0.06, 0.26), 2.97, 1.48),
        link("link2", 0, y, [0.0, 0.0, 0.2025], [0.0; 3], 4.50, [0.0003, 0.059, 0.042], cylinder_inertia(4.50, 0.06, 0.26), 2.09, 1.48),
        link("link3", 1, z, [0.0, 0.0, 0.2045], [0.0; 3], 2.45, [0.0, 0.03, 0.13], cylinder_inertia(2.45, 0.055, 0.22), 2.97, 1.74),
        link("link4", 2, y, [0.0, 0.0, 0.2155], [0.0; 3], 2.61, [0.0, 0.067, 0.034], cylinder_inertia(2.61, 0.055, 0.22), 2.09, 1.31),
        link("link5", 3, z, [0.0, 0.0, 0.1845], [0.0; 3], 3.41, [0.0001, 0.021, 0.076], cylinder_inertia(3.41, 0.05, 0.2), 2.97, 2.27),
        link("link6", 4, y, [0.0, 0.0, 0.2155], [0.0; 3], 3.39, [0.0, 0.0006, 0.0004], cylinder_inertia(3.39, 0.05, 0.18), 2.09, 2.36),
        // link7 includes a mounted tool plate (realistic deployment and it
        // keeps the M⁻¹ wrist diagonal within a 12-integer-bit Q-format's
        // range — see quant::analyzer range checks).
        link("link7", 5, z, [0.0, 0.0, 0.081], [0.0; 3], 1.20, [0.0, 0.0, 0.04], cylinder_inertia(1.20, 0.06, 0.10), 3.05, 2.36),
    ];
    Robot { name: "iiwa".into(), links, gravity: V3::new(G[0], G[1], G[2]) }
}

/// HyQ — hydraulic quadruped, 12 actuated joints (4 legs × HAA/HFE/KFE).
/// Trunk is the (fixed) base in this model; the paper counts the 12
/// actuated DOF. Hip positions/masses follow the IIT HyQ description.
pub fn hyq() -> Robot {
    let x = [1.0, 0.0, 0.0];
    let y = [0.0, 1.0, 0.0];
    let mut links = Vec::new();
    let legs = [
        ("lf", 0.3735, 0.207),
        ("rf", 0.3735, -0.207),
        ("lh", -0.3735, 0.207),
        ("rh", -0.3735, -0.207),
    ];
    for (name, px, py) in legs {
        let base = links.len() as i64;
        // HAA: hip abduction/adduction about x
        links.push(link(
            &format!("{name}_haa"), -1, x, [px, py, 0.0], [0.0; 3],
            2.93, [0.045, 0.0, 0.0], box_inertia(2.93, 0.12, 0.08, 0.08), 1.22, 12.0,
        ));
        // HFE: hip flexion/extension about y
        links.push(link(
            &format!("{name}_hfe"), base, y, [0.08, 0.0, 0.0], [0.0; 3],
            2.64, [0.026, 0.0, -0.15], cylinder_inertia(2.64, 0.045, 0.35), 1.57, 12.0,
        ));
        // KFE: knee flexion/extension about y
        links.push(link(
            &format!("{name}_kfe"), base + 1, y, [0.0, 0.0, -0.35], [0.0; 3],
            0.88, [0.0, 0.0, -0.14], cylinder_inertia(0.88, 0.03, 0.33), 2.44, 12.0,
        ));
    }
    Robot { name: "hyq".into(), links, gravity: V3::new(G[0], G[1], G[2]) }
}

/// Boston Dynamics Atlas — 30-DOF humanoid: 3 back joints, neck, two
/// 7-DOF arms, two 6-DOF legs. Pelvis is the base link.
pub fn atlas() -> Robot {
    let x = [1.0, 0.0, 0.0];
    let y = [0.0, 1.0, 0.0];
    let z = [0.0, 0.0, 1.0];
    let mut links: Vec<Link> = Vec::new();
    let mut add = |l: Link| -> i64 {
        links.push(l);
        (links.len() - 1) as i64
    };
    // --- torso chain (back_bkz, back_bky, back_bkx) off pelvis(base)
    let bkz = add(link("back_bkz", -1, z, [-0.0125, 0.0, 0.0], [0.0; 3], 9.5, [-0.01, 0.0, 0.16], box_inertia(9.5, 0.25, 0.3, 0.3), 0.66, 12.0));
    let bky = add(link("back_bky", bkz, y, [0.0, 0.0, 0.162], [0.0; 3], 4.0, [0.0, 0.0, 0.05], box_inertia(4.0, 0.2, 0.25, 0.15), 0.54, 9.0));
    let bkx = add(link("back_bkx", bky, x, [0.0, 0.0, 0.05], [0.0; 3], 27.0, [-0.02, 0.0, 0.21], box_inertia(27.0, 0.3, 0.35, 0.5), 0.52, 12.0));
    // --- neck
    let _ = add(link("neck_ry", bkx, y, [0.0, 0.0, 0.35], [0.0; 3], 1.5, [0.0, 0.0, 0.05], box_inertia(1.5, 0.12, 0.12, 0.12), 1.0, 6.0));
    // --- arms (7 DOF each): shz, shx, ely, elx, wry, wrx, wry2
    for (side, sy) in [("l", 1.0), ("r", -1.0)] {
        let shz = add(link(&format!("{side}_arm_shz"), bkx, z, [0.11, sy * 0.22, 0.32], [0.0; 3], 2.7, [0.0, sy * 0.05, 0.0], cylinder_inertia(2.7, 0.06, 0.15), 1.57, 12.0));
        let shx = add(link(&format!("{side}_arm_shx"), shz, x, [0.0, sy * 0.11, 0.0], [0.0; 3], 3.5, [0.0, sy * 0.1, -0.01], cylinder_inertia(3.5, 0.06, 0.26), 1.57, 12.0));
        let ely = add(link(&format!("{side}_arm_ely"), shx, y, [0.0, sy * 0.19, 0.0], [0.0; 3], 3.0, [0.0, sy * 0.09, 0.0], cylinder_inertia(3.0, 0.055, 0.25), 3.14, 12.0));
        let elx = add(link(&format!("{side}_arm_elx"), ely, x, [0.0, sy * 0.12, 0.0], [0.0; 3], 2.8, [0.0, sy * 0.08, 0.0], cylinder_inertia(2.8, 0.05, 0.22), 2.35, 12.0));
        let wry = add(link(&format!("{side}_arm_wry"), elx, y, [0.0, sy * 0.19, 0.0], [0.0; 3], 1.6, [0.0, sy * 0.05, 0.0], cylinder_inertia(1.6, 0.045, 0.15), 3.14, 12.0));
        let wrx = add(link(&format!("{side}_arm_wrx"), wry, x, [0.0, sy * 0.12, 0.0], [0.0; 3], 1.2, [0.0, sy * 0.03, 0.0], cylinder_inertia(1.2, 0.04, 0.1), 1.17, 12.0));
        let _ = add(link(&format!("{side}_arm_wry2"), wrx, y, [0.0, sy * 0.08, 0.0], [0.0; 3], 0.6, [0.0, sy * 0.02, 0.0], cylinder_inertia(0.6, 0.035, 0.08), 2.0, 12.0));
    }
    // --- legs (6 DOF each): hpz, hpx, hpy, kny, aky, akx
    for (side, sy) in [("l", 1.0), ("r", -1.0)] {
        let hpz = add(link(&format!("{side}_leg_hpz"), -1, z, [0.0, sy * 0.089, 0.0], [0.0; 3], 2.4, [0.0, 0.0, -0.04], box_inertia(2.4, 0.12, 0.12, 0.1), 0.79, 12.0));
        let hpx = add(link(&format!("{side}_leg_hpx"), hpz, x, [0.0, 0.0, -0.05], [0.0; 3], 1.9, [0.0, 0.0, -0.05], box_inertia(1.9, 0.12, 0.1, 0.1), 0.52, 12.0));
        let hpy = add(link(&format!("{side}_leg_hpy"), hpx, y, [0.05, 0.0, -0.05], [0.0; 3], 8.2, [0.0, 0.0, -0.21], cylinder_inertia(8.2, 0.07, 0.42), 1.57, 12.0));
        let kny = add(link(&format!("{side}_leg_kny"), hpy, y, [-0.05, 0.0, -0.42], [0.0; 3], 4.5, [0.0, 0.0, -0.2], cylinder_inertia(4.5, 0.06, 0.42), 2.35, 12.0));
        let aky = add(link(&format!("{side}_leg_aky"), kny, y, [0.0, 0.0, -0.42], [0.0; 3], 2.0, [0.02, 0.0, -0.04], box_inertia(2.0, 0.16, 0.1, 0.06), 1.0, 12.0));
        let _ = add(link(&format!("{side}_leg_akx"), aky, x, [0.0, 0.0, -0.06], [0.0; 3], 1.2, [0.04, 0.0, -0.02], box_inertia(1.2, 0.22, 0.1, 0.04), 0.8, 12.0));
    }
    debug_assert_eq!(links.len(), 30);
    Robot { name: "atlas".into(), links, gravity: V3::new(G[0], G[1], G[2]) }
}

/// Rethink Baxter — two 7-DOF arms off a fixed torso (14 DOF total).
pub fn baxter() -> Robot {
    let z = [0.0, 0.0, 1.0];
    let y = [0.0, 1.0, 0.0];
    let x = [1.0, 0.0, 0.0];
    let mut links = Vec::new();
    for (side, sy) in [("left", 1.0), ("right", -1.0)] {
        let base = links.len() as i64;
        // Mount: shoulder offset rotated ±75° about z.
        let mount_rpy = [0.0, 0.0, sy * 0.7854];
        links.push(link(&format!("{side}_s0"), -1, z, [0.064, sy * 0.259, 0.13], mount_rpy, 5.70, [0.01, 0.0, 0.25], cylinder_inertia(5.7, 0.08, 0.3), 1.70, 2.0));
        links.push(link(&format!("{side}_s1"), base, y, [0.069, 0.0, 0.27], [0.0; 3], 3.23, [0.0, -0.01, 0.0], cylinder_inertia(3.23, 0.06, 0.2), 1.54, 2.0));
        links.push(link(&format!("{side}_e0"), base + 1, x, [0.102, 0.0, 0.0], [0.0; 3], 4.31, [0.12, 0.0, 0.0], cylinder_inertia(4.31, 0.06, 0.26), 3.05, 2.0));
        links.push(link(&format!("{side}_e1"), base + 2, y, [0.262, 0.0, 0.0], [0.0; 3], 2.07, [0.06, 0.0, 0.0], cylinder_inertia(2.07, 0.05, 0.2), 2.62, 2.0));
        links.push(link(&format!("{side}_w0"), base + 3, x, [0.104, 0.0, 0.0], [0.0; 3], 2.25, [0.11, 0.0, 0.0], cylinder_inertia(2.25, 0.045, 0.22), 3.06, 4.0));
        links.push(link(&format!("{side}_w1"), base + 4, y, [0.264, 0.0, 0.0], [0.0; 3], 1.61, [0.03, 0.0, 0.0], cylinder_inertia(1.61, 0.04, 0.14), 2.09, 4.0));
        links.push(link(&format!("{side}_w2"), base + 5, x, [0.104, 0.0, 0.0], [0.0; 3], 0.54, [0.02, 0.0, 0.0], cylinder_inertia(0.54, 0.035, 0.08), 3.06, 4.0));
    }
    Robot { name: "baxter".into(), links, gravity: V3::new(G[0], G[1], G[2]) }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dofs_match_paper() {
        assert_eq!(iiwa().dof(), 7);
        assert_eq!(hyq().dof(), 12);
        assert_eq!(atlas().dof(), 30);
        assert_eq!(baxter().dof(), 14);
    }

    #[test]
    fn all_validate() {
        for r in [iiwa(), hyq(), atlas(), baxter()] {
            r.validate().unwrap_or_else(|e| panic!("{}: {e}", r.name));
        }
    }

    #[test]
    fn topologies() {
        // iiwa: pure chain; hyq: 4 branches of 3; baxter: 2 branches of 7;
        // atlas: tree with max chain length 9 (pelvis→back×3→arm×7 minus
        // shared... count: bkz,bky,bkx + 7 arm = 10? arm hangs off bkx:
        // depth of wry2 = 3 + 7 = 10).
        assert_eq!(iiwa().max_chain_len(), 7);
        assert_eq!(hyq().max_chain_len(), 3);
        assert_eq!(baxter().max_chain_len(), 7);
        assert_eq!(atlas().max_chain_len(), 10);
        assert_eq!(hyq().children(None).len(), 4);
        assert_eq!(atlas().children(None).len(), 3); // back + 2 legs
    }

    #[test]
    fn masses_positive_and_plausible() {
        for r in [iiwa(), hyq(), atlas(), baxter()] {
            let total: f64 = r.links.iter().map(|l| l.inertia.mass).sum();
            assert!(total > 1.0 && total < 400.0, "{}: {total}", r.name);
        }
    }
}
