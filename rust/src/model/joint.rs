//! Joint models: 1-DOF revolute and prismatic joints (the paper's robots
//! — iiwa/HyQ/Atlas/Baxter — are modeled with 1-DOF joints, N_i = 1, so
//! the motion subspace S_i is a single spatial vector).

use crate::spatial::{M3, SV, V3, Xform};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JointType {
    Revolute,
    Prismatic,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Joint {
    pub jtype: JointType,
    /// Unit axis in the successor (child link) frame.
    pub axis: V3,
}

impl Joint {
    pub fn revolute(axis: V3) -> Joint {
        Joint { jtype: JointType::Revolute, axis: axis.normalized() }
    }

    pub fn prismatic(axis: V3) -> Joint {
        Joint { jtype: JointType::Prismatic, axis: axis.normalized() }
    }

    /// Motion subspace S (constant for these joint types).
    pub fn motion_subspace(&self) -> SV {
        match self.jtype {
            JointType::Revolute => SV::new(self.axis, V3::ZERO),
            JointType::Prismatic => SV::new(V3::ZERO, self.axis),
        }
    }

    /// Joint transform X_J(q): maps frame-before-joint coordinates into
    /// the child link frame (Featherstone jcalc).
    pub fn xform(&self, q: f64) -> Xform {
        match self.jtype {
            JointType::Revolute => Xform::rotation(M3::rot_axis(&self.axis, q)),
            JointType::Prismatic => Xform::translation(self.axis.scale(q)),
        }
    }

    pub fn type_name(&self) -> &'static str {
        match self.jtype {
            JointType::Revolute => "revolute",
            JointType::Prismatic => "prismatic",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::close;

    #[test]
    fn revolute_velocity_matches_subspace_derivative() {
        // v = S q̇ must equal d/dq [X_J(q)] applied appropriately; check the
        // defining property numerically: X(q+h) x ≈ X(q) (x + h S×x) for
        // motion vector x... simpler: the spatial velocity of the child
        // frame for unit q̇ is S itself, i.e.
        // lim (X(q+h) X(q)^-1 - I)/h acting on coordinates = -S× (body frame).
        // We verify via finite difference of a transformed fixed vector.
        let j = Joint::revolute(V3::new(0.0, 0.0, 1.0));
        let q = 0.37;
        let h = 1e-7;
        let x0 = j.xform(q);
        let x1 = j.xform(q + h);
        let p = SV::new(V3::new(0.2, -0.4, 0.9), V3::new(1.0, 0.5, -0.3));
        // body-frame derivative: d/dq (X(q) p) = -S × (X(q) p)
        let fd = (x1.apply(&p) - x0.apply(&p)).scale(1.0 / h);
        let analytic = -j.motion_subspace().crm(&x0.apply(&p));
        assert!((fd - analytic).norm() < 1e-5, "{}", (fd - analytic).norm());
    }

    #[test]
    fn prismatic_shifts_linear_part() {
        let j = Joint::prismatic(V3::new(1.0, 0.0, 0.0));
        let x = j.xform(2.0);
        // A pure angular velocity about z, re-expressed at a frame whose
        // origin sits at +2x, picks up linear velocity w × r = (0, 2, 0).
        let v = SV::new(V3::new(0.0, 0.0, 1.0), V3::ZERO);
        let out = x.apply(&v);
        assert!(close(out.lin.y(), 2.0, 1e-14), "{:?}", out);
    }

    #[test]
    fn subspace_unit_norm() {
        for j in [
            Joint::revolute(V3::new(0.0, 3.0, 0.0)),
            Joint::prismatic(V3::new(0.0, 0.0, -2.0)),
        ] {
            assert!(close(j.motion_subspace().norm(), 1.0, 1e-12));
        }
    }

    #[test]
    fn zero_q_is_identity() {
        for j in [Joint::revolute(V3::new(0.0, 1.0, 0.0)), Joint::prismatic(V3::new(1.0, 0.0, 0.0))]
        {
            let x = j.xform(0.0);
            let v = SV::new(V3::new(0.1, 0.2, 0.3), V3::new(0.4, 0.5, 0.6));
            assert!((x.apply(&v) - v).norm() < 1e-14);
        }
    }
}
