//! Robot modeling: joints, links, topology trees, built-in robots, and a
//! URDF-lite importer.

pub mod builtin;
pub mod joint;
pub mod robot;
pub mod urdf;

pub use joint::{Joint, JointType};
pub use robot::{builtin_robot, robot_registry, Link, Robot, State};
