//! URDF-lite importer.
//!
//! The paper's quantization framework takes "the robot's urdf description"
//! as input. This module parses the URDF subset needed for RBD: `<link>`
//! inertial blocks and `<joint>` origin/axis/parent/child/limit, over a
//! from-scratch XML tokenizer (no XML crate offline). Fixed joints are
//! merged into their parent; only revolute/continuous/prismatic joints
//! become model DOF.

use super::joint::{Joint, JointType};
use super::robot::{Link, Robot};
use crate::spatial::{Inertia, M3, V3};
use std::collections::BTreeMap;

// ------------------------- tiny XML -------------------------

#[derive(Debug, Clone, PartialEq)]
pub struct XmlNode {
    pub tag: String,
    pub attrs: BTreeMap<String, String>,
    pub children: Vec<XmlNode>,
}

impl XmlNode {
    pub fn attr(&self, name: &str) -> Option<&str> {
        self.attrs.get(name).map(|s| s.as_str())
    }

    pub fn find_all<'a>(&'a self, tag: &str) -> Vec<&'a XmlNode> {
        self.children.iter().filter(|c| c.tag == tag).collect()
    }

    pub fn find<'a>(&'a self, tag: &str) -> Option<&'a XmlNode> {
        self.children.iter().find(|c| c.tag == tag)
    }
}

/// Parse an XML document into its root element. Handles declarations,
/// comments, self-closing tags, quoted attributes; ignores text content
/// (URDF carries everything in attributes).
pub fn parse_xml(src: &str) -> Result<XmlNode, String> {
    let mut p = Xml { b: src.as_bytes(), i: 0 };
    p.skip_misc();
    let root = p.element()?;
    Ok(root)
}

struct Xml<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Xml<'a> {
    fn err(&self, m: &str) -> String {
        format!("xml error at byte {}: {m}", self.i)
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn starts(&self, s: &str) -> bool {
        self.b[self.i..].starts_with(s.as_bytes())
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    /// Skip whitespace, comments, processing instructions, doctype.
    fn skip_misc(&mut self) {
        loop {
            self.skip_ws();
            if self.starts("<!--") {
                if let Some(end) = find(self.b, self.i + 4, b"-->") {
                    self.i = end + 3;
                    continue;
                }
                self.i = self.b.len();
            } else if self.starts("<?") {
                if let Some(end) = find(self.b, self.i + 2, b"?>") {
                    self.i = end + 2;
                    continue;
                }
                self.i = self.b.len();
            } else if self.starts("<!") {
                if let Some(end) = find(self.b, self.i + 2, b">") {
                    self.i = end + 1;
                    continue;
                }
                self.i = self.b.len();
            } else {
                break;
            }
        }
    }

    fn name(&mut self) -> Result<String, String> {
        let start = self.i;
        while matches!(self.peek(), Some(c) if c.is_ascii_alphanumeric() || c == b'_' || c == b'-' || c == b':' || c == b'.')
        {
            self.i += 1;
        }
        if self.i == start {
            return Err(self.err("expected name"));
        }
        Ok(String::from_utf8_lossy(&self.b[start..self.i]).into_owned())
    }

    fn element(&mut self) -> Result<XmlNode, String> {
        if self.peek() != Some(b'<') {
            return Err(self.err("expected '<'"));
        }
        self.i += 1;
        let tag = self.name()?;
        let mut attrs = BTreeMap::new();
        loop {
            self.skip_ws();
            match self.peek() {
                Some(b'/') => {
                    self.i += 1;
                    if self.peek() != Some(b'>') {
                        return Err(self.err("expected '>' after '/'"));
                    }
                    self.i += 1;
                    return Ok(XmlNode { tag, attrs, children: Vec::new() });
                }
                Some(b'>') => {
                    self.i += 1;
                    break;
                }
                Some(_) => {
                    let key = self.name()?;
                    self.skip_ws();
                    if self.peek() != Some(b'=') {
                        return Err(self.err("expected '='"));
                    }
                    self.i += 1;
                    self.skip_ws();
                    let quote = self.peek().ok_or_else(|| self.err("eof in attr"))?;
                    if quote != b'"' && quote != b'\'' {
                        return Err(self.err("expected quote"));
                    }
                    self.i += 1;
                    let start = self.i;
                    while self.peek().is_some() && self.peek() != Some(quote) {
                        self.i += 1;
                    }
                    let val = String::from_utf8_lossy(&self.b[start..self.i]).into_owned();
                    self.i += 1; // closing quote
                    attrs.insert(key, val);
                }
                None => return Err(self.err("eof in tag")),
            }
        }
        // children / text until closing tag
        let mut children = Vec::new();
        loop {
            self.skip_misc();
            if self.starts("</") {
                self.i += 2;
                let close = self.name()?;
                if close != tag {
                    return Err(self.err(&format!("mismatched </{close}>, open <{tag}>")));
                }
                self.skip_ws();
                if self.peek() != Some(b'>') {
                    return Err(self.err("expected '>'"));
                }
                self.i += 1;
                return Ok(XmlNode { tag, attrs, children });
            } else if self.peek() == Some(b'<') {
                children.push(self.element()?);
            } else if self.peek().is_some() {
                // text content: skip to next '<'
                while self.peek().is_some() && self.peek() != Some(b'<') {
                    self.i += 1;
                }
            } else {
                return Err(self.err(&format!("eof, unclosed <{tag}>")));
            }
        }
    }
}

fn find(hay: &[u8], from: usize, needle: &[u8]) -> Option<usize> {
    (from..hay.len().saturating_sub(needle.len() - 1)).find(|&i| hay[i..].starts_with(needle))
}

// ------------------------- URDF → Robot -------------------------

fn parse_vec3(s: &str) -> Result<[f64; 3], String> {
    let v: Vec<f64> = s
        .split_whitespace()
        .map(|t| t.parse::<f64>().map_err(|e| format!("bad number '{t}': {e}")))
        .collect::<Result<_, _>>()?;
    if v.len() != 3 {
        return Err(format!("expected 3 numbers, got {}", v.len()));
    }
    Ok([v[0], v[1], v[2]])
}

struct UrdfJoint {
    name: String,
    jtype: String,
    parent: String,
    child: String,
    xyz: [f64; 3],
    rpy: [f64; 3],
    axis: [f64; 3],
    lower: f64,
    upper: f64,
    velocity: f64,
}

struct UrdfInertial {
    mass: f64,
    com: [f64; 3],
    i_com: M3,
}

/// Convert URDF text into a [`Robot`]. Kinematic chains are rebuilt in
/// topological order starting from the root link (the link that is never
/// a child). Fixed joints fuse their child's inertia into the parent DOF
/// frame only when the fixed offset is zero; otherwise they are rejected
/// (keeps this importer honest about what it supports).
pub fn robot_from_urdf(src: &str) -> Result<Robot, String> {
    let root = parse_xml(src)?;
    if root.tag != "robot" {
        return Err(format!("root element is <{}>, expected <robot>", root.tag));
    }
    let name = root.attr("name").unwrap_or("urdf-robot").to_string();

    let mut inertials: BTreeMap<String, UrdfInertial> = BTreeMap::new();
    for l in root.find_all("link") {
        let lname = l.attr("name").ok_or("link without name")?.to_string();
        if let Some(inert) = l.find("inertial") {
            let mass = inert
                .find("mass")
                .and_then(|m| m.attr("value"))
                .ok_or("inertial without mass")?
                .parse::<f64>()
                .map_err(|e| e.to_string())?;
            let com = inert
                .find("origin")
                .and_then(|o| o.attr("xyz"))
                .map(parse_vec3)
                .transpose()?
                .unwrap_or([0.0; 3]);
            let iel = inert.find("inertia").ok_or("inertial without inertia")?;
            let g = |k: &str| -> Result<f64, String> {
                iel.attr(k).unwrap_or("0").parse::<f64>().map_err(|e| e.to_string())
            };
            let (ixx, iyy, izz) = (g("ixx")?, g("iyy")?, g("izz")?);
            let (ixy, ixz, iyz) = (g("ixy")?, g("ixz")?, g("iyz")?);
            let i_com = M3([[ixx, ixy, ixz], [ixy, iyy, iyz], [ixz, iyz, izz]]);
            inertials.insert(lname, UrdfInertial { mass, com, i_com });
        } else {
            inertials.insert(lname, UrdfInertial { mass: 0.0, com: [0.0; 3], i_com: M3::ZERO });
        }
    }

    let mut joints = Vec::new();
    for j in root.find_all("joint") {
        let jtype = j.attr("type").unwrap_or("").to_string();
        let origin = j.find("origin");
        joints.push(UrdfJoint {
            name: j.attr("name").unwrap_or("joint").to_string(),
            parent: j
                .find("parent")
                .and_then(|p| p.attr("link"))
                .ok_or("joint without parent")?
                .to_string(),
            child: j
                .find("child")
                .and_then(|c| c.attr("link"))
                .ok_or("joint without child")?
                .to_string(),
            xyz: origin.and_then(|o| o.attr("xyz")).map(parse_vec3).transpose()?.unwrap_or([0.0; 3]),
            rpy: origin.and_then(|o| o.attr("rpy")).map(parse_vec3).transpose()?.unwrap_or([0.0; 3]),
            axis: j
                .find("axis")
                .and_then(|a| a.attr("xyz"))
                .map(parse_vec3)
                .transpose()?
                .unwrap_or([0.0, 0.0, 1.0]),
            lower: j
                .find("limit")
                .and_then(|l| l.attr("lower"))
                .and_then(|v| v.parse().ok())
                .unwrap_or(-std::f64::consts::PI),
            upper: j
                .find("limit")
                .and_then(|l| l.attr("upper"))
                .and_then(|v| v.parse().ok())
                .unwrap_or(std::f64::consts::PI),
            velocity: j
                .find("limit")
                .and_then(|l| l.attr("velocity"))
                .and_then(|v| v.parse().ok())
                .unwrap_or(2.0),
            jtype,
        });
    }

    // Root link: never a child.
    let children_set: std::collections::BTreeSet<&str> =
        joints.iter().map(|j| j.child.as_str()).collect();
    let all_parents: Vec<&str> = joints.iter().map(|j| j.parent.as_str()).collect();
    let root_link = all_parents
        .iter()
        .find(|p| !children_set.contains(*p))
        .ok_or("no root link found (cycle?)")?
        .to_string();

    // BFS from root, emitting moving joints in topological order.
    // `frame` maps urdf link name → model link index (or None for base).
    let mut frame: BTreeMap<String, Option<usize>> = BTreeMap::new();
    frame.insert(root_link.clone(), None);
    let mut links: Vec<Link> = Vec::new();
    let mut queue = vec![root_link];
    while let Some(cur) = queue.pop() {
        let parent_idx = frame[&cur];
        for j in joints.iter().filter(|j| j.parent == cur) {
            match j.jtype.as_str() {
                "revolute" | "continuous" | "prismatic" => {
                    let inert = inertials
                        .get(&j.child)
                        .ok_or_else(|| format!("joint {} child {} missing", j.name, j.child))?;
                    let jm = if j.jtype == "prismatic" {
                        Joint {
                            jtype: JointType::Prismatic,
                            axis: V3::new(j.axis[0], j.axis[1], j.axis[2]).normalized(),
                        }
                    } else {
                        Joint {
                            jtype: JointType::Revolute,
                            axis: V3::new(j.axis[0], j.axis[1], j.axis[2]).normalized(),
                        }
                    };
                    links.push(Link {
                        name: j.child.clone(),
                        parent: parent_idx,
                        joint: jm,
                        x_tree: super::builtin::tree_xform(j.xyz, j.rpy),
                        inertia: Inertia::from_com_inertia(
                            inert.mass.max(1e-6),
                            V3::new(inert.com[0], inert.com[1], inert.com[2]),
                            inert.i_com,
                        ),
                        q_min: j.lower,
                        q_max: j.upper,
                        qd_max: j.velocity,
                    });
                    frame.insert(j.child.clone(), Some(links.len() - 1));
                    queue.push(j.child.clone());
                }
                "fixed" => {
                    // Supported when the offset is zero (common for frames
                    // like tool mounts with negligible inertia).
                    if j.xyz != [0.0; 3] || j.rpy != [0.0; 3] {
                        return Err(format!(
                            "fixed joint '{}' with non-zero offset unsupported by urdf-lite",
                            j.name
                        ));
                    }
                    frame.insert(j.child.clone(), parent_idx);
                    queue.push(j.child.clone());
                }
                other => {
                    return Err(format!("joint '{}' has unsupported type '{other}'", j.name));
                }
            }
        }
    }

    let robot = Robot { name, links, gravity: V3::new(0.0, 0.0, -9.81) };
    robot.validate()?;
    Ok(robot)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"<?xml version="1.0"?>
<!-- a 2-link arm -->
<robot name="mini">
  <link name="base"/>
  <link name="upper">
    <inertial>
      <origin xyz="0 0 0.1"/>
      <mass value="2.0"/>
      <inertia ixx="0.02" iyy="0.02" izz="0.01" ixy="0" ixz="0" iyz="0"/>
    </inertial>
  </link>
  <link name="lower">
    <inertial>
      <origin xyz="0 0 0.15"/>
      <mass value="1.0"/>
      <inertia ixx="0.01" iyy="0.01" izz="0.005"/>
    </inertial>
  </link>
  <joint name="j1" type="revolute">
    <parent link="base"/>
    <child link="upper"/>
    <origin xyz="0 0 0.2" rpy="0 0 0"/>
    <axis xyz="0 1 0"/>
    <limit lower="-1.5" upper="1.5" velocity="3.0"/>
  </joint>
  <joint name="j2" type="continuous">
    <parent link="upper"/>
    <child link="lower"/>
    <origin xyz="0 0 0.3"/>
    <axis xyz="0 1 0"/>
  </joint>
</robot>"#;

    #[test]
    fn xml_parses_structure() {
        let root = parse_xml(SAMPLE).unwrap();
        assert_eq!(root.tag, "robot");
        assert_eq!(root.attr("name"), Some("mini"));
        assert_eq!(root.find_all("link").len(), 3);
        assert_eq!(root.find_all("joint").len(), 2);
    }

    #[test]
    fn urdf_to_robot() {
        let r = robot_from_urdf(SAMPLE).unwrap();
        assert_eq!(r.name, "mini");
        assert_eq!(r.dof(), 2);
        assert_eq!(r.links[0].name, "upper");
        assert_eq!(r.links[0].parent, None);
        assert_eq!(r.links[1].parent, Some(0));
        assert!((r.links[0].inertia.mass - 2.0).abs() < 1e-12);
        assert!((r.links[0].q_max - 1.5).abs() < 1e-12);
        assert!((r.links[1].x_tree.r.z() - 0.3).abs() < 1e-12);
    }

    #[test]
    fn xml_self_closing_and_comments() {
        let x = parse_xml("<a><!-- c --><b x='1'/><b x=\"2\"></b></a>").unwrap();
        assert_eq!(x.find_all("b").len(), 2);
        assert_eq!(x.find_all("b")[0].attr("x"), Some("1"));
    }

    #[test]
    fn xml_rejects_mismatch() {
        assert!(parse_xml("<a><b></a></b>").is_err());
        assert!(parse_xml("<a>").is_err());
    }

    #[test]
    fn unsupported_joint_type_rejected() {
        let bad = SAMPLE.replace("type=\"continuous\"", "type=\"floating\"");
        assert!(robot_from_urdf(&bad).is_err());
    }

    #[test]
    fn roundtrip_through_dynamics_smoke() {
        // Parsed robot should work with State sampling.
        let r = robot_from_urdf(SAMPLE).unwrap();
        let mut rng = crate::util::rng::Rng::new(1);
        let s = crate::model::robot::State::random(&r, &mut rng);
        assert_eq!(s.q.len(), 2);
    }
}
