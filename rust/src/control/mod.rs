//! Controllers (PID with dynamics compensation, LQR, MPC/iLQR) over a
//! swappable RBD backend — the three control templates pre-implemented in
//! the ICMS (paper Fig. 4).

pub mod backend;
pub mod lqr;
pub mod mpc;
pub mod pid;

pub use backend::{Controller, RbdBackend};
