//! MPC via iLQR: at each control step, optimize a torque sequence over a
//! receding horizon using backend FD for rollouts and backend ΔFD for
//! linearization, run a fixed number of iterations (the paper's Fig. 13
//! model assumes 10 optimization-loop iterations), and apply the first
//! torque. The per-solve optimization cost is recorded (Fig. 8(d)).
//!
//! RBD dominates MPC runtime (the paper's motivating ~90% figure): each
//! iteration needs H forward-dynamics rollout steps and H ΔFD
//! linearizations — exactly the FD/ΔFD workloads the accelerator serves.

use super::backend::{Controller, RbdBackend};
use crate::model::Robot;
use crate::sim::traj::Trajectory;
use crate::spatial::DMat;

pub struct MpcController {
    pub robot: Robot,
    pub backend: RbdBackend,
    pub traj: Trajectory,
    pub horizon: usize,
    pub iters: usize,
    pub dt: f64,
    pub w_pos: f64,
    pub w_vel: f64,
    pub w_ctl: f64,
    /// Warm-started torque plan.
    plan: Vec<Vec<f64>>,
    /// Optimization cost after each solve (Fig. 8(d) series).
    pub cost_history: Vec<f64>,
}

impl MpcController {
    pub fn new(robot: Robot, backend: RbdBackend, traj: Trajectory, dt: f64) -> MpcController {
        let n = robot.dof();
        MpcController {
            robot,
            backend,
            traj,
            horizon: 12,
            iters: 10,
            dt,
            w_pos: 300.0,
            w_vel: 5.0,
            w_ctl: 1e-4,
            plan: vec![vec![0.0; n]; 12],
            cost_history: Vec::new(),
        }
    }

    fn rollout_cost(&self, t0: f64, q0: &[f64], qd0: &[f64], plan: &[Vec<f64>]) -> f64 {
        let n = self.robot.dof();
        let mut q = q0.to_vec();
        let mut qd = qd0.to_vec();
        let mut cost = 0.0;
        for (k, u) in plan.iter().enumerate() {
            let qdd = self.backend.fd(&self.robot, &q, &qd, u);
            for i in 0..n {
                qd[i] += qdd[i] * self.dt;
                q[i] += qd[i] * self.dt;
            }
            let (qr, qdr, _) = self.traj.sample(t0 + (k + 1) as f64 * self.dt);
            for i in 0..n {
                cost += self.w_pos * (q[i] - qr[i]).powi(2)
                    + self.w_vel * (qd[i] - qdr[i]).powi(2)
                    + self.w_ctl * u[i] * u[i];
            }
        }
        cost
    }

    /// One iLQR solve from state (q0, qd0) at time t0; returns the
    /// optimized plan and its cost.
    fn solve(&mut self, t0: f64, q0: &[f64], qd0: &[f64]) -> (Vec<Vec<f64>>, f64) {
        let n = self.robot.dof();
        let h = self.horizon;
        let nx = 2 * n;
        let mut plan = self.plan.clone();
        let mut best_cost = self.rollout_cost(t0, q0, qd0, &plan);

        for _ in 0..self.iters {
            // Forward rollout storing the trajectory and linearizations.
            let mut xs: Vec<(Vec<f64>, Vec<f64>)> = Vec::with_capacity(h + 1);
            xs.push((q0.to_vec(), qd0.to_vec()));
            let mut lin: Vec<(DMat, DMat, DMat)> = Vec::with_capacity(h);
            for u in plan.iter().take(h) {
                let (q, qd) = xs.last().unwrap().clone();
                lin.push(self.backend.fd_derivatives(&self.robot, &q, &qd, u));
                let qdd = self.backend.fd(&self.robot, &q, &qd, u);
                let mut q2 = q;
                let mut qd2 = qd;
                for i in 0..n {
                    qd2[i] += qdd[i] * self.dt;
                    q2[i] += qd2[i] * self.dt;
                }
                xs.push((q2, qd2));
            }

            // Backward pass: quadratic value function V = ½xᵀPx + pᵀx.
            let mut p_mat = DMat::zeros(nx, nx);
            let mut p_vec = vec![0.0; nx];
            // Terminal cost on the last state.
            {
                let (qr, qdr, _) = self.traj.sample(t0 + h as f64 * self.dt);
                let (q, qd) = &xs[h];
                for i in 0..n {
                    p_mat[(i, i)] = 2.0 * self.w_pos;
                    p_mat[(n + i, n + i)] = 2.0 * self.w_vel;
                    p_vec[i] = 2.0 * self.w_pos * (q[i] - qr[i]);
                    p_vec[n + i] = 2.0 * self.w_vel * (qd[i] - qdr[i]);
                }
            }
            let mut k_ff: Vec<Vec<f64>> = vec![vec![0.0; n]; h];
            let mut k_fb: Vec<DMat> = Vec::with_capacity(h);
            let mut ok = true;
            for k in (0..h).rev() {
                let (dq, dqd, mi) = &lin[k];
                // A, B as in the LQR module (semi-implicit discretization).
                let mut a = DMat::identity(nx);
                for i in 0..n {
                    a[(i, n + i)] += self.dt;
                    for j in 0..n {
                        a[(n + i, j)] += self.dt * dq[(i, j)];
                        a[(n + i, n + j)] += self.dt * dqd[(i, j)];
                    }
                }
                let mut b = DMat::zeros(nx, n);
                for i in 0..n {
                    for j in 0..n {
                        b[(n + i, j)] = self.dt * mi[(i, j)];
                    }
                }
                // Stage cost gradients at the nominal point.
                let (qr, qdr, _) = self.traj.sample(t0 + (k + 1) as f64 * self.dt);
                let (q, qd) = &xs[k + 1];
                let mut lx = vec![0.0; nx];
                for i in 0..n {
                    lx[i] = 2.0 * self.w_pos * (q[i] - qr[i]);
                    lx[n + i] = 2.0 * self.w_vel * (qd[i] - qdr[i]);
                }
                let mut lxx = DMat::zeros(nx, nx);
                for i in 0..n {
                    lxx[(i, i)] = 2.0 * self.w_pos;
                    lxx[(n + i, n + i)] = 2.0 * self.w_vel;
                }
                let lu: Vec<f64> = plan[k].iter().map(|u| 2.0 * self.w_ctl * u).collect();
                let luu = DMat::identity(n).scale(2.0 * self.w_ctl);

                // Q-function terms (cost-to-go after stepping).
                let at_p = a.t().matmul(&p_mat);
                let qxx = lxx.add(&at_p.matmul(&a)).symmetrize();
                let qux = b.t().matmul(&p_mat).matmul(&a);
                let quu = luu.add(&b.t().matmul(&p_mat).matmul(&b)).symmetrize();
                let qx: Vec<f64> = {
                    let apv = a.t().matvec(&p_vec);
                    lx.iter().zip(&apv).map(|(l, v)| l + v).collect()
                };
                let qu: Vec<f64> = {
                    let bpv = b.t().matvec(&p_vec);
                    lu.iter().zip(&bpv).map(|(l, v)| l + v).collect()
                };
                // Regularize and invert Quu.
                let mut quu_reg = quu.clone();
                for i in 0..n {
                    quu_reg[(i, i)] += 1e-6;
                }
                let quu_inv = match quu_reg.inverse() {
                    Some(m) => m,
                    None => {
                        ok = false;
                        break;
                    }
                };
                let kff: Vec<f64> = quu_inv.matvec(&qu).iter().map(|x| -x).collect();
                let kfb = quu_inv.matmul(&qux).scale(-1.0);
                // Value update.
                p_vec = {
                    let kq: Vec<f64> = kfb.t().matvec(&qu);
                    let qk: Vec<f64> = qux.t().matvec(&kff);
                    let kqk: Vec<f64> = kfb.t().matvec(&quu.matvec(&kff));
                    (0..nx).map(|i| qx[i] + kq[i] + qk[i] + kqk[i]).collect()
                };
                p_mat = qxx
                    .add(&kfb.t().matmul(&quu).matmul(&kfb))
                    .add(&kfb.t().matmul(&qux))
                    .add(&qux.t().matmul(&kfb))
                    .symmetrize();
                k_ff[k] = kff;
                k_fb.push(kfb);
            }
            if !ok {
                break;
            }
            k_fb.reverse();

            // Line search on the feedforward step.
            let mut improved = false;
            for alpha in [1.0, 0.5, 0.25, 0.1] {
                let mut cand = plan.clone();
                let mut q = q0.to_vec();
                let mut qd = qd0.to_vec();
                for k in 0..h {
                    let mut dx = vec![0.0; nx];
                    for i in 0..n {
                        dx[i] = q[i] - xs[k].0[i];
                        dx[n + i] = qd[i] - xs[k].1[i];
                    }
                    let fb = k_fb[k].matvec(&dx);
                    for i in 0..n {
                        cand[k][i] = plan[k][i] + alpha * k_ff[k][i] + fb[i];
                    }
                    let qdd = self.backend.fd(&self.robot, &q, &qd, &cand[k]);
                    for i in 0..n {
                        qd[i] += qdd[i] * self.dt;
                        q[i] += qd[i] * self.dt;
                    }
                }
                let c = self.rollout_cost(t0, q0, qd0, &cand);
                if c < best_cost {
                    best_cost = c;
                    plan = cand;
                    improved = true;
                    break;
                }
            }
            if !improved {
                break;
            }
        }
        (plan, best_cost)
    }
}

impl Controller for MpcController {
    fn control(&mut self, t: f64, q: &[f64], qd: &[f64]) -> Vec<f64> {
        let (plan, cost) = self.solve(t, q, qd);
        self.cost_history.push(cost);
        let u0 = plan[0].clone();
        // Warm start: shift the plan.
        let n = self.robot.dof();
        self.plan = plan;
        self.plan.rotate_left(1);
        *self.plan.last_mut().unwrap() = vec![0.0; n];
        u0
    }

    fn name(&self) -> &'static str {
        "mpc"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{builtin, State};
    use crate::sim::integrate::step_semi_implicit;

    #[test]
    fn mpc_reduces_cost_within_solve() {
        let robot = builtin::iiwa();
        let traj = Trajectory::reach(&robot, 0.3, 0.5);
        let dt = 5e-3;
        let mut ctl = MpcController::new(robot.clone(), RbdBackend::Exact, traj.clone(), dt);
        ctl.horizon = 8;
        ctl.iters = 6;
        ctl.plan = vec![vec![0.0; robot.dof()]; 8];
        let (q0, _, _) = traj.sample(0.0);
        let n = robot.dof();
        let zero_cost = ctl.rollout_cost(0.0, &q0, &vec![0.0; n], &ctl.plan.clone());
        let (_, solved_cost) = ctl.solve(0.0, &q0, &vec![0.0; n]);
        assert!(
            solved_cost < zero_cost,
            "iLQR must improve on the zero plan: {solved_cost} vs {zero_cost}"
        );
    }

    #[test]
    fn mpc_tracks_reach() {
        let robot = builtin::iiwa();
        let traj = Trajectory::reach(&robot, 0.25, 0.4);
        let dt = 5e-3;
        let mut ctl = MpcController::new(robot.clone(), RbdBackend::Exact, traj.clone(), dt);
        ctl.horizon = 8;
        ctl.iters = 4;
        ctl.plan = vec![vec![0.0; robot.dof()]; 8];
        let n = robot.dof();
        let (q0, _, _) = traj.sample(0.0);
        let mut s = State { q: q0, qd: vec![0.0; n] };
        for k in 0..160 {
            let t = k as f64 * dt;
            let tau = ctl.control(t, &s.q, &s.qd);
            step_semi_implicit(&robot, &mut s, &tau, None, dt);
        }
        let (qr, _, _) = traj.sample(0.8);
        let err: f64 =
            (0..n).map(|i| (s.q[i] - qr[i]).abs()).fold(0.0, f64::max);
        assert!(err < 0.08, "MPC terminal tracking error {err} rad");
        assert!(!ctl.cost_history.is_empty());
    }
}
