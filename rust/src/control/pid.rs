//! PID with dynamics compensation (computed-torque control): the
//! controller type the paper finds *most* sensitive to RBD quantization
//! (§III-A, Fig. 9), because the feedforward term is a direct RNEA
//! evaluation with no long-horizon correction.
//!
//!   τ = ID_backend(q, q̇, q̈_ref + Kp·e + Kd·ė + Ki·∫e)

use super::backend::{Controller, RbdBackend};
use crate::model::Robot;
use crate::sim::traj::Trajectory;

pub struct PidController {
    pub robot: Robot,
    pub backend: RbdBackend,
    pub traj: Trajectory,
    pub kp: f64,
    pub kd: f64,
    pub ki: f64,
    integral: Vec<f64>,
    last_t: f64,
}

impl PidController {
    pub fn new(robot: Robot, backend: RbdBackend, traj: Trajectory) -> PidController {
        let n = robot.dof();
        PidController {
            robot,
            backend,
            traj,
            // Deliberately simple, conventional gains (§V-A: "controller
            // settings are kept simple ... avoiding robust tuning").
            kp: 100.0,
            kd: 20.0,
            ki: 1.0,
            integral: vec![0.0; n],
            last_t: 0.0,
        }
    }
}

impl Controller for PidController {
    fn control(&mut self, t: f64, q: &[f64], qd: &[f64]) -> Vec<f64> {
        let n = self.robot.dof();
        let (qr, qdr, qddr) = self.traj.sample(t);
        let dt = (t - self.last_t).max(0.0);
        self.last_t = t;
        let mut v = vec![0.0; n];
        for i in 0..n {
            let e = qr[i] - q[i];
            let ed = qdr[i] - qd[i];
            self.integral[i] = (self.integral[i] + e * dt).clamp(-5.0, 5.0);
            v[i] = qddr[i] + self.kp * e + self.kd * ed + self.ki * self.integral[i];
        }
        // Computed torque through the (possibly quantized) backend.
        self.backend.rnea(&self.robot, q, qd, &v)
    }

    fn name(&self) -> &'static str {
        "pid"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{builtin, State};
    use crate::sim::integrate::step_semi_implicit;

    /// Exact-backend computed-torque PID must track a reach trajectory to
    /// sub-millirad joint error.
    #[test]
    fn pid_converges_to_target() {
        let robot = builtin::iiwa();
        let traj = Trajectory::reach(&robot, 0.4, 1.0);
        let mut ctl = PidController::new(robot.clone(), RbdBackend::Exact, traj.clone());
        let n = robot.dof();
        let (q0, _, _) = traj.sample(0.0);
        let mut s = State { q: q0, qd: vec![0.0; n] };
        let dt = 1e-3;
        for k in 0..3000 {
            let t = k as f64 * dt;
            let tau = ctl.control(t, &s.q, &s.qd);
            step_semi_implicit(&robot, &mut s, &tau, None, dt);
        }
        let (q_end, _, _) = traj.sample(3.0);
        for i in 0..n {
            assert!(
                (s.q[i] - q_end[i]).abs() < 1e-3,
                "joint {i}: {} vs target {}",
                s.q[i],
                q_end[i]
            );
        }
    }

    #[test]
    fn integral_windup_clamped() {
        let robot = builtin::iiwa();
        let traj = Trajectory::reach(&robot, 0.9, 0.5);
        let mut ctl = PidController::new(robot.clone(), RbdBackend::Exact, traj);
        // Hold the robot at a wrong pose for many steps; integral clamps.
        let n = robot.dof();
        let q = vec![0.0; n];
        let qd = vec![0.0; n];
        for k in 0..20000 {
            let _ = ctl.control(k as f64 * 1e-3, &q, &qd);
        }
        for i in 0..n {
            assert!(ctl.integral[i].abs() <= 5.0 + 1e-12);
        }
    }
}
