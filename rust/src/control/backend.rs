//! RBD backend abstraction: controllers compute their dynamics terms
//! either in exact f64 or in emulated fixed point. This is the switch the
//! ICMS uses to run the paired (float vs quantized) closed-loop
//! simulations of Fig. 4.

use crate::dynamics;
use crate::model::Robot;
use crate::quant::qformat::QFormat;
use crate::quant::qrbd;
use crate::spatial::DMat;

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RbdBackend {
    Exact,
    Quantized(QFormat),
}

impl RbdBackend {
    pub fn label(&self) -> String {
        match self {
            RbdBackend::Exact => "float".to_string(),
            RbdBackend::Quantized(f) => f.label(),
        }
    }

    pub fn rnea(&self, robot: &Robot, q: &[f64], qd: &[f64], qdd: &[f64]) -> Vec<f64> {
        match self {
            RbdBackend::Exact => dynamics::rnea(robot, q, qd, qdd, None),
            RbdBackend::Quantized(fmt) => qrbd::quant_rnea(robot, q, qd, qdd, *fmt),
        }
    }

    pub fn minv(&self, robot: &Robot, q: &[f64]) -> DMat {
        match self {
            RbdBackend::Exact => dynamics::minv(robot, q),
            RbdBackend::Quantized(fmt) => qrbd::quant_minv(robot, q, *fmt),
        }
    }

    pub fn fd(&self, robot: &Robot, q: &[f64], qd: &[f64], tau: &[f64]) -> Vec<f64> {
        match self {
            RbdBackend::Exact => dynamics::fd(robot, q, qd, tau, None),
            RbdBackend::Quantized(fmt) => qrbd::quant_fd(robot, q, qd, tau, *fmt),
        }
    }

    /// ΔFD blocks (∂q̈/∂q, ∂q̈/∂q̇, M⁻¹) through this backend.
    pub fn fd_derivatives(
        &self,
        robot: &Robot,
        q: &[f64],
        qd: &[f64],
        tau: &[f64],
    ) -> (DMat, DMat, DMat) {
        match self {
            RbdBackend::Exact => dynamics::fd_derivatives(robot, q, qd, tau),
            RbdBackend::Quantized(fmt) => {
                let qdd = qrbd::quant_fd(robot, q, qd, tau, *fmt);
                let (did_dq, did_dqd) =
                    qrbd::quant_rnea_derivatives(robot, q, qd, &qdd, *fmt);
                let mi = qrbd::quant_minv(robot, q, *fmt);
                let dq = mi.matmul(&did_dq).scale(-1.0);
                let dqd = mi.matmul(&did_dqd).scale(-1.0);
                (dq, dqd, mi)
            }
        }
    }
}

/// A torque controller: maps (t, q, q̇) → τ.
pub trait Controller {
    fn control(&mut self, t: f64, q: &[f64], qd: &[f64]) -> Vec<f64>;
    fn name(&self) -> &'static str;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{builtin, State};
    use crate::util::rng::Rng;

    #[test]
    fn backends_agree_at_high_precision() {
        let robot = builtin::iiwa();
        let mut rng = Rng::new(800);
        let s = State::random(&robot, &mut rng);
        let n = robot.dof();
        let qdd = rng.vec_range(n, -1.0, 1.0);
        let exact = RbdBackend::Exact.rnea(&robot, &s.q, &s.qd, &qdd);
        let fine = RbdBackend::Quantized(QFormat::new(16, 32)).rnea(&robot, &s.q, &s.qd, &qdd);
        for i in 0..n {
            assert!((exact[i] - fine[i]).abs() < 1e-4 * (1.0 + exact[i].abs()));
        }
    }

    #[test]
    fn quantized_derivative_error_visible_at_coarse_format() {
        let robot = builtin::iiwa();
        let mut rng = Rng::new(801);
        let s = State::random(&robot, &mut rng);
        let tau = rng.vec_range(robot.dof(), -5.0, 5.0);
        let (dq_e, _, _) = RbdBackend::Exact.fd_derivatives(&robot, &s.q, &s.qd, &tau);
        let (dq_q, _, _) = RbdBackend::Quantized(QFormat::new(10, 8))
            .fd_derivatives(&robot, &s.q, &s.qd, &tau);
        let err = dq_e.sub(&dq_q).frobenius();
        assert!(err > 1e-6, "coarse quantization must perturb ΔFD (got {err})");
        assert!(err < 1e3, "but not absurdly");
    }
}
