//! LQR tracking controller. Linearizes the dynamics with the backend's
//! ΔFD at a periodically-refreshed operating point, discretizes, and
//! solves the discrete-time Riccati equation by fixed-point iteration for
//! the feedback gain K. Feedforward is gravity/bias compensation through
//! the backend's RNEA; feedback acts on the state error.
//!
//! The paper (Fig. 8(a–c)) reports that LQR "exhibits limited sensitivity
//! to quantization errors in dynamics derivatives" — the quantized ΔFD
//! enters only through K, which the cost-minimizing structure smooths.

use super::backend::{Controller, RbdBackend};
use crate::model::Robot;
use crate::sim::traj::Trajectory;
use crate::spatial::DMat;

pub struct LqrController {
    pub robot: Robot,
    pub backend: RbdBackend,
    pub traj: Trajectory,
    /// State cost: position block (q_weight) and velocity block.
    pub q_pos: f64,
    pub q_vel: f64,
    pub r_ctl: f64,
    pub dt: f64,
    /// Relinearization period (control steps).
    pub relin_every: usize,
    k_gain: Option<DMat>,
    steps: usize,
}

impl LqrController {
    pub fn new(robot: Robot, backend: RbdBackend, traj: Trajectory, dt: f64) -> LqrController {
        LqrController {
            robot,
            backend,
            traj,
            q_pos: 200.0,
            q_vel: 10.0,
            r_ctl: 1e-3,
            dt,
            relin_every: 50,
            k_gain: None,
            steps: 0,
        }
    }

    /// Discrete LQR gain via Riccati fixed-point iteration.
    /// x = [q; q̇], A = I + dt·[[0, I], [∂q̈/∂q, ∂q̈/∂q̇]], B = dt·[[0]; [M⁻¹]].
    fn compute_gain(&self, q: &[f64], qd: &[f64], tau_op: &[f64]) -> DMat {
        let n = self.robot.dof();
        let (dq, dqd, mi) = self.backend.fd_derivatives(&self.robot, q, qd, tau_op);
        let nx = 2 * n;
        let mut a = DMat::identity(nx);
        for i in 0..n {
            a[(i, n + i)] += self.dt;
            for j in 0..n {
                a[(n + i, j)] += self.dt * dq[(i, j)];
                a[(n + i, n + j)] += self.dt * dqd[(i, j)];
            }
        }
        let mut b = DMat::zeros(nx, n);
        for i in 0..n {
            for j in 0..n {
                b[(n + i, j)] = self.dt * mi[(i, j)];
            }
        }
        let mut qcost = DMat::zeros(nx, nx);
        for i in 0..n {
            qcost[(i, i)] = self.q_pos;
            qcost[(n + i, n + i)] = self.q_vel;
        }
        let rcost = DMat::identity(n).scale(self.r_ctl);

        // Riccati iteration: P ← Q + Aᵀ(P − P B (R + BᵀPB)⁻¹ BᵀP)A
        let mut p = qcost.clone();
        for _ in 0..150 {
            let btp = b.t().matmul(&p);
            let s = rcost.add(&btp.matmul(&b));
            let sinv = match s.inverse() {
                Some(m) => m,
                None => break,
            };
            let k = sinv.matmul(&btp).matmul(&a); // K = (R+BᵀPB)⁻¹ BᵀP A
            let acl = a.sub(&b.matmul(&k));
            let pn = qcost
                .add(&k.t().matmul(&rcost).matmul(&k))
                .add(&acl.t().matmul(&p).matmul(&acl))
                .symmetrize();
            let delta = pn.sub(&p).max_abs();
            p = pn;
            if delta < 1e-9 {
                break;
            }
        }
        let btp = b.t().matmul(&p);
        let s = rcost.add(&btp.matmul(&b));
        s.inverse().map(|si| si.matmul(&btp).matmul(&a)).unwrap_or_else(|| DMat::zeros(n, nx))
    }
}

impl Controller for LqrController {
    fn control(&mut self, t: f64, q: &[f64], qd: &[f64]) -> Vec<f64> {
        let n = self.robot.dof();
        let (qr, qdr, qddr) = self.traj.sample(t);
        // Feedforward: follow the reference through the backend dynamics.
        let tau_ff = self.backend.rnea(&self.robot, &qr, &qdr, &qddr);
        if self.k_gain.is_none() || self.steps % self.relin_every == 0 {
            self.k_gain = Some(self.compute_gain(q, qd, &tau_ff));
        }
        self.steps += 1;
        let k = self.k_gain.as_ref().unwrap();
        // u = τ_ff − K (x − x_ref)
        let mut dx = vec![0.0; 2 * n];
        for i in 0..n {
            dx[i] = q[i] - qr[i];
            dx[n + i] = qd[i] - qdr[i];
        }
        let fb = k.matvec(&dx);
        (0..n).map(|i| tau_ff[i] - fb[i]).collect()
    }

    fn name(&self) -> &'static str {
        "lqr"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{builtin, State};
    use crate::sim::integrate::step_semi_implicit;

    #[test]
    fn lqr_tracks_sinusoid() {
        let robot = builtin::iiwa();
        let traj = Trajectory::gentle_sinusoid(&robot, 0.15, 1.0);
        let dt = 1e-3;
        let mut ctl = LqrController::new(robot.clone(), RbdBackend::Exact, traj.clone(), dt);
        let n = robot.dof();
        let (q0, qd0, _) = traj.sample(0.0);
        let mut s = State { q: q0, qd: qd0 };
        let mut worst: f64 = 0.0;
        for k in 0..1500 {
            let t = k as f64 * dt;
            let tau = ctl.control(t, &s.q, &s.qd);
            step_semi_implicit(&robot, &mut s, &tau, None, dt);
            if k > 300 {
                let (qr, _, _) = traj.sample(t + dt);
                for i in 0..n {
                    worst = worst.max((s.q[i] - qr[i]).abs());
                }
            }
        }
        assert!(worst < 0.05, "steady-state tracking error {worst} rad too large");
    }

    #[test]
    fn gain_is_stabilizing_at_equilibrium() {
        // Spectral check by simulation: from a perturbed state near the
        // operating point, the closed loop must contract.
        let robot = builtin::iiwa();
        let traj = Trajectory::reach(&robot, 0.0, 0.5); // hold midpoint
        let dt = 1e-3;
        let mut ctl = LqrController::new(robot.clone(), RbdBackend::Exact, traj.clone(), dt);
        let n = robot.dof();
        let (qr, _, _) = traj.sample(10.0);
        let mut s = State { q: qr.clone(), qd: vec![0.0; n] };
        s.q[2] += 0.1;
        let e0 = 0.1;
        for k in 0..800 {
            let t = 10.0 + k as f64 * dt;
            let tau = ctl.control(t, &s.q, &s.qd);
            step_semi_implicit(&robot, &mut s, &tau, None, dt);
        }
        let e1 = (s.q[2] - qr[2]).abs();
        assert!(e1 < 0.3 * e0, "perturbation must contract: {e0} → {e1}");
    }
}
