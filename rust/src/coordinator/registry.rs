//! Multi-robot serving registry: which robots a coordinator serves and
//! with which backend.
//!
//! DRACO's scalability claim is "across various robot types"; the
//! registry is the serving-side realization — one `draco serve` process
//! owns one engine + workspace pool per registered robot and routes jobs
//! by robot name, instead of one robot per process. Each entry also
//! picks the robot's execution backend: the f64 native engine or the
//! quantized engine at a per-robot `QFormat` (precision as a serving
//! knob, per the paper's precision-aware co-design).

use super::batcher::{BackendSpec, TrajLane};
use super::qos::QosClass;
use crate::model::{builtin_robot, Robot};
use crate::quant::QFormat;
use crate::runtime::artifact::ArtifactFn;

/// Default fixed-point format for `:quant` registry entries that do not
/// name one: the paper's 24-bit (12 int / 12 frac) DSP-friendly format.
pub const DEFAULT_QUANT_FORMAT: QFormat = QFormat::new(12, 12);

/// Which execution backend serves a registered robot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// f64 workspace engine (the default).
    Native,
    /// Rounded fixed-point engine at this format (`quant::qrbd`
    /// kernels — f64 datapath underneath, faithful error behaviour at
    /// any width ≤ 53 bits).
    NativeQuant(QFormat),
    /// True-integer `i64` engine at this format (`quant::qint` kernels;
    /// FD/M⁻¹ on the division-deferring sweeps under a shift schedule).
    /// Registration requires the fixed-point scaling analysis to accept
    /// the (robot, format) pair — see
    /// [`crate::quant::scaling::validate_int_backend`] and
    /// [`RobotRegistry::validate`]; there is **no** silent fallback to
    /// the rounded lane.
    NativeInt(QFormat),
}

impl BackendKind {
    /// Human-readable label for tables and logs.
    pub fn label(&self) -> String {
        match self {
            BackendKind::Native => "native".to_string(),
            BackendKind::NativeQuant(fmt) => format!("native-quant {}", fmt.label()),
            BackendKind::NativeInt(fmt) => format!("native-int {}", fmt.label()),
        }
    }
}

/// One registered robot: the model, its backend, its batch size, its
/// intra-route parallelism, and the M⁻¹ compensation opt-in.
#[derive(Debug, Clone)]
pub struct RobotEntry {
    /// The robot model served under its `robot.name`.
    pub robot: Robot,
    /// Execution backend for every route of this robot.
    pub backend: BackendKind,
    /// Batch size for the robot's step routes (and rollout drain cap).
    pub batch: usize,
    /// Max worker-pool chunks each step batch splits into (`0` = one per
    /// pool worker, `1` = serial). Applies to native **and** quantized
    /// routes — the pool is engine-generic.
    pub parallel: usize,
    /// Opt-in M⁻¹ error compensation (`+comp` in the CLI spec): fitted
    /// per (robot, format) and applied on the quantized M⁻¹ route;
    /// ignored by native entries and by non-Minv routes.
    pub comp: bool,
    /// Default QoS class of every route of this robot (`!class` in the
    /// CLI spec): `Control` drains before `Interactive` before `Bulk`,
    /// and per-request [`super::SubmitOptions`] can still override it.
    pub qos: QosClass,
}

/// Registry of robots one coordinator serves, keyed by robot name.
/// Insertion order is preserved: the first registered robot is the
/// coordinator's default target for [`super::Coordinator::submit`].
#[derive(Debug, Clone, Default)]
pub struct RobotRegistry {
    entries: Vec<RobotEntry>,
}

impl RobotRegistry {
    /// Empty registry.
    pub fn new() -> RobotRegistry {
        RobotRegistry::default()
    }

    /// Register (or replace) a robot under its model name. Step batches
    /// execute serially; use [`RobotRegistry::register_parallel`] to fan
    /// a route's batches out across the worker pool.
    pub fn register(&mut self, robot: Robot, backend: BackendKind, batch: usize) -> &mut Self {
        self.register_parallel(robot, backend, batch, 1)
    }

    /// Register (or replace) a robot with intra-route parallelism: each
    /// assembled step batch (native **or** quantized — the worker pool is
    /// engine-generic) splits into up to `parallel` contiguous chunks on
    /// the global worker pool (`0` = one chunk per pool worker, `1` =
    /// serial). Pooled execution is bitwise identical to serial — same
    /// kernels, one cached per-(structure, format) workspace per pool
    /// worker.
    pub fn register_parallel(
        &mut self,
        robot: Robot,
        backend: BackendKind,
        batch: usize,
        parallel: usize,
    ) -> &mut Self {
        self.register_with(robot, backend, batch, parallel, false)
    }

    /// Full registration: parallelism as in
    /// [`RobotRegistry::register_parallel`] plus the M⁻¹ compensation
    /// opt-in (meaningful on quantized backends only; see
    /// [`RobotEntry::comp`]).
    pub fn register_with(
        &mut self,
        robot: Robot,
        backend: BackendKind,
        batch: usize,
        parallel: usize,
        comp: bool,
    ) -> &mut Self {
        assert!(batch > 0, "batch must be positive");
        let entry =
            RobotEntry { robot, backend, batch, parallel, comp, qos: QosClass::default() };
        match self.entries.iter_mut().find(|e| e.robot.name == entry.robot.name) {
            Some(slot) => *slot = entry,
            None => self.entries.push(entry),
        }
        self
    }

    /// Set intra-route parallelism for every registered robot (`0` = one
    /// chunk per pool worker, `1` = serial), native and quantized alike.
    pub fn set_parallelism(&mut self, parallel: usize) -> &mut Self {
        for e in &mut self.entries {
            e.parallel = parallel;
        }
        self
    }

    /// Set the default QoS class of a registered robot's routes (no-op
    /// for unknown names). `Control` traffic drains before
    /// `Interactive` before `Bulk` on every route of the coordinator.
    pub fn set_qos(&mut self, name: &str, qos: QosClass) -> &mut Self {
        if let Some(e) = self.entries.iter_mut().find(|e| e.robot.name == name) {
            e.qos = qos;
        }
        self
    }

    /// Look a registered robot up by name.
    pub fn get(&self, name: &str) -> Option<&RobotEntry> {
        self.entries.iter().find(|e| e.robot.name == name)
    }

    /// Registered robot names, in registration order.
    pub fn names(&self) -> Vec<String> {
        self.entries.iter().map(|e| e.robot.name.clone()).collect()
    }

    /// Number of registered robots.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Whether the registry is empty.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Expand the registry into backend specs: for every robot (in
    /// registration order, so the first robot becomes the coordinator's
    /// default), one step route per RBD function (RNEA / FD / M⁻¹ /
    /// the fused multi-output `dyn_all`) on the robot's backend, plus
    /// one trajectory route.
    pub fn specs(&self) -> Vec<BackendSpec> {
        let mut specs = Vec::with_capacity(self.entries.len() * 5);
        for entry in &self.entries {
            for function in
                [ArtifactFn::Rnea, ArtifactFn::Fd, ArtifactFn::Minv, ArtifactFn::DynAll]
            {
                specs.push(match entry.backend {
                    BackendKind::Native => BackendSpec::Native {
                        robot: entry.robot.clone(),
                        function,
                        batch: entry.batch,
                        parallel: entry.parallel,
                        class: entry.qos,
                    },
                    BackendKind::NativeQuant(fmt) => BackendSpec::NativeQuant {
                        robot: entry.robot.clone(),
                        function,
                        batch: entry.batch,
                        fmt,
                        parallel: entry.parallel,
                        comp: entry.comp,
                        class: entry.qos,
                    },
                    BackendKind::NativeInt(fmt) => BackendSpec::NativeInt {
                        robot: entry.robot.clone(),
                        function,
                        batch: entry.batch,
                        fmt,
                        parallel: entry.parallel,
                        class: entry.qos,
                    },
                });
            }
            specs.push(BackendSpec::Trajectory {
                robot: entry.robot.clone(),
                batch: entry.batch,
                lane: match entry.backend {
                    BackendKind::Native => TrajLane::F64,
                    BackendKind::NativeQuant(fmt) => TrajLane::Quant(fmt),
                    BackendKind::NativeInt(fmt) => TrajLane::Int(fmt),
                },
                class: entry.qos,
            });
        }
        specs
    }

    /// Check every `qint` entry against the fixed-point scaling
    /// analysis; an `Err` names the entry and the overflowing stage.
    /// [`RobotRegistry::from_cli_spec`] runs this implicitly; callers
    /// registering [`BackendKind::NativeInt`] programmatically should
    /// call it before starting a coordinator — a failing entry's routes
    /// would otherwise answer every request with the same witness (the
    /// engine refuses to build; requests are never silently served by
    /// the rounded-f64 lane).
    pub fn validate(&self) -> Result<(), String> {
        for e in &self.entries {
            if let BackendKind::NativeInt(fmt) = e.backend {
                crate::quant::scaling::validate_int_backend(&e.robot, fmt)
                    .map_err(|err| format!("registry entry '{}': {err}", e.robot.name))?;
            }
        }
        Ok(())
    }

    /// Build a registry from a CLI spec: a comma-separated list of
    /// entries
    /// `name[=path.urdf][:native|:quant[@INT.FRAC][+comp]|:qint[@INT.FRAC]][!class]`.
    /// Plain names resolve against the builtin robots; `name=path.urdf`
    /// loads the robot through the URDF-lite importer
    /// ([`crate::model::urdf::robot_from_urdf`]) and registers it under
    /// `name`. The optional `!control` / `!interactive` / `!bulk`
    /// suffix sets the robot's default QoS class (default:
    /// `interactive`). Examples:
    ///
    /// * `iiwa` — one builtin robot, f64 native backend;
    /// * `iiwa,atlas:quant` — two robots, atlas quantized at the default
    ///   24-bit format ([`DEFAULT_QUANT_FORMAT`]);
    /// * `hyq:quant@14.18` — quantized at Q14.18;
    /// * `atlas:quant@12.10+comp` — quantized with the fitted M⁻¹ error
    ///   compensation applied on the M⁻¹ route;
    /// * `atlas:qint@12.14` — the true-integer `i64` lane; the
    ///   fixed-point scaling analysis must accept the (robot, format)
    ///   pair or registration **fails here** with the overflow witness
    ///   (an explicit `qint` spec never degrades to the rounded lane);
    /// * `arm=models/arm.urdf:quant` — a URDF-loaded robot named `arm`
    ///   served next to the builtins;
    /// * `iiwa!control,atlas:quant@12.12!bulk` — iiwa's routes drain as
    ///   `Control` (ahead of everything else under overload), atlas'
    ///   quantized routes as `Bulk` (drained last, shed first).
    pub fn from_cli_spec(spec: &str, batch: usize) -> Result<RobotRegistry, String> {
        let mut reg = RobotRegistry::new();
        for full_entry in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            // The QoS suffix is split off first — it always trails the
            // backend (`atlas:quant@12.12!bulk`). A '!'-suffix that is
            // not a class name stays part of the entry and fails robot
            // resolution loudly, instead of being silently dropped.
            let (entry, qos) = match full_entry.rsplit_once('!') {
                Some((head, tail)) => match QosClass::parse(tail.trim()) {
                    Some(class) => (head.trim(), class),
                    None => (full_entry, QosClass::default()),
                },
                None => (full_entry, QosClass::default()),
            };
            // URDF entries are recognized by '=' BEFORE splitting off the
            // backend, and their backend is the suffix after the LAST ':'
            // only when it looks like one — so paths containing ':'
            // (e.g. ros:noetic overlays) parse instead of being truncated
            // at the first colon.
            let (target, backend_str) = if entry.contains('=') {
                match entry.rsplit_once(':') {
                    Some((head, tail)) if looks_like_backend(tail.trim()) => {
                        (head.trim(), Some(tail.trim()))
                    }
                    _ => (entry, None),
                }
            } else {
                match entry.split_once(':') {
                    Some((n, b)) => (n.trim(), Some(b.trim())),
                    None => (entry, None),
                }
            };
            let robot = match target.split_once('=') {
                Some((name, path)) => {
                    let (name, path) = (name.trim(), path.trim());
                    if name.is_empty() {
                        return Err(format!("empty robot name in '{entry}'"));
                    }
                    let src = std::fs::read_to_string(path)
                        .map_err(|e| format!("cannot read urdf '{path}': {e}"))?;
                    let mut robot = crate::model::urdf::robot_from_urdf(&src)
                        .map_err(|e| format!("bad urdf '{path}': {e}"))?;
                    // The registry routes by robot name; the spec's name
                    // wins over whatever the URDF file calls itself.
                    robot.name = name.to_string();
                    robot
                }
                None => builtin_robot(target).ok_or_else(|| {
                    format!("unknown robot '{target}' (try iiwa|hyq|atlas|baxter, or name=path.urdf)")
                })?,
            };
            let (backend, comp) = match backend_str {
                None => (BackendKind::Native, false),
                Some(b) => {
                    let (core, comp) = match b.strip_suffix("+comp") {
                        Some(c) => (c.trim(), true),
                        None => (b, false),
                    };
                    match core {
                        "native" => {
                            if comp {
                                return Err(format!(
                                    "'+comp' needs a quant backend in '{entry}' (M⁻¹ \
                                     compensation corrects the quantized reciprocal)"
                                ));
                            }
                            (BackendKind::Native, false)
                        }
                        _ if core == "qint" || core.starts_with("qint@") => {
                            if comp {
                                return Err(format!(
                                    "'+comp' applies to the rounded-f64 quant lane only in \
                                     '{entry}' (the fitted offset does not model the integer \
                                     datapath)"
                                ));
                            }
                            let fmt = match core.strip_prefix("qint").unwrap().strip_prefix('@') {
                                None => DEFAULT_QUANT_FORMAT,
                                Some(f) => parse_qformat(f)?,
                            };
                            // An explicit qint spec must serve integer
                            // kernels or fail HERE with the scaling
                            // analysis' witness — never quietly degrade
                            // to the rounded-f64 lane.
                            crate::quant::scaling::validate_int_backend(&robot, fmt)
                                .map_err(|e| format!("registry entry '{entry}': {e}"))?;
                            (BackendKind::NativeInt(fmt), false)
                        }
                        _ => {
                            let rest = core.strip_prefix("quant").ok_or_else(|| {
                                format!(
                                    "unknown backend '{b}' (try native|quant[@I.F][+comp]|qint[@I.F])"
                                )
                            })?;
                            let fmt = match rest.strip_prefix('@') {
                                None if rest.is_empty() => DEFAULT_QUANT_FORMAT,
                                Some(f) => parse_qformat(f)?,
                                None => {
                                    return Err(format!(
                                        "unknown backend '{b}' \
                                         (try native|quant[@I.F][+comp]|qint[@I.F])"
                                    ))
                                }
                            };
                            (BackendKind::NativeQuant(fmt), comp)
                        }
                    }
                }
            };
            let name = robot.name.clone();
            reg.register_with(robot, backend, batch, 1, comp);
            reg.set_qos(&name, qos);
        }
        if reg.is_empty() {
            return Err("no robots given".to_string());
        }
        Ok(reg)
    }
}

/// Whether a `:`-suffix of a registry entry is a backend selector
/// (`native` / `quant…`, optionally `+comp`) rather than part of a URDF
/// path containing colons.
fn looks_like_backend(s: &str) -> bool {
    let core = s.strip_suffix("+comp").unwrap_or(s);
    // Exact grammar only: a path segment that merely *starts* with
    // "quant" (e.g. `…ros:quant_overlay/arm.urdf`) must stay a path.
    !core.contains('/')
        && (core == "native"
            || core == "quant"
            || core.starts_with("quant@")
            || core == "qint"
            || core.starts_with("qint@"))
}

/// Parse `INT.FRAC` (e.g. `12.14`) into a [`QFormat`].
fn parse_qformat(s: &str) -> Result<QFormat, String> {
    let (i, f) = s.split_once('.').ok_or_else(|| format!("bad Q-format '{s}' (want INT.FRAC)"))?;
    let int_bits: u32 = i.parse().map_err(|_| format!("bad integer bits in '{s}'"))?;
    let frac_bits: u32 = f.parse().map_err(|_| format!("bad fractional bits in '{s}'"))?;
    if int_bits == 0 || int_bits + frac_bits > 53 {
        return Err(format!("unsupported Q-format '{s}' (need 0 < INT and INT+FRAC ≤ 53)"));
    }
    Ok(QFormat::new(int_bits, frac_bits))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::Route;

    #[test]
    fn registry_expands_routes_per_robot() {
        let mut reg = RobotRegistry::new();
        reg.register(builtin_robot("iiwa").unwrap(), BackendKind::Native, 16)
            .register(builtin_robot("atlas").unwrap(), BackendKind::NativeQuant(QFormat::new(12, 14)), 8);
        assert_eq!(reg.len(), 2);
        let specs = reg.specs();
        // 4 step routes (rnea/fd/minv/dyn_all) + 1 trajectory per robot.
        assert_eq!(specs.len(), 10);
        let atlas_traj = specs
            .iter()
            .filter(|s| s.robot_name() == "atlas" && s.route() == Route::Traj)
            .count();
        assert_eq!(atlas_traj, 1);
    }

    #[test]
    fn cli_spec_parses_backends() {
        let reg = RobotRegistry::from_cli_spec("iiwa, atlas:quant,hyq:quant@14.18", 32).unwrap();
        // Registration order is preserved — the first listed robot is
        // the coordinator's default submit target.
        assert_eq!(reg.names(), vec!["iiwa", "atlas", "hyq"]);
        assert_eq!(reg.get("iiwa").unwrap().backend, BackendKind::Native);
        assert_eq!(
            reg.get("atlas").unwrap().backend,
            BackendKind::NativeQuant(DEFAULT_QUANT_FORMAT)
        );
        assert_eq!(
            reg.get("hyq").unwrap().backend,
            BackendKind::NativeQuant(QFormat::new(14, 18))
        );
    }

    #[test]
    fn cli_spec_rejects_garbage() {
        assert!(RobotRegistry::from_cli_spec("", 32).is_err());
        assert!(RobotRegistry::from_cli_spec("panda", 32).is_err());
        assert!(RobotRegistry::from_cli_spec("iiwa:fp8", 32).is_err());
        assert!(RobotRegistry::from_cli_spec("iiwa:quant@twelve.12", 32).is_err());
        assert!(RobotRegistry::from_cli_spec("iiwa:quant@0.12", 32).is_err());
        assert!(RobotRegistry::from_cli_spec("iiwa:quant@40.40", 32).is_err());
        // Compensation is a quant-only flag, and URDF paths must exist.
        assert!(RobotRegistry::from_cli_spec("iiwa:native+comp", 32).is_err());
        assert!(RobotRegistry::from_cli_spec("arm=/nonexistent/robot.urdf", 32).is_err());
        assert!(RobotRegistry::from_cli_spec("=some.urdf", 32).is_err());
    }

    /// URDF entries may contain ':' in the path: the backend is split
    /// off only when the last ':'-suffix looks like one, so the error
    /// message carries the full (untruncated) path.
    #[test]
    fn cli_spec_urdf_paths_keep_colons() {
        assert!(looks_like_backend("native"));
        assert!(looks_like_backend("quant"));
        assert!(looks_like_backend("quant+comp"));
        assert!(looks_like_backend("quant@12.14+comp"));
        assert!(!looks_like_backend("noetic/arm.urdf"));
        assert!(!looks_like_backend("quant_overlay/arm.urdf"));
        let err = RobotRegistry::from_cli_spec("arm=/data/ros:quant_overlay/arm.urdf", 32)
            .unwrap_err();
        assert!(err.contains("/data/ros:quant_overlay/arm.urdf"), "path truncated: {err}");
        let err =
            RobotRegistry::from_cli_spec("arm=/data/ros:noetic/arm.urdf", 32).unwrap_err();
        assert!(err.contains("/data/ros:noetic/arm.urdf"), "path truncated: {err}");
        // And a real backend suffix still splits off a colon-bearing path.
        let err =
            RobotRegistry::from_cli_spec("arm=/data/ros:noetic/arm.urdf:quant@12.12", 32)
                .unwrap_err();
        assert!(err.contains("/data/ros:noetic/arm.urdf"), "path truncated: {err}");
        assert!(!err.contains("quant@12.12"), "backend leaked into the path: {err}");
    }

    #[test]
    fn cli_spec_parses_comp_flag() {
        let reg =
            RobotRegistry::from_cli_spec("iiwa,atlas:quant+comp,hyq:quant@14.18+comp", 16).unwrap();
        assert!(!reg.get("iiwa").unwrap().comp);
        let atlas = reg.get("atlas").unwrap();
        assert_eq!(atlas.backend, BackendKind::NativeQuant(DEFAULT_QUANT_FORMAT));
        assert!(atlas.comp);
        let hyq = reg.get("hyq").unwrap();
        assert_eq!(hyq.backend, BackendKind::NativeQuant(QFormat::new(14, 18)));
        assert!(hyq.comp);
    }

    #[test]
    fn parallelism_applies_to_quant_entries() {
        let mut reg = RobotRegistry::new();
        reg.register(builtin_robot("iiwa").unwrap(), BackendKind::Native, 16).register(
            builtin_robot("atlas").unwrap(),
            BackendKind::NativeQuant(QFormat::new(12, 12)),
            16,
        );
        reg.set_parallelism(0);
        for spec in reg.specs() {
            match spec {
                BackendSpec::Native { parallel, .. } => assert_eq!(parallel, 0),
                BackendSpec::NativeQuant { parallel, comp, .. } => {
                    assert_eq!(parallel, 0, "quant routes must inherit parallelism");
                    assert!(!comp);
                }
                BackendSpec::NativeInt { parallel, .. } => {
                    assert_eq!(parallel, 0, "qint routes must inherit parallelism");
                }
                BackendSpec::Trajectory { .. } | BackendSpec::Chaos { .. } => {}
                #[cfg(feature = "pjrt")]
                BackendSpec::Pjrt { .. } => {}
            }
        }
    }

    #[test]
    fn cli_spec_parses_qint_backends() {
        let reg =
            RobotRegistry::from_cli_spec("iiwa:qint,atlas:qint@12.14", 16).expect("accepted");
        assert_eq!(reg.get("iiwa").unwrap().backend, BackendKind::NativeInt(DEFAULT_QUANT_FORMAT));
        assert_eq!(
            reg.get("atlas").unwrap().backend,
            BackendKind::NativeInt(QFormat::new(12, 14))
        );
        assert!(looks_like_backend("qint"));
        assert!(looks_like_backend("qint@12.14"));
        assert!(!looks_like_backend("qint_overlay/arm.urdf"));
        // The int-lane routes expand like any other backend: 4 step
        // routes + a trajectory route on the integer lane.
        let specs = reg.specs();
        assert_eq!(specs.len(), 10);
        let int_steps = specs
            .iter()
            .filter(|s| matches!(s, BackendSpec::NativeInt { .. }))
            .count();
        assert_eq!(int_steps, 8);
        assert!(specs.iter().any(|s| matches!(
            s,
            BackendSpec::Trajectory { lane: TrajLane::Int(_), .. }
        )));
    }

    /// The no-silent-fallback satellite: an explicit `qint` spec that
    /// the integer lane cannot carry must fail REGISTRATION with the
    /// reason — wide words name the width cap, range rejections name
    /// the overflowing stage and joint.
    #[test]
    fn cli_spec_qint_rejections_carry_the_witness() {
        let err = RobotRegistry::from_cli_spec("iiwa:qint@16.16", 16).unwrap_err();
        assert!(err.contains("26"), "width cap not named: {err}");
        let err = RobotRegistry::from_cli_spec("baxter:qint@12.12", 16).unwrap_err();
        assert!(err.contains("minv.Dinv"), "overflow stage not named: {err}");
        assert!(err.contains("w2"), "overflowing joint not named: {err}");
        // One more integer bit and the same robot registers fine.
        RobotRegistry::from_cli_spec("baxter:qint@13.13", 16).expect("baxter@13.13 fits");
        // Compensation models the rounded lane's reciprocal, not the
        // integer datapath.
        assert!(RobotRegistry::from_cli_spec("iiwa:qint+comp", 16).is_err());
        assert!(RobotRegistry::from_cli_spec("iiwa:qint@12.12+comp", 16).is_err());
    }

    /// The `!class` suffix sets the robot's default QoS class and flows
    /// through to every expanded backend spec.
    #[test]
    fn cli_spec_parses_qos_classes() {
        let reg =
            RobotRegistry::from_cli_spec("iiwa!control,atlas:quant@12.12!bulk,hyq", 16).unwrap();
        assert_eq!(reg.get("iiwa").unwrap().qos, QosClass::Control);
        assert_eq!(reg.get("atlas").unwrap().qos, QosClass::Bulk);
        assert_eq!(
            reg.get("atlas").unwrap().backend,
            BackendKind::NativeQuant(DEFAULT_QUANT_FORMAT),
            "the backend still parses underneath the QoS suffix"
        );
        assert_eq!(reg.get("hyq").unwrap().qos, QosClass::Interactive, "default class");
        for spec in reg.specs() {
            let want = match spec.robot_name() {
                "iiwa" => QosClass::Control,
                "atlas" => QosClass::Bulk,
                _ => QosClass::Interactive,
            };
            assert_eq!(spec.class(), want, "spec class for {}", spec.robot_name());
        }
        // A '!'-suffix that is not a class name fails loudly instead of
        // being silently dropped.
        let err = RobotRegistry::from_cli_spec("iiwa!fast", 16).unwrap_err();
        assert!(err.contains("iiwa!fast"), "{err}");
        // set_qos on an unknown name is a no-op.
        let mut reg = RobotRegistry::new();
        reg.register(builtin_robot("iiwa").unwrap(), BackendKind::Native, 8)
            .set_qos("panda", QosClass::Bulk);
        assert_eq!(reg.get("iiwa").unwrap().qos, QosClass::Interactive);
    }

    /// Programmatic registrations go through [`RobotRegistry::validate`].
    #[test]
    fn validate_checks_programmatic_int_entries() {
        let mut reg = RobotRegistry::new();
        reg.register(
            builtin_robot("baxter").unwrap(),
            BackendKind::NativeInt(QFormat::new(12, 12)),
            8,
        );
        let err = reg.validate().unwrap_err();
        assert!(err.contains("baxter") && err.contains("minv.Dinv"), "{err}");
        let mut ok = RobotRegistry::new();
        ok.register(builtin_robot("iiwa").unwrap(), BackendKind::NativeInt(QFormat::new(12, 14)), 8)
            .register(builtin_robot("hyq").unwrap(), BackendKind::Native, 8);
        ok.validate().expect("valid registry");
    }
}
