//! Quality-of-service substrate for the serving coordinator: priority
//! classes, deadline-aware admission control, and per-route circuit
//! breakers.
//!
//! The batcher is fast when traffic is polite; this module is what keeps
//! it **predictable when traffic is not**. Three mechanisms compose:
//!
//! * **Priority classes** ([`QosClass`]): every job carries a class
//!   (`Control > Interactive > Bulk`); batch formation drains higher
//!   classes first, so a 1 kHz control-loop request never waits behind a
//!   10 k-row analytics backlog on the same route.
//! * **Admission control** ([`RouteGate`]): per-class queues are bounded.
//!   A job that would overflow its class queue is refused *at submission*
//!   with a structured [`ServeError::Rejected`] carrying a
//!   `retry_after_us` hint — overload degrades into explicit shed
//!   responses instead of unbounded queueing and silent stall. Jobs may
//!   also carry a deadline; a job whose deadline passes while queued is
//!   dropped at batch formation as [`ServeError::Expired`] and is
//!   **never executed**.
//! * **Fault isolation**: a panicking engine evaluation is caught at the
//!   batch boundary (it fails only its own batch), failures are counted
//!   per route, and [`QosPolicy::breaker_trip`] consecutive failures trip
//!   a circuit breaker — the route sheds with [`ServeError::Shed`] for a
//!   cooldown, then half-opens and recovers on the first healthy batch.

use std::fmt;
use std::sync::atomic::{AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::time::Instant;

/// Priority class of a served request. Lower [`QosClass::index`] drains
/// first: batch formation exhausts `Control` before `Interactive` before
/// `Bulk`, so under overload the strict priority order decides who rides
/// and the per-class admission caps decide who sheds.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub enum QosClass {
    /// Hard-deadline control-loop traffic (e.g. a 1 kHz QP controller):
    /// drained first, expected to be a small fraction of offered load.
    Control,
    /// Interactive queries (teleop previews, debugging probes): drained
    /// after `Control`, before `Bulk`. The default class.
    #[default]
    Interactive,
    /// Throughput workloads (analytics sweeps, dataset generation, RL
    /// rollout farms): drained last and shed first under overload.
    Bulk,
}

impl QosClass {
    /// Every class, in draining order (highest priority first).
    pub const ALL: [QosClass; 3] = [QosClass::Control, QosClass::Interactive, QosClass::Bulk];

    /// Dense index in draining order: `Control = 0`, `Interactive = 1`,
    /// `Bulk = 2`.
    pub fn index(self) -> usize {
        match self {
            QosClass::Control => 0,
            QosClass::Interactive => 1,
            QosClass::Bulk => 2,
        }
    }

    /// Lower-case name, as accepted by the `!class` registry-spec suffix.
    pub fn name(self) -> &'static str {
        match self {
            QosClass::Control => "control",
            QosClass::Interactive => "interactive",
            QosClass::Bulk => "bulk",
        }
    }

    /// Parse a class name (`control` / `interactive` / `bulk`).
    pub fn parse(s: &str) -> Option<QosClass> {
        match s {
            "control" => Some(QosClass::Control),
            "interactive" => Some(QosClass::Interactive),
            "bulk" => Some(QosClass::Bulk),
            _ => None,
        }
    }
}

impl fmt::Display for QosClass {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Per-request submission options: class override and optional deadline.
#[derive(Debug, Clone, Copy, Default)]
pub struct SubmitOptions {
    /// Priority class; `None` inherits the route's default class.
    pub class: Option<QosClass>,
    /// Deadline relative to submission [µs]. A job still queued when its
    /// deadline passes is dropped at batch formation with
    /// [`ServeError::Expired`] — it is never executed.
    pub deadline_us: Option<u64>,
}

impl SubmitOptions {
    /// Options carrying only a class override.
    pub fn class(class: QosClass) -> SubmitOptions {
        SubmitOptions { class: Some(class), deadline_us: None }
    }

    /// Options carrying only a relative deadline [µs].
    pub fn deadline_us(deadline_us: u64) -> SubmitOptions {
        SubmitOptions { class: None, deadline_us: Some(deadline_us) }
    }
}

/// Structured serving error: every refused, expired, or failed request
/// names *why* and, where retrying makes sense, *when*.
#[derive(Debug, Clone, PartialEq)]
pub enum ServeError {
    /// Admission control refused the job: its class queue is at capacity.
    /// The job was never enqueued; retry after the hint.
    Rejected {
        /// Class whose queue was full.
        class: QosClass,
        /// Queue depth observed at admission (admitted, not yet
        /// answered).
        depth: usize,
        /// Suggested backoff before retrying [µs] (current backlog in
        /// batch windows).
        retry_after_us: u64,
    },
    /// The route's circuit breaker is open after consecutive batch
    /// failures; the route sheds instead of queueing onto a faulty
    /// engine. Retry after the hint (the breaker half-opens then).
    Shed {
        /// Consecutive batch failures observed when the breaker tripped.
        consecutive_failures: u32,
        /// Remaining breaker cooldown [µs].
        retry_after_us: u64,
    },
    /// The job's deadline passed while it was queued; it was dropped at
    /// batch formation and **never executed**.
    Expired {
        /// The deadline the job carried, relative to submission [µs].
        deadline_us: u64,
        /// How long the job had waited when it was dropped [µs].
        waited_us: u64,
    },
    /// Execution-layer failure: engine error or a caught engine panic
    /// (the panic fails only the batch it was in; the route keeps
    /// serving).
    Engine(String),
    /// Malformed request (arity/shape/routing), refused before
    /// execution.
    BadRequest(String),
    /// The coordinator is shutting down; queued jobs are answered with
    /// this error instead of being executed or silently dropped.
    ShuttingDown,
    /// The consumer of this job's responses disconnected while the job
    /// was still queued; it was dropped at batch formation and **never
    /// executed** (a job already streaming is cancelled between rows
    /// via the sink's `alive` poll instead).
    Cancelled,
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::Rejected { class, depth, retry_after_us } => write!(
                f,
                "rejected: {class} queue full (depth {depth}); retry after {retry_after_us} µs"
            ),
            ServeError::Shed { consecutive_failures, retry_after_us } => write!(
                f,
                "shed: circuit open after {consecutive_failures} consecutive batch failures; \
                 retry after {retry_after_us} µs"
            ),
            ServeError::Expired { deadline_us, waited_us } => write!(
                f,
                "expired: {deadline_us} µs deadline passed after {waited_us} µs in queue \
                 (never executed)"
            ),
            ServeError::Engine(msg) => write!(f, "engine error: {msg}"),
            ServeError::BadRequest(msg) => write!(f, "bad request: {msg}"),
            ServeError::ShuttingDown => f.write_str("coordinator shutting down"),
            ServeError::Cancelled => {
                f.write_str("cancelled: consumer disconnected before execution")
            }
        }
    }
}

impl std::error::Error for ServeError {}

/// Overload policy of one coordinator: admission caps and breaker
/// tuning. Shared by every route the coordinator starts.
#[derive(Debug, Clone, Copy)]
pub struct QosPolicy {
    /// Per-class admission cap, indexed by [`QosClass::index`]: the
    /// maximum number of admitted-but-unanswered jobs per (route,
    /// class). Admissions beyond the cap return
    /// [`ServeError::Rejected`].
    pub queue_cap: [usize; 3],
    /// Consecutive failed batches that trip a route's circuit breaker.
    pub breaker_trip: u32,
    /// How long a tripped breaker sheds before half-opening [µs].
    pub breaker_cooldown_us: u64,
}

impl Default for QosPolicy {
    fn default() -> QosPolicy {
        QosPolicy {
            // Control gets the deepest queue (it drains first anyway);
            // bulk the shallowest, so overload converts to explicit
            // shed responses quickly instead of a long silent stall.
            queue_cap: [4096, 2048, 1024],
            breaker_trip: 5,
            breaker_cooldown_us: 100_000,
        }
    }
}

/// Shared admission state of one route: per-class depth gauges the
/// submitting side checks before enqueueing, plus the circuit-breaker
/// state the route worker updates after every batch.
///
/// Depths count **admitted but unanswered** jobs (queued *or* in the
/// batch currently executing); the worker releases one unit per job when
/// its response is sent, whatever the outcome. The count is maintained
/// with relaxed-failure `fetch_add`/`fetch_sub` pairs, so a burst racing
/// the cap can transiently overshoot by the number of racing submitters
/// — bounded and harmless for load shedding.
#[derive(Debug)]
pub(crate) struct RouteGate {
    /// Default class for jobs submitted without an override.
    pub(crate) default_class: QosClass,
    policy: QosPolicy,
    /// Route batch size (retry-hint quantum).
    batch: usize,
    /// Route batching window [µs] (retry-hint quantum).
    window_us: u64,
    depths: [AtomicUsize; 3],
    /// Monotonic time base for the breaker timestamps.
    epoch: Instant,
    /// µs since `epoch` until which the breaker sheds; `0` = closed.
    open_until_us: AtomicU64,
    /// Consecutive failed batches (reset by any successful batch).
    failures: AtomicU32,
}

impl RouteGate {
    /// Gate for one route.
    pub(crate) fn new(
        default_class: QosClass,
        policy: QosPolicy,
        batch: usize,
        window_us: u64,
    ) -> RouteGate {
        RouteGate {
            default_class,
            policy,
            batch: batch.max(1),
            window_us: window_us.max(1),
            depths: [AtomicUsize::new(0), AtomicUsize::new(0), AtomicUsize::new(0)],
            epoch: Instant::now(),
            open_until_us: AtomicU64::new(0),
            failures: AtomicU32::new(0),
        }
    }

    fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Remaining breaker cooldown, or `None` when the breaker is closed
    /// (or half-open: a lapsed cooldown admits probes again).
    pub(crate) fn breaker_open(&self) -> Option<(u32, u64)> {
        let until = self.open_until_us.load(Ordering::Acquire);
        if until == 0 {
            return None;
        }
        let now = self.now_us();
        if now < until {
            Some((self.failures.load(Ordering::Relaxed), until - now))
        } else {
            None
        }
    }

    /// Try to admit one job of `class`. On success the class depth is
    /// charged one unit (released via [`RouteGate::release`] when the
    /// job is answered); on refusal the returned error carries the
    /// retry-after hint.
    pub(crate) fn admit(&self, class: QosClass) -> Result<(), ServeError> {
        if let Some((consecutive_failures, retry_after_us)) = self.breaker_open() {
            return Err(ServeError::Shed { consecutive_failures, retry_after_us });
        }
        let i = class.index();
        let prev = self.depths[i].fetch_add(1, Ordering::AcqRel);
        if prev >= self.policy.queue_cap[i] {
            self.depths[i].fetch_sub(1, Ordering::AcqRel);
            // Backlog expressed in batch windows: a full queue of D jobs
            // needs ~D/batch flushes, each at most one window apart.
            let retry_after_us =
                self.window_us.saturating_mul(prev as u64 / self.batch as u64 + 1);
            return Err(ServeError::Rejected { class, depth: prev, retry_after_us });
        }
        Ok(())
    }

    /// Release one admitted unit of `class` (the job was answered).
    pub(crate) fn release(&self, class: QosClass) {
        self.depths[class.index()].fetch_sub(1, Ordering::AcqRel);
    }

    /// Admitted-but-unanswered depth of `class`.
    pub(crate) fn depth(&self, class: QosClass) -> usize {
        self.depths[class.index()].load(Ordering::Acquire)
    }

    /// A batch succeeded: reset the failure streak and close the breaker
    /// (a half-open probe that succeeds recovers the route).
    pub(crate) fn on_success(&self) {
        self.failures.store(0, Ordering::Relaxed);
        self.open_until_us.store(0, Ordering::Release);
    }

    /// A batch failed: extend the failure streak, tripping (or
    /// re-tripping, for a failed half-open probe) the breaker at the
    /// policy threshold. Returns `true` when this failure tripped it.
    pub(crate) fn on_failure(&self) -> bool {
        let streak = self.failures.fetch_add(1, Ordering::Relaxed).saturating_add(1);
        if streak >= self.policy.breaker_trip {
            self.open_until_us
                .store(self.now_us() + self.policy.breaker_cooldown_us, Ordering::Release);
            true
        } else {
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn class_order_and_names_round_trip() {
        assert!(QosClass::Control < QosClass::Interactive);
        assert!(QosClass::Interactive < QosClass::Bulk);
        for (i, c) in QosClass::ALL.iter().enumerate() {
            assert_eq!(c.index(), i);
            assert_eq!(QosClass::parse(c.name()), Some(*c));
        }
        assert_eq!(QosClass::parse("batch"), None);
        assert_eq!(QosClass::default(), QosClass::Interactive);
    }

    #[test]
    fn gate_admits_to_cap_then_rejects_with_retry_hint() {
        let policy = QosPolicy { queue_cap: [2, 2, 2], ..QosPolicy::default() };
        let gate = RouteGate::new(QosClass::Bulk, policy, 4, 100);
        assert!(gate.admit(QosClass::Bulk).is_ok());
        assert!(gate.admit(QosClass::Bulk).is_ok());
        match gate.admit(QosClass::Bulk) {
            Err(ServeError::Rejected { class, depth, retry_after_us }) => {
                assert_eq!(class, QosClass::Bulk);
                assert_eq!(depth, 2);
                assert!(retry_after_us >= 100);
            }
            other => panic!("expected Rejected, got {other:?}"),
        }
        // Caps are per class: control still admits.
        assert!(gate.admit(QosClass::Control).is_ok());
        // Releasing frees a slot.
        gate.release(QosClass::Bulk);
        assert!(gate.admit(QosClass::Bulk).is_ok());
    }

    #[test]
    fn breaker_trips_after_streak_and_recovers_on_success() {
        let policy =
            QosPolicy { breaker_trip: 3, breaker_cooldown_us: 3_600_000_000, ..QosPolicy::default() };
        let gate = RouteGate::new(QosClass::Interactive, policy, 4, 100);
        assert!(!gate.on_failure());
        assert!(!gate.on_failure());
        assert!(gate.breaker_open().is_none(), "two failures must not trip a 3-trip breaker");
        assert!(gate.on_failure(), "third failure trips");
        let (fails, retry) = gate.breaker_open().expect("breaker open");
        assert_eq!(fails, 3);
        assert!(retry > 0);
        assert!(matches!(gate.admit(QosClass::Control), Err(ServeError::Shed { .. })));
        // A successful (half-open) batch closes the breaker.
        gate.on_success();
        assert!(gate.breaker_open().is_none());
        assert!(gate.admit(QosClass::Control).is_ok());
    }

    #[test]
    fn serve_errors_display_their_fields() {
        let s = ServeError::Rejected { class: QosClass::Bulk, depth: 7, retry_after_us: 400 }
            .to_string();
        assert!(s.contains("bulk") && s.contains("400"), "{s}");
        let s = ServeError::Expired { deadline_us: 10, waited_us: 55 }.to_string();
        assert!(s.contains("never executed"), "{s}");
        let s = ServeError::Shed { consecutive_failures: 5, retry_after_us: 9 }.to_string();
        assert!(s.contains("circuit open"), "{s}");
    }
}
