//! Dynamic batcher: per-(function) worker threads that coalesce requests
//! into engine-sized batches under a latency window.
//!
//! Each route is backed by a [`BackendSpec`]: the native workspace engine
//! (default — one [`NativeEngine`] and hence one `DynWorkspace` per
//! worker thread) or, behind the `pjrt` feature, a compiled PJRT
//! artifact. The batching loop is identical either way.

use super::stats::{ServeStats, StatsInner};
use crate::model::Robot;
#[cfg(feature = "pjrt")]
use crate::runtime::artifact::ArtifactMeta;
use crate::runtime::artifact::ArtifactFn;
use crate::runtime::native::NativeEngine;
use std::collections::BTreeMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// One request: flat f32 operands for a single task (each of length N,
/// or N·N where applicable).
pub struct Job {
    pub operands: Vec<Vec<f32>>,
    pub enqueued: Instant,
    pub resp: Sender<JobResult>,
}

/// Per-task result: the flat f32 output slice for this task.
pub type JobResult = Result<Vec<f32>, String>;

enum Msg {
    Work(Job),
    Stop,
}

/// How one route executes its batches.
pub enum BackendSpec {
    /// Native workspace engine: no artifacts, no external toolchain.
    Native { robot: Robot, function: ArtifactFn, batch: usize },
    /// Compiled PJRT artifact (requires the `pjrt` feature + artifacts).
    #[cfg(feature = "pjrt")]
    Pjrt(ArtifactMeta),
}

impl BackendSpec {
    pub fn function(&self) -> ArtifactFn {
        match self {
            BackendSpec::Native { function, .. } => *function,
            #[cfg(feature = "pjrt")]
            BackendSpec::Pjrt(meta) => meta.function,
        }
    }
}

/// Uniform executor interface the batching loop drives.
trait BatchExecutor {
    fn batch(&self) -> usize;
    fn arity(&self) -> usize;
    fn n(&self) -> usize;
    fn out_per_task(&self) -> usize;
    /// Whether the executor's shapes are compiled-in (PJRT) and partial
    /// batches must be padded to `batch()`. The native engine accepts
    /// any row count ≤ batch, so partial batches cost only the real
    /// tasks.
    fn pad_to_batch(&self) -> bool;
    fn execute(&mut self, inputs: &[Vec<f32>]) -> Result<Vec<f32>, String>;
}

struct NativeExecutor(NativeEngine);

impl BatchExecutor for NativeExecutor {
    fn batch(&self) -> usize {
        self.0.batch
    }
    fn arity(&self) -> usize {
        self.0.function.arity()
    }
    fn n(&self) -> usize {
        self.0.n()
    }
    fn out_per_task(&self) -> usize {
        self.0.expected_output_len() / self.0.batch
    }
    fn pad_to_batch(&self) -> bool {
        false
    }
    fn execute(&mut self, inputs: &[Vec<f32>]) -> Result<Vec<f32>, String> {
        self.0.run(inputs).map_err(|e| e.0)
    }
}

/// PJRT client + engine pair; the engine is declared first so it drops
/// before the client that compiled it.
#[cfg(feature = "pjrt")]
struct PjrtExecutor {
    engine: crate::runtime::engine::Engine,
    _client: xla::PjRtClient,
}

#[cfg(feature = "pjrt")]
impl BatchExecutor for PjrtExecutor {
    fn batch(&self) -> usize {
        self.engine.meta.batch
    }
    fn arity(&self) -> usize {
        self.engine.meta.function.arity()
    }
    fn n(&self) -> usize {
        self.engine.n
    }
    fn out_per_task(&self) -> usize {
        self.engine.expected_output_len() / self.engine.meta.batch
    }
    fn pad_to_batch(&self) -> bool {
        true
    }
    fn execute(&mut self, inputs: &[Vec<f32>]) -> Result<Vec<f32>, String> {
        self.engine.run(inputs).map_err(|e| e.0)
    }
}

/// Routing front-end: submit() → per-function worker.
pub struct Coordinator {
    routes: BTreeMap<ArtifactFn, Sender<Msg>>,
    workers: Vec<JoinHandle<()>>,
    stats: Arc<Mutex<StatsInner>>,
}

impl Coordinator {
    /// Start one worker per backend spec. `n` is the robot DOF (used by
    /// the PJRT path to define operand shapes); `window_us` is the
    /// batching window (deadline to fill a batch).
    pub fn start(specs: Vec<BackendSpec>, n: usize, window_us: u64) -> Coordinator {
        let stats = Arc::new(Mutex::new(StatsInner::default()));
        let mut routes = BTreeMap::new();
        let mut workers = Vec::new();
        for spec in specs {
            let (tx, rx) = channel::<Msg>();
            routes.insert(spec.function(), tx);
            let st = Arc::clone(&stats);
            workers.push(std::thread::spawn(move || worker_loop(spec, n, window_us, rx, st)));
        }
        Coordinator { routes, workers, stats }
    }

    /// Start a native coordinator serving `functions` for one robot, one
    /// worker (and one workspace) per function.
    pub fn start_native(
        robot: &Robot,
        functions: &[(ArtifactFn, usize)],
        window_us: u64,
    ) -> Coordinator {
        let n = robot.dof();
        let specs = functions
            .iter()
            .map(|&(function, batch)| BackendSpec::Native {
                robot: robot.clone(),
                function,
                batch,
            })
            .collect();
        Coordinator::start(specs, n, window_us)
    }

    /// Start a PJRT coordinator over compiled artifacts.
    #[cfg(feature = "pjrt")]
    pub fn start_pjrt(artifacts: Vec<ArtifactMeta>, n: usize, window_us: u64) -> Coordinator {
        let specs = artifacts.into_iter().map(BackendSpec::Pjrt).collect();
        Coordinator::start(specs, n, window_us)
    }

    /// Submit one task; returns the channel the result arrives on.
    pub fn submit(&self, function: ArtifactFn, operands: Vec<Vec<f32>>) -> Receiver<JobResult> {
        let (tx, rx) = channel();
        match self.routes.get(&function) {
            Some(route) => {
                let job = Job { operands, enqueued: Instant::now(), resp: tx };
                if route.send(Msg::Work(job)).is_err() {
                    // Worker gone: report through the response channel by
                    // dropping tx — recv() errors out on the caller side.
                }
            }
            None => {
                let _ = tx.send(Err(format!("no executable for {}", function.name())));
            }
        }
        rx
    }

    pub fn stats(&self) -> ServeStats {
        self.stats.lock().unwrap().snapshot()
    }

    pub fn shutdown(self) {
        for (_, tx) in &self.routes {
            let _ = tx.send(Msg::Stop);
        }
        drop(self.routes);
        for w in self.workers {
            let _ = w.join();
        }
    }
}

/// Worker: owns its executor. PJRT handles are not `Send`, and the native
/// engine's workspace is deliberately thread-local, so everything is
/// created inside the thread.
fn worker_loop(
    spec: BackendSpec,
    n: usize,
    window_us: u64,
    rx: Receiver<Msg>,
    stats: Arc<Mutex<StatsInner>>,
) {
    let mut exec: Box<dyn BatchExecutor> = match spec {
        BackendSpec::Native { robot, function, batch } => {
            Box::new(NativeExecutor(NativeEngine::new(robot, function, batch)))
        }
        #[cfg(feature = "pjrt")]
        BackendSpec::Pjrt(meta) => {
            let client = match xla::PjRtClient::cpu() {
                Ok(c) => c,
                Err(e) => {
                    fail_all(&rx, &format!("pjrt client: {e:?}"));
                    return;
                }
            };
            let engine = match crate::runtime::engine::Engine::load(&client, meta, n) {
                Ok(e) => e,
                Err(e) => {
                    fail_all(&rx, &e.0);
                    return;
                }
            };
            Box::new(PjrtExecutor { engine, _client: client })
        }
    };
    let _ = n; // used only by the pjrt arm
    let b = exec.batch();
    let window = Duration::from_micros(window_us);

    let mut queue: Vec<Job> = Vec::with_capacity(b);
    loop {
        // Block for the first job, then drain within the window.
        match rx.recv() {
            Ok(Msg::Work(j)) => queue.push(j),
            Ok(Msg::Stop) | Err(_) => break,
        }
        let deadline = Instant::now() + window;
        while queue.len() < b {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(Msg::Work(j)) => queue.push(j),
                Ok(Msg::Stop) => {
                    flush(exec.as_mut(), &mut queue, &stats);
                    return;
                }
                Err(_) => break,
            }
        }
        flush(exec.as_mut(), &mut queue, &stats);
    }
    flush(exec.as_mut(), &mut queue, &stats);
}

/// Execute the queued jobs as one padded batch and fan results out.
fn flush(exec: &mut dyn BatchExecutor, queue: &mut Vec<Job>, stats: &Arc<Mutex<StatsInner>>) {
    if queue.is_empty() {
        return;
    }
    let b = exec.batch();
    let n = exec.n();
    let arity = exec.arity();

    // Reject malformed jobs up front: a bad task must fail alone instead
    // of poisoning (or panicking) the whole assembled batch.
    let mut k = 0;
    while k < queue.len() {
        let ok = queue[k].operands.len() == arity
            && queue[k].operands.iter().all(|op| op.len() == n);
        if ok {
            k += 1;
        } else {
            let job = queue.remove(k);
            let _ = job
                .resp
                .send(Err(format!("bad operands: expected {arity} arrays of length {n}")));
        }
    }
    if queue.is_empty() {
        return;
    }
    let fill = queue.len().min(b);

    // Assemble operands, padding the tail by repeating the last task
    // (keeps the padded rows numerically benign).
    let mut inputs: Vec<Vec<f32>> = vec![Vec::with_capacity(b * n); arity];
    for job in queue.iter().take(fill) {
        for (k, op) in job.operands.iter().enumerate().take(arity) {
            inputs[k].extend_from_slice(op);
        }
    }
    if exec.pad_to_batch() {
        for _ in fill..b {
            for input in inputs.iter_mut() {
                let last: Vec<f32> = input[(fill - 1) * n..fill * n].to_vec();
                input.extend_from_slice(&last);
            }
        }
    }

    let t0 = Instant::now();
    let result = exec.execute(&inputs);
    let exec_us = t0.elapsed().as_micros() as f64;

    let out_per_task = exec.out_per_task();
    match result {
        Ok(flat) => {
            for (i, job) in queue.drain(..).enumerate() {
                if i < fill {
                    let chunk = flat[i * out_per_task..(i + 1) * out_per_task].to_vec();
                    let wait_us = job.enqueued.elapsed().as_micros() as f64;
                    stats.lock().unwrap().record(wait_us);
                    let _ = job.resp.send(Ok(chunk));
                } else {
                    let _ = job.resp.send(Err("overflow past batch".into()));
                }
            }
            stats.lock().unwrap().record_batch(fill as f64 / b as f64, exec_us);
        }
        Err(e) => {
            for job in queue.drain(..) {
                let _ = job.resp.send(Err(e.clone()));
            }
        }
    }
}

#[allow(dead_code)] // only reachable from the pjrt arm without the feature
fn fail_all(rx: &Receiver<Msg>, err: &str) {
    while let Ok(msg) = rx.recv() {
        match msg {
            Msg::Work(j) => {
                let _ = j.resp.send(Err(err.to_string()));
            }
            Msg::Stop => break,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::builtin_robot;

    #[test]
    fn submit_unknown_function_errors_fast() {
        let coord = Coordinator::start(Vec::new(), 7, 100);
        let rx = coord.submit(ArtifactFn::Minv, vec![vec![0.0; 7]]);
        let res = rx.recv().unwrap();
        assert!(res.is_err());
        coord.shutdown();
    }

    #[test]
    fn native_worker_answers_without_artifacts() {
        let robot = builtin_robot("iiwa").unwrap();
        let n = robot.dof();
        let coord = Coordinator::start_native(&robot, &[(ArtifactFn::Rnea, 8)], 100);
        let rx = coord.submit(ArtifactFn::Rnea, vec![vec![0.1; n]; 3]);
        let res = rx.recv().expect("worker must answer");
        let out = res.expect("native execution succeeds");
        assert_eq!(out.len(), n);
        assert!(out.iter().all(|x| x.is_finite()));
        coord.shutdown();
    }

    #[test]
    fn native_worker_reports_shape_errors() {
        let robot = builtin_robot("iiwa").unwrap();
        let coord = Coordinator::start_native(&robot, &[(ArtifactFn::Rnea, 4)], 100);
        // Wrong arity: one operand instead of three.
        let rx = coord.submit(ArtifactFn::Rnea, vec![vec![0.0; 7]]);
        let res = rx.recv().expect("worker must answer even on failure");
        assert!(res.is_err());
        coord.shutdown();
    }
}
