//! Dynamic batcher: per-route worker threads that coalesce requests into
//! engine-sized batches under a latency window.
//!
//! Routes are keyed by **(robot, route)** so a single coordinator serves
//! many registered robots concurrently — the multi-tenant operating model
//! of the accelerator (one deployment, heterogeneous dynamics queries).
//! Each route is backed by a [`BackendSpec`]: the native f64 workspace
//! engine, the rounded fixed-point engine at a per-robot `QFormat`, the
//! true-integer `i64` engine under a proved shift schedule, a
//! trajectory-rollout route driven through the workspace integrator
//! (on the robot's serving lane — see [`TrajLane`]), or (behind the
//! `pjrt` feature) a compiled PJRT artifact. The batching loop is
//! identical either way.

use super::registry::RobotRegistry;
use super::stats::{ServeStats, StatsInner};
use crate::model::Robot;
use crate::quant::QFormat;
#[cfg(feature = "pjrt")]
use crate::runtime::artifact::ArtifactMeta;
use crate::runtime::artifact::ArtifactFn;
use crate::runtime::{DynamicsEngine, NativeEngine, QIntEngine, QuantEngine};
use std::collections::BTreeMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// One trajectory request: an initial state plus H torque rows, unrolled
/// server-side through the workspace integrator in a single dispatch.
#[derive(Debug, Clone)]
pub struct TrajRequest {
    /// Initial joint positions (length N).
    pub q0: Vec<f32>,
    /// Initial joint velocities (length N).
    pub qd0: Vec<f32>,
    /// H torque rows, row-major flat (length H·N).
    pub tau: Vec<f32>,
    /// Integration step [s].
    pub dt: f64,
}

/// What a job carries: one step task or one trajectory rollout.
pub enum JobPayload {
    /// Flat f32 operands for a single step task (each of length N).
    Step(Vec<Vec<f32>>),
    /// A trajectory rollout request.
    Traj(TrajRequest),
}

/// One queued request.
pub struct Job {
    /// The request body.
    pub payload: JobPayload,
    /// When the request entered the coordinator (for latency stats).
    pub enqueued: Instant,
    /// Channel the flat f32 result (or error) is sent back on.
    pub resp: Sender<JobResult>,
}

/// Per-task result: the flat f32 output slice for this task.
pub type JobResult = Result<Vec<f32>, String>;

enum Msg {
    Work(Job),
    Stop,
}

/// Which worker a request is routed to within one robot's route group.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Route {
    /// Single-step RBD function batches (RNEA / FD / M⁻¹).
    Step(ArtifactFn),
    /// Trajectory rollouts through the workspace integrator.
    Traj,
}

/// Which datapath a trajectory route integrates q̈ with.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TrajLane {
    /// f64 workspace FD (ABA-composed) — the default.
    F64,
    /// Rounded fixed-point FD at this format (`QuantEngine`).
    Quant(QFormat),
    /// True-integer deferred FD at this format (`QIntEngine`) —
    /// rollouts on integer backends step through the qint path, not the
    /// rounded lane.
    Int(QFormat),
}

/// How one route executes its batches.
pub enum BackendSpec {
    /// Native f64 workspace engine: no artifacts, no external toolchain.
    Native {
        /// Robot served by this route.
        robot: Robot,
        /// RBD function this route evaluates.
        function: ArtifactFn,
        /// Batch size (requests coalesced per execution).
        batch: usize,
        /// Max chunks each assembled batch splits into on the global
        /// worker pool (`0` = one per pool worker, `1` = serial).
        /// Pooled execution is bitwise identical to serial.
        parallel: usize,
    },
    /// Quantized fixed-point engine (`quant::qrbd` kernels) at a
    /// per-robot format — precision as a serving knob.
    NativeQuant {
        /// Robot served by this route.
        robot: Robot,
        /// RBD function this route evaluates.
        function: ArtifactFn,
        /// Batch size (requests coalesced per execution).
        batch: usize,
        /// Fixed-point format every evaluation is rounded to.
        fmt: QFormat,
        /// Max chunks each assembled batch splits into on the global
        /// worker pool (`0` = one per pool worker, `1` = serial) —
        /// quantized routes fan out like native ones, bitwise identical
        /// to serial.
        parallel: usize,
        /// Opt-in M⁻¹ error compensation (fitted at route startup,
        /// applied on the M⁻¹ route; other functions ignore it).
        comp: bool,
    },
    /// True-integer `i64` engine (`quant::qint` kernels; FD/M⁻¹ on the
    /// division-deferring sweeps under a proved shift schedule). The
    /// engine is built at route startup from the scaling analysis — a
    /// rejected (robot, format) pair fails every request with the
    /// overflow witness instead of degrading to the rounded lane;
    /// registries validate at registration so served routes never hit
    /// that path.
    NativeInt {
        /// Robot served by this route.
        robot: Robot,
        /// RBD function this route evaluates.
        function: ArtifactFn,
        /// Batch size (requests coalesced per execution).
        batch: usize,
        /// Fixed-point format the integer lane carries.
        fmt: QFormat,
        /// Max chunks each assembled batch splits into on the global
        /// worker pool (`0` = one per pool worker, `1` = serial) —
        /// pooled execution is bitwise identical to serial.
        parallel: usize,
    },
    /// Trajectory-rollout route: FD + semi-implicit Euler unrolled
    /// server-side on the robot's serving lane.
    Trajectory {
        /// Robot served by this route.
        robot: Robot,
        /// Rollouts coalesced per drain.
        batch: usize,
        /// Which datapath computes q̈ each step.
        lane: TrajLane,
    },
    /// Compiled PJRT artifact (requires the `pjrt` feature + artifacts).
    #[cfg(feature = "pjrt")]
    Pjrt(ArtifactMeta),
}

impl BackendSpec {
    /// Name of the robot this spec serves (the routing key).
    pub fn robot_name(&self) -> &str {
        match self {
            BackendSpec::Native { robot, .. }
            | BackendSpec::NativeQuant { robot, .. }
            | BackendSpec::NativeInt { robot, .. }
            | BackendSpec::Trajectory { robot, .. } => &robot.name,
            #[cfg(feature = "pjrt")]
            BackendSpec::Pjrt(meta) => &meta.robot,
        }
    }

    /// The route this spec backs.
    pub fn route(&self) -> Route {
        match self {
            BackendSpec::Native { function, .. }
            | BackendSpec::NativeQuant { function, .. }
            | BackendSpec::NativeInt { function, .. } => Route::Step(*function),
            BackendSpec::Trajectory { .. } => Route::Traj,
            #[cfg(feature = "pjrt")]
            BackendSpec::Pjrt(meta) => Route::Step(meta.function),
        }
    }
}

/// Uniform executor interface the step-batching loop drives.
trait BatchExecutor {
    fn batch(&self) -> usize;
    fn arity(&self) -> usize;
    fn n(&self) -> usize;
    fn out_per_task(&self) -> usize;
    /// Whether the executor's shapes are compiled-in (PJRT) and partial
    /// batches must be padded to `batch()`. The native engines accept
    /// any row count ≤ batch, so partial batches cost only the real
    /// tasks.
    fn pad_to_batch(&self) -> bool;
    fn execute(&mut self, inputs: &[Vec<f32>]) -> Result<Vec<f32>, String>;
}

/// Adapter from the runtime [`DynamicsEngine`] trait (native f64 or
/// quantized) to the batching loop.
struct EngineExecutor(Box<dyn DynamicsEngine>);

impl BatchExecutor for EngineExecutor {
    fn batch(&self) -> usize {
        self.0.batch()
    }
    fn arity(&self) -> usize {
        self.0.function().arity()
    }
    fn n(&self) -> usize {
        self.0.n()
    }
    fn out_per_task(&self) -> usize {
        self.0.out_per_task()
    }
    fn pad_to_batch(&self) -> bool {
        false
    }
    fn execute(&mut self, inputs: &[Vec<f32>]) -> Result<Vec<f32>, String> {
        self.0.run(inputs).map_err(|e| e.0)
    }
}

/// PJRT client + engine pair; the engine is declared first so it drops
/// before the client that compiled it.
#[cfg(feature = "pjrt")]
struct PjrtExecutor {
    engine: crate::runtime::engine::Engine,
    _client: xla::PjRtClient,
}

#[cfg(feature = "pjrt")]
impl BatchExecutor for PjrtExecutor {
    fn batch(&self) -> usize {
        self.engine.meta.batch
    }
    fn arity(&self) -> usize {
        self.engine.meta.function.arity()
    }
    fn n(&self) -> usize {
        self.engine.n
    }
    fn out_per_task(&self) -> usize {
        self.engine.expected_output_len() / self.engine.meta.batch
    }
    fn pad_to_batch(&self) -> bool {
        true
    }
    fn execute(&mut self, inputs: &[Vec<f32>]) -> Result<Vec<f32>, String> {
        self.engine.run(inputs).map_err(|e| e.0)
    }
}

/// Routing front-end: `submit_to(robot, fn, …)` → per-(robot, function)
/// worker; `submit_traj(robot, …)` → the robot's trajectory worker.
pub struct Coordinator {
    routes: BTreeMap<(String, Route), Sender<Msg>>,
    default_robot: Option<String>,
    workers: Vec<JoinHandle<()>>,
    stats: Arc<Mutex<StatsInner>>,
}

impl Coordinator {
    /// Start one worker per backend spec. `n` is the robot DOF (used by
    /// the PJRT path to define operand shapes); `window_us` is the
    /// batching window (deadline to fill a batch). The first spec's
    /// robot becomes the default target of [`Coordinator::submit`].
    pub fn start(specs: Vec<BackendSpec>, n: usize, window_us: u64) -> Coordinator {
        let stats = Arc::new(Mutex::new(StatsInner::default()));
        let default_robot = specs.first().map(|s| s.robot_name().to_string());
        let mut routes = BTreeMap::new();
        let mut workers = Vec::new();
        for spec in specs {
            let (tx, rx) = channel::<Msg>();
            routes.insert((spec.robot_name().to_string(), spec.route()), tx);
            let st = Arc::clone(&stats);
            workers.push(std::thread::spawn(move || worker_loop(spec, n, window_us, rx, st)));
        }
        Coordinator { routes, default_robot, workers, stats }
    }

    /// Start a native coordinator serving `functions` for one robot, one
    /// worker (and one workspace) per function, plus a trajectory route.
    /// Routes execute serially; pass `BackendSpec::Native { parallel, .. }`
    /// specs to [`Coordinator::start`] (or use
    /// [`RobotRegistry::register_parallel`]) for intra-route parallelism.
    pub fn start_native(
        robot: &Robot,
        functions: &[(ArtifactFn, usize)],
        window_us: u64,
    ) -> Coordinator {
        let n = robot.dof();
        let traj_batch = functions.iter().map(|&(_, b)| b).max().unwrap_or(8);
        let mut specs: Vec<BackendSpec> = functions
            .iter()
            .map(|&(function, batch)| BackendSpec::Native {
                robot: robot.clone(),
                function,
                batch,
                parallel: 1,
            })
            .collect();
        specs.push(BackendSpec::Trajectory {
            robot: robot.clone(),
            batch: traj_batch,
            lane: TrajLane::F64,
        });
        Coordinator::start(specs, n, window_us)
    }

    /// Start a coordinator over a [`RobotRegistry`]: for every registered
    /// robot, one worker per RBD function on the robot's chosen backend
    /// plus one trajectory route.
    pub fn start_registry(registry: &RobotRegistry, window_us: u64) -> Coordinator {
        Coordinator::start(registry.specs(), 0, window_us)
    }

    /// Start a PJRT coordinator over compiled artifacts.
    #[cfg(feature = "pjrt")]
    pub fn start_pjrt(artifacts: Vec<ArtifactMeta>, n: usize, window_us: u64) -> Coordinator {
        let specs = artifacts.into_iter().map(BackendSpec::Pjrt).collect();
        Coordinator::start(specs, n, window_us)
    }

    /// Submit one step task to the **default** robot (the first spec
    /// passed to [`Coordinator::start`]); returns the channel the result
    /// arrives on. Single-robot deployments can ignore routing entirely.
    pub fn submit(&self, function: ArtifactFn, operands: Vec<Vec<f32>>) -> Receiver<JobResult> {
        match self.default_robot.clone() {
            Some(name) => self.submit_to(&name, function, operands),
            None => {
                let (tx, rx) = channel();
                let _ = tx.send(Err(format!("no executable for {}", function.name())));
                rx
            }
        }
    }

    /// Submit one step task for a named robot.
    pub fn submit_to(
        &self,
        robot: &str,
        function: ArtifactFn,
        operands: Vec<Vec<f32>>,
    ) -> Receiver<JobResult> {
        self.dispatch(robot, Route::Step(function), JobPayload::Step(operands))
    }

    /// Submit one trajectory rollout for a named robot. The response is
    /// flat f32 of length `2·H·N`: H q-rows then H q̇-rows (see
    /// [`NativeEngine::rollout`]).
    pub fn submit_traj(&self, robot: &str, req: TrajRequest) -> Receiver<JobResult> {
        self.dispatch(robot, Route::Traj, JobPayload::Traj(req))
    }

    fn dispatch(&self, robot: &str, route: Route, payload: JobPayload) -> Receiver<JobResult> {
        let (tx, rx) = channel();
        match self.routes.get(&(robot.to_string(), route)) {
            Some(sender) => {
                let job = Job { payload, enqueued: Instant::now(), resp: tx };
                // If the worker is gone the send fails and tx is dropped
                // with it — recv() errors out on the caller side.
                let _ = sender.send(Msg::Work(job));
            }
            None => {
                let what = match route {
                    Route::Step(f) => format!("no route for robot '{robot}' / {}", f.name()),
                    Route::Traj => format!("no trajectory route for robot '{robot}'"),
                };
                let _ = tx.send(Err(what));
            }
        }
        rx
    }

    /// Names of the robots this coordinator routes for (sorted, deduped).
    pub fn robots(&self) -> Vec<String> {
        let mut names: Vec<String> = self.routes.keys().map(|(r, _)| r.clone()).collect();
        names.dedup();
        names
    }

    /// Snapshot of the aggregate serving statistics.
    pub fn stats(&self) -> ServeStats {
        self.stats.lock().unwrap().snapshot()
    }

    /// Stop every worker (flushing queued work) and join the threads.
    pub fn shutdown(self) {
        for (_, tx) in &self.routes {
            let _ = tx.send(Msg::Stop);
        }
        drop(self.routes);
        for w in self.workers {
            let _ = w.join();
        }
    }
}

/// Worker: owns its executor. PJRT handles are not `Send`, and the native
/// engines' workspaces are deliberately thread-local, so everything is
/// created inside the thread.
fn worker_loop(
    spec: BackendSpec,
    n: usize,
    window_us: u64,
    rx: Receiver<Msg>,
    stats: Arc<Mutex<StatsInner>>,
) {
    let _ = n; // used only by the pjrt arm
    let window = Duration::from_micros(window_us);
    match spec {
        BackendSpec::Native { robot, function, batch, parallel } => {
            let exec = EngineExecutor(Box::new(NativeEngine::with_parallelism(
                robot, function, batch, parallel,
            )));
            step_worker(Box::new(exec), window, rx, stats);
        }
        BackendSpec::NativeQuant { robot, function, batch, fmt, parallel, comp } => {
            let exec = EngineExecutor(Box::new(QuantEngine::with_options(
                robot, function, batch, fmt, parallel, comp,
            )));
            step_worker(Box::new(exec), window, rx, stats);
        }
        BackendSpec::NativeInt { robot, function, batch, fmt, parallel } => {
            // The engine runs the scaling analysis; a rejected pair
            // fails every request with the witness — the route never
            // falls back to the rounded-f64 lane.
            match QIntEngine::with_parallelism(robot, function, batch, fmt, parallel) {
                Ok(engine) => {
                    step_worker(Box::new(EngineExecutor(Box::new(engine))), window, rx, stats)
                }
                Err(e) => fail_all(&rx, &e.0),
            }
        }
        BackendSpec::Trajectory { robot, batch, lane } => {
            let engine: Box<dyn DynamicsEngine> = match lane {
                TrajLane::Quant(f) => Box::new(QuantEngine::new(robot, ArtifactFn::Fd, batch, f)),
                TrajLane::Int(f) => match QIntEngine::new(robot, ArtifactFn::Fd, batch, f) {
                    Ok(engine) => Box::new(engine),
                    Err(e) => {
                        fail_all(&rx, &e.0);
                        return;
                    }
                },
                TrajLane::F64 => Box::new(NativeEngine::new(robot, ArtifactFn::Fd, batch)),
            };
            traj_worker(engine, batch, window, rx, stats);
        }
        #[cfg(feature = "pjrt")]
        BackendSpec::Pjrt(meta) => {
            let client = match xla::PjRtClient::cpu() {
                Ok(c) => c,
                Err(e) => {
                    fail_all(&rx, &format!("pjrt client: {e:?}"));
                    return;
                }
            };
            let engine = match crate::runtime::engine::Engine::load(&client, meta, n) {
                Ok(e) => e,
                Err(e) => {
                    fail_all(&rx, &e.0);
                    return;
                }
            };
            step_worker(Box::new(PjrtExecutor { engine, _client: client }), window, rx, stats);
        }
    }
}

/// Step-batch loop: block for the first job, drain within the window,
/// execute as one batch.
fn step_worker(
    mut exec: Box<dyn BatchExecutor>,
    window: Duration,
    rx: Receiver<Msg>,
    stats: Arc<Mutex<StatsInner>>,
) {
    let b = exec.batch();
    let mut queue: Vec<Job> = Vec::with_capacity(b);
    loop {
        match rx.recv() {
            Ok(Msg::Work(j)) => queue.push(j),
            Ok(Msg::Stop) | Err(_) => break,
        }
        if !drain_window(&rx, &mut queue, b, window) {
            flush(exec.as_mut(), &mut queue, &stats);
            return;
        }
        flush(exec.as_mut(), &mut queue, &stats);
    }
    flush(exec.as_mut(), &mut queue, &stats);
}

/// Trajectory loop: drain rollout requests within the window and execute
/// them back-to-back on one engine (one workspace, zero per-step
/// dispatch).
fn traj_worker(
    mut engine: Box<dyn DynamicsEngine>,
    cap: usize,
    window: Duration,
    rx: Receiver<Msg>,
    stats: Arc<Mutex<StatsInner>>,
) {
    let cap = cap.max(1);
    let mut queue: Vec<Job> = Vec::with_capacity(cap);
    loop {
        match rx.recv() {
            Ok(Msg::Work(j)) => queue.push(j),
            Ok(Msg::Stop) | Err(_) => break,
        }
        if !drain_window(&rx, &mut queue, cap, window) {
            flush_traj(engine.as_mut(), &mut queue, &stats, cap);
            return;
        }
        flush_traj(engine.as_mut(), &mut queue, &stats, cap);
    }
    flush_traj(engine.as_mut(), &mut queue, &stats, cap);
}

/// Collect further work until `cap` jobs are queued or the window
/// expires. Returns `false` when the worker should flush and exit (Stop
/// received or all senders gone).
fn drain_window(rx: &Receiver<Msg>, queue: &mut Vec<Job>, cap: usize, window: Duration) -> bool {
    let deadline = Instant::now() + window;
    while queue.len() < cap {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(Msg::Work(j)) => queue.push(j),
            Ok(Msg::Stop) => return false,
            Err(_) => break,
        }
    }
    true
}

/// Execute the queued step jobs as one batch and fan results out.
fn flush(exec: &mut dyn BatchExecutor, queue: &mut Vec<Job>, stats: &Arc<Mutex<StatsInner>>) {
    if queue.is_empty() {
        return;
    }
    let b = exec.batch();
    let n = exec.n();
    let arity = exec.arity();

    // Reject malformed jobs up front: a bad task must fail alone instead
    // of poisoning (or panicking) the whole assembled batch. Single
    // in-place pass (answering rejects as they are dropped) — the old
    // `queue.remove(k)` loop was O(n²) under a malformed burst.
    queue.retain(|job| {
        let ok = match &job.payload {
            JobPayload::Step(ops) => ops.len() == arity && ops.iter().all(|op| op.len() == n),
            JobPayload::Traj(_) => false,
        };
        if !ok {
            let _ = job
                .resp
                .send(Err(format!("bad operands: expected {arity} arrays of length {n}")));
        }
        ok
    });
    if queue.is_empty() {
        return;
    }
    let fill = queue.len().min(b);

    // Assemble operands, padding the tail by repeating the last task
    // (keeps the padded rows numerically benign).
    let mut inputs: Vec<Vec<f32>> = vec![Vec::with_capacity(b * n); arity];
    for job in queue.iter().take(fill) {
        if let JobPayload::Step(ops) = &job.payload {
            for (k, op) in ops.iter().enumerate().take(arity) {
                inputs[k].extend_from_slice(op);
            }
        }
    }
    if exec.pad_to_batch() {
        for _ in fill..b {
            for input in inputs.iter_mut() {
                let last: Vec<f32> = input[(fill - 1) * n..fill * n].to_vec();
                input.extend_from_slice(&last);
            }
        }
    }

    let t0 = Instant::now();
    let result = exec.execute(&inputs);
    let exec_us = t0.elapsed().as_micros() as f64;

    let out_per_task = exec.out_per_task();
    match result {
        Ok(flat) => {
            for (i, job) in queue.drain(..).enumerate() {
                if i < fill {
                    let chunk = flat[i * out_per_task..(i + 1) * out_per_task].to_vec();
                    let wait_us = job.enqueued.elapsed().as_micros() as f64;
                    stats.lock().unwrap().record(wait_us);
                    let _ = job.resp.send(Ok(chunk));
                } else {
                    let _ = job.resp.send(Err("overflow past batch".into()));
                }
            }
        }
        Err(e) => {
            for job in queue.drain(..) {
                let _ = job.resp.send(Err(e.clone()));
            }
        }
    }
    // Record the batch on BOTH paths: a failed execution still consumed
    // a batch slot and wall clock, and skipping it skewed `mean_fill` /
    // `mean_exec_us` against `batches` under error bursts.
    stats.lock().unwrap().record_batch(fill as f64 / b as f64, exec_us);
}

/// Execute the queued trajectory rollouts back-to-back and fan results
/// out.
fn flush_traj(
    engine: &mut dyn DynamicsEngine,
    queue: &mut Vec<Job>,
    stats: &Arc<Mutex<StatsInner>>,
    cap: usize,
) {
    if queue.is_empty() {
        return;
    }
    let fill = queue.len().min(cap) as f64 / cap as f64;
    let t0 = Instant::now();
    for job in queue.drain(..) {
        let result = match &job.payload {
            JobPayload::Traj(req) => {
                engine.rollout(&req.q0, &req.qd0, &req.tau, req.dt).map_err(|e| e.0)
            }
            JobPayload::Step(_) => Err("step operands sent to a trajectory route".to_string()),
        };
        if result.is_ok() {
            let wait_us = job.enqueued.elapsed().as_micros() as f64;
            stats.lock().unwrap().record(wait_us);
        }
        let _ = job.resp.send(result);
    }
    stats.lock().unwrap().record_batch(fill, t0.elapsed().as_micros() as f64);
}

/// Answer every queued (and future) request on this route with the same
/// error — the loud-failure path for routes whose engine refused to
/// build (rejected qint formats, missing PJRT artifacts).
fn fail_all(rx: &Receiver<Msg>, err: &str) {
    while let Ok(msg) = rx.recv() {
        match msg {
            Msg::Work(j) => {
                let _ = j.resp.send(Err(err.to_string()));
            }
            Msg::Stop => break,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::builtin_robot;

    #[test]
    fn submit_unknown_function_errors_fast() {
        let coord = Coordinator::start(Vec::new(), 7, 100);
        let rx = coord.submit(ArtifactFn::Minv, vec![vec![0.0; 7]]);
        let res = rx.recv().unwrap();
        assert!(res.is_err());
        coord.shutdown();
    }

    #[test]
    fn native_worker_answers_without_artifacts() {
        let robot = builtin_robot("iiwa").unwrap();
        let n = robot.dof();
        let coord = Coordinator::start_native(&robot, &[(ArtifactFn::Rnea, 8)], 100);
        let rx = coord.submit(ArtifactFn::Rnea, vec![vec![0.1; n]; 3]);
        let res = rx.recv().expect("worker must answer");
        let out = res.expect("native execution succeeds");
        assert_eq!(out.len(), n);
        assert!(out.iter().all(|x| x.is_finite()));
        coord.shutdown();
    }

    #[test]
    fn native_worker_reports_shape_errors() {
        let robot = builtin_robot("iiwa").unwrap();
        let coord = Coordinator::start_native(&robot, &[(ArtifactFn::Rnea, 4)], 100);
        // Wrong arity: one operand instead of three.
        let rx = coord.submit(ArtifactFn::Rnea, vec![vec![0.0; 7]]);
        let res = rx.recv().expect("worker must answer even on failure");
        assert!(res.is_err());
        coord.shutdown();
    }

    #[test]
    fn unknown_robot_errors_fast() {
        let robot = builtin_robot("iiwa").unwrap();
        let coord = Coordinator::start_native(&robot, &[(ArtifactFn::Rnea, 4)], 100);
        let rx = coord.submit_to("panda", ArtifactFn::Rnea, vec![vec![0.0; 7]; 3]);
        assert!(rx.recv().unwrap().is_err());
        coord.shutdown();
    }

    #[test]
    fn trajectory_route_answers() {
        let robot = builtin_robot("iiwa").unwrap();
        let n = robot.dof();
        let coord = Coordinator::start_native(&robot, &[(ArtifactFn::Fd, 8)], 100);
        let h = 5;
        let req = TrajRequest {
            q0: vec![0.1; n],
            qd0: vec![0.0; n],
            tau: vec![0.0; h * n],
            dt: 1e-3,
        };
        let rx = coord.submit_traj("iiwa", req);
        let out = rx.recv().expect("answer").expect("rollout ok");
        assert_eq!(out.len(), 2 * h * n);
        assert!(out.iter().all(|x| x.is_finite()));
        // Malformed rollouts fail alone.
        let bad = TrajRequest { q0: vec![0.0; n - 1], qd0: vec![0.0; n], tau: vec![0.0; n], dt: 1e-3 };
        let rx = coord.submit_traj("iiwa", bad);
        assert!(rx.recv().unwrap().is_err());
        coord.shutdown();
    }
}
